; STREAM triad over far memory: a[i] = b[i] + scalar * c[i], then a
; checksum reduction so the run is self-validating. Exercises sized
; loads/stores, .region attribution, the ROI window, and .arg expressions.
.program stream_triad
.arg n 1024
.arg scalar 3
; sum(a) = sum(i + scalar*2i) = (1+2*scalar) * n*(n-1)/2, paren-free:
.check LOCAL_BASE $n/2*7*$n-$n/2*7

.region setup
  li r5, $n
  li r1, 0                  ; i
  li r2, FAR_BASE           ; &b[0]
  li r3, FAR_BASE+0x100000  ; &c[0]
init:
  st.8 r1, 0(r2)            ; b[i] = i
  slli r6, r1, 1
  st.8 r6, 0(r3)            ; c[i] = 2*i
  addi r2, r2, 8
  addi r3, r3, 8
  addi r1, r1, 1
  blt r1, r5, init

.region main
  li r1, 0
  li r2, FAR_BASE
  li r3, FAR_BASE+0x100000
  li r4, FAR_BASE+0x200000  ; &a[0]
  li r8, $scalar
  roi.begin
triad:
  ld.8 r6, 0(r2)
  ld.8 r7, 0(r3)
  mul r7, r7, r8
  add r6, r6, r7
  st.8 r6, 0(r4)
  addi r2, r2, 8
  addi r3, r3, 8
  addi r4, r4, 8
  addi r1, r1, 1
  blt r1, r5, triad
  roi.end

  li r1, 0                  ; checksum pass over a[]
  li r4, FAR_BASE+0x200000
  li r9, 0
sum:
  ld.8 r6, 0(r4)
  add r9, r9, r6
  addi r4, r4, 8
  addi r1, r1, 1
  blt r1, r5, sum
  li r6, LOCAL_BASE
  st.8 r9, 0(r6)
  halt
