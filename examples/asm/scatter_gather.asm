; Gather reduction through a permuted index: sum src[(7i+3) % n] for all
; i. gcd(7, n) = 1 makes the index a bijection, so the sum equals
; sum(0..n-1) = n*(n-1)/2 regardless of order.
.program scatter_gather
.arg n 1024
.check LOCAL_BASE $n*$n/2-$n/2

.region setup
  li r1, 0                  ; j
  li r3, $n
  li r2, FAR_BASE           ; &src[0]
init:
  st.8 r1, 0(r2)            ; src[j] = j
  addi r2, r2, 8
  addi r1, r1, 1
  blt r1, r3, init

.region main
  li r1, 0                  ; i
  li r2, FAR_BASE
  li r9, 0                  ; sum
  roi.begin
gather:
  slli r4, r1, 3            ; 7i = 8i - i
  sub r4, r4, r1
  addi r4, r4, 3
  andi r4, r4, $n-1
  slli r4, r4, 3
  add r4, r4, r2
  ld.8 r5, 0(r4)
  add r9, r9, r5
  addi r1, r1, 1
  blt r1, r3, gather
  roi.end
  li r6, LOCAL_BASE
  st.8 r9, 0(r6)
  halt
