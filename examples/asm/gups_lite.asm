; GUPS-style random-access updates: table[h(i) % tbl_words] ^= i with a
; splitmix-style index hash, then an XOR fold over the table. Every i is
; XORed into exactly one slot, so the fold equals XOR(0..updates-1) = 0
; when updates is a multiple of 4.
.program gups_lite
.arg updates 2048
.arg tbl_words 1024
.check LOCAL_BASE 0

.region setup
  li r2, FAR_BASE           ; zero the table
  li r7, 0
  li r8, $tbl_words
  li r9, 0
zinit:
  st.8 r9, 0(r2)
  addi r2, r2, 8
  addi r7, r7, 1
  blt r7, r8, zinit

.region main
  li r1, 0                  ; i
  li r3, $updates
  li r2, FAR_BASE
  li r20, 0x9E3779B97F4A7C15
  li r21, 0xBF58476D1CE4E5B9
  roi.begin
update:
  mul r4, r1, r20           ; h = splitmix-ish(i)
  srli r5, r4, 31
  xor r4, r4, r5
  mul r4, r4, r21
  srli r5, r4, 27
  xor r4, r4, r5
  andi r4, r4, $tbl_words-1
  slli r4, r4, 3
  add r4, r4, r2
  ld.8 r5, 0(r4)            ; table[h] ^= i
  xor r5, r5, r1
  st.8 r5, 0(r4)
  addi r1, r1, 1
  blt r1, r3, update
  roi.end

  li r2, FAR_BASE           ; XOR-fold the table
  li r7, 0
  li r6, 0
fold:
  ld.8 r5, 0(r2)
  xor r6, r6, r5
  addi r2, r2, 8
  addi r7, r7, 1
  blt r7, r8, fold
  li r9, LOCAL_BASE
  st.8 r6, 0(r9)
  halt
