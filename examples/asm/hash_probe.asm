; Open-addressing hash table in far memory: 16-byte slots [key, value],
; linear probing. Phase 1 inserts `keys` distinct nonzero keys (a
; splitmix-style hash of i+1, which is injective); phase 2 looks every
; key back up and sums the values: sum(1..keys).
.program hash_probe
.arg keys 256
.arg slots 2048
.check LOCAL_BASE $keys*$keys/2+$keys/2

.region setup
  li r2, FAR_BASE           ; zero the key fields
  li r5, 0
  li r6, $slots
  li r7, 0
zinit:
  st.8 r7, 0(r2)
  addi r2, r2, 16
  addi r5, r5, 1
  blt r5, r6, zinit

  li r1, 0                  ; i
  li r3, $keys
  li r2, FAR_BASE
  li r20, 0x9E3779B97F4A7C15
  li r21, 0xBF58476D1CE4E5B9
insert:
  addi r4, r1, 1            ; key = splitmix-ish(i+1), nonzero
  mul r4, r4, r20
  srli r5, r4, 31
  xor r4, r4, r5
  mul r4, r4, r21
  srli r5, r4, 27
  xor r4, r4, r5
  andi r6, r4, $slots-1     ; slot
ins_probe:
  slli r7, r6, 4
  add r7, r7, r2
  ld.8 r8, 0(r7)
  beq r8, zero, ins_put     ; empty slot -> claim it
  addi r6, r6, 1
  andi r6, r6, $slots-1
  j ins_probe
ins_put:
  st.8 r4, 0(r7)
  addi r9, r1, 1
  st.8 r9, 8(r7)            ; value = i+1
  addi r1, r1, 1
  blt r1, r3, insert

.region main
  li r1, 0
  li r11, 0                 ; sum
  roi.begin
lookup:
  addi r4, r1, 1            ; recompute key i+1
  mul r4, r4, r20
  srli r5, r4, 31
  xor r4, r4, r5
  mul r4, r4, r21
  srli r5, r4, 27
  xor r4, r4, r5
  andi r6, r4, $slots-1
lk_probe:
  slli r7, r6, 4
  add r7, r7, r2
  ld.8 r8, 0(r7)
  beq r8, r4, lk_hit        ; keys are all present: must terminate
  addi r6, r6, 1
  andi r6, r6, $slots-1
  j lk_probe
lk_hit:
  ld.8 r9, 8(r7)
  add r11, r11, r9
  addi r1, r1, 1
  blt r1, r3, lookup
  roi.end
  li r5, LOCAL_BASE
  st.8 r11, 0(r5)
  halt
