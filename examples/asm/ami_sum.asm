; Asynchronous far-memory reduction through the AMI instructions: stage
; each 8-byte word into an SPM slot with `aload`, poll `getfin` until the
; request completes, then read the slot and accumulate. Single request in
; flight — the protocol-conformance baseline for the verifier (issue /
; drain / read-after-completion all clean). Needs --config amu.
; sum(far[i]) = sum(1..n).
.program ami_sum
.arg n 256
.check LOCAL_BASE $n*$n/2+$n/2

.region setup
  li r1, 0                  ; i
  li r2, $n
  li r3, FAR_BASE
init:
  addi r4, r1, 1
  st.8 r4, 0(r3)            ; far[i] = i+1
  addi r3, r3, 8
  addi r1, r1, 1
  blt r1, r2, init

  li r3, FAR_BASE           ; hand the staged lines back to far memory
  li r1, 0
  li r2, $n/8               ; n words / 8 words-per-64B-line
fl:
  flush 0(r3)
  addi r3, r3, 64
  addi r1, r1, 1
  blt r1, r2, fl

.region main
  li r1, 8
  cfgwr r1, granularity     ; 8-byte transfers
  li r2, SPM_BASE           ; staging slot
  li r3, FAR_BASE           ; cursor
  li r4, FAR_BASE+$n*8      ; end
  li r9, 0                  ; sum
  roi.begin
loop:
  aload r6, r2, r3          ; issue: far[cursor] -> SPM slot
wait:
  getfin r7                 ; drain completions
  beq r7, zero, wait
  ld.8 r8, 0(r2)            ; slot is safe after the drain
  add r9, r9, r8
  addi r3, r3, 8
  blt r3, r4, loop
  roi.end
  li r5, LOCAL_BASE
  st.8 r9, 0(r5)
  halt
