; Linked-list walk: 16-byte nodes [value, next] laid out contiguously in
; far memory, last node's next = 0. The walk is a dependent-load chain
; like pchase but with a data payload: sum(values) = sum(1..nodes).
.program ll_sum
.arg nodes 256
.check LOCAL_BASE $nodes*$nodes/2+$nodes/2

.region setup
  li r1, 0                  ; i
  li r3, $nodes
  li r2, FAR_BASE           ; &node[i]
init:
  addi r4, r1, 1
  st.8 r4, 0(r2)            ; value = i+1
  beq r4, r3, last          ; i+1 == nodes -> tail
  addi r6, r2, 16
  j cont
last:
  li r6, 0
cont:
  st.8 r6, 8(r2)            ; next
  addi r2, r2, 16
  addi r1, r1, 1
  blt r1, r3, init

.region main
  li r8, FAR_BASE           ; cursor
  li r9, 0                  ; sum
  roi.begin
walk:
  ld.8 r4, 0(r8)
  add r9, r9, r4
  ld.8 r8, 8(r8)
  bne r8, zero, walk
  roi.end
  li r5, LOCAL_BASE
  st.8 r9, 0(r5)
  halt
