; Pointer chase over a strided ring in far memory: the classic
; latency-bound dependent-load chain. node[i] = &node[(i+stride) % nodes];
; the chase walks `steps` hops from node 0 and stores the final cursor.
; steps*stride mod nodes = 1000*17 mod 512 = 104 -> FAR_BASE + 104*8.
.program pchase
.arg nodes 512
.arg steps 1000
.arg stride 17
.check LOCAL_BASE FAR_BASE+104*8

.region setup
  li r1, 0                  ; i
  li r3, $nodes
  li r2, FAR_BASE           ; &node[i]
  li r5, FAR_BASE
init:
  addi r4, r1, $stride
  andi r4, r4, $nodes-1
  slli r4, r4, 3
  add r4, r5, r4
  st.8 r4, 0(r2)
  addi r2, r2, 8
  addi r1, r1, 1
  blt r1, r3, init

.region main
  li r6, 0                  ; step
  li r7, $steps
  li r8, FAR_BASE           ; cursor
  roi.begin
chase:
  ld.8 r8, 0(r8)
  addi r6, r6, 1
  blt r6, r7, chase
  roi.end
  li r9, LOCAL_BASE
  st.8 r8, 0(r9)
  halt
