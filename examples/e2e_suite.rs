//! End-to-end driver: proves all layers compose on a real small workload.
//!
//! 1. Loads the AOT payload engine (jax/pallas -> HLO text -> PJRT) and
//!    validates a GUPS payload batch against the host oracle.
//! 2. Runs the full benchmark suite on the cycle-level simulator at 1 µs,
//!    baseline vs AMU, validating every benchmark's architectural result.
//! 3. Reports the paper's headline metrics (mean speedup, GUPS @5 µs MLP).
//!
//!     make artifacts && cargo run --release --example e2e_suite

use amu_sim::config::SimConfig;
use amu_sim::runtime::{hash_mult_host, Runtime, GUPS_BATCH};
use amu_sim::session::{RunRequest, RunResult};
use amu_sim::util::geomean;
use amu_sim::workloads::{Variant, ALL};

fn run(bench: &str, cfg: SimConfig, variant: Variant, lat: f64) -> RunResult {
    RunRequest::bench(bench)
        .config(cfg)
        .variant(variant)
        .latency_ns(lat)
        .no_jitter()
        .run()
        .unwrap_or_else(|e| panic!("{bench}: {e}"))
}

fn main() {
    // --- Layer composition: PJRT payload engine ---
    match Runtime::load_default() {
        Ok(rt) => {
            let vals: Vec<i32> = (0..GUPS_BATCH as i32).collect();
            let idxs: Vec<i32> = (0..GUPS_BATCH as i32).map(|i| i ^ 0x5A5A).collect();
            let out = rt.gups_step(&vals, &idxs).expect("gups_step");
            let ok = (0..GUPS_BATCH)
                .all(|i| out[i] == vals[i] ^ (hash_mult_host(idxs[i] as u32) as i32));
            println!(
                "[1/3] payload engine ({}): gups_step batch of {} -> {}",
                rt.platform(),
                GUPS_BATCH,
                if ok { "OK" } else { "MISMATCH" }
            );
            assert!(ok);
        }
        Err(e) => println!("[1/3] payload engine unavailable ({e}); run `make artifacts`"),
    }

    // --- Full suite at 1 us ---
    println!("[2/3] full benchmark suite @1us (test scale), baseline vs AMU:");
    let mut speedups = Vec::new();
    for name in ALL {
        let base = run(name, SimConfig::baseline(), Variant::Sync, 1000.0);
        let amu = run(name, SimConfig::amu(), Variant::Amu, 1000.0);
        let s = base.measured_cycles as f64 / amu.measured_cycles as f64;
        speedups.push(s);
        println!(
            "  {:>7}: baseline {:>9}c  amu {:>9}c  speedup {:>6.2}x  (validated)",
            name, base.measured_cycles, amu.measured_cycles, s
        );
    }
    println!(
        "  geomean speedup @1us: {:.2}x (paper: 2.42x at paper scale)",
        geomean(&speedups).unwrap()
    );

    // --- Headline: GUPS at 5 us ---
    let base = run("gups", SimConfig::baseline(), Variant::Sync, 5000.0);
    let amu = run("gups", SimConfig::amu(), Variant::Amu, 5000.0);
    println!(
        "[3/3] GUPS @5us: speedup {:.2}x, avg MLP {:.1}, peak in-flight {} (paper: 26.86x, >130)",
        base.measured_cycles as f64 / amu.measured_cycles as f64,
        amu.mlp,
        amu.peak_inflight
    );
}
