//! Domain scenario: a Redis-like KV server with collision chains in far
//! memory, served by request-concurrent coroutines (the paper's Redis
//! port). Reports throughput (requests per million cycles) and the mean
//! request latency baseline-vs-AMU.
//!
//!     cargo run --release --example kv_server

use amu_sim::config::SimConfig;
use amu_sim::workloads::{build, Scale, Variant};

fn main() {
    println!("KV serving (YCSB-B-like, 95% GET / 5% SET, zipf keys)");
    println!(
        "{:>9} {:>14} {:>14} {:>12}",
        "lat(us)", "base req/Mcyc", "amu req/Mcyc", "throughput x"
    );
    // 32 concurrent client coroutines x 4 ops each at test scale.
    let requests = 32.0 * 4.0;
    for lat in [200.0, 1000.0, 5000.0] {
        let mut b = SimConfig::baseline().with_far_latency_ns(lat);
        b.far.jitter_frac = 0.0;
        let mut a = SimConfig::amu().with_far_latency_ns(lat);
        a.far.jitter_frac = 0.0;
        let base = build("redis", &b, Variant::Sync, Scale::Test).run(&b).unwrap();
        let amu = build("redis", &a, Variant::Amu, Scale::Test).run(&a).unwrap();
        let tb = requests / (base.stats.measured_cycles as f64 / 1e6);
        let ta = requests / (amu.stats.measured_cycles as f64 / 1e6);
        println!("{:>9.1} {:>14.1} {:>14.1} {:>11.2}x", lat / 1000.0, tb, ta, ta / tb);
    }
}
