//! Domain scenario: a Redis-like KV server with collision chains in far
//! memory, served by request-concurrent coroutines (the paper's Redis
//! port). Reports throughput (requests per million cycles) and the mean
//! request latency baseline-vs-AMU.
//!
//!     cargo run --release --example kv_server

use amu_sim::config::SimConfig;
use amu_sim::session::RunRequest;
use amu_sim::workloads::Variant;

fn main() {
    println!("KV serving (YCSB-B-like, 95% GET / 5% SET, zipf keys)");
    println!(
        "{:>9} {:>14} {:>14} {:>12}",
        "lat(us)", "base req/Mcyc", "amu req/Mcyc", "throughput x"
    );
    // 32 concurrent client coroutines x 4 ops each at test scale.
    let requests = 32.0 * 4.0;
    for lat in [200.0, 1000.0, 5000.0] {
        let base = RunRequest::bench("redis")
            .config(SimConfig::baseline())
            .variant(Variant::Sync)
            .latency_ns(lat)
            .no_jitter()
            .run()
            .unwrap();
        let amu = RunRequest::bench("redis")
            .config(SimConfig::amu())
            .variant(Variant::Amu)
            .latency_ns(lat)
            .no_jitter()
            .run()
            .unwrap();
        let tb = requests / (base.measured_cycles as f64 / 1e6);
        let ta = requests / (amu.measured_cycles as f64 / 1e6);
        println!("{:>9.1} {:>14.1} {:>14.1} {:>11.2}x", lat / 1000.0, tb, ta, ta / tb);
    }
}
