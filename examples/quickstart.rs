//! Quickstart: simulate GUPS on the baseline and on the AMU at 1 µs far
//! memory latency and print the speedup — the paper's elevator pitch.
//!
//!     cargo run --release --example quickstart

use amu_sim::config::SimConfig;
use amu_sim::workloads::{build, Scale, Variant};

fn main() {
    let latency_ns = 1000.0;
    let base_cfg = SimConfig::baseline().with_far_latency_ns(latency_ns);
    let amu_cfg = SimConfig::amu().with_far_latency_ns(latency_ns);

    println!("GUPS @ {latency_ns} ns additional far-memory latency");
    let base = build("gups", &base_cfg, Variant::Sync, Scale::Test)
        .run(&base_cfg)
        .expect("baseline run");
    println!(
        "  baseline : {:>9} cycles  ipc={:.2}  mlp={:.1}",
        base.stats.measured_cycles,
        base.stats.ipc(),
        base.stats.mlp()
    );
    let amu = build("gups", &amu_cfg, Variant::Amu, Scale::Test)
        .run(&amu_cfg)
        .expect("amu run");
    println!(
        "  AMU      : {:>9} cycles  ipc={:.2}  mlp={:.1}  peak in-flight={}",
        amu.stats.measured_cycles,
        amu.stats.ipc(),
        amu.stats.mlp(),
        amu.stats.far_inflight.max
    );
    println!(
        "  speedup  : {:.2}x",
        base.stats.measured_cycles as f64 / amu.stats.measured_cycles as f64
    );
}
