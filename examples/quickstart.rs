//! Quickstart: simulate GUPS on the baseline and on the AMU at 1 µs far
//! memory latency and print the speedup — the paper's elevator pitch.
//!
//!     cargo run --release --example quickstart

use amu_sim::config::SimConfig;
use amu_sim::session::RunRequest;
use amu_sim::workloads::Variant;

fn main() {
    let latency_ns = 1000.0;
    println!("GUPS @ {latency_ns} ns additional far-memory latency");
    let base = RunRequest::bench("gups")
        .config(SimConfig::baseline())
        .variant(Variant::Sync)
        .latency_ns(latency_ns)
        .run()
        .expect("baseline run");
    println!(
        "  baseline : {:>9} cycles  ipc={:.2}  mlp={:.1}",
        base.measured_cycles, base.ipc, base.mlp
    );
    let amu = RunRequest::bench("gups")
        .config(SimConfig::amu())
        .variant(Variant::Amu)
        .latency_ns(latency_ns)
        .run()
        .expect("amu run");
    println!(
        "  AMU      : {:>9} cycles  ipc={:.2}  mlp={:.1}  peak in-flight={}",
        amu.measured_cycles, amu.ipc, amu.mlp, amu.peak_inflight
    );
    println!(
        "  speedup  : {:.2}x",
        base.measured_cycles as f64 / amu.measured_cycles as f64
    );
}
