//! Domain scenario: graph analytics over far memory. Runs Graph500-style
//! BFS with the adjacency lists in far memory, comparing the synchronous
//! baseline against the AMU coroutine port across latencies — the "big
//! data with poor locality" workload class the paper's introduction
//! motivates.
//!
//!     cargo run --release --example graph_analytics

use amu_sim::config::SimConfig;
use amu_sim::workloads::{build, Scale, Variant};

fn main() {
    println!("BFS (V=512, E=8192 undirected), adjacency in far memory");
    println!("{:>9} {:>12} {:>12} {:>8}", "lat(us)", "baseline", "amu", "speedup");
    for lat in [200.0, 500.0, 1000.0, 2000.0, 5000.0] {
        let mut b = SimConfig::baseline().with_far_latency_ns(lat);
        b.far.jitter_frac = 0.0;
        let mut a = SimConfig::amu().with_far_latency_ns(lat);
        a.far.jitter_frac = 0.0;
        let base = build("bfs", &b, Variant::Sync, Scale::Test).run(&b).unwrap();
        let amu = build("bfs", &a, Variant::Amu, Scale::Test).run(&a).unwrap();
        println!(
            "{:>9.1} {:>12} {:>12} {:>7.2}x",
            lat / 1000.0,
            base.stats.measured_cycles,
            amu.stats.measured_cycles,
            base.stats.measured_cycles as f64 / amu.stats.measured_cycles as f64
        );
    }
}
