//! Domain scenario: graph analytics over far memory. Runs Graph500-style
//! BFS with the adjacency lists in far memory, comparing the synchronous
//! baseline against the AMU coroutine port across latencies — the "big
//! data with poor locality" workload class the paper's introduction
//! motivates.
//!
//!     cargo run --release --example graph_analytics

use amu_sim::config::SimConfig;
use amu_sim::session::RunRequest;
use amu_sim::workloads::Variant;

fn main() {
    println!("BFS (V=512, E=8192 undirected), adjacency in far memory");
    println!("{:>9} {:>12} {:>12} {:>8}", "lat(us)", "baseline", "amu", "speedup");
    for lat in [200.0, 500.0, 1000.0, 2000.0, 5000.0] {
        let base = RunRequest::bench("bfs")
            .config(SimConfig::baseline())
            .variant(Variant::Sync)
            .latency_ns(lat)
            .no_jitter()
            .run()
            .unwrap();
        let amu = RunRequest::bench("bfs")
            .config(SimConfig::amu())
            .variant(Variant::Amu)
            .latency_ns(lat)
            .no_jitter()
            .run()
            .unwrap();
        println!(
            "{:>9.1} {:>12} {:>12} {:>7.2}x",
            lat / 1000.0,
            base.measured_cycles,
            amu.measured_cycles,
            base.measured_cycles as f64 / amu.measured_cycles as f64
        );
    }
}
