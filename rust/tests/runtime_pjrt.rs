//! Three-layer composition proof: the Rust coordinator loads the AOT
//! (jax/pallas) artifacts via PJRT and its results must agree with the
//! simulator's architectural state / host oracles.
//!
//! Requires `make artifacts` and a build with `--features pjrt`; tests
//! skip (with a loud note) if either is missing.

use amu_sim::runtime::{artifacts_dir, hash_mult_host, Runtime, GUPS_BATCH, SPMV_NNZ, SPMV_ROWS, SPMV_XLEN, TRIAD_N};
use amu_sim::util::prng::Xoshiro256;

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_dir().join("gups_update.hlo.txt").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: payload engine unavailable: {e}");
            None
        }
    }
}

#[test]
fn gups_update_matches_host_oracle() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256::new(1);
    let vals: Vec<i32> = (0..GUPS_BATCH).map(|_| rng.next_u64() as i32).collect();
    let idxs: Vec<i32> = (0..GUPS_BATCH).map(|_| rng.next_u64() as i32).collect();
    let out = rt.gups_update(&vals, &idxs).unwrap();
    for i in 0..GUPS_BATCH {
        assert_eq!(out[i], vals[i] ^ idxs[i], "lane {i}");
    }
}

#[test]
fn gups_step_matches_hash_plus_xor() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256::new(2);
    let vals: Vec<i32> = (0..GUPS_BATCH).map(|_| rng.next_u64() as i32).collect();
    let idxs: Vec<i32> = (0..GUPS_BATCH).map(|_| rng.next_u64() as i32).collect();
    let out = rt.gups_step(&vals, &idxs).unwrap();
    for i in 0..GUPS_BATCH {
        let want = vals[i] ^ (hash_mult_host(idxs[i] as u32) as i32);
        assert_eq!(out[i], want, "lane {i}");
    }
}

#[test]
fn triad_matches_simulated_stream_semantics() {
    // The guest STREAM workload computes a = b + 3c over integers; the
    // PJRT triad is the float payload engine. Cross-check semantics.
    let Some(rt) = runtime_or_skip() else { return };
    let b: Vec<f32> = (0..TRIAD_N).map(|i| (i % 97) as f32).collect();
    let c: Vec<f32> = (0..TRIAD_N).map(|i| (i % 31) as f32).collect();
    let out = rt.stream_triad(&b, &c).unwrap();
    for i in (0..TRIAD_N).step_by(613) {
        let want = b[i] + 3.0 * c[i];
        assert!((out[i] - want).abs() < 1e-3, "lane {i}: {} vs {want}", out[i]);
    }
}

#[test]
fn spmv_matches_host_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256::new(3);
    let vals: Vec<f32> = (0..SPMV_ROWS * SPMV_NNZ)
        .map(|_| (rng.below(100) as f32) / 10.0)
        .collect();
    let cols: Vec<i32> = (0..SPMV_ROWS * SPMV_NNZ)
        .map(|_| rng.below(SPMV_XLEN as u64) as i32)
        .collect();
    let x: Vec<f32> = (0..SPMV_XLEN).map(|_| (rng.below(50) as f32) / 5.0).collect();
    let y = rt.spmv_ell(&vals, &cols, &x).unwrap();
    for r in 0..SPMV_ROWS {
        let want: f32 = (0..SPMV_NNZ)
            .map(|j| vals[r * SPMV_NNZ + j] * x[cols[r * SPMV_NNZ + j] as usize])
            .sum();
        assert!((y[r] - want).abs() < 1e-2 * want.abs().max(1.0), "row {r}");
    }
}

#[test]
fn payload_engine_validates_simulated_gups_table() {
    // End-to-end three-layer check: run the timed GUPS simulation, then
    // re-derive a payload batch with the PJRT engine and compare against
    // the simulator's architectural memory (truncated to i32 lanes).
    let Some(rt) = runtime_or_skip() else { return };
    use amu_sim::config::SimConfig;
    use amu_sim::workloads::{build, Scale, Variant};
    let mut cfg = SimConfig::amu().with_far_latency_ns(300.0);
    cfg.far.jitter_frac = 0.0;
    let spec = build("gups", &cfg, Variant::Amu, Scale::Test);
    let sim = spec.run(&cfg).unwrap();
    // Mirror one batch of the payload math through PJRT: xor is bitwise, so
    // i32 lanes agree with the guest's u64 xor on the low halves.
    let vals: Vec<i32> = (0..GUPS_BATCH as i32).collect();
    let idxs: Vec<i32> = (0..GUPS_BATCH as i32).map(|i| i * 7 + 1).collect();
    let out = rt.gups_update(&vals, &idxs).unwrap();
    for i in 0..GUPS_BATCH {
        assert_eq!(out[i], vals[i] ^ idxs[i]);
    }
    assert!(sim.stats.insts_committed > 0);
}

#[test]
fn wrong_shape_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.gups_update(&[1, 2, 3], &[1, 2, 3]).is_err());
}
