//! Workload-level behavioural tests beyond basic validation: variant
//! relationships the paper's evaluation depends on.

use amu_sim::config::SimConfig;
use amu_sim::workloads::{build, Scale, Variant};

fn cycles(name: &str, preset: &str, variant: Variant, lat: f64) -> u64 {
    let mut cfg = SimConfig::preset(preset).unwrap().with_far_latency_ns(lat);
    cfg.far.jitter_frac = 0.0;
    build(name, &cfg, variant, Scale::Test)
        .run(&cfg)
        .unwrap()
        .stats
        .measured_cycles
}

#[test]
fn gups_group_prefetch_group_size_matters() {
    // Fig 3: group size changes performance measurably (the paper's point
    // is that the best size shifts with latency/hardware, so tuning is
    // fragile). At 5us the timeliness gap between tiny and large groups
    // must show.
    let g2 = cycles("gups", "cxl-ideal", Variant::GroupPrefetch(2), 5000.0);
    let g64 = cycles("gups", "cxl-ideal", Variant::GroupPrefetch(64), 5000.0);
    let ratio = g2 as f64 / g64 as f64;
    assert!(
        ratio > 1.10 || ratio < 0.91,
        "group 2 ({g2}) vs 64 ({g64}) should differ by >9%"
    );
}

#[test]
fn gups_best_prefetch_group_competitive_with_baseline() {
    // Fig 3's message: GP can outperform OR underperform the plain
    // baseline depending on group size — only a well-tuned size wins.
    let plain = cycles("gups", "cxl-ideal", Variant::Sync, 2000.0);
    let best = [8usize, 16, 32, 64, 128]
        .iter()
        .map(|&g| cycles("gups", "cxl-ideal", Variant::GroupPrefetch(g), 2000.0))
        .min()
        .unwrap();
    assert!(
        (best as f64) < plain as f64 * 1.05,
        "best GP ({best}) should at least match plain ({plain}) at 2us"
    );
}

#[test]
fn hybrid_near_tier_stats_are_harvested_into_run_stats() {
    // A full pipeline run under the hybrid backend's LRU capacity model
    // must surface the near-tier counters in `Stats`: GUPS touches far
    // more distinct far lines than a 2-line near tier holds, so evictions
    // are guaranteed, and every access either hits near or pays the link.
    use amu_sim::config::FarBackendKind;
    let mut cfg = SimConfig::baseline().with_far_latency_ns(300.0);
    cfg.far.backend = FarBackendKind::Hybrid;
    cfg.far.jitter_frac = 0.0;
    cfg.far.near_capacity_lines = 2;
    use amu_sim::stats::ScenarioCol;
    let sim = build("gups", &cfg, Variant::Sync, Scale::Test).run(&cfg).unwrap();
    assert!(
        sim.stats.scenario.get(ScenarioCol::NearEvictions) > 0,
        "a 2-line near tier must evict under GUPS: {:?}",
        sim.stats.scenario
    );
    // The legacy coin-flip default reports hits but never evictions.
    let mut cfg = SimConfig::baseline().with_far_latency_ns(300.0);
    cfg.far.backend = FarBackendKind::Hybrid;
    cfg.far.jitter_frac = 0.0;
    let sim = build("gups", &cfg, Variant::Sync, Scale::Test).run(&cfg).unwrap();
    assert!(
        sim.stats.scenario.get(ScenarioCol::NearHits) > 0,
        "near_frac=0.5 must land some near hits"
    );
    assert_eq!(
        sim.stats.scenario.get(ScenarioCol::NearEvictions),
        0,
        "coin-flip model has no occupancy"
    );
}

#[test]
fn stream_large_granularity_beats_8b() {
    let blocked = cycles("stream", "amu", Variant::Amu, 1000.0);
    let fine = cycles("stream", "amu", Variant::AmuLlvm, 1000.0);
    assert!(fine > blocked * 2, "Table 4 STREAM: 8B {fine} vs 512B {blocked}");
}

#[test]
fn ht_disambiguation_share_falls_with_latency() {
    // Table 5 trend for HT: share shrinks as latency grows.
    let frac = |lat: f64| {
        let mut cfg = SimConfig::amu().with_far_latency_ns(lat);
        cfg.far.jitter_frac = 0.0;
        let sim = build("ht", &cfg, Variant::Amu, Scale::Test).run(&cfg).unwrap();
        sim.stats.region_fraction(amu_sim::stats::Region::Disambig)
    };
    let low = frac(100.0);
    let high = frac(5000.0);
    assert!(
        high < low,
        "disambig share should fall with latency: {low:.3} -> {high:.3}"
    );
}

#[test]
fn bfs_visits_whole_graph_on_both_ports() {
    for preset in ["baseline", "amu"] {
        let mut cfg = SimConfig::preset(preset).unwrap().with_far_latency_ns(300.0);
        cfg.far.jitter_frac = 0.0;
        let v = amu_sim::workloads::variant_for(&cfg);
        // validate() checks levels against a host BFS — run() is the test.
        build("bfs", &cfg, v, Scale::Test).run(&cfg).unwrap();
    }
}

#[test]
fn is_output_is_fully_sorted_both_ports() {
    for preset in ["baseline", "amu"] {
        let mut cfg = SimConfig::preset(preset).unwrap().with_far_latency_ns(300.0);
        cfg.far.jitter_frac = 0.0;
        let v = amu_sim::workloads::variant_for(&cfg);
        build("is", &cfg, v, Scale::Test).run(&cfg).unwrap();
    }
}
