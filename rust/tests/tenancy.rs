//! End-to-end multi-tenant (`mtrun`) invariants: byte-identical output
//! across worker counts, slowdown sanity vs solo baselines, and the
//! per-tenant scenario columns riding the full metric schema.

use amu_sim::config::{FarBackendKind, QosPolicyKind, SimConfig};
use amu_sim::session::metrics::{self, Selection};
use amu_sim::session::tenancy::{self, MtRequest};
use amu_sim::stats::schema::ScenarioCol;
use amu_sim::workloads::Scale;

/// The acceptance cell: 3 tenants (two gups, one bfs) on one shared pool
/// under two QoS policies, test scale.
fn request(jobs: usize) -> MtRequest {
    let mut cfg = SimConfig::amu().with_far_latency_ns(300.0);
    cfg.far.backend = FarBackendKind::Pooled;
    let tenants = tenancy::parse_tenants("gups:2,bfs:1").unwrap();
    let mut req = MtRequest::new(tenants, cfg);
    req.policies = vec![QosPolicyKind::FairShare, QosPolicyKind::Throttle];
    req.scale = Scale::Test;
    req.jobs = jobs;
    req.quiet = true;
    req
}

#[test]
fn mtrun_is_byte_identical_across_worker_counts() {
    let r1 = request(1);
    let r4 = request(4);
    let o1 = r1.run().unwrap();
    let o4 = r4.run().unwrap();
    let csv1 = tenancy::mt_csv(&r1.tenants, r1.scale, &o1);
    let csv4 = tenancy::mt_csv(&r4.tenants, r4.scale, &o4);
    assert_eq!(csv1, csv4, "--jobs must not change a byte of mtrun output");
    // Comment + header + 2 policies x 3 tenants.
    assert_eq!(csv1.lines().count(), 2 + 2 * 3, "{csv1}");
    assert!(csv1.starts_with("# amu-sim mtrun tenants=gups:2@1/normal,bfs:1@1/normal "), "{csv1}");
}

#[test]
fn co_scheduled_tenants_report_slowdown_in_the_full_schema() {
    let req = request(2);
    let outcomes = req.run().unwrap();
    assert_eq!(outcomes.len(), 2);
    assert_eq!(outcomes[0].policy, QosPolicyKind::FairShare);
    for o in &outcomes {
        let labels: Vec<&str> = o.rows.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, ["gups#0", "gups#1", "bfs#2"]);
        let cell_max = o.rows.iter().map(|r| r.slowdown_permille).max().unwrap();
        assert!(
            cell_max >= 1000,
            "qos={}: a co-scheduled cell can not beat every solo run ({cell_max})",
            o.policy.tag()
        );
        for r in &o.rows {
            assert!(r.solo_cycles > 0, "{}: missing solo baseline", r.label);
            // Every row of a cell carries the same pool-wide snapshot,
            // with the cell's worst slowdown stamped as the high-water
            // mark.
            assert_eq!(r.result.scenario.get(ScenarioCol::TenantSlowdownMax), cell_max);
            assert_eq!(r.result.scenario, o.rows[0].result.scenario, "{}", r.label);
        }
    }
    // Fair-share pacing on a contended pool must charge someone.
    let fair = &outcomes[0];
    assert!(fair.rows[0].result.scenario.get(ScenarioCol::PoolStealCycles) > 0);

    // The per-tenant columns ride `--columns all`: present in the header,
    // and the emitted row carries the stamped slowdown value.
    let header = metrics::csv_header(&Selection::All);
    for name in ["tenant_slowdown_max", "qos_throttle_events", "pool_steal_cycles"] {
        assert!(header.contains(name), "{header}");
    }
    let cols = Selection::All.columns();
    let row = metrics::csv_row_with(&cols, &fair.rows[0].result);
    let fields: Vec<&str> = row.split(',').collect();
    assert_eq!(fields.len(), header.split(',').count());
    let n = fields.len();
    let cell_max = fair.rows.iter().map(|r| r.slowdown_permille).max().unwrap();
    assert_eq!(fields[n - 3].parse::<u64>().unwrap(), cell_max, "{row}");
}
