//! Integration tests for the session API: typed requests, the parallel
//! sweep executor, cache resume/invalidation, and the determinism
//! guarantee (`--jobs 1` vs `--jobs N` byte-identical CSV).

use amu_sim::session::{cache, RunRequest, RunResult, Selection, Session, SessionError, SweepGrid};
use amu_sim::stats::schema::{ScenarioStats, SCENARIO_COLUMNS};
use amu_sim::testing::{check, PropConfig};
use amu_sim::workloads::Scale;
use std::path::PathBuf;

/// A small but multi-axis grid that exercises AMU and non-AMU configs.
fn small_grid() -> SweepGrid {
    SweepGrid::new(Scale::Test)
        .benches(["gups", "ll"])
        .configs(["baseline", "amu"])
        .latencies_ns([300.0, 1500.0])
}

fn temp_cache(name: &str) -> PathBuf {
    let file = format!("amu_sim_session_test_{name}_{}.csv", std::process::id());
    let p = std::env::temp_dir().join(file);
    let _ = std::fs::remove_file(&p);
    p
}

#[test]
fn invalid_requests_err_with_valid_choices_named() {
    let e = RunRequest::bench("memcached").build().unwrap_err();
    assert!(matches!(e, SessionError::UnknownBench(_)));
    let msg = e.to_string();
    assert!(msg.contains("gups") && msg.contains("stream"), "{msg}");

    let e = RunRequest::bench("gups").config_name("turbo").build().unwrap_err();
    let msg = e.to_string();
    assert!(msg.contains("baseline") && msg.contains("amu-dma"), "{msg}");
}

/// The headline guard: the same grid with 1 worker and N workers must
/// produce byte-identical CSV (row order and every value).
#[test]
fn sweep_is_deterministic_across_job_counts() {
    let grid = small_grid();
    let serial = Session::new().jobs(1).quiet(true).sweep(&grid).unwrap();
    let parallel = Session::new().jobs(4).quiet(true).sweep(&grid).unwrap();
    let fp = grid.fingerprint();
    let csv1 = cache::to_csv_string(fp, &serial);
    let csvn = cache::to_csv_string(fp, &parallel);
    assert_eq!(csv1, csvn, "parallel sweep must be byte-identical to serial");
    assert_eq!(serial.len(), grid.len());
}

/// Backend determinism: the same `(grid, seed, backend)` must produce
/// byte-identical sweep CSV across `--jobs 1` vs `--jobs N`, for each of
/// the four far-memory backends (their internal PRNG streams are
/// per-run-seeded, never shared across workers).
#[test]
fn sweep_is_deterministic_across_job_counts_for_every_backend() {
    for backend in ["serial-link", "pooled", "distribution", "hybrid"] {
        let grid = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline", "amu"])
            .latencies_ns([800.0])
            .backends([backend]);
        let serial = Session::new().jobs(1).quiet(true).sweep(&grid).unwrap();
        let parallel = Session::new().jobs(4).quiet(true).sweep(&grid).unwrap();
        let fp = grid.fingerprint();
        let csv1 = cache::to_csv_string(fp, &serial);
        let csvn = cache::to_csv_string(fp, &parallel);
        assert_eq!(csv1, csvn, "{backend}: jobs=1 vs jobs=4 CSV must be byte-identical");
        assert!(serial.iter().all(|r| r.backend == backend), "{backend}: rows must be tagged");
    }
}

/// Pool-policy determinism: for each channel-selection policy, `--jobs 1`
/// and `--jobs N` must produce byte-identical CSV (the CI determinism gate
/// runs the same check through the real binary).
#[test]
fn sweep_is_deterministic_across_job_counts_for_every_pool_policy() {
    for policy in ["hash", "least-loaded", "round-robin", "adaptive"] {
        let grid = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([800.0])
            .backends(["pooled"])
            .pool_policy(policy);
        let serial = Session::new().jobs(1).quiet(true).sweep(&grid).unwrap();
        let parallel = Session::new().jobs(4).quiet(true).sweep(&grid).unwrap();
        let fp = grid.fingerprint();
        assert_eq!(
            cache::to_csv_string(fp, &serial),
            cache::to_csv_string(fp, &parallel),
            "{policy}: jobs=1 vs jobs=4 CSV must be byte-identical"
        );
    }
}

/// The pool policy is a grid refinement: the default (`hash`) keeps the
/// paper grid's historical fingerprint (existing v3 caches stay valid); a
/// policy flag on a grid that never runs `pooled` is a no-op (same
/// fingerprint, same cache file — no duplicate re-simulation); and only
/// grids that actually sweep `pooled` under a non-default policy get
/// distinct fingerprints and cache files.
#[test]
fn default_pool_policy_preserves_fingerprints_and_cache_paths() {
    let base = SweepGrid::paper(Scale::Test);
    let hash = SweepGrid::paper(Scale::Test).pool_policy("hash");
    assert_eq!(base.fingerprint(), hash.fingerprint());
    assert_eq!(
        Session::default_cache_path(&base),
        Session::default_cache_path(&hash),
        "explicit hash must keep the historical sweep_<scale>.csv location"
    );
    // Ineffective flag (no pooled backend in the grid): complete no-op.
    let ll_no_pool = SweepGrid::paper(Scale::Test).pool_policy("least-loaded");
    assert_eq!(base.fingerprint(), ll_no_pool.fingerprint());
    assert_eq!(Session::default_cache_path(&base), Session::default_cache_path(&ll_no_pool));
    // Effective refinement: pooled swept under a non-default policy.
    let pooled = SweepGrid::paper(Scale::Test).backend("pooled");
    let ll = SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("least-loaded");
    assert_ne!(pooled.fingerprint(), ll.fingerprint());
    assert_ne!(
        Session::default_cache_path(&pooled),
        Session::default_cache_path(&ll),
        "refined grids must not clobber the pooled sweep cache"
    );
}

#[test]
fn sweep_rows_follow_canonical_grid_order() {
    let grid = small_grid();
    let rows = Session::new().quiet(true).sweep(&grid).unwrap();
    let expected: Vec<(String, String, f64)> = grid
        .requests()
        .unwrap()
        .iter()
        .map(|r| (r.bench_name().to_string(), r.config_name().to_string(), r.latency_ns()))
        .collect();
    let got: Vec<(String, String, f64)> =
        rows.iter().map(|r| (r.bench.clone(), r.config.clone(), r.latency_ns)).collect();
    assert_eq!(got, expected);
}

/// Keyed cache resume: rows present in the cache are reused verbatim,
/// missing cells are simulated.
#[test]
fn partial_cache_resumes_instead_of_resimulating() {
    let path = temp_cache("resume");
    let grid = small_grid();
    let session = Session::new().quiet(true).cache_path(path.clone());
    let rows = session.sweep(&grid).unwrap();

    // Drop one row and plant a sentinel in another: the sentinel proves
    // cached rows are reused, the dropped row proves missing cells rerun.
    let mut edited: Vec<RunResult> = rows.clone();
    edited.remove(3);
    edited[0].ipc = 42.5;
    std::fs::write(&path, cache::to_csv_string(grid.fingerprint(), &edited)).unwrap();

    let resumed = session.sweep(&grid).unwrap();
    assert_eq!(resumed.len(), grid.len());
    assert_eq!(resumed[0].ipc, 42.5, "cached row must be reused, not re-simulated");
    assert_eq!(resumed[3], rows[3], "missing cell must be re-simulated deterministically");

    // The rewritten file is the full canonical grid again.
    let (fp, reloaded) = cache::parse_csv(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(fp, grid.fingerprint());
    assert_eq!(reloaded.len(), grid.len());
    let _ = std::fs::remove_file(&path);
}

/// Fingerprint staleness: a cache written for one grid is never silently
/// reused for a different grid sharing the same path.
#[test]
fn stale_cache_for_a_different_grid_is_invalidated() {
    let path = temp_cache("stale");
    let grid_a = SweepGrid::new(Scale::Test)
        .benches(["gups"])
        .configs(["baseline"])
        .latencies_ns([300.0]);
    let grid_b = grid_a.clone().latencies_ns([900.0]);
    let session = Session::new().quiet(true).cache_path(path.clone());

    let rows_a = session.sweep(&grid_a).unwrap();
    assert_eq!(rows_a[0].latency_ns, 300.0);

    // Same path, different grid: the stale file must not leak 300ns rows.
    let rows_b = session.sweep(&grid_b).unwrap();
    assert_eq!(rows_b.len(), 1);
    assert_eq!(rows_b[0].latency_ns, 900.0);
    let (fp, _) = cache::parse_csv(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(fp, grid_b.fingerprint(), "cache must be rewritten for the new grid");
    let _ = std::fs::remove_file(&path);
}

/// A corrupt cache file is rejected whole (and the sweep still succeeds by
/// re-simulating).
#[test]
fn corrupt_cache_is_rejected_not_partially_loaded() {
    let path = temp_cache("corrupt");
    let grid = SweepGrid::new(Scale::Test)
        .benches(["gups"])
        .configs(["baseline"])
        .latencies_ns([300.0]);
    let session = Session::new().quiet(true).cache_path(path.clone());
    let rows = session.sweep(&grid).unwrap();

    // Corrupt the numeric payload of the row.
    let text = std::fs::read_to_string(&path).unwrap();
    let bad = text.replace(&rows[0].measured_cycles.to_string(), "not-a-number");
    assert!(cache::parse_csv(&bad).is_err(), "corrupt row must reject the file");
    std::fs::write(&path, &bad).unwrap();
    let recovered = session.sweep(&grid).unwrap();
    assert_eq!(recovered, rows, "re-simulation must reproduce the original rows");
    let _ = std::fs::remove_file(&path);
}

/// Property: CSV row serialization reproduces every `RunResult` field,
/// including exact bit patterns of the floats (ipc, disambig_frac, ...).
#[test]
fn prop_csv_round_trips_every_field_bit_exactly() {
    check(
        &PropConfig { cases: 128, seed: 0xC5F_0001, ..Default::default() },
        |rng| {
            // Finite floats across magnitudes, built from random mantissas.
            fn frac(bits: u64) -> f64 {
                (bits >> 11) as f64 / (1u64 << 53) as f64
            }
            let variant = format!("gp{}", rng.below(512));
            let backends = ["serial-link", "pooled", "distribution", "hybrid"];
            let backend = backends[rng.below(backends.len() as u64) as usize].to_string();
            let latency_ns = frac(rng.next_u64()) * 10_000.0;
            let measured_cycles = rng.next_u64() >> rng.below(40);
            let total_cycles = rng.next_u64() >> rng.below(40);
            let insts = rng.next_u64() >> rng.below(40);
            let ipc = frac(rng.next_u64()) * 8.0;
            let mlp = frac(rng.next_u64()) * 512.0;
            let peak_inflight = rng.below(100_000);
            let dynamic_uj = frac(rng.next_u64()) * 1e-3;
            let static_uj = frac(rng.next_u64()) * 1e6;
            let disambig_frac = frac(rng.next_u64());
            // Every scenario (u64) column gets a random value too, so the
            // round trip covers the schema's full column set.
            let mut scenario = ScenarioStats::default();
            for d in SCENARIO_COLUMNS {
                scenario.set(d.col, rng.next_u64() >> rng.below(40));
            }
            RunResult {
                bench: "gups".into(),
                config: "cxl-ideal".into(),
                backend,
                variant,
                latency_ns,
                measured_cycles,
                total_cycles,
                insts,
                ipc,
                mlp,
                peak_inflight,
                dynamic_uj,
                static_uj,
                disambig_frac,
                scenario,
            }
        },
        |r| {
            let text = cache::to_csv_string(r.latency_ns.to_bits(), &[r.clone()]);
            let (fp, rows) =
                cache::parse_csv(&text).map_err(|e| format!("parse failed: {e}"))?;
            if fp != r.latency_ns.to_bits() {
                return Err("fingerprint mismatch".into());
            }
            if rows.len() != 1 {
                return Err(format!("expected 1 row, got {}", rows.len()));
            }
            let p = &rows[0];
            if p != r {
                return Err(format!("round trip mismatch:\n  in:  {r:?}\n  out: {p:?}"));
            }
            for (a, b, name) in [
                (p.ipc, r.ipc, "ipc"),
                (p.mlp, r.mlp, "mlp"),
                (p.latency_ns, r.latency_ns, "latency_ns"),
                (p.dynamic_uj, r.dynamic_uj, "dynamic_uj"),
                (p.static_uj, r.static_uj, "static_uj"),
                (p.disambig_frac, r.disambig_frac, "disambig_frac"),
            ] {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("{name} lost precision: {b} -> {a}"));
                }
            }
            Ok(())
        },
    );
}

/// A v3-era cache file (pre-schema, 14-field rows) is rejected with a
/// migration error naming the regeneration command, and the sweep
/// recovers by re-simulating and rewriting the file as v4.
#[test]
fn v3_cache_is_rejected_with_migration_error_and_regenerated_as_v4() {
    let v3 = "# amu-sim sweep cache v3 grid=0123456789abcdef\n\
              bench,config,backend,variant,latency_ns,measured_cycles,total_cycles,\
              insts,ipc,mlp,peak_inflight,dynamic_uj,static_uj,disambig_frac\n\
              gups,baseline,serial-link,sync,300,10,20,30,0.5,1.5,4,0.1,0.2,0.3\n";
    let e = cache::parse_csv(v3).unwrap_err();
    assert!(e.contains("v3"), "{e}");
    assert!(e.contains("amu-sim sweep"), "must name the regeneration command: {e}");

    let path = temp_cache("v3_migrate");
    std::fs::write(&path, v3).unwrap();
    let grid = SweepGrid::new(Scale::Test)
        .benches(["gups"])
        .configs(["baseline"])
        .latencies_ns([300.0]);
    let rows = Session::new().quiet(true).cache_path(path.clone()).sweep(&grid).unwrap();
    assert_eq!(rows.len(), 1, "sweep must recover by re-simulating");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(
        text.starts_with("# amu-sim sweep cache v4 grid="),
        "stale v3 file must be rewritten as v4: {}",
        text.lines().next().unwrap()
    );
    let (fp, reloaded) = cache::parse_csv(&text).unwrap();
    assert_eq!(fp, grid.fingerprint());
    assert_eq!(reloaded, rows);
    let _ = std::fs::remove_file(&path);
}

/// End-to-end through the real binary: `AMU_RESULTS_DIR` redirects the
/// default sweep-cache location at runtime, and `--columns all --out`
/// emits the schema-selected CSV whose header matches the golden file.
#[test]
fn binary_honors_results_dir_override_and_emits_selected_columns() {
    let dir = std::env::temp_dir()
        .join(format!("amu_sim_results_override_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cols_path = dir.join("cols.csv");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_amu-sim"))
        .env("AMU_RESULTS_DIR", &dir)
        .args([
            "sweep",
            "--benches",
            "gups",
            "--configs",
            "baseline",
            "--latencies-ns",
            "300",
            "--scale",
            "test",
            "--jobs",
            "1",
            "--quiet",
            "--columns",
            "all",
            "--out",
            cols_path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn amu-sim");
    assert!(
        out.status.success(),
        "sweep failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    // The default cache landed under the override, not under results/.
    let grid = SweepGrid::new(Scale::Test)
        .benches(["gups"])
        .configs(["baseline"])
        .latencies_ns([300.0]);
    let cache_file = dir.join(format!("sweep_test_{:016x}.csv", grid.fingerprint()));
    assert!(
        cache_file.exists(),
        "default cache must honor AMU_RESULTS_DIR (expected {})",
        cache_file.display()
    );
    let (fp, rows) = cache::parse_csv(&std::fs::read_to_string(&cache_file).unwrap()).unwrap();
    assert_eq!(fp, grid.fingerprint());
    assert_eq!(rows.len(), 1);
    // The --columns all CSV has the golden header and one data row whose
    // core prefix matches the `core` selection of the cached row.
    let cols = std::fs::read_to_string(&cols_path).unwrap();
    let mut lines = cols.lines();
    assert_eq!(
        format!("{}\n", lines.next().unwrap()),
        include_str!("golden/columns_all_header.txt")
    );
    let all_row = lines.next().unwrap();
    let core_row = amu_sim::session::metrics::csv_row(&rows[0], &Selection::Core);
    assert!(all_row.starts_with(&core_row), "core must prefix all:\n{core_row}\n{all_row}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A failing cell surfaces as an error from the executor, not a panic.
#[test]
fn sweep_propagates_run_errors() {
    // max_cycles too small: every run aborts. Build the request directly
    // (grids only reference presets) and run it through Session::run.
    let mut cfg = amu_sim::config::SimConfig::baseline();
    cfg.max_cycles = 10;
    let req = RunRequest::bench("gups").config(cfg).latency_ns(300.0).build().unwrap();
    let err = Session::new().quiet(true).run(&req).unwrap_err();
    assert!(matches!(err, SessionError::Run(_)), "{err}");
}
