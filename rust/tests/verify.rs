//! Integration tests for the static program verifier (`isa::verify`).
//!
//! Three layers:
//! 1. a negative corpus — one deliberately broken program per diagnostic
//!    code, asserting the code fires at the expected instruction index;
//! 2. a registry sweep — every built-in benchmark x supported variant must
//!    verify with zero deny- AND zero warn-level findings (the CI gate is
//!    `amu-sim check --all --deny-warnings`);
//! 3. golden output — the diagnostics table rendering is byte-pinned.

use amu_sim::config::SimConfig;
use amu_sim::isa::{
    verify, Asm, CfgReg, Inst, Opcode, Program, Severity, VerifyCode as Code, VerifyReport,
    FAR_BASE, LOCAL_BASE, SPM_BASE,
};
use amu_sim::session::registry::REGISTRY;
use amu_sim::workloads::{Scale, Variant, VariantKind, WorkloadSpec};

/// Does the report contain `code` anchored at instruction `at`?
fn has(r: &VerifyReport, code: Code, at: usize) -> bool {
    r.diags.iter().any(|d| d.code == code && d.at == at)
}

fn assert_only_code_at(r: &VerifyReport, code: Code, at: usize) {
    assert!(has(r, code, at), "expected {code:?} at {at}, got: {:?}", r.diags);
}

// ---------------------------------------------------------------------------
// Negative corpus: every code fires, at the right index.
// ---------------------------------------------------------------------------

#[test]
fn ami001_bad_target() {
    // The assembler cannot emit an unresolved target, so build raw.
    let p = Program {
        name: "bad-target".into(),
        insts: vec![
            Inst { op: Opcode::Beq, imm: 99, ..Inst::nop() },
            Inst { op: Opcode::Halt, ..Inst::nop() },
        ],
        labels: vec![],
    };
    let r = verify(&p);
    assert_only_code_at(&r, Code::BadTarget, 0);
    assert_eq!(Code::BadTarget.severity(), Severity::Deny);
}

#[test]
fn ami002_falls_off_end() {
    let mut a = Asm::new("fall");
    a.li(1, 1);
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::FallsOffEnd, 0);
    assert!(!r.is_clean(false));
}

#[test]
fn ami003_unreachable() {
    let mut a = Asm::new("dead");
    a.halt();
    a.label("dead");
    a.nop();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::Unreachable, 1);
    assert_eq!(Code::Unreachable.severity(), Severity::Info);
    // Info findings never gate, even under --deny-warnings.
    assert!(r.is_clean(true));
}

#[test]
fn ami004_dead_write() {
    let mut a = Asm::new("r0");
    a.li(0, 5);
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::DeadWrite, 0);
    assert_eq!(Code::DeadWrite.severity(), Severity::Warn);
    assert!(r.is_clean(false) && !r.is_clean(true));
}

#[test]
fn ami005_maybe_uninit() {
    let mut a = Asm::new("uninit");
    a.add(1, 2, 3); // r2, r3 never written
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MaybeUninit, 0), "{:?}", r.diags);
    assert_eq!(Code::MaybeUninit.severity(), Severity::Info);
}

#[test]
fn ami006_bad_cfg_index() {
    let p = Program {
        name: "bad-cfg".into(),
        insts: vec![
            Inst { op: Opcode::CfgWr, imm: 7, ..Inst::nop() },
            Inst { op: Opcode::Halt, ..Inst::nop() },
        ],
        labels: vec![],
    };
    let r = verify(&p);
    assert_only_code_at(&r, Code::BadCfgIndex, 0);
    assert_eq!(Code::BadCfgIndex.severity(), Severity::Deny);
}

#[test]
fn ami007_queue_cfg_not_dominating() {
    let mut a = Asm::new("no-dom");
    a.li(1, 256);
    a.beq(2, 0, "issue"); // may skip the queue configuration
    a.cfgwr(1, CfgReg::QueueLength);
    a.label("issue");
    a.li(3, SPM_BASE as i64);
    a.li(4, FAR_BASE as i64);
    a.aload(5, 3, 4);
    a.label("poll");
    a.getfin(6);
    a.beq(6, 0, "poll");
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::QueueCfgNotDominating, 5), "{:?}", r.diags);
}

#[test]
fn ami007_silent_when_program_relies_on_reset_defaults() {
    // No cfgwr QueueBase/QueueLength anywhere: hardware reset defaults
    // apply and AMI007 must not fire (this is every built-in benchmark).
    let mut a = Asm::new("reset-defaults");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.label("poll");
    a.getfin(4);
    a.beq(4, 0, "poll");
    a.halt();
    let r = verify(&a.finish());
    assert!(r.is_clean(true), "{:?}", r.diags);
}

#[test]
fn ami008_queue_reconfig_in_flight() {
    let mut a = Asm::new("reconfig");
    a.li(1, 64);
    a.cfgwr(1, CfgReg::QueueLength);
    a.li(2, SPM_BASE as i64);
    a.li(3, FAR_BASE as i64);
    a.aload(4, 2, 3);
    a.label("poll");
    a.getfin(5);
    a.beq(5, 0, "poll");
    a.cfgwr(1, CfgReg::QueueLength); // requests may still be in flight
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::QueueReconfigInFlight, 7), "{:?}", r.diags);
}

#[test]
fn ami009_spm_operand_out_of_range() {
    let mut a = Asm::new("bad-spm");
    a.li(1, LOCAL_BASE as i64); // not an SPM address
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.getfin(4);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmOperandOutOfRange, 2), "{:?}", r.diags);
}

#[test]
fn ami009_spm_operand_inside_queue_region() {
    // QueueBase = SPM_BASE, QueueLength = 4 entries x 32 B = 128 B; an
    // SPM operand at SPM_BASE+32 aliases the AMART metadata.
    let mut a = Asm::new("queue-alias");
    a.li(1, SPM_BASE as i64);
    a.cfgwr(1, CfgReg::QueueBase);
    a.li(2, 4);
    a.cfgwr(2, CfgReg::QueueLength);
    a.li(3, (SPM_BASE + 32) as i64);
    a.li(4, FAR_BASE as i64);
    a.aload(5, 3, 4);
    a.getfin(6);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmOperandOutOfRange, 6), "{:?}", r.diags);
}

#[test]
fn ami010_mem_operand_in_spm() {
    let mut a = Asm::new("mem-in-spm");
    a.li(1, SPM_BASE as i64);
    a.li(2, (SPM_BASE + 64) as i64); // memory operand inside the scratchpad
    a.aload(3, 1, 2);
    a.getfin(4);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MemOperandInSpm, 2), "{:?}", r.diags);
}

#[test]
fn ami011_issue_without_drain() {
    let mut a = Asm::new("no-drain");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::IssueWithoutDrain, 2), "{:?}", r.diags);
}

#[test]
fn ami012_discarded_request_id() {
    let mut a = Asm::new("discard-id");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(0, 1, 2); // id into r0: can never be awaited
    a.getfin(3);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::DiscardedRequestId, 2), "{:?}", r.diags);
    assert_eq!(Code::DiscardedRequestId.severity(), Severity::Warn);
}

#[test]
fn ami013_drain_without_issue() {
    let mut a = Asm::new("no-issue");
    a.getfin(1);
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::DrainWithoutIssue, 0);
    assert_eq!(Code::DrainWithoutIssue.severity(), Severity::Warn);
}

#[test]
fn ami014_roi_double_begin() {
    let mut a = Asm::new("roi-double");
    a.roi_begin();
    a.roi_begin();
    a.roi_end();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::RoiImbalance, 1);
}

#[test]
fn ami014_roi_end_without_begin() {
    let mut a = Asm::new("roi-end");
    a.roi_end();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::RoiImbalance, 0);
}

#[test]
fn ami014_halt_inside_roi() {
    let mut a = Asm::new("roi-halt");
    a.roi_begin();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::RoiImbalance, 1);
}

#[test]
fn ami015_missing_flush() {
    let mut a = Asm::new("no-flush");
    a.li(1, FAR_BASE as i64);
    a.ld64(2, 1, 0); // sync far access at a constant address
    a.li(3, SPM_BASE as i64);
    a.aload(4, 3, 1); // async issue without an intervening flush
    a.getfin(5);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MissingFlush, 3), "{:?}", r.diags);
    assert_eq!(Code::MissingFlush.severity(), Severity::Info);
}

#[test]
fn ami015_flush_clears_the_transition() {
    let mut a = Asm::new("flushed");
    a.li(1, FAR_BASE as i64);
    a.ld64(2, 1, 0);
    a.flush(1, 0); // paper §5.3.2: flush at the sync->async transition
    a.li(3, SPM_BASE as i64);
    a.aload(4, 3, 1);
    a.getfin(5);
    a.halt();
    let r = verify(&a.finish());
    assert!(
        !r.diags.iter().any(|d| d.code == Code::MissingFlush),
        "{:?}",
        r.diags
    );
}

// ---------------------------------------------------------------------------
// Registry sweep: every built-in benchmark verifies clean.
// ---------------------------------------------------------------------------

/// The representative payload for each variant kind (mirrors `amu-sim
/// check`).
fn representative(kind: VariantKind) -> Variant {
    match kind {
        VariantKind::Sync => Variant::Sync,
        VariantKind::Amu => Variant::Amu,
        VariantKind::GroupPrefetch => Variant::GroupPrefetch(16),
        VariantKind::SwPrefetch => Variant::SwPrefetch { batch: 16, depth: 2 },
        VariantKind::AmuLlvm => Variant::AmuLlvm,
    }
}

#[test]
fn every_builtin_benchmark_verifies_clean() {
    for w in REGISTRY {
        for &kind in w.supported_variants() {
            let variant = representative(kind);
            let cfg = match kind {
                VariantKind::Amu | VariantKind::AmuLlvm => SimConfig::amu(),
                _ => SimConfig::baseline(),
            };
            let spec = w.build(&cfg, variant, Scale::Test);
            let report = spec.verify();
            assert_eq!(
                (report.deny_count(), report.warn_count()),
                (0, 0),
                "{}/{} must verify clean:\n{}",
                w.name(),
                variant.tag(),
                report.render_table(Severity::Info)
            );
            spec.verify_ok().unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn verifier_is_deterministic() {
    let cfg = SimConfig::amu();
    let w = REGISTRY.iter().find(|w| w.name() == "gups").unwrap();
    let spec = w.build(&cfg, Variant::Amu, Scale::Test);
    assert_eq!(spec.verify(), spec.verify());
}

// ---------------------------------------------------------------------------
// The fail-fast hook: invalid programs are refused before simulation.
// ---------------------------------------------------------------------------

#[test]
fn run_refuses_programs_with_deny_findings() {
    let mut a = Asm::new("broken");
    a.li(1, 1); // falls off the end: AMI002
    let spec = WorkloadSpec {
        name: "broken".into(),
        prog: a.finish(),
        setup: Box::new(|_| {}),
        validate: Box::new(|_| Ok(())),
    };
    let err = spec.run(&SimConfig::baseline()).unwrap_err();
    assert!(err.contains("rejected by the verifier"), "{err}");
    assert!(err.contains("AMI002"), "{err}");
}

#[test]
fn warn_level_findings_do_not_block_run() {
    // A dead write is a warn: `run` must still simulate the program.
    let mut a = Asm::new("warn-only");
    a.li(0, 7);
    a.halt();
    let spec = WorkloadSpec {
        name: "warn-only".into(),
        prog: a.finish(),
        setup: Box::new(|_| {}),
        validate: Box::new(|_| Ok(())),
    };
    assert!(spec.verify_ok().is_ok());
    spec.run(&SimConfig::baseline()).expect("warn-level program must run");
}

// ---------------------------------------------------------------------------
// Golden diagnostics table.
// ---------------------------------------------------------------------------

#[test]
fn diagnostics_table_matches_golden() {
    let mut a = Asm::new("kitchen-sink");
    a.li(0, 7); // 0: AMI004
    a.roi_begin(); // 1
    a.li(1, LOCAL_BASE as i64); // 2
    a.li(2, FAR_BASE as i64); // 3
    a.aload(3, 1, 2); // 4: AMI009 + AMI011
    a.roi_end(); // 5
    a.halt(); // 6
    a.label("dead");
    a.nop(); // 7: AMI003
    let r = verify(&a.finish());
    let expected = include_str!("golden/verify_diagnostics.txt");
    assert_eq!(
        r.render_table(Severity::Info),
        expected,
        "diagnostics table drifted from rust/tests/golden/verify_diagnostics.txt"
    );
    assert_eq!((r.deny_count(), r.warn_count(), r.count(Severity::Info)), (2, 1, 1));
}
