//! Integration tests for the static program verifier (`isa::verify`).
//!
//! Five layers:
//! 1. a negative corpus — one deliberately broken program per diagnostic
//!    code (AMI001..AMI024), asserting the code fires at the expected
//!    instruction index, with silence companions for the calibrated
//!    race/lifetime checks;
//! 2. a registry sweep — every built-in benchmark x supported variant must
//!    verify with zero deny- AND zero warn-level findings (the CI gate is
//!    `amu-sim check --all --deny-warnings`);
//! 3. a termination property — the widened interval fixpoint stays within
//!    an explicit iteration bound on adversarial generated programs;
//! 4. verify_ok caching — one analysis per distinct program fingerprint;
//! 5. golden output — the diagnostics table and the `--format json`
//!    envelope are byte-pinned (and the JSON is byte-deterministic).

use amu_sim::config::SimConfig;
use amu_sim::isa::{
    verify, Asm, CfgReg, Inst, Opcode, Program, Severity, VerifyCode as Code, VerifyReport,
    FAR_BASE, LOCAL_BASE, SPM_BASE,
};
use amu_sim::session::registry::REGISTRY;
use amu_sim::workloads::{Scale, Variant, VariantKind, WorkloadSpec};

/// Does the report contain `code` anchored at instruction `at`?
fn has(r: &VerifyReport, code: Code, at: usize) -> bool {
    r.diags.iter().any(|d| d.code == code && d.at == at)
}

fn assert_only_code_at(r: &VerifyReport, code: Code, at: usize) {
    assert!(has(r, code, at), "expected {code:?} at {at}, got: {:?}", r.diags);
}

// ---------------------------------------------------------------------------
// Negative corpus: every code fires, at the right index.
// ---------------------------------------------------------------------------

#[test]
fn ami001_bad_target() {
    // The assembler cannot emit an unresolved target, so build raw.
    let p = Program {
        name: "bad-target".into(),
        insts: vec![
            Inst { op: Opcode::Beq, imm: 99, ..Inst::nop() },
            Inst { op: Opcode::Halt, ..Inst::nop() },
        ],
        ..Default::default()
    };
    let r = verify(&p);
    assert_only_code_at(&r, Code::BadTarget, 0);
    assert_eq!(Code::BadTarget.severity(), Severity::Deny);
}

#[test]
fn ami002_falls_off_end() {
    let mut a = Asm::new("fall");
    a.li(1, 1);
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::FallsOffEnd, 0);
    assert!(!r.is_clean(false));
}

#[test]
fn ami003_unreachable() {
    let mut a = Asm::new("dead");
    a.halt();
    a.label("dead");
    a.nop();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::Unreachable, 1);
    assert_eq!(Code::Unreachable.severity(), Severity::Info);
    // Info findings never gate, even under --deny-warnings.
    assert!(r.is_clean(true));
}

#[test]
fn ami004_dead_write() {
    let mut a = Asm::new("r0");
    a.li(0, 5);
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::DeadWrite, 0);
    assert_eq!(Code::DeadWrite.severity(), Severity::Warn);
    assert!(r.is_clean(false) && !r.is_clean(true));
}

#[test]
fn ami005_maybe_uninit() {
    let mut a = Asm::new("uninit");
    a.add(1, 2, 3); // r2, r3 never written
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MaybeUninit, 0), "{:?}", r.diags);
    assert_eq!(Code::MaybeUninit.severity(), Severity::Info);
}

#[test]
fn ami006_bad_cfg_index() {
    let p = Program {
        name: "bad-cfg".into(),
        insts: vec![
            Inst { op: Opcode::CfgWr, imm: 7, ..Inst::nop() },
            Inst { op: Opcode::Halt, ..Inst::nop() },
        ],
        ..Default::default()
    };
    let r = verify(&p);
    assert_only_code_at(&r, Code::BadCfgIndex, 0);
    assert_eq!(Code::BadCfgIndex.severity(), Severity::Deny);
}

#[test]
fn ami007_queue_cfg_not_dominating() {
    let mut a = Asm::new("no-dom");
    a.li(1, 256);
    a.beq(2, 0, "issue"); // may skip the queue configuration
    a.cfgwr(1, CfgReg::QueueLength);
    a.label("issue");
    a.li(3, SPM_BASE as i64);
    a.li(4, FAR_BASE as i64);
    a.aload(5, 3, 4);
    a.label("poll");
    a.getfin(6);
    a.beq(6, 0, "poll");
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::QueueCfgNotDominating, 5), "{:?}", r.diags);
}

#[test]
fn ami007_silent_when_program_relies_on_reset_defaults() {
    // No cfgwr QueueBase/QueueLength anywhere: hardware reset defaults
    // apply and AMI007 must not fire (this is every built-in benchmark).
    let mut a = Asm::new("reset-defaults");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.label("poll");
    a.getfin(4);
    a.beq(4, 0, "poll");
    a.halt();
    let r = verify(&a.finish());
    assert!(r.is_clean(true), "{:?}", r.diags);
}

#[test]
fn ami008_queue_reconfig_in_flight() {
    let mut a = Asm::new("reconfig");
    a.li(1, 64);
    a.cfgwr(1, CfgReg::QueueLength);
    a.li(2, SPM_BASE as i64);
    a.li(3, FAR_BASE as i64);
    a.aload(4, 2, 3);
    a.label("poll");
    a.getfin(5);
    a.beq(5, 0, "poll");
    a.cfgwr(1, CfgReg::QueueLength); // requests may still be in flight
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::QueueReconfigInFlight, 7), "{:?}", r.diags);
}

#[test]
fn ami009_spm_operand_out_of_range() {
    let mut a = Asm::new("bad-spm");
    a.li(1, LOCAL_BASE as i64); // not an SPM address
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.getfin(4);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmOperandOutOfRange, 2), "{:?}", r.diags);
}

#[test]
fn ami009_spm_operand_inside_queue_region() {
    // QueueBase = SPM_BASE, QueueLength = 4 entries x 32 B = 128 B; an
    // SPM operand at SPM_BASE+32 aliases the AMART metadata.
    let mut a = Asm::new("queue-alias");
    a.li(1, SPM_BASE as i64);
    a.cfgwr(1, CfgReg::QueueBase);
    a.li(2, 4);
    a.cfgwr(2, CfgReg::QueueLength);
    a.li(3, (SPM_BASE + 32) as i64);
    a.li(4, FAR_BASE as i64);
    a.aload(5, 3, 4);
    a.getfin(6);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmOperandOutOfRange, 6), "{:?}", r.diags);
}

#[test]
fn ami010_mem_operand_in_spm() {
    let mut a = Asm::new("mem-in-spm");
    a.li(1, SPM_BASE as i64);
    a.li(2, (SPM_BASE + 64) as i64); // memory operand inside the scratchpad
    a.aload(3, 1, 2);
    a.getfin(4);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MemOperandInSpm, 2), "{:?}", r.diags);
}

#[test]
fn ami011_issue_without_drain() {
    let mut a = Asm::new("no-drain");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::IssueWithoutDrain, 2), "{:?}", r.diags);
}

#[test]
fn ami012_discarded_request_id() {
    let mut a = Asm::new("discard-id");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(0, 1, 2); // id into r0: can never be awaited
    a.getfin(3);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::DiscardedRequestId, 2), "{:?}", r.diags);
    assert_eq!(Code::DiscardedRequestId.severity(), Severity::Warn);
}

#[test]
fn ami013_drain_without_issue() {
    let mut a = Asm::new("no-issue");
    a.getfin(1);
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::DrainWithoutIssue, 0);
    assert_eq!(Code::DrainWithoutIssue.severity(), Severity::Warn);
}

#[test]
fn ami014_roi_double_begin() {
    let mut a = Asm::new("roi-double");
    a.roi_begin();
    a.roi_begin();
    a.roi_end();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::RoiImbalance, 1);
}

#[test]
fn ami014_roi_end_without_begin() {
    let mut a = Asm::new("roi-end");
    a.roi_end();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::RoiImbalance, 0);
}

#[test]
fn ami014_halt_inside_roi() {
    let mut a = Asm::new("roi-halt");
    a.roi_begin();
    a.halt();
    let r = verify(&a.finish());
    assert_only_code_at(&r, Code::RoiImbalance, 1);
}

#[test]
fn ami015_missing_flush() {
    let mut a = Asm::new("no-flush");
    a.li(1, FAR_BASE as i64);
    a.ld64(2, 1, 0); // sync far access at a constant address
    a.li(3, SPM_BASE as i64);
    a.aload(4, 3, 1); // async issue without an intervening flush
    a.getfin(5);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MissingFlush, 3), "{:?}", r.diags);
    assert_eq!(Code::MissingFlush.severity(), Severity::Info);
}

#[test]
fn ami015_flush_clears_the_transition() {
    let mut a = Asm::new("flushed");
    a.li(1, FAR_BASE as i64);
    a.ld64(2, 1, 0);
    a.flush(1, 0); // paper §5.3.2: flush at the sync->async transition
    a.li(3, SPM_BASE as i64);
    a.aload(4, 3, 1);
    a.getfin(5);
    a.halt();
    let r = verify(&a.finish());
    assert!(
        !r.diags.iter().any(|d| d.code == Code::MissingFlush),
        "{:?}",
        r.diags
    );
}

// ---------------------------------------------------------------------------
// Registry sweep: every built-in benchmark verifies clean.
// ---------------------------------------------------------------------------

/// The representative payload for each variant kind (mirrors `amu-sim
/// check`).
fn representative(kind: VariantKind) -> Variant {
    match kind {
        VariantKind::Sync => Variant::Sync,
        VariantKind::Amu => Variant::Amu,
        VariantKind::GroupPrefetch => Variant::GroupPrefetch(16),
        VariantKind::SwPrefetch => Variant::SwPrefetch { batch: 16, depth: 2 },
        VariantKind::AmuLlvm => Variant::AmuLlvm,
    }
}

#[test]
fn every_builtin_benchmark_verifies_clean() {
    for w in REGISTRY {
        for &kind in w.supported_variants() {
            let variant = representative(kind);
            let cfg = match kind {
                VariantKind::Amu | VariantKind::AmuLlvm => SimConfig::amu(),
                _ => SimConfig::baseline(),
            };
            let spec = w.build(&cfg, variant, Scale::Test);
            let report = spec.verify();
            assert_eq!(
                (report.deny_count(), report.warn_count()),
                (0, 0),
                "{}/{} must verify clean:\n{}",
                w.name(),
                variant.tag(),
                report.render_table(Severity::Info)
            );
            spec.verify_ok().unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn verifier_is_deterministic() {
    let cfg = SimConfig::amu();
    let w = REGISTRY.iter().find(|w| w.name() == "gups").unwrap();
    let spec = w.build(&cfg, Variant::Amu, Scale::Test);
    assert_eq!(spec.verify(), spec.verify());
}

// ---------------------------------------------------------------------------
// The fail-fast hook: invalid programs are refused before simulation.
// ---------------------------------------------------------------------------

#[test]
fn run_refuses_programs_with_deny_findings() {
    let mut a = Asm::new("broken");
    a.li(1, 1); // falls off the end: AMI002
    let spec = WorkloadSpec {
        name: "broken".into(),
        prog: a.finish(),
        setup: Box::new(|_| {}),
        validate: Box::new(|_| Ok(())),
    };
    let err = spec.run(&SimConfig::baseline()).unwrap_err();
    assert!(err.contains("rejected by the verifier"), "{err}");
    assert!(err.contains("AMI002"), "{err}");
}

#[test]
fn warn_level_findings_do_not_block_run() {
    // A dead write is a warn: `run` must still simulate the program.
    let mut a = Asm::new("warn-only");
    a.li(0, 7);
    a.halt();
    let spec = WorkloadSpec {
        name: "warn-only".into(),
        prog: a.finish(),
        setup: Box::new(|_| {}),
        validate: Box::new(|_| Ok(())),
    };
    assert!(spec.verify_ok().is_ok());
    spec.run(&SimConfig::baseline()).expect("warn-level program must run");
}

// ---------------------------------------------------------------------------
// Race & lifetime corpus (AMI016..AMI024): the interval and request-lifetime
// analyses. Every code fires at an exact instruction index, and each deny
// check has a companion showing the calibrated silence condition.
// ---------------------------------------------------------------------------

#[test]
fn ami016_spm_read_while_in_flight() {
    let mut a = Asm::new("race-read");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.ld64(4, 1, 0); // 3: reads the slot before the request completes
    a.getfin(5);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmReadInFlight, 3), "{:?}", r.diags);
    assert_eq!(Code::SpmReadInFlight.severity(), Severity::Deny);
    assert!(!r.is_clean(false));
}

#[test]
fn ami016_silent_once_drained() {
    // After one getfin poll the completed request is unknown (must ->
    // maybe), so the deny-level race check stands down.
    let mut a = Asm::new("race-read-drained");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.getfin(4);
    a.ld64(5, 1, 0);
    a.halt();
    let r = verify(&a.finish());
    assert!(r.is_clean(true), "{:?}", r.diags);
}

#[test]
fn ami017_spm_write_while_in_flight() {
    let mut a = Asm::new("race-write");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.st64(2, 1, 0); // 3: the completion will clobber (or race with) this
    a.getfin(5);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmWriteInFlight, 3), "{:?}", r.diags);
    assert_eq!(Code::SpmWriteInFlight.severity(), Severity::Deny);
}

#[test]
fn ami018_overlapping_requests() {
    let mut a = Asm::new("overlap");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.aload(4, 1, 2); // 3: same slot while the first request is in flight
    a.getfin(5);
    a.getfin(6);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::OverlappingRequests, 3), "{:?}", r.diags);
    assert_eq!(Code::OverlappingRequests.severity(), Severity::Warn);
}

#[test]
fn ami019_request_id_leak() {
    let mut a = Asm::new("id-leak");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2); // id lives in r3
    a.mv(4, 3); // a copy keeps it alive
    a.li(3, 0); // 4: r4 still holds the id -> no finding here
    a.nop();
    a.li(4, 0); // 6: last copy gone, and no getfin anywhere ahead
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::RequestIdLeak, 6), "{:?}", r.diags);
    assert!(!has(&r, Code::RequestIdLeak, 4), "{:?}", r.diags);
    assert_eq!(Code::RequestIdLeak.severity(), Severity::Warn);
}

#[test]
fn ami020_halt_with_requests_in_flight() {
    let mut a = Asm::new("halt-in-flight");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.halt(); // 3
    let r = verify(&a.finish());
    assert!(has(&r, Code::HaltWithInFlight, 3), "{:?}", r.diags);
    assert_eq!(Code::HaltWithInFlight.severity(), Severity::Warn);
}

#[test]
fn ami020_silent_after_a_drain_poll() {
    let mut a = Asm::new("halt-after-drain");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.getfin(4);
    a.halt();
    let r = verify(&a.finish());
    assert!(!r.diags.iter().any(|d| d.code == Code::HaltWithInFlight), "{:?}", r.diags);
}

#[test]
fn ami021_flush_of_in_flight_target() {
    let mut a = Asm::new("flush-target");
    a.li(1, SPM_BASE as i64);
    a.li(2, FAR_BASE as i64);
    a.aload(3, 1, 2);
    a.flush(1, 0); // 3: flushes the line the completion will write
    a.getfin(4);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::FlushInFlightTarget, 3), "{:?}", r.diags);
    assert_eq!(Code::FlushInFlightTarget.severity(), Severity::Warn);
}

#[test]
fn ami022_spm_interval_entirely_outside() {
    // The SPM operand is a two-way join (a non-singleton interval): the
    // const-prop check AMI009 cannot see it, the interval domain can.
    let mut a = Asm::new("ival-spm");
    a.li(1, LOCAL_BASE as i64);
    a.ld64(2, 1, 0); // unknown selector
    a.li(4, FAR_BASE as i64);
    a.beq(2, 0, "hi_slot");
    a.li(3, LOCAL_BASE as i64);
    a.j("issue");
    a.label("hi_slot");
    a.li(3, (LOCAL_BASE + 4096) as i64);
    a.label("issue");
    a.aload(5, 3, 4); // 7: r3 ranges over [LOCAL_BASE, LOCAL_BASE+4096]
    a.getfin(6);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::SpmIntervalOutOfRange, 7), "{:?}", r.diags);
    assert!(!r.diags.iter().any(|d| d.code == Code::SpmOperandOutOfRange), "{:?}", r.diags);
    assert_eq!(Code::SpmIntervalOutOfRange.severity(), Severity::Deny);
}

#[test]
fn ami023_mem_interval_entirely_inside_spm() {
    let mut a = Asm::new("ival-mem");
    a.li(1, LOCAL_BASE as i64);
    a.ld64(2, 1, 0);
    a.li(3, SPM_BASE as i64);
    a.beq(2, 0, "hi");
    a.li(4, (SPM_BASE + 256) as i64);
    a.j("issue");
    a.label("hi");
    a.li(4, (SPM_BASE + 512) as i64);
    a.label("issue");
    a.aload(5, 3, 4); // 7: memory operand interval sits inside the SPM
    a.getfin(6);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::MemIntervalInSpm, 7), "{:?}", r.diags);
    assert_eq!(Code::MemIntervalInSpm.severity(), Severity::Deny);
}

#[test]
fn ami024_queue_depth_exceeded() {
    let mut a = Asm::new("depth");
    a.li(1, 1);
    a.cfgwr(1, CfgReg::QueueLength);
    a.li(2, SPM_BASE as i64);
    a.li(3, FAR_BASE as i64);
    a.aload(4, 2, 3); // first request fills the 1-entry queue
    a.li(5, (SPM_BASE + 512) as i64);
    a.aload(6, 5, 3); // 6: second concurrent request exceeds QueueLength=1
    a.getfin(7);
    a.getfin(8);
    a.halt();
    let r = verify(&a.finish());
    assert!(has(&r, Code::QueueDepthExceeded, 6), "{:?}", r.diags);
    assert!(!has(&r, Code::QueueDepthExceeded, 4), "{:?}", r.diags);
    // Disjoint slots: the depth warning must not drag in an overlap one.
    assert!(!r.diags.iter().any(|d| d.code == Code::OverlappingRequests), "{:?}", r.diags);
    assert_eq!(Code::QueueDepthExceeded.severity(), Severity::Warn);
}

// ---------------------------------------------------------------------------
// Termination: widening bounds the fixpoint on adversarial programs.
// ---------------------------------------------------------------------------

fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

#[test]
fn verifier_terminates_with_bounded_fixpoint_on_adversarial_programs() {
    use Opcode::*;
    const OPS: &[Opcode] = &[
        Add, Sub, Xor, And, Or, Sll, Srl, Mul, SltU, Addi, Xori, Andi, Ori, Slli, Srli, Li,
        Ld, St, Prefetch, Beq, Bne, Blt, Bge, BltU, Jal, Jalr, ALoad, AStore, GetFin, CfgWr,
        CfgRd, Nop, Halt, Roi, Flush,
    ];
    let mut seed: u64 = 0x9e37_79b9_7f4a_7c15;
    for case in 0..64 {
        let len = 4 + (xorshift(&mut seed) % 48) as usize;
        let mut insts = Vec::with_capacity(len);
        for _ in 0..len {
            let op = OPS[(xorshift(&mut seed) % OPS.len() as u64) as usize];
            insts.push(Inst {
                op,
                rd: (xorshift(&mut seed) % 64) as u8,
                rs1: (xorshift(&mut seed) % 64) as u8,
                rs2: (xorshift(&mut seed) % 64) as u8,
                // Mostly in-range branch targets, so loops actually form.
                imm: (xorshift(&mut seed) % (2 * len as u64)) as i64 - len as i64 / 2,
                size: [0u8, 1, 8, 64][(xorshift(&mut seed) % 4) as usize],
                region: 0,
            });
        }
        let p = Program { name: format!("fuzz-{case}"), insts, ..Default::default() };
        let r = verify(&p);
        // Per block, widening caps the changed joins: WIDEN_AFTER exact
        // joins, then each interval bound moves to its extreme at most
        // once, plus the monotone bit/tri components — comfortably under
        // 256 + 72*len changes; blocks <= len + entry.
        let bound = (p.len() + 2) * (256 + 72 * p.len());
        assert!(
            r.fixpoint_iters <= bound,
            "fuzz-{case}: fixpoint_iters {} exceeds bound {bound}",
            r.fixpoint_iters
        );
    }
}

// ---------------------------------------------------------------------------
// verify_ok caching: one analysis per distinct program.
// ---------------------------------------------------------------------------

#[test]
fn verify_ok_results_are_cached_per_program() {
    use amu_sim::workloads::verify_cache_len;
    let mk = |name: &str| {
        let mut a = Asm::new(name);
        a.li(1, SPM_BASE as i64);
        a.li(2, FAR_BASE as i64);
        a.aload(3, 1, 2);
        a.getfin(4);
        a.halt();
        WorkloadSpec {
            name: name.into(),
            prog: a.finish(),
            setup: Box::new(|_| {}),
            validate: Box::new(|_| Ok(())),
        }
    };
    let s1 = mk("cache-probe");
    assert!(s1.verify_ok().is_ok());
    let n = verify_cache_len();
    assert!(n >= 1);
    // An identical spec hits the same entry and agrees; the cache never
    // shrinks (tests in this binary run concurrently, so only monotone
    // facts about the global length are assertable).
    assert!(mk("cache-probe").verify_ok().is_ok());
    assert!(s1.verify_ok().is_ok());
    assert!(verify_cache_len() >= n);
    // The cached error for a rejected program is byte-stable.
    let broken = || {
        let mut a = Asm::new("cache-broken");
        a.li(1, 1); // AMI002: falls off the end
        WorkloadSpec {
            name: "cache-broken".into(),
            prog: a.finish(),
            setup: Box::new(|_| {}),
            validate: Box::new(|_| Ok(())),
        }
    };
    let e1 = broken().verify_ok().unwrap_err();
    let e2 = broken().verify_ok().unwrap_err();
    assert_eq!(e1, e2);
    assert!(e1.contains("AMI002"), "{e1}");
}

// ---------------------------------------------------------------------------
// Golden outputs: the diagnostics table, the JSON envelope (byte-pinned and
// byte-deterministic), and the SARIF rendering.
// ---------------------------------------------------------------------------

/// The shared golden-fixture program: two deny, two warn, one info.
fn kitchen_sink() -> Program {
    let mut a = Asm::new("kitchen-sink");
    a.li(0, 7); // 0: AMI004
    a.roi_begin(); // 1
    a.li(1, LOCAL_BASE as i64); // 2
    a.li(2, FAR_BASE as i64); // 3
    a.aload(3, 1, 2); // 4: AMI009 + AMI011
    a.roi_end(); // 5
    a.halt(); // 6: AMI020 (the request is never drained)
    a.label("dead");
    a.nop(); // 7: AMI003
    a.finish()
}

#[test]
fn diagnostics_table_matches_golden() {
    let r = verify(&kitchen_sink());
    let expected = include_str!("golden/verify_diagnostics.txt");
    assert_eq!(
        r.render_table(Severity::Info),
        expected,
        "diagnostics table drifted from rust/tests/golden/verify_diagnostics.txt"
    );
    assert_eq!((r.deny_count(), r.warn_count(), r.count(Severity::Info)), (2, 2, 1));
}

#[test]
fn check_json_matches_golden() {
    let mut clean = Asm::new("clean");
    clean.li(1, SPM_BASE as i64);
    clean.li(2, FAR_BASE as i64);
    clean.aload(3, 1, 2);
    clean.label("poll");
    clean.getfin(4);
    clean.beq(4, 0, "poll");
    clean.halt();
    let outcomes = vec![
        ("kitchen-sink/amu".to_string(), verify(&kitchen_sink())),
        ("clean/sync".to_string(), verify(&clean.finish())),
    ];
    let got = amu_sim::report::check_json(&outcomes);
    assert_eq!(
        got,
        include_str!("golden/verify_check.json"),
        "JSON envelope drifted from rust/tests/golden/verify_check.json"
    );
}

#[test]
fn check_json_is_byte_deterministic_across_builds() {
    let render = || {
        let cfg = SimConfig::amu();
        let w = REGISTRY.iter().find(|w| w.name() == "gups").unwrap();
        let spec = w.build(&cfg, Variant::Amu, Scale::Test);
        amu_sim::report::check_json(&[("gups/amu".to_string(), spec.verify())])
    };
    let first = render();
    assert_eq!(first, render(), "check --format json must be byte-deterministic");
    assert!(first.contains("\"schema_version\": 1"), "{first}");
}

#[test]
fn check_sarif_lists_every_rule_and_locates_findings() {
    let s = amu_sim::report::check_sarif(&[("ks/amu".to_string(), verify(&kitchen_sink()))]);
    for k in 1..=24 {
        assert!(s.contains(&format!("\"id\": \"AMI{k:03}\"")), "missing rule AMI{k:03}");
    }
    assert!(s.contains("\"fullyQualifiedName\": \"ks/amu@4\""), "{s}");
    assert!(s.contains("\"level\": \"error\""), "{s}");
}
