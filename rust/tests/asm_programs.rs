//! Integration tests for the text-format AMI assembly subsystem:
//!
//! * negative corpus — one malformed program per `ParseErrorKind`, each
//!   asserting the exact `line:col` the parser reports;
//! * disasm round-trip over every builtin benchmark × representative
//!   variant (`parse_str(disasm(p)) == p`);
//! * golden file — the canonical grammar pinned byte-for-byte;
//! * the `examples/asm/` corpus — parses, verifies with zero deny AND
//!   zero warn findings (the CI `--deny-warnings` gate), and runs
//!   end-to-end through the loader with its `.check` assertions;
//! * sweep-cache fingerprint forking on a `.asm` byte change.

use std::path::{Path, PathBuf};

use amu_sim::config::SimConfig;
use amu_sim::isa::{disasm, parse_str, ParseErrorKind};
use amu_sim::session::programs::{self, ProgramError};
use amu_sim::session::registry;
use amu_sim::session::{RunRequest, SweepGrid, Workload};
use amu_sim::workloads::{Scale, Variant, VariantKind};

// ---------------------------------------------------------------------------
// Negative corpus: exact positions for every ParseErrorKind.
// ---------------------------------------------------------------------------

fn parse_err(src: &str) -> amu_sim::isa::ParseError {
    parse_str(src, "neg.asm", "neg").expect_err("program must not parse")
}

#[test]
fn unknown_mnemonic_position() {
    let e = parse_err("nop\n  frobnicate r1\n");
    assert_eq!((e.line, e.col), (2, 3));
    assert_eq!(e.kind, ParseErrorKind::UnknownMnemonic("frobnicate".into()));
    assert_eq!(e.to_string(), "neg.asm:2:3: unknown mnemonic 'frobnicate'");
}

#[test]
fn unknown_directive_position() {
    let e = parse_err(".programme foo\nhalt\n");
    assert_eq!((e.line, e.col), (1, 1));
    assert_eq!(e.kind, ParseErrorKind::UnknownDirective(".programme".into()));
}

#[test]
fn bad_register_position() {
    let e = parse_err("add r1, r99, r2\nhalt\n");
    assert_eq!((e.line, e.col), (1, 9));
    assert_eq!(e.kind, ParseErrorKind::BadRegister("r99".into()));
}

#[test]
fn bad_immediate_position() {
    let e = parse_err("li r1, 12x9\nhalt\n");
    assert_eq!((e.line, e.col), (1, 8));
    assert_eq!(e.kind, ParseErrorKind::BadImmediate("12x9".into()));
    // Division by zero is a bad immediate too, not a panic.
    let e = parse_err("li r1, 8/0\nhalt\n");
    assert_eq!((e.line, e.col), (1, 8));
    assert_eq!(e.kind, ParseErrorKind::BadImmediate("8/0".into()));
}

#[test]
fn wrong_operand_count_position() {
    let e = parse_err("add r1, r2\nhalt\n");
    assert_eq!((e.line, e.col), (1, 1));
    match e.kind {
        ParseErrorKind::WrongOperandCount { mnemonic, expected, got } => {
            assert_eq!(mnemonic, "add");
            assert_eq!(expected, "rd, rs1, rs2");
            assert_eq!(got, 2);
        }
        other => panic!("expected WrongOperandCount, got {other:?}"),
    }
}

#[test]
fn bad_address_operand_position() {
    let e = parse_err("ld.8 r1, r2\nhalt\n");
    assert_eq!((e.line, e.col), (1, 10));
    assert_eq!(e.kind, ParseErrorKind::BadAddressOperand("r2".into()));
}

#[test]
fn bad_cfg_reg_position() {
    let e = parse_err("cfgwr r1, turbo\nhalt\n");
    assert_eq!((e.line, e.col), (1, 11));
    assert_eq!(e.kind, ParseErrorKind::BadCfgReg("turbo".into()));
}

#[test]
fn bad_region_position() {
    let e = parse_err(".region fast\nnop\nhalt\n");
    assert_eq!((e.line, e.col), (1, 9));
    assert_eq!(e.kind, ParseErrorKind::BadRegion("fast".into()));
}

#[test]
fn bad_size_position() {
    let e = parse_err("ld.3 r1, 0(r2)\nhalt\n");
    assert_eq!((e.line, e.col), (1, 1));
    assert_eq!(e.kind, ParseErrorKind::BadSize("ld.3".into()));
}

#[test]
fn duplicate_label_position() {
    let e = parse_err("x: nop\nx: halt\n");
    assert_eq!((e.line, e.col), (2, 1));
    assert_eq!(e.kind, ParseErrorKind::DuplicateLabel("x".into()));
}

#[test]
fn undefined_label_position() {
    // Reported at the first reference, in source order.
    let e = parse_err("j nowhere\nhalt\n");
    assert_eq!((e.line, e.col), (1, 3));
    assert_eq!(e.kind, ParseErrorKind::UndefinedLabel("nowhere".into()));
}

#[test]
fn duplicate_arg_position() {
    let e = parse_err(".arg n 1\n.arg n 2\nnop\nhalt\n");
    assert_eq!((e.line, e.col), (2, 6));
    assert_eq!(e.kind, ParseErrorKind::DuplicateArg("n".into()));
}

#[test]
fn unknown_symbol_position() {
    let e = parse_err("li r1, $bogus\nhalt\n");
    assert_eq!((e.line, e.col), (1, 8));
    assert_eq!(e.kind, ParseErrorKind::UnknownSymbol("$bogus".into()));
}

#[test]
fn aliased_request_regs_position() {
    // The builder would assert (panic); the parser must pre-check.
    let e = parse_err("aload r2, r2, r3\nhalt\n");
    assert_eq!((e.line, e.col), (1, 7));
    assert_eq!(e.kind, ParseErrorKind::AliasedRequestRegs("aload".into()));
}

#[test]
fn empty_program_position() {
    let e = parse_err("; nothing but comments\n\n# and blanks\n");
    assert_eq!((e.line, e.col), (1, 1));
    assert_eq!(e.kind, ParseErrorKind::EmptyProgram);
}

// ---------------------------------------------------------------------------
// Round-trip: every builtin × representative variant re-parses identically.
// ---------------------------------------------------------------------------

fn normalized_labels(p: &amu_sim::isa::Program) -> Vec<(usize, String)> {
    let mut v: Vec<(usize, String)> = p.labels.iter().map(|(n, at)| (*at, n.clone())).collect();
    v.sort();
    v
}

#[test]
fn every_builtin_variant_round_trips_through_disasm() {
    let representative = |kind: VariantKind| match kind {
        VariantKind::Sync => Variant::Sync,
        VariantKind::Amu => Variant::Amu,
        VariantKind::AmuLlvm => Variant::AmuLlvm,
        VariantKind::GroupPrefetch => Variant::GroupPrefetch(16),
        VariantKind::SwPrefetch => Variant::SwPrefetch { batch: 16, depth: 2 },
    };
    for w in registry::REGISTRY {
        for &kind in w.supported_variants() {
            let v = representative(kind);
            let cfg = match kind {
                VariantKind::Amu | VariantKind::AmuLlvm => SimConfig::amu(),
                _ => SimConfig::baseline(),
            };
            let spec = w.build(&cfg, v, Scale::Test);
            let text = disasm(&spec.prog);
            let q = parse_str(&text, "<disasm>", &spec.prog.name).unwrap_or_else(|e| {
                panic!("{}/{:?}: disasm failed to re-parse: {e}", w.name(), kind)
            });
            assert_eq!(spec.prog.insts, q.prog.insts, "{}/{kind:?}", w.name());
            assert_eq!(spec.prog.name, q.prog.name, "{}/{kind:?}", w.name());
            assert_eq!(
                spec.prog.addr_taken,
                q.prog.addr_taken,
                "{}/{kind:?}",
                w.name()
            );
            assert_eq!(
                normalized_labels(&spec.prog),
                normalized_labels(&q.prog),
                "{}/{kind:?}",
                w.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Golden file: the canonical grammar, pinned byte-for-byte.
// ---------------------------------------------------------------------------

/// A hand-rolled program exercising every mnemonic family the
/// disassembler can emit; its canonical text lives in
/// `tests/golden/disasm_reference.txt`.
fn golden_program() -> amu_sim::isa::Program {
    use amu_sim::isa::{Asm, CfgReg};
    use amu_sim::stats::Region;
    let mut a = Asm::new("golden");
    a.region(Region::Setup);
    a.li(1, 0);
    a.li_label(2, "task");
    a.mark_addr_taken("task");
    a.region(Region::Main);
    a.label("loop");
    a.add(3, 1, 2);
    a.sub(4, 3, 1);
    a.xor(5, 4, 3);
    a.and(6, 5, 4);
    a.or(7, 6, 5);
    a.sll(8, 7, 1);
    a.srl(9, 8, 1);
    a.mul(10, 9, 8);
    a.sltu(11, 10, 9);
    a.addi(12, 11, 5);
    a.xori(13, 12, 3);
    a.andi(14, 13, 7);
    a.ori(15, 14, 1);
    a.slli(16, 15, 2);
    a.srli(17, 16, 2);
    a.ld(18, 1, 8, 8);
    a.ld(19, 1, 0, 4);
    a.st(18, 1, -8, 2);
    a.st(19, 1, 16, 1);
    a.prefetch(1, 64);
    a.flush(1, 0);
    a.beq(1, 2, "loop");
    a.bne(3, 4, "loop");
    a.blt(5, 6, "loop");
    a.bge(7, 8, "loop");
    a.bltu(9, 10, "loop");
    a.call("task");
    a.j("after");
    a.label("task");
    a.region(Region::Scheduler);
    a.cfgwr(1, CfgReg::Granularity);
    a.cfgwr(1, CfgReg::QueueBase);
    a.cfgwr(1, CfgReg::QueueLength);
    a.cfgrd(20, CfgReg::Granularity);
    a.aload(21, 22, 23);
    a.astore(24, 22, 23);
    a.getfin(25);
    a.ret();
    a.label("after");
    a.region(Region::Disambig);
    a.jal(26, "task");
    a.jalr(0, 26);
    a.jalr(27, 26);
    a.region(Region::Main);
    a.roi_begin();
    a.nop();
    a.roi_end();
    a.halt();
    a.finish()
}

fn golden_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden/disasm_reference.txt")
}

#[test]
fn disasm_matches_the_golden_reference() {
    let prog = golden_program();
    let text = disasm(&prog);
    let golden = std::fs::read_to_string(golden_path())
        .expect("tests/golden/disasm_reference.txt must exist");
    assert_eq!(
        text, golden,
        "canonical disasm drifted from the golden file; if the grammar \
         change is intentional, regenerate the golden"
    );
    // And the golden text itself reassembles to the identical program.
    let q = parse_str(&golden, "golden", "golden").expect("golden must parse");
    assert_eq!(prog.insts, q.prog.insts);
    assert_eq!(prog.addr_taken, q.prog.addr_taken);
    assert_eq!(normalized_labels(&prog), normalized_labels(&q.prog));
}

// ---------------------------------------------------------------------------
// The examples/asm corpus: clean verification and end-to-end runs.
// ---------------------------------------------------------------------------

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/asm")
}

fn corpus_files() -> Vec<PathBuf> {
    let mut v: Vec<PathBuf> = std::fs::read_dir(corpus_dir())
        .expect("examples/asm must exist")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "asm"))
        .collect();
    v.sort();
    assert!(v.len() >= 6, "corpus shrank: {} kernels", v.len());
    v
}

#[test]
fn corpus_verifies_with_zero_deny_and_zero_warn() {
    for path in corpus_files() {
        let (name, prog) = programs::parse_for_check(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let report = amu_sim::isa::verify(&prog);
        assert_eq!(
            (report.deny_count(), report.warn_count()),
            (0, 0),
            "{name} has findings: {report:?}"
        );
    }
}

#[test]
fn corpus_loads_and_runs_end_to_end() {
    for path in corpus_files() {
        let lp = programs::load_file(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let ami = !lp.supported_variants().contains(&VariantKind::Sync);
        let cfg = if ami { SimConfig::amu() } else { SimConfig::baseline() };
        // `.run()` validates the program's `.check` assertions.
        let r = RunRequest::bench(lp.name())
            .config(cfg)
            .latency_ns(300.0)
            .scale(Scale::Test)
            .run()
            .unwrap_or_else(|e| panic!("{}: {e}", lp.name()));
        assert!(r.insts > 0, "{}", lp.name());
    }
}

#[test]
fn ami_corpus_program_refuses_the_baseline_config() {
    // Under amu.enabled = false the AMI datapath never ticks; the loader
    // must surface a typed UnsupportedVariant error instead of hanging.
    let path = corpus_dir().join("ami_sum.asm");
    let lp = programs::load_file(path.to_str().unwrap()).expect("loads clean");
    assert_eq!(
        lp.supported_variants(),
        &[VariantKind::Amu, VariantKind::AmuLlvm][..]
    );
    let e = RunRequest::bench(lp.name())
        .config(SimConfig::baseline())
        .scale(Scale::Test)
        .build()
        .expect_err("baseline config must be rejected");
    assert!(
        e.to_string().contains("does not support variant"),
        "unexpected error: {e}"
    );
}

#[test]
fn loaded_corpus_round_trips_through_disasm() {
    for path in corpus_files() {
        let src = std::fs::read_to_string(&path).unwrap();
        let parsed =
            parse_str(&src, path.to_str().unwrap(), "x").expect("corpus parses");
        let text = disasm(&parsed.prog);
        let q = parse_str(&text, "<disasm>", &parsed.prog.name).unwrap_or_else(|e| {
            panic!("{}: disasm failed to re-parse: {e}", path.display())
        });
        assert_eq!(parsed.prog.insts, q.prog.insts, "{}", path.display());
        assert_eq!(parsed.prog.addr_taken, q.prog.addr_taken, "{}", path.display());
    }
}

// ---------------------------------------------------------------------------
// Sweep-cache fingerprint forking on .asm byte changes.
// ---------------------------------------------------------------------------

#[test]
fn editing_a_program_file_forks_the_sweep_fingerprint() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("amu_fork_{}.asm", std::process::id()));
    let p = path.to_str().unwrap();

    std::fs::write(&path, ".program tprog_fork\n  nop\n  halt\n").unwrap();
    let fp1 = programs::load_file(p).expect("v1 loads").fingerprint();

    std::fs::write(&path, ".program tprog_fork\n  nop\n  nop\n  halt\n").unwrap();
    let fp2 = programs::load_file(p).expect("v2 loads").fingerprint();
    assert_ne!(fp1, fp2, "content fingerprint must fork on a byte change");

    let base = SweepGrid::new(Scale::Test)
        .benches(["tprog_fork"])
        .configs(["baseline"])
        .latencies_ns([300.0]);
    let g1 = base.clone().programs([("tprog_fork".to_string(), fp1)]);
    let g2 = base.clone().programs([("tprog_fork".to_string(), fp2)]);
    assert_ne!(
        g1.fingerprint(),
        g2.fingerprint(),
        "sweep fingerprint must fork when the program bytes change"
    );
    assert_ne!(
        base.fingerprint(),
        g1.fingerprint(),
        "a swept program refines the plain grid fingerprint"
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Registry integration: loaded programs merge into names and suggestions.
// ---------------------------------------------------------------------------

#[test]
fn loaded_programs_join_known_names_and_typo_hints() {
    programs::load_str(
        ".program tprog_suggest_me\n  nop\n  halt\n",
        "tprog_suggest_me.asm",
    )
    .expect("loads clean");
    let names = registry::known_names();
    assert!(names.contains(&"tprog_suggest_me"), "{names:?}");
    let mut sorted = names.clone();
    sorted.sort_unstable();
    assert_eq!(names, sorted, "known_names must stay sorted");

    // One-edit typo resolves to the loaded program in the error hint.
    let e = RunRequest::bench("tprog_suggest_mq").build().expect_err("unknown");
    let msg = e.to_string();
    assert!(msg.contains("unknown benchmark 'tprog_suggest_mq'"), "{msg}");
    assert!(msg.contains("did you mean 'tprog_suggest_me'?"), "{msg}");
    assert!(msg.contains("tprog_suggest_me"), "{msg}");
}

#[test]
fn shadowing_and_io_errors_are_typed() {
    let e = programs::load_str(".program gups\n  nop\n  halt\n", "gups.asm")
        .expect_err("builtin shadowing must be refused");
    assert!(matches!(e, ProgramError::ShadowsBuiltin(_)), "{e}");

    let e = programs::load_file("/nonexistent/nope.asm").expect_err("missing file");
    assert!(matches!(e, ProgramError::Io { .. }), "{e}");
    assert!(e.to_string().contains("/nonexistent/nope.asm"), "{e}");
}
