//! Property-based tests over coordinator invariants (in-repo prop runner).

use amu_sim::config::SimConfig;
use amu_sim::isa::mem::{FAR_BASE, SPM_BASE};
use amu_sim::isa::Asm;
use amu_sim::sim::Simulator;
use amu_sim::testing::{check, check_with, shrink_vec, PropConfig};

/// Random AMI op sequences: every run must conserve request IDs, complete
/// every issued request exactly once, and leave the pipeline clean.
#[test]
fn prop_amu_id_conservation_under_random_programs() {
    check(
        &PropConfig { cases: 24, seed: 0xA11CE, ..Default::default() },
        |rng| {
            // (n_aloads, use_branches)
            (1 + rng.below(40) as usize, rng.below(2) == 1)
        },
        |&(n, branchy)| {
            let mut a = Asm::new("prop");
            a.li(1, SPM_BASE as i64);
            a.li(2, FAR_BASE as i64);
            a.li(10, 0);
            a.li(11, n as i64);
            for k in 0..n as i64 {
                if branchy {
                    // Data-dependent hiccup to provoke squashes.
                    a.mul(5, 10, 10);
                    a.addi(5, 5, k);
                    a.andi(5, 5, 1);
                    a.beq(5, 0, &format!("skip{k}"));
                    a.nop();
                    a.label(&format!("skip{k}"));
                }
                a.addi(3, 1, (k % 64) * 64);
                a.addi(4, 2, k * 4096);
                a.aload(6, 3, 4);
            }
            a.label("drain");
            a.getfin(7);
            a.beq(7, 0, "drain");
            a.addi(10, 10, 1);
            a.blt(10, 11, "drain");
            a.halt();
            let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
            cfg.far.jitter_frac = 0.0;
            let mut sim = Simulator::new(cfg, a.finish());
            sim.run().map_err(|e| e)?;
            if !sim.amu_ids_conserved() {
                return Err("ids not conserved".into());
            }
            if sim.memsys.far_inflight() != 0 {
                return Err("requests left in flight".into());
            }
            Ok(())
        },
    );
}

/// Random load/store programs: the timed core's architectural memory must
/// match the functional interpreter exactly.
#[test]
fn prop_core_matches_interp_on_random_memory_programs() {
    use amu_sim::isa::interp::{CompletionOrder, Interp};
    use amu_sim::isa::GuestMem;
    check_with(
        &PropConfig { cases: 16, seed: 0xBEEF, ..Default::default() },
        |rng| {
            let n = 4 + rng.below(40);
            (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
        },
        |seeds| {
            let mut a = Asm::new("prop-mem");
            a.li(1, amu_sim::isa::LOCAL_BASE as i64);
            for (i, s) in seeds.iter().enumerate() {
                let r = 2 + (i % 20) as u8;
                let off = ((s >> 8) % 512) as i64 * 8;
                match s % 4 {
                    0 => {
                        a.li(r, (s >> 32) as i64);
                        a.st64(r, 1, off);
                    }
                    1 => {
                        a.ld64(r, 1, off);
                    }
                    2 => {
                        a.li(r, *s as i64 & 0xFFFF);
                        a.st(r, 1, off, 4);
                    }
                    _ => {
                        a.ld64(r, 1, off);
                        a.addi(r, r, 1);
                        a.st64(r, 1, off);
                    }
                }
            }
            a.halt();
            let prog = a.finish();
            let mut sim = Simulator::new(SimConfig::baseline(), prog.clone());
            sim.run().map_err(|e| e)?;
            let mut mem = GuestMem::new();
            let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
            it.run(&prog, 1_000_000).map_err(|e| e)?;
            let a_sum = sim.guest.checksum(amu_sim::isa::LOCAL_BASE, 512 * 8 + 64);
            let b_sum = mem.checksum(amu_sim::isa::LOCAL_BASE, 512 * 8 + 64);
            if a_sum != b_sum {
                return Err("architectural memory diverged from oracle".into());
            }
            Ok(())
        },
        shrink_vec,
    );
}

/// Cache + MSHR invariants under random access streams.
#[test]
fn prop_memsys_completes_every_accepted_access() {
    use amu_sim::mem::{AccessKind, MemSys, SubmitResult};
    check(
        &PropConfig { cases: 20, seed: 0xCAFE, ..Default::default() },
        |rng| {
            let n = 1 + rng.below(200);
            (0..n)
                .map(|_| (rng.below(1 << 22), rng.below(3)))
                .collect::<Vec<(u64, u64)>>()
        },
        |ops| {
            let mut cfg = SimConfig::baseline().with_far_latency_ns(300.0);
            cfg.far.jitter_frac = 0.0;
            let mut m = MemSys::new(&cfg);
            let mut accepted = Vec::new();
            let mut cycle = 0u64;
            for (i, (addr_seed, kind)) in ops.iter().enumerate() {
                let kind = match kind {
                    0 => AccessKind::Load,
                    1 => AccessKind::Store,
                    _ => AccessKind::Prefetch,
                };
                let addr = amu_sim::isa::FAR_BASE + (addr_seed & !7);
                loop {
                    m.tick(cycle, 10, 4);
                    match m.submit(kind, addr, i as u32, cycle, 4) {
                        SubmitResult::Accepted => break,
                        _ => cycle += 1,
                    }
                }
                if kind != AccessKind::Prefetch {
                    accepted.push(i as u32);
                }
                cycle += 1;
            }
            for c in cycle..cycle + 2_000_000 {
                m.tick(c, 10, 4);
                if m.pending_events() == 0 {
                    break;
                }
            }
            let mut done: Vec<u32> = m.completions.iter().map(|c| c.token).collect();
            done.sort_unstable();
            done.dedup();
            if done.len() != accepted.len() {
                return Err(format!(
                    "{} accepted but {} completed",
                    accepted.len(),
                    done.len()
                ));
            }
            if m.far_inflight() != 0 {
                return Err("link accounting leaked".into());
            }
            Ok(())
        },
    );
}

/// Schema invariant: [`ScenarioStats`] values round-trip losslessly
/// through the schema-ordered `MetricSet` record and a full-schema CSV row
/// for EVERY `ScenarioCol` variant (including the multi-tenant columns
/// `tenant_slowdown_max` / `qos_throttle_events` / `pool_steal_cycles`),
/// and `accumulate` / `merged` obey each column's declared merge
/// semantics.
#[test]
fn prop_scenario_stats_round_trip_through_metric_set_and_merge() {
    use amu_sim::session::metrics::{MetricSet, Selection};
    use amu_sim::session::RunResult;
    use amu_sim::stats::schema::{Merge, ScenarioCol, ScenarioStats, NUM_SCENARIO_COLS, SCENARIO_COLUMNS};
    // The tenant columns must be in the table, with the slowdown cell a
    // high-water mark (multi-tenant cells re-stamp one shared snapshot).
    for name in ["tenant_slowdown_max", "qos_throttle_events", "pool_steal_cycles"] {
        assert!(
            SCENARIO_COLUMNS.iter().any(|d| d.name == name),
            "schema table lost the {name} column"
        );
    }
    assert_eq!(ScenarioCol::TenantSlowdownMax.def().merge, Merge::Max);
    check(
        &PropConfig { cases: 32, seed: 0x7E4A47, ..Default::default() },
        |rng| (0..2 * NUM_SCENARIO_COLS).map(|_| rng.next_u64() >> 12).collect::<Vec<u64>>(),
        |vals| {
            let (a_vals, b_vals) = vals.split_at(NUM_SCENARIO_COLS);
            let mut a = ScenarioStats::default();
            let mut b = ScenarioStats::default();
            for (i, d) in SCENARIO_COLUMNS.iter().enumerate() {
                a.set(d.col, a_vals[i]);
                b.set(d.col, b_vals[i]);
            }
            // Every variant reads back exactly what was written.
            for (i, d) in SCENARIO_COLUMNS.iter().enumerate() {
                if a.get(d.col) != a_vals[i] {
                    return Err(format!("{} did not read back", d.name));
                }
            }
            // Round trip through the schema-ordered MetricSet record...
            let r = RunResult {
                bench: "gups".into(),
                config: "amu".into(),
                backend: "pooled".into(),
                variant: "amu".into(),
                scenario: a,
                ..Default::default()
            };
            let back = MetricSet::of(&r).to_run_result();
            if back != r {
                return Err("MetricSet::of -> to_run_result was lossy".into());
            }
            // ... and through one full-schema CSV row.
            let row = MetricSet::of(&r).csv_row(&Selection::All);
            let parsed = MetricSet::parse_csv_row(&row)?.to_run_result();
            if parsed.scenario != a {
                return Err(format!("CSV round trip lost scenario values in '{row}'"));
            }
            // accumulate obeys the per-column Merge declaration.
            let mut acc = a;
            acc.accumulate(&b);
            for (i, d) in SCENARIO_COLUMNS.iter().enumerate() {
                let want = match d.merge {
                    Merge::Sum => a_vals[i].wrapping_add(b_vals[i]),
                    Merge::Max => a_vals[i].max(b_vals[i]),
                };
                if acc.get(d.col) != want {
                    return Err(format!("{} merged as {:?} incorrectly", d.name, d.merge));
                }
            }
            // merged == a left fold of accumulate; the empty merge is zero.
            if ScenarioStats::merged([&a, &b]) != acc {
                return Err("merged != accumulate fold".into());
            }
            if ScenarioStats::merged(std::iter::empty::<&ScenarioStats>()) != ScenarioStats::default() {
                return Err("empty merge must be the zero snapshot".into());
            }
            Ok(())
        },
    );
}

/// Coroutine scheduler never loses a task regardless of task count.
#[test]
fn prop_scheduler_finishes_all_tasks() {
    use amu_sim::coro::CoroRt;
    use amu_sim::isa::mem::Layout;
    check(
        &PropConfig { cases: 10, seed: 0x50_ED, ..Default::default() },
        |rng| 1 + rng.below(100) as usize,
        |&ntasks| {
            let mut cfg = SimConfig::amu().with_far_latency_ns(200.0);
            cfg.far.jitter_frac = 0.0;
            let meta = cfg.amu.queue_length as u64 * 32;
            let mut layout = Layout::new((cfg.amu.spm_bytes as u64 - meta) as usize);
            let rt = CoroRt::new(&mut layout, ntasks, cfg.amu.queue_length);
            let far = layout.alloc_far(ntasks as u64 * 8, 64);
            let mut a = Asm::new("prop-coro");
            a.li(1, 8);
            a.cfgwr(1, amu_sim::isa::CfgReg::Granularity);
            rt.emit_prologue(&mut a);
            a.j("sched");
            a.label("task");
            rt.emit_load_param(&mut a, 10, 0);
            rt.emit_load_param(&mut a, 11, 1);
            a.aload(12, 11, 10);
            rt.emit_await(&mut a, 12, &[10, 11], "t_r");
            rt.emit_task_finish(&mut a);
            a.label("sched");
            rt.emit_scheduler(&mut a, "done");
            a.label("done");
            a.halt();
            let prog = a.finish();
            let mut sim = Simulator::new(cfg, prog.clone());
            rt.write_tcbs(&mut sim.guest, &prog, "task", |tid| {
                [far + tid as u64 * 8, SPM_BASE + (tid as u64 % 512) * 64, 0, 0]
            });
            sim.run().map_err(|e| format!("{ntasks} tasks: {e}"))?;
            Ok(())
        },
    );
}
