//! Cross-module integration tests: configs x workloads x simulator,
//! functional-vs-timing oracle agreement, and AMU invariants end to end.

use amu_sim::config::SimConfig;
use amu_sim::workloads::{build, Scale, Variant, ALL};

#[test]
fn every_benchmark_validates_on_every_preset() {
    for name in ALL {
        for preset in ["baseline", "cxl-ideal", "amu", "amu-dma"] {
            let mut cfg = SimConfig::preset(preset).unwrap().with_far_latency_ns(300.0);
            cfg.far.jitter_frac = 0.0;
            let variant = amu_sim::workloads::variant_for(&cfg);
            let spec = build(name, &cfg, variant, Scale::Test);
            let sim = spec
                .run(&cfg)
                .unwrap_or_else(|e| panic!("{name}/{preset}: {e}"));
            assert!(sim.stats.insts_committed > 0, "{name}/{preset}: no progress");
            assert!(sim.amu_ids_conserved(), "{name}/{preset}: AMU ids leaked");
        }
    }
}

#[test]
fn amu_beats_baseline_at_high_latency_on_random_access() {
    // The paper's core claim at benchmark granularity.
    for name in ["gups", "bs", "ll", "ht"] {
        let base_cfg = SimConfig::baseline().with_far_latency_ns(2000.0);
        let mut amu_cfg = SimConfig::amu().with_far_latency_ns(2000.0);
        amu_cfg.far.jitter_frac = 0.0;
        let base = build(name, &base_cfg, Variant::Sync, Scale::Test)
            .run(&base_cfg)
            .unwrap();
        let amu = build(name, &amu_cfg, Variant::Amu, Scale::Test)
            .run(&amu_cfg)
            .unwrap();
        assert!(
            amu.stats.measured_cycles < base.stats.measured_cycles,
            "{name}: AMU {} !< baseline {}",
            amu.stats.measured_cycles,
            base.stats.measured_cycles
        );
    }
}

#[test]
fn amu_latency_insensitivity_vs_baseline_degradation() {
    // Fig 8 shape: between 0.2us and 2us the baseline degrades much more
    // than AMU on GUPS.
    let run = |preset: &str, lat: f64| {
        let mut cfg = SimConfig::preset(preset).unwrap().with_far_latency_ns(lat);
        cfg.far.jitter_frac = 0.0;
        let v = amu_sim::workloads::variant_for(&cfg);
        build("gups", &cfg, v, Scale::Test)
            .run(&cfg)
            .unwrap()
            .stats
            .measured_cycles as f64
    };
    let base_ratio = run("baseline", 2000.0) / run("baseline", 200.0);
    let amu_ratio = run("amu", 2000.0) / run("amu", 200.0);
    assert!(
        base_ratio > 2.0 * amu_ratio,
        "baseline degradation {base_ratio:.2}x should dwarf AMU {amu_ratio:.2}x"
    );
}

#[test]
fn mlp_grows_with_latency_under_amu() {
    // Fig 9 shape: AMU MLP rises with latency; baseline MLP saturates.
    let run = |preset: &str, lat: f64| {
        let mut cfg = SimConfig::preset(preset).unwrap().with_far_latency_ns(lat);
        cfg.far.jitter_frac = 0.0;
        let v = amu_sim::workloads::variant_for(&cfg);
        let sim = build("gups", &cfg, v, Scale::Test).run(&cfg).unwrap();
        sim.stats.mlp()
    };
    let amu_low = run("amu", 200.0);
    let amu_high = run("amu", 5000.0);
    assert!(amu_high > amu_low * 1.1, "AMU MLP must scale: {amu_low:.1} -> {amu_high:.1}");
}

#[test]
fn dma_mode_loses_to_amu() {
    let mut amu = SimConfig::amu().with_far_latency_ns(1000.0);
    amu.far.jitter_frac = 0.0;
    let mut dma = SimConfig::amu_dma().with_far_latency_ns(1000.0);
    dma.far.jitter_frac = 0.0;
    let a = build("gups", &amu, Variant::Amu, Scale::Test).run(&amu).unwrap();
    let d = build("gups", &dma, Variant::Amu, Scale::Test).run(&dma).unwrap();
    assert!(d.stats.measured_cycles > a.stats.measured_cycles);
}

#[test]
fn config_file_overrides_apply_end_to_end() {
    let mut cfg = SimConfig::baseline();
    let doc = amu_sim::util::toml_lite::parse("[core]\nrob_entries = 32\n[l1d]\nmshrs = 2\n")
        .unwrap();
    cfg.apply_overrides(&doc).unwrap();
    let spec = build("gups", &cfg, Variant::Sync, Scale::Test);
    let sim = spec.run(&cfg).unwrap();
    // A 32-entry ROB with 2 MSHRs must be much slower than Table 2.
    let full = build("gups", &SimConfig::baseline(), Variant::Sync, Scale::Test)
        .run(&SimConfig::baseline())
        .unwrap();
    assert!(sim.stats.measured_cycles > full.stats.measured_cycles);
}
