//! Fast-forward determinism suite: event-driven fast-forward must be a
//! pure host-speed optimization. Every simulated statistic, sweep CSV
//! byte, and multi-tenant outcome must be identical with the feature on
//! or off — across all four far-memory backends — and the grid
//! fingerprint must not fork on the toggle (ff and non-ff runs share one
//! cache entry). The CI determinism leg repeats the CSV comparisons
//! through the real binary.

use amu_sim::config::{FarBackendKind, SimConfig};
use amu_sim::session::tenancy::{self, MtRequest};
use amu_sim::session::{cache, metrics, RunRequest, Selection, Session, SweepGrid};
use amu_sim::workloads::Scale;

fn grid(ff: bool, backend: &str) -> SweepGrid {
    SweepGrid::new(Scale::Test)
        .benches(["gups", "ll"])
        .configs(["baseline", "amu"])
        .latencies_ns([300.0, 1500.0])
        .backends([backend])
        .fast_forward(ff)
}

/// The headline guard: for each backend, the same grid swept with
/// fast-forward on and off must produce byte-identical CSV — row order,
/// every counter, every occupancy integral.
#[test]
fn sweep_csv_is_byte_identical_with_fast_forward_on_or_off_for_every_backend() {
    for backend in ["serial-link", "pooled", "distribution", "hybrid"] {
        let on = grid(true, backend);
        let off = grid(false, backend);
        assert_eq!(
            on.fingerprint(),
            off.fingerprint(),
            "{backend}: the toggle must not fork the cache fingerprint"
        );
        let rows_on = Session::new().jobs(2).quiet(true).sweep(&on).unwrap();
        let rows_off = Session::new().jobs(2).quiet(true).sweep(&off).unwrap();
        let csv_on = cache::to_csv_string(on.fingerprint(), &rows_on);
        let csv_off = cache::to_csv_string(off.fingerprint(), &rows_off);
        assert_eq!(
            csv_on, csv_off,
            "{backend}: fast-forward must not change a byte of the sweep CSV"
        );
    }
}

/// Replay property: a fast-forwarded run re-executed tick-by-tick must
/// land on the same row across the FULL metric schema (scenario columns
/// included) — i.e. `next_event_cycle` never over-jumps past a cycle at
/// which anything could have changed. GUPS at the paper's 5 µs far
/// latency is the cell the fast-forward speedup target is measured on.
#[test]
fn fast_forwarded_rows_match_tick_by_tick_replay_across_the_full_schema() {
    let all = Selection::parse("all").unwrap();
    let cells = [
        ("gups", "baseline", 5000.0),
        ("gups", "amu", 5000.0),
        ("bfs", "amu", 1000.0),
        ("ll", "cxl-ideal", 1500.0),
    ];
    for (bench, config, latency_ns) in cells {
        let run = |ff: bool| {
            let mut cfg = SimConfig::preset(config).unwrap();
            cfg.fast_forward = ff;
            RunRequest::bench(bench)
                .config(cfg)
                .latency_ns(latency_ns)
                .scale(Scale::Test)
                .run()
                .unwrap()
        };
        let fast = run(true);
        let slow = run(false);
        assert_eq!(
            metrics::csv_row(&fast, &all),
            metrics::csv_row(&slow, &all),
            "{bench}/{config}@{latency_ns}ns: full-schema row must be identical"
        );
    }
}

/// Multi-tenant rounds interleave `run_for` windows on one shared pool:
/// fast-forward jumps clamp to the round boundary, so the per-tenant
/// slowdown CSV must be byte-identical with the feature on or off.
#[test]
fn mtrun_csv_is_byte_identical_with_fast_forward_on_or_off() {
    let request = |ff: bool| {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.backend = FarBackendKind::Pooled;
        cfg.fast_forward = ff;
        let tenants = tenancy::parse_tenants("gups:2,bfs:1").unwrap();
        let mut req = MtRequest::new(tenants, cfg);
        req.scale = Scale::Test;
        req.jobs = 2;
        req.quiet = true;
        req
    };
    let on = request(true);
    let off = request(false);
    let csv_on = tenancy::mt_csv(&on.tenants, on.scale, &on.run().unwrap());
    let csv_off = tenancy::mt_csv(&off.tenants, off.scale, &off.run().unwrap());
    assert_eq!(csv_on, csv_off, "fast-forward must not change a byte of mtrun output");
}
