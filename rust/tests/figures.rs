//! Figure/table regeneration smoke tests at test scale: every generator
//! must produce plausible output (the paper-scale numbers live in
//! EXPERIMENTS.md and are produced by `cargo bench`).

use amu_sim::report;
use amu_sim::session::{RunRequest, Session};
use amu_sim::workloads::{Scale, Variant};

#[test]
fn table6_matches_paper_bands() {
    let t = report::table6();
    assert!(t.contains("LUT"));
    assert!(t.contains("71510 gates") || t.contains("gates"));
}

#[test]
fn fig3_group_size_sensitivity_renders() {
    let s = report::fig3(&Session::new(), Scale::Test, 1000.0);
    assert!(s.lines().count() > 5, "{s}");
    assert!(s.contains("group"));
}

#[test]
fn table5_disambiguation_renders() {
    let s = report::table5(&Session::new(), Scale::Test);
    assert!(s.contains("hj") && s.contains("ht"), "{s}");
    assert!(s.contains('%'));
}

#[test]
fn single_run_request_row_sane() {
    let r = RunRequest::bench("gups")
        .config_name("amu")
        .variant(Variant::Amu)
        .latency_ns(1000.0)
        .scale(Scale::Test)
        .run()
        .unwrap();
    assert!(r.mlp > 1.0, "AMU GUPS must overlap: mlp={}", r.mlp);
    assert!(r.peak_inflight >= 16);
}
