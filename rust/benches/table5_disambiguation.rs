//! Bench harness for paper Table 5: software memory disambiguation
//! overhead (HJ, HT) across latencies.
use amu_sim::report;
use amu_sim::session::Session;
fn bench_scale() -> amu_sim::workloads::Scale {
    match std::env::var("AMU_BENCH_SCALE").as_deref() {
        Ok("paper") => amu_sim::workloads::Scale::Paper,
        _ => amu_sim::workloads::Scale::Test,
    }
}
fn main() {
    let session = Session::new();
    report::write_report("table5", &report::table5(&session, bench_scale()));
}
