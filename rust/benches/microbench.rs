//! Microbenchmarks: simulator throughput (host cycles/sec) per subsystem —
//! the §Perf measurement harness (criterion is unavailable offline; this
//! reports wall-clock and simulated-cycle rates directly).
use amu_sim::config::SimConfig;
use amu_sim::session::RunRequest;
use amu_sim::workloads::{Scale, Variant};

fn time_one(bench: &str, config: &str, variant: Variant, lat: f64) {
    let t0 = std::time::Instant::now();
    let r = RunRequest::bench(bench)
        .config_name(config)
        .variant(variant)
        .latency_ns(lat)
        .scale(Scale::Test)
        .run()
        .expect(bench);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{bench:>8} {config:>10} {:>6} @{lat:>6}ns: {:>10} cycles in {:>7.3}s = {:>6.2} Mcyc/s",
        variant.tag(),
        r.total_cycles,
        dt,
        r.total_cycles as f64 / dt / 1e6
    );
}

fn main() {
    println!("# simulator throughput microbenchmarks");
    for lat in [100.0, 1000.0, 5000.0] {
        time_one("gups", "baseline", Variant::Sync, lat);
        time_one("gups", "amu", Variant::Amu, lat);
    }
    time_one("stream", "cxl-ideal", Variant::Sync, 1000.0);
    time_one("bfs", "amu", Variant::Amu, 1000.0);
    let _ = SimConfig::baseline();
}
