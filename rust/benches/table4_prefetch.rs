//! Bench harness for paper Table 4: CXL vs best software prefetch vs AMU
//! vs compiler-style AMU for GUPS/HJ/STREAM.
use amu_sim::report;
use amu_sim::session::Session;
fn bench_scale() -> amu_sim::workloads::Scale {
    match std::env::var("AMU_BENCH_SCALE").as_deref() {
        Ok("paper") => amu_sim::workloads::Scale::Paper,
        _ => amu_sim::workloads::Scale::Test,
    }
}
fn main() {
    let t0 = std::time::Instant::now();
    let session = Session::new();
    report::write_report("table4", &report::table4(&session, bench_scale()));
    eprintln!("[bench table4] wall {:?}", t0.elapsed());
}
