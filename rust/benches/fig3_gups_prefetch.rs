//! Bench harness for paper Fig 3: GUPS group-prefetch sensitivity across
//! hardware scaling (cxl-ideal / x2 / x4).
use amu_sim::report;
use amu_sim::session::Session;
fn bench_scale() -> amu_sim::workloads::Scale {
    match std::env::var("AMU_BENCH_SCALE").as_deref() {
        Ok("paper") => amu_sim::workloads::Scale::Paper,
        _ => amu_sim::workloads::Scale::Test,
    }
}
fn main() {
    let t0 = std::time::Instant::now();
    let session = Session::new();
    report::write_report("fig3", &report::fig3(&session, bench_scale(), 1000.0));
    eprintln!("[bench fig3] wall {:?}", t0.elapsed());
}
