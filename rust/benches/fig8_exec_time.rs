//! Bench harness for paper Fig 8: normalized execution time, 11 benchmarks
//! x 4 configs x 6 latencies.
use amu_sim::report;
use amu_sim::session::Session;
fn bench_scale() -> amu_sim::workloads::Scale {
    match std::env::var("AMU_BENCH_SCALE").as_deref() {
        Ok("paper") => amu_sim::workloads::Scale::Paper,
        _ => amu_sim::workloads::Scale::Test,
    }
}
fn main() {
    let t0 = std::time::Instant::now();
    let rows = Session::new().sweep_paper(bench_scale()).expect("sweep");
    report::write_report("fig8", &report::fig8(&rows));
    eprintln!("[bench fig8] wall {:?}", t0.elapsed());
}
