//! Bench harness for paper Fig 2: baseline slowdown vs far-memory latency.
//! Run with AMU_BENCH_SCALE=paper for paper-scale inputs.
use amu_sim::report;
use amu_sim::session::Session;
fn bench_scale() -> amu_sim::workloads::Scale {
    match std::env::var("AMU_BENCH_SCALE").as_deref() {
        Ok("paper") => amu_sim::workloads::Scale::Paper,
        _ => amu_sim::workloads::Scale::Test,
    }
}
fn main() {
    let t0 = std::time::Instant::now();
    let rows = Session::new().sweep_paper(bench_scale()).expect("sweep");
    report::write_report("fig2", &report::fig2(&rows));
    eprintln!("[bench fig2] wall {:?}", t0.elapsed());
}
