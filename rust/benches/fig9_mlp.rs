//! Bench harness for paper Fig 9: average in-flight far requests (MLP).
use amu_sim::report;
use amu_sim::session::Session;
fn bench_scale() -> amu_sim::workloads::Scale {
    match std::env::var("AMU_BENCH_SCALE").as_deref() {
        Ok("paper") => amu_sim::workloads::Scale::Paper,
        _ => amu_sim::workloads::Scale::Test,
    }
}
fn main() {
    let rows = Session::new().sweep_paper(bench_scale()).expect("sweep");
    report::write_report("fig9", &report::fig9(&rows));
}
