//! Bench harness for paper Table 6: AMU hardware resource overhead.
use amu_sim::report;
fn main() {
    report::write_report("table6", &report::table6());
}
