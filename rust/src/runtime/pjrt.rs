//! The real PJRT backend (`--features pjrt`): compiles the AOT HLO text
//! artifacts with XLA and executes them on the CPU client.

use super::{artifacts_dir, GUPS_BATCH, HASH_BATCH, SPMV_NNZ, SPMV_ROWS, SPMV_XLEN, TRIAD_N};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for name in ["gups_update", "gups_step", "stream_triad", "hash_mult", "spmv_ell"] {
            let path = dir.join(format!("{name}.hlo.txt"));
            let text_path = path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(text_path)
                .map_err(|e| anyhow!("parsing {path:?}: {e} (run `make artifacts`?)"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(Self { client, exes })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable '{name}'"))?;
        let out = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        Ok(out.to_tuple1()?)
    }

    /// GUPS payload batch: `new_vals[i] = vals[i] ^ idxs[i]`.
    pub fn gups_update(&self, vals: &[i32], idxs: &[i32]) -> Result<Vec<i32>> {
        check_len("gups_update", vals.len(), GUPS_BATCH)?;
        check_len("gups_update", idxs.len(), GUPS_BATCH)?;
        let out = self.run(
            "gups_update",
            &[xla::Literal::vec1(vals), xla::Literal::vec1(idxs)],
        )?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Fused hash+xor GUPS step.
    pub fn gups_step(&self, vals: &[i32], idxs: &[i32]) -> Result<Vec<i32>> {
        check_len("gups_step", vals.len(), GUPS_BATCH)?;
        let out = self.run(
            "gups_step",
            &[xla::Literal::vec1(vals), xla::Literal::vec1(idxs)],
        )?;
        Ok(out.to_vec::<i32>()?)
    }

    /// STREAM triad with the baked scalar 3.0: `a = b + 3c`.
    pub fn stream_triad(&self, b: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        check_len("stream_triad", b.len(), TRIAD_N)?;
        let out = self.run(
            "stream_triad",
            &[xla::Literal::vec1(b), xla::Literal::vec1(c)],
        )?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Batched multiplicative hash.
    pub fn hash_mult(&self, keys: &[i32]) -> Result<Vec<i32>> {
        check_len("hash_mult", keys.len(), HASH_BATCH)?;
        let out = self.run("hash_mult", &[xla::Literal::vec1(keys)])?;
        Ok(out.to_vec::<i32>()?)
    }

    /// ELL SpMV over the fixed (256 x 32) block with a 2048-long x.
    pub fn spmv_ell(&self, vals: &[f32], cols: &[i32], x: &[f32]) -> Result<Vec<f32>> {
        check_len("spmv vals", vals.len(), SPMV_ROWS * SPMV_NNZ)?;
        check_len("spmv cols", cols.len(), SPMV_ROWS * SPMV_NNZ)?;
        check_len("spmv x", x.len(), SPMV_XLEN)?;
        let v = xla::Literal::vec1(vals).reshape(&[SPMV_ROWS as i64, SPMV_NNZ as i64])?;
        let c = xla::Literal::vec1(cols).reshape(&[SPMV_ROWS as i64, SPMV_NNZ as i64])?;
        let out = self.run("spmv_ell", &[v, c, xla::Literal::vec1(x)])?;
        Ok(out.to_vec::<f32>()?)
    }
}

fn check_len(what: &str, got: usize, want: usize) -> Result<()> {
    if got == want {
        Ok(())
    } else {
        Err(anyhow!("{what}: length {got}, AOT shape requires {want}"))
    }
}
