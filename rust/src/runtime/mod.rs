//! PJRT runtime: loads the AOT-compiled payload-engine artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from Rust. Python never runs on this path.
//!
//! The payload engine computes the *functional* far-memory payload
//! transforms (GUPS update batches, STREAM triad blocks, ELL SpMV, bucket
//! hashing); the simulator models their *timing*. Integration tests
//! cross-check the two against each other, proving the three layers
//! (Pallas kernel -> JAX model -> Rust coordinator) compose.
//!
//! The PJRT backend needs the `xla` and `anyhow` crates plus the XLA C
//! libraries, which the offline build image does not ship. It is therefore
//! gated behind the off-by-default `pjrt` cargo feature: without it, a
//! stub [`Runtime`] with the same API reports the engine as unavailable
//! (callers already handle that — tests skip, drivers print a note). To
//! use the real backend, add the two crates as local dependencies and
//! build with `--features pjrt`.

use std::path::PathBuf;

// Fixed AOT shapes, mirrored from python/compile/model.py.
pub const GUPS_BATCH: usize = 4096;
pub const TRIAD_N: usize = 8192;
pub const HASH_BATCH: usize = 4096;
pub const SPMV_ROWS: usize = 256;
pub const SPMV_NNZ: usize = 32;
pub const SPMV_XLEN: usize = 2048;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Default artifacts location: `$AMU_SIM_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("AMU_SIM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Relative to the crate root so tests and binaries agree.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Host mirror of the kernel hash (for oracle checks without PJRT).
pub fn hash_mult_host(key: u32) -> u32 {
    let mut h = key.wrapping_mul(0x9E37_79B9);
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^ (h >> 13)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_host_mirror_is_stable() {
        // Golden values pin the semantics shared with the Pallas kernel.
        assert_eq!(hash_mult_host(0), 0);
        assert_ne!(hash_mult_host(1), hash_mult_host(2));
    }

    #[test]
    fn artifacts_dir_default_ends_with_artifacts() {
        if std::env::var("AMU_SIM_ARTIFACTS").is_err() {
            assert!(artifacts_dir().ends_with("artifacts"));
        }
    }
}
