//! PJRT runtime: loads the AOT-compiled payload-engine artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from Rust. Python never runs on this path.
//!
//! The payload engine computes the *functional* far-memory payload
//! transforms (GUPS update batches, STREAM triad blocks, ELL SpMV, bucket
//! hashing); the simulator models their *timing*. Integration tests
//! cross-check the two against each other, proving the three layers
//! (Pallas kernel -> JAX model -> Rust coordinator) compose.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

// Fixed AOT shapes, mirrored from python/compile/model.py.
pub const GUPS_BATCH: usize = 4096;
pub const TRIAD_N: usize = 8192;
pub const HASH_BATCH: usize = 4096;
pub const SPMV_ROWS: usize = 256;
pub const SPMV_NNZ: usize = 32;
pub const SPMV_XLEN: usize = 2048;

pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Default artifacts location: `$AMU_SIM_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("AMU_SIM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    // Relative to the crate root so tests and binaries agree.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

impl Runtime {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut exes = HashMap::new();
        for name in ["gups_update", "gups_step", "stream_triad", "hash_mult", "spmv_ell"] {
            let path = dir.join(format!("{name}.hlo.txt"));
            let text_path = path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(text_path)
                .map_err(|e| anyhow!("parsing {path:?}: {e} (run `make artifacts`?)"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(Self { client, exes })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable '{name}'"))?;
        let out = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        Ok(out.to_tuple1()?)
    }

    /// GUPS payload batch: `new_vals[i] = vals[i] ^ idxs[i]`.
    pub fn gups_update(&self, vals: &[i32], idxs: &[i32]) -> Result<Vec<i32>> {
        check_len("gups_update", vals.len(), GUPS_BATCH)?;
        check_len("gups_update", idxs.len(), GUPS_BATCH)?;
        let out = self.run(
            "gups_update",
            &[xla::Literal::vec1(vals), xla::Literal::vec1(idxs)],
        )?;
        Ok(out.to_vec::<i32>()?)
    }

    /// Fused hash+xor GUPS step.
    pub fn gups_step(&self, vals: &[i32], idxs: &[i32]) -> Result<Vec<i32>> {
        check_len("gups_step", vals.len(), GUPS_BATCH)?;
        let out = self.run(
            "gups_step",
            &[xla::Literal::vec1(vals), xla::Literal::vec1(idxs)],
        )?;
        Ok(out.to_vec::<i32>()?)
    }

    /// STREAM triad with the baked scalar 3.0: `a = b + 3c`.
    pub fn stream_triad(&self, b: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        check_len("stream_triad", b.len(), TRIAD_N)?;
        let out = self.run(
            "stream_triad",
            &[xla::Literal::vec1(b), xla::Literal::vec1(c)],
        )?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Batched multiplicative hash.
    pub fn hash_mult(&self, keys: &[i32]) -> Result<Vec<i32>> {
        check_len("hash_mult", keys.len(), HASH_BATCH)?;
        let out = self.run("hash_mult", &[xla::Literal::vec1(keys)])?;
        Ok(out.to_vec::<i32>()?)
    }

    /// ELL SpMV over the fixed (256 x 32) block with a 2048-long x.
    pub fn spmv_ell(&self, vals: &[f32], cols: &[i32], x: &[f32]) -> Result<Vec<f32>> {
        check_len("spmv vals", vals.len(), SPMV_ROWS * SPMV_NNZ)?;
        check_len("spmv cols", cols.len(), SPMV_ROWS * SPMV_NNZ)?;
        check_len("spmv x", x.len(), SPMV_XLEN)?;
        let v = xla::Literal::vec1(vals).reshape(&[SPMV_ROWS as i64, SPMV_NNZ as i64])?;
        let c = xla::Literal::vec1(cols).reshape(&[SPMV_ROWS as i64, SPMV_NNZ as i64])?;
        let out = self.run("spmv_ell", &[v, c, xla::Literal::vec1(x)])?;
        Ok(out.to_vec::<f32>()?)
    }
}

fn check_len(what: &str, got: usize, want: usize) -> Result<()> {
    if got == want {
        Ok(())
    } else {
        Err(anyhow!("{what}: length {got}, AOT shape requires {want}"))
    }
}

/// Host mirror of the kernel hash (for oracle checks without PJRT).
pub fn hash_mult_host(key: u32) -> u32 {
    let mut h = key.wrapping_mul(0x9E37_79B9);
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^ (h >> 13)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_host_mirror_is_stable() {
        // Golden values pin the semantics shared with the Pallas kernel.
        assert_eq!(hash_mult_host(0), 0);
        assert_ne!(hash_mult_host(1), hash_mult_host(2));
    }

    #[test]
    fn artifacts_dir_default_ends_with_artifacts() {
        if std::env::var("AMU_SIM_ARTIFACTS").is_err() {
            assert!(artifacts_dir().ends_with("artifacts"));
        }
    }
}
