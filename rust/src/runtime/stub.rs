//! Payload-engine stub used when the `pjrt` feature is off (the default in
//! the offline build image): same API as the real backend, every entry
//! point reports the engine as unavailable. Callers already degrade
//! gracefully — integration tests skip, drivers print a note.

use std::path::Path;

#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn unavailable<T>() -> Result<T> {
    Err(RuntimeError(
        "PJRT payload engine not compiled in: rebuild with `--features pjrt` \
         (requires the xla and anyhow crates; see the runtime module docs)"
            .into(),
    ))
}

/// Unconstructible stand-in for the PJRT runtime: `load`/`load_default`
/// always return `Err`, so the payload methods are never reachable, but
/// they keep call sites compiling identically under both feature states.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    pub fn load(_dir: &Path) -> Result<Self> {
        unavailable()
    }

    pub fn load_default() -> Result<Self> {
        unavailable()
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn gups_update(&self, _vals: &[i32], _idxs: &[i32]) -> Result<Vec<i32>> {
        unavailable()
    }

    pub fn gups_step(&self, _vals: &[i32], _idxs: &[i32]) -> Result<Vec<i32>> {
        unavailable()
    }

    pub fn stream_triad(&self, _b: &[f32], _c: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }

    pub fn hash_mult(&self, _keys: &[i32]) -> Result<Vec<i32>> {
        unavailable()
    }

    pub fn spmv_ell(&self, _vals: &[f32], _cols: &[i32], _x: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = Runtime::load_default().err().expect("stub must not load");
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
