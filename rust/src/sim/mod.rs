//! Cycle-stepped out-of-order core + whole-system simulator.
//!
//! Models the paper's Table 2 machine: a 6-wide OoO pipeline with ROB,
//! unified issue queue, split load/store queues, physical register file,
//! post-commit store buffer, gshare+BTB branch prediction, and the AMU's
//! ALSU integrated as two extra function units. Synchronous loads/stores
//! traverse the L1D/L2/MSHR hierarchy in `crate::mem`; AMI requests flow
//! through the ASMC in `crate::amu` and bypass the caches entirely.
//!
//! The simulator executes guest programs *functionally at execute/commit
//! time* while modeling timing structurally, and its architectural results
//! are cross-checked against the `isa::interp` oracle in tests.

pub mod bpred;
mod pipeline;

pub use pipeline::{SimResult, Simulator};
