//! Branch prediction: gshare direction predictor + BTB (with last-target
//! indirect prediction for `jalr`).
//!
//! The coroutine scheduler's indirect dispatch (`jr cont_pc`) is highly
//! polymorphic, so indirect mispredictions are a real, measured part of
//! the AMU software overhead — exactly as in the paper's IPC discussion.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Prediction {
    pub taken: bool,
    /// Predicted next pc (instruction index).
    pub target: Option<usize>,
}

pub struct BranchPredictor {
    /// 2-bit saturating counters.
    pht: Vec<u8>,
    history: u64,
    history_mask: u64,
    btb: Vec<BtbEntry>,
    pub lookups: u64,
    pub mispredicts: u64,
}

#[derive(Debug, Clone, Copy, Default)]
struct BtbEntry {
    pc: usize,
    target: usize,
    valid: bool,
}

impl BranchPredictor {
    pub fn new(table_bits: usize, btb_entries: usize) -> Self {
        Self {
            pht: vec![1u8; 1 << table_bits], // weakly not-taken
            history: 0,
            history_mask: (1u64 << table_bits.min(63)) - 1,
            btb: vec![BtbEntry::default(); btb_entries.next_power_of_two()],
            lookups: 0,
            mispredicts: 0,
        }
    }

    #[inline]
    fn pht_index(&self, pc: usize) -> usize {
        (((pc as u64) ^ self.history) & self.history_mask) as usize
    }

    #[inline]
    fn btb_index(&self, pc: usize) -> usize {
        pc & (self.btb.len() - 1)
    }

    /// Predict a conditional branch at `pc` with static target `target`.
    pub fn predict_cond(&mut self, pc: usize, target: usize) -> Prediction {
        self.lookups += 1;
        let taken = self.pht[self.pht_index(pc)] >= 2;
        Prediction { taken, target: if taken { Some(target) } else { None } }
    }

    /// Predict an indirect jump (`jalr`) via the BTB's last-seen target.
    pub fn predict_indirect(&mut self, pc: usize) -> Prediction {
        self.lookups += 1;
        let e = self.btb[self.btb_index(pc)];
        if e.valid && e.pc == pc {
            Prediction { taken: true, target: Some(e.target) }
        } else {
            Prediction { taken: true, target: None } // unknown: frontend stalls
        }
    }

    /// Update on resolution. Returns true if this was a misprediction.
    pub fn update_cond(&mut self, pc: usize, pred: Prediction, taken: bool) -> bool {
        let idx = self.pht_index(pc);
        let c = &mut self.pht[idx];
        if taken {
            *c = (*c + 1).min(3);
        } else {
            *c = c.saturating_sub(1);
        }
        self.history = (self.history << 1) | taken as u64;
        let mis = pred.taken != taken;
        if mis {
            self.mispredicts += 1;
        }
        mis
    }

    pub fn update_indirect(&mut self, pc: usize, pred: Prediction, target: usize) -> bool {
        let idx = self.btb_index(pc);
        self.btb[idx] = BtbEntry { pc, target, valid: true };
        let mis = pred.target != Some(target);
        if mis {
            self.mispredicts += 1;
        }
        mis
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learns_always_taken_loop() {
        // gshare: the rolling history changes the PHT index until it
        // saturates, so convergence takes ~history-length iterations.
        let mut bp = BranchPredictor::new(10, 64);
        let mut warm_mispredicts = 0;
        let mut late_mispredicts = 0;
        for i in 0..200 {
            let p = bp.predict_cond(7, 3);
            if bp.update_cond(7, p, true) {
                if i < 100 {
                    warm_mispredicts += 1;
                } else {
                    late_mispredicts += 1;
                }
            }
        }
        assert!(warm_mispredicts <= 25, "warmup too slow: {warm_mispredicts}");
        assert_eq!(late_mispredicts, 0, "steady state must be perfect");
    }

    #[test]
    fn learns_alternating_with_history() {
        let mut bp = BranchPredictor::new(12, 64);
        let mut late_mispredicts = 0;
        for i in 0..400 {
            let taken = i % 2 == 0;
            let p = bp.predict_cond(9, 2);
            let mis = bp.update_cond(9, p, taken);
            if i > 200 && mis {
                late_mispredicts += 1;
            }
        }
        assert!(late_mispredicts < 20, "history should capture alternation: {late_mispredicts}");
    }

    #[test]
    fn indirect_repeats_last_target() {
        let mut bp = BranchPredictor::new(10, 64);
        let p0 = bp.predict_indirect(5);
        assert_eq!(p0.target, None, "cold BTB");
        bp.update_indirect(5, p0, 42);
        let p1 = bp.predict_indirect(5);
        assert_eq!(p1.target, Some(42));
        assert!(bp.update_indirect(5, p1, 77), "target change mispredicts");
        assert_eq!(bp.predict_indirect(5).target, Some(77));
    }

    #[test]
    fn mispredict_counting() {
        let mut bp = BranchPredictor::new(10, 64);
        let p = bp.predict_cond(1, 9); // predicts not-taken initially
        assert!(!p.taken);
        assert!(bp.update_cond(1, p, true));
        assert_eq!(bp.mispredicts, 1);
    }
}
