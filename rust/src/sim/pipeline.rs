//! The out-of-order pipeline and whole-system `Simulator`.
//!
//! Stage order inside one tick (reverse pipeline order so results are
//! consumed no earlier than the following cycle): memory-system events →
//! ASMC → ALSU batch delivery → writeback → commit → store-buffer/LSQ
//! pumps → issue → rename/dispatch → fetch → per-cycle stats.

use crate::amu::{Alsu, AmiReq, Asmc, BatchKind, BatchTicket, LvrKind};
use crate::config::SimConfig;
use crate::isa::inst::{CfgReg, Inst, Opcode};
use crate::isa::mem::{region_of, GuestMem, MemRegion};
use crate::isa::Program;
use crate::mem::{AccessKind, MemSys, SubmitResult};
use crate::sim::bpred::{BranchPredictor, Prediction};
use crate::stats::{Region, Stats};
use crate::util::Mix64;
use std::collections::VecDeque;

const NO_REG: u32 = u32::MAX;

/// Fast-forward engages only when the jump would skip more than this many
/// cycles: below it, the fixed-point proof (two fingerprints + a stats
/// snapshot) costs more than the ticks it saves.
const FF_MIN_SKIP: u64 = 4;

/// After a failed fixed-point attempt (the machine is actively computing),
/// wait this many cycles before trying again, so busy phases don't pay the
/// fingerprint cost every tick.
const FF_RETRY_BACKOFF: u64 = 6;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum UopKind {
    Alu,
    Mul,
    Branch,
    Jump, // unconditional with static target (no prediction needed)
    IndirectJump,
    Load,
    Store,
    Prefetch,
    Flush,
    AIdAlloc,
    AExec { is_store: bool },
    GetFin,
    CfgWr,
    CfgRd,
    Roi,
    Nop,
    Halt,
}

impl UopKind {
    fn needs_execution(self) -> bool {
        !matches!(self, UopKind::Nop | UopKind::Roi | UopKind::Halt)
    }
}

#[derive(Debug, Clone, Copy)]
struct FetchedUop {
    seq: u64,
    pc: usize,
    inst: Inst,
    kind: UopKind,
    last_of_inst: bool,
    pred: Option<Prediction>,
    ready_at: u64,
}

#[derive(Debug)]
struct RobEntry {
    seq: u64,
    pc: usize,
    inst: Inst,
    kind: UopKind,
    last_of_inst: bool,
    region: u8,
    // Rename state.
    prd: u32,
    old_prd: u32,
    prs: [u32; 3],
    // Progress.
    in_iq: bool,
    executing: bool,
    completed: bool,
    result: u64,
    // Branches.
    pred: Option<Prediction>,
    // Memory.
    lq_idx: bool, // occupies a LQ slot
    sq_idx: bool, // occupies a SQ slot
    // AMI bookkeeping.
    lvr_undo: Option<(LvrKind, u16)>,
    ami_vals: Option<(u64, u64, u64)>, // (id, spm, mem)
    batch_wait: Option<BatchTicket>,
    issued_batch: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LqState {
    WaitAddr,
    WaitIssue,
    Issued,
    Done,
}

#[derive(Debug, Clone, Copy)]
struct LqEntry {
    seq: u64,
    addr: u64,
    size: u8,
    has_addr: bool,
    state: LqState,
    issue_cycle: u64,
}

#[derive(Debug, Clone, Copy)]
struct SqEntry {
    seq: u64,
    addr: u64,
    size: u8,
    value: u64,
    has_addr: bool,
    /// Data operand captured (STA/STD split: addr can be known first).
    has_value: bool,
}

#[derive(Debug, Clone, Copy)]
struct SbEntry {
    addr: u64,
    issued: bool,
    done: bool,
}

#[derive(Debug, Clone, Copy)]
enum TokenTarget {
    Load(u64),  // seq
    StoreBuf(u64), // sb id
}

pub struct SimResult {
    pub cycles: u64,
    pub committed_insts: u64,
}

/// Whole-system simulator: one OoO core + memory system (+ AMU).
pub struct Simulator {
    pub cfg: SimConfig,
    pub prog: Program,
    pub guest: GuestMem,
    pub memsys: MemSys,
    pub asmc: Asmc,
    alsu: Alsu,
    bp: BranchPredictor,
    pub stats: Stats,

    // Clock / termination.
    pub cycle: u64,
    pub done: bool,

    // Frontend.
    pc: usize,
    next_seq: u64,
    fetch_halted: bool,
    fetch_blocked_on: Option<u64>,
    fetch_q: VecDeque<FetchedUop>,

    // Rename.
    map: [u32; 64],
    prf_val: Vec<u64>,
    prf_ready: Vec<bool>,
    prf_free: Vec<u32>,

    // Backend.
    rob: VecDeque<RobEntry>,
    iq: Vec<u64>,
    lq: Vec<LqEntry>,
    sq: Vec<SqEntry>,
    sb: VecDeque<(u64, SbEntry)>, // (sb id, entry)
    next_sb_id: u64,
    writeback: Vec<(u64, u64)>, // (when, seq)
    /// Stores whose address executed but whose data operand is still being
    /// produced (split STA/STD semantics: the address must not wait for the
    /// data, or independent younger loads serialize behind it).
    std_wait: Vec<u64>,

    // Memory tokens.
    tokens: Vec<Option<TokenTarget>>,
    token_free: Vec<u32>,

    // Measurement window.
    in_roi: bool,
    last_far_inflight: u64,
    /// Set when the architectural state diverges in an unrecoverable way.
    pub error: Option<String>,

    // Event-driven fast-forward (see `tick_fast`).
    fast_forward: bool,
    /// Earliest cycle at which to attempt the next fixed-point proof
    /// (backoff after a failed attempt).
    ff_next_try: u64,
    /// Host-side observability: cycles skipped by fast-forward jumps.
    /// Deliberately NOT part of `Stats` — simulated statistics must be
    /// identical with fast-forward on or off.
    pub ff_jumped_cycles: u64,
    /// `AMU_SIM_TRACE` presence, read once at construction instead of per
    /// 10k-cycle window in the hot loop.
    trace: bool,

    // Reused tick-path scratch buffers (no per-cycle allocations).
    scratch_iq: Vec<u64>,
    scratch_wb: Vec<u64>,
    scratch_std: Vec<u64>,
    scratch_alsu: Vec<u64>,
    scratch_comp: Vec<crate::mem::Completion>,
}

impl Simulator {
    pub fn new(cfg: SimConfig, prog: Program) -> Self {
        cfg.validate().expect("invalid config");
        let n_prf = cfg.core.phys_regs.max(80);
        let mut map = [0u32; 64];
        for (i, m) in map.iter_mut().enumerate() {
            *m = i as u32;
        }
        let prf_free: Vec<u32> = (64..n_prf as u32).rev().collect();
        let memsys = MemSys::new(&cfg);
        let asmc = Asmc::new(&cfg.amu);
        let alsu = Alsu::new(cfg.amu.lvr_capacity, cfg.amu.dma_mode);
        let bp = BranchPredictor::new(cfg.core.bp_table_bits, cfg.core.btb_entries);
        Self {
            prog,
            guest: GuestMem::new(),
            memsys,
            asmc,
            alsu,
            bp,
            stats: Stats::default(),
            cycle: 0,
            done: false,
            pc: 0,
            next_seq: 0,
            fetch_halted: false,
            fetch_blocked_on: None,
            fetch_q: VecDeque::new(),
            map,
            prf_val: vec![0; n_prf],
            prf_ready: vec![true; n_prf],
            prf_free,
            rob: VecDeque::new(),
            iq: Vec::new(),
            lq: Vec::new(),
            sq: Vec::new(),
            sb: VecDeque::new(),
            next_sb_id: 0,
            writeback: Vec::new(),
            std_wait: Vec::new(),
            tokens: Vec::new(),
            token_free: Vec::new(),
            in_roi: false,
            last_far_inflight: 0,
            error: None,
            fast_forward: cfg.fast_forward,
            ff_next_try: 0,
            ff_jumped_cycles: 0,
            trace: std::env::var("AMU_SIM_TRACE").is_ok(),
            scratch_iq: Vec::new(),
            scratch_wb: Vec::new(),
            scratch_std: Vec::new(),
            scratch_alsu: Vec::new(),
            scratch_comp: Vec::new(),
            cfg,
        }
    }

    // ---------------- token helpers ----------------

    fn token_alloc(&mut self, target: TokenTarget) -> u32 {
        if let Some(t) = self.token_free.pop() {
            self.tokens[t as usize] = Some(target);
            t
        } else {
            self.tokens.push(Some(target));
            (self.tokens.len() - 1) as u32
        }
    }

    fn token_take(&mut self, t: u32) -> Option<TokenTarget> {
        let out = self.tokens[t as usize].take();
        if out.is_some() {
            self.token_free.push(t);
        }
        out
    }

    fn token_cancel(&mut self, t: u32) {
        // Completion will arrive later and be dropped.
        self.tokens[t as usize] = None;
        self.token_free.push(t);
    }

    // ---------------- ROB helpers ----------------

    #[inline]
    fn rob_idx(&self, seq: u64) -> Option<usize> {
        let head = self.rob.front()?.seq;
        if seq < head {
            return None;
        }
        let idx = (seq - head) as usize;
        if idx < self.rob.len() {
            debug_assert_eq!(self.rob[idx].seq, seq);
            Some(idx)
        } else {
            None
        }
    }

    fn all_older_completed(&self, seq: u64) -> bool {
        for e in self.rob.iter() {
            if e.seq >= seq {
                return true;
            }
            if !e.completed {
                return false;
            }
        }
        true
    }

    // ---------------- decode / µop expansion ----------------

    fn uop_kind(inst: &Inst) -> UopKind {
        use Opcode::*;
        match inst.op {
            Add | Sub | Xor | And | Or | Sll | Srl | SltU | Addi | Xori | Andi | Ori
            | Slli | Srli | Li => UopKind::Alu,
            Mul => UopKind::Mul,
            Beq | Bne | Blt | Bge | BltU => UopKind::Branch,
            Jal => UopKind::Jump,
            Jalr => UopKind::IndirectJump,
            Ld => UopKind::Load,
            St => UopKind::Store,
            Prefetch => UopKind::Prefetch,
            Flush => UopKind::Flush,
            GetFin => UopKind::GetFin,
            CfgWr => UopKind::CfgWr,
            CfgRd => UopKind::CfgRd,
            Nop => UopKind::Nop,
            Halt => UopKind::Halt,
            Roi => UopKind::Roi,
            ALoad | AStore => unreachable!("expanded at fetch"),
        }
    }

    // ---------------- fetch ----------------

    fn fetch(&mut self) {
        if self.fetch_halted || self.done {
            return;
        }
        if self.fetch_blocked_on.is_some() {
            return;
        }
        let width = self.cfg.core.fetch_width;
        let depth = self.cfg.core.frontend_depth as u64;
        let qcap = width * (self.cfg.core.frontend_depth + 3);
        let mut fetched_insts = 0;
        while fetched_insts < width && self.fetch_q.len() + 2 <= qcap {
            if self.pc >= self.prog.insts.len() {
                self.fetch_halted = true;
                break;
            }
            let inst = self.prog.insts[self.pc];
            let pc = self.pc;
            let ready_at = self.cycle + depth;
            let push = |s: &mut Self, kind, last, pred| {
                let seq = s.next_seq;
                s.next_seq += 1;
                s.fetch_q.push_back(FetchedUop {
                    seq,
                    pc,
                    inst,
                    kind,
                    last_of_inst: last,
                    pred,
                    ready_at,
                });
                s.stats.fetched_uops += 1;
                seq
            };
            match inst.op {
                Opcode::ALoad | Opcode::AStore => {
                    let is_store = inst.op == Opcode::AStore;
                    push(self, UopKind::AIdAlloc, false, None);
                    push(self, UopKind::AExec { is_store }, true, None);
                    self.pc += 1;
                }
                Opcode::Beq | Opcode::Bne | Opcode::Blt | Opcode::Bge | Opcode::BltU => {
                    let pred = self.bp.predict_cond(pc, inst.imm as usize);
                    let taken = pred.taken;
                    push(self, UopKind::Branch, true, Some(pred));
                    self.stats.branches += 1;
                    if taken {
                        self.pc = inst.imm as usize;
                        break; // end fetch group at a predicted-taken branch
                    } else {
                        self.pc += 1;
                    }
                }
                Opcode::Jal => {
                    push(self, UopKind::Jump, true, None);
                    self.pc = inst.imm as usize;
                    break;
                }
                Opcode::Jalr => {
                    let pred = self.bp.predict_indirect(pc);
                    let seq = push(self, UopKind::IndirectJump, true, Some(pred));
                    self.stats.branches += 1;
                    match pred.target {
                        Some(t) => {
                            self.pc = t;
                            break;
                        }
                        None => {
                            // Unknown target: frontend stalls until resolve.
                            self.fetch_blocked_on = Some(seq);
                            return;
                        }
                    }
                }
                Opcode::Halt => {
                    push(self, UopKind::Halt, true, None);
                    self.fetch_halted = true;
                    return;
                }
                _ => {
                    let kind = Self::uop_kind(&inst);
                    push(self, kind, true, None);
                    self.pc += 1;
                }
            }
            fetched_insts += 1;
        }
    }

    // ---------------- rename / dispatch ----------------

    fn dispatch(&mut self) {
        for _ in 0..self.cfg.core.decode_width {
            let Some(fu) = self.fetch_q.front() else { break };
            if fu.ready_at > self.cycle {
                break;
            }
            if self.rob.len() >= self.cfg.core.rob_entries {
                break;
            }
            let kind = fu.kind;
            if kind.needs_execution() && self.iq.len() >= self.cfg.core.iq_entries {
                break;
            }
            let needs_lq = kind == UopKind::Load;
            let needs_sq = kind == UopKind::Store;
            if needs_lq && self.lq.len() >= self.cfg.core.lq_entries {
                break;
            }
            if needs_sq && self.sq.len() >= self.cfg.core.sq_entries {
                break;
            }
            let inst = fu.inst;
            let writes_rd = match kind {
                UopKind::AIdAlloc | UopKind::GetFin | UopKind::CfgRd => inst.rd != 0,
                UopKind::AExec { .. } => false,
                _ => inst.writes_rd(),
            };
            if writes_rd && self.prf_free.is_empty() {
                break;
            }
            let fu = self.fetch_q.pop_front().unwrap();

            // Source mapping.
            let mut prs = [NO_REG; 3];
            match kind {
                UopKind::AExec { .. } => {
                    prs[0] = self.map[inst.rs1 as usize];
                    prs[1] = self.map[inst.rs2 as usize];
                    prs[2] = self.map[inst.rd as usize]; // the allocated ID
                }
                UopKind::AIdAlloc | UopKind::GetFin | UopKind::CfgRd => {}
                _ => {
                    let (s1, s2) = inst.sources();
                    if let Some(r) = s1 {
                        prs[0] = self.map[r as usize];
                    }
                    if let Some(r) = s2 {
                        prs[1] = self.map[r as usize];
                    }
                }
            }

            // Destination rename.
            let (prd, old_prd) = if writes_rd {
                let p = self.prf_free.pop().unwrap();
                let old = self.map[inst.rd as usize];
                self.map[inst.rd as usize] = p;
                self.prf_ready[p as usize] = false;
                self.stats.regfile_writes += 1;
                (p, old)
            } else {
                (NO_REG, NO_REG)
            };

            let completed = !kind.needs_execution();
            let entry = RobEntry {
                seq: fu.seq,
                pc: fu.pc,
                inst,
                kind,
                last_of_inst: fu.last_of_inst,
                region: inst.region,
                prd,
                old_prd,
                prs,
                in_iq: !completed,
                executing: false,
                completed,
                result: 0,
                pred: fu.pred,
                lq_idx: needs_lq,
                sq_idx: needs_sq,
                lvr_undo: None,
                ami_vals: None,
                batch_wait: None,
                issued_batch: false,
            };
            if needs_lq {
                self.lq.push(LqEntry {
                    seq: fu.seq,
                    addr: 0,
                    size: inst.size,
                    has_addr: false,
                    state: LqState::WaitAddr,
                    issue_cycle: 0,
                });
            }
            if needs_sq {
                self.sq.push(SqEntry {
                    seq: fu.seq,
                    addr: 0,
                    size: inst.size,
                    value: 0,
                    has_addr: false,
                    has_value: false,
                });
            }
            if !completed {
                self.iq.push(fu.seq);
                self.stats.iq_writes += 1;
            }
            self.stats.rob_writes += 1;
            self.rob.push_back(entry);
        }
    }

    // ---------------- issue / execute ----------------

    fn src_ready(&self, prs: &[u32; 3]) -> bool {
        prs.iter().all(|&p| p == NO_REG || self.prf_ready[p as usize])
    }

    fn src_val(&self, p: u32) -> u64 {
        if p == NO_REG {
            0
        } else {
            self.prf_val[p as usize]
        }
    }

    fn issue(&mut self) {
        let mut alu_left = self.cfg.core.alu_units;
        let mut mul_left = self.cfg.core.mul_units;
        let mut agu_left = self.cfg.core.mem_ports;
        let mut id_unit_left = 1usize; // ALSU ID-management unit
        let mut req_unit_left = 1usize; // ALSU request-generation unit
        let mut issued = 0usize;
        let width = self.cfg.core.issue_width;

        let mut iq_snapshot = std::mem::take(&mut self.scratch_iq);
        iq_snapshot.clear();
        iq_snapshot.extend_from_slice(&self.iq);
        for &seq in iq_snapshot.iter() {
            if issued >= width {
                break;
            }
            let Some(idx) = self.rob_idx(seq) else { continue };
            let (kind, prs, pc, inst) = {
                let e = &self.rob[idx];
                if e.executing || e.completed || !e.in_iq {
                    continue;
                }
                (e.kind, e.prs, e.pc, e.inst)
            };
            let ready = if kind == UopKind::Store {
                // STA/STD split: issue address generation as soon as the
                // base register is ready; the data is captured later.
                prs[0] == NO_REG || self.prf_ready[prs[0] as usize]
            } else {
                self.src_ready(&prs)
            };
            if !ready {
                continue;
            }
            // Structural hazards per kind.
            let unit_ok = match kind {
                UopKind::Alu | UopKind::Branch | UopKind::Jump | UopKind::IndirectJump
                | UopKind::Flush => alu_left > 0,
                UopKind::Mul => mul_left > 0,
                UopKind::Load | UopKind::Store | UopKind::Prefetch => agu_left > 0,
                UopKind::AIdAlloc | UopKind::GetFin => id_unit_left > 0,
                UopKind::AExec { .. } | UopKind::CfgWr | UopKind::CfgRd => req_unit_left > 0,
                _ => true,
            };
            if !unit_ok {
                continue;
            }
            // DMA-mode: ID micro-ops are non-speculative — oldest-only.
            if self.alsu.dma_mode
                && matches!(kind, UopKind::AIdAlloc | UopKind::GetFin)
                && !self.all_older_completed(seq)
            {
                continue;
            }
            let v1 = self.src_val(prs[0]);
            let v2 = self.src_val(prs[1]);
            let v3 = self.src_val(prs[2]);
            self.stats.regfile_reads +=
                prs.iter().filter(|&&p| p != NO_REG).count() as u64;

            let now = self.cycle;
            let mut complete_at = now + 1;
            let mut result = 0u64;
            let mut keep_in_iq = false;

            match kind {
                UopKind::Alu | UopKind::Jump => {
                    alu_left -= 1;
                    result = Self::alu_result(&inst, v1, v2, pc);
                }
                UopKind::Mul => {
                    mul_left -= 1;
                    result = v1.wrapping_mul(v2);
                    complete_at = now + self.cfg.core.mul_latency;
                }
                UopKind::Branch | UopKind::IndirectJump => {
                    alu_left -= 1;
                    result = (pc + 1) as u64; // link value for jalr
                }
                UopKind::Load => {
                    agu_left -= 1;
                    let addr = v1.wrapping_add(inst.imm as u64);
                    if let Some(l) = self.lq.iter_mut().find(|l| l.seq == seq) {
                        l.addr = addr;
                        l.has_addr = true;
                        l.state = LqState::WaitIssue;
                    }
                    // Execution continues in the LQ pump; µop completes when
                    // data arrives.
                    let e = &mut self.rob[idx];
                    e.in_iq = false;
                    e.executing = true;
                    self.iq.retain(|&s| s != seq);
                    issued += 1;
                    continue;
                }
                UopKind::Store => {
                    agu_left -= 1;
                    let addr = v1.wrapping_add(inst.imm as u64);
                    let data_ready =
                        prs[1] == NO_REG || self.prf_ready[prs[1] as usize];
                    if let Some(s) = self.sq.iter_mut().find(|s| s.seq == seq) {
                        s.addr = addr;
                        s.has_addr = true;
                        if data_ready {
                            s.value =
                                if prs[1] == NO_REG { 0 } else { self.prf_val[prs[1] as usize] };
                            s.has_value = true;
                        }
                    }
                    self.stats.lsq_searches += 1;
                    if !data_ready {
                        // STD pending: complete when the data register
                        // becomes ready (see `std_pump`).
                        let e = &mut self.rob[idx];
                        e.in_iq = false;
                        e.executing = true;
                        self.iq.retain(|&s| s != seq);
                        self.std_wait.push(seq);
                        issued += 1;
                        continue;
                    }
                }
                UopKind::Prefetch => {
                    agu_left -= 1;
                    let addr = v1.wrapping_add(inst.imm as u64);
                    if region_of(addr) != MemRegion::Spm {
                        let t = self.token_alloc(TokenTarget::Load(u64::MAX));
                        let r = self.memsys.submit(
                            AccessKind::Prefetch,
                            addr,
                            t,
                            now,
                            self.cfg.l1d.hit_latency,
                        );
                        match r {
                            SubmitResult::Accepted => self.stats.prefetches_issued += 1,
                            _ => self.token_cancel(t), // best effort: drop
                        }
                    }
                }
                UopKind::Flush => {
                    alu_left -= 1;
                    let addr = v1.wrapping_add(inst.imm as u64);
                    self.rob[idx].ami_vals = Some((0, addr, 0));
                    complete_at = now + self.cfg.l1d.hit_latency;
                }
                UopKind::AExec { .. } => {
                    req_unit_left -= 1;
                    // (id, spm, mem) captured for the commit-time handoff.
                    self.rob[idx].ami_vals = Some((v3, v1, v2));
                }
                UopKind::CfgWr => {
                    req_unit_left -= 1;
                    self.rob[idx].ami_vals = Some((v1, 0, 0));
                }
                UopKind::CfgRd => {
                    req_unit_left -= 1;
                    // Invalid cfg indices read as zero in the timing model;
                    // the verifier (AMI006) refuses such programs up front.
                    result = match CfgReg::from_imm(inst.imm) {
                        Some(CfgReg::Granularity) => self.asmc.granularity,
                        Some(CfgReg::QueueBase) | None => 0,
                        Some(CfgReg::QueueLength) => self.asmc.queue_length as u64,
                    };
                }
                UopKind::AIdAlloc => {
                    id_unit_left -= 1;
                    match self.try_id_uop(idx, LvrKind::Free, now) {
                        IdUopOutcome::Got(id) => result = id as u64,
                        IdUopOutcome::Wait => {} // waiting on batch delivery
                        IdUopOutcome::Retry => keep_in_iq = true, // busy: retry
                    }
                }
                UopKind::GetFin => {
                    id_unit_left -= 1;
                    self.stats.getfins += 1;
                    match self.try_id_uop(idx, LvrKind::Finished, now) {
                        IdUopOutcome::Got(id) => result = id as u64,
                        IdUopOutcome::Wait => {}
                        IdUopOutcome::Retry => keep_in_iq = true,
                    }
                }
                UopKind::Nop | UopKind::Roi | UopKind::Halt => unreachable!(),
            }

            let e = &mut self.rob[idx];
            if keep_in_iq {
                // Structural retry next cycle (stay in IQ).
                continue;
            }
            e.in_iq = false;
            self.iq.retain(|&s| s != seq);
            issued += 1;
            if e.batch_wait.is_some() {
                e.executing = true; // completes on batch delivery
                continue;
            }
            e.executing = true;
            e.result = result;
            self.writeback.push((complete_at, seq));
            self.stats.iq_wakeups += 1;
        }
        self.scratch_iq = iq_snapshot;
    }

    fn alu_result(inst: &Inst, v1: u64, v2: u64, pc: usize) -> u64 {
        use Opcode::*;
        match inst.op {
            Add => v1.wrapping_add(v2),
            Sub => v1.wrapping_sub(v2),
            Xor => v1 ^ v2,
            And => v1 & v2,
            Or => v1 | v2,
            Sll => v1.wrapping_shl(v2 as u32 & 63),
            Srl => v1.wrapping_shr(v2 as u32 & 63),
            SltU => (v1 < v2) as u64,
            Addi => v1.wrapping_add(inst.imm as u64),
            Xori => v1 ^ inst.imm as u64,
            Andi => v1 & inst.imm as u64,
            Ori => v1 | inst.imm as u64,
            Slli => v1.wrapping_shl(inst.imm as u32 & 63),
            Srli => v1.wrapping_shr(inst.imm as u32 & 63),
            Li => inst.imm as u64,
            Jal => (pc + 1) as u64,
            _ => 0,
        }
    }

    fn try_id_uop(&mut self, rob_idx: usize, kind: LvrKind, now: u64) -> IdUopOutcome {
        if let Some(id) = self.alsu.pop(kind) {
            self.rob[rob_idx].lvr_undo = Some((kind, id));
            return IdUopOutcome::Got(id);
        }
        if self.alsu.batch_busy {
            return IdUopOutcome::Retry;
        }
        // Initiate a batch fetch (the uncommitted-ID-register slot).
        let extra = if self.alsu.dma_mode {
            self.cfg.amu.dma_uncore_cycles
        } else {
            0
        };
        let bk = match kind {
            LvrKind::Free => BatchKind::Free,
            LvrKind::Finished => BatchKind::Finished,
        };
        let ticket = self.asmc.request_batch(bk, self.alsu.cap, now, extra);
        self.alsu.batch_busy = true;
        let e = &mut self.rob[rob_idx];
        e.batch_wait = Some(ticket);
        e.issued_batch = true;
        IdUopOutcome::Wait
    }

    /// Poll in-flight ALSU batch deliveries and complete waiting µops.
    fn alsu_poll(&mut self) {
        let now = self.cycle;
        // At most one batch outstanding (batch_busy contract).
        let mut waiting = std::mem::take(&mut self.scratch_alsu);
        waiting.clear();
        waiting.extend(self.rob.iter().filter(|e| e.batch_wait.is_some()).map(|e| e.seq));
        for &seq in waiting.iter() {
            let Some(idx) = self.rob_idx(seq) else { continue };
            let ticket = self.rob[idx].batch_wait.unwrap();
            if let Some(ids) = self.asmc.poll_batch(ticket, now) {
                let kind = match self.rob[idx].kind {
                    UopKind::AIdAlloc => LvrKind::Free,
                    UopKind::GetFin => LvrKind::Finished,
                    _ => unreachable!(),
                };
                self.alsu.refill(kind, &ids);
                self.alsu.batch_busy = false;
                let result = match self.alsu.pop(kind) {
                    Some(id) => {
                        self.rob[idx].lvr_undo = Some((kind, id));
                        id as u64
                    }
                    None => {
                        if kind == LvrKind::Finished {
                            self.stats.getfin_misses += 1;
                        }
                        0
                    }
                };
                let e = &mut self.rob[idx];
                e.batch_wait = None;
                e.result = result;
                self.writeback.push((now + 1, seq));
            }
        }
        self.scratch_alsu = waiting;
        // If the batch initiator was squashed, the delivery still clears the
        // busy flag (uncommitted-ID-register recovery): handled in squash by
        // keeping a phantom entry? Simpler: orphaned tickets are drained
        // here.
        if self.alsu.batch_busy && !self.rob.iter().any(|e| e.batch_wait.is_some()) {
            // The waiting µop was squashed; poll its ticket via the ASMC by
            // scanning — tickets are monotonically assigned, so we ask the
            // ASMC for any deliverable batch addressed to us.
            if let Some(ids) = self.asmc.poll_any_batch(now) {
                // IDs land in the free LVR (they are free IDs by
                // construction of the squash path — finished-batch IDs are
                // finished; route by the batch's kind).
                self.alsu.refill(ids.1, &ids.0);
                self.alsu.batch_busy = false;
            }
        }
    }

    // ---------------- LSQ pumps ----------------

    fn min_unknown_store_seq(&self) -> u64 {
        self.sq
            .iter()
            .filter(|s| !s.has_addr)
            .map(|s| s.seq)
            .min()
            .unwrap_or(u64::MAX)
    }

    /// STD pump: stores whose address executed earlier capture their data
    /// operand as soon as it is produced, then complete.
    fn std_pump(&mut self) {
        let now = self.cycle;
        let mut done = std::mem::take(&mut self.scratch_std);
        done.clear();
        let mut i = 0;
        while i < self.std_wait.len() {
            let seq = self.std_wait[i];
            let Some(idx) = self.rob_idx(seq) else {
                self.std_wait.swap_remove(i);
                continue;
            };
            let prs1 = self.rob[idx].prs[1];
            if prs1 == NO_REG || self.prf_ready[prs1 as usize] {
                let v = if prs1 == NO_REG { 0 } else { self.prf_val[prs1 as usize] };
                if let Some(sq) = self.sq.iter_mut().find(|s| s.seq == seq) {
                    sq.value = v;
                    sq.has_value = true;
                }
                done.push(seq);
                self.std_wait.swap_remove(i);
            } else {
                i += 1;
            }
        }
        for &seq in done.iter() {
            self.writeback.push((now + 1, seq));
        }
        self.scratch_std = done;
    }

    fn lq_pump(&mut self) {
        let now = self.cycle;
        let unknown_min = self.min_unknown_store_seq();
        let mut issued = 0usize;
        let max_issue = self.cfg.core.mem_ports;
        let lq_len = self.lq.len();
        for i in 0..lq_len {
            if issued >= max_issue {
                break;
            }
            let l = self.lq[i];
            if l.state != LqState::WaitIssue {
                continue;
            }
            // Conservative ordering: all older stores must have addresses.
            if unknown_min < l.seq {
                continue;
            }
            self.stats.lsq_searches += 1;
            // Overlap check against older stores.
            let mut forward: Option<u64> = None;
            let mut must_wait = false;
            for s in self.sq.iter() {
                if s.seq >= l.seq || !s.has_addr {
                    continue;
                }
                let (la, lz) = (l.addr, l.addr + l.size as u64);
                let (sa, sz) = (s.addr, s.addr + s.size as u64);
                if la < sz && sa < lz {
                    if sa == la && s.size == l.size && s.has_value {
                        forward = Some(s.value); // youngest older wins
                    } else {
                        // Partial overlap, or the store's data is not yet
                        // captured: wait.
                        must_wait = true;
                        forward = None;
                    }
                }
            }
            if must_wait {
                continue;
            }
            let Some(idx) = self.rob_idx(l.seq) else { continue };
            if let Some(v) = forward {
                let e = &mut self.rob[idx];
                e.result = v;
                self.lq[i].state = LqState::Done;
                self.writeback.push((now + 1, l.seq));
                issued += 1;
                continue;
            }
            match region_of(l.addr) {
                MemRegion::Spm => {
                    self.stats.spm_accesses += 1;
                    self.lq[i].state = LqState::Issued;
                    self.lq[i].issue_cycle = now;
                    // Value read at completion.
                    self.writeback.push((now + self.cfg.amu.spm_latency, l.seq));
                    issued += 1;
                }
                _ => {
                    let t = self.token_alloc(TokenTarget::Load(l.seq));
                    match self.memsys.submit(
                        AccessKind::Load,
                        l.addr,
                        t,
                        now,
                        self.cfg.l1d.hit_latency,
                    ) {
                        SubmitResult::Accepted => {
                            self.stats.l1d_accesses += 1;
                            self.lq[i].state = LqState::Issued;
                            self.lq[i].issue_cycle = now;
                            issued += 1;
                        }
                        _ => {
                            self.token_cancel(t);
                            // Retry next cycle; MSHR/port pressure.
                        }
                    }
                }
            }
        }
    }

    fn sb_pump(&mut self) {
        let now = self.cycle;
        // Issue the oldest unissued store-buffer entry (one per cycle).
        let next = self
            .sb
            .iter()
            .find(|(_, e)| !e.issued)
            .map(|(id, e)| (*id, e.addr));
        if let Some((id, addr)) = next {
            if region_of(addr) == MemRegion::Spm {
                // Fixed-latency SPM write: no cache, no MSHR.
                self.stats.spm_accesses += 1;
                if let Some((_, e)) = self.sb.iter_mut().find(|(i, _)| *i == id) {
                    e.issued = true;
                    e.done = true;
                }
            } else {
                let t = self.token_alloc(TokenTarget::StoreBuf(id));
                match self
                    .memsys
                    .submit(AccessKind::Store, addr, t, now, self.cfg.l1d.hit_latency)
                {
                    SubmitResult::Accepted => {
                        self.stats.l1d_accesses += 1;
                        if let Some((_, e)) = self.sb.iter_mut().find(|(i, _)| *i == id) {
                            e.issued = true;
                        }
                    }
                    _ => self.token_cancel(t),
                }
            }
        }
        // Retire finished entries from the front.
        while matches!(self.sb.front(), Some((_, e)) if e.done) {
            self.sb.pop_front();
        }
    }

    // ---------------- writeback ----------------

    fn writeback_stage(&mut self) {
        let now = self.cycle;
        let mut due = std::mem::take(&mut self.scratch_wb);
        due.clear();
        self.writeback.retain(|&(when, seq)| {
            if when <= now {
                due.push(seq);
                false
            } else {
                true
            }
        });
        for &seq in due.iter() {
            let Some(idx) = self.rob_idx(seq) else { continue };
            // A load completing from memory/SPM reads its value now (the
            // architectural state reflects exactly the stores that committed
            // before it, which the LSQ ordering rules guarantee are the ones
            // it must observe). Forwarded loads (state Done) already carry
            // their value from the store queue.
            if self.rob[idx].kind == UopKind::Load {
                let info = self
                    .lq
                    .iter()
                    .find(|l| l.seq == seq)
                    .map(|l| (l.addr, l.size, l.state));
                let Some((addr, size, state)) = info else { continue };
                if state == LqState::Issued {
                    let v = self.guest.read(addr, size);
                    self.rob[idx].result = v;
                }
                if let Some(l) = self.lq.iter_mut().find(|l| l.seq == seq) {
                    l.state = LqState::Done;
                }
            }
            let (kind, inst, pc, pred, prs) = {
                let e = &mut self.rob[idx];
                e.completed = true;
                e.executing = false;
                if e.prd != NO_REG {
                    self.prf_val[e.prd as usize] = e.result;
                    self.prf_ready[e.prd as usize] = true;
                }
                (e.kind, e.inst, e.pc, e.pred, e.prs)
            };
            // Branch resolution.
            match kind {
                UopKind::Branch => {
                    let taken = Self::branch_taken(
                        &inst,
                        self.prf_val[prs[0] as usize],
                        self.prf_val[prs[1] as usize],
                    );
                    let pred = pred.unwrap();
                    let target = if taken { inst.imm as usize } else { pc + 1 };
                    let mis = self.bp.update_cond(pc, pred, taken);
                    if mis {
                        self.stats.branch_mispredicts += 1;
                        self.squash(seq, target);
                    }
                }
                UopKind::IndirectJump => {
                    let target = self.prf_val[prs[0] as usize] as usize;
                    let pred = pred.unwrap();
                    self.bp.update_indirect(pc, pred, target);
                    if self.fetch_blocked_on == Some(seq) {
                        // Frontend stalled on this jalr: redirect, no squash.
                        self.fetch_blocked_on = None;
                        self.pc = target;
                    } else if pred.target != Some(target) {
                        self.stats.branch_mispredicts += 1;
                        self.squash(seq, target);
                    }
                }
                _ => {}
            }
        }
        self.scratch_wb = due;
    }

    fn branch_taken(inst: &Inst, v1: u64, v2: u64) -> bool {
        match inst.op {
            Opcode::Beq => v1 == v2,
            Opcode::Bne => v1 != v2,
            Opcode::Blt => (v1 as i64) < (v2 as i64),
            Opcode::Bge => (v1 as i64) >= (v2 as i64),
            Opcode::BltU => v1 < v2,
            _ => unreachable!(),
        }
    }

    // ---------------- squash ----------------

    fn squash(&mut self, after_seq: u64, new_pc: usize) {
        // Drop younger frontend µops wholesale.
        self.fetch_q.clear();
        self.fetch_halted = false;
        if let Some(b) = self.fetch_blocked_on {
            if b > after_seq {
                self.fetch_blocked_on = None;
            }
        }
        self.pc = new_pc;
        // Walk ROB tail -> after_seq, undoing state.
        while let Some(e) = self.rob.back() {
            if e.seq <= after_seq {
                break;
            }
            let e = self.rob.pop_back().unwrap();
            self.stats.squashed_uops += 1;
            if e.prd != NO_REG {
                self.map[e.inst.rd as usize] = e.old_prd;
                self.prf_free.push(e.prd);
            }
            if let Some((kind, id)) = e.lvr_undo {
                self.alsu.unpop(kind, id);
            }
            if e.issued_batch && e.batch_wait.is_some() {
                // Batch still in flight; delivery is captured by
                // `alsu_poll`'s orphan path and the busy flag stays set
                // until it lands.
            }
            if e.lq_idx {
                // Cancel any in-flight memory token for this load.
                let seq = e.seq;
                for t in 0..self.tokens.len() {
                    if matches!(self.tokens[t], Some(TokenTarget::Load(s)) if s == seq) {
                        self.token_cancel(t as u32);
                    }
                }
                self.lq.retain(|l| l.seq != seq);
            }
            if e.sq_idx {
                self.sq.retain(|s| s.seq != e.seq);
            }
        }
        self.iq.retain(|&s| s <= after_seq);
        self.writeback.retain(|&(_, s)| s <= after_seq);
        self.std_wait.retain(|&s| s <= after_seq);
        self.next_seq = after_seq + 1;
    }

    // ---------------- commit ----------------

    fn commit(&mut self) {
        for _ in 0..self.cfg.core.commit_width {
            let Some(head) = self.rob.front() else { break };
            if !head.completed {
                break;
            }
            let kind = head.kind;
            // Structural commit gates.
            match kind {
                UopKind::Store => {
                    if self.sb.len() >= self.cfg.core.store_buffer {
                        break;
                    }
                }
                UopKind::AExec { .. } => {
                    let id = head.ami_vals.map(|v| v.0).unwrap_or(0);
                    if id != 0 && !self.asmc.queue_has_space() {
                        break; // ASMC pending queue full: backpressure
                    }
                }
                UopKind::Halt => {
                    self.done = true;
                    return;
                }
                _ => {}
            }
            let e = self.rob.pop_front().unwrap();
            self.stats.uops_committed += 1;
            if e.last_of_inst {
                self.stats.insts_committed += 1;
                if self.in_roi {
                    self.stats.measured_insts += 1;
                }
            }
            self.stats.region_uops[(e.region as usize).min(3)] += 1;
            if e.old_prd != NO_REG {
                self.prf_free.push(e.old_prd);
            }
            match e.kind {
                UopKind::Store => {
                    // Architectural memory write + store buffer entry.
                    let s = self
                        .sq
                        .iter()
                        .position(|s| s.seq == e.seq)
                        .expect("store commit without SQ entry");
                    let sq = self.sq.remove(s);
                    debug_assert!(sq.has_addr);
                    self.guest.write(sq.addr, sq.size, sq.value);
                    let id = self.next_sb_id;
                    self.next_sb_id += 1;
                    self.sb.push_back((
                        id,
                        SbEntry { addr: sq.addr, issued: false, done: false },
                    ));
                }
                UopKind::Load => {
                    self.lq.retain(|l| l.seq != e.seq);
                }
                UopKind::AExec { is_store } => {
                    if let Some((id, spm, mem)) = e.ami_vals {
                        if id != 0 {
                            self.asmc.push_request(AmiReq {
                                id: id as u16,
                                spm,
                                mem,
                                is_store,
                            });
                        }
                    }
                }
                UopKind::GetFin => {
                    // A returned ID becomes free again (paper: getfin puts it
                    // back into the free list); recycle locally when there is
                    // register room.
                    if e.result != 0 && !self.alsu.recycle_free(e.result as u16) {
                        self.asmc.return_ids(&[e.result as u16]);
                    }
                }
                UopKind::CfgWr => {
                    let v = e.ami_vals.map(|x| x.0).unwrap_or(0);
                    // Invalid cfg indices are a commit-time no-op here; the
                    // verifier (AMI006) refuses such programs up front.
                    match CfgReg::from_imm(e.inst.imm) {
                        Some(CfgReg::Granularity) => self.asmc.set_granularity(v),
                        Some(CfgReg::QueueBase) | None => {}
                        Some(CfgReg::QueueLength) => self.asmc.set_queue_length(v),
                    }
                }
                UopKind::Flush => {
                    if let Some((_, addr, _)) = e.ami_vals {
                        self.memsys.flush_line(addr, self.cycle);
                    }
                }
                UopKind::Roi => {
                    self.in_roi = e.inst.imm == 1;
                }
                _ => {}
            }
        }
    }

    // ---------------- memory completion handling ----------------

    fn drain_mem_completions(&mut self) {
        let mut completions = std::mem::take(&mut self.scratch_comp);
        completions.clear();
        completions.append(&mut self.memsys.completions);
        for &c in completions.iter() {
            match self.token_take(c.token) {
                Some(TokenTarget::Load(seq)) => {
                    if seq == u64::MAX {
                        continue; // software prefetch
                    }
                    if let Some(idx) = self.rob_idx(seq) {
                        if self.rob[idx].kind == UopKind::Load && !self.rob[idx].completed {
                            let issue = self
                                .lq
                                .iter()
                                .find(|l| l.seq == seq)
                                .map(|l| l.issue_cycle)
                                .unwrap_or(self.cycle);
                            let lat = self.cycle.saturating_sub(issue);
                            self.stats.sync_load_latency.add(lat);
                            self.writeback.push((self.cycle, seq));
                        }
                    }
                }
                Some(TokenTarget::StoreBuf(id)) => {
                    if let Some((_, e)) = self.sb.iter_mut().find(|(i, _)| *i == id) {
                        e.done = true;
                    }
                }
                None => {} // squashed load or dropped prefetch
            }
        }
        self.scratch_comp = completions;
    }

    // ---------------- per-cycle stats ----------------

    fn cycle_stats(&mut self) {
        let c = self.cycle;
        let s = &mut self.stats;
        s.rob_occ.update(c, self.rob.len() as u64);
        s.iq_occ.update(c, self.iq.len() as u64);
        s.lq_occ.update(c, self.lq.len() as u64);
        s.sq_occ.update(c, self.sq.len() as u64);
        s.l1d_mshr_occ.update(c, self.memsys.l1d.mshr_used() as u64);
        s.l2_mshr_occ.update(c, self.memsys.l2.mshr_used() as u64);
        let fi = self.memsys.far_inflight();
        if fi != self.last_far_inflight {
            s.far_inflight.update(c, fi);
            self.last_far_inflight = fi;
        }
        s.amu_inflight.update(c, self.asmc.inflight_amart() as u64);
        if self.in_roi {
            s.measured_cycles += 1;
        }
        // Region attribution: the ROB head's region owns this cycle.
        let region = self
            .rob
            .front()
            .map(|e| e.region)
            .unwrap_or(Region::Main as u8);
        s.region_cycles[(region as usize).min(3)] += 1;
    }

    // ---------------- top-level ----------------

    pub fn tick(&mut self) {
        let now = self.cycle;
        self.memsys
            .tick(now, self.cfg.l2.hit_latency, self.cfg.l1d.hit_latency);
        self.drain_mem_completions();
        if self.cfg.amu.enabled {
            self.asmc
                .tick(now, &mut self.memsys, &mut self.guest, &mut self.stats);
            self.alsu_poll();
        }
        self.writeback_stage();
        self.commit();
        if self.done {
            return;
        }
        self.sb_pump();
        self.std_pump();
        self.lq_pump();
        self.issue();
        self.dispatch();
        self.fetch();
        self.cycle_stats();
        self.cycle += 1;
        self.stats.cycles = self.cycle;
    }

    /// Architectural value of guest register `r` (via the rename map).
    pub fn arch_reg(&self, r: u8) -> u64 {
        self.prf_val[self.map[r as usize] as usize]
    }

    /// AMU ID-conservation invariant, checkable mid-run from tests: the
    /// ASMC-side ledger (free + finished + in-flight + at-ALSU + batches)
    /// must always cover exactly `queue_length` IDs.
    pub fn amu_ids_conserved(&self) -> bool {
        !self.cfg.amu.enabled || self.asmc.id_conservation_holds()
    }

    // ---------------- event-driven fast-forward ----------------

    /// Earliest future cycle at which anything inside the machine can change
    /// *on its own*: pending memory-system events (which subsume backend
    /// link/channel timers via [`MemSys::next_event_cycle`]), ASMC ID-batch
    /// arrivals/deliveries, scheduled writebacks, and frontend µops still
    /// traversing the fetch pipeline. Everything else (issue, commit, LSQ
    /// pumps, dispatch) only acts when state changes — which the fixed-point
    /// fingerprint check rules out before a jump.
    fn next_wake_cycle(&self) -> u64 {
        let mut wake = u64::MAX;
        if let Some(t) = self.memsys.next_event_cycle(self.cycle) {
            wake = wake.min(t);
        }
        if self.cfg.amu.enabled {
            if let Some(t) = self.asmc.next_event_cycle() {
                wake = wake.min(t);
            }
        }
        for &(when, _) in &self.writeback {
            wake = wake.min(when);
        }
        if let Some(f) = self.fetch_q.front() {
            if f.ready_at > self.cycle {
                wake = wake.min(f.ready_at);
            }
        }
        wake
    }

    /// Mix all the pipeline state a tick could structurally change — queues,
    /// tables, flags, timers — into one word. Two consecutive ticks with
    /// equal fingerprints prove the machine is at a fixed point. Monotone
    /// counters are deliberately excluded (retry loops bump them every idle
    /// cycle; they are folded in closed form instead), as are value arrays
    /// (PRF contents, cache lines, guest memory, predictor tables): those
    /// are only written on paths that also change fingerprinted state (ROB
    /// flags, queue occupancy, MSHR slots, event-queue sequence numbers).
    fn state_fingerprint(&self) -> u64 {
        let mut h = Mix64::new();
        h.mix(self.pc as u64);
        h.mix(self.next_seq);
        h.mix(self.done as u64
            | (self.fetch_halted as u64) << 1
            | (self.in_roi as u64) << 2
            | (self.alsu.batch_busy as u64) << 3);
        h.mix(self.fetch_blocked_on.unwrap_or(u64::MAX));
        h.mix(self.fetch_q.len() as u64);
        for f in &self.fetch_q {
            h.mix(f.seq);
            h.mix(f.ready_at);
        }
        h.mix(self.prf_free.len() as u64);
        h.mix(self.rob.len() as u64);
        for e in &self.rob {
            h.mix(e.seq);
            h.mix(e.in_iq as u64
                | (e.executing as u64) << 1
                | (e.completed as u64) << 2
                | (e.issued_batch as u64) << 3
                | e.batch_wait.map_or(0, |t| t.0 + 1) << 8);
            h.mix(e.result);
        }
        h.mix(self.iq.len() as u64);
        for &s in &self.iq {
            h.mix(s);
        }
        h.mix(self.lq.len() as u64);
        for l in &self.lq {
            h.mix(l.seq);
            h.mix(l.addr);
            h.mix((l.state as u64) << 1 | l.has_addr as u64);
            h.mix(l.issue_cycle);
        }
        h.mix(self.sq.len() as u64);
        for s in &self.sq {
            h.mix(s.seq);
            h.mix(s.addr);
            h.mix(s.value);
            h.mix((s.has_addr as u64) << 1 | s.has_value as u64);
        }
        h.mix(self.sb.len() as u64);
        for (id, e) in &self.sb {
            h.mix(*id);
            h.mix(e.addr);
            h.mix((e.issued as u64) << 1 | e.done as u64);
        }
        h.mix(self.next_sb_id);
        h.mix(self.writeback.len() as u64);
        for &(when, seq) in &self.writeback {
            h.mix(when);
            h.mix(seq);
        }
        h.mix(self.std_wait.len() as u64);
        for &s in &self.std_wait {
            h.mix(s);
        }
        h.mix(self.tokens.len() as u64);
        for t in &self.tokens {
            h.mix(match t {
                None => 0,
                Some(TokenTarget::Load(s)) => 1 | s << 2,
                Some(TokenTarget::StoreBuf(i)) => 2 | i << 2,
            });
        }
        h.mix(self.token_free.len() as u64);
        for &t in &self.token_free {
            h.mix(t as u64);
        }
        h.mix(self.alsu.free_lvr.len() as u64);
        for &id in &self.alsu.free_lvr {
            h.mix(id as u64);
        }
        h.mix(self.alsu.fin_lvr.len() as u64);
        for &id in &self.alsu.fin_lvr {
            h.mix(id as u64);
        }
        self.asmc.state_signature(&mut h);
        self.memsys.state_signature(&mut h);
        h.finish()
    }

    /// One stepping quantum with fast-forward: run a single *trial* tick
    /// (always kept), and if it proves to be a fixed point — identical
    /// fingerprint, no histogram/level movement — replicate its counter
    /// deltas across every cycle up to `bound` or the next wake event,
    /// whichever is earlier, and jump the clock there. The skipped ticks are
    /// identical by induction: the machine state they would act on is
    /// byte-for-byte the state the trial tick acted on, and no timer fires
    /// before the target.
    fn tick_fast(&mut self, bound: u64) {
        let now = self.cycle;
        if now < self.ff_next_try {
            self.tick();
            return;
        }
        let target = self.next_wake_cycle().min(bound);
        if target <= now.saturating_add(FF_MIN_SKIP) {
            self.tick();
            return;
        }
        let before_fp = self.state_fingerprint();
        let before_stats = self.stats.clone();
        let before_mem = self.memsys.counter_snapshot();
        self.tick();
        if self.done
            || self.state_fingerprint() != before_fp
            || !self.stats.hists_and_levels_unchanged(&before_stats)
        {
            // Actively computing: don't re-pay the proof cost every tick.
            self.ff_next_try = self.cycle + FF_RETRY_BACKOFF;
            return;
        }
        // Fixed point: ticks at now+1 .. target-1 are identical to the trial
        // tick. Fold their counter deltas in closed form and jump.
        let k = target - (now + 1);
        if k == 0 {
            return;
        }
        self.stats.fold_idle(k, &before_stats);
        self.memsys.fold_idle_counters(k, &before_mem);
        self.ff_jumped_cycles += k;
        self.cycle = target;
        self.stats.cycles = target;
    }

    // ---------------- top-level stepping ----------------

    /// Shared stepping core behind [`Simulator::run`] and
    /// [`Simulator::run_for`]: ticks (fast-forwarding across provably idle
    /// spans unless `cfg.fast_forward` is off) until the program halts,
    /// `stop_at` is reached, the `max_cycles` ceiling trips, or the drained-
    /// pipeline deadlock detector fires. Both error paths live only here, so
    /// the solo and multi-tenant drivers report identical diagnostics.
    fn step_until(&mut self, stop_at: u64) -> Result<(), String> {
        let max = self.cfg.max_cycles;
        let bound = stop_at.min(max);
        while !self.done && self.cycle < stop_at {
            if self.cycle >= max {
                return Err(format!(
                    "simulation exceeded {max} cycles at pc={} (rob={}, iq={}, fetch_q={})",
                    self.rob.front().map(|e| e.pc).unwrap_or(self.pc),
                    self.rob.len(),
                    self.iq.len(),
                    self.fetch_q.len()
                ));
            }
            if self.fast_forward {
                self.tick_fast(bound);
            } else {
                self.tick();
            }
            if self.trace && self.cycle % 10_000 == 0 {
                eprintln!(
                    "[trace] cyc={} pc={} rob={} iq={} lq={} sq={} wb={} tokens={} fetchq={} committed={} inflight={} batches={} memev={} stdw={}",
                    self.cycle,
                    self.rob.front().map(|e| e.pc).unwrap_or(self.pc),
                    self.rob.len(),
                    self.iq.len(),
                    self.lq.len(),
                    self.sq.len(),
                    self.writeback.len(),
                    self.tokens.len(),
                    self.fetch_q.len(),
                    self.stats.uops_committed,
                    self.memsys.far_inflight(),
                    self.asmc.batches_len(),
                    self.memsys.pending_events(),
                    self.std_wait.len(),
                );
            }
            // Deadlock detector: nothing in flight and nothing fetchable.
            if self.rob.is_empty()
                && self.fetch_q.is_empty()
                && self.fetch_halted
                && self.fetch_blocked_on.is_none()
                && !self.done
                && self.sb.is_empty()
            {
                return Err("pipeline drained without Halt (fell off program end)".into());
            }
        }
        if self.done {
            // Harvest backend scenario counters (near-tier hits/evictions,
            // pool congestion, policy switches) now that the far data plane
            // is quiescent. One assignment regardless of how many columns
            // the scenario schema grows.
            self.stats.scenario = self.memsys.scenario_stats();
        }
        Ok(())
    }

    /// Run to completion (Halt) or `max_cycles`.
    pub fn run(&mut self) -> Result<SimResult, String> {
        self.step_until(u64::MAX)?;
        Ok(SimResult {
            cycles: self.cycle,
            committed_insts: self.stats.insts_committed,
        })
    }

    /// Run at most `budget` further cycles: `Ok(true)` once the program
    /// halts (scenario counters harvested, exactly like [`Simulator::run`]),
    /// `Ok(false)` when the budget is exhausted first. The multi-tenant
    /// driver (`session::tenancy`) steps co-scheduled simulators round-robin
    /// through this, so tenants sharing one far-memory pool perceive each
    /// other's congestion while each pipeline stays single-threaded. The
    /// same `max_cycles` ceiling and drained-pipeline deadlock detector as
    /// `run` apply across calls; fast-forward jumps clamp to the budget
    /// boundary so round-based interleaving sees identical timing.
    pub fn run_for(&mut self, budget: u64) -> Result<bool, String> {
        let stop_at = self.cycle.saturating_add(budget);
        self.step_until(stop_at)?;
        Ok(self.done)
    }
}

enum IdUopOutcome {
    Got(u16),
    Wait,
    Retry,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::interp::{CompletionOrder, Interp};
    use crate::isa::mem::{FAR_BASE, LOCAL_BASE, SPM_BASE};
    use crate::isa::Asm;

    fn run_sim(cfg: SimConfig, prog: Program) -> Simulator {
        let mut sim = Simulator::new(cfg, prog);
        sim.run().expect("sim failed");
        sim
    }

    fn run_sim_with_mem<F: FnOnce(&mut GuestMem)>(
        cfg: SimConfig,
        prog: Program,
        init: F,
    ) -> Simulator {
        let mut sim = Simulator::new(cfg, prog);
        init(&mut sim.guest);
        sim.run().expect("sim failed");
        sim
    }

    #[test]
    fn alu_loop_matches_interp() {
        let mut a = Asm::new("sum");
        a.li(1, 0).li(2, 0).li(3, 100);
        a.label("loop");
        a.add(2, 2, 1);
        a.addi(1, 1, 1);
        a.blt(1, 3, "loop");
        a.halt();
        let prog = a.finish();
        let sim = run_sim(SimConfig::baseline(), prog.clone());
        assert_eq!(sim.arch_reg(2), 4950);
        // Cross-check against the functional oracle.
        let mut mem = GuestMem::new();
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        it.run(&prog, 100_000).unwrap();
        assert_eq!(it.regs[2], sim.arch_reg(2));
    }

    #[test]
    fn run_for_chunked_stepping_matches_run_exactly() {
        // The multi-tenant interleaver depends on this: stepping a pipeline
        // in bounded rounds must be invisible to the simulated machine.
        let mk = || {
            let mut a = Asm::new("chunked");
            a.li(1, FAR_BASE as i64);
            a.li(2, 0).li(3, 0).li(4, 200);
            a.label("loop");
            a.ld64(5, 1, 0);
            a.add(3, 3, 5);
            a.addi(2, 2, 1);
            a.blt(2, 4, "loop");
            a.halt();
            Simulator::new(SimConfig::baseline(), a.finish())
        };
        let mut whole = mk();
        let res = whole.run().expect("run");
        let mut chunked = mk();
        let mut rounds = 0u64;
        while !chunked.run_for(64).expect("run_for") {
            rounds += 1;
            assert!(rounds < 1_000_000, "chunked run must terminate");
        }
        assert!(rounds > 1, "budget 64 must take multiple rounds");
        assert_eq!(chunked.cycle, res.cycles, "round boundaries must not change timing");
        assert_eq!(chunked.arch_reg(3), whole.arch_reg(3));
        assert_eq!(chunked.stats.insts_committed, whole.stats.insts_committed);
        assert_eq!(chunked.stats.scenario, whole.stats.scenario, "scenario harvest on done");
        // Once done, further budget is a no-op.
        assert!(chunked.run_for(64).expect("idempotent"));
        assert_eq!(chunked.cycle, res.cycles);
    }

    #[test]
    fn run_and_run_for_report_identical_max_cycles_error() {
        // Both entry points delegate to one stepping core; the ceiling
        // diagnostic must be byte-identical whichever path trips it.
        let mk = || {
            let mut a = Asm::new("spin");
            a.li(1, 0).li(2, 1);
            a.label("loop");
            a.blt(1, 2, "loop"); // 0 < 1 forever
            a.halt();
            let mut cfg = SimConfig::baseline();
            cfg.max_cycles = 2_000;
            Simulator::new(cfg, a.finish())
        };
        let e_run = mk().run().expect_err("must exceed max_cycles");
        let mut sim = mk();
        let e_run_for = loop {
            match sim.run_for(128) {
                Ok(done) => assert!(!done, "spin loop must not complete"),
                Err(e) => break e,
            }
        };
        assert_eq!(e_run, e_run_for, "both stepping paths share one error site");
        assert!(e_run.contains("simulation exceeded 2000 cycles"), "{e_run}");
    }

    #[test]
    fn run_and_run_for_report_identical_drained_pipeline_error() {
        // A program with no Halt falls off the end: same deadlock text from
        // the shared stepping core on both paths.
        let mk = || {
            let mut a = Asm::new("noend");
            a.li(1, 7);
            a.add(2, 1, 1);
            Simulator::new(SimConfig::baseline(), a.finish())
        };
        let e_run = mk().run().expect_err("must detect drained pipeline");
        let mut sim = mk();
        let e_run_for = loop {
            match sim.run_for(16) {
                Ok(done) => assert!(!done, "drained pipeline must not report done"),
                Err(e) => break e,
            }
        };
        assert_eq!(e_run, e_run_for, "both stepping paths share one error site");
        assert_eq!(e_run, "pipeline drained without Halt (fell off program end)");
    }

    #[test]
    fn fast_forward_folds_idle_spans_and_preserves_all_stats() {
        // Strided far loads at 5 µs: the pipeline spends almost all its
        // cycles stalled on the link, which fast-forward must skip without
        // perturbing a single counter, histogram, or occupancy integral.
        let mk = |ff: bool| {
            let mut a = Asm::new("ff");
            a.li(1, FAR_BASE as i64);
            a.li(2, 0).li(3, 0).li(4, 24);
            a.roi_begin();
            a.label("loop");
            a.ld64(5, 1, 0);
            a.add(3, 3, 5);
            a.addi(1, 1, 64); // next line: every iteration is a far miss
            a.addi(2, 2, 1);
            a.blt(2, 4, "loop");
            a.roi_end();
            a.halt();
            let mut cfg = SimConfig::baseline().with_far_latency_ns(5000.0);
            cfg.far.jitter_frac = 0.0;
            cfg.fast_forward = ff;
            Simulator::new(cfg, a.finish())
        };
        let mut fast = mk(true);
        fast.run().expect("fast-forward run");
        let mut slow = mk(false);
        slow.run().expect("tick-by-tick run");
        assert!(fast.ff_jumped_cycles > 0, "5us far stalls must trigger jumps");
        assert_eq!(slow.ff_jumped_cycles, 0, "disabled means every cycle ticks");
        assert_eq!(fast.cycle, slow.cycle, "fast-forward must not change timing");
        assert_eq!(fast.arch_reg(3), slow.arch_reg(3), "architectural state");
        assert_eq!(fast.stats, slow.stats, "every statistic must be identical");
    }

    #[test]
    fn fast_forward_is_chunk_boundary_invariant() {
        // run_for with fast-forward on: jumps clamp to the budget boundary,
        // so round-based multi-tenant stepping still matches a whole run.
        let mk = || {
            let mut a = Asm::new("ffchunk");
            a.li(1, FAR_BASE as i64);
            a.li(2, 0).li(3, 0).li(4, 12);
            a.label("loop");
            a.ld64(5, 1, 0);
            a.add(3, 3, 5);
            a.addi(1, 1, 64);
            a.addi(2, 2, 1);
            a.blt(2, 4, "loop");
            a.halt();
            let mut cfg = SimConfig::baseline().with_far_latency_ns(5000.0);
            cfg.far.jitter_frac = 0.0;
            Simulator::new(cfg, a.finish())
        };
        let mut whole = mk();
        whole.run().expect("run");
        let mut chunked = mk();
        let mut rounds = 0u64;
        while !chunked.run_for(1024).expect("run_for") {
            rounds += 1;
            assert!(rounds < 1_000_000, "chunked run must terminate");
        }
        assert!(rounds > 1, "budget must take multiple rounds");
        assert!(chunked.ff_jumped_cycles > 0, "chunked runs still fast-forward");
        assert_eq!(chunked.cycle, whole.cycle);
        assert_eq!(chunked.stats, whole.stats, "round boundaries are invisible");
    }

    #[test]
    fn alu_loop_ipc_is_superscalar_ish() {
        let mut a = Asm::new("ipc");
        // Independent work: 4 chains.
        a.li(1, 0).li(2, 0).li(3, 0).li(4, 0).li(5, 0).li(6, 5000);
        a.label("loop");
        a.addi(1, 1, 1);
        a.addi(2, 2, 1);
        a.addi(3, 3, 1);
        a.addi(4, 4, 1);
        a.addi(5, 5, 1);
        a.blt(5, 6, "loop");
        a.halt();
        let sim = run_sim(SimConfig::baseline(), a.finish());
        let ipc = sim.stats.insts_committed as f64 / sim.cycle as f64;
        assert!(ipc > 2.0, "6-wide core should sustain ipc > 2 on ALU loop: {ipc:.2}");
    }

    #[test]
    fn store_load_roundtrip_local() {
        let mut a = Asm::new("mem");
        a.li(1, LOCAL_BASE as i64);
        a.li(2, 0xDEAD);
        a.st64(2, 1, 16);
        a.ld64(3, 1, 16);
        a.halt();
        let sim = run_sim(SimConfig::baseline(), a.finish());
        assert_eq!(sim.arch_reg(3), 0xDEAD, "store-to-load forwarding value");
    }

    #[test]
    fn partial_overlap_store_load_stalls_but_correct() {
        let mut a = Asm::new("partial");
        a.li(1, LOCAL_BASE as i64);
        a.li(2, 0x1122334455667788u64 as i64);
        a.st64(2, 1, 0);
        a.ld(3, 1, 4, 4); // upper half: partial overlap, must wait for commit
        a.halt();
        let sim = run_sim(SimConfig::baseline(), a.finish());
        assert_eq!(sim.arch_reg(3), 0x11223344);
    }

    #[test]
    fn far_load_pays_link_latency() {
        let mk = |ns: f64| {
            let mut a = Asm::new("far");
            a.li(1, FAR_BASE as i64);
            a.roi_begin();
            a.ld64(2, 1, 0);
            a.roi_end();
            a.halt();
            let mut cfg = SimConfig::baseline().with_far_latency_ns(ns);
            cfg.far.jitter_frac = 0.0;
            run_sim(cfg, a.finish())
        };
        let fast = mk(100.0);
        let slow = mk(2000.0);
        let d = slow.cycle as i64 - fast.cycle as i64;
        assert!(d > 5000, "2us vs 0.1us far load must differ by ~5.7k cycles: {d}");
    }

    #[test]
    fn branchy_program_matches_interp() {
        // Data-dependent branches over a pseudo-random array.
        let mut a = Asm::new("branchy");
        a.li(1, LOCAL_BASE as i64); // base
        a.li(2, 0); // i
        a.li(3, 256); // n
        a.li(4, 0); // acc
        a.label("loop");
        a.slli(5, 2, 3);
        a.add(5, 5, 1);
        a.ld64(6, 5, 0);
        a.andi(7, 6, 1);
        a.beq(7, 0, "even");
        a.add(4, 4, 6);
        a.j("next");
        a.label("even");
        a.sub(4, 4, 6);
        a.label("next");
        a.addi(2, 2, 1);
        a.blt(2, 3, "loop");
        a.halt();
        let prog = a.finish();
        let init = |mem: &mut GuestMem| {
            let mut rng = crate::util::prng::Xoshiro256::new(42);
            for i in 0..256u64 {
                mem.write_u64(LOCAL_BASE + i * 8, rng.next_u64() >> 32);
            }
        };
        let sim = run_sim_with_mem(SimConfig::baseline(), prog.clone(), init);
        let mut mem = GuestMem::new();
        init(&mut mem);
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        it.run(&prog, 1_000_000).unwrap();
        assert_eq!(sim.arch_reg(4), it.regs[4], "squash recovery must be exact");
        assert!(sim.stats.branch_mispredicts > 0, "random branches must mispredict");
        assert!(sim.stats.squashed_uops > 0);
    }

    #[test]
    fn jalr_dispatch_works() {
        // Computed dispatch: r1 holds target.
        let mut a = Asm::new("jalr");
        a.li(2, 0);
        a.li(1, 6); // target = label "t1" (instruction index 6)
        a.jalr(3, 1);
        a.li(2, 111); // skipped
        a.halt();
        a.nop();
        // index 6:
        a.label("t1");
        a.addi(2, 2, 7);
        a.halt();
        let prog = a.finish();
        // Verify label landed where the literal says.
        assert_eq!(prog.labels.iter().find(|(n, _)| n == "t1").unwrap().1, 6);
        let sim = run_sim(SimConfig::baseline(), prog);
        assert_eq!(sim.arch_reg(2), 7);
        assert_eq!(sim.arch_reg(3), 3, "link register value");
    }

    #[test]
    fn ami_aload_roundtrip_on_amu_config() {
        let mut a = Asm::new("ami");
        a.li(1, (SPM_BASE + 128) as i64);
        a.li(2, (FAR_BASE + 64) as i64);
        a.aload(3, 1, 2);
        a.label("poll");
        a.getfin(4);
        a.beq(4, 0, "poll");
        a.ld64(5, 1, 0);
        a.halt();
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let sim = run_sim_with_mem(cfg, a.finish(), |mem| {
            mem.write_u64(FAR_BASE + 64, 0xABCD);
        });
        assert_ne!(sim.arch_reg(3), 0, "id allocated");
        assert_eq!(sim.arch_reg(4), sim.arch_reg(3), "getfin returns the id");
        assert_eq!(sim.arch_reg(5), 0xABCD, "data landed in SPM");
        assert!(sim.cycle > 3000, "must include the far round trip");
        assert!(sim.amu_ids_conserved());
    }

    #[test]
    fn ami_astore_writes_far_memory() {
        let mut a = Asm::new("astore");
        a.li(1, SPM_BASE as i64);
        a.li(2, 0x77AA);
        a.st64(2, 1, 0); // write SPM
        a.ld64(6, 1, 0); // force ordering: read it back before astore
        a.li(3, (FAR_BASE + 256) as i64);
        a.astore(4, 1, 3);
        a.label("poll");
        a.getfin(5);
        a.beq(5, 0, "poll");
        a.halt();
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let mut sim = Simulator::new(cfg, a.finish());
        sim.run().unwrap();
        assert_eq!(sim.guest.read_u64(FAR_BASE + 256), 0x77AA);
        assert!(sim.amu_ids_conserved());
    }

    #[test]
    fn many_aloads_reach_high_mlp() {
        // 64 independent aloads in flight before polling: the AMU must
        // track them all concurrently with no MSHR pressure.
        let mut a = Asm::new("mlp");
        a.li(1, SPM_BASE as i64);
        a.li(2, FAR_BASE as i64);
        a.li(10, 0); // counter of completed
        a.li(11, 64);
        a.roi_begin();
        for k in 0..64i64 {
            a.addi(3, 1, k * 64);
            a.addi(4, 2, k * 4096);
            a.aload(5, 3, 4);
        }
        a.label("poll");
        a.getfin(6);
        a.beq(6, 0, "poll");
        a.addi(10, 10, 1);
        a.blt(10, 11, "poll");
        a.roi_end();
        a.halt();
        let mut cfg = SimConfig::amu().with_far_latency_ns(2000.0);
        cfg.far.jitter_frac = 0.0;
        let mut sim = Simulator::new(cfg, a.finish());
        sim.run().unwrap();
        assert!(
            sim.stats.far_inflight.max >= 48,
            "peak far MLP should approach 64: {}",
            sim.stats.far_inflight.max
        );
        // All 64 complete in roughly ONE round trip if truly overlapped:
        // far latency 6000 cycles; serial would be 384k cycles.
        assert!(
            sim.cycle < 30_000,
            "aloads must overlap, not serialize: {} cycles",
            sim.cycle
        );
        assert!(sim.amu_ids_conserved());
    }

    #[test]
    fn baseline_sync_loads_hit_mshr_wall() {
        // The same 64 independent far accesses with synchronous loads on
        // the baseline: bounded by LQ/MSHR, still overlapped but the core
        // must hold resources. Sanity: it completes and is slower per-access
        // than the AMU version at high latency.
        let mut a = Asm::new("sync64");
        a.li(2, FAR_BASE as i64);
        a.li(10, 0);
        a.roi_begin();
        for k in 0..64i64 {
            a.ld64(5, 2, k * 4096);
            a.add(10, 10, 5);
        }
        a.roi_end();
        a.halt();
        let mut cfg = SimConfig::baseline().with_far_latency_ns(2000.0);
        cfg.far.jitter_frac = 0.0;
        let mut sim = Simulator::new(cfg, a.finish());
        sim.run().unwrap();
        assert!(sim.stats.far_inflight.max >= 16, "OoO should overlap some");
        assert!(sim.cycle < 200_000);
    }

    #[test]
    fn id_exhaustion_returns_zero_and_recovers() {
        let mut a = Asm::new("exhaust");
        a.li(1, 2);
        a.cfgwr(1, CfgReg::QueueLength);
        a.li(2, SPM_BASE as i64);
        a.li(3, FAR_BASE as i64);
        // Issue 3 aloads with queue_length=2: LVR batch gets both free ids;
        // third allocation must return 0.
        a.aload(4, 2, 3);
        a.aload(5, 2, 3);
        a.aload(6, 2, 3);
        // Drain both.
        a.li(10, 0);
        a.label("poll");
        a.getfin(7);
        a.beq(7, 0, "poll");
        a.addi(10, 10, 1);
        a.li(11, 2);
        a.blt(10, 11, "poll");
        a.halt();
        let mut cfg = SimConfig::amu().with_far_latency_ns(200.0);
        cfg.far.jitter_frac = 0.0;
        let sim = run_sim(cfg, a.finish());
        assert_ne!(sim.arch_reg(4), 0);
        assert_ne!(sim.arch_reg(5), 0);
        assert_eq!(sim.arch_reg(6), 0, "third alloc must fail with queue_length=2");
        assert!(sim.amu_ids_conserved());
    }

    #[test]
    fn dma_mode_is_slower_than_amu() {
        let prog = || {
            let mut a = Asm::new("dma");
            a.li(1, SPM_BASE as i64);
            a.li(2, FAR_BASE as i64);
            a.li(10, 0);
            a.li(11, 32);
            a.roi_begin();
            for k in 0..32i64 {
                a.addi(3, 1, k * 64);
                a.addi(4, 2, k * 4096);
                a.aload(5, 3, 4);
            }
            a.label("poll");
            a.getfin(6);
            a.beq(6, 0, "poll");
            a.addi(10, 10, 1);
            a.blt(10, 11, "poll");
            a.roi_end();
            a.halt();
            a.finish()
        };
        let mut amu_cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        amu_cfg.far.jitter_frac = 0.0;
        let mut dma_cfg = SimConfig::amu_dma().with_far_latency_ns(1000.0);
        dma_cfg.far.jitter_frac = 0.0;
        let amu = run_sim(amu_cfg, prog());
        let dma = run_sim(dma_cfg, prog());
        assert!(
            dma.cycle > amu.cycle,
            "DMA-mode ({}) must be slower than AMU ({})",
            dma.cycle,
            amu.cycle
        );
    }

    #[test]
    fn squash_preserves_amu_ids() {
        // A data-dependent branch guards an aload; mispredictions will
        // speculatively execute IdAlloc µops that later squash. IDs must
        // survive.
        let mut a = Asm::new("squashids");
        a.li(1, SPM_BASE as i64);
        a.li(2, FAR_BASE as i64);
        a.li(10, 0); // i
        a.li(11, 64); // n
        a.li(12, 0); // issued count
        a.label("loop");
        // pseudo-random condition: hash(i) & 1
        a.mul(5, 10, 10);
        a.addi(5, 5, 12345);
        a.andi(5, 5, 1);
        a.beq(5, 0, "skip");
        a.aload(6, 1, 2);
        a.addi(12, 12, 0); // keep
        a.label("drain");
        a.getfin(7);
        a.beq(7, 0, "drain");
        a.label("skip");
        a.addi(10, 10, 1);
        a.blt(10, 11, "loop");
        a.halt();
        let mut cfg = SimConfig::amu().with_far_latency_ns(200.0);
        cfg.far.jitter_frac = 0.0;
        let mut sim = Simulator::new(cfg, a.finish());
        sim.run().unwrap();
        assert!(sim.stats.branch_mispredicts > 0, "need mispredicts to test rollback");
        assert!(sim.amu_ids_conserved(), "IDs lost or duplicated across squashes");
    }

    #[test]
    fn roi_markers_bound_measurement() {
        let mut a = Asm::new("roi");
        a.li(1, 0);
        a.li(2, 1000);
        a.label("warm"); // unmeasured warmup loop
        a.addi(1, 1, 1);
        a.blt(1, 2, "warm");
        a.roi_begin();
        a.li(3, 0);
        a.li(4, 100);
        a.label("hot");
        a.addi(3, 3, 1);
        a.blt(3, 4, "hot");
        a.roi_end();
        a.halt();
        let sim = run_sim(SimConfig::baseline(), a.finish());
        assert!(sim.stats.measured_cycles > 0);
        assert!(sim.stats.measured_cycles < sim.cycle / 2, "ROI excludes warmup");
        assert!(sim.stats.measured_insts >= 200);
    }

    #[test]
    fn prefetch_op_brings_line_in() {
        let mut a = Asm::new("pf");
        a.li(1, (FAR_BASE + 1 << 16) as i64);
        a.prefetch(1, 0);
        // Busy wait doing unrelated work ~ the far latency.
        a.li(2, 0);
        a.li(3, 2000);
        a.label("spin");
        a.addi(2, 2, 1);
        a.blt(2, 3, "spin");
        a.roi_begin();
        a.ld64(4, 1, 0); // should now hit in cache
        a.roi_end();
        a.halt();
        let mut cfg = SimConfig::baseline().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let sim = run_sim(cfg, a.finish());
        assert_eq!(sim.stats.prefetches_issued, 1);
        assert!(
            sim.stats.measured_cycles < 100,
            "prefetched load should hit: {} cycles",
            sim.stats.measured_cycles
        );
    }

    #[test]
    fn mixed_program_guest_memory_matches_interp() {
        // Writes a deterministic pattern through loops/branches/stores.
        let mut a = Asm::new("mixed");
        a.li(1, LOCAL_BASE as i64);
        a.li(2, 0);
        a.li(3, 128);
        a.label("loop");
        a.mul(4, 2, 2);
        a.xori(4, 4, 0x5A);
        a.slli(5, 2, 3);
        a.add(5, 5, 1);
        a.st64(4, 5, 0);
        a.addi(2, 2, 1);
        a.blt(2, 3, "loop");
        a.halt();
        let prog = a.finish();
        let mut sim = Simulator::new(SimConfig::baseline(), prog.clone());
        sim.run().unwrap();
        let mut mem = GuestMem::new();
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        it.run(&prog, 1_000_000).unwrap();
        let sim_sum = sim.guest.checksum(LOCAL_BASE, 128 * 8);
        let ref_sum = mem.checksum(LOCAL_BASE, 128 * 8);
        assert_eq!(sim_sum, ref_sum, "architectural memory state must match oracle");
    }
}
