//! amu-sim command-line launcher.
//!
//! Subcommands:
//!   run     — simulate one benchmark under one configuration
//!   report  — regenerate paper figures/tables (fig2..fig11, table4..6, all)
//!   list    — enumerate benchmarks and configuration presets
//!   payload — smoke-test the PJRT payload engine (artifacts/)

use amu_sim::config::SimConfig;
use amu_sim::report;
use amu_sim::util::cli::{self, flag, opt, Spec};
use amu_sim::workloads::{self, Scale, Variant};

const RUN_SPECS: &[Spec] = &[
    opt("bench", "benchmark name (see `list`)"),
    opt("config", "configuration preset (baseline|cxl-ideal|amu|amu-dma|x2|x4)"),
    opt("latency-ns", "additional far-memory latency in ns"),
    opt("scale", "test|paper"),
    opt("variant", "sync|amu|llvm|gp<N>|pf<N>"),
    opt("config-file", "TOML-lite overrides applied on top of the preset"),
    flag("quiet", "suppress progress output"),
];

fn parse_scale(s: &str) -> Scale {
    match s {
        "paper" => Scale::Paper,
        _ => Scale::Test,
    }
}

fn parse_variant(s: &str, cfg: &SimConfig) -> Variant {
    if s == "sync" {
        Variant::Sync
    } else if s == "amu" {
        Variant::Amu
    } else if s == "llvm" {
        Variant::AmuLlvm
    } else if let Some(g) = s.strip_prefix("gp") {
        Variant::GroupPrefetch(g.parse().unwrap_or(16))
    } else if let Some(g) = s.strip_prefix("pf") {
        Variant::SwPrefetch { batch: g.parse().unwrap_or(16), depth: 0 }
    } else {
        workloads::variant_for(cfg)
    }
}

fn cmd_run(argv: &[String]) -> Result<(), String> {
    let args = cli::parse(argv, RUN_SPECS).map_err(|e| e.to_string())?;
    let bench = args.get_str("bench", "gups");
    let config = args.get_str("config", "baseline");
    let latency = args.get_f64("latency-ns", 1000.0).map_err(|e| e.to_string())?;
    let scale = parse_scale(&args.get_str("scale", "test"));
    let mut cfg = SimConfig::preset(&config)
        .ok_or_else(|| format!("unknown config '{config}'"))?
        .with_far_latency_ns(latency);
    if let Some(path) = args.get("config-file") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = amu_sim::util::toml_lite::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_overrides(&doc)?;
    }
    let variant = parse_variant(&args.get_str("variant", "auto"), &cfg);
    let r = report::run_one(&bench, &config, variant, latency, scale)?;
    println!(
        "bench={} config={} variant={} latency={}ns",
        r.bench, r.config, r.variant, r.latency_ns
    );
    println!(
        "  cycles(measured)={}  total={}  insts={}",
        r.measured_cycles, r.total_cycles, r.insts
    );
    println!(
        "  ipc={:.3}  mlp={:.2}  peak_inflight={}",
        r.ipc, r.mlp, r.peak_inflight
    );
    println!(
        "  energy: dynamic={:.2}uJ static={:.2}uJ  disambig={:.2}%  host={}ms",
        r.dynamic_uj,
        r.static_uj,
        r.disambig_frac * 100.0,
        r.host_ms
    );
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<(), String> {
    let specs: &[Spec] = &[opt("scale", "test|paper"), flag("quiet", "less progress")];
    let args = cli::parse(&argv[1..], specs).map_err(|e| e.to_string())?;
    let what = argv.first().map(|s| s.as_str()).unwrap_or("all");
    let scale = parse_scale(&args.get_str("scale", "paper"));
    let quiet = args.has_flag("quiet");
    let needs_sweep = matches!(
        what,
        "fig2" | "fig8" | "fig9" | "fig10" | "fig11" | "headline" | "all"
    );
    let rows = if needs_sweep {
        report::sweep_cached(scale, quiet)
    } else {
        Vec::new()
    };
    let emit = |name: &str, body: String| report::write_report(name, &body);
    match what {
        "fig2" => emit("fig2", report::fig2(&rows)),
        "fig3" => emit("fig3", report::fig3(scale, 1000.0)),
        "fig8" => emit("fig8", report::fig8(&rows)),
        "fig9" => emit("fig9", report::fig9(&rows)),
        "fig10" => emit("fig10", report::fig10(&rows)),
        "fig11" => emit("fig11", report::fig11(&rows)),
        "table4" => emit("table4", report::table4(scale)),
        "table5" => emit("table5", report::table5(scale)),
        "table6" => emit("table6", report::table6()),
        "headline" => emit("headline", report::headline(&rows)),
        "all" => {
            emit("fig2", report::fig2(&rows));
            emit("fig3", report::fig3(scale, 1000.0));
            emit("fig8", report::fig8(&rows));
            emit("fig9", report::fig9(&rows));
            emit("fig10", report::fig10(&rows));
            emit("fig11", report::fig11(&rows));
            emit("table4", report::table4(scale));
            emit("table5", report::table5(scale));
            emit("table6", report::table6());
            emit("headline", report::headline(&rows));
        }
        other => return Err(format!("unknown report '{other}'")),
    }
    Ok(())
}

fn cmd_payload() -> Result<(), String> {
    let rt = amu_sim::runtime::Runtime::load_default().map_err(|e| e.to_string())?;
    println!("payload engine on platform={}", rt.platform());
    let vals: Vec<i32> = (0..amu_sim::runtime::GUPS_BATCH as i32).collect();
    let idxs: Vec<i32> = (0..amu_sim::runtime::GUPS_BATCH as i32).rev().collect();
    let out = rt.gups_update(&vals, &idxs).map_err(|e| e.to_string())?;
    let ok = out
        .iter()
        .zip(vals.iter().zip(idxs.iter()))
        .all(|(o, (v, i))| *o == v ^ i);
    println!("gups_update[{}] check: {}", out.len(), if ok { "OK" } else { "MISMATCH" });
    if !ok {
        return Err("payload engine mismatch".into());
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let result = match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("report") => cmd_report(&argv[1..]),
        Some("payload") => cmd_payload(),
        Some("list") => {
            println!("benchmarks: {}", workloads::ALL.join(" "));
            println!("configs:    {}", SimConfig::preset_names().join(" "));
            Ok(())
        }
        _ => {
            eprintln!("amu-sim {} — AMU paper reproduction", amu_sim::version());
            eprintln!("usage: amu-sim <run|report|payload|list> [options]");
            eprintln!("{}", cli::usage("amu-sim run", RUN_SPECS));
            eprintln!("reports: fig2 fig3 fig8 fig9 fig10 fig11 table4 table5 table6 headline all");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
