//! amu-sim command-line launcher.
//!
//! Subcommands:
//!   run     — simulate one benchmark under one configuration
//!   sweep   — run a (custom or paper) scenario grid in parallel (--jobs)
//!   mtrun   — co-schedule N tenants on one shared far-memory pool under a
//!             QoS policy (fair-share|priority|throttle) and emit
//!             per-tenant slowdown rows
//!   report  — regenerate paper figures/tables (fig2..fig11, table4..6,
//!             sweep, mt, all)
//!   bench   — simulator-throughput benchmark, fast-forward on vs off
//!             (writes BENCH_PR9.json)
//!   check   — static-verify guest programs (isa::verify) without
//!             simulating; prints the AMIxxx diagnostics table
//!   disasm  — emit a built-in benchmark program (or a loaded .asm file)
//!             in the text assembly format (round-trips through `isa::parse`)
//!   list    — enumerate benchmarks, configuration presets, backends,
//!             policies, and metric columns
//!   payload — smoke-test the PJRT payload engine (artifacts/)
//!
//! External programs (`--program <file.asm>`, repeatable): `run`, `sweep`,
//! and `check` load text-format AMI assembly files (see README "External
//! AMI programs" and `examples/asm/`). A loaded program passes the same
//! `isa::verify` deny gate as the built-ins and registers under its
//! `.program` name as a first-class benchmark; sweep cache fingerprints
//! fold in the file's content hash so an edited program never reuses a
//! stale cache.
//!
//! Far-memory backends (`--backend`): every command that simulates far
//! memory accepts a backend selecting the data-plane model — `serial-link`
//! (the paper's CXL-like link, default), `pooled` (multi-channel
//! disaggregated pool with congestion back-pressure), `distribution`
//! (lognormal/bimodal latency with the configured mean, for tail-latency
//! scenarios), and `hybrid` (fast-path/slow-path split). The pooled
//! backend's channel selection is `--pool-policy`: `hash` (default),
//! `least-loaded`, `round-robin`, or `adaptive` (hash until observed
//! congestion crosses `far.pool_adapt_threshold`, then least-loaded). The
//! hybrid near tier's capacity is `--near-capacity` (64 B lines; 0 keeps
//! the legacy `near_frac` coin-flip).
//!
//! Event-driven fast-forward is ON by default for every simulating
//! subcommand: when the pipeline is provably at a fixed point the clock
//! jumps to the next scheduled event and the skipped cycles fold into the
//! counters in closed form — statistics are byte-identical either way (see
//! README "Performance"). `--no-fast-forward` (alias `--no-ff`) ticks
//! every cycle instead; `bench` measures both modes and reports the ratio.
//!
//! Metric columns (`--columns`): every CSV is emitted through the metric
//! schema (`session::metrics`) — `core` (default; the historical row
//! layout, byte-identical), `backend` (keys + per-backend scenario
//! columns: `near_hits`, `near_evictions`, `pool_congestion`, ...),
//! `all`, or an explicit comma-separated column list. Examples:
//!
//! ```text
//! amu-sim run --bench gups --config amu --backend hybrid --latency-ns 2000
//! amu-sim sweep --backend serial-link,pooled,distribution,hybrid --jobs 8
//! amu-sim sweep --backend hybrid --near-capacity 4096 --columns all --jobs 8
//! amu-sim sweep --backend pooled --pool-policy adaptive --columns backend
//! amu-sim mtrun --tenants redis:2,bfs:1 --qos-policy fair-share,throttle
//! amu-sim report mt --tenants redis:1@1/high,bfs:3 --qos-policy priority
//! amu-sim report fig8 --backend distribution --scale test
//! amu-sim report sweep --backend hybrid --columns all --scale test
//! ```
//!
//! Multi-tenancy (`mtrun`): tenant specs are
//! `bench[:count][@weight][/priority]` — e.g. `redis:2@3/high,bfs:1` runs
//! two high-priority redis tenants at weight 3 alongside one bfs tenant.
//! All tenants share ONE far-memory backend instance through the
//! shared-backend arbitration point; `--qos-policy` picks how contended
//! capacity is divided (`fair-share` weighted pacing, `priority` strict
//! admission classes, `throttle` adaptive per-tenant rate limiting). Each
//! row reports the tenant's slowdown vs a solo run of the same benchmark.
//!
//! Sweep CSVs carry the backend both as a column and in the grid
//! fingerprint, so caches from different backends never mix; the pool
//! policy and the hybrid near-tier capacity refine the fingerprint when
//! non-default and the grid sweeps the backend they affect, so those
//! scenarios get their own cache files while existing default caches stay
//! valid (and an ineffective flag is a no-op instead of a duplicate
//! re-simulation). Cache files are format v5: the header pins the grid
//! fingerprint and the metric-schema hash, and stale v3/v4 files are
//! rejected with a migration error naming the regeneration command.

use amu_sim::config::SimConfig;
use amu_sim::report;
use amu_sim::session::{metrics, RunRequest, Selection, Session, SweepGrid, VariantSel, Workload};
use amu_sim::util::cli::{self, flag, opt, Spec, Validate};
use amu_sim::workloads::{self, Scale};

// ---------------------------------------------------------------------------
// Shared option table: every flag is declared exactly ONCE — canonical name,
// aliases, value placeholder, syntactic validator, help line — and the
// subcommand tables below compose from these constants. `--help` output,
// alias spellings, unknown-option suggestions, and number validation are
// therefore consistent across run/sweep/mtrun/report/check/bench by
// construction.
// ---------------------------------------------------------------------------

const O_BENCH: Spec = opt("bench", "name", "benchmark name (see `list`)");
const O_BENCHES: Spec =
    opt("benches", "list", "comma-separated benchmark names (default: all 11)");
const O_CONFIG: Spec = opt(
    "config",
    "preset",
    "configuration preset: baseline|cxl-ideal|amu|amu-dma|x2|x4 (see `list`)",
);
const O_CONFIGS: Spec = opt(
    "configs",
    "list",
    "comma-separated presets (default: baseline,cxl-ideal,amu,amu-dma)",
);
const O_LATENCY: Spec = opt("latency-ns", "ns", "far-memory latency in ns (default: 1000)")
    .aliases(&["latency"])
    .validate(Validate::F64);
const O_LATENCIES: Spec = opt(
    "latencies-ns",
    "list",
    "comma-separated latencies in ns (default: paper's 6 points)",
)
.aliases(&["latencies"])
.validate(Validate::F64List);
const O_BACKEND: Spec = opt(
    "backend",
    "tag[,..]",
    "far-memory backend(s): serial-link|pooled|distribution|hybrid",
)
.aliases(&["backends"]);
const O_POOL_POLICY: Spec = opt(
    "pool-policy",
    "tag",
    "pooled channel selection: hash|least-loaded|round-robin|adaptive (default: hash)",
);
const O_NEAR_CAPACITY: Spec = opt(
    "near-capacity",
    "lines",
    "hybrid near-tier capacity in 64B lines (0 = near_frac coin-flip)",
)
.validate(Validate::U64);
const O_QOS_POLICY: Spec = opt(
    "qos-policy",
    "list",
    "comma-separated QoS policies: fair-share|priority|throttle (default: fair-share)",
);
const O_TENANTS: Spec = opt(
    "tenants",
    "spec",
    "tenant specs: bench[:count][@weight][/priority],... (e.g. redis:2@3/high,bfs:1)",
);
const O_COLUMNS: Spec = opt(
    "columns",
    "sel",
    "emit a column-selected CSV: core|backend|all|<comma-list> (see `list`)",
)
.aliases(&["cols"]);
const O_PROGRAM: Spec = opt(
    "program",
    "file.asm",
    "load an external AMI assembly program (repeatable; see README \"External AMI programs\")",
);
const O_VARIANT: Spec =
    opt("variant", "sel", "auto|sync|amu|llvm|gp<N>|pf<N>[-<D>] (default: auto per config)");
const O_SCALE: Spec = opt("scale", "test|paper", "workload scale (default: test)");
const O_CONFIG_FILE: Spec =
    opt("config-file", "path", "TOML-lite overrides applied on top of the preset");
const O_OUT: Spec =
    opt("out", "path", "write the output CSV/JSON to this path instead of stdout")
        .aliases(&["output"]);
const O_JOBS: Spec =
    opt("jobs", "n", "worker threads (default: all cores)").validate(Validate::U64);
const O_CACHE_FILE: Spec = opt("cache-file", "path", "explicit cache CSV path");
const O_FORMAT: Spec = opt("format", "fmt", "output format: table|json|sarif (default: table)");
const F_QUIET: Spec = flag("quiet", "suppress progress output").aliases(&["q"]);
const F_NO_CACHE: Spec = flag("no-cache", "do not read or write the sweep cache");
const F_NO_FF: Spec = flag(
    "no-fast-forward",
    "tick every cycle instead of event-driven fast-forward (identical statistics, slower host)",
)
.aliases(&["no-ff"]);
const F_ALL: Spec = flag("all", "check every registered benchmark");
const F_DENY_WARNINGS: Spec =
    flag("deny-warnings", "exit nonzero on warn-level findings too (the CI gate)");
const F_VERBOSE: Spec = flag("verbose", "also print info-level diagnostics");

const RUN_SPECS: &[Spec] = &[
    O_BENCH,
    O_PROGRAM,
    O_CONFIG,
    O_LATENCY,
    O_BACKEND,
    O_POOL_POLICY,
    O_NEAR_CAPACITY,
    O_COLUMNS,
    O_SCALE,
    O_VARIANT,
    O_CONFIG_FILE,
    F_NO_FF,
    F_QUIET,
];

const SWEEP_SPECS: &[Spec] = &[
    O_BENCHES,
    O_PROGRAM,
    O_CONFIGS,
    O_LATENCIES,
    O_VARIANT,
    O_BACKEND,
    O_POOL_POLICY,
    O_NEAR_CAPACITY,
    O_COLUMNS,
    O_OUT,
    O_SCALE,
    O_JOBS,
    O_CACHE_FILE,
    F_NO_CACHE,
    F_NO_FF,
    F_QUIET,
];

const MTRUN_SPECS: &[Spec] = &[
    O_TENANTS,
    O_QOS_POLICY,
    O_CONFIG,
    O_BACKEND,
    O_LATENCY,
    O_CONFIG_FILE,
    O_SCALE,
    O_JOBS,
    O_OUT,
    F_NO_FF,
    F_QUIET,
];

const BENCH_SPECS: &[Spec] = &[O_OUT, F_NO_FF, F_QUIET];

const CHECK_SPECS: &[Spec] =
    &[O_BENCH, O_PROGRAM, O_VARIANT, O_SCALE, O_FORMAT, F_ALL, F_DENY_WARNINGS, F_VERBOSE];

const DISASM_SPECS: &[Spec] = &[O_BENCH, O_PROGRAM, O_VARIANT, O_SCALE, O_OUT];

const REPORT_SPECS: &[Spec] = &[
    O_SCALE,
    O_BACKEND,
    O_POOL_POLICY,
    O_NEAR_CAPACITY,
    O_COLUMNS,
    O_TENANTS,
    O_QOS_POLICY,
    O_CONFIG,
    O_LATENCY,
    O_CONFIG_FILE,
    O_JOBS,
    F_NO_FF,
    F_QUIET,
];

/// Parse a subcommand's argv against its spec table, honouring `--help`.
/// Returns `None` when help was printed (the command should exit cleanly).
fn parse_cmd(cmd: &str, argv: &[String], specs: &[Spec]) -> Result<Option<cli::Args>, String> {
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", cli::usage(cmd, specs));
        return Ok(None);
    }
    cli::parse(argv, specs).map(Some).map_err(|e| e.to_string())
}

fn parse_scale(s: &str) -> Result<Scale, String> {
    s.parse()
}

fn parse_variant_sel(s: &str) -> Result<VariantSel, String> {
    VariantSel::parse(s).map_err(|e| e.to_string())
}

fn parse_jobs(args: &cli::Args) -> Result<Option<usize>, String> {
    match args.get("jobs") {
        None => Ok(None),
        Some(s) => {
            let n = cli::parse_u64(s)
                .map_err(|_| format!("--jobs: bad count '{s}' (expected a positive integer)"))?;
            if n == 0 {
                return Err("--jobs must be >= 1".into());
            }
            Ok(Some(n as usize))
        }
    }
}

fn split_list(s: &str) -> Vec<String> {
    s.split(',').map(str::trim).filter(|p| !p.is_empty()).map(String::from).collect()
}

fn parse_near_capacity(args: &cli::Args) -> Result<Option<usize>, String> {
    match args.get("near-capacity") {
        None => Ok(None),
        Some(s) => cli::parse_u64(s)
            .map(|n| Some(n as usize))
            .map_err(|_| format!("--near-capacity: bad line count '{s}' (expected an integer)")),
    }
}

fn parse_columns(args: &cli::Args) -> Result<Option<Selection>, String> {
    args.get("columns").map(|s| Selection::parse(s)).transpose()
}

/// Load every `--program <file.asm>` given on the command line through the
/// verify-gated loader, returning the registered handles in argv order.
/// Parse errors surface as `file:line:col: ...`, deny-level verifier
/// findings as the AMIxxx summary — never a panic or a silent skip.
fn load_programs(
    args: &cli::Args,
) -> Result<Vec<&'static amu_sim::session::LoadedProgram>, String> {
    args.get_all("program")
        .into_iter()
        .map(|p| amu_sim::session::programs::load_file(p).map_err(|e| e.to_string()))
        .collect()
}

fn cmd_run(argv: &[String]) -> Result<(), String> {
    let Some(args) = parse_cmd("amu-sim run", argv, RUN_SPECS)? else { return Ok(()) };
    let programs = load_programs(&args)?;
    // `--program x.asm` without `--bench` runs the loaded file; the
    // historical default (gups) only applies when nothing was loaded.
    let default_bench = programs.first().map(|p| p.name()).unwrap_or("gups");
    let bench = args.get_str("bench", default_bench);
    let config = args.get_str("config", "baseline");
    let latency = args.get_f64("latency-ns", 1000.0).map_err(|e| e.to_string())?;
    let scale = parse_scale(&args.get_str("scale", "test"))?;
    let mut cfg = SimConfig::preset(&config)
        .ok_or_else(|| format!("unknown config '{config}'"))?
        .with_far_latency_ns(latency);
    if let Some(path) = args.get("config-file") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = amu_sim::util::toml_lite::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_overrides(&doc)?;
    }
    cfg.fast_forward = !args.has_flag("no-fast-forward");
    let mut builder = RunRequest::bench(bench).config(cfg).scale(scale);
    if let Some(b) = args.get("backend") {
        builder = builder.backend(b);
    }
    if let Some(p) = args.get("pool-policy") {
        builder = builder.pool_policy(p);
    }
    if let Some(n) = parse_near_capacity(&args)? {
        builder = builder.near_capacity(n);
    }
    let columns = parse_columns(&args)?;
    match parse_variant_sel(&args.get_str("variant", "auto"))? {
        VariantSel::Auto => {}
        VariantSel::Fixed(v) => builder = builder.variant(v),
    }
    let req = builder.build().map_err(|e| e.to_string())?;
    let t0 = std::time::Instant::now();
    let r = req.run().map_err(|e| e.to_string())?;
    let host_ms = t0.elapsed().as_millis();
    if let Some(sel) = columns {
        // Machine-readable mode: the schema-selected CSV header + row.
        println!("{}", metrics::csv_header(&sel));
        println!("{}", metrics::csv_row(&r, &sel));
        return Ok(());
    }
    println!(
        "bench={} config={} backend={} variant={} latency={}ns",
        r.bench, r.config, r.backend, r.variant, r.latency_ns
    );
    println!(
        "  cycles(measured)={}  total={}  insts={}",
        r.measured_cycles, r.total_cycles, r.insts
    );
    println!(
        "  ipc={:.3}  mlp={:.2}  peak_inflight={}",
        r.ipc, r.mlp, r.peak_inflight
    );
    println!(
        "  energy: dynamic={:.2}uJ static={:.2}uJ  disambig={:.2}%  host={}ms",
        r.dynamic_uj,
        r.static_uj,
        r.disambig_frac * 100.0,
        host_ms
    );
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<(), String> {
    let Some(args) = parse_cmd("amu-sim sweep", argv, SWEEP_SPECS)? else { return Ok(()) };
    let scale = parse_scale(&args.get_str("scale", "test"))?;
    let programs = load_programs(&args)?;
    let mut grid = SweepGrid::paper(scale);
    if let Some(s) = args.get("benches") {
        grid.benches = split_list(s);
    } else if !programs.is_empty() {
        // `--program` without `--benches` sweeps just the loaded files
        // (sweeping the full built-in grid too would be surprising).
        grid.benches = programs.iter().map(|p| p.name().to_string()).collect();
    }
    if !programs.is_empty() {
        // Loaded programs that the grid actually sweeps refine the cache
        // fingerprint with their content hash: editing the .asm forks the
        // cache file instead of resurrecting stale rows.
        let swept: Vec<(String, u64)> = programs
            .iter()
            .filter(|p| grid.benches.iter().any(|b| b == p.name()))
            .map(|p| (p.name().to_string(), p.fingerprint()))
            .collect();
        grid = grid.programs(swept);
    }
    if let Some(s) = args.get("configs") {
        grid.configs = split_list(s);
    }
    if let Some(s) = args.get("latencies-ns") {
        let mut lats = Vec::new();
        for item in split_list(s) {
            lats.push(
                item.parse::<f64>()
                    .map_err(|_| format!("--latencies-ns: bad latency '{item}'"))?,
            );
        }
        grid.latencies_ns = lats;
    }
    grid.variants = vec![parse_variant_sel(&args.get_str("variant", "auto"))?];
    if let Some(s) = args.get("backend") {
        // Through the builder so alias spellings canonicalize (cache
        // fingerprints must not fork on `serial` vs `serial-link`).
        grid = grid.backends(split_list(s));
    }
    if let Some(p) = args.get("pool-policy") {
        // Also canonicalized in the builder; non-default policies refine
        // the fingerprint so they cache in their own file.
        grid = grid.pool_policy(p);
    }
    if let Some(n) = parse_near_capacity(&args)? {
        // A refinement like the pool policy: non-default capacities on
        // hybrid-sweeping grids get their own fingerprint and cache file.
        grid = grid.near_capacity(n);
    }
    // Host-speed only: folded statistics are byte-identical, so this never
    // enters the fingerprint and ff/non-ff runs share one cache entry.
    grid = grid.fast_forward(!args.has_flag("no-fast-forward"));
    // Validate the emission flags up front: a typo'd column name or a
    // stray --out must fail in milliseconds, not after a paper-scale sweep.
    let columns = parse_columns(&args)?;
    if columns.is_none() && args.get("out").is_some() {
        return Err("--out requires --columns".into());
    }

    let mut session = Session::new().quiet(args.has_flag("quiet"));
    if let Some(n) = parse_jobs(&args)? {
        session = session.jobs(n);
    }
    let cache_path = if args.has_flag("no-cache") {
        None
    } else {
        Some(match args.get("cache-file") {
            Some(p) => std::path::PathBuf::from(p),
            None => Session::default_cache_path(&grid),
        })
    };
    if let Some(p) = &cache_path {
        session = session.cache_path(p.clone());
    }

    let t0 = std::time::Instant::now();
    let rows = session.sweep(&grid).map_err(|e| e.to_string())?;
    let wall = t0.elapsed();
    // Only advertise the policy when it could affect a row (same condition
    // the fingerprint refinement uses) — a flag on a pool-less sweep is a
    // no-op and must not claim a scenario that didn't run.
    let mut policy_note = if grid.pool_policy == "hash" || !grid.sweeps_pooled() {
        String::new()
    } else {
        format!(" [pool-policy={}]", grid.pool_policy)
    };
    if grid.near_capacity_lines != 0 && grid.sweeps_hybrid() {
        policy_note.push_str(&format!(" [near-capacity={}]", grid.near_capacity_lines));
    }
    println!(
        "sweep: {} rows ({} benches x {} configs x {} latencies x {} variants x {} backends)\
         {} in {:.2?}",
        rows.len(),
        grid.benches.len(),
        grid.configs.len(),
        grid.latencies_ns.len(),
        grid.variants.len(),
        grid.backends.len(),
        policy_note,
        wall
    );
    match &cache_path {
        Some(p) => println!("csv: {}", p.display()),
        None => println!("csv: (not written; --no-cache)"),
    }
    // Schema-selected CSV emission (`--columns core|backend|all|<list>`):
    // to --out if given, else to stdout. Distinct from the cache file,
    // which always stores every schema column.
    if let Some(sel) = columns {
        let body = report::sweep_csv(&rows, &sel);
        match args.get("out") {
            Some(path) => {
                std::fs::write(path, &body).map_err(|e| format!("{path}: {e}"))?;
                println!("columns csv: {path}");
            }
            None => print!("{body}"),
        }
    }
    Ok(())
}

/// Shared between `mtrun` and `report mt`: tenant specs + base config +
/// policy list from the CLI flags, validated before any simulation.
fn build_mt_request(args: &cli::Args) -> Result<amu_sim::session::MtRequest, String> {
    use amu_sim::session::tenancy;
    let spec = args
        .get("tenants")
        .ok_or("--tenants is required (e.g. --tenants redis:2,bfs:1)")?;
    let tenants = tenancy::parse_tenants(spec).map_err(|e| e.to_string())?;
    let config = args.get_str("config", "amu");
    let latency = args.get_f64("latency-ns", 1000.0).map_err(|e| e.to_string())?;
    let mut cfg = SimConfig::preset(&config)
        .ok_or_else(|| format!("unknown config '{config}'"))?
        .with_far_latency_ns(latency);
    let backend = args.get_str("backend", "pooled");
    cfg.far.backend = amu_sim::config::FarBackendKind::parse(&backend)
        .ok_or_else(|| format!("unknown backend '{backend}'"))?;
    if let Some(path) = args.get("config-file") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = amu_sim::util::toml_lite::parse(&text).map_err(|e| e.to_string())?;
        cfg.apply_overrides(&doc)?;
    }
    cfg.fast_forward = !args.has_flag("no-fast-forward");
    let mut req = amu_sim::session::MtRequest::new(tenants, cfg);
    if let Some(s) = args.get("qos-policy") {
        req.policies = tenancy::parse_policies(s).map_err(|e| e.to_string())?;
    }
    req.scale = parse_scale(&args.get_str("scale", "test"))?;
    req.jobs = match parse_jobs(args)? {
        Some(n) => n,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    };
    req.quiet = args.has_flag("quiet");
    Ok(req)
}

fn cmd_mtrun(argv: &[String]) -> Result<(), String> {
    let Some(args) = parse_cmd("amu-sim mtrun", argv, MTRUN_SPECS)? else { return Ok(()) };
    let req = build_mt_request(&args)?;
    let t0 = std::time::Instant::now();
    let outcomes = req.run().map_err(|e| e.to_string())?;
    let wall = t0.elapsed();
    let body = amu_sim::session::tenancy::mt_csv(&req.tenants, req.scale, &outcomes);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &body).map_err(|e| format!("{path}: {e}"))?;
            let rows: usize = outcomes.iter().map(|o| o.rows.len()).sum();
            println!(
                "mtrun: {rows} tenant rows across {} QoS policies in {wall:.2?}",
                outcomes.len()
            );
            println!("csv: {path}");
        }
        None => print!("{body}"),
    }
    Ok(())
}

/// Simulator-throughput smoke benchmark: GUPS (at 1 µs and the paper's
/// 5 µs far latency) + BFS at the small test scale, each measured with
/// event-driven fast-forward on and off, reporting simulated cycles per
/// host-second and wall time. The two modes must produce identical
/// `total_cycles`/`insts` (the determinism contract); the ratio of their
/// `sim_cycles_per_host_s` is the fast-forward speedup.
fn cmd_bench(argv: &[String]) -> Result<(), String> {
    let Some(args) = parse_cmd("amu-sim bench", argv, BENCH_SPECS)? else { return Ok(()) };
    let quiet = args.has_flag("quiet");
    // `--no-fast-forward` restricts to the tick-by-tick entries (useful to
    // time the pure interpreter); by default both modes are measured.
    let modes: &[bool] = if args.has_flag("no-fast-forward") { &[false] } else { &[true, false] };
    let mut entries = Vec::new();
    for (b, latency_ns) in [("gups", 1000.0), ("gups", 5000.0), ("bfs", 1000.0)] {
        for &ff in modes {
            if !quiet {
                eprintln!(
                    "[bench] {b} (amu, test scale, {latency_ns}ns, fast_forward={ff}) ..."
                );
            }
            let mut cfg = SimConfig::amu();
            cfg.fast_forward = ff;
            let t0 = std::time::Instant::now();
            let r = RunRequest::bench(b)
                .config(cfg)
                .latency_ns(latency_ns)
                .scale(Scale::Test)
                .run()
                .map_err(|e| e.to_string())?;
            let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
            entries.push(format!(
                "    {{\"bench\": \"{b}\", \"latency_ns\": {latency_ns:.1}, \
                 \"fast_forward\": {ff}, \"total_cycles\": {}, \"insts\": {}, \
                 \"wall_ms\": {:.3}, \"sim_cycles_per_host_s\": {:.0}}}",
                r.total_cycles,
                r.insts,
                wall_s * 1e3,
                r.total_cycles as f64 / wall_s
            ));
        }
    }
    let json = format!(
        "{{\n  \"config\": \"amu\",\n  \"scale\": \"test\",\n  \
         \"workloads\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_PR9.json"),
    };
    std::fs::write(&out, &json).map_err(|e| format!("{}: {e}", out.display()))?;
    print!("{json}");
    eprintln!("[bench] wrote {}", out.display());
    Ok(())
}

/// `amu-sim check`: run the static verifier (`isa::verify`) over built-in
/// benchmark programs without simulating them, print the diagnostics
/// (as a table, JSON, or SARIF via `--format`), and exit nonzero on
/// deny-level findings (warn-level too under `--deny-warnings`).
fn cmd_check(argv: &[String]) -> Result<(), String> {
    use amu_sim::isa::Severity;
    use amu_sim::session::registry::{self, Workload};
    use amu_sim::workloads::{Variant, VariantKind};
    let Some(args) = parse_cmd("amu-sim check", argv, CHECK_SPECS)? else { return Ok(()) };
    let scale = parse_scale(&args.get_str("scale", "test"))?;
    let deny_warnings = args.has_flag("deny-warnings");
    let min = if args.has_flag("verbose") { Severity::Info } else { Severity::Warn };
    let format = args.get_str("format", "table");
    if !matches!(format.as_str(), "table" | "json" | "sarif") {
        return Err(format!("unknown format '{format}' (valid: table, json, sarif)"));
    }
    // `--program <file.asm>` verifies external files standalone: parsed
    // (typed file:line:col errors) but NOT registered or deny-gated — the
    // whole point of `check` is to see the full report, including the
    // findings that would refuse a `run`-path registration.
    let program_files = args.get_all("program");
    let mut outcomes = Vec::new();
    for path in &program_files {
        let (name, prog) = amu_sim::session::programs::parse_for_check(path)
            .map_err(|e| e.to_string())?;
        outcomes.push((format!("{name}/asm"), amu_sim::isa::verify(&prog)));
    }
    let benches: Vec<&'static dyn Workload> = match args.get("bench") {
        Some(name) => vec![registry::find_or_err(&name).map_err(|e| e.to_string())?],
        None if args.has_flag("all") => registry::REGISTRY.to_vec(),
        None if !program_files.is_empty() => Vec::new(),
        None => return Err("pass --bench <name>, --all, or --program <file.asm>".into()),
    };
    let variant_filter = match args.get("variant") {
        Some(s) => Some(s.parse::<Variant>()?),
        None => None,
    };
    // A representative variant per supported kind: verification depends on
    // program structure, which the payload parameters don't change.
    let representative = |kind: VariantKind| match kind {
        VariantKind::Sync => Variant::Sync,
        VariantKind::Amu => Variant::Amu,
        VariantKind::AmuLlvm => Variant::AmuLlvm,
        VariantKind::GroupPrefetch => Variant::GroupPrefetch(16),
        VariantKind::SwPrefetch => Variant::SwPrefetch { batch: 16, depth: 2 },
    };
    for w in &benches {
        let variants: Vec<Variant> = match variant_filter {
            Some(v) => {
                if !w.supported_variants().contains(&v.kind()) {
                    if benches.len() == 1 {
                        return Err(format!(
                            "benchmark '{}' does not support variant '{}'",
                            w.name(),
                            v.tag()
                        ));
                    }
                    continue; // --all with a filter: skip non-implementers
                }
                vec![v]
            }
            None => w.supported_variants().iter().map(|k| representative(*k)).collect(),
        };
        for v in variants {
            // AMU programs are built against the AMU preset (queue sizing,
            // SPM budget); everything else against the baseline.
            let cfg = match v.kind() {
                VariantKind::Amu | VariantKind::AmuLlvm => SimConfig::amu(),
                _ => SimConfig::baseline(),
            };
            let spec = w.build(&cfg, v, scale);
            outcomes.push((format!("{}/{}", w.name(), v.tag()), spec.verify()));
        }
    }
    match format.as_str() {
        "json" => print!("{}", report::check_json(&outcomes)),
        "sarif" => print!("{}", report::check_sarif(&outcomes)),
        _ => print!("{}", report::check_table(&outcomes, min)),
    }
    let deny: usize = outcomes.iter().map(|(_, r)| r.deny_count()).sum();
    let warn: usize = outcomes.iter().map(|(_, r)| r.warn_count()).sum();
    if deny > 0 || (deny_warnings && warn > 0) {
        return Err(format!(
            "check failed: {deny} deny-level and {warn} warn-level finding(s){}",
            if deny_warnings { " (--deny-warnings)" } else { "" }
        ));
    }
    Ok(())
}

/// `amu-sim disasm`: emit a benchmark's program in the text assembly
/// format (the `isa::parse` grammar — the output reassembles to an
/// identical `Program`). Works for built-ins (`--bench`, optionally
/// `--variant`/`--scale` to pick the concrete instance) and for loaded
/// `.asm` files (`--program`), which round-trips the canonical form.
fn cmd_disasm(argv: &[String]) -> Result<(), String> {
    use amu_sim::session::registry;
    use amu_sim::workloads::{Variant, VariantKind};
    let Some(args) = parse_cmd("amu-sim disasm", argv, DISASM_SPECS)? else { return Ok(()) };
    let scale = parse_scale(&args.get_str("scale", "test"))?;
    let programs = load_programs(&args)?;
    let bench = match args.get("bench") {
        Some(b) => b.to_string(),
        None => match programs.first() {
            Some(p) => p.name().to_string(),
            None => return Err("pass --bench <name> or --program <file.asm>".into()),
        },
    };
    let w = registry::find_or_err(&bench).map_err(|e| e.to_string())?;
    // AMI-only programs don't implement sync: default to the first
    // variant the benchmark actually supports.
    let default_variant =
        if w.supported_variants().contains(&VariantKind::Sync) { "sync" } else { "amu" };
    let v: Variant = args.get_str("variant", default_variant).parse()?;
    if !w.supported_variants().contains(&v.kind()) {
        return Err(format!(
            "benchmark '{}' does not support variant '{}'",
            w.name(),
            v.tag()
        ));
    }
    let cfg = match v.kind() {
        VariantKind::Amu | VariantKind::AmuLlvm => SimConfig::amu(),
        _ => SimConfig::baseline(),
    };
    let spec = w.build(&cfg, v, scale);
    let text = amu_sim::isa::disasm(&spec.prog);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("[disasm] wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<(), String> {
    // `--help` may come before the report kind, so scan the full argv here
    // (parse_cmd would only see the tail after the positional).
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", cli::usage("amu-sim report <kind>", REPORT_SPECS));
        return Ok(());
    }
    let args =
        cli::parse(argv.get(1..).unwrap_or(&[]), REPORT_SPECS).map_err(|e| e.to_string())?;
    let what = argv.first().map(|s| s.as_str()).unwrap_or("all");
    let scale = parse_scale(&args.get_str("scale", "paper"))?;
    let quiet = args.has_flag("quiet");
    let mut session = Session::new().quiet(quiet);
    if let Some(n) = parse_jobs(&args)? {
        session = session.jobs(n);
    }
    // Validate the column selection before any simulation: a typo'd
    // column name must not cost a paper-scale sweep. Only `report sweep`
    // emits selected columns — reject the flag elsewhere rather than
    // silently ignoring it.
    let columns_arg = parse_columns(&args)?;
    if columns_arg.is_some() && what != "sweep" {
        return Err(format!(
            "--columns only applies to `report sweep`, not `report {what}`"
        ));
    }
    let sweep_sel = columns_arg.unwrap_or(Selection::Core);
    // `report mt` is the multi-tenant fairness table — it simulates its
    // own tenant cells (no paper sweep) and reads the mtrun flags
    // (`--tenants`, `--qos-policy`, ...; scale defaults to `test` inside
    // `build_mt_request`, since a tenant cell is one shared pool, not the
    // 264-row paper grid).
    if what == "mt" {
        let req = build_mt_request(&args)?;
        let outcomes = req.run().map_err(|e| e.to_string())?;
        report::write_report("mt", &report::mt_table(&outcomes));
        return Ok(());
    }
    let needs_sweep = matches!(
        what,
        "fig2" | "fig8" | "fig9" | "fig10" | "fig11" | "headline" | "sweep" | "all"
    );
    let rows = if needs_sweep {
        let mut grid = SweepGrid::paper(scale);
        if let Some(b) = args.get("backend") {
            grid = grid.backend(b);
        }
        if let Some(p) = args.get("pool-policy") {
            grid = grid.pool_policy(p);
        }
        if let Some(n) = parse_near_capacity(&args)? {
            grid = grid.near_capacity(n);
        }
        grid = grid.fast_forward(!args.has_flag("no-fast-forward"));
        session.sweep_default_cached(&grid).map_err(|e| e.to_string())?
    } else {
        Vec::new()
    };
    let emit = |name: &str, body: String| report::write_report(name, &body);
    match what {
        "sweep" => {
            // Schema-driven row dump with a column selection (default:
            // the historical core layout).
            let body = report::sweep_csv(&rows, &sweep_sel);
            let path = report::results_dir().join("sweep_columns.csv");
            std::fs::write(&path, &body).map_err(|e| format!("{}: {e}", path.display()))?;
            print!("{body}");
            eprintln!("[report] wrote {}", path.display());
        }
        "fig2" => emit("fig2", report::fig2(&rows)),
        "fig3" => emit("fig3", report::fig3(&session, scale, 1000.0)),
        "fig8" => emit("fig8", report::fig8(&rows)),
        "fig9" => emit("fig9", report::fig9(&rows)),
        "fig10" => emit("fig10", report::fig10(&rows)),
        "fig11" => emit("fig11", report::fig11(&rows)),
        "table4" => emit("table4", report::table4(&session, scale)),
        "table5" => emit("table5", report::table5(&session, scale)),
        "table6" => emit("table6", report::table6()),
        "headline" => emit("headline", report::headline(&rows)),
        "all" => {
            emit("fig2", report::fig2(&rows));
            emit("fig3", report::fig3(&session, scale, 1000.0));
            emit("fig8", report::fig8(&rows));
            emit("fig9", report::fig9(&rows));
            emit("fig10", report::fig10(&rows));
            emit("fig11", report::fig11(&rows));
            emit("table4", report::table4(&session, scale));
            emit("table5", report::table5(&session, scale));
            emit("table6", report::table6());
            emit("headline", report::headline(&rows));
        }
        other => return Err(format!("unknown report '{other}'")),
    }
    Ok(())
}

fn cmd_payload() -> Result<(), String> {
    let rt = amu_sim::runtime::Runtime::load_default().map_err(|e| e.to_string())?;
    println!("payload engine on platform={}", rt.platform());
    let vals: Vec<i32> = (0..amu_sim::runtime::GUPS_BATCH as i32).collect();
    let idxs: Vec<i32> = (0..amu_sim::runtime::GUPS_BATCH as i32).rev().collect();
    let out = rt.gups_update(&vals, &idxs).map_err(|e| e.to_string())?;
    let ok = out
        .iter()
        .zip(vals.iter().zip(idxs.iter()))
        .all(|(o, (v, i))| *o == v ^ i);
    println!("gups_update[{}] check: {}", out.len(), if ok { "OK" } else { "MISMATCH" });
    if !ok {
        return Err("payload engine mismatch".into());
    }
    Ok(())
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let result = match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("sweep") => cmd_sweep(&argv[1..]),
        Some("mtrun") => cmd_mtrun(&argv[1..]),
        Some("bench") => cmd_bench(&argv[1..]),
        Some("check") => cmd_check(&argv[1..]),
        Some("disasm") => cmd_disasm(&argv[1..]),
        Some("report") => cmd_report(&argv[1..]),
        Some("payload") => cmd_payload(),
        Some("list") => {
            println!("benchmarks: {}", workloads::ALL.join(" "));
            println!("configs:    {}", SimConfig::preset_names().join(" "));
            println!(
                "backends:   {}",
                amu_sim::config::FarBackendKind::names().join(" ")
            );
            println!(
                "pool-policies: {}",
                amu_sim::config::PoolPolicy::names().join(" ")
            );
            println!(
                "qos-policies: {}",
                amu_sim::config::QosPolicyKind::names().join(" ")
            );
            println!("columns (schema v5, --columns core|backend|all|<comma-list>):");
            for c in metrics::columns() {
                let unit = if c.unit().is_empty() { "-" } else { c.unit() };
                let group = format!("{:?}", c.group()).to_lowercase();
                println!("  {:<16} {:<9} unit={}", c.name(), group, unit);
            }
            Ok(())
        }
        _ => {
            eprintln!("amu-sim {} — AMU paper reproduction", amu_sim::version());
            eprintln!(
                "usage: amu-sim <run|sweep|mtrun|bench|check|disasm|report|payload|list> [options]"
            );
            eprintln!("(every subcommand also accepts --help)");
            eprintln!("{}", cli::usage("amu-sim run", RUN_SPECS));
            eprintln!("{}", cli::usage("amu-sim sweep", SWEEP_SPECS));
            eprintln!("{}", cli::usage("amu-sim mtrun", MTRUN_SPECS));
            eprintln!("{}", cli::usage("amu-sim bench", BENCH_SPECS));
            eprintln!("{}", cli::usage("amu-sim check", CHECK_SPECS));
            eprintln!("{}", cli::usage("amu-sim disasm", DISASM_SPECS));
            eprintln!("{}", cli::usage("amu-sim report <kind>", REPORT_SPECS));
            eprintln!(
                "reports: fig2 fig3 fig8 fig9 fig10 fig11 table4 table5 table6 headline sweep \
                 mt all"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
