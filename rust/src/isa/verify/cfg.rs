//! Control-flow graph over instruction indices, with typed edges so the
//! dataflow can refine branch conditions per successor, and a backward
//! "can a `getfin` still run" reachability used by the id-leak check.

use crate::isa::inst::{Opcode, Program};

/// How a successor edge is taken — drives interval refinement of the
/// branch operands along that edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum EdgeKind {
    /// The branch at the end of the source block was taken.
    Taken,
    /// The branch fell through.
    Fall,
    /// Unconditional flow (plain fallthrough, `jal`, `jalr`).
    Other,
}

pub(super) struct Cfg {
    /// Basic blocks as `[start, end)` instruction ranges, in index order.
    pub blocks: Vec<(usize, usize)>,
    /// Instruction index -> block id.
    pub block_of: Vec<usize>,
    /// Block id -> successor (block id, edge kind) pairs.
    pub succs: Vec<Vec<(usize, EdgeKind)>>,
    /// Block reachability from entry.
    pub reachable: Vec<bool>,
    /// Block contains a `getfin` or can reach a block that does.
    getfin_ahead: Vec<bool>,
}

pub(super) fn valid_target(imm: i64, len: usize) -> Option<usize> {
    if imm >= 0 && (imm as usize) < len {
        Some(imm as usize)
    } else {
        None
    }
}

pub(super) fn is_terminator(op: Opcode) -> bool {
    matches!(op, Opcode::Halt | Opcode::Jal | Opcode::Jalr)
}

impl Cfg {
    /// Build the CFG. Indirect jumps (`jalr`) target the program's
    /// address-taken set: labels whose index was materialized into a
    /// register (`li_label` continuations, `Asm::mark_addr_taken` for
    /// host-injected resume pointers) plus call-return sites (the
    /// instruction after a `jal` with a live link register — `ret` jumps
    /// there). Programs with no address-taken info (hand-built raw
    /// `Program`s) fall back to the legacy over-approximation: every
    /// label is a potential indirect target.
    pub fn build(prog: &Program) -> Cfg {
        let len = prog.len();
        let insts = &prog.insts;
        let mut indirect: Vec<usize> =
            prog.addr_taken.iter().copied().filter(|&at| at < len).collect();
        if indirect.is_empty() {
            indirect = prog.labels.iter().map(|(_, at)| *at).filter(|at| *at < len).collect();
        }
        for (i, inst) in insts.iter().enumerate() {
            if inst.op == Opcode::Jal && inst.rd != 0 && i + 1 < len {
                indirect.push(i + 1);
            }
        }
        indirect.sort_unstable();
        indirect.dedup();

        // Leaders.
        let mut leader = vec![false; len];
        if len > 0 {
            leader[0] = true;
        }
        for &at in &indirect {
            leader[at] = true;
        }
        for (i, inst) in insts.iter().enumerate() {
            if inst.is_branch() || is_terminator(inst.op) {
                if i + 1 < len {
                    leader[i + 1] = true;
                }
                if inst.op != Opcode::Jalr {
                    if let Some(t) = valid_target(inst.imm, len) {
                        leader[t] = true;
                    }
                }
            }
        }

        let mut blocks = Vec::new();
        let mut block_of = vec![0usize; len];
        let mut start = 0;
        for i in 0..len {
            if i > 0 && leader[i] {
                blocks.push((start, i));
                start = i;
            }
        }
        if len > 0 {
            blocks.push((start, len));
        }
        for (b, &(s, e)) in blocks.iter().enumerate() {
            for i in s..e {
                block_of[i] = b;
            }
        }

        let indirect_blocks: Vec<usize> = indirect.iter().map(|&at| block_of[at]).collect();
        let mut succs: Vec<Vec<(usize, EdgeKind)>> = vec![Vec::new(); blocks.len()];
        for (b, &(_, e)) in blocks.iter().enumerate() {
            let last = e - 1;
            let inst = &insts[last];
            let mut out: Vec<(usize, EdgeKind)> = Vec::new();
            match inst.op {
                Opcode::Halt => {}
                Opcode::Jal => {
                    if let Some(t) = valid_target(inst.imm, len) {
                        out.push((block_of[t], EdgeKind::Other));
                    }
                }
                Opcode::Jalr => {
                    out.extend(indirect_blocks.iter().map(|&t| (t, EdgeKind::Other)));
                }
                Opcode::Beq | Opcode::Bne | Opcode::Blt | Opcode::Bge | Opcode::BltU => {
                    if let Some(t) = valid_target(inst.imm, len) {
                        out.push((block_of[t], EdgeKind::Taken));
                    }
                    if last + 1 < len {
                        out.push((block_of[last + 1], EdgeKind::Fall));
                    }
                }
                _ => {
                    if last + 1 < len {
                        out.push((block_of[last + 1], EdgeKind::Other));
                    }
                }
            }
            out.sort_unstable_by_key(|&(t, k)| (t, k as u8));
            out.dedup();
            succs[b] = out;
        }

        // Reachability from entry.
        let mut reachable = vec![false; blocks.len()];
        if !blocks.is_empty() {
            let mut stack = vec![0usize];
            reachable[0] = true;
            while let Some(b) = stack.pop() {
                for &(s, _) in &succs[b] {
                    if !reachable[s] {
                        reachable[s] = true;
                        stack.push(s);
                    }
                }
            }
        }

        // Backward: can a getfin still execute at-or-after each block?
        let mut getfin_ahead: Vec<bool> = blocks
            .iter()
            .map(|&(s, e)| insts[s..e].iter().any(|i| i.op == Opcode::GetFin))
            .collect();
        let mut changed = true;
        while changed {
            changed = false;
            for b in 0..blocks.len() {
                if !getfin_ahead[b] && succs[b].iter().any(|&(s, _)| getfin_ahead[s]) {
                    getfin_ahead[b] = true;
                    changed = true;
                }
            }
        }

        Cfg { blocks, block_of, succs, reachable, getfin_ahead }
    }

    /// Can any `getfin` execute strictly after instruction `at`?
    pub fn getfin_reachable_after(&self, prog: &Program, at: usize) -> bool {
        let b = self.block_of[at];
        let (_, e) = self.blocks[b];
        if prog.insts[at + 1..e].iter().any(|i| i.op == Opcode::GetFin) {
            return true;
        }
        self.succs[b].iter().any(|&(s, _)| self.getfin_ahead[s])
    }
}
