//! The interval abstract domain and the fused forward dataflow state.
//!
//! Registers (and the three AMI configuration registers) are tracked as
//! unsigned intervals `[lo, hi]` (inclusive). Singletons are evaluated
//! exactly with wrapping arithmetic — bit-compatible with the old
//! constant-propagation lattice — while non-singleton intervals use
//! checked bound arithmetic and fall to `TOP` on any possible overflow,
//! so bounds are always sound. Joins take the convex hull; loop heads are
//! widened (lo -> 0, hi -> u64::MAX per moving bound) after a bounded
//! number of changed joins, which makes the fixpoint terminate on
//! arbitrary programs (property-tested in `rust/tests/verify.rs`).

use super::lifetime::HandleState;
use crate::isa::inst::NUM_ARCH_REGS;

/// An unsigned interval `[lo, hi]`, inclusive on both ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct Ival {
    pub lo: u64,
    pub hi: u64,
}

impl Ival {
    pub const TOP: Ival = Ival { lo: 0, hi: u64::MAX };

    pub fn singleton(v: u64) -> Ival {
        Ival { lo: v, hi: v }
    }

    pub fn is_top(self) -> bool {
        self == Ival::TOP
    }

    /// The single value this interval holds, if it holds exactly one.
    pub fn as_const(self) -> Option<u64> {
        if self.lo == self.hi {
            Some(self.lo)
        } else {
            None
        }
    }

    /// Convex hull (the interval join).
    pub fn join(self, other: Ival) -> Ival {
        Ival { lo: self.lo.min(other.lo), hi: self.hi.max(other.hi) }
    }

    /// Do the two (inclusive) intervals share at least one value?
    pub fn overlaps(self, other: Ival) -> bool {
        self.lo.max(other.lo) <= self.hi.min(other.hi)
    }

    /// Exact binary op, defined only when both sides are singletons
    /// (xor/or and other non-monotone ops).
    pub fn bin_exact(self, other: Ival, f: impl Fn(u64, u64) -> u64) -> Ival {
        match (self.as_const(), other.as_const()) {
            (Some(a), Some(b)) => Ival::singleton(f(a, b)),
            _ => Ival::TOP,
        }
    }

    pub fn add(self, other: Ival) -> Ival {
        if let (Some(a), Some(b)) = (self.as_const(), other.as_const()) {
            return Ival::singleton(a.wrapping_add(b));
        }
        match (self.lo.checked_add(other.lo), self.hi.checked_add(other.hi)) {
            (Some(lo), Some(hi)) => Ival { lo, hi },
            _ => Ival::TOP,
        }
    }

    /// `self + imm` with a signed immediate (the `addi`/address-offset
    /// shape); singletons wrap exactly.
    pub fn add_imm(self, imm: i64) -> Ival {
        if let Some(a) = self.as_const() {
            return Ival::singleton(a.wrapping_add(imm as u64));
        }
        if imm >= 0 {
            self.add(Ival::singleton(imm as u64))
        } else {
            self.sub(Ival::singleton(imm.unsigned_abs()))
        }
    }

    pub fn sub(self, other: Ival) -> Ival {
        if let (Some(a), Some(b)) = (self.as_const(), other.as_const()) {
            return Ival::singleton(a.wrapping_sub(b));
        }
        if self.lo >= other.hi {
            Ival { lo: self.lo - other.hi, hi: self.hi - other.lo }
        } else {
            Ival::TOP
        }
    }

    pub fn mul(self, other: Ival) -> Ival {
        if let (Some(a), Some(b)) = (self.as_const(), other.as_const()) {
            return Ival::singleton(a.wrapping_mul(b));
        }
        match (self.lo.checked_mul(other.lo), self.hi.checked_mul(other.hi)) {
            (Some(lo), Some(hi)) => Ival { lo, hi },
            _ => Ival::TOP,
        }
    }

    /// `self & mask` for a constant mask: the result is at most both the
    /// mask and the original upper bound.
    pub fn and_mask(self, mask: u64) -> Ival {
        if let Some(a) = self.as_const() {
            return Ival::singleton(a & mask);
        }
        Ival { lo: 0, hi: self.hi.min(mask) }
    }

    pub fn and(self, other: Ival) -> Ival {
        match (self.as_const(), other.as_const()) {
            (Some(a), Some(b)) => Ival::singleton(a & b),
            (Some(m), None) => other.and_mask(m),
            (None, Some(m)) => self.and_mask(m),
            (None, None) => Ival { lo: 0, hi: self.hi.min(other.hi) },
        }
    }

    pub fn shl_const(self, sh: u32) -> Ival {
        if let Some(a) = self.as_const() {
            return Ival::singleton(a.wrapping_shl(sh));
        }
        // Sound only if the top bound shifts without losing bits.
        if self.hi.leading_zeros() >= sh {
            Ival { lo: self.lo << sh, hi: self.hi << sh }
        } else {
            Ival::TOP
        }
    }

    pub fn shr_const(self, sh: u32) -> Ival {
        if let Some(a) = self.as_const() {
            return Ival::singleton(a.wrapping_shr(sh));
        }
        Ival { lo: self.lo >> sh, hi: self.hi >> sh }
    }

    /// Dynamic shift: exact when the amount is a singleton.
    pub fn shl_dyn(self, amount: Ival) -> Ival {
        match amount.as_const() {
            Some(sh) => self.shl_const(sh as u32 & 63),
            None => Ival::TOP,
        }
    }

    pub fn shr_dyn(self, amount: Ival) -> Ival {
        match amount.as_const() {
            Some(sh) => self.shr_const(sh as u32 & 63),
            None => Ival::TOP,
        }
    }

    pub fn sltu(self, other: Ival) -> Ival {
        if self.hi < other.lo {
            Ival::singleton(1)
        } else if self.lo >= other.hi {
            Ival::singleton(0)
        } else {
            Ival { lo: 0, hi: 1 }
        }
    }
}

/// Joined forward dataflow state at a program point. All components are
/// may-facts (join = union / convex hull), so one fixpoint serves every
/// check; the "queue configuration dominates" must-fact is encoded as its
/// dual (`queue_unconfig`: the configuration *may not* have executed yet),
/// and request lifetimes carry a three-point must/may lattice per issue
/// site (see `lifetime`).
#[derive(Clone, PartialEq)]
pub(super) struct State {
    /// Bit r set: register r may not have been written yet.
    pub uninit: u64,
    /// Queue configuration (`cfgwr QueueBase/QueueLength`) may not have
    /// executed on some path to this point.
    pub queue_unconfig: bool,
    /// An async request may have been issued.
    pub issued: bool,
    /// The ROI window may be open / may be closed here.
    pub roi_in: bool,
    pub roi_out: bool,
    /// A constant-address sync far access may have happened since the
    /// last `flush`.
    pub far_dirty: bool,
    pub regs: [Ival; NUM_ARCH_REGS],
    /// Value intervals of the three AMI configuration registers.
    pub cfg: [Ival; 3],
    /// One abstract request handle per static issue site, indexed like
    /// `Verifier::issue_sites`.
    pub handles: Vec<HandleState>,
}

impl State {
    pub fn entry(nhandles: usize) -> State {
        State {
            uninit: !1u64, // every register but hardwired r0
            queue_unconfig: true,
            issued: false,
            roi_in: false,
            roi_out: true,
            far_dirty: false,
            // Architectural reset state: all registers read as zero.
            regs: [Ival::singleton(0); NUM_ARCH_REGS],
            cfg: [Ival::TOP; 3],
            handles: vec![HandleState::bot(); nhandles],
        }
    }

    pub fn join(&mut self, other: &State) -> bool {
        let before = self.clone();
        self.uninit |= other.uninit;
        self.queue_unconfig |= other.queue_unconfig;
        self.issued |= other.issued;
        self.roi_in |= other.roi_in;
        self.roi_out |= other.roi_out;
        self.far_dirty |= other.far_dirty;
        for (a, b) in self.regs.iter_mut().zip(other.regs.iter()) {
            *a = a.join(*b);
        }
        for (a, b) in self.cfg.iter_mut().zip(other.cfg.iter()) {
            *a = a.join(*b);
        }
        for (a, b) in self.handles.iter_mut().zip(other.handles.iter()) {
            *a = a.join(*b);
        }
        *self != before
    }

    /// Widen every interval bound that moved since `prev` to its domain
    /// extreme. Applied at join points after `WIDEN_AFTER` changed joins;
    /// together with the monotone bit/tri-state components this bounds
    /// the number of state changes per block, so the fixpoint terminates.
    pub fn widen(&mut self, prev: &State) {
        fn w(cur: &mut Ival, prev: Ival) {
            if cur.lo < prev.lo {
                cur.lo = 0;
            }
            if cur.hi > prev.hi {
                cur.hi = u64::MAX;
            }
        }
        for (c, p) in self.regs.iter_mut().zip(prev.regs.iter()) {
            w(c, *p);
        }
        for (c, p) in self.cfg.iter_mut().zip(prev.cfg.iter()) {
            w(c, *p);
        }
        for (c, p) in self.handles.iter_mut().zip(prev.handles.iter()) {
            w(&mut c.region, p.region);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn singleton_arithmetic_is_exact_and_wrapping() {
        let a = Ival::singleton(u64::MAX);
        assert_eq!(a.add_imm(1), Ival::singleton(0));
        assert_eq!(a.add(Ival::singleton(2)), Ival::singleton(1));
        assert_eq!(Ival::singleton(3).mul(Ival::singleton(4)), Ival::singleton(12));
    }

    #[test]
    fn nonsingleton_overflow_goes_top() {
        let a = Ival { lo: 1, hi: u64::MAX };
        assert!(a.add(Ival { lo: 0, hi: 1 }).is_top());
        assert!(a.shl_const(1).is_top());
    }

    #[test]
    fn bounded_ops_stay_bounded() {
        let a = Ival { lo: 0, hi: 3 };
        assert_eq!(a.shl_const(6), Ival { lo: 0, hi: 192 });
        assert_eq!(a.add_imm(16), Ival { lo: 16, hi: 19 });
        assert_eq!(a.and_mask(2), Ival { lo: 0, hi: 2 });
        assert_eq!(Ival { lo: 8, hi: 24 }.sub(Ival { lo: 1, hi: 4 }), Ival { lo: 4, hi: 23 });
    }

    #[test]
    fn sltu_decides_when_ranges_separate() {
        assert_eq!(Ival { lo: 0, hi: 3 }.sltu(Ival::singleton(5)), Ival::singleton(1));
        assert_eq!(Ival { lo: 9, hi: 12 }.sltu(Ival { lo: 0, hi: 4 }), Ival::singleton(0));
        assert_eq!(Ival { lo: 0, hi: 9 }.sltu(Ival::singleton(5)), Ival { lo: 0, hi: 1 });
    }

    #[test]
    fn widen_moves_only_changed_bounds() {
        let mut st = State::entry(1);
        let prev = st.clone();
        st.regs[5] = Ival { lo: 0, hi: 7 };
        st.widen(&prev);
        assert_eq!(st.regs[5], Ival { lo: 0, hi: u64::MAX });
        assert_eq!(st.regs[6], Ival::singleton(0));
    }
}
