//! The request-lifetime domain: one abstract handle per static
//! `aload`/`astore` issue site.
//!
//! A handle tracks whether its site's most recent request is in flight on
//! every path (`Must`), on some path (`Maybe`), or was never issued
//! (`Bot`); which registers may still hold the request id (a bitmask,
//! propagated through `mv`-shaped copies and intersected at joins); and
//! the interval of the request's SPM target region. `getfin` demotes
//! every `Must` handle to `Maybe` — after one drain poll the *specific*
//! request that completed is unknown, so only never-polled requests
//! support the deny-level use-before-completion race checks (AMI016/017).
//! Re-issuing through the same site is a strong update: the handle state
//! is replaced wholesale.

use super::domain::Ival;
use crate::isa::mem::{SPM_BASE, SPM_END};

/// Three-point lattice for "this site's request is in flight here".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum Tri {
    /// The site has not issued on any path to this point.
    Bot,
    /// In flight on every path to this point.
    Must,
    /// In flight on some path (or already drained on some path).
    Maybe,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct HandleState {
    pub st: Tri,
    /// Bit r set: register r may still hold this request's id on every
    /// path (intersected at joins: a must-fact, so AMI019 never fires on
    /// a path that actually kept a copy).
    pub ids: u64,
    /// Interval of the request's SPM target region (inclusive bytes).
    pub region: Ival,
}

impl HandleState {
    pub fn bot() -> HandleState {
        HandleState { st: Tri::Bot, ids: 0, region: Ival::TOP }
    }

    pub fn join(self, other: HandleState) -> HandleState {
        match (self.st, other.st) {
            (Tri::Bot, _) => other,
            (_, Tri::Bot) => self,
            (a, b) => HandleState {
                st: if a == b { a } else { Tri::Maybe },
                ids: self.ids & other.ids,
                region: self.region.join(other.region),
            },
        }
    }
}

/// Inclusive byte interval of a request's SPM target: the operand
/// interval extended by the transfer granularity.
pub(super) fn target_region(spm: Ival, granularity: u64) -> Ival {
    let g = granularity.max(1);
    Ival { lo: spm.lo, hi: spm.hi.saturating_add(g - 1) }
}

/// Is the whole (inclusive) interval inside the scratchpad? Widened/TOP
/// intervals fail this, which keeps the race checks silent wherever the
/// SPM slot address flows in from memory (every coroutine workload).
pub(super) fn within_spm(v: Ival) -> bool {
    v.lo >= SPM_BASE && v.hi < SPM_END
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_is_must_only_when_both_must() {
        let must = HandleState { st: Tri::Must, ids: 0b110, region: Ival::singleton(SPM_BASE) };
        let bot = HandleState::bot();
        assert_eq!(must.join(bot), must);
        assert_eq!(bot.join(must), must);
        let other =
            HandleState { st: Tri::Must, ids: 0b100, region: Ival::singleton(SPM_BASE + 64) };
        let j = must.join(other);
        assert_eq!(j.st, Tri::Must);
        assert_eq!(j.ids, 0b100);
        assert_eq!(j.region, Ival { lo: SPM_BASE, hi: SPM_BASE + 64 });
        let maybe = HandleState { st: Tri::Maybe, ..other };
        assert_eq!(must.join(maybe).st, Tri::Maybe);
    }

    #[test]
    fn spm_containment_rejects_top_and_partial() {
        assert!(within_spm(Ival { lo: SPM_BASE, hi: SPM_BASE + 63 }));
        assert!(!within_spm(Ival::TOP));
        assert!(!within_spm(Ival { lo: SPM_BASE - 1, hi: SPM_BASE }));
        assert_eq!(
            target_region(Ival::singleton(SPM_BASE), 64),
            Ival { lo: SPM_BASE, hi: SPM_BASE + 63 }
        );
    }
}
