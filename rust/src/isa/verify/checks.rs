//! The verifier proper: structural checks, the fused forward dataflow
//! fixpoint (intervals + protocol bits + request lifetimes), branch-edge
//! interval refinement, and the collection pass that emits diagnostics.

use super::cfg::{is_terminator, valid_target, Cfg, EdgeKind};
use super::diag::{Code, Diagnostic, Report};
use super::domain::{Ival, State};
use super::lifetime::{target_region, within_spm, HandleState, Tri};
use crate::isa::inst::{CfgReg, Inst, Opcode, Program};
use crate::isa::mem::{region_of, MemRegion};

/// Changed joins tolerated at a block before its moving interval bounds
/// are widened to the domain extremes. Large enough that short counted
/// loops (in-flight windows, queue sizing) converge to exact bounds
/// first; small enough to bound the fixpoint on adversarial programs.
const WIDEN_AFTER: usize = 12;

pub(super) struct Verifier<'p> {
    prog: &'p Program,
    cfg: Cfg,
    /// Does any reachable instruction configure the queue? (If none does,
    /// the hardware reset defaults apply and AMI007 stays silent.)
    has_queue_cfg: bool,
    /// Instruction index of each static issue site; `State::handles` is
    /// indexed in parallel.
    issue_sites: Vec<usize>,
    /// Instruction index -> issue-site index.
    site_index: Vec<Option<usize>>,
    fixpoint_iters: usize,
    diags: Vec<Diagnostic>,
}

/// Run the full static-analysis pass over an assembled program.
pub(super) fn analyze(prog: &Program) -> Report {
    let cfg = Cfg::build(prog);
    let mut issue_sites = Vec::new();
    let mut site_index = vec![None; prog.len()];
    for (i, inst) in prog.insts.iter().enumerate() {
        if matches!(inst.op, Opcode::ALoad | Opcode::AStore) {
            site_index[i] = Some(issue_sites.len());
            issue_sites.push(i);
        }
    }
    let mut v = Verifier {
        prog,
        cfg,
        has_queue_cfg: false,
        issue_sites,
        site_index,
        fixpoint_iters: 0,
        diags: Vec::new(),
    };
    v.run();
    let mut diags = v.diags;
    diags.sort_by(|a, b| (a.at, a.code).cmp(&(b.at, b.code)));
    diags.dedup();
    Report {
        program: prog.name.clone(),
        insts: prog.len(),
        diags,
        fixpoint_iters: v.fixpoint_iters,
    }
}

impl<'p> Verifier<'p> {
    fn label_at(&self, at: usize) -> String {
        self.prog
            .labels
            .iter()
            .filter(|(_, l)| *l <= at)
            .max_by_key(|(_, l)| *l)
            .map(|(n, _)| n.clone())
            .unwrap_or_default()
    }

    fn emit(&mut self, code: Code, at: usize, message: String) {
        let label = self.label_at(at);
        self.diags.push(Diagnostic { code, at, label, message });
    }

    fn inst_reachable(&self, at: usize) -> bool {
        self.cfg.reachable[self.cfg.block_of[at]]
    }

    fn run(&mut self) {
        let len = self.prog.len();
        if len == 0 {
            self.diags.push(Diagnostic {
                code: Code::FallsOffEnd,
                at: 0,
                label: String::new(),
                message: "program is empty".into(),
            });
            return;
        }
        self.structural();
        self.has_queue_cfg = self.prog.insts.iter().enumerate().any(|(i, inst)| {
            inst.op == Opcode::CfgWr
                && matches!(
                    CfgReg::from_imm(inst.imm),
                    Some(CfgReg::QueueBase) | Some(CfgReg::QueueLength)
                )
                && self.inst_reachable(i)
        });
        self.dataflow();
        self.issue_drain_balance();
    }

    /// Structural checks: bad targets, fall-through off the end,
    /// unreachable instruction runs.
    fn structural(&mut self) {
        let len = self.prog.len();
        for (i, inst) in self.prog.insts.iter().enumerate() {
            let targets = inst.is_branch() && inst.op != Opcode::Jalr;
            if targets && valid_target(inst.imm, len).is_none() {
                self.emit(
                    Code::BadTarget,
                    i,
                    format!(
                        "{:?} target {} outside the program (length {len})",
                        inst.op, inst.imm
                    ),
                );
            }
        }
        // Fall-through off the end: the last instruction is reachable and
        // is not an unconditional control transfer.
        let last = &self.prog.insts[len - 1];
        if !is_terminator(last.op) && self.inst_reachable(len - 1) {
            self.emit(
                Code::FallsOffEnd,
                len - 1,
                format!("{:?} at the program end can fall through past it", last.op),
            );
        }
        // Unreachable instructions, reported once per contiguous run.
        let mut i = 0;
        while i < len {
            if self.inst_reachable(i) {
                i += 1;
                continue;
            }
            let start = i;
            while i < len && !self.inst_reachable(i) {
                i += 1;
            }
            self.emit(
                Code::Unreachable,
                start,
                format!("{} unreachable instruction(s)", i - start),
            );
        }
    }

    /// Whole-program issue/drain balance over reachable instructions.
    fn issue_drain_balance(&mut self) {
        let first_reachable = |pred: &dyn Fn(&Inst) -> bool| -> Option<usize> {
            self.prog
                .insts
                .iter()
                .enumerate()
                .position(|(i, inst)| pred(inst) && self.inst_reachable(i))
        };
        let first_issue =
            first_reachable(&|i| matches!(i.op, Opcode::ALoad | Opcode::AStore));
        let first_drain = first_reachable(&|i| i.op == Opcode::GetFin);
        match (first_issue, first_drain) {
            (Some(at), None) => self.emit(
                Code::IssueWithoutDrain,
                at,
                "async requests are issued but no getfin is reachable: completions leak".into(),
            ),
            (None, Some(at)) => self.emit(
                Code::DrainWithoutIssue,
                at,
                "getfin polls for completions but the program never issues a request".into(),
            ),
            _ => {}
        }
    }

    /// The fused forward dataflow fixpoint plus a final collection pass.
    fn dataflow(&mut self) {
        let nblocks = self.cfg.blocks.len();
        let nhandles = self.issue_sites.len();
        let mut in_states: Vec<Option<State>> = vec![None; nblocks];
        in_states[0] = Some(State::entry(nhandles));
        let mut joins = vec![0usize; nblocks];
        let mut work: Vec<usize> = vec![0];
        while let Some(b) = work.pop() {
            self.fixpoint_iters += 1;
            let mut st = in_states[b].clone().expect("worklist block has a state");
            let (s, e) = self.cfg.blocks[b];
            for i in s..e {
                self.transfer(&mut st, i, false);
            }
            let last = e - 1;
            for &(succ, kind) in &self.cfg.succs[b].clone() {
                let mut out = st.clone();
                refine_edge(&mut out, &self.prog.insts[last], kind);
                let changed = match &mut in_states[succ] {
                    Some(cur) => {
                        let prev = cur.clone();
                        let ch = cur.join(&out);
                        if ch {
                            joins[succ] += 1;
                            if joins[succ] > WIDEN_AFTER {
                                cur.widen(&prev);
                            }
                        }
                        ch
                    }
                    slot @ None => {
                        *slot = Some(out);
                        true
                    }
                };
                if changed && !work.contains(&succ) {
                    work.push(succ);
                }
            }
        }
        // Collection pass over the converged states.
        for b in 0..nblocks {
            let Some(mut st) = in_states[b].clone() else { continue };
            let (s, e) = self.cfg.blocks[b];
            for i in s..e {
                self.transfer(&mut st, i, true);
            }
        }
    }

    /// One-instruction transfer function; with `collect`, findings are
    /// emitted against the (converged) incoming state.
    fn transfer(&mut self, st: &mut State, at: usize, collect: bool) {
        let i = self.prog.insts[at];
        use Opcode::*;

        // Use-before-def on the registers this instruction actually reads.
        if collect {
            let (a, b) = i.sources();
            for r in [a, b].into_iter().flatten() {
                if r != 0 && st.uninit & (1u64 << r) != 0 {
                    self.emit(
                        Code::MaybeUninit,
                        at,
                        format!("r{r} may be read before its first write (reads as zero)"),
                    );
                }
            }
        }

        let rs1 = st.regs[i.rs1 as usize];
        let rs2 = st.regs[i.rs2 as usize];

        // Dead writes to hardwired r0. `j`/`jr` (Jal/Jalr rd=0) and
        // drain-and-discard `getfin r0` are idioms, not bugs.
        if collect && i.rd == 0 {
            match i.op {
                Add | Sub | Xor | And | Or | Sll | Srl | Mul | SltU | Addi | Xori | Andi
                | Ori | Slli | Srli | Li | Ld | CfgRd => self.emit(
                    Code::DeadWrite,
                    at,
                    format!("{:?} writes hardwired r0; the result is discarded", i.op),
                ),
                ALoad | AStore => self.emit(
                    Code::DiscardedRequestId,
                    at,
                    format!("{:?} writes its request id to r0: it cannot be awaited", i.op),
                ),
                _ => {}
            }
        }

        // Per-opcode protocol checks and interval evaluation.
        let mut wrote: Option<(u8, Ival)> = None;
        let mut issued_handle: Option<usize> = None;
        match i.op {
            Add => wrote = Some((i.rd, rs1.add(rs2))),
            Sub => wrote = Some((i.rd, rs1.sub(rs2))),
            Xor => wrote = Some((i.rd, rs1.bin_exact(rs2, |a, b| a ^ b))),
            And => wrote = Some((i.rd, rs1.and(rs2))),
            Or => wrote = Some((i.rd, rs1.bin_exact(rs2, |a, b| a | b))),
            Sll => wrote = Some((i.rd, rs1.shl_dyn(rs2))),
            Srl => wrote = Some((i.rd, rs1.shr_dyn(rs2))),
            Mul => wrote = Some((i.rd, rs1.mul(rs2))),
            SltU => wrote = Some((i.rd, rs1.sltu(rs2))),
            Addi => wrote = Some((i.rd, rs1.add_imm(i.imm))),
            Xori => wrote = Some((i.rd, rs1.bin_exact(Ival::singleton(i.imm as u64), |a, b| a ^ b))),
            Andi => wrote = Some((i.rd, rs1.and_mask(i.imm as u64))),
            Ori => wrote = Some((i.rd, rs1.bin_exact(Ival::singleton(i.imm as u64), |a, b| a | b))),
            Slli => wrote = Some((i.rd, rs1.shl_const(i.imm as u32 & 63))),
            Srli => wrote = Some((i.rd, rs1.shr_const(i.imm as u32 & 63))),
            Li => wrote = Some((i.rd, Ival::singleton(i.imm as u64))),
            Ld => {
                let addr = rs1.add_imm(i.imm);
                if let Some(a) = addr.as_const() {
                    self.note_sync_far(st, a);
                }
                if collect {
                    self.check_spm_access(st, at, &i, addr, true);
                }
                wrote = Some((i.rd, Ival::TOP));
            }
            St => {
                let addr = rs1.add_imm(i.imm);
                if let Some(a) = addr.as_const() {
                    self.note_sync_far(st, a);
                }
                if collect {
                    self.check_spm_access(st, at, &i, addr, false);
                }
            }
            Prefetch => {}
            Flush => {
                if collect {
                    let addr = rs1.add_imm(i.imm);
                    let width = i.size.max(1) as u64;
                    let acc = Ival { lo: addr.lo, hi: addr.hi.saturating_add(width - 1) };
                    if within_spm(acc) {
                        for k in 0..st.handles.len() {
                            let h = st.handles[k];
                            if h.st == Tri::Must && within_spm(h.region) && acc.overlaps(h.region)
                            {
                                let site = self.issue_sites[k];
                                self.emit(
                                    Code::FlushInFlightTarget,
                                    at,
                                    format!(
                                        "flush of SPM [{:#x}, {:#x}] targets the region of the \
                                         in-flight request issued at inst {site}",
                                        acc.lo, acc.hi
                                    ),
                                );
                            }
                        }
                    }
                }
                st.far_dirty = false;
            }
            Beq | Bne | Blt | Bge | BltU | Nop | Roi | Halt => {}
            Jal | Jalr => wrote = Some((i.rd, Ival::singleton(at as u64 + 1))),
            ALoad | AStore => {
                self.check_issue(st, at, &i, collect);
                if let Some(k) = self.site_index[at] {
                    let g = st.cfg[CfgReg::Granularity as usize].as_const().unwrap_or(1);
                    let region = target_region(rs1, g);
                    if collect {
                        self.check_overlap_and_depth(st, at, k, region);
                    }
                    // Strong update: re-issuing through the same site
                    // replaces the handle wholesale.
                    st.handles[k] = HandleState {
                        st: Tri::Must,
                        ids: if i.rd != 0 { 1u64 << i.rd } else { 0 },
                        region,
                    };
                    issued_handle = Some(k);
                }
                st.issued = true;
                st.far_dirty = false;
                wrote = Some((i.rd, Ival::TOP));
            }
            GetFin => {
                // One drain poll may complete *any* in-flight request:
                // every must-in-flight handle decays to maybe.
                for h in st.handles.iter_mut() {
                    if h.st == Tri::Must {
                        h.st = Tri::Maybe;
                    }
                }
                wrote = Some((i.rd, Ival::TOP));
            }
            CfgWr => match CfgReg::from_imm(i.imm) {
                Some(CfgReg::Granularity) => st.cfg[CfgReg::Granularity as usize] = rs1,
                Some(reg) => {
                    if collect && st.issued {
                        self.emit(
                            Code::QueueReconfigInFlight,
                            at,
                            format!(
                                "cfgwr {reg:?} is reachable after an async issue: \
                                 reconfiguration resets request ids that may be in flight"
                            ),
                        );
                    }
                    st.queue_unconfig = false;
                    st.cfg[reg as usize] = rs1;
                }
                None => {
                    if collect {
                        self.emit(
                            Code::BadCfgIndex,
                            at,
                            format!("cfgwr immediate {} names no configuration register", i.imm),
                        );
                    }
                }
            },
            CfgRd => match CfgReg::from_imm(i.imm) {
                Some(reg) => wrote = Some((i.rd, st.cfg[reg as usize])),
                None => {
                    if collect {
                        self.emit(
                            Code::BadCfgIndex,
                            at,
                            format!("cfgrd immediate {} names no configuration register", i.imm),
                        );
                    }
                    wrote = Some((i.rd, Ival::TOP));
                }
            },
        }

        // ROI window hygiene. Must-style conditions (`!roi_out` = the
        // window is open on *every* path in): the jalr over-approximation
        // would make may-style conditions fire on the coroutine scheduler.
        if i.op == Roi {
            let begin = i.imm == 1;
            if collect {
                if begin && !st.roi_out {
                    self.emit(
                        Code::RoiImbalance,
                        at,
                        "roi begin with the ROI window already open on every path here".into(),
                    );
                } else if !begin && !st.roi_in {
                    self.emit(
                        Code::RoiImbalance,
                        at,
                        "roi end with no ROI window open on any path here".into(),
                    );
                }
            }
            st.roi_in = begin;
            st.roi_out = !begin;
        }
        if i.op == Halt && collect && !st.roi_out {
            self.emit(
                Code::RoiImbalance,
                at,
                "program halts with the ROI window still open".into(),
            );
        }

        // Register write-back, tracking request-id copies: `mv rd, rs`
        // keeps an id alive in rd; any other write to a register holding
        // the *last* live copy of a must-in-flight id, at a point with no
        // getfin ahead, leaks the request (AMI019).
        let copy_src: Option<u8> = match i.op {
            Addi if i.imm == 0 => Some(i.rs1),
            Add | Or if i.rs2 == 0 => Some(i.rs1),
            Add | Or if i.rs1 == 0 => Some(i.rs2),
            _ => None,
        };
        if let Some((rd, v)) = wrote {
            if rd != 0 {
                let rd_bit = 1u64 << rd;
                for k in 0..st.handles.len() {
                    if Some(k) == issued_handle {
                        continue;
                    }
                    let src_live = copy_src
                        .map_or(false, |s| s != 0 && st.handles[k].ids & (1u64 << s) != 0);
                    if src_live {
                        st.handles[k].ids |= rd_bit;
                        continue;
                    }
                    if st.handles[k].ids & rd_bit != 0 {
                        st.handles[k].ids &= !rd_bit;
                        if collect
                            && st.handles[k].st == Tri::Must
                            && st.handles[k].ids == 0
                            && !self.cfg.getfin_reachable_after(self.prog, at)
                        {
                            let site = self.issue_sites[k];
                            self.emit(
                                Code::RequestIdLeak,
                                at,
                                format!(
                                    "overwrites r{rd}, the last live copy of the request id \
                                     issued at inst {site}, with no getfin reachable"
                                ),
                            );
                        }
                    }
                }
                st.regs[rd as usize] = v;
                st.uninit &= !(1u64 << rd);
            }
        }

        // Termination with requests in flight on every path: halt, or a
        // reachable fall-through off the program end (AMI002 fires too).
        if collect && (i.op == Halt || (at + 1 == self.prog.len() && !is_terminator(i.op))) {
            let must: Vec<usize> = st
                .handles
                .iter()
                .enumerate()
                .filter(|&(_, h)| h.st == Tri::Must)
                .map(|(k, _)| self.issue_sites[k])
                .collect();
            if !must.is_empty() {
                let verb = if i.op == Halt { "halts" } else { "runs off its end" };
                self.emit(
                    Code::HaltWithInFlight,
                    at,
                    format!(
                        "program {verb} with {} async request(s) still in flight (issued at \
                         inst {})",
                        must.len(),
                        must[0]
                    ),
                );
            }
        }
    }

    /// A constant-address sync access touching the far region marks the
    /// sync->async transition state (cleared by `flush`).
    fn note_sync_far(&self, st: &mut State, addr: u64) {
        if region_of(addr) == MemRegion::Far {
            st.far_dirty = true;
        }
    }

    /// AMI016/AMI017: a sync SPM access whose byte range provably lies in
    /// the scratchpad and overlaps the target region of a request that is
    /// in flight on every path here — the use-before-completion race.
    fn check_spm_access(&mut self, st: &State, at: usize, i: &Inst, addr: Ival, is_read: bool) {
        let width = i.size.max(1) as u64;
        let acc = Ival { lo: addr.lo, hi: addr.hi.saturating_add(width - 1) };
        if !within_spm(acc) {
            return;
        }
        for (k, h) in st.handles.iter().enumerate() {
            if h.st == Tri::Must && within_spm(h.region) && acc.overlaps(h.region) {
                let site = self.issue_sites[k];
                let (code, verb) = if is_read {
                    (Code::SpmReadInFlight, "reads")
                } else {
                    (Code::SpmWriteInFlight, "writes")
                };
                self.emit(
                    code,
                    at,
                    format!(
                        "{:?} {verb} SPM [{:#x}, {:#x}] while the request issued at inst \
                         {site} targeting [{:#x}, {:#x}] is in flight",
                        i.op, acc.lo, acc.hi, h.region.lo, h.region.hi
                    ),
                );
            }
        }
    }

    /// AMI018/AMI024 at an issue site: may-overlap against every other
    /// must-in-flight handle, and the bounded-queue-depth check against a
    /// constant-propagated `QueueLength`.
    fn check_overlap_and_depth(&mut self, st: &State, at: usize, k: usize, region: Ival) {
        if let Some(ql) = st.cfg[CfgReg::QueueLength as usize].as_const() {
            let in_flight = st
                .handles
                .iter()
                .enumerate()
                .filter(|&(j, h)| j != k && h.st == Tri::Must)
                .count() as u64;
            if in_flight + 1 > ql {
                self.emit(
                    Code::QueueDepthExceeded,
                    at,
                    format!(
                        "issue raises the in-flight request count to {}, exceeding the \
                         configured QueueLength {ql}",
                        in_flight + 1
                    ),
                );
            }
        }
        if !within_spm(region) {
            return;
        }
        for (j, h) in st.handles.iter().enumerate() {
            if j != k && h.st == Tri::Must && within_spm(h.region) && region.overlaps(h.region) {
                let site = self.issue_sites[j];
                self.emit(
                    Code::OverlappingRequests,
                    at,
                    format!(
                        "request target [{:#x}, {:#x}] may overlap the in-flight request \
                         issued at inst {site} targeting [{:#x}, {:#x}]: completion order \
                         decides the slot contents",
                        region.lo, region.hi, h.region.lo, h.region.hi
                    ),
                );
            }
        }
    }

    /// Protocol checks at an `aload`/`astore` issue point.
    fn check_issue(&mut self, st: &State, at: usize, i: &Inst, collect: bool) {
        if !collect {
            return;
        }
        let op = i.op;
        if self.has_queue_cfg && st.queue_unconfig {
            self.emit(
                Code::QueueCfgNotDominating,
                at,
                format!(
                    "{op:?} issued on a path where cfgwr QueueBase/QueueLength has not executed"
                ),
            );
        }
        if st.far_dirty {
            self.emit(
                Code::MissingFlush,
                at,
                format!(
                    "{op:?} issued after a sync far-region access with no intervening flush \
                     (sync->async transition)"
                ),
            );
        }
        let qreg = || {
            Option::zip(
                st.cfg[CfgReg::QueueBase as usize].as_const(),
                st.cfg[CfgReg::QueueLength as usize].as_const(),
            )
            // AMART metadata: 32 B per queue entry (paper Table 2).
            .map(|(qb, ql)| (qb, qb.saturating_add(ql.saturating_mul(32))))
        };
        let spm = st.regs[i.rs1 as usize];
        if let Some(v) = spm.as_const() {
            if region_of(v) != MemRegion::Spm {
                self.emit(
                    Code::SpmOperandOutOfRange,
                    at,
                    format!(
                        "{op:?} SPM operand resolves to {v:#x}, outside the scratchpad"
                    ),
                );
            } else if let Some((qb, qend)) = qreg() {
                if v >= qb && v < qend {
                    self.emit(
                        Code::SpmOperandOutOfRange,
                        at,
                        format!(
                            "{op:?} SPM operand {v:#x} lies inside the configured queue \
                             region [{qb:#x}, {qend:#x})"
                        ),
                    );
                }
            }
        } else if !spm.is_top() {
            // Interval refinement (AMI022): a loop-varying/merged operand
            // whose whole byte range is provably misplaced.
            let g = st.cfg[CfgReg::Granularity as usize].as_const().unwrap_or(1);
            let reg = target_region(spm, g);
            if reg.hi < crate::isa::mem::SPM_BASE || reg.lo >= crate::isa::mem::SPM_END {
                self.emit(
                    Code::SpmIntervalOutOfRange,
                    at,
                    format!(
                        "{op:?} SPM operand ranges over [{:#x}, {:#x}], entirely outside \
                         the scratchpad",
                        reg.lo, reg.hi
                    ),
                );
            } else if let Some((qb, qend)) = qreg() {
                if reg.lo >= qb && reg.hi < qend {
                    self.emit(
                        Code::SpmIntervalOutOfRange,
                        at,
                        format!(
                            "{op:?} SPM operand range [{:#x}, {:#x}] lies inside the \
                             configured queue region [{qb:#x}, {qend:#x})",
                            reg.lo, reg.hi
                        ),
                    );
                }
            }
        }
        let mem = st.regs[i.rs2 as usize];
        if let Some(v) = mem.as_const() {
            if region_of(v) == MemRegion::Spm {
                self.emit(
                    Code::MemOperandInSpm,
                    at,
                    format!(
                        "{op:?} memory operand resolves to {v:#x}, inside the scratchpad"
                    ),
                );
            }
        } else if !mem.is_top() && within_spm(mem) {
            self.emit(
                Code::MemIntervalInSpm,
                at,
                format!(
                    "{op:?} memory operand ranges over [{:#x}, {:#x}], entirely inside \
                     the scratchpad",
                    mem.lo, mem.hi
                ),
            );
        }
    }
}

/// Refine the branch operand intervals along a `Taken`/`Fall` edge. A
/// refinement that would empty an interval is skipped (the edge is still
/// propagated unrefined — soundness over precision, so no previously
/// analyzed block ever loses its state). Signed compares refine only when
/// both operands provably fit in the non-negative signed range, where
/// signed and unsigned order coincide. Hardwired r0 is never refined.
fn refine_edge(st: &mut State, last: &Inst, kind: EdgeKind) {
    if kind == EdgeKind::Other {
        return;
    }
    let taken = kind == EdgeKind::Taken;
    let a = st.regs[last.rs1 as usize];
    let b = st.regs[last.rs2 as usize];
    let (mut na, mut nb) = (a, b);
    let signed_safe = |v: Ival| v.hi <= i64::MAX as u64;
    match last.op {
        Opcode::BltU => refine_ltu(&mut na, &mut nb, taken),
        Opcode::Blt if signed_safe(a) && signed_safe(b) => refine_ltu(&mut na, &mut nb, taken),
        Opcode::Bge if signed_safe(a) && signed_safe(b) => refine_ltu(&mut na, &mut nb, !taken),
        Opcode::Beq => {
            if taken {
                refine_eq(&mut na, &mut nb);
            } else {
                refine_ne(&mut na, &mut nb);
            }
        }
        Opcode::Bne => {
            if taken {
                refine_ne(&mut na, &mut nb);
            } else {
                refine_eq(&mut na, &mut nb);
            }
        }
        _ => return,
    }
    if last.rs1 != 0 {
        st.regs[last.rs1 as usize] = na;
    }
    if last.rs2 != 0 {
        st.regs[last.rs2 as usize] = nb;
    }
}

/// `a < b` (unsigned) when `lt`, else `a >= b`; tighten each side only
/// when the new bound stays inside the interval.
fn refine_ltu(a: &mut Ival, b: &mut Ival, lt: bool) {
    if lt {
        if b.hi > 0 {
            let cap = b.hi - 1;
            if cap < a.hi && cap >= a.lo {
                a.hi = cap;
            }
        }
        if a.lo < u64::MAX {
            let floor = a.lo + 1;
            if floor > b.lo && floor <= b.hi {
                b.lo = floor;
            }
        }
    } else {
        if b.lo > a.lo && b.lo <= a.hi {
            a.lo = b.lo;
        }
        if a.hi < b.hi && a.hi >= b.lo {
            b.hi = a.hi;
        }
    }
}

fn refine_eq(a: &mut Ival, b: &mut Ival) {
    let lo = a.lo.max(b.lo);
    let hi = a.hi.min(b.hi);
    if lo <= hi {
        *a = Ival { lo, hi };
        *b = *a;
    }
}

/// `a != b`: trim a matching interval endpoint when the other side is a
/// singleton (the only shape intervals can express).
fn refine_ne(a: &mut Ival, b: &mut Ival) {
    fn trim(v: &mut Ival, c: u64) {
        if v.lo == v.hi {
            return; // refusing to empty a singleton
        }
        if v.lo == c {
            v.lo += 1;
        } else if v.hi == c {
            v.hi -= 1;
        }
    }
    if let Some(c) = b.as_const() {
        trim(a, c);
    }
    if let Some(c) = a.as_const() {
        trim(b, c);
    }
}
