//! Static analysis over assembled guest programs (`amu-sim check`).
//!
//! AMI decouples request issue (`aload`/`astore`) from response handling
//! (`getfin`), with request state parked in SPM — so a guest program can be
//! silently wrong in ways synchronous load/store code cannot: requests
//! issued before the AMART queue is configured, SPM operands that alias the
//! configured queue region, issue/drain imbalance that leaks request IDs,
//! sync reads of an SPM slot whose fill request is still in flight, or
//! unbalanced ROI markers that corrupt the measurement window. This module
//! machine-checks every program before it reaches the cycle-accurate
//! pipeline.
//!
//! The pass builds a CFG over instruction indices (branch/`jal`/`jalr`/
//! `halt` terminators; indirect jumps approximated by the program's
//! address-taken label set plus call-return sites — see [`cfg`]) and runs
//! five analysis families:
//!
//! 1. **structural** — out-of-bounds jump targets, fall-through off the
//!    program end, unreachable instructions, dead writes to hardwired `r0`;
//! 2. **register dataflow** — use-before-def via a forward
//!    may-be-uninitialized analysis (info-level: registers reset to zero),
//!    plus an interval domain over register values ([`domain`]): joined at
//!    merges, refined along branch edges, widened at loop heads — so
//!    strided and loop-varying addresses stay analyzable, not just
//!    constants;
//! 3. **AMI protocol** — queue configuration dominating every issue, SPM
//!    operands (constant *or* bounded-interval) inside the scratchpad and
//!    outside the configured queue region, issue/drain balance, valid
//!    `CfgReg` indices, no queue reconfiguration with requests in flight;
//! 4. **request lifetimes** ([`lifetime`]) — one abstract handle per
//!    static issue site tracks must/may in-flight state, the registers
//!    still holding the request id, and the interval of the SPM target
//!    region: sync access of an in-flight target (AMI016/017), overlapping
//!    in-flight targets (AMI018), id overwritten with no drain ahead
//!    (AMI019), halt with requests in flight (AMI020), flush of an
//!    in-flight target (AMI021), and queue-depth overflow (AMI024);
//! 5. **measurement hygiene** — `roi` begin/end paired on all paths,
//!    `flush` between constant-address sync far accesses and async issue.
//!
//! The CFG still over-approximates indirect control flow (a `jalr` may
//! target any address-taken label or call-return site), so path-sensitive
//! checks are conservative: they never miss a violation on a real path,
//! but exotic external programs may need restructuring to verify cleanly.
//! Deny-level race findings additionally require the access *and* the
//! in-flight target region to be provably inside the scratchpad, so
//! widened or memory-fed addresses never produce false denials. Every
//! built-in benchmark passes with zero deny- and warn-level findings
//! (enforced in CI by `amu-sim check --all --deny-warnings`).

mod cfg;
mod checks;
mod diag;
mod domain;
mod lifetime;

pub use diag::{Code, Diagnostic, Report, Severity, ALL_CODES};
pub(crate) use diag::json_escape;

use super::inst::Program;

/// Run the full static-analysis pass over an assembled program.
pub fn verify(prog: &Program) -> Report {
    checks::analyze(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::mem::{FAR_BASE, SPM_BASE};
    use crate::isa::Asm;

    fn codes(r: &Report) -> Vec<Code> {
        r.diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_minimal_program() {
        let mut a = Asm::new("ok");
        a.li(1, 5).addi(1, 1, 1).halt();
        let r = verify(&a.finish());
        assert!(r.diags.is_empty(), "{:?}", r.diags);
        assert!(r.is_clean(true));
    }

    #[test]
    fn clean_ami_roundtrip() {
        let mut a = Asm::new("ami-ok");
        a.li(1, SPM_BASE as i64);
        a.li(2, FAR_BASE as i64);
        a.aload(3, 1, 2);
        a.label("poll");
        a.getfin(4);
        a.beq(4, 0, "poll");
        a.halt();
        let r = verify(&a.finish());
        assert!(r.is_clean(true), "{:?}", r.diags);
    }

    #[test]
    fn empty_program_flagged() {
        let r = verify(&Program { name: "empty".into(), ..Default::default() });
        assert_eq!(codes(&r), vec![Code::FallsOffEnd]);
    }

    #[test]
    fn falls_off_end() {
        let mut a = Asm::new("fall");
        a.li(1, 1);
        let r = verify(&a.finish());
        assert_eq!(codes(&r), vec![Code::FallsOffEnd]);
        assert_eq!(r.diags[0].at, 0);
    }

    #[test]
    fn label_context_attached() {
        let mut a = Asm::new("ctx");
        a.halt();
        a.label("dead_code");
        a.nop();
        a.halt();
        let r = verify(&a.finish());
        assert_eq!(r.diags.len(), 1);
        assert_eq!(r.diags[0].code, Code::Unreachable);
        assert_eq!(r.diags[0].label, "dead_code");
    }

    #[test]
    fn severity_order() {
        assert!(Severity::Deny > Severity::Warn && Severity::Warn > Severity::Info);
    }

    #[test]
    fn all_codes_unique_and_ordered() {
        let tags: Vec<&str> = ALL_CODES.iter().map(|c| c.tag()).collect();
        let mut sorted = tags.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(tags.len(), sorted.len());
        assert_eq!(tags, sorted, "ALL_CODES must be in ascending AMIxxx order");
    }

    #[test]
    fn report_counts_and_gating() {
        let mut a = Asm::new("mix");
        a.li(0, 1); // AMI004 warn
        a.halt();
        let r = verify(&a.finish());
        assert_eq!((r.deny_count(), r.warn_count()), (0, 1));
        assert!(r.is_clean(false) && !r.is_clean(true));
    }

    #[test]
    fn widening_terminates_unbounded_loop() {
        // r4 counts forever; without widening the interval [0, n] grows one
        // join at a time and the fixpoint never converges.
        let mut a = Asm::new("loop");
        a.li(4, 0);
        a.label("loop");
        a.addi(4, 4, 1);
        a.bne(4, 0, "loop");
        a.halt();
        let r = verify(&a.finish());
        assert!(r.is_clean(true), "{:?}", r.diags);
        assert!(
            r.fixpoint_iters < 100,
            "fixpoint took {} iterations — widening is not kicking in",
            r.fixpoint_iters
        );
    }

    #[test]
    fn branch_refinement_bounds_a_counted_loop() {
        // for r4 in 0..8 { r5 = SPM_BASE + (r4 << 3); aload r6, r5, r2 }:
        // without the bltu-taken refinement r4's interval widens to TOP and
        // AMI022 could never be judged; with it the operand stays inside
        // the scratchpad and the program is clean.
        let mut a = Asm::new("strided");
        a.li(2, FAR_BASE as i64);
        a.li(4, 0);
        a.li(7, 8);
        a.label("loop");
        a.slli(5, 4, 3);
        a.li(6, SPM_BASE as i64);
        a.add(5, 5, 6);
        a.aload(6, 5, 2);
        a.getfin(0);
        a.addi(4, 4, 1);
        a.bltu(4, 7, "loop");
        a.halt();
        let r = verify(&a.finish());
        assert!(r.is_clean(true), "{:?}", r.diags);
    }

    #[test]
    fn jalr_targets_narrow_to_addr_taken_labels() {
        // The only address-taken label is "cont": the refined CFG must not
        // treat "skipped" as a jalr target, so its body is unreachable.
        let mut a = Asm::new("jalr-narrow");
        a.li_label(1, "cont");
        a.jalr(0, 1);
        a.label("skipped");
        a.nop();
        a.halt();
        a.label("cont");
        a.halt();
        let r = verify(&a.finish());
        assert_eq!(codes(&r), vec![Code::Unreachable]);
        assert_eq!(r.diags[0].at, 2);
    }

    #[test]
    fn raw_programs_fall_back_to_all_label_targets() {
        // Hand-built programs carry no address-taken info: every label is
        // a potential jalr target, so nothing here is unreachable.
        let mut a = Asm::new("jalr-legacy");
        a.li(1, 4);
        a.jalr(0, 1);
        a.label("a");
        a.nop();
        a.halt();
        a.label("b");
        a.halt();
        let mut p = a.finish();
        p.addr_taken.clear(); // simulate a raw Program
        let r = verify(&p);
        assert!(!codes(&r).contains(&Code::Unreachable), "{:?}", r.diags);
    }
}
