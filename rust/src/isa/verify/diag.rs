//! Diagnostic vocabulary: severities, the stable `AMIxxx` code set, and
//! the per-program [`Report`] with its table and JSON renderings.

/// Diagnostic severity. `Deny` findings make `run`/`sweep`/`mtrun` refuse
/// the program; `Warn` findings fail `amu-sim check --deny-warnings`;
/// `Info` findings never gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Deny,
}

impl Severity {
    pub fn tag(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// Typed diagnostic codes. Stable identifiers: tests, CI and the README
/// table key off these strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    /// AMI001: branch/jump target outside the program.
    BadTarget,
    /// AMI002: execution can fall through past the last instruction.
    FallsOffEnd,
    /// AMI003: instruction unreachable from entry.
    Unreachable,
    /// AMI004: ALU/load result written to hardwired `r0` (discarded).
    DeadWrite,
    /// AMI005: register may be read before its first write.
    MaybeUninit,
    /// AMI006: `cfgwr`/`cfgrd` immediate names no configuration register.
    BadCfgIndex,
    /// AMI007: issue on a path where the queue configuration (`cfgwr`
    /// `QueueBase`/`QueueLength`) has not executed, in a program that does
    /// configure the queue elsewhere.
    QueueCfgNotDominating,
    /// AMI008: queue reconfigured while requests may be in flight.
    QueueReconfigInFlight,
    /// AMI009: constant SPM operand outside the scratchpad (or inside the
    /// configured AMART queue region).
    SpmOperandOutOfRange,
    /// AMI010: constant memory operand inside the scratchpad.
    MemOperandInSpm,
    /// AMI011: async requests issued but the program contains no
    /// reachable `getfin` drain.
    IssueWithoutDrain,
    /// AMI012: request ID written to `r0` — the request can never be
    /// awaited individually.
    DiscardedRequestId,
    /// AMI013: `getfin` polling in a program that never issues a request.
    DrainWithoutIssue,
    /// AMI014: unbalanced `roi` markers: a begin with the window already
    /// open on every path, an end with it open on no path, or a halt with
    /// it open on every path. (Must-style conditions: the indirect-jump
    /// over-approximation makes may-style ROI checks fire spuriously on
    /// the coroutine scheduler.)
    RoiImbalance,
    /// AMI015: constant-address sync far access followed by an async
    /// issue with no intervening `flush` (sync->async region transition).
    MissingFlush,
    /// AMI016: SPM read overlapping the target region of a request that is
    /// in flight on every path here — the use-before-completion race: the
    /// slot's contents are undefined until `getfin` reports the id.
    SpmReadInFlight,
    /// AMI017: SPM write overlapping the target region of an in-flight
    /// request — the completion will clobber (or race with) the write.
    SpmWriteInFlight,
    /// AMI018: two simultaneously in-flight requests whose SPM target
    /// regions may overlap — completion order decides the slot contents.
    OverlappingRequests,
    /// AMI019: the last live copy of an in-flight request id is
    /// overwritten at a point from which no `getfin` is reachable — the
    /// request can never be awaited and its queue entry leaks.
    RequestIdLeak,
    /// AMI020: the program can halt (or run off its end) with requests
    /// still in flight on every path to that point.
    HaltWithInFlight,
    /// AMI021: `flush` targets the SPM region of an in-flight request.
    FlushInFlightTarget,
    /// AMI022: a loop-varying/merged SPM operand whose interval lies
    /// entirely outside the scratchpad (or entirely inside the configured
    /// queue region) — the interval-domain refinement of AMI009.
    SpmIntervalOutOfRange,
    /// AMI023: a loop-varying/merged memory operand whose interval lies
    /// entirely inside the scratchpad — the interval refinement of AMI010.
    MemIntervalInSpm,
    /// AMI024: an issue raises the must-in-flight request count above the
    /// constant-propagated `QueueLength`.
    QueueDepthExceeded,
}

/// Every diagnostic code, in ascending `AMIxxx` order (the README table
/// and the negative-corpus test iterate this).
pub const ALL_CODES: &[Code] = &[
    Code::BadTarget,
    Code::FallsOffEnd,
    Code::Unreachable,
    Code::DeadWrite,
    Code::MaybeUninit,
    Code::BadCfgIndex,
    Code::QueueCfgNotDominating,
    Code::QueueReconfigInFlight,
    Code::SpmOperandOutOfRange,
    Code::MemOperandInSpm,
    Code::IssueWithoutDrain,
    Code::DiscardedRequestId,
    Code::DrainWithoutIssue,
    Code::RoiImbalance,
    Code::MissingFlush,
    Code::SpmReadInFlight,
    Code::SpmWriteInFlight,
    Code::OverlappingRequests,
    Code::RequestIdLeak,
    Code::HaltWithInFlight,
    Code::FlushInFlightTarget,
    Code::SpmIntervalOutOfRange,
    Code::MemIntervalInSpm,
    Code::QueueDepthExceeded,
];

impl Code {
    pub fn tag(&self) -> &'static str {
        match self {
            Code::BadTarget => "AMI001",
            Code::FallsOffEnd => "AMI002",
            Code::Unreachable => "AMI003",
            Code::DeadWrite => "AMI004",
            Code::MaybeUninit => "AMI005",
            Code::BadCfgIndex => "AMI006",
            Code::QueueCfgNotDominating => "AMI007",
            Code::QueueReconfigInFlight => "AMI008",
            Code::SpmOperandOutOfRange => "AMI009",
            Code::MemOperandInSpm => "AMI010",
            Code::IssueWithoutDrain => "AMI011",
            Code::DiscardedRequestId => "AMI012",
            Code::DrainWithoutIssue => "AMI013",
            Code::RoiImbalance => "AMI014",
            Code::MissingFlush => "AMI015",
            Code::SpmReadInFlight => "AMI016",
            Code::SpmWriteInFlight => "AMI017",
            Code::OverlappingRequests => "AMI018",
            Code::RequestIdLeak => "AMI019",
            Code::HaltWithInFlight => "AMI020",
            Code::FlushInFlightTarget => "AMI021",
            Code::SpmIntervalOutOfRange => "AMI022",
            Code::MemIntervalInSpm => "AMI023",
            Code::QueueDepthExceeded => "AMI024",
        }
    }

    pub fn severity(&self) -> Severity {
        match self {
            Code::BadTarget
            | Code::FallsOffEnd
            | Code::BadCfgIndex
            | Code::QueueCfgNotDominating
            | Code::QueueReconfigInFlight
            | Code::SpmOperandOutOfRange
            | Code::MemOperandInSpm
            | Code::IssueWithoutDrain
            | Code::RoiImbalance
            // Use-before-completion races and interval-refined operand
            // violations are definite protocol breaches: the access/operand
            // range is known to fall where it must not.
            | Code::SpmReadInFlight
            | Code::SpmWriteInFlight
            | Code::SpmIntervalOutOfRange
            | Code::MemIntervalInSpm => Severity::Deny,
            Code::DeadWrite
            | Code::DiscardedRequestId
            | Code::DrainWithoutIssue
            // Lifetime hazards below are may-facts over joined handle
            // states (overlap/leak/depth depend on completion order or on
            // which abstract path is real) — they gate only under
            // --deny-warnings, like the other hygiene warns.
            | Code::OverlappingRequests
            | Code::RequestIdLeak
            | Code::HaltWithInFlight
            | Code::FlushInFlightTarget
            | Code::QueueDepthExceeded => Severity::Warn,
            // Unreachable defensive padding after indirect jumps is a
            // deliberate idiom in the coroutine scheduler, registers
            // architecturally reset to zero, and the far-dirty bit is a
            // may-fact over an over-approximated CFG — notes, not gates.
            Code::Unreachable | Code::MaybeUninit | Code::MissingFlush => Severity::Info,
        }
    }

    /// One-line meaning for the README table and `check` summaries.
    pub fn meaning(&self) -> &'static str {
        match self {
            Code::BadTarget => "branch/jump target outside the program",
            Code::FallsOffEnd => "execution can fall through past the last instruction",
            Code::Unreachable => "instruction unreachable from entry",
            Code::DeadWrite => "result written to hardwired r0 and discarded",
            Code::MaybeUninit => "register may be read before its first write",
            Code::BadCfgIndex => "cfgwr/cfgrd immediate names no configuration register",
            Code::QueueCfgNotDominating => {
                "issue on a path where the AMART queue configuration has not executed"
            }
            Code::QueueReconfigInFlight => {
                "queue reconfigured while async requests may be in flight"
            }
            Code::SpmOperandOutOfRange => {
                "SPM operand outside the scratchpad or inside the configured queue region"
            }
            Code::MemOperandInSpm => "memory operand of an async request inside the scratchpad",
            Code::IssueWithoutDrain => "async requests issued but no getfin drain is reachable",
            Code::DiscardedRequestId => "request id written to r0; request cannot be awaited",
            Code::DrainWithoutIssue => "getfin polling but the program never issues a request",
            Code::RoiImbalance => "roi begin/end unbalanced on some path",
            Code::MissingFlush => "sync far access reaches an async issue without a flush",
            Code::SpmReadInFlight => {
                "SPM read overlaps the target region of an in-flight async request"
            }
            Code::SpmWriteInFlight => {
                "SPM write overlaps the target region of an in-flight async request"
            }
            Code::OverlappingRequests => {
                "two in-flight async requests may target overlapping SPM regions"
            }
            Code::RequestIdLeak => {
                "last copy of an in-flight request id overwritten with no getfin reachable"
            }
            Code::HaltWithInFlight => "program can halt with async requests still in flight",
            Code::FlushInFlightTarget => {
                "flush targets the SPM region of an in-flight async request"
            }
            Code::SpmIntervalOutOfRange => {
                "SPM operand interval entirely outside the scratchpad or inside the queue region"
            }
            Code::MemIntervalInSpm => {
                "memory operand interval entirely inside the scratchpad"
            }
            Code::QueueDepthExceeded => {
                "in-flight request count exceeds the configured QueueLength"
            }
        }
    }
}

/// One finding: code, location (instruction index), enclosing label
/// context, and a concrete message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: Code,
    /// Instruction index the finding anchors to.
    pub at: usize,
    /// Nearest label at or before `at` (empty if none).
    pub label: String,
    pub message: String,
}

impl Diagnostic {
    pub fn severity(&self) -> Severity {
        self.code.severity()
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ctx = if self.label.is_empty() { "-".to_string() } else { self.label.clone() };
        write!(
            f,
            "{} {} @{} ({}): {}",
            self.code.tag(),
            self.severity().tag(),
            self.at,
            ctx,
            self.message
        )
    }
}

/// The verifier's result for one program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Report {
    /// `Program::name` of the verified program.
    pub program: String,
    /// Program length in instructions.
    pub insts: usize,
    /// All findings, sorted by instruction index then code.
    pub diags: Vec<Diagnostic>,
    /// Blocks processed by the dataflow worklist before the fixpoint
    /// converged (widening guarantees a bound; property-tested).
    pub fixpoint_iters: usize,
}

impl Report {
    pub fn count(&self, sev: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity() == sev).count()
    }

    pub fn deny_count(&self) -> usize {
        self.count(Severity::Deny)
    }

    pub fn warn_count(&self) -> usize {
        self.count(Severity::Warn)
    }

    /// Does this report gate execution? With `deny_warnings`, warn-level
    /// findings gate too (the CI configuration).
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        self.deny_count() == 0 && (!deny_warnings || self.warn_count() == 0)
    }

    /// Render findings at or above `min` as a fixed-width diagnostics
    /// table (golden-pinned; `amu-sim check` output).
    pub fn render_table(&self, min: Severity) -> String {
        let mut s = String::new();
        for d in self.diags.iter().filter(|d| d.severity() >= min) {
            let ctx = if d.label.is_empty() { "-" } else { &d.label };
            s.push_str(&format!(
                "  {} {:<4} @{:<5} {:<14} {}\n",
                d.code.tag(),
                d.severity().tag(),
                d.at,
                ctx,
                d.message
            ));
        }
        s
    }

    /// Render this report as one entry of the `check --format json`
    /// `programs` array. The field set (code/severity/index/label/message)
    /// is a stable schema, golden-pinned in
    /// `rust/tests/golden/verify_check.json`.
    pub fn render_json(&self, label: &str) -> String {
        let mut s = String::new();
        s.push_str("    {\n");
        s.push_str(&format!("      \"label\": \"{}\",\n", json_escape(label)));
        s.push_str(&format!("      \"program\": \"{}\",\n", json_escape(&self.program)));
        s.push_str(&format!("      \"insts\": {},\n", self.insts));
        s.push_str(&format!("      \"deny\": {},\n", self.deny_count()));
        s.push_str(&format!("      \"warn\": {},\n", self.warn_count()));
        s.push_str(&format!("      \"info\": {},\n", self.count(Severity::Info)));
        if self.diags.is_empty() {
            s.push_str("      \"diagnostics\": []\n");
        } else {
            s.push_str("      \"diagnostics\": [\n");
            for (k, d) in self.diags.iter().enumerate() {
                s.push_str("        {\n");
                s.push_str(&format!("          \"code\": \"{}\",\n", d.code.tag()));
                s.push_str(&format!("          \"severity\": \"{}\",\n", d.severity().tag()));
                s.push_str(&format!("          \"index\": {},\n", d.at));
                s.push_str(&format!("          \"label\": \"{}\",\n", json_escape(&d.label)));
                s.push_str(&format!("          \"message\": \"{}\"\n", json_escape(&d.message)));
                s.push_str(if k + 1 < self.diags.len() { "        },\n" } else { "        }\n" });
            }
            s.push_str("      ]\n");
        }
        s.push_str("    }");
        s
    }

    /// Compact one-line summary of the deny-level findings, for errors
    /// raised by the fail-fast hook in the workload registry.
    pub fn deny_summary(&self) -> String {
        let denies: Vec<String> = self
            .diags
            .iter()
            .filter(|d| d.severity() == Severity::Deny)
            .take(3)
            .map(|d| d.to_string())
            .collect();
        let extra = self.deny_count().saturating_sub(denies.len());
        let mut s = denies.join("; ");
        if extra > 0 {
            s.push_str(&format!("; +{extra} more"));
        }
        s
    }
}

/// Minimal JSON string escaping for the hand-rolled renderers (no JSON
/// dependency in the crate; determinism matters more than generality).
pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
