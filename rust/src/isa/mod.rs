//! Guest ISA: instruction set, assembler, address space, and a functional
//! interpreter used as the timing model's architectural oracle.

pub mod asm;
pub mod disasm;
pub mod inst;
pub mod interp;
pub mod mem;
pub mod parse;
pub mod verify;

pub use asm::{Asm, AsmError};
pub use disasm::disasm;
pub use inst::{CfgReg, Inst, Opcode, Program};
pub use interp::{CompletionOrder, Interp};
pub use mem::{region_of, GuestMem, Layout, MemRegion, FAR_BASE, LOCAL_BASE, SPM_BASE};
pub use parse::{parse_str, ParseError, ParseErrorKind, ParsedProgram};
pub use verify::{verify, Code as VerifyCode, Diagnostic, Report as VerifyReport, Severity};
