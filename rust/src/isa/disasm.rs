//! Canonical text rendering of an assembled [`Program`] in the `isa::parse`
//! grammar.
//!
//! `parse_str(disasm(&p), ...)` reproduces `p` exactly (same instruction
//! words, same `addr_taken` set, same labels up to the assembler's
//! arbitrary ordering of labels that share an instruction index) — the
//! round-trip is property-tested over every builtin × variant, and the
//! grammar itself is pinned by `rust/tests/golden/disasm_reference.txt`.
//!
//! Canonical choices: sized memory ops always print as `ld.N`/`st.N`
//! (never `ld64`), `li` always prints a numeric immediate (label addresses
//! that escape into data are carried by `.addr_taken` directives), and
//! `jal r0`/`jalr r0` print as their `j`/`jr` shorthands.

use std::collections::{BTreeMap, HashMap, HashSet};

use super::inst::{Inst, Opcode, Program};

fn region_name(r: u8) -> &'static str {
    match r {
        1 => "scheduler",
        2 => "disambig",
        3 => "setup",
        _ => "main",
    }
}

fn cfg_name(imm: i64) -> String {
    match imm {
        0 => "granularity".to_string(),
        1 => "queue_base".to_string(),
        2 => "queue_length".to_string(),
        other => other.to_string(),
    }
}

fn render(inst: &Inst, label_of: &dyn Fn(usize) -> String) -> String {
    use Opcode::*;
    let Inst { rd, rs1, rs2, imm, size, .. } = *inst;
    let alu = |m: &str| format!("{m} r{rd}, r{rs1}, r{rs2}");
    let alui = |m: &str| format!("{m} r{rd}, r{rs1}, {imm}");
    let br = |m: &str| format!("{m} r{rs1}, r{rs2}, {}", label_of(imm as usize));
    match inst.op {
        Add => alu("add"),
        Sub => alu("sub"),
        Xor => alu("xor"),
        And => alu("and"),
        Or => alu("or"),
        Sll => alu("sll"),
        Srl => alu("srl"),
        Mul => alu("mul"),
        SltU => alu("sltu"),
        Addi => alui("addi"),
        Xori => alui("xori"),
        Andi => alui("andi"),
        Ori => alui("ori"),
        Slli => alui("slli"),
        Srli => alui("srli"),
        Li => format!("li r{rd}, {imm}"),
        Ld => format!("ld.{size} r{rd}, {imm}(r{rs1})"),
        St => format!("st.{size} r{rs2}, {imm}(r{rs1})"),
        Prefetch => format!("prefetch {imm}(r{rs1})"),
        Flush => format!("flush {imm}(r{rs1})"),
        Beq => br("beq"),
        Bne => br("bne"),
        Blt => br("blt"),
        Bge => br("bge"),
        BltU => br("bltu"),
        Jal if rd == 0 => format!("j {}", label_of(imm as usize)),
        Jal => format!("jal r{rd}, {}", label_of(imm as usize)),
        Jalr if rd == 0 => format!("jr r{rs1}"),
        Jalr => format!("jalr r{rd}, r{rs1}"),
        ALoad => format!("aload r{rd}, r{rs1}, r{rs2}"),
        AStore => format!("astore r{rd}, r{rs1}, r{rs2}"),
        GetFin => format!("getfin r{rd}"),
        CfgWr => format!("cfgwr r{rs1}, {}", cfg_name(imm)),
        CfgRd => format!("cfgrd r{rd}, {}", cfg_name(imm)),
        Nop => "nop".to_string(),
        Halt => "halt".to_string(),
        Roi if imm != 0 => "roi.begin".to_string(),
        Roi => "roi.end".to_string(),
    }
}

/// Render `prog` as parseable AMI assembly text.
pub fn disasm(prog: &Program) -> String {
    // First label at each index names branch targets; indices that are
    // referenced (branch/jump target or addr-taken) without any label get
    // a synthesized `__L<idx>` one so the text always parses back.
    let mut first_label: HashMap<usize, String> = HashMap::new();
    let taken_names: HashSet<&str> = prog.labels.iter().map(|(n, _)| n.as_str()).collect();
    for (name, at) in &prog.labels {
        first_label.entry(*at).or_insert_with(|| name.clone());
    }
    let mut referenced: Vec<usize> = prog.addr_taken.clone();
    for inst in &prog.insts {
        if matches!(
            inst.op,
            Opcode::Beq | Opcode::Bne | Opcode::Blt | Opcode::Bge | Opcode::BltU | Opcode::Jal
        ) {
            referenced.push(inst.imm as usize);
        }
    }
    let mut emit_at: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for (name, at) in &prog.labels {
        emit_at.entry(*at).or_default().push(name.clone());
    }
    for idx in referenced {
        if !first_label.contains_key(&idx) {
            let mut synth = format!("__L{idx}");
            while taken_names.contains(synth.as_str()) {
                synth.push('_');
            }
            emit_at.entry(idx).or_default().push(synth.clone());
            first_label.insert(idx, synth);
        }
    }
    let label_of = |idx: usize| -> String {
        first_label.get(&idx).cloned().unwrap_or_else(|| format!("__L{idx}"))
    };

    let mut out = String::new();
    out.push_str(&format!(".program {}\n", prog.name));
    for &idx in &prog.addr_taken {
        out.push_str(&format!(".addr_taken {}\n", label_of(idx)));
    }
    let mut region = 0u8;
    for (i, inst) in prog.insts.iter().enumerate() {
        if let Some(names) = emit_at.get(&i) {
            for name in names {
                out.push_str(&format!("{name}:\n"));
            }
        }
        if inst.region != region {
            region = inst.region;
            out.push_str(&format!(".region {}\n", region_name(region)));
        }
        out.push_str(&format!("  {}\n", render(inst, &label_of)));
    }
    if let Some(names) = emit_at.get(&prog.insts.len()) {
        for name in names {
            out.push_str(&format!("{name}:\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::Asm;
    use crate::isa::parse::parse_str;
    use crate::stats::Region;

    /// Labels that share an instruction index come back from `try_finish`
    /// in arbitrary (HashMap) order; compare them as sorted sets.
    fn normalized_labels(p: &Program) -> Vec<(usize, String)> {
        let mut v: Vec<(usize, String)> =
            p.labels.iter().map(|(n, at)| (*at, n.clone())).collect();
        v.sort();
        v
    }

    fn assert_round_trip(p: &Program) {
        let text = disasm(p);
        let q = parse_str(&text, "<disasm>", &p.name).unwrap_or_else(|e| {
            panic!("disasm output failed to re-parse: {e}\n{text}");
        });
        assert_eq!(p.insts, q.prog.insts, "instructions drifted:\n{text}");
        assert_eq!(p.name, q.prog.name);
        assert_eq!(p.addr_taken, q.prog.addr_taken, "addr_taken drifted:\n{text}");
        assert_eq!(normalized_labels(p), normalized_labels(&q.prog));
    }

    #[test]
    fn loops_branches_and_regions_round_trip() {
        let mut a = Asm::new("rt");
        a.region(Region::Setup);
        a.li(1, 0);
        a.li(2, 64);
        a.region(Region::Main);
        a.label("loop");
        a.ld64(3, 1, 8);
        a.st(3, 1, -8, 4);
        a.addi(1, 1, 1);
        a.blt(1, 2, "loop");
        a.halt();
        assert_round_trip(&a.finish());
    }

    #[test]
    fn ami_and_pseudo_ops_round_trip() {
        let mut a = Asm::new("rt2");
        a.li_label(1, "task");
        a.mark_addr_taken("task");
        a.call("task");
        a.j("done");
        a.label("task");
        a.aload(3, 4, 5);
        a.getfin(6);
        a.ret();
        a.label("done");
        a.roi_begin();
        a.prefetch(4, 64);
        a.flush(4, 0);
        a.roi_end();
        a.halt();
        assert_round_trip(&a.finish());
    }

    #[test]
    fn unlabeled_branch_target_synthesizes_a_label() {
        // A hand-built program whose branch target has no label must still
        // disassemble to parseable text.
        use crate::isa::inst::{Inst, Opcode};
        let prog = Program {
            name: "raw".to_string(),
            insts: vec![
                Inst { op: Opcode::Beq, rd: 0, rs1: 1, rs2: 0, imm: 2, size: 0, region: 0 },
                Inst::nop(),
                Inst { op: Opcode::Halt, rd: 0, rs1: 0, rs2: 0, imm: 0, size: 0, region: 0 },
            ],
            labels: vec![],
            addr_taken: vec![],
        };
        let text = disasm(&prog);
        assert!(text.contains("beq r1, r0, __L2"), "{text}");
        let q = parse_str(&text, "<disasm>", "raw").unwrap();
        assert_eq!(prog.insts, q.prog.insts);
    }
}
