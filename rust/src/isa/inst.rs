//! Guest instruction set.
//!
//! A compact RISC-style ISA sufficient to express the paper's benchmarks
//! with real dependence chains, data-dependent branches and pointer chasing,
//! plus the paper's AMI extension (`aload`/`astore`/`getfin`/`cfgrw`).
//! Code addresses are instruction indices; data addresses are 64-bit byte
//! addresses in the guest address space (see `super::mem` for the region
//! map).

/// Architectural registers r0..r63; r0 is hardwired to zero.
pub const NUM_ARCH_REGS: usize = 64;
pub const ZERO: u8 = 0;
/// Conventional link register used by the assembler's call/ret pseudo-ops.
pub const LINK: u8 = 63;

/// AMI configuration registers (paper Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CfgReg {
    Granularity = 0,
    QueueBase = 1,
    QueueLength = 2,
}

impl CfgReg {
    /// Decode a `cfgwr`/`cfgrd` immediate. Unknown indices are a program
    /// bug (they used to silently alias `Granularity`): the interpreter
    /// faults on them and the verifier reports `AMI006`.
    pub fn from_imm(v: i64) -> Option<CfgReg> {
        match v {
            0 => Some(CfgReg::Granularity),
            1 => Some(CfgReg::QueueBase),
            2 => Some(CfgReg::QueueLength),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Opcode {
    // ALU register-register.
    Add,
    Sub,
    Xor,
    And,
    Or,
    Sll, // shift left logical by rs2
    Srl,
    Mul,
    SltU, // rd = (rs1 < rs2) unsigned
    // ALU register-immediate (imm).
    Addi,
    Xori,
    Andi,
    Ori,
    Slli,
    Srli,
    Li, // rd = imm
    // Memory: address = regs[rs1] + imm, `size` bytes (1/2/4/8).
    Ld,
    St, // stores regs[rs2]
    // Software prefetch (asynchronous, best-effort, holds an MSHR).
    Prefetch,
    // Control: branch target / jump target in imm (instruction index).
    Beq,
    Bne,
    Blt,  // signed
    Bge,  // signed
    BltU,
    Jal,  // rd = next pc, jump to imm
    Jalr, // rd = next pc, jump to regs[rs1] (indirect; coroutine dispatch)
    // AMI (paper Table 1).
    ALoad,  // rd = request id; rs1 = SPM addr, rs2 = memory addr
    AStore, // rd = request id; rs1 = SPM addr, rs2 = memory addr
    GetFin, // rd = completed id, or 0 if none finished
    CfgWr,  // cfg[imm] = regs[rs1]
    CfgRd,  // rd = cfg[imm]
    // Misc.
    Nop,
    Halt,
    /// Region-of-interest marker: imm=1 begin, imm=0 end (measurement window).
    Roi,
    /// Cache flush of the line containing regs[rs1]+imm (region transition
    /// between sync and async phases, paper §5.3.2).
    Flush,
}

/// One decoded guest instruction. Flat layout keeps the pipeline simple.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Inst {
    pub op: Opcode,
    pub rd: u8,
    pub rs1: u8,
    pub rs2: u8,
    pub imm: i64,
    /// Memory access size in bytes (Ld/St).
    pub size: u8,
    /// Stats attribution region (see `stats::Region`), set by the assembler.
    pub region: u8,
}

impl Inst {
    pub fn nop() -> Inst {
        Inst { op: Opcode::Nop, rd: 0, rs1: 0, rs2: 0, imm: 0, size: 0, region: 0 }
    }

    pub fn is_branch(&self) -> bool {
        matches!(
            self.op,
            Opcode::Beq
                | Opcode::Bne
                | Opcode::Blt
                | Opcode::Bge
                | Opcode::BltU
                | Opcode::Jal
                | Opcode::Jalr
        )
    }

    pub fn is_mem(&self) -> bool {
        matches!(self.op, Opcode::Ld | Opcode::St | Opcode::Prefetch | Opcode::Flush)
    }

    pub fn is_ami(&self) -> bool {
        matches!(
            self.op,
            Opcode::ALoad | Opcode::AStore | Opcode::GetFin | Opcode::CfgWr | Opcode::CfgRd
        )
    }

    /// Does this instruction write `rd`?
    pub fn writes_rd(&self) -> bool {
        match self.op {
            Opcode::St
            | Opcode::Prefetch
            | Opcode::Beq
            | Opcode::Bne
            | Opcode::Blt
            | Opcode::Bge
            | Opcode::BltU
            | Opcode::CfgWr
            | Opcode::Nop
            | Opcode::Halt
            | Opcode::Roi
            | Opcode::Flush => false,
            _ => self.rd != ZERO,
        }
    }

    /// Source registers actually read (for rename/dependency tracking).
    pub fn sources(&self) -> (Option<u8>, Option<u8>) {
        use Opcode::*;
        match self.op {
            Add | Sub | Xor | And | Or | Sll | Srl | Mul | SltU => {
                (Some(self.rs1), Some(self.rs2))
            }
            Addi | Xori | Andi | Ori | Slli | Srli => (Some(self.rs1), None),
            Li | Nop | Halt | Roi | GetFin | CfgRd | Jal => (None, None),
            Ld | Prefetch | Flush | Jalr => (Some(self.rs1), None),
            St => (Some(self.rs1), Some(self.rs2)),
            Beq | Bne | Blt | Bge | BltU => (Some(self.rs1), Some(self.rs2)),
            ALoad | AStore => (Some(self.rs1), Some(self.rs2)),
            CfgWr => (Some(self.rs1), None),
        }
    }
}

/// An assembled guest program.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Program {
    pub name: String,
    pub insts: Vec<Inst>,
    /// Label name -> instruction index (kept for disassembly/debugging).
    pub labels: Vec<(String, usize)>,
    /// Instruction indices whose address is materialized into a register
    /// (`li_label` continuations, explicit `Asm::mark_addr_taken`). The
    /// verifier narrows `jalr` successors to this set plus call-return
    /// sites; when empty, it falls back to treating every label as a
    /// potential indirect target (hand-built raw programs).
    pub addr_taken: Vec<usize>,
}

impl Program {
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    pub fn disasm(&self, pc: usize) -> String {
        let i = &self.insts[pc];
        for (name, at) in &self.labels {
            if *at == pc {
                return format!("{pc:6} <{name}>: {:?}", i);
            }
        }
        format!("{pc:6}: {:?}", i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_reg_never_written() {
        let mut i = Inst::nop();
        i.op = Opcode::Li;
        i.rd = ZERO;
        assert!(!i.writes_rd());
        i.rd = 5;
        assert!(i.writes_rd());
    }

    #[test]
    fn classifications() {
        let mut i = Inst::nop();
        i.op = Opcode::ALoad;
        assert!(i.is_ami() && !i.is_mem() && !i.is_branch());
        i.op = Opcode::Ld;
        assert!(i.is_mem() && !i.is_ami());
        i.op = Opcode::Jalr;
        assert!(i.is_branch());
    }

    #[test]
    fn sources_match_semantics() {
        let mut i = Inst::nop();
        i.op = Opcode::St;
        i.rs1 = 3;
        i.rs2 = 4;
        assert_eq!(i.sources(), (Some(3), Some(4)));
        i.op = Opcode::Li;
        assert_eq!(i.sources(), (None, None));
        i.op = Opcode::GetFin;
        assert_eq!(i.sources(), (None, None));
    }

    #[test]
    fn cfg_reg_roundtrip() {
        assert_eq!(CfgReg::from_imm(0), Some(CfgReg::Granularity));
        assert_eq!(CfgReg::from_imm(1), Some(CfgReg::QueueBase));
        assert_eq!(CfgReg::from_imm(2), Some(CfgReg::QueueLength));
        assert_eq!(CfgReg::from_imm(3), None);
        assert_eq!(CfgReg::from_imm(-1), None);
    }
}
