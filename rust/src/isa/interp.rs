//! Functional (timing-free) interpreter for guest programs.
//!
//! Used as the correctness oracle for the OoO core model (both must reach
//! the same architectural state) and for fast workload unit tests. AMI
//! semantics are modeled functionally: data moves at request time and
//! completions are delivered by `getfin` in a configurable order — FIFO or
//! seeded-random — so workload programs can be checked against *any* legal
//! completion order, which is exactly the property the paper's coroutine
//! framework must tolerate.

use super::inst::{CfgReg, Opcode, Program, NUM_ARCH_REGS};
use super::mem::GuestMem;
use crate::util::prng::Xoshiro256;
use std::collections::VecDeque;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionOrder {
    Fifo,
    /// Deliver completions in pseudo-random order (seeded).
    Random(u64),
}

pub struct Interp<'a> {
    pub regs: [u64; NUM_ARCH_REGS],
    pub pc: usize,
    pub mem: &'a mut GuestMem,
    pub halted: bool,
    pub steps: u64,
    pub roi_steps: u64,
    in_roi: bool,
    // AMI state.
    granularity: u64,
    queue_length: u64,
    free_ids: VecDeque<u16>,
    finished: Vec<u16>,
    order: CompletionOrder,
    rng: Xoshiro256,
    /// Completions withheld to simulate in-flight latency: a request only
    /// becomes getfin-visible after `visibility_delay` further getfin polls.
    pending: VecDeque<(u16, u64)>,
    poll_count: u64,
    visibility_delay: u64,
}

#[derive(Debug)]
pub struct InterpResult {
    pub steps: u64,
    pub roi_steps: u64,
    pub halted: bool,
}

impl<'a> Interp<'a> {
    pub fn new(mem: &'a mut GuestMem, order: CompletionOrder) -> Self {
        let seed = match order {
            CompletionOrder::Random(s) => s,
            CompletionOrder::Fifo => 0,
        };
        let mut it = Interp {
            regs: [0; NUM_ARCH_REGS],
            pc: 0,
            mem,
            halted: false,
            steps: 0,
            roi_steps: 0,
            in_roi: false,
            granularity: 8,
            queue_length: 256,
            free_ids: VecDeque::new(),
            finished: Vec::new(),
            order,
            rng: Xoshiro256::new(seed ^ 0x17e7_e57a),
            pending: VecDeque::new(),
            poll_count: 0,
            visibility_delay: 3,
        };
        it.reset_ids();
        it
    }

    fn reset_ids(&mut self) {
        self.free_ids = (1..=self.queue_length as u16).collect();
        self.finished.clear();
        self.pending.clear();
    }

    fn alloc_id(&mut self) -> u64 {
        match self.free_ids.pop_front() {
            Some(id) => id as u64,
            None => 0, // allocation failure per the ISA spec
        }
    }

    /// Run until halt or `max_steps`; returns Err on runaway.
    pub fn run(&mut self, prog: &Program, max_steps: u64) -> Result<InterpResult, String> {
        while !self.halted {
            if self.steps >= max_steps {
                return Err(format!(
                    "interp exceeded {max_steps} steps at pc={} ({})",
                    self.pc,
                    prog.disasm(self.pc.min(prog.len().saturating_sub(1)))
                ));
            }
            self.step(prog)?;
        }
        Ok(InterpResult { steps: self.steps, roi_steps: self.roi_steps, halted: self.halted })
    }

    pub fn step(&mut self, prog: &Program) -> Result<(), String> {
        if self.pc >= prog.len() {
            return Err(format!("pc {} out of range", self.pc));
        }
        let i = prog.insts[self.pc];
        self.steps += 1;
        if self.in_roi {
            self.roi_steps += 1;
        }
        let mut next = self.pc + 1;
        let rs1 = self.regs[i.rs1 as usize];
        let rs2 = self.regs[i.rs2 as usize];
        let wr = |regs: &mut [u64; NUM_ARCH_REGS], rd: u8, v: u64| {
            if rd != 0 {
                regs[rd as usize] = v;
            }
        };
        use Opcode::*;
        match i.op {
            Add => wr(&mut self.regs, i.rd, rs1.wrapping_add(rs2)),
            Sub => wr(&mut self.regs, i.rd, rs1.wrapping_sub(rs2)),
            Xor => wr(&mut self.regs, i.rd, rs1 ^ rs2),
            And => wr(&mut self.regs, i.rd, rs1 & rs2),
            Or => wr(&mut self.regs, i.rd, rs1 | rs2),
            Sll => wr(&mut self.regs, i.rd, rs1.wrapping_shl(rs2 as u32 & 63)),
            Srl => wr(&mut self.regs, i.rd, rs1.wrapping_shr(rs2 as u32 & 63)),
            Mul => wr(&mut self.regs, i.rd, rs1.wrapping_mul(rs2)),
            SltU => wr(&mut self.regs, i.rd, (rs1 < rs2) as u64),
            Addi => wr(&mut self.regs, i.rd, rs1.wrapping_add(i.imm as u64)),
            Xori => wr(&mut self.regs, i.rd, rs1 ^ i.imm as u64),
            Andi => wr(&mut self.regs, i.rd, rs1 & i.imm as u64),
            Ori => wr(&mut self.regs, i.rd, rs1 | i.imm as u64),
            Slli => wr(&mut self.regs, i.rd, rs1.wrapping_shl(i.imm as u32 & 63)),
            Srli => wr(&mut self.regs, i.rd, rs1.wrapping_shr(i.imm as u32 & 63)),
            Li => wr(&mut self.regs, i.rd, i.imm as u64),
            Ld => {
                let addr = rs1.wrapping_add(i.imm as u64);
                let v = self.mem.read(addr, i.size);
                wr(&mut self.regs, i.rd, v);
            }
            St => {
                let addr = rs1.wrapping_add(i.imm as u64);
                self.mem.write(addr, i.size, rs2);
            }
            Prefetch | Flush => {} // timing-only
            Beq => {
                if rs1 == rs2 {
                    next = i.imm as usize;
                }
            }
            Bne => {
                if rs1 != rs2 {
                    next = i.imm as usize;
                }
            }
            Blt => {
                if (rs1 as i64) < (rs2 as i64) {
                    next = i.imm as usize;
                }
            }
            Bge => {
                if (rs1 as i64) >= (rs2 as i64) {
                    next = i.imm as usize;
                }
            }
            BltU => {
                if rs1 < rs2 {
                    next = i.imm as usize;
                }
            }
            Jal => {
                wr(&mut self.regs, i.rd, (self.pc + 1) as u64);
                next = i.imm as usize;
            }
            Jalr => {
                wr(&mut self.regs, i.rd, (self.pc + 1) as u64);
                next = rs1 as usize;
            }
            ALoad => {
                let id = self.alloc_id();
                if id != 0 {
                    // rs1 = SPM addr, rs2 = memory addr (paper Table 1).
                    self.mem.copy(rs1, rs2, self.granularity as usize);
                    self.pending.push_back((id as u16, self.poll_count));
                }
                wr(&mut self.regs, i.rd, id);
            }
            AStore => {
                let id = self.alloc_id();
                if id != 0 {
                    self.mem.copy(rs2, rs1, self.granularity as usize);
                    self.pending.push_back((id as u16, self.poll_count));
                }
                wr(&mut self.regs, i.rd, id);
            }
            GetFin => {
                self.poll_count += 1;
                // Promote pending requests that have "aged" enough.
                while let Some(&(id, at)) = self.pending.front() {
                    if self.poll_count >= at + self.visibility_delay {
                        self.finished.push(id);
                        self.pending.pop_front();
                    } else {
                        break;
                    }
                }
                let id = if self.finished.is_empty() {
                    // Nothing ready: if requests are pending, force-age the
                    // oldest so pure polling loops always terminate.
                    if let Some((id, _)) = self.pending.pop_front() {
                        self.finished.push(id);
                        self.pop_finished()
                    } else {
                        0
                    }
                } else {
                    self.pop_finished()
                };
                if id != 0 {
                    self.free_ids.push_back(id as u16);
                }
                wr(&mut self.regs, i.rd, id);
            }
            CfgWr => match CfgReg::from_imm(i.imm) {
                Some(CfgReg::Granularity) => self.granularity = rs1.max(1),
                Some(CfgReg::QueueBase) => {} // metadata base; functional no-op
                Some(CfgReg::QueueLength) => {
                    self.queue_length = rs1.clamp(1, 4096);
                    self.reset_ids();
                }
                None => {
                    return Err(format!(
                        "cfgwr fault at pc={}: immediate {} names no configuration register",
                        self.pc, i.imm
                    ))
                }
            },
            CfgRd => {
                let v = match CfgReg::from_imm(i.imm) {
                    Some(CfgReg::Granularity) => self.granularity,
                    Some(CfgReg::QueueBase) => 0,
                    Some(CfgReg::QueueLength) => self.queue_length,
                    None => {
                        return Err(format!(
                            "cfgrd fault at pc={}: immediate {} names no configuration register",
                            self.pc, i.imm
                        ))
                    }
                };
                wr(&mut self.regs, i.rd, v);
            }
            Nop => {}
            Halt => self.halted = true,
            Roi => self.in_roi = i.imm == 1,
        }
        self.pc = next;
        Ok(())
    }

    fn pop_finished(&mut self) -> u64 {
        if self.finished.is_empty() {
            return 0;
        }
        let idx = match self.order {
            CompletionOrder::Fifo => 0,
            CompletionOrder::Random(_) => self.rng.below(self.finished.len() as u64) as usize,
        };
        self.finished.swap_remove(idx) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::Asm;
    use crate::isa::mem::{GuestMem, FAR_BASE, LOCAL_BASE, SPM_BASE};

    fn run(prog: &Program, mem: &mut GuestMem) -> [u64; NUM_ARCH_REGS] {
        let mut it = Interp::new(mem, CompletionOrder::Fifo);
        it.run(prog, 1_000_000).expect("interp failed");
        it.regs
    }

    #[test]
    fn alu_loop_sums() {
        // r2 = sum(0..10)
        let mut a = Asm::new("sum");
        a.li(1, 0).li(2, 0).li(3, 10);
        a.label("loop");
        a.add(2, 2, 1);
        a.addi(1, 1, 1);
        a.blt(1, 3, "loop");
        a.halt();
        let mut mem = GuestMem::new();
        let regs = run(&a.finish(), &mut mem);
        assert_eq!(regs[2], 45);
    }

    #[test]
    fn loads_stores_roundtrip() {
        let mut a = Asm::new("mem");
        a.li(1, LOCAL_BASE as i64);
        a.li(2, 0x1234);
        a.st64(2, 1, 8);
        a.ld64(3, 1, 8);
        a.halt();
        let mut mem = GuestMem::new();
        let regs = run(&a.finish(), &mut mem);
        assert_eq!(regs[3], 0x1234);
    }

    #[test]
    fn aload_moves_far_to_spm() {
        let mut mem = GuestMem::new();
        mem.write_u64(FAR_BASE + 64, 0xABCD);
        let mut a = Asm::new("ami");
        a.li(1, (SPM_BASE + 128) as i64);
        a.li(2, (FAR_BASE + 64) as i64);
        a.aload(3, 1, 2); // id in r3
        a.label("poll");
        a.getfin(4);
        a.beq(4, 0, "poll");
        a.ld64(5, 1, 0);
        a.halt();
        let regs = run(&a.finish(), &mut mem);
        assert_ne!(regs[3], 0, "id allocation must succeed");
        assert_eq!(regs[4], regs[3], "getfin returns the completed id");
        assert_eq!(regs[5], 0xABCD);
    }

    #[test]
    fn astore_moves_spm_to_far() {
        let mut mem = GuestMem::new();
        mem.write_u64(SPM_BASE, 0x5577);
        let mut a = Asm::new("ami");
        a.li(1, SPM_BASE as i64);
        a.li(2, (FAR_BASE + 256) as i64);
        a.astore(3, 1, 2);
        a.label("poll");
        a.getfin(4);
        a.beq(4, 0, "poll");
        a.halt();
        let mut it_mem = mem;
        run(&a.finish(), &mut it_mem);
        assert_eq!(it_mem.read_u64(FAR_BASE + 256), 0x5577);
    }

    #[test]
    fn granularity_config_controls_copy_size() {
        let mut mem = GuestMem::new();
        for i in 0..64 {
            mem.write(FAR_BASE + i, 1, (i + 1) & 0xff);
        }
        let mut a = Asm::new("gran");
        a.li(1, 64).cfgwr(1, CfgReg::Granularity);
        a.li(2, SPM_BASE as i64);
        a.li(3, FAR_BASE as i64);
        a.aload(4, 2, 3);
        a.label("poll");
        a.getfin(5);
        a.beq(5, 0, "poll");
        a.halt();
        let mut m = mem;
        run(&a.finish(), &mut m);
        for i in 0..64u64 {
            assert_eq!(m.read(SPM_BASE + i, 1), (i + 1) & 0xff);
        }
    }

    #[test]
    fn id_exhaustion_returns_zero() {
        let mut mem = GuestMem::new();
        let mut a = Asm::new("exhaust");
        a.li(1, 2).cfgwr(1, CfgReg::QueueLength);
        a.li(2, SPM_BASE as i64);
        a.li(3, FAR_BASE as i64);
        a.aload(4, 2, 3);
        a.aload(5, 2, 3);
        a.aload(6, 2, 3); // queue_length=2 -> must fail
        a.halt();
        let regs = run(&a.finish(), &mut mem);
        assert_ne!(regs[4], 0);
        assert_ne!(regs[5], 0);
        assert_eq!(regs[6], 0);
    }

    #[test]
    fn getfin_recycles_ids() {
        let mut mem = GuestMem::new();
        let mut a = Asm::new("recycle");
        a.li(1, 1).cfgwr(1, CfgReg::QueueLength);
        a.li(2, SPM_BASE as i64);
        a.li(3, FAR_BASE as i64);
        // Two sequential aloads with a getfin drain between them.
        a.aload(4, 2, 3);
        a.label("p1");
        a.getfin(5);
        a.beq(5, 0, "p1");
        a.aload(6, 2, 3);
        a.halt();
        let regs = run(&a.finish(), &mut mem);
        assert_ne!(regs[4], 0);
        assert_ne!(regs[6], 0, "id must be recycled after getfin");
    }

    #[test]
    fn random_completion_order_is_deterministic_per_seed() {
        let prog = {
            let mut a = Asm::new("multi");
            a.li(1, SPM_BASE as i64);
            a.li(2, FAR_BASE as i64);
            for k in 0..4 {
                a.addi(3, 1, k * 64);
                a.addi(4, 2, k * 64);
                a.aload(5, 3, 4);
            }
            // collect 4 completions, recording the first
            a.li(10, 0);
            a.label("poll");
            a.getfin(6);
            a.beq(6, 0, "poll");
            a.bne(10, 0, "skip");
            a.mv(10, 6);
            a.label("skip");
            a.addi(11, 11, 1);
            a.li(12, 4);
            a.blt(11, 12, "poll");
            a.halt();
            a.finish()
        };
        let first = |seed: u64| {
            let mut mem = GuestMem::new();
            let mut it = Interp::new(&mut mem, CompletionOrder::Random(seed));
            it.run(&prog, 100_000).unwrap();
            it.regs[10]
        };
        assert_eq!(first(1), first(1), "same seed, same order");
    }

    #[test]
    fn call_ret() {
        let mut a = Asm::new("call");
        a.li(1, 5);
        a.call("double");
        a.halt();
        a.label("double");
        a.add(1, 1, 1);
        a.ret();
        let mut mem = GuestMem::new();
        let regs = run(&a.finish(), &mut mem);
        assert_eq!(regs[1], 10);
    }

    #[test]
    fn roi_counts_steps() {
        let mut a = Asm::new("roi");
        a.nop().roi_begin().nop().nop().roi_end().halt();
        let mut mem = GuestMem::new();
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        let r = it.run(&a.finish(), 1000).unwrap();
        assert_eq!(r.roi_steps, 3); // nop, nop, roi_end
    }

    #[test]
    fn invalid_cfg_index_faults() {
        use crate::isa::inst::Inst;
        let prog = Program {
            name: "badcfg".into(),
            insts: vec![
                Inst { op: Opcode::CfgWr, imm: 7, ..Inst::nop() },
                Inst { op: Opcode::Halt, ..Inst::nop() },
            ],
            ..Default::default()
        };
        let mut mem = GuestMem::new();
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        let err = it.run(&prog, 1000).unwrap_err();
        assert!(err.contains("names no configuration register"), "{err}");
    }

    #[test]
    fn runaway_detected() {
        let mut a = Asm::new("spin");
        a.label("top");
        a.j("top");
        let mut mem = GuestMem::new();
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        assert!(it.run(&a.finish(), 1000).is_err());
    }
}
