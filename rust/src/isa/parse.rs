//! Text-format AMI assembly.
//!
//! A line-oriented assembler front-end over [`Asm`]: every builder
//! mnemonic has a textual spelling, so guest programs can be loaded from
//! `.asm` files at runtime instead of being compiled into the simulator
//! (see `session::programs` for the loader and the README "External AMI
//! programs" section for the grammar reference). The parser produces the
//! same [`Program`] the builder would, which means external programs flow
//! through the identical `isa::verify` gate as the built-in benchmarks.
//!
//! Errors are typed and carry an exact `file:line:col` position; the
//! parser never panics on malformed input (the builder's `aload`/`astore`
//! alias asserts are pre-checked here as [`ParseErrorKind::AliasedRequestRegs`]).
//!
//! Grammar sketch (`;` and `#` start comments, commas are whitespace):
//!
//! ```text
//! .program gups_lite            ; program name (defaults to the file stem)
//! .arg n 1024                   ; scalar argument, referenced as $n
//! .mem FAR_BASE 1 2 3           ; u64 words at FAR_BASE, FAR_BASE+8, ...
//! .check LOCAL_BASE 42          ; post-run validation: [addr] == value
//! .region setup                 ; stats attribution (main|scheduler|disambig|setup)
//! .addr_taken task              ; label escapes into data (jalr target set)
//! top: li r1, FAR_BASE+8*4      ; labels, symbolic constants, + - * /
//!   ld.8 r2, 0(r1)              ; sized loads/stores: ld.1/.2/.4/.8, ld64
//!   aload r3, r4, r5            ; AMI: rd, spm-addr reg, mem-addr reg
//!   cfgwr r1, granularity       ; AMI config: granularity|queue_base|queue_length
//!   beq r2, zero, top
//!   halt
//! ```

use std::collections::HashMap;
use std::fmt;

use super::asm::{Asm, AsmError};
use super::inst::{CfgReg, Program, LINK, NUM_ARCH_REGS};
use super::mem::{FAR_BASE, FAR_END, LOCAL_BASE, SPM_BASE, SPM_END};
use crate::stats::Region;

/// What went wrong, without the position (see [`ParseError`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseErrorKind {
    UnknownMnemonic(String),
    UnknownDirective(String),
    BadRegister(String),
    /// Malformed immediate expression (bad literal, trailing operator,
    /// division by zero).
    BadImmediate(String),
    WrongOperandCount { mnemonic: String, expected: &'static str, got: usize },
    /// Memory operand that is not `off(reg)`.
    BadAddressOperand(String),
    BadCfgReg(String),
    BadRegion(String),
    /// `ld.`/`st.` size suffix other than 1/2/4/8.
    BadSize(String),
    DuplicateLabel(String),
    UndefinedLabel(String),
    DuplicateArg(String),
    /// Unresolvable `$arg` or symbolic constant in an expression.
    UnknownSymbol(String),
    /// `aload`/`astore` rd aliasing an operand register (the ID-allocation
    /// µop writes rd before the request µop reads rs1/rs2).
    AliasedRequestRegs(String),
    EmptyProgram,
}

impl fmt::Display for ParseErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use ParseErrorKind::*;
        match self {
            UnknownMnemonic(m) => write!(f, "unknown mnemonic '{m}'"),
            UnknownDirective(d) => write!(f, "unknown directive '{d}'"),
            BadRegister(r) => {
                write!(f, "bad register '{r}' (expected r0..r63, zero, or ra)")
            }
            BadImmediate(e) => write!(f, "bad immediate expression '{e}'"),
            WrongOperandCount { mnemonic, expected, got } => {
                write!(f, "'{mnemonic}' expects operands `{expected}`, got {got}")
            }
            BadAddressOperand(a) => {
                write!(f, "bad address operand '{a}' (expected off(reg), e.g. 8(r2))")
            }
            BadCfgReg(c) => write!(
                f,
                "bad AMI config register '{c}' (expected granularity, queue_base, \
                 queue_length, or an index 0..=2)"
            ),
            BadRegion(r) => {
                write!(f, "bad region '{r}' (expected main, scheduler, disambig, or setup)")
            }
            BadSize(m) => write!(f, "bad access size in '{m}' (expected .1/.2/.4/.8)"),
            DuplicateLabel(l) => write!(f, "duplicate label '{l}'"),
            UndefinedLabel(l) => write!(f, "undefined label '{l}'"),
            DuplicateArg(a) => write!(f, "duplicate .arg '{a}'"),
            UnknownSymbol(s) => write!(f, "unknown symbol '{s}'"),
            AliasedRequestRegs(m) => {
                write!(f, "'{m}': rd must not alias the spm/mem operand registers")
            }
            EmptyProgram => write!(f, "program contains no instructions"),
        }
    }
}

/// A parse failure at an exact source position (1-based line and column).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub kind: ParseErrorKind,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{}: {}", self.file, self.line, self.col, self.kind)
    }
}

impl std::error::Error for ParseError {}

/// A parsed `.asm` file: the assembled program plus its header directives.
#[derive(Debug, Clone)]
pub struct ParsedProgram {
    pub prog: Program,
    /// `.arg name value` scalars, in declaration order.
    pub args: Vec<(String, u64)>,
    /// `.mem` memory-image words: `(byte address, u64 value)`.
    pub mem: Vec<(u64, u64)>,
    /// `.check` post-run assertions: `(byte address, expected u64)`.
    pub checks: Vec<(u64, u64)>,
}

/// One source token with its 1-based column.
struct Tok {
    text: String,
    col: u32,
}

/// Split a line into tokens on whitespace and commas; `;` and `#` start a
/// comment. `off(base)` address operands survive as single tokens.
fn tokenize(line: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut cur = String::new();
    let mut start = 0u32;
    for (i, ch) in line.chars().enumerate() {
        if ch == ';' || ch == '#' {
            break;
        }
        if ch.is_whitespace() || ch == ',' {
            if !cur.is_empty() {
                toks.push(Tok { text: std::mem::take(&mut cur), col: start });
            }
        } else {
            if cur.is_empty() {
                start = i as u32 + 1;
            }
            cur.push(ch);
        }
    }
    if !cur.is_empty() {
        toks.push(Tok { text: cur, col: start });
    }
    toks
}

/// Parse a u64 literal: decimal or `0x` hex, `_` separators allowed.
fn parse_u64_lit(s: &str) -> Option<u64> {
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Some(hex) = cleaned.strip_prefix("0x").or_else(|| cleaned.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        cleaned.parse::<u64>().ok()
    }
}

/// Expression lexemes: atoms separated by `+ - * /` operators.
enum Lx {
    Atom(String),
    Op(char),
}

fn lex_expr(s: &str) -> Vec<Lx> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in s.chars() {
        if matches!(ch, '+' | '-' | '*' | '/') {
            if !cur.is_empty() {
                out.push(Lx::Atom(std::mem::take(&mut cur)));
            }
            out.push(Lx::Op(ch));
        } else {
            cur.push(ch);
        }
    }
    if !cur.is_empty() {
        out.push(Lx::Atom(cur));
    }
    out
}

struct Parser<'a> {
    file: &'a str,
    asm: Asm,
    args: Vec<(String, u64)>,
    mem: Vec<(u64, u64)>,
    checks: Vec<(u64, u64)>,
    /// Label definitions seen so far: name -> (line, col) of the definition.
    defined: HashMap<String, (u32, u32)>,
    /// Label references in source order: (name, line, col).
    refs: Vec<(String, u32, u32)>,
}

impl<'a> Parser<'a> {
    fn err(&self, line: u32, col: u32, kind: ParseErrorKind) -> ParseError {
        ParseError { file: self.file.to_string(), line, col, kind }
    }

    fn reg_str(&self, s: &str, line: u32, col: u32) -> Result<u8, ParseError> {
        match s {
            "zero" => return Ok(0),
            "ra" => return Ok(LINK),
            _ => {}
        }
        if let Some(num) = s.strip_prefix('r') {
            if !num.is_empty() && num.bytes().all(|b| b.is_ascii_digit()) {
                if let Ok(n) = num.parse::<usize>() {
                    if n < NUM_ARCH_REGS {
                        return Ok(n as u8);
                    }
                }
            }
        }
        Err(self.err(line, col, ParseErrorKind::BadRegister(s.to_string())))
    }

    fn reg(&self, t: &Tok, line: u32) -> Result<u8, ParseError> {
        self.reg_str(&t.text, line, t.col)
    }

    fn atom(&self, a: &str, line: u32, col: u32) -> Result<u64, ParseError> {
        if let Some(name) = a.strip_prefix('$') {
            return self
                .args
                .iter()
                .find(|(n, _)| n == name)
                .map(|&(_, v)| v)
                .ok_or_else(|| self.err(line, col, ParseErrorKind::UnknownSymbol(a.to_string())));
        }
        if a.starts_with(|c: char| c.is_ascii_digit()) {
            return parse_u64_lit(a)
                .ok_or_else(|| self.err(line, col, ParseErrorKind::BadImmediate(a.to_string())));
        }
        match a {
            "LOCAL_BASE" => Ok(LOCAL_BASE),
            "FAR_BASE" => Ok(FAR_BASE),
            "FAR_END" => Ok(FAR_END),
            "SPM_BASE" => Ok(SPM_BASE),
            "SPM_END" => Ok(SPM_END),
            _ => Err(self.err(line, col, ParseErrorKind::UnknownSymbol(a.to_string()))),
        }
    }

    /// `atom (('*'|'/') atom)*` — `*` and `/` bind tighter than `+`/`-`.
    fn prod(
        &self,
        lex: &[Lx],
        i: &mut usize,
        whole: &str,
        line: u32,
        col: u32,
    ) -> Result<u64, ParseError> {
        let bad = || self.err(line, col, ParseErrorKind::BadImmediate(whole.to_string()));
        let mut v = match lex.get(*i) {
            Some(Lx::Atom(a)) => self.atom(a, line, col)?,
            _ => return Err(bad()),
        };
        *i += 1;
        while let Some(Lx::Op(op @ ('*' | '/'))) = lex.get(*i) {
            let op = *op;
            *i += 1;
            let rhs = match lex.get(*i) {
                Some(Lx::Atom(a)) => self.atom(a, line, col)?,
                _ => return Err(bad()),
            };
            *i += 1;
            v = if op == '*' {
                v.wrapping_mul(rhs)
            } else if rhs == 0 {
                return Err(bad());
            } else {
                v / rhs
            };
        }
        Ok(v)
    }

    /// Evaluate an immediate expression: `['-'] prod (('+'|'-') prod)*`,
    /// wrapping u64 arithmetic (negatives are two's-complement).
    fn eval_str(&self, s: &str, line: u32, col: u32) -> Result<u64, ParseError> {
        let bad = || self.err(line, col, ParseErrorKind::BadImmediate(s.to_string()));
        let lex = lex_expr(s);
        let mut i = 0usize;
        let neg = matches!(lex.first(), Some(Lx::Op('-')));
        if neg {
            i = 1;
        }
        let mut acc = self.prod(&lex, &mut i, s, line, col)?;
        if neg {
            acc = 0u64.wrapping_sub(acc);
        }
        while i < lex.len() {
            let op = match lex[i] {
                Lx::Op(op @ ('+' | '-')) => op,
                _ => return Err(bad()),
            };
            i += 1;
            let rhs = self.prod(&lex, &mut i, s, line, col)?;
            acc = if op == '+' { acc.wrapping_add(rhs) } else { acc.wrapping_sub(rhs) };
        }
        Ok(acc)
    }

    fn expr(&self, t: &Tok, line: u32) -> Result<u64, ParseError> {
        self.eval_str(&t.text, line, t.col)
    }

    fn imm(&self, t: &Tok, line: u32) -> Result<i64, ParseError> {
        Ok(self.expr(t, line)? as i64)
    }

    /// `off(reg)` address operand; an empty offset means 0.
    fn addr(&self, t: &Tok, line: u32) -> Result<(i64, u8), ParseError> {
        let s = &t.text;
        let bad = || self.err(line, t.col, ParseErrorKind::BadAddressOperand(s.clone()));
        let open = s.find('(').ok_or_else(bad)?;
        if !s.ends_with(')') || open + 2 > s.len() - 1 {
            return Err(bad());
        }
        let off_s = &s[..open];
        let reg_s = &s[open + 1..s.len() - 1];
        let off =
            if off_s.is_empty() { 0 } else { self.eval_str(off_s, line, t.col)? as i64 };
        let base = self.reg_str(reg_s, line, t.col + open as u32 + 1)?;
        Ok((off, base))
    }

    fn cfg_reg(&self, t: &Tok, line: u32) -> Result<CfgReg, ParseError> {
        match t.text.as_str() {
            "granularity" | "0" => Ok(CfgReg::Granularity),
            "queue_base" | "1" => Ok(CfgReg::QueueBase),
            "queue_length" | "2" => Ok(CfgReg::QueueLength),
            _ => Err(self.err(line, t.col, ParseErrorKind::BadCfgReg(t.text.clone()))),
        }
    }

    fn mem_size(&self, t: &Tok, line: u32) -> Result<u8, ParseError> {
        match t.text[2..].strip_prefix('.') {
            Some("1") => Ok(1),
            Some("2") => Ok(2),
            Some("4") => Ok(4),
            Some("8") => Ok(8),
            _ => Err(self.err(line, t.col, ParseErrorKind::BadSize(t.text.clone()))),
        }
    }

    fn expect_ops<'t>(
        &self,
        m: &Tok,
        ops: &'t [Tok],
        n: usize,
        expected: &'static str,
        line: u32,
    ) -> Result<&'t [Tok], ParseError> {
        if ops.len() != n {
            return Err(self.err(
                line,
                m.col,
                ParseErrorKind::WrongOperandCount {
                    mnemonic: m.text.clone(),
                    expected,
                    got: ops.len(),
                },
            ));
        }
        Ok(ops)
    }

    fn directive(&mut self, m: &Tok, ops: &[Tok], line: u32) -> Result<(), ParseError> {
        match m.text.as_str() {
            ".program" => {
                // The name was applied by the pre-scan (first occurrence
                // wins); here we only validate the operand count.
                self.expect_ops(m, ops, 1, "name", line)?;
            }
            ".arg" => {
                let o = self.expect_ops(m, ops, 2, "name value", line)?;
                let name = o[0].text.clone();
                if self.args.iter().any(|(n, _)| *n == name) {
                    return Err(self.err(line, o[0].col, ParseErrorKind::DuplicateArg(name)));
                }
                let v = self.expr(&o[1], line)?;
                self.args.push((name, v));
            }
            ".mem" => {
                if ops.len() < 2 {
                    return Err(self.err(
                        line,
                        m.col,
                        ParseErrorKind::WrongOperandCount {
                            mnemonic: m.text.clone(),
                            expected: "addr value...",
                            got: ops.len(),
                        },
                    ));
                }
                let base = self.expr(&ops[0], line)?;
                for (i, v) in ops[1..].iter().enumerate() {
                    let v = self.expr(v, line)?;
                    self.mem.push((base.wrapping_add(8 * i as u64), v));
                }
            }
            ".check" => {
                let o = self.expect_ops(m, ops, 2, "addr value", line)?;
                let addr = self.expr(&o[0], line)?;
                let v = self.expr(&o[1], line)?;
                self.checks.push((addr, v));
            }
            ".region" => {
                let o = self.expect_ops(m, ops, 1, "main|scheduler|disambig|setup", line)?;
                let r = match o[0].text.as_str() {
                    "main" => Region::Main,
                    "scheduler" => Region::Scheduler,
                    "disambig" => Region::Disambig,
                    "setup" => Region::Setup,
                    other => {
                        return Err(self.err(
                            line,
                            o[0].col,
                            ParseErrorKind::BadRegion(other.to_string()),
                        ))
                    }
                };
                self.asm.region(r);
            }
            ".addr_taken" => {
                let o = self.expect_ops(m, ops, 1, "label", line)?;
                self.refs.push((o[0].text.clone(), line, o[0].col));
                self.asm.mark_addr_taken(&o[0].text);
            }
            other => {
                return Err(self.err(
                    line,
                    m.col,
                    ParseErrorKind::UnknownDirective(other.to_string()),
                ))
            }
        }
        Ok(())
    }

    fn instruction(&mut self, m: &Tok, ops: &[Tok], line: u32) -> Result<(), ParseError> {
        match m.text.as_str() {
            "add" | "sub" | "xor" | "and" | "or" | "sll" | "srl" | "mul" | "sltu" => {
                let o = self.expect_ops(m, ops, 3, "rd, rs1, rs2", line)?;
                let rd = self.reg(&o[0], line)?;
                let rs1 = self.reg(&o[1], line)?;
                let rs2 = self.reg(&o[2], line)?;
                match m.text.as_str() {
                    "add" => self.asm.add(rd, rs1, rs2),
                    "sub" => self.asm.sub(rd, rs1, rs2),
                    "xor" => self.asm.xor(rd, rs1, rs2),
                    "and" => self.asm.and(rd, rs1, rs2),
                    "or" => self.asm.or(rd, rs1, rs2),
                    "sll" => self.asm.sll(rd, rs1, rs2),
                    "srl" => self.asm.srl(rd, rs1, rs2),
                    "mul" => self.asm.mul(rd, rs1, rs2),
                    _ => self.asm.sltu(rd, rs1, rs2),
                };
            }
            "addi" | "xori" | "andi" | "ori" | "slli" | "srli" => {
                let o = self.expect_ops(m, ops, 3, "rd, rs1, imm", line)?;
                let rd = self.reg(&o[0], line)?;
                let rs1 = self.reg(&o[1], line)?;
                let imm = self.imm(&o[2], line)?;
                match m.text.as_str() {
                    "addi" => self.asm.addi(rd, rs1, imm),
                    "xori" => self.asm.xori(rd, rs1, imm),
                    "andi" => self.asm.andi(rd, rs1, imm),
                    "ori" => self.asm.ori(rd, rs1, imm),
                    "slli" => self.asm.slli(rd, rs1, imm),
                    _ => self.asm.srli(rd, rs1, imm),
                };
            }
            "li" => {
                let o = self.expect_ops(m, ops, 2, "rd, imm|@label", line)?;
                let rd = self.reg(&o[0], line)?;
                if let Some(label) = o[1].text.strip_prefix('@') {
                    if label.is_empty() {
                        return Err(self.err(
                            line,
                            o[1].col,
                            ParseErrorKind::BadImmediate(o[1].text.clone()),
                        ));
                    }
                    self.refs.push((label.to_string(), line, o[1].col));
                    self.asm.li_label(rd, label);
                } else {
                    let imm = self.imm(&o[1], line)?;
                    self.asm.li(rd, imm);
                }
            }
            "mv" => {
                let o = self.expect_ops(m, ops, 2, "rd, rs", line)?;
                let rd = self.reg(&o[0], line)?;
                let rs = self.reg(&o[1], line)?;
                self.asm.mv(rd, rs);
            }
            "beq" | "bne" | "blt" | "bge" | "bltu" => {
                let o = self.expect_ops(m, ops, 3, "rs1, rs2, label", line)?;
                let rs1 = self.reg(&o[0], line)?;
                let rs2 = self.reg(&o[1], line)?;
                let target = o[2].text.as_str();
                self.refs.push((target.to_string(), line, o[2].col));
                match m.text.as_str() {
                    "beq" => self.asm.beq(rs1, rs2, target),
                    "bne" => self.asm.bne(rs1, rs2, target),
                    "blt" => self.asm.blt(rs1, rs2, target),
                    "bge" => self.asm.bge(rs1, rs2, target),
                    _ => self.asm.bltu(rs1, rs2, target),
                };
            }
            "j" => {
                let o = self.expect_ops(m, ops, 1, "label", line)?;
                self.refs.push((o[0].text.clone(), line, o[0].col));
                self.asm.j(&o[0].text);
            }
            "jal" => {
                let o = self.expect_ops(m, ops, 2, "rd, label", line)?;
                let rd = self.reg(&o[0], line)?;
                self.refs.push((o[1].text.clone(), line, o[1].col));
                self.asm.jal(rd, &o[1].text);
            }
            "jalr" => {
                let o = self.expect_ops(m, ops, 2, "rd, rs1", line)?;
                let rd = self.reg(&o[0], line)?;
                let rs1 = self.reg(&o[1], line)?;
                self.asm.jalr(rd, rs1);
            }
            "jr" => {
                let o = self.expect_ops(m, ops, 1, "rs1", line)?;
                let rs1 = self.reg(&o[0], line)?;
                self.asm.jr(rs1);
            }
            "call" => {
                let o = self.expect_ops(m, ops, 1, "label", line)?;
                self.refs.push((o[0].text.clone(), line, o[0].col));
                self.asm.call(&o[0].text);
            }
            "ret" => {
                self.expect_ops(m, ops, 0, "", line)?;
                self.asm.ret();
            }
            "prefetch" | "flush" => {
                let o = self.expect_ops(m, ops, 1, "off(base)", line)?;
                let (off, base) = self.addr(&o[0], line)?;
                if m.text.as_str() == "prefetch" {
                    self.asm.prefetch(base, off);
                } else {
                    self.asm.flush(base, off);
                }
            }
            "aload" | "astore" => {
                let o = self.expect_ops(m, ops, 3, "rd, spm, mem", line)?;
                let rd = self.reg(&o[0], line)?;
                let spm = self.reg(&o[1], line)?;
                let mem = self.reg(&o[2], line)?;
                if rd == spm || rd == mem {
                    return Err(self.err(
                        line,
                        o[0].col,
                        ParseErrorKind::AliasedRequestRegs(m.text.clone()),
                    ));
                }
                if m.text.as_str() == "aload" {
                    self.asm.aload(rd, spm, mem);
                } else {
                    self.asm.astore(rd, spm, mem);
                }
            }
            "getfin" => {
                let o = self.expect_ops(m, ops, 1, "rd", line)?;
                let rd = self.reg(&o[0], line)?;
                self.asm.getfin(rd);
            }
            "cfgwr" => {
                let o = self.expect_ops(m, ops, 2, "rs1, cfg", line)?;
                let rs1 = self.reg(&o[0], line)?;
                let cfg = self.cfg_reg(&o[1], line)?;
                self.asm.cfgwr(rs1, cfg);
            }
            "cfgrd" => {
                let o = self.expect_ops(m, ops, 2, "rd, cfg", line)?;
                let rd = self.reg(&o[0], line)?;
                let cfg = self.cfg_reg(&o[1], line)?;
                self.asm.cfgrd(rd, cfg);
            }
            "nop" => {
                self.expect_ops(m, ops, 0, "", line)?;
                self.asm.nop();
            }
            "halt" => {
                self.expect_ops(m, ops, 0, "", line)?;
                self.asm.halt();
            }
            "roi.begin" => {
                self.expect_ops(m, ops, 0, "", line)?;
                self.asm.roi_begin();
            }
            "roi.end" => {
                self.expect_ops(m, ops, 0, "", line)?;
                self.asm.roi_end();
            }
            t if t == "ld64" || t.starts_with("ld.") => {
                let size = if t == "ld64" { 8 } else { self.mem_size(m, line)? };
                let o = self.expect_ops(m, ops, 2, "rd, off(base)", line)?;
                let rd = self.reg(&o[0], line)?;
                let (off, base) = self.addr(&o[1], line)?;
                self.asm.ld(rd, base, off, size);
            }
            t if t == "st64" || t.starts_with("st.") => {
                let size = if t == "st64" { 8 } else { self.mem_size(m, line)? };
                let o = self.expect_ops(m, ops, 2, "src, off(base)", line)?;
                let src = self.reg(&o[0], line)?;
                let (off, base) = self.addr(&o[1], line)?;
                self.asm.st(src, base, off, size);
            }
            other => {
                return Err(self.err(
                    line,
                    m.col,
                    ParseErrorKind::UnknownMnemonic(other.to_string()),
                ))
            }
        }
        Ok(())
    }
}

/// Parse AMI assembly text into a [`ParsedProgram`]. `file` is used only
/// for error positions; the program name is the `.program` directive or,
/// absent one, `default_name`.
pub fn parse_str(src: &str, file: &str, default_name: &str) -> Result<ParsedProgram, ParseError> {
    // Pre-scan for the program name: Asm binds it at construction.
    let mut name = default_name.to_string();
    for line in src.lines() {
        let toks = tokenize(line);
        if toks.len() == 2 && toks[0].text == ".program" {
            name = toks[1].text.clone();
            break;
        }
    }

    let mut p = Parser {
        file,
        asm: Asm::new(&name),
        args: Vec::new(),
        mem: Vec::new(),
        checks: Vec::new(),
        defined: HashMap::new(),
        refs: Vec::new(),
    };

    for (ln0, line) in src.lines().enumerate() {
        let ln = ln0 as u32 + 1;
        let toks = tokenize(line);
        let mut idx = 0usize;
        while idx < toks.len() && toks[idx].text.len() > 1 && toks[idx].text.ends_with(':') {
            let t = &toks[idx];
            let lname = t.text[..t.text.len() - 1].to_string();
            if p.defined.contains_key(&lname) {
                return Err(p.err(ln, t.col, ParseErrorKind::DuplicateLabel(lname)));
            }
            p.defined.insert(lname.clone(), (ln, t.col));
            p.asm.label(&lname);
            idx += 1;
        }
        if idx >= toks.len() {
            continue;
        }
        let (m, ops) = (&toks[idx], &toks[idx + 1..]);
        if m.text.starts_with('.') {
            p.directive(m, ops, ln)?;
        } else {
            p.instruction(m, ops, ln)?;
        }
    }

    if p.asm.here() == 0 {
        return Err(p.err(1, 1, ParseErrorKind::EmptyProgram));
    }
    for (lname, ln, col) in &p.refs {
        if !p.defined.contains_key(lname) {
            return Err(p.err(*ln, *col, ParseErrorKind::UndefinedLabel(lname.clone())));
        }
    }
    let Parser { asm, args, mem, checks, file, .. } = p;
    // All duplicate/undefined labels were reported above with positions;
    // map any residual assembler error defensively (never panic).
    let prog = asm.try_finish().map_err(|e| {
        let kind = match e {
            AsmError::DuplicateLabel { label, .. } => ParseErrorKind::DuplicateLabel(label),
            AsmError::UndefinedLabel { label, .. } => ParseErrorKind::UndefinedLabel(label),
        };
        ParseError { file: file.to_string(), line: 1, col: 1, kind }
    })?;
    Ok(ParsedProgram { prog, args, mem, checks })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::inst::Opcode;

    fn parse(src: &str) -> ParsedProgram {
        parse_str(src, "<test>", "t").unwrap()
    }

    #[test]
    fn text_matches_builder_encoding() {
        let p = parse(
            "top: addi r1, r1, 1\n\
             ld.4 r2, 8(r1)\n\
             st64 r2, 0(r1)\n\
             bne r1, zero, top\n\
             halt\n",
        );
        let mut a = Asm::new("t");
        a.label("top");
        a.addi(1, 1, 1);
        a.ld(2, 1, 8, 4);
        a.st64(2, 1, 0);
        a.bne(1, 0, "top");
        a.halt();
        let b = a.finish();
        assert_eq!(p.prog.insts, b.insts);
        assert_eq!(p.prog.labels, b.labels);
    }

    #[test]
    fn expressions_and_args_evaluate() {
        let p = parse(
            ".arg n 64\n\
             .mem FAR_BASE+8 1 2\n\
             .check LOCAL_BASE $n*2-1\n\
             li r1, FAR_BASE+$n*8\n\
             li r2, -4\n\
             li r3, $n/4\n\
             halt\n",
        );
        assert_eq!(p.args, vec![("n".to_string(), 64)]);
        assert_eq!(p.mem, vec![(FAR_BASE + 8, 1), (FAR_BASE + 16, 2)]);
        assert_eq!(p.checks, vec![(LOCAL_BASE, 127)]);
        assert_eq!(p.prog.insts[0].imm, (FAR_BASE + 512) as i64);
        assert_eq!(p.prog.insts[1].imm, -4);
        assert_eq!(p.prog.insts[2].imm, 16);
    }

    #[test]
    fn li_label_and_addr_taken_resolve() {
        let p = parse(
            ".addr_taken task\n\
             li r1, @task\n\
             jalr r0, r1\n\
             task: halt\n",
        );
        assert_eq!(p.prog.insts[0].op, Opcode::Li);
        assert_eq!(p.prog.insts[0].imm, 2);
        assert_eq!(p.prog.addr_taken, vec![2]);
    }

    #[test]
    fn ami_forms_parse() {
        let p = parse(
            "li r1, 8\n\
             cfgwr r1, granularity\n\
             cfgrd r2, 2\n\
             aload r3, r4, r5\n\
             astore r6, r4, r5\n\
             getfin r7\n\
             halt\n",
        );
        assert_eq!(p.prog.insts[1].op, Opcode::CfgWr);
        assert_eq!(p.prog.insts[1].imm, CfgReg::Granularity as i64);
        assert_eq!(p.prog.insts[2].imm, CfgReg::QueueLength as i64);
        assert_eq!(p.prog.insts[3].op, Opcode::ALoad);
        assert_eq!(p.prog.insts[4].op, Opcode::AStore);
    }

    #[test]
    fn program_directive_names_the_program() {
        let p = parse(".program foo\nnop\nhalt\n");
        assert_eq!(p.prog.name, "foo");
        let q = parse("nop\nhalt\n");
        assert_eq!(q.prog.name, "t");
    }

    #[test]
    fn error_positions_are_exact() {
        let e = parse_str("nop\n  frobnicate r1\n", "f.asm", "t").unwrap_err();
        assert_eq!((e.line, e.col), (2, 3));
        assert_eq!(e.kind, ParseErrorKind::UnknownMnemonic("frobnicate".to_string()));
        assert_eq!(e.to_string(), "f.asm:2:3: unknown mnemonic 'frobnicate'");
    }
}
