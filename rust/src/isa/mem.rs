//! Guest physical address space: region map + sparse paged memory.
//!
//! Regions (fixed mapping, no translation modeled — the paper treats
//! address translation as orthogonal and assumes a conventional TLB):
//!
//! * `LOCAL`  — local DDR4 DRAM.
//! * `FAR`    — far memory behind the serial link (CXL-like).
//! * `SPM`    — the L2 carve-out scratchpad: fixed-latency, never misses.
//!
//! Workload setup uses the bump allocators in [`Layout`]; the simulated
//! core and the functional interpreter both read/write through [`GuestMem`].

use std::collections::HashMap;

pub const LOCAL_BASE: u64 = 0x0000_0000_1000_0000;
pub const LOCAL_END: u64 = 0x0000_0010_0000_0000;
pub const FAR_BASE: u64 = 0x0000_0040_0000_0000;
pub const FAR_END: u64 = 0x0000_0080_0000_0000;
pub const SPM_BASE: u64 = 0x0000_00F0_0000_0000;
/// Generous bound; the configured SPM data area is much smaller.
pub const SPM_END: u64 = SPM_BASE + (1 << 20);

pub const PAGE_BYTES: usize = 4096;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemRegion {
    Local,
    Far,
    Spm,
}

pub fn region_of(addr: u64) -> MemRegion {
    if (FAR_BASE..FAR_END).contains(&addr) {
        MemRegion::Far
    } else if (SPM_BASE..SPM_END).contains(&addr) {
        MemRegion::Spm
    } else {
        MemRegion::Local
    }
}

/// Sparse paged guest memory with a one-page lookup cache.
#[derive(Default)]
pub struct GuestMem {
    pages: HashMap<u64, Box<[u8; PAGE_BYTES]>>,
    last_page: Option<(u64, *mut [u8; PAGE_BYTES])>,
}

// SAFETY: the raw pointer cache is only used single-threaded and is
// invalidated on any structural change (we never remove pages).
unsafe impl Send for GuestMem {}

impl GuestMem {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn page_mut(&mut self, pno: u64) -> &mut [u8; PAGE_BYTES] {
        if let Some((cached, ptr)) = self.last_page {
            if cached == pno {
                // SAFETY: pages are boxed (stable addresses) and never freed.
                return unsafe { &mut *ptr };
            }
        }
        let page = self
            .pages
            .entry(pno)
            .or_insert_with(|| Box::new([0u8; PAGE_BYTES]));
        let ptr: *mut [u8; PAGE_BYTES] = &mut **page;
        self.last_page = Some((pno, ptr));
        unsafe { &mut *ptr }
    }

    /// Read `size` (1/2/4/8) bytes, zero-extended. Unaligned and
    /// page-crossing accesses are supported (byte loop fallback).
    #[inline]
    pub fn read(&mut self, addr: u64, size: u8) -> u64 {
        let pno = addr / PAGE_BYTES as u64;
        let off = (addr % PAGE_BYTES as u64) as usize;
        if off + size as usize <= PAGE_BYTES {
            let page = self.page_mut(pno);
            let mut buf = [0u8; 8];
            buf[..size as usize].copy_from_slice(&page[off..off + size as usize]);
            u64::from_le_bytes(buf)
        } else {
            let mut v = 0u64;
            for i in 0..size as u64 {
                v |= (self.read(addr + i, 1) & 0xff) << (8 * i);
            }
            v
        }
    }

    /// Write the low `size` bytes of `value`.
    #[inline]
    pub fn write(&mut self, addr: u64, size: u8, value: u64) {
        let pno = addr / PAGE_BYTES as u64;
        let off = (addr % PAGE_BYTES as u64) as usize;
        if off + size as usize <= PAGE_BYTES {
            let page = self.page_mut(pno);
            page[off..off + size as usize]
                .copy_from_slice(&value.to_le_bytes()[..size as usize]);
        } else {
            for i in 0..size as u64 {
                self.write(addr + i, 1, (value >> (8 * i)) & 0xff);
            }
        }
    }

    pub fn read_u64(&mut self, addr: u64) -> u64 {
        self.read(addr, 8)
    }

    pub fn write_u64(&mut self, addr: u64, value: u64) {
        self.write(addr, 8, value)
    }

    /// Bulk copy helpers (workload setup, AMU block transfers).
    pub fn write_block(&mut self, addr: u64, data: &[u8]) {
        for (i, b) in data.iter().enumerate() {
            self.write(addr + i as u64, 1, *b as u64);
        }
    }

    pub fn read_block(&mut self, addr: u64, len: usize) -> Vec<u8> {
        (0..len).map(|i| self.read(addr + i as u64, 1) as u8).collect()
    }

    /// Copy `len` bytes inside guest memory (AMU data movement).
    pub fn copy(&mut self, dst: u64, src: u64, len: usize) {
        // Buffered to tolerate overlap.
        let data = self.read_block(src, len);
        self.write_block(dst, &data);
    }

    /// FNV-1a checksum of a block (workload result validation).
    pub fn checksum(&mut self, addr: u64, len: usize) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for i in 0..len {
            h ^= self.read(addr + i as u64, 1);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    pub fn page_count(&self) -> usize {
        self.pages.len()
    }
}

/// Bump allocators per region for workload setup.
#[derive(Debug, Clone)]
pub struct Layout {
    local_brk: u64,
    far_brk: u64,
    spm_brk: u64,
    spm_limit: u64,
}

impl Layout {
    /// `spm_data_bytes` is the software-visible SPM data area (total SPM
    /// minus the AMART metadata area managed by the ASMC).
    pub fn new(spm_data_bytes: usize) -> Self {
        Self {
            local_brk: LOCAL_BASE,
            far_brk: FAR_BASE,
            spm_brk: SPM_BASE,
            spm_limit: SPM_BASE + spm_data_bytes as u64,
        }
    }

    fn bump(brk: &mut u64, size: u64, align: u64) -> u64 {
        let a = align.max(1);
        let base = (*brk + a - 1) / a * a;
        *brk = base + size;
        base
    }

    pub fn alloc_local(&mut self, size: u64, align: u64) -> u64 {
        assert!(self.local_brk + size < LOCAL_END, "local region exhausted");
        Self::bump(&mut self.local_brk, size, align)
    }

    pub fn alloc_far(&mut self, size: u64, align: u64) -> u64 {
        assert!(self.far_brk + size < FAR_END, "far region exhausted");
        Self::bump(&mut self.far_brk, size, align)
    }

    /// SPM data-area allocation; panics if the program over-allocates the
    /// scratchpad — a real bug in a workload port.
    pub fn alloc_spm(&mut self, size: u64, align: u64) -> u64 {
        let base = Self::bump(&mut self.spm_brk, size, align);
        assert!(
            self.spm_brk <= self.spm_limit,
            "SPM data area exhausted: need {} more bytes",
            self.spm_brk - self.spm_limit
        );
        base
    }

    pub fn spm_remaining(&self) -> u64 {
        self.spm_limit - self.spm_brk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_classification() {
        assert_eq!(region_of(LOCAL_BASE), MemRegion::Local);
        assert_eq!(region_of(FAR_BASE), MemRegion::Far);
        assert_eq!(region_of(FAR_BASE + 0x1000), MemRegion::Far);
        assert_eq!(region_of(SPM_BASE + 16), MemRegion::Spm);
    }

    #[test]
    fn read_write_roundtrip_sizes() {
        let mut m = GuestMem::new();
        m.write(LOCAL_BASE, 8, 0x1122_3344_5566_7788);
        assert_eq!(m.read(LOCAL_BASE, 8), 0x1122_3344_5566_7788);
        assert_eq!(m.read(LOCAL_BASE, 4), 0x5566_7788);
        assert_eq!(m.read(LOCAL_BASE, 2), 0x7788);
        assert_eq!(m.read(LOCAL_BASE, 1), 0x88);
        m.write(LOCAL_BASE + 3, 2, 0xABCD);
        assert_eq!(m.read(LOCAL_BASE + 3, 2), 0xABCD);
    }

    #[test]
    fn page_crossing_access() {
        let mut m = GuestMem::new();
        let addr = LOCAL_BASE + PAGE_BYTES as u64 - 3;
        m.write(addr, 8, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(m.read(addr, 8), 0xDEAD_BEEF_CAFE_F00D);
    }

    #[test]
    fn zero_initialized() {
        let mut m = GuestMem::new();
        assert_eq!(m.read(FAR_BASE + 12345, 8), 0);
    }

    #[test]
    fn copy_and_checksum() {
        let mut m = GuestMem::new();
        let data: Vec<u8> = (0..=255).collect();
        m.write_block(FAR_BASE, &data);
        m.copy(SPM_BASE, FAR_BASE, 256);
        assert_eq!(m.read_block(SPM_BASE, 256), data);
        assert_eq!(m.checksum(SPM_BASE, 256), m.checksum(FAR_BASE, 256));
    }

    #[test]
    fn layout_alignment_and_regions() {
        let mut l = Layout::new(48 * 1024);
        let a = l.alloc_local(100, 64);
        assert_eq!(a % 64, 0);
        assert_eq!(region_of(a), MemRegion::Local);
        let f = l.alloc_far(1 << 20, 4096);
        assert_eq!(f % 4096, 0);
        assert_eq!(region_of(f), MemRegion::Far);
        let s = l.alloc_spm(1024, 64);
        assert_eq!(region_of(s), MemRegion::Spm);
    }

    #[test]
    #[should_panic(expected = "SPM data area exhausted")]
    fn spm_overallocation_panics() {
        let mut l = Layout::new(1024);
        l.alloc_spm(2048, 8);
    }
}
