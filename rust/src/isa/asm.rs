//! Assembler for guest programs: label resolution, typed emit helpers,
//! one-level call/ret pseudo-ops, and region tagging for stats attribution.

use super::inst::{CfgReg, Inst, Opcode, Program, LINK};
use std::collections::HashMap;

/// Assemble-time error, naming the offending label. Surfaced by
/// `try_finish`; `finish` panics with the same message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AsmError {
    /// The same label was defined at two instruction indices.
    DuplicateLabel { label: String, first: usize, second: usize },
    /// A branch/jump/`li_label` referenced a label that was never defined.
    UndefinedLabel { label: String, at: usize },
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsmError::DuplicateLabel { label, first, second } => write!(
                f,
                "duplicate label '{label}' (defined at inst {first} and again at inst {second})"
            ),
            AsmError::UndefinedLabel { label, at } => {
                write!(f, "undefined label '{label}' (at inst {at})")
            }
        }
    }
}

impl std::error::Error for AsmError {}

#[derive(Default)]
pub struct Asm {
    insts: Vec<Inst>,
    labels: HashMap<String, usize>,
    fixups: Vec<(usize, String)>,
    /// Labels whose instruction address escapes into a register
    /// (`li_label` continuations, explicit `mark_addr_taken`): recorded as
    /// `(reference site, label)` so an undefined name reports a location.
    /// Resolved into `Program::addr_taken` — the verifier's `jalr`
    /// indirect-target set.
    taken: Vec<(usize, String)>,
    /// Duplicate definitions recorded by `label()`, reported at finish time.
    duplicates: Vec<AsmError>,
    region: u8,
    name: String,
}

impl Asm {
    pub fn new(name: &str) -> Self {
        Asm { name: name.to_string(), ..Default::default() }
    }

    /// Current instruction index (next emit position).
    pub fn here(&self) -> usize {
        self.insts.len()
    }

    /// Set the stats attribution region for subsequently emitted code.
    pub fn region(&mut self, r: crate::stats::Region) -> &mut Self {
        self.region = r as u8;
        self
    }

    pub fn label(&mut self, name: &str) -> &mut Self {
        let at = self.here();
        if let Some(first) = self.labels.insert(name.to_string(), at) {
            self.duplicates.push(AsmError::DuplicateLabel {
                label: name.to_string(),
                first,
                second: at,
            });
        }
        self
    }

    fn emit(&mut self, op: Opcode, rd: u8, rs1: u8, rs2: u8, imm: i64, size: u8) -> &mut Self {
        self.insts.push(Inst { op, rd, rs1, rs2, imm, size, region: self.region });
        self
    }

    fn emit_branch(&mut self, op: Opcode, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        let at = self.here();
        self.fixups.push((at, target.to_string()));
        self.emit(op, 0, rs1, rs2, 0, 0)
    }

    // --- ALU ---
    pub fn add(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Add, rd, rs1, rs2, 0, 0)
    }
    pub fn sub(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Sub, rd, rs1, rs2, 0, 0)
    }
    pub fn xor(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Xor, rd, rs1, rs2, 0, 0)
    }
    pub fn and(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::And, rd, rs1, rs2, 0, 0)
    }
    pub fn or(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Or, rd, rs1, rs2, 0, 0)
    }
    pub fn sll(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Sll, rd, rs1, rs2, 0, 0)
    }
    pub fn srl(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Srl, rd, rs1, rs2, 0, 0)
    }
    pub fn mul(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::Mul, rd, rs1, rs2, 0, 0)
    }
    pub fn sltu(&mut self, rd: u8, rs1: u8, rs2: u8) -> &mut Self {
        self.emit(Opcode::SltU, rd, rs1, rs2, 0, 0)
    }
    pub fn addi(&mut self, rd: u8, rs1: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Addi, rd, rs1, 0, imm, 0)
    }
    pub fn xori(&mut self, rd: u8, rs1: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Xori, rd, rs1, 0, imm, 0)
    }
    pub fn andi(&mut self, rd: u8, rs1: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Andi, rd, rs1, 0, imm, 0)
    }
    pub fn ori(&mut self, rd: u8, rs1: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Ori, rd, rs1, 0, imm, 0)
    }
    pub fn slli(&mut self, rd: u8, rs1: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Slli, rd, rs1, 0, imm, 0)
    }
    pub fn srli(&mut self, rd: u8, rs1: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Srli, rd, rs1, 0, imm, 0)
    }
    pub fn li(&mut self, rd: u8, imm: i64) -> &mut Self {
        self.emit(Opcode::Li, rd, 0, 0, imm, 0)
    }
    /// Load the instruction index of `target` into `rd` (continuation
    /// pointers for the coroutine runtime).
    pub fn li_label(&mut self, rd: u8, target: &str) -> &mut Self {
        let at = self.here();
        self.fixups.push((at, target.to_string()));
        self.taken.push((at, target.to_string()));
        self.emit(Opcode::Li, rd, 0, 0, 0, 0)
    }

    /// Declare that `label`'s address escapes into a register outside the
    /// assembled code (e.g. a host-written TCB resume pointer). The
    /// verifier then treats the label as a possible `jalr` target.
    pub fn mark_addr_taken(&mut self, label: &str) -> &mut Self {
        self.taken.push((self.here(), label.to_string()));
        self
    }
    pub fn mv(&mut self, rd: u8, rs: u8) -> &mut Self {
        self.addi(rd, rs, 0)
    }

    // --- memory ---
    pub fn ld(&mut self, rd: u8, base: u8, off: i64, size: u8) -> &mut Self {
        self.emit(Opcode::Ld, rd, base, 0, off, size)
    }
    pub fn st(&mut self, src: u8, base: u8, off: i64, size: u8) -> &mut Self {
        self.emit(Opcode::St, 0, base, src, off, size)
    }
    pub fn ld64(&mut self, rd: u8, base: u8, off: i64) -> &mut Self {
        self.ld(rd, base, off, 8)
    }
    pub fn st64(&mut self, src: u8, base: u8, off: i64) -> &mut Self {
        self.st(src, base, off, 8)
    }
    pub fn prefetch(&mut self, base: u8, off: i64) -> &mut Self {
        self.emit(Opcode::Prefetch, 0, base, 0, off, 64)
    }
    pub fn flush(&mut self, base: u8, off: i64) -> &mut Self {
        self.emit(Opcode::Flush, 0, base, 0, off, 64)
    }

    // --- control ---
    pub fn beq(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.emit_branch(Opcode::Beq, rs1, rs2, target)
    }
    pub fn bne(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.emit_branch(Opcode::Bne, rs1, rs2, target)
    }
    pub fn blt(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.emit_branch(Opcode::Blt, rs1, rs2, target)
    }
    pub fn bge(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.emit_branch(Opcode::Bge, rs1, rs2, target)
    }
    pub fn bltu(&mut self, rs1: u8, rs2: u8, target: &str) -> &mut Self {
        self.emit_branch(Opcode::BltU, rs1, rs2, target)
    }
    pub fn j(&mut self, target: &str) -> &mut Self {
        let at = self.here();
        self.fixups.push((at, target.to_string()));
        self.emit(Opcode::Jal, 0, 0, 0, 0, 0)
    }
    /// jal rd, label — rd receives the return instruction index.
    pub fn jal(&mut self, rd: u8, target: &str) -> &mut Self {
        let at = self.here();
        self.fixups.push((at, target.to_string()));
        self.emit(Opcode::Jal, rd, 0, 0, 0, 0)
    }
    /// Indirect jump to the instruction index in `rs1`; `rd` gets the link.
    pub fn jalr(&mut self, rd: u8, rs1: u8) -> &mut Self {
        self.emit(Opcode::Jalr, rd, rs1, 0, 0, 0)
    }
    pub fn jr(&mut self, rs1: u8) -> &mut Self {
        self.jalr(0, rs1)
    }
    /// One-level call using the conventional link register r63.
    pub fn call(&mut self, target: &str) -> &mut Self {
        self.jal(LINK, target)
    }
    pub fn ret(&mut self) -> &mut Self {
        self.jr(LINK)
    }

    // --- AMI ---
    pub fn aload(&mut self, rd: u8, spm: u8, mem: u8) -> &mut Self {
        // rd is written by the ID-allocation µop *before* the request µop
        // reads rs1/rs2; aliasing them would feed the request the ID.
        assert!(rd != spm && rd != mem, "aload: rd must not alias rs1/rs2");
        self.emit(Opcode::ALoad, rd, spm, mem, 0, 0)
    }
    pub fn astore(&mut self, rd: u8, spm: u8, mem: u8) -> &mut Self {
        assert!(rd != spm && rd != mem, "astore: rd must not alias rs1/rs2");
        self.emit(Opcode::AStore, rd, spm, mem, 0, 0)
    }
    pub fn getfin(&mut self, rd: u8) -> &mut Self {
        self.emit(Opcode::GetFin, rd, 0, 0, 0, 0)
    }
    pub fn cfgwr(&mut self, rs1: u8, cfg: CfgReg) -> &mut Self {
        self.emit(Opcode::CfgWr, 0, rs1, 0, cfg as i64, 0)
    }
    pub fn cfgrd(&mut self, rd: u8, cfg: CfgReg) -> &mut Self {
        self.emit(Opcode::CfgRd, rd, 0, 0, cfg as i64, 0)
    }

    // --- misc ---
    pub fn nop(&mut self) -> &mut Self {
        self.emit(Opcode::Nop, 0, 0, 0, 0, 0)
    }
    pub fn halt(&mut self) -> &mut Self {
        self.emit(Opcode::Halt, 0, 0, 0, 0, 0)
    }
    pub fn roi_begin(&mut self) -> &mut Self {
        self.emit(Opcode::Roi, 0, 0, 0, 1, 0)
    }
    pub fn roi_end(&mut self) -> &mut Self {
        self.emit(Opcode::Roi, 0, 0, 0, 0, 0)
    }

    /// Emit `n` dependent ALU ops on `r` — models fixed software overhead
    /// (e.g. context save/restore work we don't spell out instruction by
    /// instruction).
    pub fn burn(&mut self, r: u8, n: usize) -> &mut Self {
        for _ in 0..n {
            self.addi(r, r, 1);
        }
        self
    }

    /// Resolve labels and produce the program, reporting duplicate label
    /// definitions and unresolved references as typed errors.
    pub fn try_finish(mut self) -> Result<Program, AsmError> {
        if let Some(err) = self.duplicates.into_iter().next() {
            return Err(err);
        }
        for (at, name) in &self.fixups {
            let target = *self.labels.get(name).ok_or_else(|| AsmError::UndefinedLabel {
                label: name.clone(),
                at: *at,
            })?;
            self.insts[*at].imm = target as i64;
        }
        let mut addr_taken = Vec::with_capacity(self.taken.len());
        for (at, name) in &self.taken {
            let target = *self.labels.get(name).ok_or_else(|| AsmError::UndefinedLabel {
                label: name.clone(),
                at: *at,
            })?;
            addr_taken.push(target);
        }
        addr_taken.sort_unstable();
        addr_taken.dedup();
        let mut labels: Vec<(String, usize)> = self.labels.into_iter().collect();
        labels.sort_by_key(|(_, at)| *at);
        Ok(Program { name: self.name, insts: self.insts, labels, addr_taken })
    }

    /// Resolve labels and produce the program; panics on assembly errors
    /// (the hand-written built-in benchmarks use this — a bad label there
    /// is a build bug, not a runtime condition).
    pub fn finish(self) -> Program {
        match self.try_finish() {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::inst::Opcode;

    #[test]
    fn forward_and_backward_labels_resolve() {
        let mut a = Asm::new("t");
        a.label("top");
        a.addi(1, 1, 1);
        a.bne(1, 2, "done"); // forward
        a.j("top"); // backward
        a.label("done");
        a.halt();
        let p = a.finish();
        assert_eq!(p.insts[1].imm, 3); // "done"
        assert_eq!(p.insts[2].imm, 0); // "top"
    }

    #[test]
    #[should_panic(expected = "undefined label")]
    fn undefined_label_panics() {
        let mut a = Asm::new("t");
        a.j("nowhere");
        a.finish();
    }

    #[test]
    #[should_panic(expected = "duplicate label")]
    fn duplicate_label_panics() {
        let mut a = Asm::new("t");
        a.label("x");
        a.nop();
        a.label("x");
        a.finish();
    }

    #[test]
    fn try_finish_reports_duplicate_label() {
        let mut a = Asm::new("t");
        a.label("x");
        a.nop();
        a.label("x");
        a.halt();
        let err = a.try_finish().unwrap_err();
        assert_eq!(
            err,
            AsmError::DuplicateLabel { label: "x".into(), first: 0, second: 1 }
        );
        assert!(err.to_string().contains("duplicate label 'x'"));
    }

    #[test]
    fn try_finish_reports_undefined_label() {
        let mut a = Asm::new("t");
        a.j("nowhere");
        a.halt();
        let err = a.try_finish().unwrap_err();
        assert_eq!(err, AsmError::UndefinedLabel { label: "nowhere".into(), at: 0 });
        assert!(err.to_string().contains("undefined label 'nowhere'"));
    }

    #[test]
    fn region_tagging() {
        let mut a = Asm::new("t");
        a.nop();
        a.region(crate::stats::Region::Disambig);
        a.nop();
        a.region(crate::stats::Region::Main);
        a.nop();
        let p = a.finish();
        assert_eq!(p.insts[0].region, 0);
        assert_eq!(p.insts[1].region, 2);
        assert_eq!(p.insts[2].region, 0);
    }

    #[test]
    fn emit_helpers_encode_correctly() {
        let mut a = Asm::new("t");
        a.ld64(5, 6, 24);
        a.st(7, 8, -8, 4);
        a.aload(1, 2, 3);
        let p = a.finish();
        let ld = p.insts[0];
        assert_eq!((ld.op, ld.rd, ld.rs1, ld.imm, ld.size), (Opcode::Ld, 5, 6, 24, 8));
        let st = p.insts[1];
        assert_eq!((st.op, st.rs1, st.rs2, st.imm, st.size), (Opcode::St, 8, 7, -8, 4));
        let al = p.insts[2];
        assert_eq!((al.op, al.rd, al.rs1, al.rs2), (Opcode::ALoad, 1, 2, 3));
    }

    #[test]
    fn call_ret_use_link() {
        let mut a = Asm::new("t");
        a.call("f");
        a.halt();
        a.label("f");
        a.ret();
        let p = a.finish();
        assert_eq!(p.insts[0].rd, LINK);
        assert_eq!(p.insts[0].imm, 2);
        assert_eq!(p.insts[2].rs1, LINK);
    }
}
