//! Static analysis over assembled guest programs (`amu-sim check`).
//!
//! AMI decouples request issue (`aload`/`astore`) from response handling
//! (`getfin`), with request state parked in SPM — so a guest program can be
//! silently wrong in ways synchronous load/store code cannot: requests
//! issued before the AMART queue is configured, SPM operands that alias the
//! configured queue region, issue/drain imbalance that leaks request IDs,
//! or unbalanced ROI markers that corrupt the measurement window. This
//! module machine-checks every program before it reaches the
//! cycle-accurate pipeline.
//!
//! The pass builds a CFG over instruction indices (branch/`jal`/`jalr`/
//! `halt` terminators; indirect jumps over-approximated by the set of
//! labels and call-return sites) and runs four analysis families:
//!
//! 1. **structural** — out-of-bounds jump targets, fall-through off the
//!    program end, unreachable instructions, dead writes to hardwired `r0`;
//! 2. **register dataflow** — use-before-def via a forward
//!    may-be-uninitialized analysis (info-level: registers reset to zero);
//! 3. **AMI protocol** — queue configuration dominating every issue,
//!    constant-propagated SPM operands inside the scratchpad and outside
//!    the configured queue region, issue/drain balance, valid `CfgReg`
//!    indices, no queue reconfiguration with requests in flight;
//! 4. **measurement hygiene** — `roi` begin/end paired on all paths,
//!    `flush` between constant-address sync far accesses and async issue.
//!
//! The CFG over-approximates indirect control flow (a `jalr` may target any
//! label or call-return site), so path-sensitive checks are conservative:
//! they never miss a violation on a real path, but exotic external programs
//! may need restructuring to verify cleanly. Every built-in benchmark
//! passes with zero deny- and warn-level findings (enforced in CI by
//! `amu-sim check --all --deny-warnings`).

use super::inst::{CfgReg, Inst, Opcode, Program, NUM_ARCH_REGS};
use super::mem::{region_of, MemRegion};

/// Diagnostic severity. `Deny` findings make `run`/`sweep`/`mtrun` refuse
/// the program; `Warn` findings fail `amu-sim check --deny-warnings`;
/// `Info` findings never gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Deny,
}

impl Severity {
    pub fn tag(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// Typed diagnostic codes. Stable identifiers: tests, CI and the README
/// table key off these strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    /// AMI001: branch/jump target outside the program.
    BadTarget,
    /// AMI002: execution can fall through past the last instruction.
    FallsOffEnd,
    /// AMI003: instruction unreachable from entry.
    Unreachable,
    /// AMI004: ALU/load result written to hardwired `r0` (discarded).
    DeadWrite,
    /// AMI005: register may be read before its first write.
    MaybeUninit,
    /// AMI006: `cfgwr`/`cfgrd` immediate names no configuration register.
    BadCfgIndex,
    /// AMI007: issue on a path where the queue configuration (`cfgwr`
    /// `QueueBase`/`QueueLength`) has not executed, in a program that does
    /// configure the queue elsewhere.
    QueueCfgNotDominating,
    /// AMI008: queue reconfigured while requests may be in flight.
    QueueReconfigInFlight,
    /// AMI009: constant SPM operand outside the scratchpad (or inside the
    /// configured AMART queue region).
    SpmOperandOutOfRange,
    /// AMI010: constant memory operand inside the scratchpad.
    MemOperandInSpm,
    /// AMI011: async requests issued but the program contains no
    /// reachable `getfin` drain.
    IssueWithoutDrain,
    /// AMI012: request ID written to `r0` — the request can never be
    /// awaited individually.
    DiscardedRequestId,
    /// AMI013: `getfin` polling in a program that never issues a request.
    DrainWithoutIssue,
    /// AMI014: unbalanced `roi` markers: a begin with the window already
    /// open on every path, an end with it open on no path, or a halt with
    /// it open on every path. (Must-style conditions: the indirect-jump
    /// over-approximation makes may-style ROI checks fire spuriously on
    /// the coroutine scheduler.)
    RoiImbalance,
    /// AMI015: constant-address sync far access followed by an async
    /// issue with no intervening `flush` (sync->async region transition).
    MissingFlush,
}

/// Every diagnostic code, in ascending `AMIxxx` order (the README table
/// and the negative-corpus test iterate this).
pub const ALL_CODES: &[Code] = &[
    Code::BadTarget,
    Code::FallsOffEnd,
    Code::Unreachable,
    Code::DeadWrite,
    Code::MaybeUninit,
    Code::BadCfgIndex,
    Code::QueueCfgNotDominating,
    Code::QueueReconfigInFlight,
    Code::SpmOperandOutOfRange,
    Code::MemOperandInSpm,
    Code::IssueWithoutDrain,
    Code::DiscardedRequestId,
    Code::DrainWithoutIssue,
    Code::RoiImbalance,
    Code::MissingFlush,
];

impl Code {
    pub fn tag(&self) -> &'static str {
        match self {
            Code::BadTarget => "AMI001",
            Code::FallsOffEnd => "AMI002",
            Code::Unreachable => "AMI003",
            Code::DeadWrite => "AMI004",
            Code::MaybeUninit => "AMI005",
            Code::BadCfgIndex => "AMI006",
            Code::QueueCfgNotDominating => "AMI007",
            Code::QueueReconfigInFlight => "AMI008",
            Code::SpmOperandOutOfRange => "AMI009",
            Code::MemOperandInSpm => "AMI010",
            Code::IssueWithoutDrain => "AMI011",
            Code::DiscardedRequestId => "AMI012",
            Code::DrainWithoutIssue => "AMI013",
            Code::RoiImbalance => "AMI014",
            Code::MissingFlush => "AMI015",
        }
    }

    pub fn severity(&self) -> Severity {
        match self {
            Code::BadTarget
            | Code::FallsOffEnd
            | Code::BadCfgIndex
            | Code::QueueCfgNotDominating
            | Code::QueueReconfigInFlight
            | Code::SpmOperandOutOfRange
            | Code::MemOperandInSpm
            | Code::IssueWithoutDrain
            | Code::RoiImbalance => Severity::Deny,
            Code::DeadWrite
            | Code::DiscardedRequestId
            | Code::DrainWithoutIssue => Severity::Warn,
            // Unreachable defensive padding after indirect jumps is a
            // deliberate idiom in the coroutine scheduler, registers
            // architecturally reset to zero, and the far-dirty bit is a
            // may-fact over an over-approximated CFG — notes, not gates.
            Code::Unreachable | Code::MaybeUninit | Code::MissingFlush => Severity::Info,
        }
    }

    /// One-line meaning for the README table and `check` summaries.
    pub fn meaning(&self) -> &'static str {
        match self {
            Code::BadTarget => "branch/jump target outside the program",
            Code::FallsOffEnd => "execution can fall through past the last instruction",
            Code::Unreachable => "instruction unreachable from entry",
            Code::DeadWrite => "result written to hardwired r0 and discarded",
            Code::MaybeUninit => "register may be read before its first write",
            Code::BadCfgIndex => "cfgwr/cfgrd immediate names no configuration register",
            Code::QueueCfgNotDominating => {
                "issue on a path where the AMART queue configuration has not executed"
            }
            Code::QueueReconfigInFlight => {
                "queue reconfigured while async requests may be in flight"
            }
            Code::SpmOperandOutOfRange => {
                "SPM operand outside the scratchpad or inside the configured queue region"
            }
            Code::MemOperandInSpm => "memory operand of an async request inside the scratchpad",
            Code::IssueWithoutDrain => "async requests issued but no getfin drain is reachable",
            Code::DiscardedRequestId => "request id written to r0; request cannot be awaited",
            Code::DrainWithoutIssue => "getfin polling but the program never issues a request",
            Code::RoiImbalance => "roi begin/end unbalanced on some path",
            Code::MissingFlush => "sync far access reaches an async issue without a flush",
        }
    }
}

/// One finding: code, location (instruction index), enclosing label
/// context, and a concrete message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: Code,
    /// Instruction index the finding anchors to.
    pub at: usize,
    /// Nearest label at or before `at` (empty if none).
    pub label: String,
    pub message: String,
}

impl Diagnostic {
    pub fn severity(&self) -> Severity {
        self.code.severity()
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ctx = if self.label.is_empty() { "-".to_string() } else { self.label.clone() };
        write!(
            f,
            "{} {} @{} ({}): {}",
            self.code.tag(),
            self.severity().tag(),
            self.at,
            ctx,
            self.message
        )
    }
}

/// The verifier's result for one program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Report {
    /// `Program::name` of the verified program.
    pub program: String,
    /// Program length in instructions.
    pub insts: usize,
    /// All findings, sorted by instruction index then code.
    pub diags: Vec<Diagnostic>,
}

impl Report {
    pub fn count(&self, sev: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity() == sev).count()
    }

    pub fn deny_count(&self) -> usize {
        self.count(Severity::Deny)
    }

    pub fn warn_count(&self) -> usize {
        self.count(Severity::Warn)
    }

    /// Does this report gate execution? With `deny_warnings`, warn-level
    /// findings gate too (the CI configuration).
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        self.deny_count() == 0 && (!deny_warnings || self.warn_count() == 0)
    }

    /// Render findings at or above `min` as a fixed-width diagnostics
    /// table (golden-pinned; `amu-sim check` output).
    pub fn render_table(&self, min: Severity) -> String {
        let mut s = String::new();
        for d in self.diags.iter().filter(|d| d.severity() >= min) {
            let ctx = if d.label.is_empty() { "-" } else { &d.label };
            s.push_str(&format!(
                "  {} {:<4} @{:<5} {:<14} {}\n",
                d.code.tag(),
                d.severity().tag(),
                d.at,
                ctx,
                d.message
            ));
        }
        s
    }

    /// Compact one-line summary of the deny-level findings, for errors
    /// raised by the fail-fast hook in the workload registry.
    pub fn deny_summary(&self) -> String {
        let denies: Vec<String> = self
            .diags
            .iter()
            .filter(|d| d.severity() == Severity::Deny)
            .take(3)
            .map(|d| d.to_string())
            .collect();
        let extra = self.deny_count().saturating_sub(denies.len());
        let mut s = denies.join("; ");
        if extra > 0 {
            s.push_str(&format!("; +{extra} more"));
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Constant lattice
// ---------------------------------------------------------------------------

/// Forward constant-propagation value: a register either holds one known
/// constant on every path reaching a point, or is `Top`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cv {
    Const(u64),
    Top,
}

impl Cv {
    fn join(self, other: Cv) -> Cv {
        match (self, other) {
            (Cv::Const(a), Cv::Const(b)) if a == b => Cv::Const(a),
            _ => Cv::Top,
        }
    }

    fn get(self) -> Option<u64> {
        match self {
            Cv::Const(v) => Some(v),
            Cv::Top => None,
        }
    }
}

/// Joined forward dataflow state at a program point. All components are
/// may-facts (join = union), so one fixpoint serves every check; the
/// "queue configuration dominates" must-fact is encoded as its dual
/// (`queue_unconfig`: the configuration *may not* have executed yet).
#[derive(Clone, PartialEq)]
struct State {
    /// Bit r set: register r may not have been written yet.
    uninit: u64,
    /// Queue configuration (`cfgwr QueueBase/QueueLength`) may not have
    /// executed on some path to this point.
    queue_unconfig: bool,
    /// An async request may have been issued.
    issued: bool,
    /// The ROI window may be open / may be closed here.
    roi_in: bool,
    roi_out: bool,
    /// A constant-address sync far access may have happened since the
    /// last `flush`.
    far_dirty: bool,
    regs: [Cv; NUM_ARCH_REGS],
    /// Constant values of the three AMI configuration registers.
    cfg: [Cv; 3],
}

impl State {
    fn entry() -> State {
        State {
            uninit: !1u64, // every register but hardwired r0
            queue_unconfig: true,
            issued: false,
            roi_in: false,
            roi_out: true,
            far_dirty: false,
            // Architectural reset state: all registers read as zero.
            regs: [Cv::Const(0); NUM_ARCH_REGS],
            cfg: [Cv::Top; 3],
        }
    }

    fn join(&mut self, other: &State) -> bool {
        let before = self.clone();
        self.uninit |= other.uninit;
        self.queue_unconfig |= other.queue_unconfig;
        self.issued |= other.issued;
        self.roi_in |= other.roi_in;
        self.roi_out |= other.roi_out;
        self.far_dirty |= other.far_dirty;
        for (a, b) in self.regs.iter_mut().zip(other.regs.iter()) {
            *a = a.join(*b);
        }
        for (a, b) in self.cfg.iter_mut().zip(other.cfg.iter()) {
            *a = a.join(*b);
        }
        *self != before
    }
}

// ---------------------------------------------------------------------------
// CFG
// ---------------------------------------------------------------------------

struct Cfg {
    /// Basic blocks as `[start, end)` instruction ranges, in index order.
    blocks: Vec<(usize, usize)>,
    /// Instruction index -> block id.
    block_of: Vec<usize>,
    /// Block id -> successor block ids.
    succs: Vec<Vec<usize>>,
    /// Block reachability from entry.
    reachable: Vec<bool>,
}

fn valid_target(imm: i64, len: usize) -> Option<usize> {
    if imm >= 0 && (imm as usize) < len {
        Some(imm as usize)
    } else {
        None
    }
}

fn is_terminator(op: Opcode) -> bool {
    matches!(op, Opcode::Halt | Opcode::Jal | Opcode::Jalr)
}

impl Cfg {
    /// Build the CFG. Indirect jumps (`jalr`) are over-approximated as
    /// possibly targeting any label (continuations are loaded by label)
    /// or any call-return site (the instruction after a `jal` with a live
    /// link register — `ret` jumps there).
    fn build(prog: &Program) -> Cfg {
        let len = prog.len();
        let insts = &prog.insts;
        // Indirect target set: labels + return sites.
        let mut indirect: Vec<usize> = prog
            .labels
            .iter()
            .map(|(_, at)| *at)
            .filter(|at| *at < len)
            .collect();
        for (i, inst) in insts.iter().enumerate() {
            if inst.op == Opcode::Jal && inst.rd != 0 && i + 1 < len {
                indirect.push(i + 1);
            }
        }
        indirect.sort_unstable();
        indirect.dedup();

        // Leaders.
        let mut leader = vec![false; len];
        if len > 0 {
            leader[0] = true;
        }
        for &at in &indirect {
            leader[at] = true;
        }
        for (i, inst) in insts.iter().enumerate() {
            if inst.is_branch() || is_terminator(inst.op) {
                if i + 1 < len {
                    leader[i + 1] = true;
                }
                if inst.op != Opcode::Jalr {
                    if let Some(t) = valid_target(inst.imm, len) {
                        leader[t] = true;
                    }
                }
            }
        }

        let mut blocks = Vec::new();
        let mut block_of = vec![0usize; len];
        let mut start = 0;
        for i in 0..len {
            if i > 0 && leader[i] {
                blocks.push((start, i));
                start = i;
            }
        }
        if len > 0 {
            blocks.push((start, len));
        }
        for (b, &(s, e)) in blocks.iter().enumerate() {
            for i in s..e {
                block_of[i] = b;
            }
        }

        let indirect_blocks: Vec<usize> = indirect.iter().map(|&at| block_of[at]).collect();
        let mut succs = vec![Vec::new(); blocks.len()];
        for (b, &(_, e)) in blocks.iter().enumerate() {
            let last = e - 1;
            let inst = &insts[last];
            let mut out: Vec<usize> = Vec::new();
            match inst.op {
                Opcode::Halt => {}
                Opcode::Jal => {
                    if let Some(t) = valid_target(inst.imm, len) {
                        out.push(block_of[t]);
                    }
                }
                Opcode::Jalr => out.extend_from_slice(&indirect_blocks),
                Opcode::Beq | Opcode::Bne | Opcode::Blt | Opcode::Bge | Opcode::BltU => {
                    if let Some(t) = valid_target(inst.imm, len) {
                        out.push(block_of[t]);
                    }
                    if last + 1 < len {
                        out.push(block_of[last + 1]);
                    }
                }
                _ => {
                    if last + 1 < len {
                        out.push(block_of[last + 1]);
                    }
                }
            }
            out.sort_unstable();
            out.dedup();
            succs[b] = out;
        }

        // Reachability from entry.
        let mut reachable = vec![false; blocks.len()];
        if !blocks.is_empty() {
            let mut stack = vec![0usize];
            reachable[0] = true;
            while let Some(b) = stack.pop() {
                for &s in &succs[b] {
                    if !reachable[s] {
                        reachable[s] = true;
                        stack.push(s);
                    }
                }
            }
        }
        Cfg { blocks, block_of, succs, reachable }
    }
}

// ---------------------------------------------------------------------------
// The verifier
// ---------------------------------------------------------------------------

struct Verifier<'p> {
    prog: &'p Program,
    cfg: Cfg,
    /// Does any reachable instruction configure the queue? (If none does,
    /// the hardware reset defaults apply and AMI007 stays silent.)
    has_queue_cfg: bool,
    diags: Vec<Diagnostic>,
}

/// Run the full static-analysis pass over an assembled program.
pub fn verify(prog: &Program) -> Report {
    let cfg = Cfg::build(prog);
    let mut v = Verifier { prog, cfg, has_queue_cfg: false, diags: Vec::new() };
    v.run();
    let mut diags = v.diags;
    diags.sort_by(|a, b| (a.at, a.code).cmp(&(b.at, b.code)));
    diags.dedup();
    Report { program: prog.name.clone(), insts: prog.len(), diags }
}

impl<'p> Verifier<'p> {
    fn label_at(&self, at: usize) -> String {
        self.prog
            .labels
            .iter()
            .filter(|(_, l)| *l <= at)
            .max_by_key(|(_, l)| *l)
            .map(|(n, _)| n.clone())
            .unwrap_or_default()
    }

    fn emit(&mut self, code: Code, at: usize, message: String) {
        let label = self.label_at(at);
        self.diags.push(Diagnostic { code, at, label, message });
    }

    fn inst_reachable(&self, at: usize) -> bool {
        self.cfg.reachable[self.cfg.block_of[at]]
    }

    fn run(&mut self) {
        let len = self.prog.len();
        if len == 0 {
            self.diags.push(Diagnostic {
                code: Code::FallsOffEnd,
                at: 0,
                label: String::new(),
                message: "program is empty".into(),
            });
            return;
        }
        self.structural();
        self.has_queue_cfg = self.prog.insts.iter().enumerate().any(|(i, inst)| {
            inst.op == Opcode::CfgWr
                && matches!(
                    CfgReg::from_imm(inst.imm),
                    Some(CfgReg::QueueBase) | Some(CfgReg::QueueLength)
                )
                && self.inst_reachable(i)
        });
        self.dataflow();
        self.issue_drain_balance();
    }

    /// Structural checks: bad targets, fall-through off the end,
    /// unreachable instruction runs.
    fn structural(&mut self) {
        let len = self.prog.len();
        for (i, inst) in self.prog.insts.iter().enumerate() {
            let targets = inst.is_branch() && inst.op != Opcode::Jalr;
            if targets && valid_target(inst.imm, len).is_none() {
                self.emit(
                    Code::BadTarget,
                    i,
                    format!(
                        "{:?} target {} outside the program (length {len})",
                        inst.op, inst.imm
                    ),
                );
            }
        }
        // Fall-through off the end: the last instruction is reachable and
        // is not an unconditional control transfer.
        let last = &self.prog.insts[len - 1];
        if !is_terminator(last.op) && self.inst_reachable(len - 1) {
            self.emit(
                Code::FallsOffEnd,
                len - 1,
                format!("{:?} at the program end can fall through past it", last.op),
            );
        }
        // Unreachable instructions, reported once per contiguous run.
        let mut i = 0;
        while i < len {
            if self.inst_reachable(i) {
                i += 1;
                continue;
            }
            let start = i;
            while i < len && !self.inst_reachable(i) {
                i += 1;
            }
            self.emit(
                Code::Unreachable,
                start,
                format!("{} unreachable instruction(s)", i - start),
            );
        }
    }

    /// Whole-program issue/drain balance over reachable instructions.
    fn issue_drain_balance(&mut self) {
        let first_reachable = |pred: &dyn Fn(&Inst) -> bool| -> Option<usize> {
            self.prog
                .insts
                .iter()
                .enumerate()
                .position(|(i, inst)| pred(inst) && self.inst_reachable(i))
        };
        let first_issue =
            first_reachable(&|i| matches!(i.op, Opcode::ALoad | Opcode::AStore));
        let first_drain = first_reachable(&|i| i.op == Opcode::GetFin);
        match (first_issue, first_drain) {
            (Some(at), None) => self.emit(
                Code::IssueWithoutDrain,
                at,
                "async requests are issued but no getfin is reachable: completions leak".into(),
            ),
            (None, Some(at)) => self.emit(
                Code::DrainWithoutIssue,
                at,
                "getfin polls for completions but the program never issues a request".into(),
            ),
            _ => {}
        }
    }

    /// The fused forward dataflow fixpoint plus a final collection pass.
    fn dataflow(&mut self) {
        let nblocks = self.cfg.blocks.len();
        let mut in_states: Vec<Option<State>> = vec![None; nblocks];
        in_states[0] = Some(State::entry());
        let mut work: Vec<usize> = vec![0];
        while let Some(b) = work.pop() {
            let mut st = in_states[b].clone().expect("worklist block has a state");
            let (s, e) = self.cfg.blocks[b];
            for i in s..e {
                self.transfer(&mut st, i, false);
            }
            for &succ in &self.cfg.succs[b].clone() {
                let changed = match &mut in_states[succ] {
                    Some(cur) => cur.join(&st),
                    slot @ None => {
                        *slot = Some(st.clone());
                        true
                    }
                };
                if changed && !work.contains(&succ) {
                    work.push(succ);
                }
            }
        }
        // Collection pass over the converged states.
        for b in 0..nblocks {
            let Some(mut st) = in_states[b].clone() else { continue };
            let (s, e) = self.cfg.blocks[b];
            for i in s..e {
                self.transfer(&mut st, i, true);
            }
        }
    }

    /// One-instruction transfer function; with `collect`, findings are
    /// emitted against the (converged) incoming state.
    fn transfer(&mut self, st: &mut State, at: usize, collect: bool) {
        let i = self.prog.insts[at];
        use Opcode::*;

        // Use-before-def on the registers this instruction actually reads.
        if collect {
            let (a, b) = i.sources();
            for r in [a, b].into_iter().flatten() {
                if r != 0 && st.uninit & (1u64 << r) != 0 {
                    self.emit(
                        Code::MaybeUninit,
                        at,
                        format!("r{r} may be read before its first write (reads as zero)"),
                    );
                }
            }
        }

        let rv = |st: &State, r: u8| st.regs[r as usize].get();
        let rs1 = st.regs[i.rs1 as usize];
        let rs2 = st.regs[i.rs2 as usize];

        // Dead writes to hardwired r0. `j`/`jr` (Jal/Jalr rd=0) and
        // drain-and-discard `getfin r0` are idioms, not bugs.
        if collect && i.rd == 0 {
            match i.op {
                Add | Sub | Xor | And | Or | Sll | Srl | Mul | SltU | Addi | Xori | Andi
                | Ori | Slli | Srli | Li | Ld | CfgRd => self.emit(
                    Code::DeadWrite,
                    at,
                    format!("{:?} writes hardwired r0; the result is discarded", i.op),
                ),
                ALoad | AStore => self.emit(
                    Code::DiscardedRequestId,
                    at,
                    format!("{:?} writes its request id to r0: it cannot be awaited", i.op),
                ),
                _ => {}
            }
        }

        // Per-opcode protocol checks and constant evaluation.
        let mut wrote: Option<(u8, Cv)> = None;
        match i.op {
            Add => wrote = Some((i.rd, bin(rs1, rs2, u64::wrapping_add))),
            Sub => wrote = Some((i.rd, bin(rs1, rs2, u64::wrapping_sub))),
            Xor => wrote = Some((i.rd, bin(rs1, rs2, |a, b| a ^ b))),
            And => wrote = Some((i.rd, bin(rs1, rs2, |a, b| a & b))),
            Or => wrote = Some((i.rd, bin(rs1, rs2, |a, b| a | b))),
            Sll => wrote = Some((i.rd, bin(rs1, rs2, |a, b| a.wrapping_shl(b as u32 & 63)))),
            Srl => wrote = Some((i.rd, bin(rs1, rs2, |a, b| a.wrapping_shr(b as u32 & 63)))),
            Mul => wrote = Some((i.rd, bin(rs1, rs2, u64::wrapping_mul))),
            SltU => wrote = Some((i.rd, bin(rs1, rs2, |a, b| (a < b) as u64))),
            Addi => wrote = Some((i.rd, unary(rs1, |a| a.wrapping_add(i.imm as u64)))),
            Xori => wrote = Some((i.rd, unary(rs1, |a| a ^ i.imm as u64))),
            Andi => wrote = Some((i.rd, unary(rs1, |a| a & i.imm as u64))),
            Ori => wrote = Some((i.rd, unary(rs1, |a| a | i.imm as u64))),
            Slli => wrote = Some((i.rd, unary(rs1, |a| a.wrapping_shl(i.imm as u32 & 63)))),
            Srli => wrote = Some((i.rd, unary(rs1, |a| a.wrapping_shr(i.imm as u32 & 63)))),
            Li => wrote = Some((i.rd, Cv::Const(i.imm as u64))),
            Ld => {
                if let Some(base) = rv(st, i.rs1) {
                    self.note_sync_far(st, base.wrapping_add(i.imm as u64));
                }
                wrote = Some((i.rd, Cv::Top));
            }
            St => {
                if let Some(base) = rv(st, i.rs1) {
                    self.note_sync_far(st, base.wrapping_add(i.imm as u64));
                }
            }
            Prefetch => {}
            Flush => st.far_dirty = false,
            Beq | Bne | Blt | Bge | BltU | Nop | Roi | Halt => {}
            Jal | Jalr => wrote = Some((i.rd, Cv::Const(at as u64 + 1))),
            ALoad | AStore => {
                self.check_issue(st, at, &i, collect);
                st.issued = true;
                st.far_dirty = false;
                wrote = Some((i.rd, Cv::Top));
            }
            GetFin => wrote = Some((i.rd, Cv::Top)),
            CfgWr => match CfgReg::from_imm(i.imm) {
                Some(CfgReg::Granularity) => st.cfg[CfgReg::Granularity as usize] = rs1,
                Some(reg) => {
                    if collect && st.issued {
                        self.emit(
                            Code::QueueReconfigInFlight,
                            at,
                            format!(
                                "cfgwr {reg:?} is reachable after an async issue: \
                                 reconfiguration resets request ids that may be in flight"
                            ),
                        );
                    }
                    st.queue_unconfig = false;
                    st.cfg[reg as usize] = rs1;
                }
                None => {
                    if collect {
                        self.emit(
                            Code::BadCfgIndex,
                            at,
                            format!("cfgwr immediate {} names no configuration register", i.imm),
                        );
                    }
                }
            },
            CfgRd => match CfgReg::from_imm(i.imm) {
                Some(reg) => wrote = Some((i.rd, st.cfg[reg as usize])),
                None => {
                    if collect {
                        self.emit(
                            Code::BadCfgIndex,
                            at,
                            format!("cfgrd immediate {} names no configuration register", i.imm),
                        );
                    }
                    wrote = Some((i.rd, Cv::Top));
                }
            },
        }

        // ROI window hygiene. Must-style conditions (`!roi_out` = the
        // window is open on *every* path in): the jalr over-approximation
        // would make may-style conditions fire on the coroutine scheduler.
        if i.op == Roi {
            let begin = i.imm == 1;
            if collect {
                if begin && !st.roi_out {
                    self.emit(
                        Code::RoiImbalance,
                        at,
                        "roi begin with the ROI window already open on every path here".into(),
                    );
                } else if !begin && !st.roi_in {
                    self.emit(
                        Code::RoiImbalance,
                        at,
                        "roi end with no ROI window open on any path here".into(),
                    );
                }
            }
            st.roi_in = begin;
            st.roi_out = !begin;
        }
        if i.op == Halt && collect && !st.roi_out {
            self.emit(
                Code::RoiImbalance,
                at,
                "program halts with the ROI window still open".into(),
            );
        }

        if let Some((rd, v)) = wrote {
            if rd != 0 {
                st.regs[rd as usize] = v;
                st.uninit &= !(1u64 << rd);
            }
        }
    }

    /// A constant-address sync access touching the far region marks the
    /// sync->async transition state (cleared by `flush`).
    fn note_sync_far(&self, st: &mut State, addr: u64) {
        if region_of(addr) == MemRegion::Far {
            st.far_dirty = true;
        }
    }

    /// Protocol checks at an `aload`/`astore` issue point.
    fn check_issue(&mut self, st: &State, at: usize, i: &Inst, collect: bool) {
        if !collect {
            return;
        }
        let op = i.op;
        if self.has_queue_cfg && st.queue_unconfig {
            self.emit(
                Code::QueueCfgNotDominating,
                at,
                format!(
                    "{op:?} issued on a path where cfgwr QueueBase/QueueLength has not executed"
                ),
            );
        }
        if st.far_dirty {
            self.emit(
                Code::MissingFlush,
                at,
                format!(
                    "{op:?} issued after a sync far-region access with no intervening flush \
                     (sync->async transition)"
                ),
            );
        }
        if let Some(spm) = st.regs[i.rs1 as usize].get() {
            if region_of(spm) != MemRegion::Spm {
                self.emit(
                    Code::SpmOperandOutOfRange,
                    at,
                    format!(
                        "{op:?} SPM operand resolves to {spm:#x}, outside the scratchpad"
                    ),
                );
            } else if let (Some(qb), Some(ql)) = (
                st.cfg[CfgReg::QueueBase as usize].get(),
                st.cfg[CfgReg::QueueLength as usize].get(),
            ) {
                // AMART metadata: 32 B per queue entry (paper Table 2).
                let qend = qb.saturating_add(ql.saturating_mul(32));
                if spm >= qb && spm < qend {
                    self.emit(
                        Code::SpmOperandOutOfRange,
                        at,
                        format!(
                            "{op:?} SPM operand {spm:#x} lies inside the configured queue \
                             region [{qb:#x}, {qend:#x})"
                        ),
                    );
                }
            }
        }
        if let Some(mem) = st.regs[i.rs2 as usize].get() {
            if region_of(mem) == MemRegion::Spm {
                self.emit(
                    Code::MemOperandInSpm,
                    at,
                    format!(
                        "{op:?} memory operand resolves to {mem:#x}, inside the scratchpad"
                    ),
                );
            }
        }
    }
}

fn bin(a: Cv, b: Cv, f: impl Fn(u64, u64) -> u64) -> Cv {
    match (a, b) {
        (Cv::Const(x), Cv::Const(y)) => Cv::Const(f(x, y)),
        _ => Cv::Top,
    }
}

fn unary(a: Cv, f: impl Fn(u64) -> u64) -> Cv {
    match a {
        Cv::Const(x) => Cv::Const(f(x)),
        Cv::Top => Cv::Top,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::mem::{FAR_BASE, SPM_BASE};
    use crate::isa::Asm;

    fn codes(r: &Report) -> Vec<Code> {
        r.diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn clean_minimal_program() {
        let mut a = Asm::new("ok");
        a.li(1, 5).addi(1, 1, 1).halt();
        let r = verify(&a.finish());
        assert!(r.diags.is_empty(), "{:?}", r.diags);
        assert!(r.is_clean(true));
    }

    #[test]
    fn clean_ami_roundtrip() {
        let mut a = Asm::new("ami-ok");
        a.li(1, SPM_BASE as i64);
        a.li(2, FAR_BASE as i64);
        a.aload(3, 1, 2);
        a.label("poll");
        a.getfin(4);
        a.beq(4, 0, "poll");
        a.halt();
        let r = verify(&a.finish());
        assert!(r.is_clean(true), "{:?}", r.diags);
    }

    #[test]
    fn empty_program_flagged() {
        let r = verify(&Program { name: "empty".into(), ..Default::default() });
        assert_eq!(codes(&r), vec![Code::FallsOffEnd]);
    }

    #[test]
    fn falls_off_end() {
        let mut a = Asm::new("fall");
        a.li(1, 1);
        let r = verify(&a.finish());
        assert_eq!(codes(&r), vec![Code::FallsOffEnd]);
        assert_eq!(r.diags[0].at, 0);
    }

    #[test]
    fn label_context_attached() {
        let mut a = Asm::new("ctx");
        a.halt();
        a.label("dead_code");
        a.nop();
        a.halt();
        let r = verify(&a.finish());
        assert_eq!(r.diags.len(), 1);
        assert_eq!(r.diags[0].code, Code::Unreachable);
        assert_eq!(r.diags[0].label, "dead_code");
    }

    #[test]
    fn severity_order() {
        assert!(Severity::Deny > Severity::Warn && Severity::Warn > Severity::Info);
    }

    #[test]
    fn all_codes_unique_and_ordered() {
        let tags: Vec<&str> = ALL_CODES.iter().map(|c| c.tag()).collect();
        let mut sorted = tags.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(tags.len(), sorted.len());
        assert_eq!(tags, sorted, "ALL_CODES must be in ascending AMIxxx order");
    }

    #[test]
    fn report_counts_and_gating() {
        let mut a = Asm::new("mix");
        a.li(0, 1); // AMI004 warn
        a.halt();
        let r = verify(&a.finish());
        assert_eq!((r.deny_count(), r.warn_count()), (0, 1));
        assert!(r.is_clean(false) && !r.is_clean(true));
    }
}
