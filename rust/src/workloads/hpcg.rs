//! HPCG — the SpMV kernel that dominates HPCG, over a 27-point-stencil-like
//! sparse matrix in ELL format. Matrix rows (values + column indices, one
//! 512 B block per row) live in far memory (paper: "matrices are allocated
//! in far memory"); the x and y vectors are local. The AMU port streams
//! row blocks through the SPM at large granularity.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::CoroRt;
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;

pub struct HpcgParams {
    pub rows: u64,
    pub nnz_per_row: u64, // 27, padded into a 512 B row block
    pub tasks: usize,
}

impl HpcgParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { rows: 512, nnz_per_row: 27, tasks: 16 },
            Scale::Paper => Self { rows: 8192, nnz_per_row: 27, tasks: 64 },
        }
    }
}

const ROW_BLOCK: u64 = 512; // 27 * (8B val + 8B idx) = 432, padded to 512

fn val_of(r: u64, j: u64) -> u64 {
    (host_hash(r * 29 + j) & 0xFF) + 1
}

fn col_of(r: u64, j: u64, rows: u64) -> u64 {
    // stencil-ish: mostly near-diagonal with a few far columns
    let off = host_hash(r * 53 + j * 7) % 64;
    (r + off) % rows
}

fn x_of(i: u64) -> u64 {
    (i & 0x3FF) + 1
}

fn expected_y(p: &HpcgParams) -> Vec<u64> {
    (0..p.rows)
        .map(|r| {
            (0..p.nnz_per_row)
                .map(|j| val_of(r, j).wrapping_mul(x_of(col_of(r, j, p.rows))))
                .fold(0u64, |a, b| a.wrapping_add(b))
        })
        .collect()
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = HpcgParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let mat = layout.alloc_far(p.rows * ROW_BLOCK, 4096);
    let xv = layout.alloc_local(p.rows * 8, 64);
    let yv = layout.alloc_local(p.rows * 8, 64);
    let setup = {
        let (mat, xv, rows, nnz) = (mat, xv, p.rows, p.nnz_per_row);
        move |sim: &mut crate::sim::Simulator| {
            for r in 0..rows {
                let base = mat + r * ROW_BLOCK;
                for j in 0..nnz {
                    sim.guest.write_u64(base + j * 16, val_of(r, j));
                    sim.guest.write_u64(base + j * 16 + 8, col_of(r, j, rows));
                }
            }
            for i in 0..rows {
                sim.guest.write_u64(xv + i * 8, x_of(i));
            }
        }
    };
    let validate = {
        let want = expected_y(&p);
        let (yv, rows) = (yv, p.rows);
        move |sim: &mut crate::sim::Simulator| -> Result<(), String> {
            for r in 0..rows {
                let got = sim.guest.read_u64(yv + r * 8);
                if got != want[r as usize] {
                    return Err(format!("y[{r}] = {got}, want {}", want[r as usize]));
                }
            }
            Ok(())
        }
    };
    match variant {
        Variant::Amu | Variant::AmuLlvm => {
            build_amu(cfg, &mut layout, p, mat, xv, yv, setup, validate)
        }
        _ => build_sync(p, mat, xv, yv, setup, validate),
    }
}

#[allow(clippy::too_many_arguments)]
fn build_sync(
    p: HpcgParams,
    mat: u64,
    xv: u64,
    yv: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
    validate: impl Fn(&mut crate::sim::Simulator) -> Result<(), String> + 'static,
) -> WorkloadSpec {
    let mut a = Asm::new("hpcg-sync");
    a.li(1, mat as i64);
    a.li(2, xv as i64);
    a.li(3, yv as i64);
    a.li(4, 0); // r
    a.li(5, p.rows as i64);
    a.roi_begin();
    a.label("row");
    a.li(6, ROW_BLOCK as i64);
    a.mul(6, 6, 4);
    a.add(6, 6, 1); // row base (far)
    a.li(7, 0); // j
    a.li(8, p.nnz_per_row as i64);
    a.li(9, 0); // acc
    a.label("nz");
    a.slli(10, 7, 4);
    a.add(10, 10, 6);
    a.ld64(11, 10, 0); // val (far)
    a.ld64(12, 10, 8); // col (far)
    a.slli(12, 12, 3);
    a.add(12, 12, 2);
    a.ld64(13, 12, 0); // x[col] (local)
    a.mul(11, 11, 13);
    a.add(9, 9, 11);
    a.addi(7, 7, 1);
    a.blt(7, 8, "nz");
    a.slli(10, 4, 3);
    a.add(10, 10, 3);
    a.st64(9, 10, 0); // y[r]
    a.addi(4, 4, 1);
    a.blt(4, 5, "row");
    a.roi_end();
    a.halt();
    WorkloadSpec {
        name: "hpcg".into(),
        prog: a.finish(),
        setup: Box::new(setup),
        validate: Box::new(validate),
    }
}

#[allow(clippy::too_many_arguments)]
fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: HpcgParams,
    mat: u64,
    xv: u64,
    yv: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
    validate: impl Fn(&mut crate::sim::Simulator) -> Result<(), String> + 'static,
) -> WorkloadSpec {
    let tasks = p.tasks as u64;
    let per_task = p.rows / tasks;
    assert!(per_task >= 1);
    let nnz = p.nnz_per_row;
    let (prog, rt) = AmuScaffold::build(
        "hpcg-amu",
        layout,
        cfg,
        p.tasks,
        ROW_BLOCK,
        |a: &mut Asm, rt: &CoroRt| {
            rt.emit_load_param(a, 10, 0); // first row
            rt.emit_load_param(a, 11, 1); // spm slot (512 B)
            a.li(12, per_task as i64);
            a.label("hp_row");
            a.li(13, ROW_BLOCK as i64);
            a.mul(13, 13, 10);
            a.li(14, mat as i64);
            a.add(14, 14, 13);
            a.aload(15, 11, 14);
            rt.emit_await(a, 15, &[10, 11, 12], "hp_r1");
            // SpMV inner product from the SPM block, x local.
            a.li(16, 0); // j
            a.li(17, nnz as i64);
            a.li(18, 0); // acc
            a.li(19, xv as i64);
            a.label("hp_nz");
            a.slli(20, 16, 4);
            a.add(20, 20, 11);
            a.ld64(21, 20, 0); // val (SPM)
            a.ld64(22, 20, 8); // col (SPM)
            a.slli(22, 22, 3);
            a.add(22, 22, 19);
            a.ld64(23, 22, 0); // x[col]
            a.mul(21, 21, 23);
            a.add(18, 18, 21);
            a.addi(16, 16, 1);
            a.blt(16, 17, "hp_nz");
            a.li(20, yv as i64);
            a.slli(21, 10, 3);
            a.add(20, 20, 21);
            a.st64(18, 20, 0); // y[row] (local)
            a.addi(10, 10, 1);
            a.addi(12, 12, -1);
            a.bne(12, 0, "hp_row");
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let prog2 = prog.clone();
    WorkloadSpec {
        name: "hpcg".into(),
        prog,
        setup: Box::new(move |sim| {
            setup(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64 * per_task, SPM_BASE + tid as u64 * ROW_BLOCK, 0, 0]
            });
        }),
        validate: Box::new(validate),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_hpcg_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("hpcg sync");
    }

    #[test]
    fn amu_hpcg_validates() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("hpcg amu");
        assert_eq!(sim.asmc.granularity, 512);
    }
}
