//! IS — NAS Parallel Benchmarks Integer Sort (counting sort / bucket
//! ranking). Keys live in far memory; the histogram is local. The AMU
//! port streams keys through the SPM in 512 B blocks (the paper evaluates
//! IS for large-granularity benefit), then scatters ranked keys back with
//! 8 B writes — switching the granularity config register between phases.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::CoroRt;
use crate::isa::mem::SPM_BASE;
use crate::isa::{Asm, CfgReg};

pub struct IsParams {
    pub keys: u64,
    pub key_range: u64, // power of two
    pub tasks: usize,
}

impl IsParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { keys: 4096, key_range: 512, tasks: 16 },
            Scale::Paper => Self { keys: 65536, key_range: 1024, tasks: 64 },
        }
    }
}

fn key_at(i: u64, range: u64) -> u64 {
    host_hash(i ^ 0x15) & (range - 1)
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = IsParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let keys = layout.alloc_far(p.keys * 8, 4096);
    let out = layout.alloc_far(p.keys * 8, 4096);
    let hist = layout.alloc_local(p.key_range * 8, 64);
    let setup = {
        let (keys, n, range) = (keys, p.keys, p.key_range);
        move |sim: &mut crate::sim::Simulator| {
            for i in 0..n {
                sim.guest.write_u64(keys + i * 8, key_at(i, range));
            }
        }
    };
    let validate = {
        let (out, n) = (out, p.keys);
        move |sim: &mut crate::sim::Simulator| -> Result<(), String> {
            let mut prev = 0u64;
            for i in 0..n {
                let v = sim.guest.read_u64(out + i * 8);
                if v < prev {
                    return Err(format!("out[{i}] = {v} < out[{}] = {prev}", i - 1));
                }
                prev = v;
            }
            Ok(())
        }
    };
    match variant {
        Variant::Amu | Variant::AmuLlvm => {
            build_amu(cfg, &mut layout, p, keys, out, hist, setup, validate)
        }
        _ => build_sync(p, keys, out, hist, setup, validate),
    }
}

#[allow(clippy::too_many_arguments)]
fn build_sync(
    p: IsParams,
    keys: u64,
    out: u64,
    hist: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
    validate: impl Fn(&mut crate::sim::Simulator) -> Result<(), String> + 'static,
) -> WorkloadSpec {
    let mut a = Asm::new("is-sync");
    a.roi_begin();
    // Phase 1: histogram.
    a.li(1, keys as i64);
    a.li(2, hist as i64);
    a.li(3, 0);
    a.li(4, p.keys as i64);
    a.label("count");
    a.slli(5, 3, 3);
    a.add(5, 5, 1);
    a.ld64(6, 5, 0); // key (far)
    a.slli(6, 6, 3);
    a.add(6, 6, 2);
    a.ld64(7, 6, 0);
    a.addi(7, 7, 1);
    a.st64(7, 6, 0);
    a.addi(3, 3, 1);
    a.blt(3, 4, "count");
    // Phase 2: exclusive prefix sum -> start offsets.
    a.li(3, 0);
    a.li(8, 0); // running
    a.li(4, p.key_range as i64);
    a.label("scan");
    a.slli(5, 3, 3);
    a.add(5, 5, 2);
    a.ld64(6, 5, 0);
    a.st64(8, 5, 0);
    a.add(8, 8, 6);
    a.addi(3, 3, 1);
    a.blt(3, 4, "scan");
    // Phase 3: permute.
    a.li(3, 0);
    a.li(4, p.keys as i64);
    a.li(9, out as i64);
    a.label("permute");
    a.slli(5, 3, 3);
    a.add(5, 5, 1);
    a.ld64(6, 5, 0); // key
    a.slli(7, 6, 3);
    a.add(7, 7, 2);
    a.ld64(8, 7, 0); // rank
    a.addi(10, 8, 1);
    a.st64(10, 7, 0);
    a.slli(8, 8, 3);
    a.add(8, 8, 9);
    a.st64(6, 8, 0); // out[rank] = key (far store)
    a.addi(3, 3, 1);
    a.blt(3, 4, "permute");
    a.roi_end();
    a.halt();
    WorkloadSpec {
        name: "is".into(),
        prog: a.finish(),
        setup: Box::new(setup),
        validate: Box::new(validate),
    }
}

#[allow(clippy::too_many_arguments)]
fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: IsParams,
    keys: u64,
    out: u64,
    hist: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
    validate: impl Fn(&mut crate::sim::Simulator) -> Result<(), String> + 'static,
) -> WorkloadSpec {
    const BLOCK_WORDS: u64 = 64; // 512 B
    let tasks = p.tasks as u64;
    let blocks = p.keys / BLOCK_WORDS;
    let per_task_blocks = blocks / tasks;
    let per_task_keys = p.keys / tasks;
    assert!(per_task_blocks >= 1);
    // Three task generations (reset like BFS): 0 = histogram (512 B reads),
    // 1 = rank computation into a local staging array (512 B reads),
    // 2 = ranked scatter (8 B writes). The granularity register is switched
    // only between generations, when no requests are in flight.
    let rt = CoroRt::new(layout, p.tasks, cfg.amu.queue_length);
    let phase_cell = layout.alloc_local(8, 8);
    let staging = layout.alloc_local(p.keys * 16, 64); // [key][rank] pairs

    let mut a = Asm::new("is-amu");
    a.li(1, 512);
    a.cfgwr(1, CfgReg::Granularity);
    rt.emit_prologue(&mut a);
    a.roi_begin();
    a.j("sched");

    // ---- task: dispatch on phase ----
    a.label("task");
    a.li(20, phase_cell as i64);
    a.ld64(20, 20, 0);
    a.li(21, 1);
    a.beq(20, 21, "task_rank");
    a.bne(20, 0, "task_scatter");
    // Phase 0: histogram over this task's block range.
    rt.emit_load_param(&mut a, 10, 0); // first block
    rt.emit_load_param(&mut a, 11, 1); // spm slot
    a.li(12, per_task_blocks as i64);
    a.label("c_loop");
    a.li(13, (BLOCK_WORDS * 8) as i64);
    a.mul(13, 13, 10);
    a.li(14, keys as i64);
    a.add(14, 14, 13);
    a.aload(15, 11, 14);
    rt.emit_await(&mut a, 15, &[10, 11, 12], "c_r1");
    a.li(16, 0);
    a.li(17, BLOCK_WORDS as i64);
    a.li(18, hist as i64);
    a.label("c_kloop");
    a.slli(19, 16, 3);
    a.add(19, 19, 11);
    a.ld64(21, 19, 0);
    a.slli(21, 21, 3);
    a.add(21, 21, 18);
    a.ld64(22, 21, 0);
    a.addi(22, 22, 1);
    a.st64(22, 21, 0);
    a.addi(16, 16, 1);
    a.blt(16, 17, "c_kloop");
    a.addi(10, 10, 1);
    a.addi(12, 12, -1);
    a.bne(12, 0, "c_loop");
    rt.emit_task_finish(&mut a);

    // Phase 1: re-stream blocks, allocate ranks, stage [key][rank] locally.
    a.label("task_rank");
    rt.emit_load_param(&mut a, 10, 0);
    rt.emit_load_param(&mut a, 11, 1);
    a.li(12, per_task_blocks as i64);
    a.label("r_loop");
    a.li(13, (BLOCK_WORDS * 8) as i64);
    a.mul(13, 13, 10);
    a.li(14, keys as i64);
    a.add(14, 14, 13);
    a.aload(15, 11, 14);
    rt.emit_await(&mut a, 15, &[10, 11, 12], "r_r1");
    a.li(16, 0);
    a.li(17, BLOCK_WORDS as i64);
    a.label("r_kloop");
    a.slli(19, 16, 3);
    a.add(19, 19, 11);
    a.ld64(21, 19, 0); // key
    a.li(18, hist as i64);
    a.slli(22, 21, 3);
    a.add(22, 22, 18);
    a.ld64(23, 22, 0); // rank
    a.addi(24, 23, 1);
    a.st64(24, 22, 0);
    // staging[block*64 + k] = (key, rank)
    a.li(25, BLOCK_WORDS as i64);
    a.mul(25, 25, 10);
    a.add(25, 25, 16);
    a.slli(25, 25, 4);
    a.li(26, staging as i64);
    a.add(25, 25, 26);
    a.st64(21, 25, 0);
    a.st64(23, 25, 8);
    a.addi(16, 16, 1);
    a.blt(16, 17, "r_kloop");
    a.addi(10, 10, 1);
    a.addi(12, 12, -1);
    a.bne(12, 0, "r_loop");
    rt.emit_task_finish(&mut a);

    // Phase 2: ranked scatter at 8 B granularity from the staging array.
    a.label("task_scatter");
    rt.emit_load_param(&mut a, 10, 2); // first key index
    rt.emit_load_param(&mut a, 11, 1); // spm slot (staging word at +512)
    a.li(12, per_task_keys as i64);
    a.addi(13, 11, 512);
    a.label("x_loop");
    a.slli(14, 10, 4);
    a.li(15, staging as i64);
    a.add(14, 14, 15);
    a.ld64(16, 14, 0); // key
    a.ld64(17, 14, 8); // rank
    a.st64(16, 13, 0); // SPM staging word
    a.li(18, out as i64);
    a.slli(17, 17, 3);
    a.add(18, 18, 17);
    a.astore(19, 13, 18);
    rt.emit_await(&mut a, 19, &[10, 11, 12, 13], "x_r1");
    a.addi(10, 10, 1);
    a.addi(12, 12, -1);
    a.bne(12, 0, "x_loop");
    rt.emit_task_finish(&mut a);

    a.label("sched");
    rt.emit_scheduler(&mut a, "phase_end");
    a.label("phase_end");
    a.li(20, phase_cell as i64);
    a.ld64(21, 20, 0);
    a.li(22, 2);
    a.beq(21, 22, "all_done");
    a.bne(21, 0, "to_phase2");
    // After phase 0: exclusive scan of the histogram (serial, local).
    a.li(3, 0);
    a.li(8, 0);
    a.li(4, p.key_range as i64);
    a.li(2, hist as i64);
    a.label("scan");
    a.slli(5, 3, 3);
    a.add(5, 5, 2);
    a.ld64(6, 5, 0);
    a.st64(8, 5, 0);
    a.add(8, 8, 6);
    a.addi(3, 3, 1);
    a.blt(3, 4, "scan");
    a.li(21, 1);
    a.st64(21, 20, 0);
    a.j("reset_pool");
    a.label("to_phase2");
    a.li(21, 2);
    a.st64(21, 20, 0);
    a.li(22, 8); // scatter granularity
    a.cfgwr(22, CfgReg::Granularity);
    a.label("reset_pool");
    a.li(crate::coro::R_SPAWN, 0);
    a.li(crate::coro::R_FINISHED, 0);
    a.li(22, 0);
    a.li_label(23, "task");
    a.label("reset_loop");
    a.slli(24, 22, crate::coro::TCB_SHIFT as i64);
    a.add(24, 24, crate::coro::R_TCB_BASE);
    a.st64(23, 24, 0);
    a.addi(22, 22, 1);
    a.blt(22, crate::coro::R_NTASKS, "reset_loop");
    a.j("co_dispatch");
    a.label("all_done");
    a.roi_end();
    a.halt();
    let prog = a.finish();

    let rt_setup = rt.clone();
    let prog2 = prog.clone();
    WorkloadSpec {
        name: "is".into(),
        prog,
        setup: Box::new(move |sim| {
            setup(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [
                    tid as u64 * per_task_blocks,
                    SPM_BASE + tid as u64 * (512 + 64),
                    tid as u64 * per_task_keys,
                    0,
                ]
            });
        }),
        validate: Box::new(validate),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_is_sorts() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("is sync");
    }

    #[test]
    fn amu_is_sorts() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("is amu");
        assert!(sim.stats.amu_subrequests > 0);
    }
}
