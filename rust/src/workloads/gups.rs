//! GUPS — HPCC RandomAccess (single node), the paper's headline benchmark.
//!
//! `table[idx(i)] ^= i` over a far-memory table. Variants:
//! * `Sync` — plain load/xor/store loop (Baseline / CXL-Ideal).
//! * `Amu` — 256 coroutines, each owning a table region (regions keep
//!   concurrent streams conflict-free so validation is exact; accesses stay
//!   random and cache-hostile).
//! * `GroupPrefetch(G)` — Chen et al. group prefetching (Fig 3).
//! * `SwPrefetch{batch,..}` — Clairvoyance-style batched software prefetch
//!   (Table 4 `PF`).
//! * `AmuLlvm` — software-pipelined AMI event loop without coroutine
//!   context costs, 8 B granularity (Table 4 `LLVM AMU`).

use super::common::*;
use crate::config::SimConfig;
use crate::coro::CoroRt;
use crate::isa::mem::SPM_BASE;
use crate::isa::{Asm, CfgReg};

pub struct GupsParams {
    pub table_words: u64, // power of two
    pub updates: u64,
    pub tasks: usize,
}

impl GupsParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { table_words: 1 << 14, updates: 1024, tasks: 128 },
            Scale::Paper => Self { table_words: 1 << 17, updates: 4096, tasks: 256 },
        }
    }
}

fn expected_global(p: &GupsParams) -> Vec<u64> {
    let mut t = vec![0u64; p.table_words as usize];
    for i in 0..p.updates {
        let idx = (host_hash(i) & (p.table_words - 1)) as usize;
        t[idx] ^= i;
    }
    t
}

fn expected_regioned(p: &GupsParams, tasks: u64) -> Vec<u64> {
    let mut t = vec![0u64; p.table_words as usize];
    let per_region = p.table_words / tasks;
    let per_task = p.updates / tasks;
    for tid in 0..tasks {
        for k in 0..per_task {
            let i = tid * per_task + k;
            let idx = (tid * per_region + (host_hash(i) & (per_region - 1))) as usize;
            t[idx] ^= i;
        }
    }
    t
}

fn table_checksum(t: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in t {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = GupsParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    match variant {
        Variant::Sync => build_sync(&mut layout, p),
        Variant::GroupPrefetch(g) => build_gp(&mut layout, p, g),
        Variant::SwPrefetch { batch, .. } => build_gp(&mut layout, p, batch.max(1)),
        Variant::Amu => build_amu(cfg, &mut layout, p),
        Variant::AmuLlvm => build_llvm(cfg, &mut layout, p),
    }
}

fn build_sync(layout: &mut crate::isa::mem::Layout, p: GupsParams) -> WorkloadSpec {
    let table = layout.alloc_far(p.table_words * 8, 4096);
    let mask = p.table_words - 1;
    let mut a = Asm::new("gups-sync");
    a.li(1, table as i64);
    a.li(2, 0); // i
    a.li(3, p.updates as i64);
    a.li(4, mask as i64);
    a.roi_begin();
    a.label("loop");
    emit_hash(&mut a, 6, 2, 7);
    a.and(6, 6, 4);
    a.slli(6, 6, 3);
    a.add(6, 6, 1);
    a.ld64(8, 6, 0);
    a.xor(8, 8, 2);
    a.st64(8, 6, 0);
    a.addi(2, 2, 1);
    a.blt(2, 3, "loop");
    a.roi_end();
    a.halt();
    let prog = a.finish();
    let expected = table_checksum(&expected_global(&p));
    let words = p.table_words as usize;
    WorkloadSpec {
        name: "gups".into(),
        prog,
        setup: Box::new(|_sim| {}),
        validate: Box::new(move |sim| {
            let mut got = vec![0u64; words];
            for (i, g) in got.iter_mut().enumerate() {
                *g = sim.guest.read_u64(table + i as u64 * 8);
            }
            if table_checksum(&got) == expected {
                Ok(())
            } else {
                Err("table checksum mismatch".into())
            }
        }),
    }
}

/// Group prefetching (Fig 3) / batched software prefetch (Table 4 PF):
/// compute a group of addresses into a local scratch array, prefetch them
/// all, then perform the updates.
fn build_gp(layout: &mut crate::isa::mem::Layout, p: GupsParams, group: usize) -> WorkloadSpec {
    let group = group.max(1) as u64;
    let table = layout.alloc_far(p.table_words * 8, 4096);
    let scratch = layout.alloc_local(group * 8, 64);
    let mask = p.table_words - 1;
    let mut a = Asm::new("gups-gp");
    a.li(1, table as i64);
    a.li(2, 0); // group start i
    a.li(3, p.updates as i64);
    a.li(4, mask as i64);
    a.li(5, scratch as i64);
    a.roi_begin();
    a.label("outer");
    // Phase 1: compute + prefetch the group's addresses.
    a.li(9, 0); // k
    a.li(10, group as i64);
    a.label("pf_loop");
    a.add(11, 2, 9); // i = base + k
    emit_hash(&mut a, 6, 11, 7);
    a.and(6, 6, 4);
    a.slli(6, 6, 3);
    a.add(6, 6, 1);
    a.slli(12, 9, 3);
    a.add(12, 12, 5);
    a.st64(6, 12, 0); // scratch[k] = addr
    a.prefetch(6, 0);
    a.addi(9, 9, 1);
    a.blt(9, 10, "pf_loop");
    // Phase 2: updates.
    a.li(9, 0);
    a.label("up_loop");
    a.add(11, 2, 9);
    a.slli(12, 9, 3);
    a.add(12, 12, 5);
    a.ld64(6, 12, 0);
    a.ld64(8, 6, 0);
    a.xor(8, 8, 11);
    a.st64(8, 6, 0);
    a.addi(9, 9, 1);
    a.blt(9, 10, "up_loop");
    a.add(2, 2, 10);
    a.blt(2, 3, "outer");
    a.roi_end();
    a.halt();
    let prog = a.finish();
    let expected = table_checksum(&expected_global(&p));
    let words = p.table_words as usize;
    WorkloadSpec {
        name: format!("gups-gp{group}"),
        prog,
        setup: Box::new(|_sim| {}),
        validate: Box::new(move |sim| {
            let mut got = vec![0u64; words];
            for (i, g) in got.iter_mut().enumerate() {
                *g = sim.guest.read_u64(table + i as u64 * 8);
            }
            if table_checksum(&got) == expected {
                Ok(())
            } else {
                Err("table checksum mismatch".into())
            }
        }),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: GupsParams,
) -> WorkloadSpec {
    let table = layout.alloc_far(p.table_words * 8, 4096);
    let tasks = p.tasks as u64;
    let per_region = p.table_words / tasks;
    let per_task = p.updates / tasks;
    let region_mask = per_region - 1;
    let (prog, rt) = AmuScaffold::build(
        "gups-amu",
        layout,
        cfg,
        p.tasks,
        8,
        |a: &mut Asm, rt: &CoroRt| {
            // params: p0 = first i, p1 = region base addr, p2 = spm slot
            rt.emit_load_param(a, 10, 0); // i
            rt.emit_load_param(a, 11, 1); // region base
            rt.emit_load_param(a, 12, 2); // spm slot
            a.li(13, per_task as i64); // remaining
            a.label("g_loop");
            emit_hash(a, 14, 10, 15);
            a.li(15, region_mask as i64);
            a.and(14, 14, 15);
            a.slli(14, 14, 3);
            a.add(14, 14, 11); // far addr
            a.aload(16, 12, 14);
            rt.emit_await(a, 16, &[10, 11, 12, 13, 14], "g_r1");
            a.ld64(17, 12, 0);
            a.xor(17, 17, 10);
            a.st64(17, 12, 0);
            a.astore(18, 12, 14);
            rt.emit_await(a, 18, &[10, 11, 12, 13], "g_r2");
            a.addi(10, 10, 1);
            a.addi(13, 13, -1);
            a.bne(13, 0, "g_loop");
            rt.emit_task_finish(a);
        },
    );
    let expected = table_checksum(&expected_regioned(&p, tasks));
    let words = p.table_words as usize;
    let rt2 = rt.clone();
    let prog2 = prog.clone();
    WorkloadSpec {
        name: "gups".into(),
        prog,
        setup: Box::new(move |sim| {
            rt2.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [
                    tid as u64 * per_task,
                    table + tid as u64 * per_region * 8,
                    SPM_BASE + tid as u64 * 64,
                    0,
                ]
            });
        }),
        validate: Box::new(move |sim| {
            let mut got = vec![0u64; words];
            for (i, g) in got.iter_mut().enumerate() {
                *g = sim.guest.read_u64(table + i as u64 * 8);
            }
            if table_checksum(&got) == expected {
                Ok(())
            } else {
                Err("table checksum mismatch (regioned)".into())
            }
        }),
    }
}

/// Compiler-generated AMI (`LLVM AMU`): a flat software-pipelined event
/// loop with W in-flight slots and no per-task context save/restore — the
/// shape a loop-level pass emits for a data-independent loop.
fn build_llvm(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: GupsParams,
) -> WorkloadSpec {
    let table = layout.alloc_far(p.table_words * 8, 4096);
    let slots = p.tasks as u64; // in-flight window
    let per_region = p.table_words / slots;
    let per_slot = p.updates / slots;
    let region_mask = per_region - 1;
    // Slot state: [cur_i][remaining][far_addr][phase] = 32 B, local.
    let state = layout.alloc_local(slots * 32, 64);
    // waiters: id -> slot state addr.
    let waiters = layout.alloc_local((cfg.amu.queue_length as u64 + 1) * 8, 64);

    let mut a = Asm::new("gups-llvm");
    a.li(1, 8);
    a.cfgwr(1, CfgReg::Granularity);
    a.li(1, table as i64);
    a.li(2, state as i64);
    a.li(3, waiters as i64);
    a.li(4, 0); // completed slots
    a.li(5, slots as i64);
    a.roi_begin();
    // Initialize each slot and issue its first aload.
    a.li(6, 0); // slot idx
    a.label("init");
    a.slli(7, 6, 5);
    a.add(7, 7, 2); // state ptr
    a.li(8, per_slot as i64);
    a.st64(8, 7, 8); // remaining
    a.li(8, per_slot as i64);
    a.mul(8, 6, 8);
    a.st64(8, 7, 0); // cur_i = slot * per_slot
    a.call("issue"); // expects r7 = state ptr
    a.addi(6, 6, 1);
    a.blt(6, 5, "init");
    // Event loop.
    a.label("loop");
    a.getfin(9);
    a.beq(9, 0, "loop");
    a.slli(10, 9, 3);
    a.add(10, 10, 3);
    a.ld64(7, 10, 0); // state ptr
    a.ld64(11, 7, 24); // phase
    a.bne(11, 0, "store_done");
    // Load done: xor in SPM, astore back.
    a.ld64(12, 7, 16); // far addr
    // SPM slot address: derive from state ptr offset.
    a.sub(13, 7, 2);
    a.slli(13, 13, 1); // (ptr-base)/32*64 = *2
    a.li(14, SPM_BASE as i64);
    a.add(13, 13, 14);
    a.ld64(15, 13, 0);
    a.ld64(16, 7, 0); // cur_i
    a.xor(15, 15, 16);
    a.st64(15, 13, 0);
    a.astore(17, 13, 12);
    a.li(11, 1);
    a.st64(11, 7, 24); // phase = 1
    a.slli(10, 17, 3);
    a.add(10, 10, 3);
    a.st64(7, 10, 0); // waiters[id] = state
    a.j("loop");
    a.label("store_done");
    // Advance the slot's iteration.
    a.ld64(16, 7, 0);
    a.addi(16, 16, 1);
    a.st64(16, 7, 0);
    a.ld64(8, 7, 8);
    a.addi(8, 8, -1);
    a.st64(8, 7, 8);
    a.beq(8, 0, "slot_done");
    a.call("issue");
    a.j("loop");
    a.label("slot_done");
    a.addi(4, 4, 1);
    a.blt(4, 5, "loop");
    a.roi_end();
    a.halt();
    // issue(r7 = state ptr): compute far addr from cur_i, aload, register.
    a.label("issue");
    a.ld64(16, 7, 0); // cur_i
    emit_hash(&mut a, 12, 16, 14);
    a.li(14, region_mask as i64);
    a.and(12, 12, 14);
    // region base = table + slot*per_region*8; slot = (ptr-base)/32
    a.sub(13, 7, 2);
    a.srli(13, 13, 5);
    a.li(14, (per_region * 8) as i64);
    a.mul(13, 13, 14);
    a.add(13, 13, 1);
    a.slli(12, 12, 3);
    a.add(12, 12, 13); // far addr
    a.st64(12, 7, 16);
    // SPM slot
    a.sub(13, 7, 2);
    a.slli(13, 13, 1);
    a.li(14, SPM_BASE as i64);
    a.add(13, 13, 14);
    a.aload(15, 13, 12);
    a.st64(0, 7, 24); // phase = 0
    a.slli(14, 15, 3);
    a.add(14, 14, 3);
    a.st64(7, 14, 0); // waiters[id] = state
    a.ret();
    let prog = a.finish();

    let expected = table_checksum(&expected_regioned(
        &GupsParams { table_words: p.table_words, updates: p.updates, tasks: slots as usize },
        slots,
    ));
    let words = p.table_words as usize;
    WorkloadSpec {
        name: "gups-llvm".into(),
        prog,
        setup: Box::new(|_sim| {}),
        validate: Box::new(move |sim| {
            let mut got = vec![0u64; words];
            for (i, g) in got.iter_mut().enumerate() {
                *g = sim.guest.read_u64(table + i as u64 * 8);
            }
            if table_checksum(&got) == expected {
                Ok(())
            } else {
                Err("table checksum mismatch (llvm)".into())
            }
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_gups_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        let spec = build(&cfg, Variant::Sync, Scale::Test);
        let sim = spec.run(&cfg).expect("gups sync");
        assert!(sim.stats.insts_committed > 0);
    }

    #[test]
    fn amu_gups_validates_and_overlaps() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(2000.0);
        cfg.far.jitter_frac = 0.0;
        let spec = build(&cfg, Variant::Amu, Scale::Test);
        let sim = spec.run(&cfg).expect("gups amu");
        assert!(sim.stats.far_inflight.max >= 32, "MLP {}", sim.stats.far_inflight.max);
        // Compare against sync on the same latency: AMU must be much faster.
        let sync_cfg = SimConfig::baseline().with_far_latency_ns(2000.0);
        let sync = build(&sync_cfg, Variant::Sync, Scale::Test)
            .run(&sync_cfg)
            .expect("gups sync");
        // Our baseline OoO model is more optimistic than gem5's (perfect
        // L1I/TLB, idealized store buffer), so the gap is narrower than the
        // paper's at this scale — but AMU must still win clearly.
        assert!(
            (sim.stats.measured_cycles as f64) * 1.8 < sync.stats.measured_cycles as f64,
            "AMU {} vs sync {} cycles",
            sim.stats.measured_cycles,
            sync.stats.measured_cycles
        );
    }

    #[test]
    fn gp_gups_validates() {
        let cfg = SimConfig::cxl_ideal().with_far_latency_ns(500.0);
        let spec = build(&cfg, Variant::GroupPrefetch(16), Scale::Test);
        let sim = spec.run(&cfg).expect("gups gp");
        assert!(sim.stats.prefetches_issued >= 256);
    }

    #[test]
    fn llvm_gups_validates() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let spec = build(&cfg, Variant::AmuLlvm, Scale::Test);
        let sim = spec.run(&cfg).expect("gups llvm");
        assert!(sim.stats.far_inflight.max >= 24);
    }

    #[test]
    fn llvm_faster_than_coroutines_at_low_latency() {
        // The compiler-shaped loop skips context save/restore: it should
        // beat the coroutine port (Table 4 shows LLVM AMU < AMU for GUPS).
        let mut cfg = SimConfig::amu().with_far_latency_ns(200.0);
        cfg.far.jitter_frac = 0.0;
        let amu = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).unwrap();
        let llvm = build(&cfg, Variant::AmuLlvm, Scale::Test).run(&cfg).unwrap();
        assert!(
            llvm.stats.measured_cycles < amu.stats.measured_cycles,
            "llvm {} vs amu {}",
            llvm.stats.measured_cycles,
            amu.stats.measured_cycles
        );
    }
}
