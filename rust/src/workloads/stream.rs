//! STREAM triad: `a[i] = b[i] + s*c[i]` over far-memory arrays.
//!
//! The large-granularity showcase (paper §6.2): the AMU port moves 512 B
//! blocks per `aload`/`astore`, while the `AmuLlvm` variant is pinned to
//! the compiler's 8 B granularity — reproducing Table 4's STREAM row where
//! the compiler port loses badly to the hand-tuned one.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::CoroRt;
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;

const SCALAR: u64 = 3;

pub struct StreamParams {
    pub words: u64,
    pub tasks: usize,
    pub block_words: u64, // words per aload in the AMU variant
}

impl StreamParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { words: 4096, tasks: 16, block_words: 64 },
            Scale::Paper => Self { words: 65536, tasks: 32, block_words: 64 },
        }
    }
}

fn setup_arrays(b: u64, c: u64, words: u64) -> impl Fn(&mut crate::sim::Simulator) {
    move |sim| {
        for i in 0..words {
            sim.guest.write_u64(b + i * 8, i * 7 + 1);
            sim.guest.write_u64(c + i * 8, i * 3 + 2);
        }
    }
}

fn validate_triad(
    a_arr: u64,
    words: u64,
) -> impl Fn(&mut crate::sim::Simulator) -> Result<(), String> {
    move |sim| {
        // Sample-check plus endpoints (full check at test scale).
        let step = (words / 997).max(1);
        for i in (0..words).step_by(step as usize).chain([words - 1]) {
            let want = (i * 7 + 1) + SCALAR * (i * 3 + 2);
            let got = sim.guest.read_u64(a_arr + i * 8);
            if got != want {
                return Err(format!("a[{i}] = {got}, want {want}"));
            }
        }
        Ok(())
    }
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = StreamParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let a_arr = layout.alloc_far(p.words * 8, 4096);
    let b_arr = layout.alloc_far(p.words * 8, 4096);
    let c_arr = layout.alloc_far(p.words * 8, 4096);

    match variant {
        Variant::Amu => build_amu(cfg, &mut layout, p, a_arr, b_arr, c_arr, false),
        Variant::AmuLlvm => build_amu(cfg, &mut layout, p, a_arr, b_arr, c_arr, true),
        _ => build_sync(p, a_arr, b_arr, c_arr, variant),
    }
}

fn build_sync(
    p: StreamParams,
    a_arr: u64,
    b_arr: u64,
    c_arr: u64,
    variant: Variant,
) -> WorkloadSpec {
    let pf_dist = match variant {
        Variant::SwPrefetch { batch, .. } => batch as i64,
        Variant::GroupPrefetch(g) => g as i64,
        _ => 0,
    };
    let mut a = Asm::new("stream-sync");
    a.li(1, a_arr as i64);
    a.li(2, b_arr as i64);
    a.li(3, c_arr as i64);
    a.li(4, 0);
    a.li(5, p.words as i64);
    a.li(6, SCALAR as i64);
    a.roi_begin();
    a.label("loop");
    a.slli(7, 4, 3);
    a.add(8, 7, 2);
    if pf_dist > 0 {
        a.prefetch(8, pf_dist * 8);
    }
    a.ld64(9, 8, 0); // b[i]
    a.add(8, 7, 3);
    if pf_dist > 0 {
        a.prefetch(8, pf_dist * 8);
    }
    a.ld64(10, 8, 0); // c[i]
    a.mul(10, 10, 6);
    a.add(9, 9, 10);
    a.add(8, 7, 1);
    a.st64(9, 8, 0); // a[i]
    a.addi(4, 4, 1);
    a.blt(4, 5, "loop");
    a.roi_end();
    a.halt();
    WorkloadSpec {
        name: "stream".into(),
        prog: a.finish(),
        setup: Box::new(setup_arrays(b_arr, c_arr, p.words)),
        validate: Box::new(validate_triad(a_arr, p.words)),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: StreamParams,
    a_arr: u64,
    b_arr: u64,
    c_arr: u64,
    llvm_8b: bool,
) -> WorkloadSpec {
    let block_words = if llvm_8b { 1 } else { p.block_words };
    let gran = block_words * 8;
    let tasks = p.tasks as u64;
    let blocks = p.words / block_words;
    let per_task = blocks / tasks;
    assert!(per_task >= 1, "too few blocks for task count");
    // Two SPM buffers per task (b-block, c-block); result overwrites b.
    let slot_bytes = 2 * gran;
    let (prog, rt) = AmuScaffold::build(
        if llvm_8b { "stream-llvm" } else { "stream-amu" },
        layout,
        cfg,
        p.tasks,
        gran,
        |a: &mut Asm, rt: &CoroRt| {
            // params: p0 = first block idx, p1 = spm slot base
            rt.emit_load_param(a, 10, 0); // block idx
            rt.emit_load_param(a, 11, 1); // spm base (b buf; c buf at +gran)
            a.li(12, per_task as i64);
            a.label("s_loop");
            // far offsets
            a.li(13, (block_words * 8) as i64);
            a.mul(13, 13, 10); // byte offset of block
            a.li(14, b_arr as i64);
            a.add(14, 14, 13);
            a.aload(16, 11, 14);
            rt.emit_await(a, 16, &[10, 11, 12, 13], "s_r1");
            a.li(14, c_arr as i64);
            a.add(14, 14, 13);
            a.addi(15, 11, gran as i64);
            a.aload(17, 15, 14);
            rt.emit_await(a, 17, &[10, 11, 12, 13], "s_r2");
            // compute block in SPM: b[k] += s * c[k]
            a.li(18, 0);
            a.li(19, block_words as i64);
            a.li(20, SCALAR as i64);
            a.label("s_compute");
            a.slli(21, 18, 3);
            a.add(22, 21, 11);
            a.ld64(23, 22, 0); // b
            a.addi(24, 22, gran as i64);
            a.ld64(25, 24, 0); // c
            a.mul(25, 25, 20);
            a.add(23, 23, 25);
            a.st64(23, 22, 0);
            a.addi(18, 18, 1);
            a.blt(18, 19, "s_compute");
            // astore result block to a[]
            a.li(14, a_arr as i64);
            a.add(14, 14, 13);
            a.astore(26, 11, 14);
            rt.emit_await(a, 26, &[10, 11, 12], "s_r3");
            a.addi(10, 10, 1);
            a.addi(12, 12, -1);
            a.bne(12, 0, "s_loop");
            rt.emit_task_finish(a);
        },
    );
    let rt2 = rt.clone();
    let prog2 = prog.clone();
    let setup_data = setup_arrays(b_arr, c_arr, p.words);
    WorkloadSpec {
        name: if llvm_8b { "stream-llvm".into() } else { "stream".into() },
        prog,
        setup: Box::new(move |sim| {
            setup_data(sim);
            rt2.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64 * per_task, SPM_BASE + tid as u64 * slot_bytes, 0, 0]
            });
        }),
        validate: Box::new(validate_triad(a_arr, p.words)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_stream_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("stream sync");
    }

    #[test]
    fn amu_stream_validates_with_large_granularity() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("stream amu");
        assert!(sim.stats.amu_subrequests > 0);
        // 512B transfers: sub-requests per aload = 8.
        assert!(sim.asmc.granularity == 512);
    }

    #[test]
    fn llvm_8b_stream_much_slower_than_blocked() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let blocked = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).unwrap();
        let llvm = build(&cfg, Variant::AmuLlvm, Scale::Test).run(&cfg).unwrap();
        assert!(
            llvm.stats.measured_cycles > blocked.stats.measured_cycles * 3,
            "8B granularity should lose badly: {} vs {}",
            llvm.stats.measured_cycles,
            blocked.stats.measured_cycles
        );
    }

    #[test]
    fn cxl_ideal_prefetcher_helps_stream() {
        let mut base = SimConfig::baseline().with_far_latency_ns(500.0);
        base.far.jitter_frac = 0.0;
        let mut ideal = SimConfig::cxl_ideal().with_far_latency_ns(500.0);
        ideal.far.jitter_frac = 0.0;
        let b = build(&base, Variant::Sync, Scale::Test).run(&base).unwrap();
        let i = build(&ideal, Variant::Sync, Scale::Test).run(&ideal).unwrap();
        assert!(
            i.stats.measured_cycles < b.stats.measured_cycles,
            "BOP + 256 MSHRs must help a sequential stream: {} vs {}",
            i.stats.measured_cycles,
            b.stats.measured_cycles
        );
    }
}
