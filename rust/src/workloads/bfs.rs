//! BFS — Graph500-style breadth-first search. The graph's adjacency
//! (col_idx) lives in far memory; row pointers, the parent array and the
//! frontier queues are local (the small hot metadata). The AMU port is
//! level-synchronized: each level restarts the coroutine pool, tasks claim
//! frontier vertices from a shared cursor and fetch adjacency in 64 B
//! chunks via `aload`.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::{CoroRt, R_FINISHED, R_NTASKS, R_SPAWN, R_TCB_BASE, TCB_SHIFT};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;
use crate::util::prng::Xoshiro256;

pub struct BfsParams {
    pub vertices: u64,
    pub edges: u64,
    pub tasks: usize,
}

impl BfsParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { vertices: 512, edges: 4096, tasks: 32 },
            Scale::Paper => Self { vertices: 16384, edges: 262144, tasks: 128 },
        }
    }
}

/// Deterministic random graph in CSR form (undirected, root = 0).
pub struct Graph {
    pub row_ptr: Vec<u64>,
    pub col_idx: Vec<u64>,
}

pub fn gen_graph(p: &BfsParams, seed: u64) -> Graph {
    let mut rng = Xoshiro256::new(seed);
    let v = p.vertices;
    let mut adj: Vec<Vec<u64>> = vec![Vec::new(); v as usize];
    // A Hamiltonian-ish backbone keeps the graph connected.
    for i in 1..v {
        let j = rng.below(i);
        adj[i as usize].push(j);
        adj[j as usize].push(i);
    }
    while adj.iter().map(|a| a.len() as u64).sum::<u64>() < p.edges {
        let a = rng.below(v);
        let b = rng.below(v);
        if a != b {
            adj[a as usize].push(b);
            adj[b as usize].push(a);
        }
    }
    let mut row_ptr = Vec::with_capacity(v as usize + 1);
    let mut col_idx = Vec::new();
    row_ptr.push(0);
    for l in &adj {
        col_idx.extend_from_slice(l);
        row_ptr.push(col_idx.len() as u64);
    }
    Graph { row_ptr, col_idx }
}

fn host_bfs_levels(g: &Graph, v: u64) -> Vec<i64> {
    let mut level = vec![-1i64; v as usize];
    level[0] = 0;
    let mut frontier = vec![0u64];
    let mut l = 0;
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &u in &frontier {
            for e in g.row_ptr[u as usize]..g.row_ptr[u as usize + 1] {
                let w = g.col_idx[e as usize] as usize;
                if level[w] < 0 {
                    level[w] = l + 1;
                    next.push(w as u64);
                }
            }
        }
        frontier = next;
        l += 1;
    }
    level
}

struct Mem {
    row_ptr: u64,    // local
    col_idx: u64,    // far
    parent: u64,     // local: 0 = unvisited, else parent+1
    frontier_a: u64, // local
    frontier_b: u64,
    cells: u64, // [fsize][nsize][cursor][curbase][nextbase]
}

fn validate_levels(
    sim: &mut crate::sim::Simulator,
    g: &Graph,
    v: u64,
    parent_base: u64,
) -> Result<(), String> {
    let want = host_bfs_levels(g, v);
    // Derive levels from the parent array.
    let mut got = vec![-1i64; v as usize];
    for s in 0..v as usize {
        if got[s] >= 0 {
            continue;
        }
        // Follow parents to a resolved vertex or the root.
        let mut chain = Vec::new();
        let mut cur = s;
        loop {
            if got[cur] >= 0 {
                break;
            }
            let p = sim.guest.read_u64(parent_base + cur as u64 * 8);
            if p == 0 {
                // unvisited
                break;
            }
            chain.push(cur);
            if cur == 0 {
                got[0] = 0;
                chain.pop();
                break;
            }
            cur = (p - 1) as usize;
            if chain.len() > v as usize {
                return Err("parent cycle".into());
            }
        }
        if got[cur] >= 0 || cur == 0 {
            let mut l = got[cur];
            for &c in chain.iter().rev() {
                l += 1;
                got[c] = l;
            }
        }
    }
    for i in 0..v as usize {
        if got[i] != want[i] {
            return Err(format!(
                "vertex {i}: level {} != expected {}",
                got[i], want[i]
            ));
        }
    }
    Ok(())
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = BfsParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let g = std::rc::Rc::new(gen_graph(&p, 99));
    let mut layout = mk_layout(cfg);
    let v = p.vertices;
    let ne = g.col_idx.len() as u64;
    let m = Mem {
        row_ptr: layout.alloc_local((v + 1) * 8, 64),
        col_idx: layout.alloc_far(ne * 8, 4096),
        parent: layout.alloc_local(v * 8, 64),
        frontier_a: layout.alloc_local(v * 8, 64),
        frontier_b: layout.alloc_local(v * 8, 64),
        cells: layout.alloc_local(64, 64),
    };
    let setup = {
        let g = g.clone();
        let (rp, ci, par, fa, cells) = (m.row_ptr, m.col_idx, m.parent, m.frontier_a, m.cells);
        let (fa_cell, fb_cell) = (m.frontier_a, m.frontier_b);
        move |sim: &mut crate::sim::Simulator| {
            for (i, r) in g.row_ptr.iter().enumerate() {
                sim.guest.write_u64(rp + i as u64 * 8, *r);
            }
            for (i, c) in g.col_idx.iter().enumerate() {
                sim.guest.write_u64(ci + i as u64 * 8, *c);
            }
            // root = 0: parent[0] = 0+1, frontier = [0], fsize = 1
            sim.guest.write_u64(par, 1);
            sim.guest.write_u64(fa, 0);
            sim.guest.write_u64(cells, 1); // fsize
            sim.guest.write_u64(cells + 8, 0); // nsize
            sim.guest.write_u64(cells + 16, 0); // cursor
            sim.guest.write_u64(cells + 24, fa_cell); // cur frontier base
            sim.guest.write_u64(cells + 32, fb_cell); // next frontier base
        }
    };
    match variant {
        Variant::Amu | Variant::AmuLlvm => build_amu(cfg, &mut layout, p, m, g, setup),
        _ => build_sync(p, m, g, setup),
    }
}

fn build_sync(
    p: BfsParams,
    m: Mem,
    g: std::rc::Rc<Graph>,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    let mut a = Asm::new("bfs-sync");
    let cells = m.cells;
    a.li(40, cells as i64);
    a.roi_begin();
    a.label("level");
    a.ld64(41, 40, 0); // fsize
    a.beq(41, 0, "bfs_done");
    a.li(42, 0); // idx
    a.st64(0, 40, 8); // nsize = 0
    a.label("u_loop");
    a.bge(42, 41, "level_end");
    a.ld64(43, 40, 24); // cur frontier base
    a.slli(44, 42, 3);
    a.add(44, 44, 43);
    a.ld64(45, 44, 0); // u
    // edge range
    a.li(46, m.row_ptr as i64);
    a.slli(47, 45, 3);
    a.add(47, 47, 46);
    a.ld64(48, 47, 0); // start
    a.ld64(49, 47, 8); // end
    a.label("e_loop");
    a.bge(48, 49, "u_next");
    a.li(46, m.col_idx as i64);
    a.slli(47, 48, 3);
    a.add(47, 47, 46);
    a.ld64(50, 47, 0); // v (far load)
    // parent check
    a.li(46, m.parent as i64);
    a.slli(47, 50, 3);
    a.add(47, 47, 46);
    a.ld64(51, 47, 0);
    a.bne(51, 0, "e_next");
    a.addi(51, 45, 1);
    a.st64(51, 47, 0); // parent[v] = u+1
    // push next frontier
    a.ld64(51, 40, 8); // nsize
    a.ld64(46, 40, 32); // next base
    a.slli(52, 51, 3);
    a.add(52, 52, 46);
    a.st64(50, 52, 0);
    a.addi(51, 51, 1);
    a.st64(51, 40, 8);
    a.label("e_next");
    a.addi(48, 48, 1);
    a.j("e_loop");
    a.label("u_next");
    a.addi(42, 42, 1);
    a.j("u_loop");
    a.label("level_end");
    // swap frontiers; fsize = nsize
    a.ld64(43, 40, 24);
    a.ld64(44, 40, 32);
    a.st64(44, 40, 24);
    a.st64(43, 40, 32);
    a.ld64(45, 40, 8);
    a.st64(45, 40, 0);
    a.j("level");
    a.label("bfs_done");
    a.roi_end();
    a.halt();
    let prog = a.finish();
    let v = p.vertices;
    let parent = m.parent;
    WorkloadSpec {
        name: "bfs".into(),
        prog,
        setup: Box::new(setup),
        validate: Box::new(move |sim| validate_levels(sim, &g, v, parent)),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: BfsParams,
    m: Mem,
    g: std::rc::Rc<Graph>,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    // Custom scaffold: the scheduler is re-entered once per BFS level.
    let rt = CoroRt::new(layout, p.tasks, cfg.amu.queue_length);
    let cells = m.cells;
    let ntasks = p.tasks;
    let mut a = Asm::new("bfs-amu");
    a.li(1, 64);
    a.cfgwr(1, crate::isa::CfgReg::Granularity);
    rt.emit_prologue(&mut a);
    a.roi_begin();
    a.li(40, cells as i64);
    a.label("level");
    a.ld64(41, 40, 0); // fsize
    a.beq(41, 0, "bfs_done");
    a.st64(0, 40, 8); // nsize = 0
    a.st64(0, 40, 16); // cursor = 0
    // Reset the coroutine pool: every TCB continues at "task".
    a.li(R_SPAWN, 0);
    a.li(R_FINISHED, 0);
    a.li(42, 0);
    a.li_label(43, "task");
    a.label("reset_loop");
    a.slli(44, 42, TCB_SHIFT as i64);
    a.add(44, 44, R_TCB_BASE);
    a.st64(43, 44, 0); // cont_pc = task
    a.addi(42, 42, 1);
    a.blt(42, R_NTASKS, "reset_loop");
    a.j("co_dispatch");

    a.label("task");
    rt.emit_load_param(&mut a, 11, 1); // spm slot
    a.li(20, cells as i64);
    a.label("t_claim");
    // idx = cursor++
    a.ld64(21, 20, 16);
    a.addi(22, 21, 1);
    a.st64(22, 20, 16);
    a.ld64(23, 20, 0); // fsize
    a.bge(21, 23, "t_finish");
    a.ld64(23, 20, 24); // cur frontier base
    a.slli(24, 21, 3);
    a.add(24, 24, 23);
    a.ld64(25, 24, 0); // u
    // edge range from local row_ptr
    a.li(26, m.row_ptr as i64);
    a.slli(27, 25, 3);
    a.add(27, 27, 26);
    a.ld64(28, 27, 0); // start
    a.ld64(29, 27, 8); // end
    a.label("t_chunk");
    a.bge(28, 29, "t_claim");
    // chunk: up to 8 neighbors from col_idx[start..]
    a.li(26, m.col_idx as i64);
    a.slli(27, 28, 3);
    a.add(27, 27, 26); // far addr
    a.aload(30, 11, 27);
    rt.emit_await(&mut a, 30, &[11, 20, 25, 28, 29], "t_r1");
    // count = min(8, end-start)
    a.sub(31, 29, 28);
    a.li(26, 8);
    a.blt(31, 26, "t_cnt_ok");
    a.mv(31, 26);
    a.label("t_cnt_ok");
    a.li(21, 0); // k
    a.label("t_kloop");
    a.slli(22, 21, 3);
    a.add(22, 22, 11);
    a.ld64(23, 22, 0); // v
    // parent check (local)
    a.li(24, m.parent as i64);
    a.slli(22, 23, 3);
    a.add(22, 22, 24);
    a.ld64(24, 22, 0);
    a.bne(24, 0, "t_knext");
    a.addi(24, 25, 1);
    a.st64(24, 22, 0); // parent[v] = u+1
    // push into next frontier
    a.ld64(24, 20, 8); // nsize
    a.ld64(22, 20, 32); // next base
    a.slli(26, 24, 3);
    a.add(26, 26, 22);
    a.st64(23, 26, 0);
    a.addi(24, 24, 1);
    a.st64(24, 20, 8);
    a.label("t_knext");
    a.addi(21, 21, 1);
    a.blt(21, 31, "t_kloop");
    a.add(28, 28, 31);
    a.j("t_chunk");
    a.label("t_finish");
    rt.emit_task_finish(&mut a);

    a.label("sched");
    rt.emit_scheduler(&mut a, "level_end");
    a.label("level_end");
    // swap frontiers; fsize = nsize
    a.ld64(43, 40, 24);
    a.ld64(44, 40, 32);
    a.st64(44, 40, 24);
    a.st64(43, 40, 32);
    a.ld64(45, 40, 8);
    a.st64(45, 40, 0);
    a.j("level");
    a.label("bfs_done");
    a.roi_end();
    a.halt();
    let prog = a.finish();

    let rt_setup = rt.clone();
    let prog2 = prog.clone();
    let v = p.vertices;
    let parent = m.parent;
    WorkloadSpec {
        name: "bfs".into(),
        prog,
        setup: Box::new(move |sim| {
            setup(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * 64, 0, 0]
            });
            let _ = ntasks;
        }),
        validate: Box::new(move |sim| validate_levels(sim, &g, v, parent)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_bfs_is_sane() {
        let p = BfsParams::new(Scale::Test);
        let g = gen_graph(&p, 99);
        let levels = host_bfs_levels(&g, p.vertices);
        assert!(levels.iter().all(|&l| l >= 0), "graph must be connected");
    }

    #[test]
    fn sync_bfs_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("bfs sync");
    }

    #[test]
    fn amu_bfs_validates() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("bfs amu");
        assert!(sim.stats.far_inflight.max >= 4, "MLP {}", sim.stats.far_inflight.max);
    }
}
