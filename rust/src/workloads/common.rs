//! Shared infrastructure for the benchmark suite: the `WorkloadSpec`
//! contract, size presets, AMU scaffolding, and guest-side hash helpers.

use crate::config::SimConfig;
use crate::coro::CoroRt;
use crate::isa::mem::Layout;
use crate::isa::{Asm, Program};
use crate::sim::Simulator;
use crate::util::Fnv;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Process-wide memo of verifier gate results, keyed by program
/// fingerprint: `sweep`/`mtrun` build the same program once per grid point
/// and would otherwise re-run the whole static analysis every time.
static VERIFY_CACHE: OnceLock<Mutex<HashMap<u64, Result<(), String>>>> = OnceLock::new();

/// Number of distinct programs this process has pushed through the
/// verifier gate (test hook for the memoization).
pub fn verify_cache_len() -> usize {
    VERIFY_CACHE.get().map_or(0, |c| c.lock().unwrap().len())
}

/// A runnable benchmark instance: program + memory setup + validation.
pub struct WorkloadSpec {
    pub name: String,
    pub prog: Program,
    /// Initializes guest memory (datasets, TCBs) before the run.
    pub setup: Box<dyn Fn(&mut Simulator)>,
    /// Checks the architectural result after the run.
    pub validate: Box<dyn Fn(&mut Simulator) -> Result<(), String>>,
}

impl WorkloadSpec {
    /// Instantiate a simulator with memory initialized.
    pub fn instantiate(&self, cfg: &SimConfig) -> Simulator {
        let mut sim = Simulator::new(cfg.clone(), self.prog.clone());
        (self.setup)(&mut sim);
        sim
    }

    /// Run the static verifier over this spec's program (`isa::verify`).
    pub fn verify(&self) -> crate::isa::VerifyReport {
        crate::isa::verify(&self.prog)
    }

    /// Like [`verify`](Self::verify), but collapsed to a gate: `Err` with a
    /// one-line summary when the program has deny-level findings. Memoized
    /// per distinct (spec name, program) so sweeps verify each program
    /// once per process, not once per grid point.
    pub fn verify_ok(&self) -> Result<(), String> {
        let key = self.fingerprint();
        let cache = VERIFY_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
        if let Some(hit) = cache.lock().unwrap().get(&key) {
            return hit.clone();
        }
        let report = self.verify();
        let result = if report.deny_count() > 0 {
            Err(format!(
                "{}: program rejected by the verifier ({} deny finding(s)): {} \
                 — run `amu-sim check` for the full diagnostics table",
                self.name,
                report.deny_count(),
                report.deny_summary()
            ))
        } else {
            Ok(())
        };
        cache.lock().unwrap().insert(key, result.clone());
        result
    }

    /// FNV-1a over the spec name and full instruction stream. The spec
    /// name participates because the gate's error message embeds it.
    fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.write(self.name.as_bytes());
        h.write(&[0]);
        h.write(self.prog.name.as_bytes());
        h.write(&[0]);
        for i in &self.prog.insts {
            h.write(&[i.op as u8, i.rd, i.rs1, i.rs2, i.size]);
            h.write(&i.imm.to_le_bytes());
        }
        h.finish()
    }

    /// Run to completion and validate; returns the simulator for metrics.
    /// Programs that fail static verification are refused before a single
    /// cycle is simulated.
    pub fn run(&self, cfg: &SimConfig) -> Result<Simulator, String> {
        self.verify_ok()?;
        let mut sim = self.instantiate(cfg);
        sim.run().map_err(|e| format!("{}: {e}", self.name))?;
        (self.validate)(&mut sim).map_err(|e| format!("{}: validation: {e}", self.name))?;
        Ok(sim)
    }
}

/// Benchmark scale: `Test` keeps CI fast; `Paper` is used by the report
/// and bench harnesses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scale {
    Test,
    Paper,
}

impl Scale {
    pub fn tag(&self) -> &'static str {
        match self {
            Scale::Test => "test",
            Scale::Paper => "paper",
        }
    }
}

impl std::str::FromStr for Scale {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "test" => Ok(Scale::Test),
            "paper" => Ok(Scale::Paper),
            other => Err(format!("unknown scale '{other}' (valid: test, paper)")),
        }
    }
}

/// Which implementation of a benchmark to build.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Variant {
    /// Conventional synchronous loads/stores (Baseline / CXL-Ideal input).
    Sync,
    /// Coroutine + AMI port (the paper's §5.2 paradigm).
    Amu,
    /// Group-prefetching GUPS (Fig 3): prefetch a group, then update it.
    GroupPrefetch(usize),
    /// Compiler-style software prefetching (Table 4 `PF x-y`).
    SwPrefetch { batch: usize, depth: usize },
    /// Compiler-generated AMI (Table 4 `LLVM AMU`): software-pipelined
    /// event loop at fixed 8 B granularity, no coroutine context overhead.
    AmuLlvm,
}

/// The payload-free shape of a [`Variant`], used by the workload registry
/// to declare which implementations a benchmark provides.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VariantKind {
    Sync,
    Amu,
    GroupPrefetch,
    SwPrefetch,
    AmuLlvm,
}

/// Every variant kind, for workloads that implement (or degrade gracefully
/// under) the full set.
pub const ALL_VARIANT_KINDS: &[VariantKind] = &[
    VariantKind::Sync,
    VariantKind::Amu,
    VariantKind::GroupPrefetch,
    VariantKind::SwPrefetch,
    VariantKind::AmuLlvm,
];

impl Variant {
    pub fn tag(&self) -> String {
        match self {
            Variant::Sync => "sync".into(),
            Variant::Amu => "amu".into(),
            Variant::GroupPrefetch(g) => format!("gp{g}"),
            Variant::SwPrefetch { batch, depth } => format!("pf{batch}-{depth}"),
            Variant::AmuLlvm => "llvm".into(),
        }
    }

    pub fn kind(&self) -> VariantKind {
        match self {
            Variant::Sync => VariantKind::Sync,
            Variant::Amu => VariantKind::Amu,
            Variant::GroupPrefetch(_) => VariantKind::GroupPrefetch,
            Variant::SwPrefetch { .. } => VariantKind::SwPrefetch,
            Variant::AmuLlvm => VariantKind::AmuLlvm,
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = String;

    /// Parse `sync | amu | llvm | gp<N> | pf<N>[-<D>]`. Every failure names
    /// the valid choices instead of silently falling back.
    fn from_str(s: &str) -> Result<Self, String> {
        const VALID: &str = "sync, amu, llvm, gp<N> (e.g. gp16), pf<N>[-<D>] (e.g. pf16 or pf16-4)";
        match s {
            "sync" => return Ok(Variant::Sync),
            "amu" => return Ok(Variant::Amu),
            "llvm" => return Ok(Variant::AmuLlvm),
            _ => {}
        }
        if let Some(g) = s.strip_prefix("gp") {
            let g: usize = g
                .parse()
                .map_err(|_| format!("bad group size in '{s}' (valid variants: {VALID})"))?;
            if g == 0 {
                return Err(format!("group size must be >= 1 in '{s}'"));
            }
            return Ok(Variant::GroupPrefetch(g));
        }
        if let Some(body) = s.strip_prefix("pf") {
            let (b, d) = match body.split_once('-') {
                Some((b, d)) => (b, d),
                None => (body, "0"),
            };
            let batch: usize = b
                .parse()
                .map_err(|_| format!("bad batch size in '{s}' (valid variants: {VALID})"))?;
            let depth: usize = d
                .parse()
                .map_err(|_| format!("bad depth in '{s}' (valid variants: {VALID})"))?;
            if batch == 0 {
                return Err(format!("batch size must be >= 1 in '{s}'"));
            }
            return Ok(Variant::SwPrefetch { batch, depth });
        }
        Err(format!("unknown variant '{s}' (valid: {VALID})"))
    }
}

/// SPM data-area bytes available to software under `cfg` (total minus the
/// ASMC metadata area).
pub fn spm_data_bytes(cfg: &SimConfig) -> u64 {
    cfg.amu.spm_bytes as u64 - cfg.amu.queue_length as u64 * 32
}

pub fn mk_layout(cfg: &SimConfig) -> Layout {
    Layout::new(spm_data_bytes(cfg) as usize)
}

/// Coroutine count used by the RLP benchmarks (paper: 256, 128 for SL),
/// clamped to the AMART capacity.
pub fn default_tasks(cfg: &SimConfig, want: usize) -> usize {
    want.min(cfg.amu.queue_length)
}

/// Emit `rd = splitmix-style hash of rs` (clobbers `tmp`).
/// Matches [`host_hash`]; used to generate reproducible random access
/// streams inside guest code without memory-resident index arrays.
pub fn emit_hash(a: &mut Asm, rd: u8, rs: u8, tmp: u8) {
    debug_assert!(rd != rs && rd != tmp && rs != tmp);
    a.li(tmp, 0x9E37_79B9_7F4A_7C15u64 as i64);
    a.mul(rd, rs, tmp);
    a.srli(tmp, rd, 31);
    a.xor(rd, rd, tmp);
    a.li(tmp, 0xBF58_476D_1CE4_E5B9u64 as i64);
    a.mul(rd, rd, tmp);
    a.srli(tmp, rd, 27);
    a.xor(rd, rd, tmp);
}

/// Host-side mirror of [`emit_hash`].
pub fn host_hash(x: u64) -> u64 {
    let mut v = x.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    v ^= v >> 31;
    v = v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    v ^= v >> 27;
    v
}

/// Standard AMU-workload skeleton: configures granularity, emits the
/// coroutine prologue/scheduler and ROI around the user task body.
///
/// `emit_task(asm, rt)` must emit code starting at label `"task"` and end
/// with `rt.emit_task_finish`.
pub struct AmuScaffold {
    pub rt: CoroRt,
}

impl AmuScaffold {
    pub fn build(
        name: &str,
        layout: &mut Layout,
        cfg: &SimConfig,
        ntasks: usize,
        granularity: u64,
        emit_task: impl FnOnce(&mut Asm, &CoroRt),
    ) -> (Program, CoroRt) {
        let rt = CoroRt::new(layout, ntasks, cfg.amu.queue_length);
        let mut a = Asm::new(name);
        a.li(1, granularity as i64);
        a.cfgwr(1, crate::isa::CfgReg::Granularity);
        rt.emit_prologue(&mut a);
        a.roi_begin();
        a.j("sched");
        // Task bodies are entered via `jalr` on TCB resume pointers that
        // the host seeds to "task"; record the escape so the verifier's
        // narrowed indirect-target set keeps them reachable.
        a.mark_addr_taken("task");
        a.label("task");
        emit_task(&mut a, &rt);
        a.label("sched");
        rt.emit_scheduler(&mut a, "done");
        a.label("done");
        a.roi_end();
        a.halt();
        (a.finish(), rt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_and_guest_hash_agree() {
        use crate::isa::interp::{CompletionOrder, Interp};
        use crate::isa::GuestMem;
        let mut a = Asm::new("hash");
        a.li(1, 12345);
        emit_hash(&mut a, 2, 1, 3);
        a.halt();
        let prog = a.finish();
        let mut mem = GuestMem::new();
        let mut it = Interp::new(&mut mem, CompletionOrder::Fifo);
        it.run(&prog, 1000).unwrap();
        assert_eq!(it.regs[2], host_hash(12345));
    }

    #[test]
    fn spm_budget_positive_for_amu_preset() {
        let cfg = SimConfig::amu();
        assert!(spm_data_bytes(&cfg) >= 32 * 1024);
    }

    #[test]
    fn variant_tags() {
        assert_eq!(Variant::Sync.tag(), "sync");
        assert_eq!(Variant::GroupPrefetch(32).tag(), "gp32");
        assert_eq!(Variant::SwPrefetch { batch: 8, depth: 0 }.tag(), "pf8-0");
    }

    #[test]
    fn variant_parse_round_trips_tags() {
        for v in [
            Variant::Sync,
            Variant::Amu,
            Variant::AmuLlvm,
            Variant::GroupPrefetch(16),
            Variant::SwPrefetch { batch: 8, depth: 2 },
        ] {
            let parsed: Variant = v.tag().parse().unwrap();
            assert_eq!(parsed, v, "tag {}", v.tag());
        }
    }

    #[test]
    fn variant_parse_rejects_bad_input_naming_choices() {
        let e = "banana".parse::<Variant>().unwrap_err();
        assert!(e.contains("sync") && e.contains("gp<N>"), "{e}");
        let e = "gpx".parse::<Variant>().unwrap_err();
        assert!(e.contains("bad group size"), "{e}");
        let e = "pf".parse::<Variant>().unwrap_err();
        assert!(e.contains("bad batch size"), "{e}");
        assert!("gp0".parse::<Variant>().is_err());
    }

    #[test]
    fn scale_parse_and_tag() {
        assert_eq!("test".parse::<Scale>().unwrap(), Scale::Test);
        assert_eq!("paper".parse::<Scale>().unwrap(), Scale::Paper);
        assert_eq!(Scale::Paper.tag(), "paper");
        let e = "huge".parse::<Scale>().unwrap_err();
        assert!(e.contains("test") && e.contains("paper"), "{e}");
    }
}
