//! HT — chained hash table (ASCYLIB-style). Buckets live in local memory;
//! the 24 B `[key][value][next]` nodes live in far memory. Coroutines run
//! a 75 % lookup / 25 % insert mix; inserts claim the bucket through the
//! software disambiguation layer (this is one of Table 5's two workloads).
//!
//! Determinism: lookups target only pre-populated keys (insert-at-head
//! never breaks an existing chain, so they always hit); inserted keys are
//! unique per (task, op), so the final key set is order-independent.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::disambig::DisambigRt;
use crate::coro::{CoroRt, OFF_PARAM, R_CUR_TCB};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;

pub struct HtParams {
    pub buckets: u64, // power of two
    pub preload: u64,
    pub tasks: usize,
    pub ops_per_task: u64,
}

impl HtParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => {
                Self { buckets: 256, preload: 256, tasks: 32, ops_per_task: 4 }
            }
            Scale::Paper => {
                Self { buckets: 4096, preload: 4096, tasks: 256, ops_per_task: 8 }
            }
        }
    }
}

const NODE_BYTES: u64 = 24;
const NODE_STRIDE: u64 = 64;

fn pkey(i: u64) -> u64 {
    i * 5 + 7
}

fn bucket_of(key: u64, buckets: u64) -> u64 {
    host_hash(key.wrapping_mul(0x100_0193)) & (buckets - 1)
}

/// op o of task t: insert if `host_hash(t*977+o) % 4 == 0`.
fn op_is_insert(t: u64, o: u64) -> bool {
    host_hash(t * 977 + o + 55) % 4 == 0
}

fn lookup_target(t: u64, o: u64, preload: u64) -> u64 {
    pkey(host_hash(t * 31 + o * 17 + 2) % preload)
}

#[allow(dead_code)] // host-side mirror of the guest insert-key scheme
fn insert_key(t: u64, o: u64) -> u64 {
    // Outside the preload key space (preload keys are ≡ 7 mod 5... i.e.
    // pkey(i) = 5i+7; choose keys ≡ 3 mod 5 to guarantee uniqueness).
    (t * 4096 + o) * 5 + 3
}

struct Model {
    bucket_base: u64,
    node_base: u64,
    pool_base: u64,
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = HtParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let m = Model {
        bucket_base: layout.alloc_local(p.buckets * 8, 64),
        node_base: layout.alloc_far(p.preload * NODE_STRIDE, 4096),
        pool_base: layout
            .alloc_far(p.tasks as u64 * p.ops_per_task * NODE_STRIDE, 4096),
    };
    let setup = {
        let (bb, nb, buckets, preload) = (m.bucket_base, m.node_base, p.buckets, p.preload);
        move |sim: &mut crate::sim::Simulator| {
            // Chain preloaded nodes into buckets (host-side build phase).
            let mut heads = vec![0u64; buckets as usize];
            for i in 0..preload {
                let key = pkey(i);
                let b = bucket_of(key, buckets) as usize;
                let addr = nb + i * NODE_STRIDE;
                sim.guest.write_u64(addr, key);
                sim.guest.write_u64(addr + 8, key.wrapping_mul(3));
                sim.guest.write_u64(addr + 16, heads[b]);
                heads[b] = addr;
            }
            for (b, h) in heads.iter().enumerate() {
                sim.guest.write_u64(bb + b as u64 * 8, *h);
            }
        }
    };
    match variant {
        Variant::Amu | Variant::AmuLlvm => build_amu(cfg, &mut layout, p, m, setup),
        _ => build_sync(p, m, setup),
    }
}

/// Expected per-task sum of looked-up values.
fn expected_task_sum(t: u64, p: &HtParams) -> u64 {
    let mut sum = 0u64;
    for o in 0..p.ops_per_task {
        if !op_is_insert(t, o) {
            let key = lookup_target(t, o, p.preload);
            sum = sum.wrapping_add(key.wrapping_mul(3));
        }
    }
    sum
}

fn total_inserts(p: &HtParams) -> u64 {
    (0..p.tasks as u64)
        .map(|t| (0..p.ops_per_task).filter(|&o| op_is_insert(t, o)).count() as u64)
        .sum()
}

/// Walk all chains and check key population (shared by both variants).
fn validate_structure(
    sim: &mut crate::sim::Simulator,
    p: &HtParams,
    m_bucket_base: u64,
) -> Result<(), String> {
    let mut found = 0u64;
    for b in 0..p.buckets {
        let mut cur = sim.guest.read_u64(m_bucket_base + b * 8);
        let mut hops = 0;
        while cur != 0 {
            found += 1;
            hops += 1;
            if hops > p.preload + 100_000 {
                return Err(format!("cycle in bucket {b}"));
            }
            cur = sim.guest.read_u64(cur + 16);
        }
    }
    let want = p.preload + total_inserts(p);
    if found == want {
        Ok(())
    } else {
        Err(format!("node count {found} != {want} (lost inserts)"))
    }
}

fn emit_key_gen(a: &mut Asm, tid: u8, op: u8, p: &HtParams) {
    // r30 = is_insert, r31 = key. Clobbers r28/r29.
    // is_insert = hash(t*977+o+55) % 4 == 0
    a.li(28, 977);
    a.mul(28, tid, 28);
    a.add(28, 28, op);
    a.addi(28, 28, 55);
    emit_hash(a, 29, 28, 30);
    a.andi(30, 29, 3);
    a.li(28, 1);
    a.sltu(30, 30, 28); // r30 = 1 iff (h & 3) == 0 -> insert
    // lookup key = pkey(hash(t*31+o*17+2) % preload)
    a.li(28, 31);
    a.mul(28, tid, 28);
    a.li(29, 17);
    a.mul(29, op, 29);
    a.add(28, 28, 29);
    a.addi(28, 28, 2);
    emit_hash(a, 31, 28, 29);
    a.li(29, (p.preload - 1) as i64);
    // preload is a power of two at both scales.
    debug_assert!(p.preload.is_power_of_two());
    a.and(31, 31, 29);
    a.li(29, 5);
    a.mul(31, 31, 29);
    a.addi(31, 31, 7); // pkey
    // if insert: key = (t*4096+o)*5+3
    a.beq(30, 0, "keygen_done");
    a.slli(31, tid, 12);
    a.add(31, 31, op);
    a.li(29, 5);
    a.mul(31, 31, 29);
    a.addi(31, 31, 3);
    a.label("keygen_done");
}

fn build_sync(p: HtParams, m: Model, setup: impl Fn(&mut crate::sim::Simulator) + 'static) -> WorkloadSpec {
    let mut a = Asm::new("ht-sync");
    let (bb, pool) = (m.bucket_base, m.pool_base);
    a.li(4, 0); // sum
    a.li(20, 0); // tid
    a.li(21, p.tasks as i64);
    a.roi_begin();
    a.label("t_loop");
    a.li(22, 0); // op
    a.li(23, p.ops_per_task as i64);
    a.label("o_loop");
    emit_key_gen(&mut a, 20, 22, &p);
    // bucket addr -> r26
    a.li(26, 0x100_0193);
    a.mul(26, 31, 26);
    emit_hash(&mut a, 27, 26, 25);
    a.li(25, (p.buckets - 1) as i64);
    a.and(27, 27, 25);
    a.slli(27, 27, 3);
    a.li(26, bb as i64);
    a.add(26, 26, 27); // bucket addr
    a.bne(30, 0, "insert");
    // Lookup: walk chain with sync far loads.
    a.ld64(8, 26, 0);
    a.label("walk");
    a.beq(8, 0, "op_done"); // (pre-populated keys always hit)
    a.ld64(9, 8, 0);
    a.beq(9, 31, "hit");
    a.ld64(8, 8, 16);
    a.j("walk");
    a.label("hit");
    a.ld64(10, 8, 8);
    a.add(4, 4, 10);
    a.j("op_done");
    // Insert: node = pool + (tid*ops + op)*64; write node; push head.
    a.label("insert");
    a.li(9, p.ops_per_task as i64);
    a.mul(9, 20, 9);
    a.add(9, 9, 22);
    a.slli(9, 9, 6);
    a.li(10, pool as i64);
    a.add(9, 9, 10); // node addr
    a.st64(31, 9, 0); // key
    a.li(10, 999);
    a.st64(10, 9, 8); // value
    a.ld64(10, 26, 0); // head
    a.st64(10, 9, 16); // next
    a.st64(9, 26, 0); // head = node
    a.label("op_done");
    a.addi(22, 22, 1);
    a.blt(22, 23, "o_loop");
    a.addi(20, 20, 1);
    a.blt(20, 21, "t_loop");
    a.roi_end();
    a.li(14, crate::isa::mem::LOCAL_BASE as i64);
    a.st64(4, 14, 0);
    a.halt();
    let prog = a.finish();
    let expected: u64 = (0..p.tasks as u64)
        .map(|t| expected_task_sum(t, &p))
        .fold(0u64, |x, y| x.wrapping_add(y));
    let bb2 = m.bucket_base;
    WorkloadSpec {
        name: "ht".into(),
        prog,
        setup: Box::new(setup),
        validate: Box::new(move |sim| {
            let got = sim.guest.read_u64(crate::isa::mem::LOCAL_BASE);
            if got != expected {
                return Err(format!("sum {got} != {expected}"));
            }
            validate_structure(sim, &p, bb2)
        }),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: HtParams,
    m: Model,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    let dis = DisambigRt::new(layout, (p.tasks as u64 * 16).next_power_of_two());
    let (bb, pool) = (m.bucket_base, m.pool_base);
    let ops = p.ops_per_task;
    let pc = p.clone_for_emit();
    let (prog, rt) = AmuScaffold::build(
        "ht-amu",
        layout,
        cfg,
        p.tasks,
        NODE_BYTES,
        |a: &mut Asm, rt: &CoroRt| {
            rt.emit_load_param(a, 10, 0); // tid
            rt.emit_load_param(a, 11, 1); // spm slot
            a.li(12, 0); // op
            a.li(13, 0); // sum
            a.label("h_oloop");
            emit_key_gen(a, 10, 12, &pc); // r30 = is_insert, r31 = key
            // bucket addr -> r18
            a.li(18, 0x100_0193);
            a.mul(18, 31, 18);
            emit_hash(a, 19, 18, 17);
            a.li(17, (pc.buckets - 1) as i64);
            a.and(19, 19, 17);
            a.slli(19, 19, 3);
            a.li(18, bb as i64);
            a.add(18, 18, 19); // bucket addr (local)
            a.bne(30, 0, "h_insert");
            // --- lookup ---
            a.ld64(15, 18, 0); // head (local)
            a.label("h_walk");
            a.beq(15, 0, "h_opdone");
            a.aload(16, 11, 15);
            rt.emit_await(a, 16, &[10, 11, 12, 13, 15, 31], "h_r1");
            a.ld64(17, 11, 0);
            a.beq(17, 31, "h_hit");
            a.ld64(15, 11, 16);
            a.j("h_walk");
            a.label("h_hit");
            a.ld64(17, 11, 8);
            a.add(13, 13, 17);
            a.j("h_opdone");
            // --- insert (bucket claimed via disambiguation) ---
            a.label("h_insert");
            dis.emit_start_access(rt, a, 18, 14, &[10, 11, 12, 13, 14, 18, 31]);
            // node addr = pool + (tid*ops + op)*64
            a.li(15, ops as i64);
            a.mul(15, 10, 15);
            a.add(15, 15, 12);
            a.slli(15, 15, 6);
            a.li(16, pool as i64);
            a.add(15, 15, 16);
            // build node in SPM
            a.st64(31, 11, 0);
            a.li(16, 999);
            a.st64(16, 11, 8);
            a.ld64(16, 18, 0); // head (local, claimed)
            a.st64(16, 11, 16);
            a.astore(17, 11, 15);
            rt.emit_await(a, 17, &[10, 11, 12, 13, 14, 15, 18], "h_r2");
            a.st64(15, 18, 0); // publish new head
            dis.emit_end_access(rt, a, 14);
            a.label("h_opdone");
            a.addi(12, 12, 1);
            a.li(17, ops as i64);
            a.blt(12, 17, "h_oloop");
            a.st64(13, R_CUR_TCB, OFF_PARAM + 24);
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let rt_check = rt.clone();
    let prog2 = prog.clone();
    let expected: Vec<u64> =
        (0..p.tasks as u64).map(|t| expected_task_sum(t, &p)).collect();
    let bb2 = m.bucket_base;
    WorkloadSpec {
        name: "ht".into(),
        prog,
        setup: Box::new(move |sim| {
            setup(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * 64, 0, 0]
            });
        }),
        validate: Box::new(move |sim| {
            for (tid, want) in expected.iter().enumerate() {
                let got =
                    sim.guest.read_u64(rt_check.tcb_addr(tid) + OFF_PARAM as u64 + 24);
                if got != *want {
                    return Err(format!("task {tid}: sum {got} != {want}"));
                }
            }
            validate_structure(sim, &p, bb2)
        }),
    }
}

impl HtParams {
    fn clone_for_emit(&self) -> HtParams {
        HtParams {
            buckets: self.buckets,
            preload: self.preload,
            tasks: self.tasks,
            ops_per_task: self.ops_per_task,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_ht_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("ht sync");
    }

    #[test]
    fn amu_ht_validates_with_disambiguation() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("ht amu");
        let frac = sim.stats.region_fraction(crate::stats::Region::Disambig);
        assert!(frac > 0.0, "disambiguation work must be attributed");
    }
}
