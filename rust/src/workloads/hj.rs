//! HJ — main-memory hash join (Balkesen et al. '13 shape). The build
//! relation is inserted into a chained hash table (buckets local, 48 B
//! nodes far); the probe relation then walks the chains. The AMU port runs
//! both phases as coroutines, with the build phase's bucket updates
//! protected by software disambiguation (Table 5's other workload).
//!
//! Determinism: each task probes the tuples *it* built (already inserted
//! when probed) plus keys guaranteed absent; match counts are therefore
//! exact under any interleaving.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::disambig::DisambigRt;
use crate::coro::{CoroRt, OFF_PARAM, R_CUR_TCB};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;

pub struct HjParams {
    pub buckets: u64, // power of two
    pub tasks: usize,
    pub build_per_task: u64,
    pub probe_per_task: u64,
}

impl HjParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => {
                Self { buckets: 512, tasks: 32, build_per_task: 8, probe_per_task: 8 }
            }
            Scale::Paper => {
                Self { buckets: 16384, tasks: 256, build_per_task: 64, probe_per_task: 64 }
            }
        }
    }
}

const NODE_BYTES: u64 = 48; // paper: 48 B nodes
const NODE_STRIDE: u64 = 64;

#[allow(dead_code)] // host-side mirror of the guest key scheme (docs/tests)
fn build_key(t: u64, j: u64, ops: u64) -> u64 {
    (t * ops + j) * 2 + 2 // even keys are built
}

/// Probe j of task t: probe own built key (hits) when j even, an odd key
/// (guaranteed miss) when j odd.
#[allow(dead_code)] // host-side mirror of the guest key scheme
fn probe_key(t: u64, j: u64, build_ops: u64) -> u64 {
    if j % 2 == 0 {
        build_key(t, host_hash(t * 3 + j) % build_ops, build_ops)
    } else {
        (t * 1000 + j) * 2 + 1
    }
}

#[allow(dead_code)] // host-side mirror of the guest bucket hash
fn bucket_of(key: u64, buckets: u64) -> u64 {
    host_hash(key.wrapping_mul(0x9E3B)) & (buckets - 1)
}

fn expected_matches_per_task(p: &HjParams) -> u64 {
    (0..p.probe_per_task).filter(|j| j % 2 == 0).count() as u64
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = HjParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let bucket_base = layout.alloc_local(p.buckets * 8, 64);
    let pool = layout.alloc_far(p.tasks as u64 * p.build_per_task * NODE_STRIDE, 4096);
    match variant {
        Variant::Amu | Variant::AmuLlvm => build_amu(cfg, &mut layout, p, bucket_base, pool),
        _ => build_sync(p, bucket_base, pool),
    }
}

fn build_sync(p: HjParams, bucket_base: u64, pool: u64) -> WorkloadSpec {
    let mut a = Asm::new("hj-sync");
    a.li(4, 0); // match count
    a.roi_begin();
    // ---- build phase ----
    a.li(20, 0); // t
    a.li(21, p.tasks as i64);
    a.label("b_tloop");
    a.li(22, 0); // j
    a.li(23, p.build_per_task as i64);
    a.label("b_jloop");
    // key = (t*ops+j)*2+2
    a.li(5, p.build_per_task as i64);
    a.mul(5, 20, 5);
    a.add(5, 5, 22);
    a.slli(6, 5, 1);
    a.addi(6, 6, 2); // key in r6
    // node addr
    a.slli(7, 5, 6);
    a.li(8, pool as i64);
    a.add(7, 7, 8);
    // bucket addr -> r9
    a.li(9, 0x9E3B);
    a.mul(9, 6, 9);
    emit_hash(&mut a, 10, 9, 11);
    a.li(11, (p.buckets - 1) as i64);
    a.and(10, 10, 11);
    a.slli(10, 10, 3);
    a.li(9, bucket_base as i64);
    a.add(9, 9, 10);
    // insert: node.{key,payload,next}; head = node
    a.st64(6, 7, 0);
    a.mul(11, 6, 6);
    a.st64(11, 7, 8);
    a.ld64(11, 9, 0);
    a.st64(11, 7, 16);
    a.st64(7, 9, 0);
    a.addi(22, 22, 1);
    a.blt(22, 23, "b_jloop");
    a.addi(20, 20, 1);
    a.blt(20, 21, "b_tloop");
    // ---- probe phase ----
    a.li(20, 0);
    a.label("p_tloop");
    a.li(22, 0);
    a.li(23, p.probe_per_task as i64);
    a.label("p_jloop");
    // key: even j -> build_key(t, hash(t*3+j)%ops); odd -> miss key
    a.andi(5, 22, 1);
    a.bne(5, 0, "p_odd");
    a.li(5, 3);
    a.mul(5, 20, 5);
    a.add(5, 5, 22);
    emit_hash(&mut a, 6, 5, 7);
    // % build_ops via multiplicative reduction is wrong for the host mirror
    // unless mirrored exactly — use power-of-two ops? build_per_task is 8 or
    // 64 (powers of two): mask works.
    a.li(7, (p.build_per_task - 1) as i64);
    a.and(6, 6, 7);
    a.li(7, p.build_per_task as i64);
    a.mul(5, 20, 7);
    a.add(5, 5, 6);
    a.slli(6, 5, 1);
    a.addi(6, 6, 2);
    a.j("p_key_done");
    a.label("p_odd");
    a.li(5, 1000);
    a.mul(5, 20, 5);
    a.add(5, 5, 22);
    a.slli(6, 5, 1);
    a.addi(6, 6, 1);
    a.label("p_key_done");
    // bucket
    a.li(9, 0x9E3B);
    a.mul(9, 6, 9);
    emit_hash(&mut a, 10, 9, 11);
    a.li(11, (p.buckets - 1) as i64);
    a.and(10, 10, 11);
    a.slli(10, 10, 3);
    a.li(9, bucket_base as i64);
    a.add(9, 9, 10);
    a.ld64(8, 9, 0);
    a.label("p_walk");
    a.beq(8, 0, "p_done");
    a.ld64(10, 8, 0);
    a.beq(10, 6, "p_hit");
    a.ld64(8, 8, 16);
    a.j("p_walk");
    a.label("p_hit");
    a.addi(4, 4, 1);
    a.label("p_done");
    a.addi(22, 22, 1);
    a.blt(22, 23, "p_jloop");
    a.addi(20, 20, 1);
    a.blt(20, 21, "p_tloop");
    a.roi_end();
    a.li(14, crate::isa::mem::LOCAL_BASE as i64);
    a.st64(4, 14, 0);
    a.halt();
    let prog = a.finish();
    // Host mirror: even probes hit (their keys were built in the build
    // phase), odd probes are guaranteed misses.
    let expected: u64 = (p.tasks as u64) * expected_matches_per_task(&p);
    WorkloadSpec {
        name: "hj".into(),
        prog,
        setup: Box::new(|_sim| {}),
        validate: Box::new(move |sim| {
            let got = sim.guest.read_u64(crate::isa::mem::LOCAL_BASE);
            if got == expected {
                Ok(())
            } else {
                Err(format!("matches {got} != expected {expected}"))
            }
        }),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: HjParams,
    bucket_base: u64,
    pool: u64,
) -> WorkloadSpec {
    let dis = DisambigRt::new(layout, (p.tasks as u64 * 16).next_power_of_two());
    let build_ops = p.build_per_task;
    let probe_ops = p.probe_per_task;
    let buckets = p.buckets;
    let (prog, rt) = AmuScaffold::build(
        "hj-amu",
        layout,
        cfg,
        p.tasks,
        NODE_BYTES,
        |a: &mut Asm, rt: &CoroRt| {
            rt.emit_load_param(a, 10, 0); // tid
            rt.emit_load_param(a, 11, 1); // spm slot
            // ---- build ----
            a.li(12, 0); // j
            a.label("hb_loop");
            a.li(5, build_ops as i64);
            a.mul(5, 10, 5);
            a.add(5, 5, 12);
            a.slli(31, 5, 1);
            a.addi(31, 31, 2); // key
            a.slli(15, 5, 6);
            a.li(16, pool as i64);
            a.add(15, 15, 16); // node far addr
            // bucket addr -> r18
            a.li(18, 0x9E3B);
            a.mul(18, 31, 18);
            emit_hash(a, 19, 18, 17);
            a.li(17, (buckets - 1) as i64);
            a.and(19, 19, 17);
            a.slli(19, 19, 3);
            a.li(18, bucket_base as i64);
            a.add(18, 18, 19);
            dis.emit_start_access(rt, a, 18, 14, &[10, 11, 12, 14, 15, 18, 31]);
            // node in SPM
            a.st64(31, 11, 0);
            a.mul(16, 31, 31);
            a.st64(16, 11, 8);
            a.ld64(16, 18, 0);
            a.st64(16, 11, 16);
            a.astore(17, 11, 15);
            rt.emit_await(a, 17, &[10, 11, 12, 14, 15, 18], "hb_r1");
            a.st64(15, 18, 0);
            dis.emit_end_access(rt, a, 14);
            a.addi(12, 12, 1);
            a.li(17, build_ops as i64);
            a.blt(12, 17, "hb_loop");
            // ---- probe ----
            a.li(12, 0);
            a.li(13, 0); // matches
            a.label("hp_loop");
            a.andi(5, 12, 1);
            a.bne(5, 0, "hp_odd");
            a.li(5, 3);
            a.mul(5, 10, 5);
            a.add(5, 5, 12);
            emit_hash(a, 31, 5, 17);
            a.li(17, (build_ops - 1) as i64);
            a.and(31, 31, 17);
            a.li(17, build_ops as i64);
            a.mul(5, 10, 17);
            a.add(5, 5, 31);
            a.slli(31, 5, 1);
            a.addi(31, 31, 2);
            a.j("hp_key_done");
            a.label("hp_odd");
            a.li(5, 1000);
            a.mul(5, 10, 5);
            a.add(5, 5, 12);
            a.slli(31, 5, 1);
            a.addi(31, 31, 1);
            a.label("hp_key_done");
            a.li(18, 0x9E3B);
            a.mul(18, 31, 18);
            emit_hash(a, 19, 18, 17);
            a.li(17, (buckets - 1) as i64);
            a.and(19, 19, 17);
            a.slli(19, 19, 3);
            a.li(18, bucket_base as i64);
            a.add(18, 18, 19);
            a.ld64(15, 18, 0); // head
            a.label("hp_walk");
            a.beq(15, 0, "hp_done");
            a.aload(16, 11, 15);
            rt.emit_await(a, 16, &[10, 11, 12, 13, 15, 31], "hp_r1");
            a.ld64(17, 11, 0);
            a.beq(17, 31, "hp_hit");
            a.ld64(15, 11, 16);
            a.j("hp_walk");
            a.label("hp_hit");
            a.addi(13, 13, 1);
            a.label("hp_done");
            a.addi(12, 12, 1);
            a.li(17, probe_ops as i64);
            a.blt(12, 17, "hp_loop");
            a.st64(13, R_CUR_TCB, OFF_PARAM + 24);
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let rt_check = rt.clone();
    let prog2 = prog.clone();
    let want = expected_matches_per_task(&p);
    let tasks = p.tasks;
    WorkloadSpec {
        name: "hj".into(),
        prog,
        setup: Box::new(move |sim| {
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * 64, 0, 0]
            });
        }),
        validate: Box::new(move |sim| {
            for tid in 0..tasks {
                let got =
                    sim.guest.read_u64(rt_check.tcb_addr(tid) + OFF_PARAM as u64 + 24);
                if got != want {
                    return Err(format!("task {tid}: matches {got} != {want}"));
                }
            }
            Ok(())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_hj_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("hj sync");
    }

    #[test]
    fn amu_hj_validates_with_disambiguation() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("hj amu");
        assert!(sim.stats.region_fraction(crate::stats::Region::Disambig) > 0.0);
    }
}
