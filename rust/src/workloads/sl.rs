//! SL — skip-list lookup (ASCYLIB). Nodes carry a 32 B payload
//! (key/value/meta) plus 15 level pointers (paper Table 3); each lookup
//! descends the towers, a serial chain of dependent far accesses whose
//! length is ~log N. 128 coroutines (paper) provide the request-level
//! parallelism.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::{CoroRt, OFF_PARAM, R_CUR_TCB};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;
use crate::util::prng::Xoshiro256;

pub const MAX_LEVEL: usize = 15;
const NODE_BYTES: u64 = 24 + 8 * MAX_LEVEL as u64; // key,val,meta + ptrs = 144
const NODE_STRIDE: u64 = 192;
const OFF_PTRS: i64 = 24;

pub struct SlParams {
    pub elems: u64,
    pub tasks: usize,
    pub lookups_per_task: u64,
}

impl SlParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { elems: 256, tasks: 32, lookups_per_task: 2 },
            Scale::Paper => Self { elems: 4096, tasks: 128, lookups_per_task: 4 },
        }
    }
}

fn node_key(i: u64) -> u64 {
    2 * i + 2 // even keys; head sentinel holds key 0
}

fn target_key(tid: u64, k: u64, elems: u64) -> u64 {
    let h = host_hash(tid * 911 + k * 13 + 5);
    ((h >> 32) * (2 * elems + 2)) >> 32
}

fn expected_task_sum(tid: u64, p: &SlParams) -> u64 {
    let mut sum = 0u64;
    for k in 0..p.lookups_per_task {
        let key = target_key(tid, k, p.elems);
        if key >= 2 && key % 2 == 0 && (key - 2) / 2 < p.elems {
            let i = (key - 2) / 2;
            sum = sum.wrapping_add(i.wrapping_mul(17));
        }
    }
    sum
}

/// Host-side skip list construction: returns (head_addr, setup closure).
fn build_skiplist(
    base: u64,
    p: &SlParams,
    seed: u64,
) -> (u64, impl Fn(&mut crate::sim::Simulator) + 'static) {
    let mut rng = Xoshiro256::new(seed);
    let n = p.elems as usize;
    // Shuffled placement; slot n is the head sentinel.
    let perm = rng.permutation(n);
    let addrs: Vec<u64> = (0..n).map(|i| base + perm[i] * NODE_STRIDE).collect();
    let head = base + n as u64 * NODE_STRIDE;
    // Deterministic geometric levels in [1, MAX_LEVEL].
    let levels: Vec<usize> = (0..n)
        .map(|i| {
            let h = host_hash(seed ^ (i as u64 + 1));
            ((h.trailing_ones() as usize) + 1).min(MAX_LEVEL)
        })
        .collect();
    let elems = p.elems;
    let setup = move |sim: &mut crate::sim::Simulator| {
        // Head sentinel: key 0, full height.
        sim.guest.write_u64(head, 0);
        sim.guest.write_u64(head + 8, 0);
        // Link each level: nodes in key order with level > l.
        let mut prev_at_level: Vec<u64> = vec![head; MAX_LEVEL];
        for i in 0..elems as usize {
            let a = addrs[i];
            sim.guest.write_u64(a, node_key(i as u64));
            sim.guest.write_u64(a + 8, (i as u64).wrapping_mul(17));
            sim.guest.write_u64(a + 16, levels[i] as u64);
            for l in 0..levels[i] {
                let prev = prev_at_level[l];
                sim.guest.write_u64(prev + OFF_PTRS as u64 + l as u64 * 8, a);
                prev_at_level[l] = a;
            }
        }
        // Terminate all levels.
        for (l, prev) in prev_at_level.iter().enumerate() {
            sim.guest
                .write_u64(*prev + OFF_PTRS as u64 + l as u64 * 8, 0);
        }
    };
    (head, setup)
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = SlParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let base = layout.alloc_far((p.elems + 1) * NODE_STRIDE, 4096);
    let (head, setup) = build_skiplist(base, &p, 1234);
    match variant {
        Variant::Amu | Variant::AmuLlvm => build_amu(cfg, &mut layout, p, head, setup),
        _ => build_sync(p, head, setup),
    }
}

/// Emit key generation into `key_reg` given tid in `tid`, k in `k`.
fn emit_target_key(a: &mut Asm, key_reg: u8, tid: u8, k: u8, tmp: u8, elems: u64) {
    a.li(tmp, 911);
    a.mul(tmp, tid, tmp);
    a.li(key_reg, 13);
    a.mul(key_reg, k, key_reg);
    a.add(tmp, tmp, key_reg);
    a.addi(tmp, tmp, 5);
    emit_hash(a, key_reg, tmp, if tmp == 28 { 29 } else { 28 });
    a.srli(key_reg, key_reg, 32);
    a.li(tmp, (2 * elems + 2) as i64);
    a.mul(key_reg, key_reg, tmp);
    a.srli(key_reg, key_reg, 32);
}

fn build_sync(
    p: SlParams,
    head: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    let mut a = Asm::new("sl-sync");
    a.li(4, 0); // sum
    a.li(20, 0); // tid
    a.li(21, p.tasks as i64);
    a.roi_begin();
    a.label("t_loop");
    a.li(22, 0); // k
    a.li(23, p.lookups_per_task as i64);
    a.label("k_loop");
    emit_target_key(&mut a, 6, 20, 22, 24, p.elems);
    // descend: r8 = cur (far addr), r16 = level
    a.li(8, head as i64);
    a.li(16, (MAX_LEVEL - 1) as i64);
    a.label("desc");
    // nxt = cur.ptrs[level]
    a.slli(9, 16, 3);
    a.add(9, 9, 8);
    a.ld64(10, 9, OFF_PTRS); // nxt
    a.beq(10, 0, "down");
    a.ld64(11, 10, 0); // nxt.key
    a.beq(11, 6, "hit");
    a.bltu(11, 6, "advance");
    a.label("down");
    a.addi(16, 16, -1);
    a.bge(16, 0, "desc");
    a.j("miss");
    a.label("advance");
    a.mv(8, 10);
    a.j("desc");
    a.label("hit");
    a.ld64(12, 10, 8);
    a.add(4, 4, 12);
    a.label("miss");
    a.addi(22, 22, 1);
    a.blt(22, 23, "k_loop");
    a.addi(20, 20, 1);
    a.blt(20, 21, "t_loop");
    a.roi_end();
    a.li(14, crate::isa::mem::LOCAL_BASE as i64);
    a.st64(4, 14, 0);
    a.halt();
    let prog = a.finish();
    let expected: u64 = (0..p.tasks as u64)
        .map(|t| expected_task_sum(t, &p))
        .fold(0u64, |x, y| x.wrapping_add(y));
    WorkloadSpec {
        name: "sl".into(),
        prog,
        setup: Box::new(setup),
        validate: Box::new(move |sim| {
            let got = sim.guest.read_u64(crate::isa::mem::LOCAL_BASE);
            if got == expected {
                Ok(())
            } else {
                Err(format!("sum {got} != expected {expected}"))
            }
        }),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: SlParams,
    head: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    let elems = p.elems;
    let per_task = p.lookups_per_task;
    // Two SPM node buffers per task (cur, nxt).
    let slot_bytes = 2 * NODE_STRIDE;
    let (prog, rt) = AmuScaffold::build(
        "sl-amu",
        layout,
        cfg,
        p.tasks,
        NODE_BYTES,
        |a: &mut Asm, rt: &CoroRt| {
            rt.emit_load_param(a, 10, 0); // tid
            rt.emit_load_param(a, 11, 1); // buf A (cur)
            a.addi(21, 11, NODE_STRIDE as i64); // buf B (nxt)
            a.li(12, 0); // k
            a.li(13, 0); // sum
            a.label("sl_kloop");
            emit_target_key(a, 14, 10, 12, 15, elems);
            // load head into buf A
            a.li(15, head as i64);
            a.aload(16, 11, 15);
            rt.emit_await(a, 16, &[10, 11, 12, 13, 14, 21], "sl_r1");
            a.li(16, (MAX_LEVEL - 1) as i64); // level
            a.label("sl_desc");
            a.slli(17, 16, 3);
            a.add(17, 17, 11);
            a.ld64(18, 17, OFF_PTRS); // nxt far addr from cur buf
            a.beq(18, 0, "sl_down");
            a.aload(19, 21, 18);
            rt.emit_await(a, 19, &[10, 11, 12, 13, 14, 16, 21], "sl_r2");
            a.ld64(20, 21, 0); // nxt.key
            a.beq(20, 14, "sl_hit");
            a.bltu(20, 14, "sl_advance");
            a.label("sl_down");
            a.addi(16, 16, -1);
            a.bge(16, 0, "sl_desc");
            a.j("sl_miss");
            a.label("sl_advance");
            // swap buf roles: cur <-> nxt
            a.mv(22, 11);
            a.mv(11, 21);
            a.mv(21, 22);
            a.j("sl_desc");
            a.label("sl_hit");
            a.ld64(20, 21, 8);
            a.add(13, 13, 20);
            a.label("sl_miss");
            // restore canonical buffer assignment from the TCB param
            rt.emit_load_param(a, 11, 1);
            a.addi(21, 11, NODE_STRIDE as i64);
            a.addi(12, 12, 1);
            a.li(20, per_task as i64);
            a.blt(12, 20, "sl_kloop");
            a.st64(13, R_CUR_TCB, OFF_PARAM + 24);
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let rt_check = rt.clone();
    let prog2 = prog.clone();
    let expected: Vec<u64> =
        (0..p.tasks as u64).map(|t| expected_task_sum(t, &p)).collect();
    WorkloadSpec {
        name: "sl".into(),
        prog,
        setup: Box::new(move |sim| {
            setup(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * slot_bytes, 0, 0]
            });
        }),
        validate: Box::new(move |sim| {
            for (tid, want) in expected.iter().enumerate() {
                let got =
                    sim.guest.read_u64(rt_check.tcb_addr(tid) + OFF_PARAM as u64 + 24);
                if got != *want {
                    return Err(format!("task {tid}: sum {got} != {want}"));
                }
            }
            Ok(())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_sl_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("sl sync");
    }

    #[test]
    fn amu_sl_validates() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(500.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("sl amu");
        assert!(sim.stats.far_inflight.max >= 8);
    }
}
