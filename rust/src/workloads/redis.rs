//! Redis — a chained-hash KV store served under a YCSB-B-like mix
//! (95 % GET / 5 % SET, Zipfian keys). Matching the paper's setup: the
//! bucket array lives in local memory, the collision-list nodes (64 B:
//! key, value-length, next, 40 B inline value) live in far memory, and the
//! single-threaded execution model is replaced by request-concurrent
//! coroutines.
//!
//! The request stream is materialized host-side into a local request queue
//! (as an RPC ring would be); SETs update values in place (last-writer-wins
//! on racing SETs — keys/chains are immutable), so GET hit counts and the
//! final key population are deterministic.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::{CoroRt, OFF_PARAM, R_CUR_TCB};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;
use crate::util::prng::Xoshiro256;

pub struct RedisParams {
    pub buckets: u64, // power of two
    pub records: u64,
    pub tasks: usize,
    pub ops_per_task: u64,
    pub zipf_theta: f64,
}

impl RedisParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self {
                buckets: 256,
                records: 512,
                tasks: 32,
                ops_per_task: 4,
                zipf_theta: 0.99,
            },
            Scale::Paper => Self {
                buckets: 4096,
                records: 8192,
                tasks: 256,
                ops_per_task: 8,
                zipf_theta: 0.99,
            },
        }
    }
}

const NODE_STRIDE: u64 = 64;

fn rkey(i: u64) -> u64 {
    i * 7 + 11
}

fn bucket_of(key: u64, buckets: u64) -> u64 {
    host_hash(key.wrapping_mul(31)) & (buckets - 1)
}

/// Request: [type (0=GET,1=SET)][key] — 16 B in the local request queue.
struct Ops {
    stream: Vec<(u64, u64)>, // (type, key) flattened task-major
}

fn gen_ops(p: &RedisParams, seed: u64) -> Ops {
    let mut rng = Xoshiro256::new(seed);
    let mut stream = Vec::new();
    for _t in 0..p.tasks as u64 {
        for _o in 0..p.ops_per_task {
            let is_set = rng.below(100) < 5;
            let rec = rng.zipf(p.records, p.zipf_theta);
            stream.push((is_set as u64, rkey(rec)));
        }
    }
    Ops { stream }
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = RedisParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let ops = std::rc::Rc::new(gen_ops(&p, 0xDB));
    let mut layout = mk_layout(cfg);
    let bucket_base = layout.alloc_local(p.buckets * 8, 64);
    let nodes = layout.alloc_far(p.records * NODE_STRIDE, 4096);
    let req_q = layout.alloc_local(ops.stream.len() as u64 * 16, 64);
    let setup = {
        let ops = ops.clone();
        let (bb, nodes, req_q, buckets, records) =
            (bucket_base, nodes, req_q, p.buckets, p.records);
        move |sim: &mut crate::sim::Simulator| {
            // Preload records into chains.
            let mut heads = vec![0u64; buckets as usize];
            for i in 0..records {
                let key = rkey(i);
                let b = bucket_of(key, buckets) as usize;
                let addr = nodes + i * NODE_STRIDE;
                sim.guest.write_u64(addr, key);
                sim.guest.write_u64(addr + 8, 40); // value length
                sim.guest.write_u64(addr + 16, heads[b]);
                sim.guest.write_u64(addr + 24, key.wrapping_mul(5)); // value word
                heads[b] = addr;
            }
            for (b, h) in heads.iter().enumerate() {
                sim.guest.write_u64(bb + b as u64 * 8, *h);
            }
            for (i, (ty, key)) in ops.stream.iter().enumerate() {
                sim.guest.write_u64(req_q + i as u64 * 16, *ty);
                sim.guest.write_u64(req_q + i as u64 * 16 + 8, *key);
            }
        }
    };
    // Expected per-task GET-hit count (every key exists: all GETs hit).
    let expected: Vec<u64> = (0..p.tasks)
        .map(|t| {
            (0..p.ops_per_task)
                .filter(|o| ops.stream[t * p.ops_per_task as usize + *o as usize].0 == 0)
                .count() as u64
        })
        .collect();
    match variant {
        Variant::Amu | Variant::AmuLlvm => {
            build_amu(cfg, &mut layout, p, bucket_base, req_q, setup, expected)
        }
        _ => build_sync(p, bucket_base, req_q, setup, expected),
    }
}

fn build_sync(
    p: RedisParams,
    bucket_base: u64,
    req_q: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
    expected: Vec<u64>,
) -> WorkloadSpec {
    let total_ops = p.tasks as u64 * p.ops_per_task;
    let mut a = Asm::new("redis-sync");
    a.li(4, 0); // GET hits
    a.li(2, 0); // op index
    a.li(3, total_ops as i64);
    a.roi_begin();
    a.label("op_loop");
    a.slli(5, 2, 4);
    a.li(6, req_q as i64);
    a.add(5, 5, 6);
    a.ld64(6, 5, 0); // type
    a.ld64(7, 5, 8); // key
    // bucket
    a.li(8, 31);
    a.mul(8, 7, 8);
    emit_hash(&mut a, 9, 8, 10);
    a.li(10, (p.buckets - 1) as i64);
    a.and(9, 9, 10);
    a.slli(9, 9, 3);
    a.li(8, bucket_base as i64);
    a.add(8, 8, 9);
    a.ld64(9, 8, 0); // head
    a.label("walk");
    a.beq(9, 0, "op_next");
    a.ld64(10, 9, 0);
    a.beq(10, 7, "found");
    a.ld64(9, 9, 16);
    a.j("walk");
    a.label("found");
    a.bne(6, 0, "do_set");
    a.ld64(11, 9, 24); // read value word
    a.addi(4, 4, 1);
    a.j("op_next");
    a.label("do_set");
    a.st64(2, 9, 24); // value = op index (far store)
    a.label("op_next");
    a.addi(2, 2, 1);
    a.blt(2, 3, "op_loop");
    a.roi_end();
    a.li(14, crate::isa::mem::LOCAL_BASE as i64);
    a.st64(4, 14, 0);
    a.halt();
    let want: u64 = expected.iter().sum();
    WorkloadSpec {
        name: "redis".into(),
        prog: a.finish(),
        setup: Box::new(setup),
        validate: Box::new(move |sim| {
            let got = sim.guest.read_u64(crate::isa::mem::LOCAL_BASE);
            if got == want {
                Ok(())
            } else {
                Err(format!("GET hits {got} != {want}"))
            }
        }),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: RedisParams,
    bucket_base: u64,
    req_q: u64,
    setup: impl Fn(&mut crate::sim::Simulator) + 'static,
    expected: Vec<u64>,
) -> WorkloadSpec {
    let ops = p.ops_per_task;
    let buckets = p.buckets;
    let (prog, rt) = AmuScaffold::build(
        "redis-amu",
        layout,
        cfg,
        p.tasks,
        NODE_STRIDE, // whole node per aload
        |a: &mut Asm, rt: &CoroRt| {
            rt.emit_load_param(a, 10, 0); // tid
            rt.emit_load_param(a, 11, 1); // spm slot
            a.li(12, 0); // op
            a.li(13, 0); // hits
            a.label("rd_oloop");
            // request = req_q[(tid*ops + op) * 16]
            a.li(5, ops as i64);
            a.mul(5, 10, 5);
            a.add(5, 5, 12);
            a.slli(5, 5, 4);
            a.li(6, req_q as i64);
            a.add(5, 5, 6);
            a.ld64(30, 5, 0); // type
            a.ld64(31, 5, 8); // key
            // bucket
            a.li(18, 31);
            a.mul(18, 31, 18);
            emit_hash(a, 19, 18, 17);
            a.li(17, (buckets - 1) as i64);
            a.and(19, 19, 17);
            a.slli(19, 19, 3);
            a.li(18, bucket_base as i64);
            a.add(18, 18, 19);
            a.ld64(15, 18, 0); // head
            a.label("rd_walk");
            a.beq(15, 0, "rd_next");
            a.aload(16, 11, 15);
            rt.emit_await(a, 16, &[10, 11, 12, 13, 15, 30, 31], "rd_r1");
            a.ld64(17, 11, 0);
            a.beq(17, 31, "rd_found");
            a.ld64(15, 11, 16);
            a.j("rd_walk");
            a.label("rd_found");
            a.bne(30, 0, "rd_set");
            a.ld64(17, 11, 24);
            a.addi(13, 13, 1);
            a.j("rd_next");
            a.label("rd_set");
            // update value word in the SPM copy, write the node back
            a.li(17, ops as i64);
            a.mul(17, 10, 17);
            a.add(17, 17, 12);
            a.st64(17, 11, 24);
            a.astore(19, 11, 15);
            rt.emit_await(a, 19, &[10, 11, 12, 13], "rd_r2");
            a.label("rd_next");
            a.addi(12, 12, 1);
            a.li(17, ops as i64);
            a.blt(12, 17, "rd_oloop");
            a.st64(13, R_CUR_TCB, OFF_PARAM + 24);
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let rt_check = rt.clone();
    let prog2 = prog.clone();
    WorkloadSpec {
        name: "redis".into(),
        prog,
        setup: Box::new(move |sim| {
            setup(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * 64, 0, 0]
            });
        }),
        validate: Box::new(move |sim| {
            for (tid, want) in expected.iter().enumerate() {
                let got =
                    sim.guest.read_u64(rt_check.tcb_addr(tid) + OFF_PARAM as u64 + 24);
                if got != *want {
                    return Err(format!("task {tid}: hits {got} != {want}"));
                }
            }
            Ok(())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_redis_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("redis sync");
    }

    #[test]
    fn amu_redis_validates() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("redis amu");
        assert!(sim.stats.far_inflight.max >= 8);
    }
}
