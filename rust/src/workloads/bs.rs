//! BS — binary search over a sorted far-memory array (paper Table 3:
//! 256 coroutines, 16 B elements, random keys, shared array).
//!
//! Each lookup is a ~log2(N)-step chain of *dependent* far accesses: the
//! classic pointer-chase shape where request-level parallelism (many
//! concurrent searches) is the only available MLP.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::{CoroRt, OFF_PARAM, R_CUR_TCB};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;

pub struct BsParams {
    pub elems: u64, // power of two; element = 16 B [key][value]
    pub tasks: usize,
    pub searches_per_task: u64,
}

impl BsParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { elems: 1 << 12, tasks: 32, searches_per_task: 2 },
            Scale::Paper => Self { elems: 1 << 17, tasks: 256, searches_per_task: 4 },
        }
    }
}

/// key of element i = 2*i+1; value = i*13. Searched keys hit exactly when
/// odd and in range.
fn search_key(task: u64, k: u64, elems: u64) -> u64 {
    host_hash(task * 8191 + k) % (2 * elems)
}

/// Host-side expected sum of found values for one task.
fn expected_task_sum(tid: u64, p: &BsParams) -> u64 {
    let mut sum = 0u64;
    for k in 0..p.searches_per_task {
        let key = search_key(tid, k, p.elems);
        // Binary search for exact key 2*i+1.
        if key % 2 == 1 {
            let i = key / 2;
            if i < p.elems {
                sum = sum.wrapping_add(i.wrapping_mul(13));
            }
        }
    }
    sum
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = BsParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let arr = layout.alloc_far(p.elems * 16, 4096);
    let setup_arr = move |sim: &mut crate::sim::Simulator, elems: u64| {
        for i in 0..elems {
            sim.guest.write_u64(arr + i * 16, 2 * i + 1);
            sim.guest.write_u64(arr + i * 16 + 8, i.wrapping_mul(13));
        }
    };
    match variant {
        Variant::Amu | Variant::AmuLlvm => build_amu(cfg, &mut layout, p, arr, setup_arr),
        _ => build_sync(p, arr, setup_arr),
    }
}

/// Emit one binary-search step body shared by both variants is impractical
/// (different load mechanisms), so each variant carries its own loop.
fn build_sync(
    p: BsParams,
    arr: u64,
    setup_arr: impl Fn(&mut crate::sim::Simulator, u64) + 'static,
) -> WorkloadSpec {
    let mut a = Asm::new("bs-sync");
    a.li(1, arr as i64);
    a.li(4, 0); // sum
    a.li(20, 0); // task
    a.li(21, p.tasks as i64);
    a.roi_begin();
    a.label("task_loop");
    a.li(22, 0); // k
    a.li(23, p.searches_per_task as i64);
    a.label("k_loop");
    // key = hash(task*8191 + k) % 2N  (2N is a power of two)
    a.li(5, 8191);
    a.mul(5, 20, 5);
    a.add(5, 5, 22);
    emit_hash(&mut a, 6, 5, 7);
    a.li(7, (2 * p.elems - 1) as i64);
    a.and(6, 6, 7); // key
    // binary search [lo, hi)
    a.li(8, 0); // lo
    a.li(9, p.elems as i64); // hi
    a.label("bs_loop");
    a.bge(8, 9, "bs_done");
    a.add(10, 8, 9);
    a.srli(10, 10, 1); // mid
    a.slli(11, 10, 4);
    a.add(11, 11, 1);
    a.ld64(12, 11, 0); // key[mid]
    a.beq(12, 6, "bs_hit");
    a.bltu(12, 6, "bs_right");
    a.mv(9, 10); // hi = mid
    a.j("bs_loop");
    a.label("bs_right");
    a.addi(8, 10, 1); // lo = mid+1
    a.j("bs_loop");
    a.label("bs_hit");
    a.ld64(13, 11, 8);
    a.add(4, 4, 13);
    a.label("bs_done");
    a.addi(22, 22, 1);
    a.blt(22, 23, "k_loop");
    a.addi(20, 20, 1);
    a.blt(20, 21, "task_loop");
    a.roi_end();
    // Publish the sum for validation.
    a.li(14, crate::isa::mem::LOCAL_BASE as i64);
    a.st64(4, 14, 0);
    a.halt();
    let prog = a.finish();
    let expected: u64 = (0..p.tasks as u64)
        .map(|t| expected_task_sum(t, &p))
        .fold(0u64, |a, b| a.wrapping_add(b));
    let elems = p.elems;
    WorkloadSpec {
        name: "bs".into(),
        prog,
        setup: Box::new(move |sim| setup_arr(sim, elems)),
        validate: Box::new(move |sim| {
            let got = sim.guest.read_u64(crate::isa::mem::LOCAL_BASE);
            if got == expected {
                Ok(())
            } else {
                Err(format!("sum {got} != expected {expected}"))
            }
        }),
    }
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: BsParams,
    arr: u64,
    setup_arr: impl Fn(&mut crate::sim::Simulator, u64) + 'static,
) -> WorkloadSpec {
    let elems = p.elems;
    let per_task = p.searches_per_task;
    let (prog, rt) = AmuScaffold::build(
        "bs-amu",
        layout,
        cfg,
        p.tasks,
        16, // one 16 B element per aload
        |a: &mut Asm, rt: &CoroRt| {
            // params: p0 = tid, p1 = spm slot; accumulator published to p3.
            rt.emit_load_param(a, 10, 0); // tid
            rt.emit_load_param(a, 11, 1); // spm slot
            a.li(12, 0); // k
            a.li(13, 0); // sum
            a.label("b_kloop");
            a.li(5, 8191);
            a.mul(5, 10, 5);
            a.add(5, 5, 12);
            emit_hash(a, 14, 5, 15);
            a.li(15, (2 * elems - 1) as i64);
            a.and(14, 14, 15); // key
            a.li(15, 0); // lo
            a.li(16, elems as i64); // hi
            a.label("b_loop");
            a.bge(15, 16, "b_done");
            a.add(17, 15, 16);
            a.srli(17, 17, 1); // mid
            a.slli(18, 17, 4);
            a.li(19, arr as i64);
            a.add(18, 18, 19); // far element addr
            a.aload(20, 11, 18);
            rt.emit_await(a, 20, &[10, 11, 12, 13, 14, 15, 16, 17], "b_r1");
            a.ld64(19, 11, 0); // key[mid]
            a.beq(19, 14, "b_hit");
            a.bltu(19, 14, "b_right");
            a.mv(16, 17);
            a.j("b_loop");
            a.label("b_right");
            a.addi(15, 17, 1);
            a.j("b_loop");
            a.label("b_hit");
            a.ld64(19, 11, 8);
            a.add(13, 13, 19);
            a.label("b_done");
            a.addi(12, 12, 1);
            a.li(19, per_task as i64);
            a.blt(12, 19, "b_kloop");
            // Publish per-task sum into TCB param 3.
            a.st64(13, R_CUR_TCB, OFF_PARAM + 24);
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let rt_check = rt.clone();
    let prog2 = prog.clone();
    let expected: Vec<u64> =
        (0..p.tasks as u64).map(|t| expected_task_sum(t, &p)).collect();
    WorkloadSpec {
        name: "bs".into(),
        prog,
        setup: Box::new(move |sim| {
            setup_arr(sim, elems);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * 64, 0, 0]
            });
        }),
        validate: Box::new(move |sim| {
            // Per-task sums published into TCB param slot 3.
            for (tid, want) in expected.iter().enumerate() {
                let got =
                    sim.guest.read_u64(rt_check.tcb_addr(tid) + OFF_PARAM as u64 + 24);
                if got != *want {
                    return Err(format!("task {tid}: sum {got} != {want}"));
                }
            }
            Ok(())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_bs_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("bs sync");
    }

    #[test]
    fn amu_bs_validates_and_overlaps_chains() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("bs amu");
        assert!(
            sim.stats.far_inflight.max >= 16,
            "concurrent searches must overlap: {}",
            sim.stats.far_inflight.max
        );
    }
}
