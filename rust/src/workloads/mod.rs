//! The paper's Table 3 benchmark suite: build any benchmark in any variant
//! at test or paper scale.

pub mod bfs;
pub mod bs;
pub mod common;
pub mod gups;
pub mod hj;
pub mod hpcg;
pub mod ht;
pub mod is;
pub mod ll;
pub mod redis;
pub mod sl;
pub mod stream;

pub use common::{verify_cache_len, Scale, Variant, VariantKind, WorkloadSpec, ALL_VARIANT_KINDS};

use crate::config::SimConfig;

/// All Table 3 benchmark names, in the paper's order.
pub const ALL: &[&str] =
    &["bfs", "bs", "gups", "hj", "ht", "hpcg", "is", "ll", "redis", "sl", "stream"];

/// The memory-bound subset used in Fig 2 style motivation sweeps.
pub const MEMORY_BOUND: &[&str] = &["gups", "bs", "ll", "ht", "bfs"];

/// Build benchmark `name` in `variant` at `scale`, by registry lookup
/// (see [`crate::session::registry`]). Panics on unknown name — prefer
/// [`try_build`] or [`crate::session::RunRequest`], which return errors
/// naming the valid choices.
pub fn build(name: &str, cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    try_build(name, cfg, variant, scale)
        .unwrap_or_else(|| panic!("unknown benchmark '{name}' (known: {ALL:?})"))
}

/// Build benchmark `name` if it is registered; `None` otherwise.
pub fn try_build(
    name: &str,
    cfg: &SimConfig,
    variant: Variant,
    scale: Scale,
) -> Option<WorkloadSpec> {
    crate::session::registry::find(name).map(|w| w.build(cfg, variant, scale))
}

/// Pick the natural variant for a configuration: AMU configs run the
/// coroutine ports, everything else runs the synchronous code.
pub fn variant_for(cfg: &SimConfig) -> Variant {
    if cfg.amu.enabled {
        Variant::Amu
    } else {
        Variant::Sync
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_benchmark_sync() {
        let cfg = SimConfig::baseline();
        for name in ALL {
            let spec = build(name, &cfg, Variant::Sync, Scale::Test);
            assert!(!spec.prog.is_empty(), "{name} produced an empty program");
        }
    }

    #[test]
    fn registry_builds_every_benchmark_amu() {
        let cfg = SimConfig::amu();
        for name in ALL {
            let spec = build(name, &cfg, Variant::Amu, Scale::Test);
            assert!(!spec.prog.is_empty(), "{name} produced an empty program");
        }
    }

    #[test]
    #[should_panic(expected = "unknown benchmark")]
    fn unknown_name_panics() {
        build("nope", &SimConfig::baseline(), Variant::Sync, Scale::Test);
    }

    #[test]
    fn try_build_returns_none_for_unknown() {
        assert!(try_build("nope", &SimConfig::baseline(), Variant::Sync, Scale::Test).is_none());
        assert!(try_build("gups", &SimConfig::baseline(), Variant::Sync, Scale::Test).is_some());
    }

    #[test]
    fn variant_selection() {
        assert_eq!(variant_for(&SimConfig::amu()), Variant::Amu);
        assert_eq!(variant_for(&SimConfig::baseline()), Variant::Sync);
        assert_eq!(variant_for(&SimConfig::cxl_ideal()), Variant::Sync);
    }
}
