//! The paper's Table 3 benchmark suite: build any benchmark in any variant
//! at test or paper scale.

pub mod bfs;
pub mod bs;
pub mod common;
pub mod gups;
pub mod hj;
pub mod hpcg;
pub mod ht;
pub mod is;
pub mod ll;
pub mod redis;
pub mod sl;
pub mod stream;

pub use common::{Scale, Variant, WorkloadSpec};

use crate::config::SimConfig;

/// All Table 3 benchmark names, in the paper's order.
pub const ALL: &[&str] =
    &["bfs", "bs", "gups", "hj", "ht", "hpcg", "is", "ll", "redis", "sl", "stream"];

/// The memory-bound subset used in Fig 2 style motivation sweeps.
pub const MEMORY_BOUND: &[&str] = &["gups", "bs", "ll", "ht", "bfs"];

/// Build benchmark `name` in `variant` at `scale`. Panics on unknown name.
pub fn build(name: &str, cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    match name {
        "bfs" => bfs::build(cfg, variant, scale),
        "bs" => bs::build(cfg, variant, scale),
        "gups" => gups::build(cfg, variant, scale),
        "hj" => hj::build(cfg, variant, scale),
        "hpcg" => hpcg::build(cfg, variant, scale),
        "ht" => ht::build(cfg, variant, scale),
        "is" => is::build(cfg, variant, scale),
        "ll" => ll::build(cfg, variant, scale),
        "redis" => redis::build(cfg, variant, scale),
        "sl" => sl::build(cfg, variant, scale),
        "stream" => stream::build(cfg, variant, scale),
        _ => panic!("unknown benchmark '{name}' (known: {ALL:?})"),
    }
}

/// Pick the natural variant for a configuration: AMU configs run the
/// coroutine ports, everything else runs the synchronous code.
pub fn variant_for(cfg: &SimConfig) -> Variant {
    if cfg.amu.enabled {
        Variant::Amu
    } else {
        Variant::Sync
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_builds_every_benchmark_sync() {
        let cfg = SimConfig::baseline();
        for name in ALL {
            let spec = build(name, &cfg, Variant::Sync, Scale::Test);
            assert!(!spec.prog.is_empty(), "{name} produced an empty program");
        }
    }

    #[test]
    fn registry_builds_every_benchmark_amu() {
        let cfg = SimConfig::amu();
        for name in ALL {
            let spec = build(name, &cfg, Variant::Amu, Scale::Test);
            assert!(!spec.prog.is_empty(), "{name} produced an empty program");
        }
    }

    #[test]
    #[should_panic(expected = "unknown benchmark")]
    fn unknown_name_panics() {
        build("nope", &SimConfig::baseline(), Variant::Sync, Scale::Test);
    }

    #[test]
    fn variant_selection() {
        assert_eq!(variant_for(&SimConfig::amu()), Variant::Amu);
        assert_eq!(variant_for(&SimConfig::baseline()), Variant::Sync);
        assert_eq!(variant_for(&SimConfig::cxl_ideal()), Variant::Sync);
    }
}
