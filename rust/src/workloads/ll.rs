//! LL — hand-over-hand linked-list lookup (Herlihy & Shavit) over a far
//! memory list. Nodes are 24 B `[key][value][next]`, placed in *shuffled*
//! order so traversal is a genuine pointer chase with zero spatial
//! locality. Each coroutine looks up keys in a sorted singly-linked list.

use super::common::*;
use crate::config::SimConfig;
use crate::coro::{CoroRt, OFF_PARAM, R_CUR_TCB};
use crate::isa::mem::SPM_BASE;
use crate::isa::Asm;
use crate::util::prng::Xoshiro256;

pub struct LlParams {
    pub nodes: u64,
    pub tasks: usize,
    pub lookups_per_task: u64,
}

impl LlParams {
    pub fn new(scale: Scale) -> Self {
        match scale {
            Scale::Test => Self { nodes: 48, tasks: 32, lookups_per_task: 1 },
            Scale::Paper => Self { nodes: 192, tasks: 256, lookups_per_task: 2 },
        }
    }
}

const NODE_BYTES: u64 = 24;

/// Node i (in key order) has key 3*i+1, value i*31. Placement shuffled.
struct ListModel {
    head_addr: u64,
    addrs: Vec<u64>, // key-order index -> node addr
}

fn build_list_model(base: u64, p: &LlParams, seed: u64) -> ListModel {
    let mut rng = Xoshiro256::new(seed);
    let perm = rng.permutation(p.nodes as usize);
    let addrs: Vec<u64> = (0..p.nodes).map(|i| base + perm[i as usize] * 64).collect();
    ListModel { head_addr: addrs[0], addrs }
}

pub fn build(cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
    let mut p = LlParams::new(scale);
    p.tasks = default_tasks(cfg, p.tasks);
    let mut layout = mk_layout(cfg);
    let base = layout.alloc_far(p.nodes * 64, 4096);
    let model = build_list_model(base, &p, 77);
    let head = model.head_addr;
    let setup_list = {
        let addrs = model.addrs.clone();
        let nodes = p.nodes;
        move |sim: &mut crate::sim::Simulator| {
            for i in 0..nodes {
                let a = addrs[i as usize];
                sim.guest.write_u64(a, 3 * i + 1); // key
                sim.guest.write_u64(a + 8, i.wrapping_mul(31)); // value
                let next = if i + 1 < nodes { addrs[i as usize + 1] } else { 0 };
                sim.guest.write_u64(a + 16, next);
            }
        }
    };
    match variant {
        Variant::Amu | Variant::AmuLlvm => build_amu(cfg, &mut layout, p, head, setup_list),
        _ => build_sync(p, head, setup_list),
    }
}

fn build_sync(
    p: LlParams,
    head: u64,
    setup_list: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    let mut a = Asm::new("ll-sync");
    a.li(4, 0); // sum
    a.li(20, 0); // tid
    a.li(21, p.tasks as i64);
    a.roi_begin();
    a.label("t_loop");
    a.li(22, 0); // k
    a.li(23, p.lookups_per_task as i64);
    a.label("k_loop");
    a.li(5, 131);
    a.mul(5, 20, 5);
    a.li(6, 7);
    a.mul(6, 22, 6);
    a.add(5, 5, 6);
    a.addi(5, 5, 3);
    emit_hash(&mut a, 6, 5, 7);
    // key = h % 3N (modulo by repeated subtract is too slow; use the same
    // trick as the host: h % m via h - (h/m)*m is unavailable without div,
    // so the host precomputes: key space must be power-of-two-free. Use
    // multiplicative range reduction: key = (h >> 32) * 3N >> 32.
    a.srli(6, 6, 32);
    a.li(7, (3 * p.nodes) as i64);
    a.mul(6, 6, 7);
    a.srli(6, 6, 32); // key in [0, 3N)
    // walk the list
    a.li(8, head as i64);
    a.label("walk");
    a.beq(8, 0, "miss");
    a.ld64(9, 8, 0); // key
    a.beq(9, 6, "hit");
    a.bltu(6, 9, "miss"); // sorted: passed it
    a.ld64(8, 8, 16); // next
    a.j("walk");
    a.label("hit");
    a.ld64(10, 8, 8);
    a.add(4, 4, 10);
    a.label("miss");
    a.addi(22, 22, 1);
    a.blt(22, 23, "k_loop");
    a.addi(20, 20, 1);
    a.blt(20, 21, "t_loop");
    a.roi_end();
    a.li(14, crate::isa::mem::LOCAL_BASE as i64);
    a.st64(4, 14, 0);
    a.halt();
    let prog = a.finish();
    // Host model must use the same range reduction.
    let expected: u64 = (0..p.tasks as u64)
        .map(|t| expected_task_sum_mulred(t, &p))
        .fold(0u64, |x, y| x.wrapping_add(y));
    WorkloadSpec {
        name: "ll".into(),
        prog,
        setup: Box::new(setup_list),
        validate: Box::new(move |sim| {
            let got = sim.guest.read_u64(crate::isa::mem::LOCAL_BASE);
            if got == expected {
                Ok(())
            } else {
                Err(format!("sum {got} != expected {expected}"))
            }
        }),
    }
}

/// Host mirror of the guest's multiplicative range reduction.
fn mulred_key(tid: u64, k: u64, nodes: u64) -> u64 {
    let h = host_hash(tid * 131 + k * 7 + 3);
    ((h >> 32) * (3 * nodes)) >> 32
}

fn expected_task_sum_mulred(tid: u64, p: &LlParams) -> u64 {
    let mut sum = 0u64;
    for k in 0..p.lookups_per_task {
        let key = mulred_key(tid, k, p.nodes);
        if key % 3 == 1 {
            let i = key / 3;
            if i < p.nodes {
                sum = sum.wrapping_add(i.wrapping_mul(31));
            }
        }
    }
    sum
}

fn build_amu(
    cfg: &SimConfig,
    layout: &mut crate::isa::mem::Layout,
    p: LlParams,
    head: u64,
    setup_list: impl Fn(&mut crate::sim::Simulator) + 'static,
) -> WorkloadSpec {
    let nodes = p.nodes;
    let per_task = p.lookups_per_task;
    let (prog, rt) = AmuScaffold::build(
        "ll-amu",
        layout,
        cfg,
        p.tasks,
        NODE_BYTES,
        |a: &mut Asm, rt: &CoroRt| {
            rt.emit_load_param(a, 10, 0); // tid
            rt.emit_load_param(a, 11, 1); // spm slot
            a.li(12, 0); // k
            a.li(13, 0); // sum
            a.label("l_kloop");
            a.li(5, 131);
            a.mul(5, 10, 5);
            a.li(6, 7);
            a.mul(6, 12, 6);
            a.add(5, 5, 6);
            a.addi(5, 5, 3);
            emit_hash(a, 14, 5, 15);
            a.srli(14, 14, 32);
            a.li(15, (3 * nodes) as i64);
            a.mul(14, 14, 15);
            a.srli(14, 14, 32); // key
            a.li(15, head as i64); // cur node far addr
            a.label("l_walk");
            a.beq(15, 0, "l_miss");
            a.aload(16, 11, 15);
            rt.emit_await(a, 16, &[10, 11, 12, 13, 14, 15], "l_r1");
            a.ld64(17, 11, 0); // key
            a.beq(17, 14, "l_hit");
            a.bltu(14, 17, "l_miss");
            a.ld64(15, 11, 16); // next
            a.j("l_walk");
            a.label("l_hit");
            a.ld64(17, 11, 8);
            a.add(13, 13, 17);
            a.label("l_miss");
            a.addi(12, 12, 1);
            a.li(17, per_task as i64);
            a.blt(12, 17, "l_kloop");
            a.st64(13, R_CUR_TCB, OFF_PARAM + 24);
            rt.emit_task_finish(a);
        },
    );
    let rt_setup = rt.clone();
    let rt_check = rt.clone();
    let prog2 = prog.clone();
    let expected: Vec<u64> =
        (0..p.tasks as u64).map(|t| expected_task_sum_mulred(t, &p)).collect();
    WorkloadSpec {
        name: "ll".into(),
        prog,
        setup: Box::new(move |sim| {
            setup_list(sim);
            rt_setup.write_tcbs(&mut sim.guest, &prog2, "task", |tid| {
                [tid as u64, SPM_BASE + tid as u64 * 64, 0, 0]
            });
        }),
        validate: Box::new(move |sim| {
            for (tid, want) in expected.iter().enumerate() {
                let got =
                    sim.guest.read_u64(rt_check.tcb_addr(tid) + OFF_PARAM as u64 + 24);
                if got != *want {
                    return Err(format!("task {tid}: sum {got} != {want}"));
                }
            }
            Ok(())
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_ll_validates() {
        let cfg = SimConfig::baseline().with_far_latency_ns(200.0);
        build(&cfg, Variant::Sync, Scale::Test).run(&cfg).expect("ll sync");
    }

    #[test]
    fn amu_ll_validates_and_overlaps() {
        let mut cfg = SimConfig::amu().with_far_latency_ns(1000.0);
        cfg.far.jitter_frac = 0.0;
        let sim = build(&cfg, Variant::Amu, Scale::Test).run(&cfg).expect("ll amu");
        assert!(sim.stats.far_inflight.max >= 8, "MLP {}", sim.stats.far_inflight.max);
    }
}
