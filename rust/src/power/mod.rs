//! McPAT-lite power model (Fig 11).
//!
//! The paper integrates McPAT for power estimation; Fig 11 reports
//! *relative* static + dynamic energy. We reproduce that with an
//! event-energy model: every microarchitectural event carries a per-access
//! energy calibrated to McPAT-class 22 nm numbers (pJ), and each structure
//! leaks proportionally to its size and the run's cycle count. Absolute
//! watts are not the claim — the static/dynamic split and the cross-config
//! ratios are.

use crate::config::SimConfig;
use crate::stats::Stats;

/// Per-event energies in picojoules (order-of-magnitude McPAT values).
pub struct EnergyModel {
    pub rob_write_pj: f64,
    pub iq_write_pj: f64,
    pub iq_wakeup_pj: f64,
    pub regfile_read_pj: f64,
    pub regfile_write_pj: f64,
    pub lsq_search_pj: f64,
    pub l1_access_pj: f64,
    pub l2_access_pj: f64,
    pub spm_access_pj: f64,
    pub dram_access_pj: f64,
    pub link_byte_pj: f64,
    pub commit_pj: f64,
    pub fetch_pj: f64,
    pub bpred_pj: f64,
    pub amu_op_pj: f64,
    /// Leakage per KB of SRAM per cycle at 3 GHz, and fixed core leakage.
    pub leak_pj_per_kb_cycle: f64,
    pub core_leak_pj_per_cycle: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            rob_write_pj: 2.5,
            iq_write_pj: 2.0,
            iq_wakeup_pj: 1.5,
            regfile_read_pj: 0.8,
            regfile_write_pj: 1.0,
            lsq_search_pj: 2.2,
            l1_access_pj: 10.0,
            l2_access_pj: 28.0,
            spm_access_pj: 22.0, // SPM = L2 array minus tag/coherence logic
            dram_access_pj: 15_000.0 / 64.0, // per byte-ish, folded per access
            link_byte_pj: 4.0,
            commit_pj: 1.2,
            fetch_pj: 1.0,
            bpred_pj: 0.6,
            amu_op_pj: 1.8,
            leak_pj_per_kb_cycle: 0.0016,
            core_leak_pj_per_cycle: 0.35,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct PowerBreakdown {
    pub dynamic_uj: f64,
    pub static_uj: f64,
}

impl PowerBreakdown {
    pub fn total_uj(&self) -> f64 {
        self.dynamic_uj + self.static_uj
    }
}

/// Estimate energy for one finished run.
pub fn estimate(cfg: &SimConfig, stats: &Stats, model: &EnergyModel) -> PowerBreakdown {
    let m = model;
    let dyn_pj = stats.rob_writes as f64 * m.rob_write_pj
        + stats.iq_writes as f64 * m.iq_write_pj
        + stats.iq_wakeups as f64 * m.iq_wakeup_pj
        + stats.regfile_reads as f64 * m.regfile_read_pj
        + stats.regfile_writes as f64 * m.regfile_write_pj
        + stats.lsq_searches as f64 * m.lsq_search_pj
        + stats.l1d_accesses as f64 * m.l1_access_pj
        + stats.l2_accesses as f64 * m.l2_access_pj
        + stats.spm_accesses as f64 * m.spm_access_pj
        + (stats.dram_reads + stats.dram_writes) as f64 * m.dram_access_pj
        + stats.far_bytes as f64 * m.link_byte_pj
        + stats.uops_committed as f64 * m.commit_pj
        + stats.fetched_uops as f64 * m.fetch_pj
        + stats.branches as f64 * m.bpred_pj
        + (stats.aloads + stats.astores + stats.getfins + stats.amu_subrequests) as f64
            * m.amu_op_pj;

    // Leakage: SRAM structures (caches + SPM + queue-ish structures) plus a
    // fixed core component, integrated over the run.
    let sram_kb = (cfg.l1d.size_bytes + cfg.l2.size_bytes) as f64 / 1024.0
        + if cfg.amu.enabled { cfg.amu.spm_bytes as f64 / 1024.0 } else { 0.0 }
        + (cfg.core.rob_entries * 16 + cfg.core.iq_entries * 16
            + (cfg.core.lq_entries + cfg.core.sq_entries) * 24
            + cfg.core.phys_regs * 8) as f64
            / 1024.0;
    let static_pj = stats.cycles as f64
        * (sram_kb * m.leak_pj_per_kb_cycle + m.core_leak_pj_per_cycle);

    PowerBreakdown { dynamic_uj: dyn_pj / 1e6, static_uj: static_pj / 1e6 }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_stats(cycles: u64, activity: u64) -> Stats {
        let mut s = Stats::default();
        s.cycles = cycles;
        s.rob_writes = activity;
        s.iq_writes = activity;
        s.regfile_reads = activity * 2;
        s.l1d_accesses = activity / 2;
        s.uops_committed = activity;
        s.fetched_uops = activity;
        s
    }

    #[test]
    fn longer_runs_leak_more() {
        let cfg = SimConfig::baseline();
        let m = EnergyModel::default();
        let short = estimate(&cfg, &fake_stats(1_000, 100), &m);
        let long = estimate(&cfg, &fake_stats(1_000_000, 100), &m);
        assert!(long.static_uj > short.static_uj * 100.0);
        assert!((long.dynamic_uj - short.dynamic_uj).abs() < 1e-9);
    }

    #[test]
    fn more_activity_costs_more_dynamic() {
        let cfg = SimConfig::baseline();
        let m = EnergyModel::default();
        let idle = estimate(&cfg, &fake_stats(1000, 10), &m);
        let busy = estimate(&cfg, &fake_stats(1000, 10_000), &m);
        assert!(busy.dynamic_uj > idle.dynamic_uj * 10.0);
    }

    #[test]
    fn amu_config_leaks_spm() {
        // Same total SRAM: AMU carves SPM out of L2 (sizes add back up), so
        // leakage should be ~equal, not higher.
        let m = EnergyModel::default();
        let base = estimate(&SimConfig::baseline(), &fake_stats(10_000, 0), &m);
        let amu = estimate(&SimConfig::amu(), &fake_stats(10_000, 0), &m);
        assert!((base.static_uj - amu.static_uj).abs() / base.static_uj < 0.01);
    }

    #[test]
    fn far_traffic_counts() {
        let cfg = SimConfig::baseline();
        let m = EnergyModel::default();
        let mut s = fake_stats(1000, 0);
        s.far_bytes = 1_000_000;
        let p = estimate(&cfg, &s, &m);
        assert!(p.dynamic_uj > 3.9, "link bytes must show up: {}", p.dynamic_uj);
    }
}
