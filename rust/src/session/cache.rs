//! On-disk sweep cache: CSV with a grid-fingerprint + schema-hash header.
//!
//! Format (version 5 — adds the shared-tenancy scenario columns
//! `tenant_slowdown_max` / `qos_throttle_events` / `pool_steal_cycles` to
//! every row; like v4 it is *schema-driven*: rows carry every
//! [`crate::session::metrics`] column, core and per-backend scenario
//! alike, and the header pins the schema hash so a binary with a
//! different metric schema rejects the file with a migration error
//! instead of misparsing it):
//!
//! ```text
//! # amu-sim sweep cache v5 grid=<16-hex fingerprint> schema=<16-hex hash>
//! bench,config,backend,variant,latency_ns,...,near_hits,...,pool_steal_cycles
//! <one row per completed run>
//! ```
//!
//! Version 4 predates the tenancy columns (its 18-field rows cannot carry
//! `tenant_slowdown_max`/`qos_throttle_events`/`pool_steal_cycles`);
//! version 3 predates the scenario columns entirely. Both are rejected
//! whole with an error naming the regeneration command. Version 2
//! predates the far-memory backend axis; version 1 had no fingerprint at
//! all.
//!
//! Rows are keyed by `(bench, config, backend, variant, latency)`, so a
//! partial file (e.g. from an interrupted sweep) resumes instead of
//! re-simulating everything. Grid *refinements* (`far.pool_policy`,
//! `far.near_capacity_lines`, `far.qos_policy`) are deliberately not
//! columns: a refinement is constant across a grid, so it distinguishes
//! whole cache files via the grid fingerprint in the header. Floats are
//! serialized with Rust's shortest-round-trip formatting, so
//! `parse_csv(to_csv_row(r))` reproduces every field bit-exactly. Any
//! malformed line rejects the whole file — a corrupt cache is never
//! partially loaded.

use crate::session::metrics::{self, MetricSet, Selection};
use crate::session::RunResult;

const MAGIC_V5: &str = "# amu-sim sweep cache v5 grid=";
const MAGIC_V4: &str = "# amu-sim sweep cache v4 grid=";
const MAGIC_V3: &str = "# amu-sim sweep cache v3 grid=";

/// The full-schema column header line (every v5 row stores every column).
pub fn csv_columns() -> String {
    metrics::csv_header(&Selection::All)
}

/// Serialize one result row (all schema columns). Floats use `{}` (the
/// shortest representation that round-trips exactly), keeping cached and
/// freshly simulated rows byte-identical.
pub fn to_csv_row(r: &RunResult) -> String {
    metrics::csv_row(r, &Selection::All)
}

fn parse_row(line: &str) -> Result<RunResult, String> {
    Ok(MetricSet::parse_csv_row(line)?.to_run_result())
}

/// The v5 header line for a grid fingerprint (the schema hash is this
/// binary's — by construction a written cache always matches).
pub fn header(fingerprint: u64) -> String {
    format!("{MAGIC_V5}{fingerprint:016x} schema={:016x}", metrics::schema_hash())
}

/// Serialize a complete cache file (fingerprint/schema header + column
/// header + rows in the given order).
pub fn to_csv_string(fingerprint: u64, rows: &[RunResult]) -> String {
    let cols = Selection::All.columns();
    let mut s = header(fingerprint);
    s.push('\n');
    s.push_str(&csv_columns());
    s.push('\n');
    for r in rows {
        s.push_str(&metrics::csv_row_with(&cols, r));
        s.push('\n');
    }
    s
}

/// Parse a cache file: returns the stored grid fingerprint and every row.
/// Strict: an unrecognized header, a stale format version (v1–v4), a
/// schema-hash mismatch, or any corrupt / truncated row rejects the whole
/// file — v3/v4 and schema-drift rejections name the regeneration command.
pub fn parse_csv(text: &str) -> Result<(u64, Vec<RunResult>), String> {
    let mut lines = text.lines();
    let first = lines.next().ok_or("empty cache file")?;
    if first.starts_with(MAGIC_V3) {
        return Err(format!(
            "v3 sweep cache: the schema-driven format adds per-backend \
             scenario columns ({}, ...) that 14-field v3 rows cannot carry; \
             delete this file or rerun `amu-sim sweep` to regenerate it as v5",
            crate::stats::schema::SCENARIO_COLUMNS[0].name
        ));
    }
    if first.starts_with(MAGIC_V4) {
        return Err(
            "v4 sweep cache: the v5 metric schema adds the shared-tenancy \
             columns (tenant_slowdown_max, qos_throttle_events, \
             pool_steal_cycles) that 18-field v4 rows cannot carry; delete \
             this file or rerun `amu-sim sweep` to regenerate it as v5"
                .into(),
        );
    }
    let rest = first
        .strip_prefix(MAGIC_V5)
        .ok_or_else(|| format!("not a v5 sweep cache (header '{first}')"))?;
    let (grid_hex, schema_part) = rest
        .split_once(" schema=")
        .ok_or_else(|| format!("v5 header missing schema hash ('{first}')"))?;
    let fingerprint =
        u64::from_str_radix(grid_hex, 16).map_err(|_| format!("bad fingerprint '{grid_hex}'"))?;
    let schema = u64::from_str_radix(schema_part, 16)
        .map_err(|_| format!("bad schema hash '{schema_part}'"))?;
    if schema != metrics::schema_hash() {
        return Err(format!(
            "sweep cache schema {schema:016x} does not match this binary's \
             metric schema {:016x}; the column set changed — delete the file \
             or rerun `amu-sim sweep` to regenerate it",
            metrics::schema_hash()
        ));
    }
    let cols = lines.next().ok_or("missing column header")?;
    if cols != csv_columns() {
        return Err(format!("unexpected column header '{cols}'"));
    }
    let mut rows = Vec::new();
    for line in lines {
        rows.push(parse_row(line)?);
    }
    Ok((fingerprint, rows))
}

/// The per-run key a row is cached under.
pub fn key_of(r: &RunResult) -> (String, String, String, String, u64) {
    (
        r.bench.clone(),
        r.config.clone(),
        r.backend.clone(),
        r.variant.clone(),
        r.latency_ns.to_bits(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::schema::{ScenarioCol, ScenarioStats};

    fn sample() -> RunResult {
        RunResult {
            bench: "gups".into(),
            config: "amu".into(),
            backend: "serial-link".into(),
            variant: "amu".into(),
            latency_ns: 1000.0,
            measured_cycles: 123_456,
            total_cycles: 200_000,
            insts: 98_765,
            ipc: 0.123_456_789_012_345,
            mlp: 37.25,
            peak_inflight: 142,
            dynamic_uj: 1.0 / 3.0,
            static_uj: 2.5e-7,
            disambig_frac: 0.087_654_321,
            scenario: ScenarioStats::default()
                .with(ScenarioCol::NearHits, 31)
                .with(ScenarioCol::PoolCongestion, 7)
                .with(ScenarioCol::TenantSlowdownMax, 1375),
        }
    }

    #[test]
    fn row_round_trips_bit_exactly() {
        let r = sample();
        let text = to_csv_string(0xDEAD_BEEF, &[r.clone()]);
        let (fp, rows) = parse_csv(&text).unwrap();
        assert_eq!(fp, 0xDEAD_BEEF);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], r);
        assert_eq!(rows[0].ipc.to_bits(), r.ipc.to_bits());
        assert_eq!(rows[0].disambig_frac.to_bits(), r.disambig_frac.to_bits());
        assert_eq!(rows[0].scenario.get(ScenarioCol::NearHits), 31);
        assert_eq!(rows[0].scenario.get(ScenarioCol::TenantSlowdownMax), 1375);
    }

    #[test]
    fn truncated_or_corrupt_files_are_rejected_whole() {
        let text = to_csv_string(7, &[sample(), sample()]);
        // Truncate mid-row: the whole file is rejected, not partially loaded.
        let cut = &text[..text.len() - 20];
        assert!(parse_csv(cut).is_err());
        // Corrupt one number.
        let bad = text.replace("123456", "123xyz");
        assert!(parse_csv(&bad).is_err());
        // v1 files (no fingerprint header) are stale by definition.
        let v1 = format!("{}\n{}\n", csv_columns(), to_csv_row(&sample()));
        assert!(parse_csv(&v1).is_err());
        // v2 files (no backend column, biased link timing) are stale too.
        let v2 = text.replace("sweep cache v5", "sweep cache v2");
        assert!(parse_csv(&v2).is_err());
    }

    #[test]
    fn v3_files_are_rejected_with_the_migration_command() {
        // A faithful v3 file: 14-field rows, no schema hash.
        let v3 = "# amu-sim sweep cache v3 grid=00000000deadbeef\n\
                  bench,config,backend,variant,latency_ns,measured_cycles,total_cycles,\
                  insts,ipc,mlp,peak_inflight,dynamic_uj,static_uj,disambig_frac\n\
                  gups,amu,serial-link,amu,1000,1,2,3,0.5,1.5,4,0.1,0.2,0.3\n";
        let e = parse_csv(v3).unwrap_err();
        assert!(e.contains("v3"), "{e}");
        assert!(e.contains("amu-sim sweep"), "must name the regeneration command: {e}");
        assert!(e.contains("near_hits"), "must say what the schema adds: {e}");
    }

    #[test]
    fn v4_files_are_rejected_with_the_migration_command() {
        // A faithful v4 header: 18-field rows (no tenancy columns), with a
        // schema hash that obviously cannot match this binary's.
        let v4 = "# amu-sim sweep cache v4 grid=00000000deadbeef schema=0123456789abcdef\n\
                  bench,config,backend,variant,latency_ns,measured_cycles,total_cycles,\
                  insts,ipc,mlp,peak_inflight,dynamic_uj,static_uj,disambig_frac,\
                  near_hits,near_evictions,pool_congestion,pool_switches\n\
                  gups,amu,serial-link,amu,1000,1,2,3,0.5,1.5,4,0.1,0.2,0.3,0,0,0,0\n";
        let e = parse_csv(v4).unwrap_err();
        assert!(e.contains("v4"), "{e}");
        assert!(e.contains("amu-sim sweep"), "must name the regeneration command: {e}");
        assert!(e.contains("tenant_slowdown_max"), "must say what v5 adds: {e}");
    }

    #[test]
    fn schema_drift_is_rejected_with_a_named_hash() {
        let text = to_csv_string(7, &[sample()]);
        // Flip one schema-hash digit: a binary with a different column set
        // must refuse the rows rather than misparse them.
        let (head, tail) = text.split_once('\n').unwrap();
        let mut bad_head = head.to_string();
        let last = bad_head.pop().unwrap();
        bad_head.push(if last == '0' { '1' } else { '0' });
        let bad = format!("{bad_head}\n{tail}");
        let e = parse_csv(&bad).unwrap_err();
        assert!(e.contains("schema"), "{e}");
        assert!(e.contains("amu-sim sweep"), "{e}");
    }

    #[test]
    fn header_carries_grid_and_schema_hashes() {
        let h = header(0xABCD);
        assert!(h.starts_with("# amu-sim sweep cache v5 grid=000000000000abcd schema="));
        assert!(h.ends_with(&format!("{:016x}", metrics::schema_hash())));
    }

    #[test]
    fn empty_row_set_is_valid() {
        let (fp, rows) = parse_csv(&to_csv_string(42, &[])).unwrap();
        assert_eq!(fp, 42);
        assert!(rows.is_empty());
    }
}
