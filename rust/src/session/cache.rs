//! On-disk sweep cache: CSV with a grid-fingerprint header.
//!
//! Format (version 3 — version 2 predates the far-memory backend axis and
//! the corrected unbiased/exact-RTT link timing, so its rows are stale by
//! definition; version 1 had no fingerprint and trusted row count alone,
//! which silently reused stale files):
//!
//! ```text
//! # amu-sim sweep cache v3 grid=<16-hex-digit fingerprint>
//! bench,config,backend,variant,latency_ns,...
//! <one row per completed run>
//! ```
//!
//! Rows are keyed by `(bench, config, backend, variant, latency)`, so a
//! partial file (e.g. from an interrupted sweep) resumes instead of
//! re-simulating everything. Grid *refinements* (e.g. `far.pool_policy`)
//! are deliberately not columns: a refinement is constant across a grid,
//! so it distinguishes whole cache files via the grid fingerprint in the
//! header — the v3 row format (and every default-policy cache already on
//! disk) stays valid. Floats are serialized with Rust's
//! shortest-round-trip formatting, so `parse_csv(to_csv_row(r))`
//! reproduces every field bit-exactly. Any malformed line rejects the
//! whole file — a corrupt cache is never partially loaded.

use crate::session::RunResult;

pub const CSV_HEADER: &str = "bench,config,backend,variant,latency_ns,measured_cycles,\
total_cycles,insts,ipc,mlp,peak_inflight,dynamic_uj,static_uj,disambig_frac";

const MAGIC: &str = "# amu-sim sweep cache v3 grid=";

/// Serialize one result row. Floats use `{}` (shortest representation that
/// round-trips exactly), keeping cached and freshly simulated rows
/// byte-identical.
pub fn to_csv_row(r: &RunResult) -> String {
    format!(
        "{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
        r.bench,
        r.config,
        r.backend,
        r.variant,
        r.latency_ns,
        r.measured_cycles,
        r.total_cycles,
        r.insts,
        r.ipc,
        r.mlp,
        r.peak_inflight,
        r.dynamic_uj,
        r.static_uj,
        r.disambig_frac,
    )
}

fn parse_row(line: &str) -> Result<RunResult, String> {
    let f: Vec<&str> = line.split(',').collect();
    if f.len() != 14 {
        return Err(format!("expected 14 fields, got {} in '{line}'", f.len()));
    }
    let num = |i: usize| -> Result<f64, String> {
        f[i].parse().map_err(|_| format!("bad number '{}' in '{line}'", f[i]))
    };
    let int = |i: usize| -> Result<u64, String> {
        f[i].parse().map_err(|_| format!("bad integer '{}' in '{line}'", f[i]))
    };
    Ok(RunResult {
        bench: f[0].into(),
        config: f[1].into(),
        backend: f[2].into(),
        variant: f[3].into(),
        latency_ns: num(4)?,
        measured_cycles: int(5)?,
        total_cycles: int(6)?,
        insts: int(7)?,
        ipc: num(8)?,
        mlp: num(9)?,
        peak_inflight: int(10)?,
        dynamic_uj: num(11)?,
        static_uj: num(12)?,
        disambig_frac: num(13)?,
    })
}

/// The fingerprint header line for a grid fingerprint.
pub fn header(fingerprint: u64) -> String {
    format!("{MAGIC}{fingerprint:016x}")
}

/// Serialize a complete cache file (fingerprint header + column header +
/// rows in the given order).
pub fn to_csv_string(fingerprint: u64, rows: &[RunResult]) -> String {
    let mut s = header(fingerprint);
    s.push('\n');
    s.push_str(CSV_HEADER);
    s.push('\n');
    for r in rows {
        s.push_str(&to_csv_row(r));
        s.push('\n');
    }
    s
}

/// Parse a cache file: returns the stored grid fingerprint and every row.
/// Strict: an unrecognized header, a stale (v1) format, or any corrupt /
/// truncated row rejects the whole file.
pub fn parse_csv(text: &str) -> Result<(u64, Vec<RunResult>), String> {
    let mut lines = text.lines();
    let first = lines.next().ok_or("empty cache file")?;
    let hex = first
        .strip_prefix(MAGIC)
        .ok_or_else(|| format!("not a v2 sweep cache (header '{first}')"))?;
    let fingerprint =
        u64::from_str_radix(hex, 16).map_err(|_| format!("bad fingerprint '{hex}'"))?;
    let cols = lines.next().ok_or("missing column header")?;
    if cols != CSV_HEADER {
        return Err(format!("unexpected column header '{cols}'"));
    }
    let mut rows = Vec::new();
    for line in lines {
        rows.push(parse_row(line)?);
    }
    Ok((fingerprint, rows))
}

/// The per-run key a row is cached under.
pub fn key_of(r: &RunResult) -> (String, String, String, String, u64) {
    (
        r.bench.clone(),
        r.config.clone(),
        r.backend.clone(),
        r.variant.clone(),
        r.latency_ns.to_bits(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunResult {
        RunResult {
            bench: "gups".into(),
            config: "amu".into(),
            backend: "serial-link".into(),
            variant: "amu".into(),
            latency_ns: 1000.0,
            measured_cycles: 123_456,
            total_cycles: 200_000,
            insts: 98_765,
            ipc: 0.123_456_789_012_345,
            mlp: 37.25,
            peak_inflight: 142,
            dynamic_uj: 1.0 / 3.0,
            static_uj: 2.5e-7,
            disambig_frac: 0.087_654_321,
        }
    }

    #[test]
    fn row_round_trips_bit_exactly() {
        let r = sample();
        let text = to_csv_string(0xDEAD_BEEF, &[r.clone()]);
        let (fp, rows) = parse_csv(&text).unwrap();
        assert_eq!(fp, 0xDEAD_BEEF);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0], r);
        assert_eq!(rows[0].ipc.to_bits(), r.ipc.to_bits());
        assert_eq!(rows[0].disambig_frac.to_bits(), r.disambig_frac.to_bits());
    }

    #[test]
    fn truncated_or_corrupt_files_are_rejected_whole() {
        let text = to_csv_string(7, &[sample(), sample()]);
        // Truncate mid-row: the whole file is rejected, not partially loaded.
        let cut = &text[..text.len() - 20];
        assert!(parse_csv(cut).is_err());
        // Corrupt one number.
        let bad = text.replace("123456", "123xyz");
        assert!(parse_csv(&bad).is_err());
        // v1 files (no fingerprint header) are stale by definition.
        let v1 = format!("{CSV_HEADER}\n{}\n", to_csv_row(&sample()));
        assert!(parse_csv(&v1).is_err());
        // v2 files (no backend column, biased link timing) are stale too.
        let v2 = text.replace("sweep cache v3", "sweep cache v2");
        assert!(parse_csv(&v2).is_err());
    }

    #[test]
    fn empty_row_set_is_valid() {
        let (fp, rows) = parse_csv(&to_csv_string(42, &[])).unwrap();
        assert_eq!(fp, 42);
        assert!(rows.is_empty());
    }
}
