//! The metric schema: one ordered, typed column table driving every CSV
//! the crate emits.
//!
//! Historically `RunResult` was a fixed 14-field struct with a hand-rolled
//! CSV: adding a metric meant coordinated edits to `session/mod.rs`,
//! `session/cache.rs`, `report/mod.rs`, and every test fixture — so the
//! scenario stats the backends already produced (`near_hits`,
//! `pool_congestion`, ...) never reached reports. This module replaces
//! that with a schema:
//!
//! * [`CORE_COLUMNS`] — the key + core metric columns (exactly the v3
//!   cache row, in order), each a [`CoreDef`] with a stable name, unit,
//!   type, and typed accessors into [`RunResult`].
//! * Scenario columns — per-backend diagnostics, defined once in
//!   [`crate::stats::schema::SCENARIO_COLUMNS`] and folded in here.
//! * [`MetricSet`] — one run's record: every schema column's [`Value`] in
//!   schema order. [`RunResult`] is the typed view over it
//!   ([`MetricSet::of`] / [`MetricSet::to_run_result`] convert losslessly,
//!   bit-exactly for floats).
//! * [`Selection`] — the `--columns core|backend|all|<comma-list>` report
//!   selector. Key columns are always included so rows stay identifiable;
//!   `core` reproduces the v3 row layout byte-for-byte.
//! * [`schema_hash`] — FNV-1a over [`schema_descriptor`], stored in every
//!   v5 sweep-cache header so schema drift invalidates stale files with a
//!   migration error instead of misparsing them.
//!
//! Adding a *scenario* metric is a table edit in `stats::schema` plus the
//! backend that produces it; adding a *core* metric is a `RunResult` field
//! plus one [`CoreDef`] row here. Everything downstream — cache format,
//! column selection, report CSVs, the schema hash — follows from the
//! table.

use crate::session::RunResult;
use crate::stats::schema::{ScenarioCol, SCENARIO_COLUMNS};
use crate::util::Fnv;

/// A column's value type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ColType {
    Str = 0,
    U64 = 1,
    F64 = 2,
}

/// Which selection group a column belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ColGroup {
    /// Row identity (bench/config/backend/variant/latency): always emitted.
    Key = 0,
    /// The paper's core metrics (the v3 row body).
    Core = 1,
    /// Per-backend scenario diagnostics.
    Scenario = 2,
}

/// One typed cell. Floats serialize with `{}` (Rust's shortest
/// representation that round-trips exactly), keeping cached and freshly
/// simulated rows byte-identical.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    U64(u64),
    F64(f64),
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::U64(v) => write!(f, "{v}"),
            Value::F64(v) => write!(f, "{v}"),
        }
    }
}

impl Value {
    pub fn ty(&self) -> ColType {
        match self {
            Value::Str(_) => ColType::Str,
            Value::U64(_) => ColType::U64,
            Value::F64(_) => ColType::F64,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::U64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            _ => None,
        }
    }
}

/// Descriptor of one key/core column: stable CSV name, unit, type, group,
/// and the typed accessors tying it to [`RunResult`].
pub struct CoreDef {
    pub name: &'static str,
    pub unit: &'static str,
    pub ty: ColType,
    pub group: ColGroup,
    get: fn(&RunResult) -> Value,
    set: fn(&mut RunResult, Value),
}

macro_rules! str_col {
    ($name:literal, $field:ident) => {
        CoreDef {
            name: $name,
            unit: "",
            ty: ColType::Str,
            group: ColGroup::Key,
            get: |r| Value::Str(r.$field.clone()),
            set: |r, v| {
                if let Value::Str(s) = v {
                    r.$field = s;
                }
            },
        }
    };
}

macro_rules! u64_col {
    ($name:literal, $unit:literal, $field:ident) => {
        CoreDef {
            name: $name,
            unit: $unit,
            ty: ColType::U64,
            group: ColGroup::Core,
            get: |r| Value::U64(r.$field),
            set: |r, v| {
                if let Value::U64(x) = v {
                    r.$field = x;
                }
            },
        }
    };
}

macro_rules! f64_col {
    ($name:literal, $unit:literal, $group:expr, $field:ident) => {
        CoreDef {
            name: $name,
            unit: $unit,
            ty: ColType::F64,
            group: $group,
            get: |r| Value::F64(r.$field),
            set: |r, v| {
                if let Value::F64(x) = v {
                    r.$field = x;
                }
            },
        }
    };
}

/// Key + core metric columns — exactly the v3 cache row, in order. The
/// `core` selection emits these and nothing else, so default report rows
/// stay byte-identical to the pre-schema format.
pub const CORE_COLUMNS: &[CoreDef] = &[
    str_col!("bench", bench),
    str_col!("config", config),
    str_col!("backend", backend),
    str_col!("variant", variant),
    f64_col!("latency_ns", "ns", ColGroup::Key, latency_ns),
    u64_col!("measured_cycles", "cycles", measured_cycles),
    u64_col!("total_cycles", "cycles", total_cycles),
    u64_col!("insts", "insts", insts),
    f64_col!("ipc", "insts/cycle", ColGroup::Core, ipc),
    f64_col!("mlp", "reqs", ColGroup::Core, mlp),
    u64_col!("peak_inflight", "reqs", peak_inflight),
    f64_col!("dynamic_uj", "uJ", ColGroup::Core, dynamic_uj),
    f64_col!("static_uj", "uJ", ColGroup::Core, static_uj),
    f64_col!("disambig_frac", "frac", ColGroup::Core, disambig_frac),
];

/// Handle on one schema column (key/core or scenario).
#[derive(Clone, Copy)]
pub enum Column {
    Core(&'static CoreDef),
    Scenario(ScenarioCol),
}

impl Column {
    pub fn name(&self) -> &'static str {
        match self {
            Column::Core(d) => d.name,
            Column::Scenario(c) => c.def().name,
        }
    }

    pub fn unit(&self) -> &'static str {
        match self {
            Column::Core(d) => d.unit,
            Column::Scenario(c) => c.def().unit,
        }
    }

    pub fn ty(&self) -> ColType {
        match self {
            Column::Core(d) => d.ty,
            Column::Scenario(_) => ColType::U64,
        }
    }

    pub fn group(&self) -> ColGroup {
        match self {
            Column::Core(d) => d.group,
            Column::Scenario(_) => ColGroup::Scenario,
        }
    }

    /// This column's value on `r`.
    pub fn value(&self, r: &RunResult) -> Value {
        match self {
            Column::Core(d) => (d.get)(r),
            Column::Scenario(c) => Value::U64(r.scenario.get(*c)),
        }
    }

    fn set(&self, r: &mut RunResult, v: Value) {
        match self {
            Column::Core(d) => (d.set)(r, v),
            Column::Scenario(c) => {
                if let Value::U64(x) = v {
                    r.scenario.set(*c, x);
                }
            }
        }
    }
}

/// Every schema column, in stable order (key + core, then scenario).
pub fn columns() -> impl Iterator<Item = Column> {
    CORE_COLUMNS
        .iter()
        .map(Column::Core)
        .chain(SCENARIO_COLUMNS.iter().map(|d| Column::Scenario(d.col)))
}

/// Total column count.
pub fn num_columns() -> usize {
    CORE_COLUMNS.len() + SCENARIO_COLUMNS.len()
}

/// Look a column up by its stable CSV name.
pub fn find(name: &str) -> Option<Column> {
    columns().find(|c| c.name() == name)
}

/// All column names, schema order (for error messages and docs).
pub fn column_names() -> Vec<&'static str> {
    columns().map(|c| c.name()).collect()
}

/// The canonical human-readable schema descriptor: one `name,unit,ty,group`
/// line per column. [`schema_hash`] is FNV-1a over this text, and
/// `rust/tests/golden/metric_schema.txt` pins it — any schema drift
/// without a deliberate golden-file (version) bump fails the build.
pub fn schema_descriptor() -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for c in columns() {
        writeln!(s, "{},{},{},{}", c.name(), c.unit(), c.ty() as u8, c.group() as u8).unwrap();
    }
    s
}

/// Stable hash of the schema (stored in every v5 sweep-cache header).
pub fn schema_hash() -> u64 {
    let mut h = Fnv::new();
    h.write(schema_descriptor().as_bytes());
    h.finish()
}

/// A `--columns` selection. Key columns are always included so every
/// emitted row stays identifiable.
#[derive(Debug, Clone, PartialEq)]
pub enum Selection {
    /// Key + core metrics — the v3 row layout, byte-identical.
    Core,
    /// Key + per-backend scenario columns.
    Backend,
    /// Every schema column.
    All,
    /// Key + the named entries (schema order, duplicates ignored). An
    /// entry is a column name or one of the group presets — so
    /// `core,near_hits` is the core layout plus one scenario column.
    Custom(Vec<String>),
}

impl Selection {
    /// Parse a `--columns` argument: `core`, `backend`, `all`, or a
    /// comma-separated list of column names and/or those presets.
    /// Unknown names error naming every valid column.
    pub fn parse(s: &str) -> Result<Selection, String> {
        match s {
            "core" => Ok(Selection::Core),
            "backend" => Ok(Selection::Backend),
            "all" => Ok(Selection::All),
            list => {
                let names: Vec<String> = list
                    .split(',')
                    .map(str::trim)
                    .filter(|p| !p.is_empty())
                    .map(String::from)
                    .collect();
                if names.is_empty() {
                    return Err(
                        "--columns: expected core|backend|all or a comma-separated column list"
                            .into(),
                    );
                }
                for n in &names {
                    let is_preset = matches!(n.as_str(), "core" | "backend" | "all");
                    if !is_preset && find(n).is_none() {
                        return Err(format!(
                            "--columns: unknown column '{n}' (valid: core, backend, all, {})",
                            column_names().join(", ")
                        ));
                    }
                }
                Ok(Selection::Custom(names))
            }
        }
    }

    fn selects(&self, c: &Column) -> bool {
        if c.group() == ColGroup::Key {
            return true;
        }
        match self {
            Selection::Core => c.group() == ColGroup::Core,
            Selection::Backend => c.group() == ColGroup::Scenario,
            Selection::All => true,
            Selection::Custom(names) => names.iter().any(|n| match n.as_str() {
                "core" => c.group() == ColGroup::Core,
                "backend" => c.group() == ColGroup::Scenario,
                "all" => true,
                name => name == c.name(),
            }),
        }
    }

    /// The selected columns, in schema order.
    pub fn columns(&self) -> Vec<Column> {
        columns().filter(|c| self.selects(c)).collect()
    }
}

/// CSV column header for a selection.
pub fn csv_header(sel: &Selection) -> String {
    sel.columns().iter().map(|c| c.name()).collect::<Vec<_>>().join(",")
}

/// One result's CSV row over a precomputed column list. When emitting
/// many rows, hoist `sel.columns()` once per file and use this directly.
pub fn csv_row_with(cols: &[Column], r: &RunResult) -> String {
    cols.iter().map(|c| c.value(r).to_string()).collect::<Vec<_>>().join(",")
}

/// One result's CSV row under a selection.
pub fn csv_row(r: &RunResult, sel: &Selection) -> String {
    csv_row_with(&sel.columns(), r)
}

/// One run's schema-ordered record: every column's value. [`RunResult`]
/// is the typed view over this record; the two convert losslessly.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricSet {
    values: Vec<Value>,
}

impl MetricSet {
    /// Snapshot `r` into a schema-ordered record.
    pub fn of(r: &RunResult) -> Self {
        Self { values: columns().map(|c| c.value(r)).collect() }
    }

    /// Value of the named column, if it exists.
    pub fn value(&self, name: &str) -> Option<&Value> {
        columns().position(|c| c.name() == name).map(|i| &self.values[i])
    }

    /// All values in schema order.
    pub fn values(&self) -> &[Value] {
        &self.values
    }

    /// Rebuild the typed view. Lossless: every float's exact bit pattern
    /// and every counter survive `of` -> `to_run_result`.
    pub fn to_run_result(&self) -> RunResult {
        let mut r = RunResult::default();
        for (c, v) in columns().zip(self.values.iter()) {
            c.set(&mut r, v.clone());
        }
        r
    }

    /// Serialize the selected columns. `values` is already in schema
    /// order, so this is the same filter [`Selection::columns`] applies.
    pub fn csv_row(&self, sel: &Selection) -> String {
        columns()
            .zip(self.values.iter())
            .filter(|(c, _)| sel.selects(c))
            .map(|(_, v)| v.to_string())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Parse one full-schema CSV row (every column, schema order). Strict:
    /// field-count or type mismatches reject the row.
    pub fn parse_csv_row(line: &str) -> Result<MetricSet, String> {
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != num_columns() {
            return Err(format!(
                "expected {} fields, got {} in '{line}'",
                num_columns(),
                fields.len()
            ));
        }
        let mut values = Vec::with_capacity(fields.len());
        for (c, f) in columns().zip(fields) {
            values.push(match c.ty() {
                ColType::Str => Value::Str(f.to_string()),
                ColType::U64 => Value::U64(
                    f.parse().map_err(|_| format!("bad integer '{f}' in '{line}'"))?,
                ),
                ColType::F64 => Value::F64(
                    f.parse().map_err(|_| format!("bad number '{f}' in '{line}'"))?,
                ),
            });
        }
        Ok(MetricSet { values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::schema::ScenarioStats;

    fn sample() -> RunResult {
        RunResult {
            bench: "gups".into(),
            config: "amu".into(),
            backend: "hybrid".into(),
            variant: "amu".into(),
            latency_ns: 1000.0,
            measured_cycles: 123_456,
            total_cycles: 200_000,
            insts: 98_765,
            ipc: 0.123_456_789_012_345,
            mlp: 37.25,
            peak_inflight: 142,
            dynamic_uj: 1.0 / 3.0,
            static_uj: 2.5e-7,
            disambig_frac: 0.087_654_321,
            scenario: ScenarioStats::default()
                .with(ScenarioCol::NearHits, 77)
                .with(ScenarioCol::NearEvictions, 3)
                .with(ScenarioCol::PoolCongestion, 9),
        }
    }

    #[test]
    fn schema_matches_the_golden_descriptor() {
        // Schema drift without a deliberate version bump (updating the
        // golden file and, for layout changes, the cache version) must
        // fail the build. CI additionally diffs the emitted CSV header
        // against golden/columns_all_header.txt.
        assert_eq!(
            schema_descriptor(),
            include_str!("../../tests/golden/metric_schema.txt"),
            "metric schema drifted: update rust/tests/golden/metric_schema.txt \
             and columns_all_header.txt deliberately (and bump the cache \
             version if the row layout changed)"
        );
        assert_eq!(
            format!("{}\n", csv_header(&Selection::All)),
            include_str!("../../tests/golden/columns_all_header.txt")
        );
    }

    #[test]
    fn core_selection_is_the_v3_row_layout() {
        assert_eq!(
            csv_header(&Selection::Core),
            "bench,config,backend,variant,latency_ns,measured_cycles,total_cycles,\
             insts,ipc,mlp,peak_inflight,dynamic_uj,static_uj,disambig_frac"
        );
        // Core columns are a prefix of the full schema, so `core` rows are
        // prefixes of `all` rows (shared columns agree byte-for-byte).
        let r = sample();
        let all = csv_row(&r, &Selection::All);
        let core = csv_row(&r, &Selection::Core);
        assert!(all.starts_with(&core), "core must prefix all:\n{core}\n{all}");
        assert!(csv_header(&Selection::All).starts_with(&csv_header(&Selection::Core)));
    }

    #[test]
    fn backend_selection_keeps_keys_and_scenario_columns() {
        let h = csv_header(&Selection::Backend);
        assert_eq!(
            h,
            "bench,config,backend,variant,latency_ns,near_hits,near_evictions,\
             pool_congestion,pool_switches,tenant_slowdown_max,\
             qos_throttle_events,pool_steal_cycles"
        );
        let row = csv_row(&sample(), &Selection::Backend);
        assert_eq!(row, "gups,amu,hybrid,amu,1000,77,3,9,0,0,0,0");
    }

    #[test]
    fn custom_selection_validates_names_and_keeps_schema_order() {
        let sel = Selection::parse("mlp,near_hits").unwrap();
        assert_eq!(
            csv_header(&sel),
            "bench,config,backend,variant,latency_ns,mlp,near_hits"
        );
        let e = Selection::parse("mlp,warp9").unwrap_err();
        assert!(e.contains("warp9") && e.contains("near_hits"), "{e}");
        assert_eq!(Selection::parse("core").unwrap(), Selection::Core);
        assert_eq!(Selection::parse("all").unwrap(), Selection::All);
        assert_eq!(Selection::parse("backend").unwrap(), Selection::Backend);
        // Group presets compose inside a list: core layout + one scenario
        // column.
        let sel = Selection::parse("core,near_hits").unwrap();
        assert_eq!(
            csv_header(&sel),
            format!("{},near_hits", csv_header(&Selection::Core))
        );
    }

    #[test]
    fn metric_set_round_trips_bit_exactly() {
        let r = sample();
        let m = MetricSet::of(&r);
        assert_eq!(m.to_run_result(), r);
        let line = m.csv_row(&Selection::All);
        assert_eq!(line, csv_row(&r, &Selection::All));
        let back = MetricSet::parse_csv_row(&line).unwrap().to_run_result();
        assert_eq!(back, r);
        assert_eq!(back.ipc.to_bits(), r.ipc.to_bits());
        assert_eq!(back.scenario.get(ScenarioCol::NearHits), 77);
        assert_eq!(m.value("mlp"), Some(&Value::F64(37.25)));
        assert_eq!(m.value("near_hits"), Some(&Value::U64(77)));
        assert_eq!(m.value("warp9"), None);
    }

    #[test]
    fn parse_rejects_wrong_arity_and_types() {
        let r = sample();
        let line = MetricSet::of(&r).csv_row(&Selection::All);
        let truncated = line.rsplit_once(',').unwrap().0;
        assert!(MetricSet::parse_csv_row(truncated).is_err());
        let bad = line.replace("123456", "123xyz");
        assert!(MetricSet::parse_csv_row(&bad).is_err());
    }

    #[test]
    fn schema_hash_tracks_the_descriptor() {
        let mut h = Fnv::new();
        h.write(schema_descriptor().as_bytes());
        assert_eq!(schema_hash(), h.finish());
        // Sanity: names are unique across the whole schema.
        let names = column_names();
        for n in &names {
            assert_eq!(names.iter().filter(|m| m == &n).count(), 1, "duplicate column {n}");
        }
    }
}
