//! The parallel sweep executor.
//!
//! A [`Session`] fans a [`SweepGrid`]'s runs out across scoped worker
//! threads (default: all available cores), preserves the grid's canonical
//! row order regardless of completion order, and keeps the on-disk CSV
//! cache keyed per run — so partial sweeps resume instead of re-simulating
//! everything, and a cache written for a different grid is invalidated by
//! its fingerprint.
//!
//! Every run is an independent simulation with its own seeded PRNG, so
//! `--jobs 1` and `--jobs N` produce byte-identical CSV output.

use crate::session::cache;
use crate::session::grid::SweepGrid;
use crate::session::request::{RunRequest, SessionError};
use crate::session::{results_dir, RunResult};
use crate::workloads::Scale;
use std::collections::HashMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Deterministic parallel map over `0..n`: runs `f(i)` on up to `jobs`
/// scoped worker threads (the sweep executor's work-claiming pattern) and
/// returns the results in index order regardless of completion order.
/// Callers that must be byte-identical across worker counts (`sweep`,
/// `mtrun`) get that for free: output order never depends on scheduling.
pub fn parallel_map<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = jobs.min(n).max(1);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let k = next.fetch_add(1, Ordering::Relaxed);
                if k >= n {
                    break;
                }
                *slots[k].lock().unwrap() = Some(f(k));
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner().unwrap().expect("worker finished without storing a result")
        })
        .collect()
}

/// Executes typed run requests, serially or in parallel.
#[derive(Debug, Clone)]
pub struct Session {
    jobs: usize,
    quiet: bool,
    cache: Option<PathBuf>,
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

impl Session {
    /// A session sized to the host's available parallelism, no cache.
    pub fn new() -> Self {
        let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self { jobs, quiet: false, cache: None }
    }

    /// Set the worker count (clamped to >= 1).
    pub fn jobs(mut self, n: usize) -> Self {
        self.jobs = n.max(1);
        self
    }

    pub fn quiet(mut self, q: bool) -> Self {
        self.quiet = q;
        self
    }

    /// Cache sweep rows at `path` (fingerprint-checked, per-run keyed).
    pub fn cache_path(mut self, path: PathBuf) -> Self {
        self.cache = Some(path);
        self
    }

    /// Drop any configured cache (used by generators that run several
    /// different grids back to back and must not clobber one file).
    pub fn without_cache(mut self) -> Self {
        self.cache = None;
        self
    }

    /// Where a grid's sweep is cached by default: the paper grid keeps the
    /// historical `results/sweep_<scale>.csv` name; any other grid gets a
    /// fingerprint-suffixed file so grids never clobber each other.
    /// Compared by fingerprint — the same identity the cache header is
    /// validated against — so semantically equivalent grids (e.g. a pool
    /// policy set on a grid with no pooled backend) share one file instead
    /// of re-simulating identical rows into a duplicate.
    pub fn default_cache_path(grid: &SweepGrid) -> PathBuf {
        let tag = grid.scale.tag();
        let fp = grid.fingerprint();
        if fp == SweepGrid::paper(grid.scale).fingerprint() {
            results_dir().join(format!("sweep_{tag}.csv"))
        } else {
            results_dir().join(format!("sweep_{tag}_{fp:016x}.csv"))
        }
    }

    /// Execute one request (no caching).
    pub fn run(&self, req: &RunRequest) -> Result<RunResult, SessionError> {
        req.run()
    }

    /// The paper sweep with its default cache location.
    pub fn sweep_paper(&self, scale: Scale) -> Result<Vec<RunResult>, SessionError> {
        self.sweep_paper_backend(scale, crate::config::FarBackendKind::SerialLink.tag())
    }

    /// The paper grid under a specific far-memory backend (regenerating
    /// every paper figure per-backend). Non-default backends get their own
    /// fingerprint-suffixed cache file automatically; `serial-link` keeps
    /// the historical `sweep_<scale>.csv` location.
    pub fn sweep_paper_backend(
        &self,
        scale: Scale,
        backend: &str,
    ) -> Result<Vec<RunResult>, SessionError> {
        self.sweep_default_cached(&SweepGrid::paper(scale).backend(backend))
    }

    /// Run `grid` with its default cache location (unless an explicit cache
    /// path is already configured). Refined grids — a non-default backend,
    /// `pool_policy`, or `near_capacity_lines` — land in their own
    /// fingerprint-suffixed file, so they never clobber the default
    /// sweep's rows.
    pub fn sweep_default_cached(&self, grid: &SweepGrid) -> Result<Vec<RunResult>, SessionError> {
        let mut s = self.clone();
        if s.cache.is_none() {
            s.cache = Some(Self::default_cache_path(grid));
        }
        s.sweep(grid)
    }

    /// Run every cell of `grid`, reusing cached rows where the cache's
    /// fingerprint matches, and return results in canonical grid order.
    pub fn sweep(&self, grid: &SweepGrid) -> Result<Vec<RunResult>, SessionError> {
        let requests = grid.requests()?;
        let fingerprint = grid.fingerprint();
        let mut rows: Vec<Option<RunResult>> = vec![None; requests.len()];

        // Load per-run keyed cache rows; fingerprint mismatch invalidates.
        let mut cache_hits = 0usize;
        if let Some(path) = &self.cache {
            if let Ok(text) = std::fs::read_to_string(path) {
                match cache::parse_csv(&text) {
                    Ok((fp, cached)) if fp == fingerprint => {
                        let by_key: HashMap<_, _> =
                            cached.into_iter().map(|r| (cache::key_of(&r), r)).collect();
                        for (i, req) in requests.iter().enumerate() {
                            if let Some(r) = by_key.get(&req.key()) {
                                rows[i] = Some(r.clone());
                                cache_hits += 1;
                            }
                        }
                    }
                    Ok((fp, _)) => {
                        if !self.quiet {
                            eprintln!(
                                "[sweep] cache {} is for a different grid \
                                 ({fp:016x} != {fingerprint:016x}); re-simulating",
                                path.display()
                            );
                        }
                    }
                    Err(e) => {
                        if !self.quiet {
                            eprintln!(
                                "[sweep] ignoring unreadable cache {}: {e}",
                                path.display()
                            );
                        }
                    }
                }
            }
        }

        let pending: Vec<usize> =
            (0..requests.len()).filter(|&i| rows[i].is_none()).collect();
        if pending.is_empty() {
            if !self.quiet {
                if let Some(path) = &self.cache {
                    eprintln!("[sweep] all {} rows cached in {}", rows.len(), path.display());
                }
            }
            return Ok(rows.into_iter().map(|r| r.unwrap()).collect());
        }
        if !self.quiet && cache_hits > 0 {
            eprintln!(
                "[sweep] resuming: {cache_hits} rows cached, {} to simulate",
                pending.len()
            );
        }

        // Incremental journal: header + cache hits up front, then each
        // completed row as it lands, so an interrupted sweep resumes.
        let journal: Option<Mutex<std::fs::File>> = match &self.cache {
            Some(path) => {
                let hits: Vec<RunResult> =
                    rows.iter().filter_map(|r| r.clone()).collect();
                std::fs::write(path, cache::to_csv_string(fingerprint, &hits))
                    .map_err(|e| SessionError::Run(format!("{}: {e}", path.display())))?;
                let f = std::fs::OpenOptions::new()
                    .append(true)
                    .open(path)
                    .map_err(|e| SessionError::Run(format!("{}: {e}", path.display())))?;
                Some(Mutex::new(f))
            }
            None => None,
        };

        let jobs = self.jobs.min(pending.len()).max(1);
        let quiet = self.quiet;
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<RunResult, SessionError>>>> =
            pending.iter().map(|_| Mutex::new(None)).collect();

        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(|| loop {
                    let k = next.fetch_add(1, Ordering::Relaxed);
                    if k >= pending.len() {
                        break;
                    }
                    let req = &requests[pending[k]];
                    if !quiet {
                        eprintln!(
                            "[sweep] {} {} {} {} @{}ns ...",
                            req.bench_name(),
                            req.config_name(),
                            req.backend_tag(),
                            req.variant().tag(),
                            req.latency_ns()
                        );
                    }
                    let res = req.run();
                    if let (Ok(r), Some(j)) = (&res, &journal) {
                        let mut f = j.lock().unwrap();
                        let _ = writeln!(f, "{}", cache::to_csv_row(r));
                    }
                    *slots[k].lock().unwrap() = Some(res);
                });
            }
        });

        for (k, &i) in pending.iter().enumerate() {
            let res = slots[k]
                .lock()
                .unwrap()
                .take()
                .expect("worker finished without storing a result");
            rows[i] = Some(res?);
        }
        let out: Vec<RunResult> = rows.into_iter().map(|r| r.unwrap()).collect();

        // Rewrite the cache in canonical grid order: the final file is
        // byte-identical however many workers ran.
        if let Some(path) = &self.cache {
            std::fs::write(path, cache::to_csv_string(fingerprint, &out))
                .map_err(|e| SessionError::Run(format!("{}: {e}", path.display())))?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_index_order_across_job_counts() {
        let serial = parallel_map(1, 17, |i| i * i);
        let threaded = parallel_map(4, 17, |i| i * i);
        assert_eq!(serial, threaded, "order must not depend on scheduling");
        assert_eq!(serial[16], 256);
        assert!(parallel_map(4, 0, |i: usize| i).is_empty());
    }
}
