//! Loader for external `.asm` AMI programs (`amu-sim run/sweep/check
//! --program <file.asm>`).
//!
//! A loaded program is a first-class [`Workload`]: it parses through
//! `isa::parse`, passes the exact `isa::verify` gate the built-in
//! benchmarks pass (deny-level AMIxxx findings refuse registration), and
//! then registers into a dynamic registry that `session::registry::find`
//! consults alongside the static one — `run`, `sweep`, `mtrun` tenant
//! specs, and `check` all resolve it by name from that point on.
//!
//! The `.arg`/`.mem`/`.check` header directives become the workload's
//! setup and validation closures: `.mem` words are written into guest
//! memory before the run, `.check` assertions are compared after it.
//! Each program also carries an FNV-1a fingerprint of its source bytes;
//! `SweepGrid` folds it into the sweep fingerprint so a cache entry can
//! never survive an edit to the file it was simulated from.

use std::fmt;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

use crate::config::SimConfig;
use crate::isa::parse::{self, ParseError};
use crate::isa::Program;
use crate::session::registry::{self, Workload};
use crate::util::Fnv;
use crate::workloads::{Scale, Variant, VariantKind, WorkloadSpec};

/// Why a `.asm` file could not be loaded.
#[derive(Debug)]
pub enum ProgramError {
    /// The file could not be read.
    Io { path: String, msg: String },
    /// The text failed to parse (typed, with `file:line:col`).
    Parse(ParseError),
    /// The program parsed but has deny-level verifier findings (AMIxxx).
    Verify(String),
    /// The `.program` name collides with a built-in benchmark.
    ShadowsBuiltin(String),
}

impl fmt::Display for ProgramError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProgramError::Io { path, msg } => write!(f, "{path}: {msg}"),
            ProgramError::Parse(e) => write!(f, "{e}"),
            ProgramError::Verify(e) => write!(f, "{e}"),
            ProgramError::ShadowsBuiltin(name) => {
                write!(f, "program name '{name}' shadows a built-in benchmark")
            }
        }
    }
}

impl std::error::Error for ProgramError {}

/// Variants an external program can run under. Programs that issue AMI
/// instructions need the AMU datapath: under an `amu.enabled = false`
/// config the ID-allocation µop would wait forever on a unit that never
/// ticks, so such programs only advertise the AMU variants and a
/// `--config baseline` request fails with the typed `UnsupportedVariant`
/// error instead of hanging.
const AMI_VARIANTS: &[VariantKind] = &[VariantKind::Amu, VariantKind::AmuLlvm];
const SYNC_VARIANTS: &[VariantKind] =
    &[VariantKind::Sync, VariantKind::Amu, VariantKind::AmuLlvm];

/// A verified external program registered as a [`Workload`].
pub struct LoadedProgram {
    name: &'static str,
    path: String,
    prog: Program,
    mem: Vec<(u64, u64)>,
    checks: Vec<(u64, u64)>,
    uses_ami: bool,
    fingerprint: u64,
}

impl LoadedProgram {
    /// FNV-1a fingerprint of the source bytes (folded into sweep
    /// fingerprints via [`SweepGrid::programs`](crate::session::SweepGrid::programs)).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The file the program was loaded from (display only).
    pub fn path(&self) -> &str {
        &self.path
    }
}

impl Workload for LoadedProgram {
    fn name(&self) -> &'static str {
        self.name
    }

    /// External programs are config-agnostic: the instruction stream is
    /// fixed by the file, only the timing model varies, so `variant` and
    /// `scale` are accepted for interface parity and ignored.
    fn build(&self, _cfg: &SimConfig, _variant: Variant, _scale: Scale) -> WorkloadSpec {
        let mem = self.mem.clone();
        let checks = self.checks.clone();
        WorkloadSpec {
            name: self.name.to_string(),
            prog: self.prog.clone(),
            setup: Box::new(move |sim| {
                for &(addr, v) in &mem {
                    sim.guest.write_u64(addr, v);
                }
            }),
            validate: Box::new(move |sim| {
                for &(addr, want) in &checks {
                    let got = sim.guest.read_u64(addr);
                    if got != want {
                        return Err(format!(
                            ".check failed at {addr:#x}: got {got}, want {want}"
                        ));
                    }
                }
                Ok(())
            }),
        }
    }

    fn supported_variants(&self) -> &'static [VariantKind] {
        if self.uses_ami {
            AMI_VARIANTS
        } else {
            SYNC_VARIANTS
        }
    }
}

fn store() -> &'static Mutex<Vec<&'static LoadedProgram>> {
    static LOADED: OnceLock<Mutex<Vec<&'static LoadedProgram>>> = OnceLock::new();
    LOADED.get_or_init(|| Mutex::new(Vec::new()))
}

/// Look a loaded program up by name.
pub fn find(name: &str) -> Option<&'static LoadedProgram> {
    store().lock().unwrap().iter().copied().find(|p| p.name == name)
}

/// Names of all loaded programs, in load order.
pub fn names() -> Vec<&'static str> {
    store().lock().unwrap().iter().map(|p| p.name).collect()
}

fn content_fingerprint(src: &str) -> u64 {
    let mut h = Fnv::new();
    h.write(src.as_bytes());
    h.finish()
}

/// Parse a `.asm` file without registering it — the `check --program`
/// path, which wants the full verifier report (including deny findings
/// that [`load_file`] would refuse). Returns the program name and the
/// assembled program.
pub fn parse_for_check(path: &str) -> Result<(String, Program), ProgramError> {
    let src = read(path)?;
    let parsed = parse::parse_str(&src, path, &stem(path)).map_err(ProgramError::Parse)?;
    Ok((parsed.prog.name.clone(), parsed.prog))
}

/// Load, verify, and register a `.asm` program file. Idempotent: loading
/// a byte-identical file again returns the existing registration; loading
/// a changed file under the same name replaces it (latest wins).
pub fn load_file(path: &str) -> Result<&'static LoadedProgram, ProgramError> {
    let src = read(path)?;
    load_str(&src, path)
}

/// [`load_file`] over in-memory source; `path` is used for error
/// positions and the default program name (its file stem).
pub fn load_str(src: &str, path: &str) -> Result<&'static LoadedProgram, ProgramError> {
    let parsed = parse::parse_str(src, path, &stem(path)).map_err(ProgramError::Parse)?;
    let name = parsed.prog.name.clone();
    if registry::find_builtin(&name).is_some() {
        return Err(ProgramError::ShadowsBuiltin(name));
    }
    let fingerprint = content_fingerprint(src);
    if let Some(existing) = find(&name) {
        if existing.fingerprint == fingerprint {
            return Ok(existing);
        }
    }
    let uses_ami = parsed.prog.insts.iter().any(|i| i.is_ami());
    let lp = LoadedProgram {
        name: Box::leak(name.clone().into_boxed_str()),
        path: path.to_string(),
        prog: parsed.prog,
        mem: parsed.mem,
        checks: parsed.checks,
        uses_ami,
        fingerprint,
    };
    // Same deny gate as the builtins: build the spec and run it through
    // the memoized verifier before the name becomes resolvable.
    let spec = lp.build(&SimConfig::baseline(), Variant::Sync, Scale::Test);
    spec.verify_ok().map_err(ProgramError::Verify)?;
    let lp: &'static LoadedProgram = Box::leak(Box::new(lp));
    let mut v = store().lock().unwrap();
    match v.iter_mut().find(|p| p.name == lp.name) {
        Some(slot) => *slot = lp,
        None => v.push(lp),
    }
    Ok(lp)
}

fn read(path: &str) -> Result<String, ProgramError> {
    std::fs::read_to_string(path)
        .map_err(|e| ProgramError::Io { path: path.to_string(), msg: e.to_string() })
}

fn stem(path: &str) -> String {
    Path::new(path)
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "program".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
.program tprog_good
.mem FAR_BASE 7
.check LOCAL_BASE 7
  li r1, FAR_BASE
  ld.8 r2, 0(r1)
  li r3, LOCAL_BASE
  st.8 r2, 0(r3)
  halt
";

    #[test]
    fn load_verify_and_find() {
        let lp = load_str(GOOD, "tprog_good.asm").expect("loads clean");
        assert_eq!(lp.name(), "tprog_good");
        assert!(!lp.uses_ami);
        assert_eq!(lp.supported_variants(), SYNC_VARIANTS);
        assert!(find("tprog_good").is_some());
        // Idempotent: same bytes return the same registration.
        let again = load_str(GOOD, "tprog_good.asm").unwrap();
        assert_eq!(again.fingerprint(), lp.fingerprint());
        // Resolvable through the merged registry lookup.
        assert!(registry::find("tprog_good").is_some());
    }

    #[test]
    fn changed_bytes_replace_and_refingerprint() {
        let v1 = "\n.program tprog_edit\n  nop\n  halt\n";
        let v2 = "\n.program tprog_edit\n  nop\n  nop\n  halt\n";
        let a = load_str(v1, "tprog_edit.asm").unwrap().fingerprint();
        let b = load_str(v2, "tprog_edit.asm").unwrap().fingerprint();
        assert_ne!(a, b, "content fingerprint must fork on a byte change");
        assert_eq!(find("tprog_edit").unwrap().fingerprint(), b, "latest wins");
    }

    #[test]
    fn ami_programs_advertise_amu_variants_only() {
        let src = "\
.program tprog_ami
  li r1, 8
  cfgwr r1, granularity
  li r2, SPM_BASE
  li r3, FAR_BASE
  aload r4, r2, r3
w: getfin r5
  beq r5, zero, w
  halt
";
        let lp = load_str(src, "tprog_ami.asm").expect("verifies clean");
        assert!(lp.uses_ami);
        assert_eq!(lp.supported_variants(), AMI_VARIANTS);
    }

    #[test]
    fn deny_findings_refuse_registration() {
        // aload without any reachable getfin: AMI010-family deny finding.
        let src = "\
.program tprog_bad
  li r1, 8
  cfgwr r1, granularity
  li r2, SPM_BASE
  li r3, FAR_BASE
  aload r4, r2, r3
  halt
";
        let e = load_str(src, "tprog_bad.asm").unwrap_err();
        assert!(matches!(e, ProgramError::Verify(_)), "{e}");
        assert!(e.to_string().contains("AMI"), "{e}");
        assert!(find("tprog_bad").is_none(), "rejected programs must not register");
    }

    #[test]
    fn builtin_names_cannot_be_shadowed() {
        let e = load_str(".program gups\n  nop\n  halt\n", "gups.asm").unwrap_err();
        assert!(matches!(e, ProgramError::ShadowsBuiltin(_)), "{e}");
    }

    #[test]
    fn parse_errors_carry_position() {
        let e = load_str("  bogus r1\n", "x.asm").unwrap_err();
        match e {
            ProgramError::Parse(p) => {
                assert_eq!((p.line, p.col), (1, 3));
            }
            other => panic!("expected parse error, got {other}"),
        }
    }
}
