//! The `Workload` trait and the benchmark registry.
//!
//! Replaces the stringly `match name { ... _ => panic!() }` dispatch that
//! used to live in `workloads::build`: every benchmark is a typed entry
//! implementing [`Workload`], lookup returns `Option`, and unknown names
//! surface as errors naming the valid choices (see
//! [`SessionError::UnknownBench`](crate::session::SessionError)).

use crate::config::SimConfig;
use crate::workloads::{self, Scale, Variant, VariantKind, WorkloadSpec, ALL_VARIANT_KINDS};

/// A registered benchmark: a typed handle that can build a runnable
/// [`WorkloadSpec`] for any supported variant at any scale.
pub trait Workload: Sync {
    /// The canonical benchmark name (the paper's Table 3 spelling).
    fn name(&self) -> &'static str;

    /// Instantiate the benchmark program + memory setup + validator.
    fn build(&self, cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec;

    /// Variant kinds this benchmark implements. Kinds outside this list
    /// are rejected at `RunRequest` construction instead of silently
    /// degrading at build time (the raw `build` entry points used to map
    /// unimplemented prefetch variants to the sync port, producing rows
    /// mislabeled with the requested variant tag).
    fn supported_variants(&self) -> &'static [VariantKind] {
        ALL_VARIANT_KINDS
    }
}

/// Workloads without a dedicated software-prefetch port: only the
/// synchronous and AMU implementations exist.
const NO_PREFETCH_PORT: &[VariantKind] =
    &[VariantKind::Sync, VariantKind::Amu, VariantKind::AmuLlvm];

macro_rules! workload_entry {
    ($ty:ident, $name:literal, $module:ident, $supported:expr) => {
        pub struct $ty;
        impl Workload for $ty {
            fn name(&self) -> &'static str {
                $name
            }
            fn build(&self, cfg: &SimConfig, variant: Variant, scale: Scale) -> WorkloadSpec {
                workloads::$module::build(cfg, variant, scale)
            }
            fn supported_variants(&self) -> &'static [VariantKind] {
                $supported
            }
        }
    };
}

workload_entry!(Bfs, "bfs", bfs, NO_PREFETCH_PORT);
workload_entry!(Bs, "bs", bs, NO_PREFETCH_PORT);
workload_entry!(Gups, "gups", gups, ALL_VARIANT_KINDS);
workload_entry!(Hj, "hj", hj, NO_PREFETCH_PORT);
workload_entry!(Ht, "ht", ht, NO_PREFETCH_PORT);
workload_entry!(Hpcg, "hpcg", hpcg, NO_PREFETCH_PORT);
workload_entry!(Is, "is", is, NO_PREFETCH_PORT);
workload_entry!(Ll, "ll", ll, NO_PREFETCH_PORT);
workload_entry!(Redis, "redis", redis, NO_PREFETCH_PORT);
workload_entry!(Sl, "sl", sl, NO_PREFETCH_PORT);
workload_entry!(Stream, "stream", stream, ALL_VARIANT_KINDS);

/// Every registered benchmark, in the paper's Table 3 order (matches
/// [`workloads::ALL`]).
pub static REGISTRY: &[&dyn Workload] =
    &[&Bfs, &Bs, &Gups, &Hj, &Ht, &Hpcg, &Is, &Ll, &Redis, &Sl, &Stream];

/// Look a *built-in* benchmark up by name (static registry only).
pub fn find_builtin(name: &str) -> Option<&'static dyn Workload> {
    REGISTRY.iter().copied().find(|w| w.name() == name)
}

/// Look a benchmark up by name: built-ins first, then externally loaded
/// `.asm` programs (see [`crate::session::programs`]). Built-ins always
/// win — the loader refuses registrations that would shadow one.
pub fn find(name: &str) -> Option<&'static dyn Workload> {
    find_builtin(name)
        .or_else(|| crate::session::programs::find(name).map(|p| p as &'static dyn Workload))
}

/// Look a benchmark up by name, or produce the canonical
/// [`UnknownBench`](crate::session::SessionError::UnknownBench) error —
/// one `Display` impl names the valid choices for every caller (CLI
/// `check`, run requests, tenant specs) instead of each formatting its
/// own list.
pub fn find_or_err(name: &str) -> Result<&'static dyn Workload, crate::session::SessionError> {
    find(name).ok_or_else(|| crate::session::SessionError::UnknownBench(name.to_string()))
}

/// All *built-in* benchmark names, in registry order (matches
/// [`workloads::ALL`]; externally loaded programs are not included —
/// see [`known_names`] for the merged list).
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|w| w.name()).collect()
}

/// Every currently resolvable benchmark name — built-ins plus loaded
/// `.asm` programs — sorted and deduplicated, for suggestion lists.
pub fn known_names() -> Vec<&'static str> {
    let mut v = names();
    v.extend(crate::session::programs::names());
    v.sort_unstable();
    v.dedup();
    v
}

/// Levenshtein edit distance, for near-miss suggestions. Both inputs are
/// benchmark-name-sized, so the O(|a|·|b|) DP is fine.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// A known name one edit away from `name` (first in sorted order on
/// ties) — the "did you mean `gups`?" hint for typos.
pub fn nearest(name: &str) -> Option<&'static str> {
    known_names().into_iter().find(|c| edit_distance(name, c) == 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_workloads_all() {
        assert_eq!(names(), workloads::ALL.to_vec());
    }

    #[test]
    fn find_known_and_unknown() {
        assert_eq!(find("gups").map(|w| w.name()), Some("gups"));
        assert!(find("nope").is_none());
    }

    #[test]
    fn known_names_are_sorted_and_deduped() {
        let names = known_names();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(names, sorted);
        assert!(names.contains(&"gups") && names.contains(&"stream"));
    }

    #[test]
    fn nearest_suggests_one_edit_typos() {
        assert_eq!(nearest("gupz"), Some("gups"));
        assert_eq!(nearest("sream"), Some("stream"));
        // Distance 2+ or exact matches produce no hint.
        assert_eq!(nearest("gups"), None, "exact match is distance 0");
        assert_eq!(nearest("zzzzzz"), None);
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(edit_distance("gups", "gups"), 0);
        assert_eq!(edit_distance("gups", "cups"), 1);
        assert_eq!(edit_distance("gups", "gup"), 1);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
    }

    #[test]
    fn find_or_err_unknown_names_the_choices() {
        assert!(find_or_err("gups").is_ok());
        let e = match find_or_err("nope") {
            Ok(_) => panic!("expected UnknownBench"),
            Err(e) => e.to_string(),
        };
        assert!(e.contains("unknown benchmark 'nope'"), "{e}");
        assert!(e.contains("gups") && e.contains("stream"), "{e}");
    }

    #[test]
    fn every_entry_builds_sync_and_amu() {
        let base = SimConfig::baseline();
        let amu = SimConfig::amu();
        for w in REGISTRY {
            let s = w.build(&base, Variant::Sync, Scale::Test);
            assert!(!s.prog.is_empty(), "{} sync empty", w.name());
            let a = w.build(&amu, Variant::Amu, Scale::Test);
            assert!(!a.prog.is_empty(), "{} amu empty", w.name());
        }
    }

    #[test]
    fn supported_variants_cover_the_paper_matrix() {
        for w in REGISTRY {
            for k in [VariantKind::Sync, VariantKind::Amu, VariantKind::AmuLlvm] {
                assert!(w.supported_variants().contains(&k), "{} lacks {k:?}", w.name());
            }
        }
        // Only GUPS and STREAM implement the software-prefetch variants
        // (the others' raw build entry points degrade them to sync).
        for name in ["gups", "stream"] {
            let w = find(name).unwrap();
            assert!(w.supported_variants().contains(&VariantKind::GroupPrefetch), "{name}");
            assert!(w.supported_variants().contains(&VariantKind::SwPrefetch), "{name}");
        }
        let hj = find("hj").unwrap();
        assert!(!hj.supported_variants().contains(&VariantKind::GroupPrefetch));
    }
}
