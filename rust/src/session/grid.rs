//! Sweep grids: the cross product benches × configs × latencies × variants
//! × far-memory backends.
//!
//! A [`SweepGrid`] describes *any* scenario grid — the paper's fixed
//! 11 × 4 × 6 matrix is just [`SweepGrid::paper`] (which keeps the default
//! `serial-link` backend). Grids validate into a deterministic, canonically
//! ordered list of [`RunRequest`]s and carry a stable fingerprint that keys
//! the on-disk sweep cache, so a cache written for one grid can never be
//! silently reused for another.

use crate::config::{FarBackendKind, PoolPolicy, QosPolicyKind, SimConfig};
use crate::session::request::{RunRequest, SessionError};
use crate::util::Fnv;
use crate::workloads::{self, Scale, Variant};

/// The paper's four evaluated configurations (Fig 8–11 columns).
pub const PAPER_CONFIGS: &[&str] = &["baseline", "cxl-ideal", "amu", "amu-dma"];

/// One grid axis entry for the variant dimension: either "the natural
/// variant for each config" (AMU configs run coroutines, others sync — the
/// paper's sweep behavior) or a fixed variant.
#[derive(Debug, Clone, PartialEq)]
pub enum VariantSel {
    Auto,
    Fixed(Variant),
}

impl VariantSel {
    pub fn tag(&self) -> String {
        match self {
            VariantSel::Auto => "auto".into(),
            VariantSel::Fixed(v) => v.tag(),
        }
    }

    /// Parse `auto` or any [`Variant`] spelling; errors name the choices.
    pub fn parse(s: &str) -> Result<Self, SessionError> {
        if s == "auto" {
            return Ok(VariantSel::Auto);
        }
        s.parse::<Variant>().map(VariantSel::Fixed).map_err(SessionError::UnknownVariant)
    }

    pub fn resolve(&self, cfg: &SimConfig) -> Variant {
        match self {
            VariantSel::Auto => workloads::variant_for(cfg),
            VariantSel::Fixed(v) => *v,
        }
    }
}

/// A sweep: every combination of the five axes, in canonical row order
/// (bench-major, then config, then latency, then variant, then backend).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepGrid {
    pub benches: Vec<String>,
    pub configs: Vec<String>,
    pub latencies_ns: Vec<f64>,
    pub variants: Vec<VariantSel>,
    /// Far-memory backend tags (default: `serial-link` only).
    pub backends: Vec<String>,
    /// `pooled` channel-selection policy applied to every cell — a grid
    /// *refinement*, not an axis: it does not multiply the row count and
    /// only enters the fingerprint when non-default *and* the grid sweeps
    /// the `pooled` backend (the only backend it can affect), so caches
    /// written before the policy existed (all implicitly `hash`) stay
    /// valid and pool-less grids never fork on an ineffective flag.
    pub pool_policy: String,
    /// `hybrid` near-tier capacity in 64 B lines applied to every cell —
    /// like `pool_policy`, a grid *refinement*: it only enters the
    /// fingerprint when non-default (non-zero) *and* the grid sweeps the
    /// `hybrid` backend (the only backend it can affect), so existing
    /// fingerprints never fork on the default.
    pub near_capacity_lines: usize,
    /// Shared-backend QoS policy applied to every cell — the third grid
    /// *refinement*: it wraps the far backend in the [`SharedFar`]
    /// arbiter (see [`crate::mem::backend`]), so it only enters the
    /// fingerprint when non-default (`none`) *and* the grid sweeps a
    /// shared-capable backend (`pooled` or `hybrid`); fingerprints minted
    /// before the policy existed (all implicitly `none`) stay valid.
    ///
    /// [`SharedFar`]: crate::mem::backend::SharedFar
    pub qos_policy: String,
    /// Event-driven fast-forward for every cell (default on). A pure
    /// host-speed knob: folded statistics are byte-identical to ticked
    /// ones, so this NEVER enters the fingerprint — rows computed either
    /// way share one cache entry, and the determinism suite holds the
    /// CSVs byte-identical across the toggle.
    pub fast_forward: bool,
    /// Externally loaded `.asm` programs swept by this grid:
    /// `(program name, source-content FNV fingerprint)`. Empty for
    /// builtin-only grids (which keeps every pre-existing fingerprint
    /// valid); when non-empty the content fingerprints are folded into
    /// [`fingerprint`](Self::fingerprint) so a cached row can never
    /// survive an edit to the `.asm` file it was simulated from.
    pub programs: Vec<(String, u64)>,
    pub scale: Scale,
}

impl SweepGrid {
    /// An empty grid at `scale`; fill the axes with the builder methods.
    pub fn new(scale: Scale) -> Self {
        Self {
            benches: Vec::new(),
            configs: Vec::new(),
            latencies_ns: Vec::new(),
            variants: vec![VariantSel::Auto],
            backends: vec![FarBackendKind::SerialLink.tag().to_string()],
            pool_policy: PoolPolicy::default().tag().to_string(),
            near_capacity_lines: 0,
            qos_policy: QosPolicyKind::default().tag().to_string(),
            fast_forward: true,
            programs: Vec::new(),
            scale,
        }
    }

    /// The paper's Fig 8/9/10/11 sweep: all 11 benchmarks × 4 configs ×
    /// 6 far-memory latencies, natural variant per config.
    pub fn paper(scale: Scale) -> Self {
        Self::new(scale)
            .benches(workloads::ALL.iter().copied())
            .configs(PAPER_CONFIGS.iter().copied())
            .latencies_ns(SimConfig::paper_latencies_ns().iter().copied())
    }

    pub fn benches<I, S>(mut self, benches: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.benches = benches.into_iter().map(Into::into).collect();
        self
    }

    pub fn configs<I, S>(mut self, configs: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.configs = configs.into_iter().map(Into::into).collect();
        self
    }

    pub fn latencies_ns<I: IntoIterator<Item = f64>>(mut self, ns: I) -> Self {
        self.latencies_ns = ns.into_iter().collect();
        self
    }

    /// Replace the variant axis (default: a single `Auto` entry).
    pub fn variants<I: IntoIterator<Item = VariantSel>>(mut self, vs: I) -> Self {
        self.variants = vs.into_iter().collect();
        self
    }

    /// Fix every cell to one variant.
    pub fn variant(self, v: Variant) -> Self {
        self.variants(vec![VariantSel::Fixed(v)])
    }

    /// Replace the far-memory backend axis (default: `serial-link` only).
    /// Known alias spellings (`serial`, `pool`, `dist`, ...) are
    /// canonicalized here so the fingerprint and the cache location never
    /// fork on spelling; unknown tags are kept verbatim for `requests()`
    /// to reject with a named error.
    pub fn backends<I, S>(mut self, backends: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.backends = backends
            .into_iter()
            .map(Into::into)
            .map(|b| match FarBackendKind::parse(&b) {
                Some(k) => k.tag().to_string(),
                None => b,
            })
            .collect();
        self
    }

    /// Fix every cell to one backend.
    pub fn backend(self, tag: impl Into<String>) -> Self {
        self.backends(vec![tag.into()])
    }

    /// Set the `pooled` channel-selection policy for every cell. Known
    /// alias spellings (`ll`, `rr`, underscores) canonicalize here so the
    /// fingerprint never forks on spelling; unknown tags are kept verbatim
    /// for `requests()` to reject with a named error.
    pub fn pool_policy(mut self, policy: impl Into<String>) -> Self {
        let p = policy.into();
        self.pool_policy = match PoolPolicy::parse(&p) {
            Some(k) => k.tag().to_string(),
            None => p,
        };
        self
    }

    /// Set the `hybrid` near-tier capacity (64 B lines) for every cell.
    /// `0` (the default) keeps the legacy `near_frac` coin-flip model.
    pub fn near_capacity(mut self, lines: usize) -> Self {
        self.near_capacity_lines = lines;
        self
    }

    /// Record the external `.asm` programs this grid sweeps as
    /// `(name, content fingerprint)` pairs (see
    /// [`LoadedProgram::fingerprint`](crate::session::programs::LoadedProgram::fingerprint)).
    /// Program *content* then participates in the grid fingerprint.
    pub fn programs<I>(mut self, programs: I) -> Self
    where
        I: IntoIterator<Item = (String, u64)>,
    {
        self.programs = programs.into_iter().collect();
        self
    }

    /// Set the shared-backend QoS policy for every cell. Known alias
    /// spellings (`fair`, `prio`, `rate-limit`, underscores) canonicalize
    /// here so the fingerprint never forks on spelling; unknown tags are
    /// kept verbatim for `requests()` to reject with a named error.
    pub fn qos_policy(mut self, policy: impl Into<String>) -> Self {
        let p = policy.into();
        self.qos_policy = match QosPolicyKind::parse(&p) {
            Some(k) => k.tag().to_string(),
            None => p,
        };
        self
    }

    /// Toggle event-driven fast-forward for every cell (host-speed only;
    /// never part of the grid fingerprint — see the field docs).
    pub fn fast_forward(mut self, on: bool) -> Self {
        self.fast_forward = on;
        self
    }

    pub fn len(&self) -> usize {
        self.benches.len()
            * self.configs.len()
            * self.latencies_ns.len()
            * self.variants.len()
            * self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate every cell and return the canonical, deterministic request
    /// list. Fails fast on unknown benches/configs, bad latencies, or
    /// unsupported variants — before any simulation starts.
    pub fn requests(&self) -> Result<Vec<RunRequest>, SessionError> {
        if self.benches.is_empty() {
            return Err(SessionError::EmptyGrid("benches"));
        }
        if self.configs.is_empty() {
            return Err(SessionError::EmptyGrid("configs"));
        }
        if self.latencies_ns.is_empty() {
            return Err(SessionError::EmptyGrid("latencies"));
        }
        if self.variants.is_empty() {
            return Err(SessionError::EmptyGrid("variants"));
        }
        if self.backends.is_empty() {
            return Err(SessionError::EmptyGrid("backends"));
        }
        // Fail fast on unknown backend tags, before any simulation starts.
        for b in &self.backends {
            if FarBackendKind::parse(b).is_none() {
                return Err(SessionError::UnknownBackend(b.clone()));
            }
        }
        let pool_policy = PoolPolicy::parse(&self.pool_policy)
            .ok_or_else(|| SessionError::UnknownPoolPolicy(self.pool_policy.clone()))?;
        let qos_policy = QosPolicyKind::parse(&self.qos_policy)
            .ok_or_else(|| SessionError::UnknownQosPolicy(self.qos_policy.clone()))?;
        let mut out = Vec::with_capacity(self.len());
        for bench in &self.benches {
            for config in &self.configs {
                let mut cfg = SimConfig::preset(config)
                    .ok_or_else(|| SessionError::UnknownConfig(config.clone()))?;
                cfg.far.pool_policy = pool_policy;
                cfg.far.near_capacity_lines = self.near_capacity_lines;
                cfg.far.qos_policy = qos_policy;
                cfg.fast_forward = self.fast_forward;
                for &lat in &self.latencies_ns {
                    for sel in &self.variants {
                        for backend in &self.backends {
                            out.push(
                                RunRequest::bench(bench.clone())
                                    .config(cfg.clone())
                                    .latency_ns(lat)
                                    .variant(sel.resolve(&cfg))
                                    .backend(backend.clone())
                                    .scale(self.scale)
                                    .build()?,
                            );
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// A stable FNV-1a fingerprint over every axis (including scale, the
    /// exact latency bit patterns, and the backend axis). Stored in the
    /// cache header; any grid change invalidates cached rows.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.write(self.scale.tag().as_bytes());
        for b in &self.benches {
            h.write(b.as_bytes());
            h.write(&[0xFF]);
        }
        h.write(&[0xFE]);
        for c in &self.configs {
            h.write(c.as_bytes());
            h.write(&[0xFF]);
        }
        h.write(&[0xFE]);
        for &l in &self.latencies_ns {
            h.write(&l.to_bits().to_le_bytes());
        }
        h.write(&[0xFE]);
        for v in &self.variants {
            h.write(v.tag().as_bytes());
            h.write(&[0xFF]);
        }
        h.write(&[0xFE]);
        for b in &self.backends {
            h.write(b.as_bytes());
            h.write(&[0xFF]);
        }
        // Grid refinements enter the fingerprint only when they can change
        // a row: non-default pool policy AND a pooled backend in the grid.
        // Every fingerprint minted before the refinement existed stays
        // valid (v3 caches are all implicitly `hash`), and a policy flag on
        // a pool-less grid doesn't force a duplicate re-simulation of
        // byte-identical rows into a new cache file.
        if self.pool_policy != PoolPolicy::default().tag() && self.sweeps_pooled() {
            h.write(&[0xFD]);
            h.write(b"pool_policy=");
            h.write(self.pool_policy.as_bytes());
        }
        // Same non-default-only trick for the hybrid near-tier capacity:
        // the default (0, the legacy coin-flip) never enters the hash, so
        // every fingerprint minted before this refinement existed stays
        // valid, and the flag is a no-op on hybrid-less grids.
        if self.near_capacity_lines != 0 && self.sweeps_hybrid() {
            h.write(&[0xFC]);
            h.write(b"near_capacity=");
            h.write(&(self.near_capacity_lines as u64).to_le_bytes());
        }
        // And for the QoS policy: `none` (the unwrapped backend) never
        // enters the hash, and the flag is a no-op on grids that sweep
        // neither shared-capable backend (`pooled` / `hybrid`).
        if self.qos_policy != QosPolicyKind::default().tag()
            && (self.sweeps_pooled() || self.sweeps_hybrid())
        {
            h.write(&[0xFB]);
            h.write(b"qos_policy=");
            h.write(self.qos_policy.as_bytes());
        }
        // External `.asm` program content: empty for builtin-only grids
        // (every fingerprint minted before the loader existed stays
        // valid); sorted by name so registration order can't fork the
        // hash; the content fingerprint means editing the file's bytes
        // invalidates its cached rows.
        if !self.programs.is_empty() {
            let mut programs = self.programs.clone();
            programs.sort();
            h.write(&[0xFA]);
            h.write(b"programs=");
            for (name, fp) in &programs {
                h.write(name.as_bytes());
                h.write(&[0xFF]);
                h.write(&fp.to_le_bytes());
            }
        }
        h.finish()
    }

    /// Whether any cell of this grid runs the `pooled` backend (the only
    /// backend the pool policy can affect).
    pub fn sweeps_pooled(&self) -> bool {
        self.backends
            .iter()
            .any(|b| FarBackendKind::parse(b) == Some(FarBackendKind::Pooled))
    }

    /// Whether any cell of this grid runs the `hybrid` backend (the only
    /// backend the near-tier capacity can affect).
    pub fn sweeps_hybrid(&self) -> bool {
        self.backends
            .iter()
            .any(|b| FarBackendKind::parse(b) == Some(FarBackendKind::Hybrid))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_has_the_matrix_shape() {
        let g = SweepGrid::paper(Scale::Test);
        assert_eq!(g.len(), 11 * 4 * 6);
        let reqs = g.requests().unwrap();
        assert_eq!(reqs.len(), g.len());
        // Canonical order: bench-major, config, latency.
        assert_eq!(reqs[0].bench_name(), "bfs");
        assert_eq!(reqs[0].config_name(), "baseline");
        assert_eq!(reqs[0].latency_ns(), 100.0);
        assert_eq!(reqs[1].latency_ns(), 200.0);
        assert_eq!(reqs[6].config_name(), "cxl-ideal");
        // Auto variant resolves per config.
        let amu_row = reqs.iter().find(|r| r.config_name() == "amu").unwrap();
        assert_eq!(amu_row.variant(), Variant::Amu);
    }

    #[test]
    fn empty_axes_are_rejected() {
        let g = SweepGrid::new(Scale::Test).configs(["baseline"]).latencies_ns([100.0]);
        assert!(matches!(g.requests(), Err(SessionError::EmptyGrid("benches"))));
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .variants([]);
        assert!(matches!(g.requests(), Err(SessionError::EmptyGrid("variants"))));
    }

    #[test]
    fn unknown_axis_entries_fail_fast() {
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups", "nope"])
            .configs(["baseline"])
            .latencies_ns([100.0]);
        assert!(matches!(g.requests(), Err(SessionError::UnknownBench(_))));
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["warp9"])
            .latencies_ns([100.0]);
        assert!(matches!(g.requests(), Err(SessionError::UnknownConfig(_))));
    }

    #[test]
    fn fingerprint_tracks_every_axis() {
        let g = SweepGrid::paper(Scale::Test);
        let fp = g.fingerprint();
        assert_eq!(fp, SweepGrid::paper(Scale::Test).fingerprint(), "stable");
        assert_ne!(fp, SweepGrid::paper(Scale::Paper).fingerprint(), "scale");
        let fewer = SweepGrid::paper(Scale::Test).latencies_ns([100.0]);
        assert_ne!(fp, fewer.fingerprint(), "latencies");
        let fixed = SweepGrid::paper(Scale::Test).variant(Variant::Sync);
        assert_ne!(fp, fixed.fingerprint(), "variants");
        let pooled = SweepGrid::paper(Scale::Test).backend("pooled");
        assert_ne!(fp, pooled.fingerprint(), "backends");
        // Every backend gets a distinct fingerprint.
        let fps: Vec<u64> = ["serial-link", "pooled", "distribution", "hybrid"]
            .iter()
            .map(|b| SweepGrid::paper(Scale::Test).backend(*b).fingerprint())
            .collect();
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "backends {i} and {j} must not collide");
            }
        }
    }

    #[test]
    fn backend_axis_multiplies_the_grid() {
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0, 500.0])
            .backends(["serial-link", "pooled", "distribution", "hybrid"]);
        assert_eq!(g.len(), 8);
        let reqs = g.requests().unwrap();
        assert_eq!(reqs.len(), 8);
        // Backend is the innermost axis.
        assert_eq!(reqs[0].backend_tag(), "serial-link");
        assert_eq!(reqs[1].backend_tag(), "pooled");
        assert_eq!(reqs[4].latency_ns(), 500.0);
    }

    #[test]
    fn backend_aliases_canonicalize_in_the_builder() {
        // `serial` and `serial-link` must produce the same fingerprint and
        // the same (default) grid, so the sweep cache never forks on
        // spelling.
        let canonical = SweepGrid::paper(Scale::Test);
        let alias = SweepGrid::paper(Scale::Test).backends(["serial"]);
        assert_eq!(alias, canonical);
        assert_eq!(alias.fingerprint(), canonical.fingerprint());
        let pool = SweepGrid::paper(Scale::Test).backend("pool");
        assert_eq!(pool.backends, vec!["pooled".to_string()]);
    }

    #[test]
    fn unknown_or_empty_backends_are_rejected() {
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .backends(["warp9"]);
        assert!(matches!(g.requests(), Err(SessionError::UnknownBackend(_))));
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .backends(Vec::<String>::new());
        assert!(matches!(g.requests(), Err(SessionError::EmptyGrid("backends"))));
    }

    #[test]
    fn pool_policy_refines_the_fingerprint_only_when_it_can_matter() {
        // Explicit `hash` IS the default: byte-identical grid and
        // fingerprint, so every pre-existing v3 cache stays valid.
        let base = SweepGrid::paper(Scale::Test);
        let hash = SweepGrid::paper(Scale::Test).pool_policy("hash");
        assert_eq!(base, hash);
        assert_eq!(base.fingerprint(), hash.fingerprint());
        // On a grid without the pooled backend the policy cannot change
        // any row, so the fingerprint must not fork (a stray flag would
        // otherwise force a duplicate re-simulation of identical rows).
        let ll_no_pool = SweepGrid::paper(Scale::Test).pool_policy("least-loaded");
        assert_eq!(base.fingerprint(), ll_no_pool.fingerprint());
        // With pooled swept, non-default policies refine the fingerprint.
        let pooled = SweepGrid::paper(Scale::Test).backend("pooled");
        let ll = SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("least-loaded");
        let rr = SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("round-robin");
        assert_ne!(pooled.fingerprint(), ll.fingerprint());
        assert_ne!(pooled.fingerprint(), rr.fingerprint());
        assert_ne!(ll.fingerprint(), rr.fingerprint());
        // Alias spellings canonicalize in the builder, like backends do.
        assert_eq!(SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("rr"), rr);
        assert_eq!(
            SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("ll").fingerprint(),
            ll.fingerprint()
        );
    }

    #[test]
    fn pool_policy_applies_to_every_request() {
        use crate::config::PoolPolicy;
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .backends(["pooled"])
            .pool_policy("least-loaded");
        let reqs = g.requests().unwrap();
        assert!(reqs
            .iter()
            .all(|r| r.config().far.pool_policy == PoolPolicy::LeastLoaded));
        // Default grids keep the hash policy.
        let reqs = SweepGrid::paper(Scale::Test).requests().unwrap();
        assert!(reqs.iter().all(|r| r.config().far.pool_policy == PoolPolicy::Hash));
    }

    #[test]
    fn unknown_pool_policy_fails_fast_naming_choices() {
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .pool_policy("warp9");
        let e = g.requests().unwrap_err();
        assert!(matches!(e, SessionError::UnknownPoolPolicy(_)));
        let msg = e.to_string();
        assert!(msg.contains("least-loaded") && msg.contains("round-robin"), "{msg}");
    }

    #[test]
    fn near_capacity_refines_the_fingerprint_only_when_it_can_matter() {
        // Explicit 0 IS the default: byte-identical grid and fingerprint,
        // so every pre-existing v4 fingerprint stays valid.
        let base = SweepGrid::paper(Scale::Test);
        let zero = SweepGrid::paper(Scale::Test).near_capacity(0);
        assert_eq!(base, zero);
        assert_eq!(base.fingerprint(), zero.fingerprint());
        // On a grid without the hybrid backend the capacity cannot change
        // any row, so the fingerprint must not fork.
        let no_hybrid = SweepGrid::paper(Scale::Test).near_capacity(4096);
        assert_eq!(base.fingerprint(), no_hybrid.fingerprint());
        // With hybrid swept, non-default capacities refine the fingerprint
        // and distinct capacities get distinct fingerprints.
        let hybrid = SweepGrid::paper(Scale::Test).backend("hybrid");
        let cap4k = SweepGrid::paper(Scale::Test).backend("hybrid").near_capacity(4096);
        let cap64 = SweepGrid::paper(Scale::Test).backend("hybrid").near_capacity(64);
        assert_ne!(hybrid.fingerprint(), cap4k.fingerprint());
        assert_ne!(cap4k.fingerprint(), cap64.fingerprint());
    }

    #[test]
    fn near_capacity_applies_to_every_request() {
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .backends(["hybrid"])
            .near_capacity(256);
        let reqs = g.requests().unwrap();
        assert!(reqs.iter().all(|r| r.config().far.near_capacity_lines == 256));
        // Default grids keep the legacy coin-flip (capacity 0).
        let reqs = SweepGrid::paper(Scale::Test).requests().unwrap();
        assert!(reqs.iter().all(|r| r.config().far.near_capacity_lines == 0));
    }

    #[test]
    fn adaptive_pool_policy_is_a_valid_refinement() {
        let pooled = SweepGrid::paper(Scale::Test).backend("pooled");
        let adaptive = SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("adaptive");
        assert_ne!(pooled.fingerprint(), adaptive.fingerprint());
        assert!(adaptive.requests().is_ok());
        // Alias spelling canonicalizes like the others.
        assert_eq!(
            SweepGrid::paper(Scale::Test).backend("pooled").pool_policy("adapt"),
            adaptive
        );
    }

    #[test]
    fn qos_policy_refines_the_fingerprint_only_when_it_can_matter() {
        // Explicit `none` IS the default: byte-identical grid and
        // fingerprint, so every pre-existing v5 fingerprint stays valid.
        let base = SweepGrid::paper(Scale::Test);
        let none = SweepGrid::paper(Scale::Test).qos_policy("none");
        assert_eq!(base, none);
        assert_eq!(base.fingerprint(), none.fingerprint());
        // On a grid sweeping neither pooled nor hybrid the policy wraps
        // nothing shared, so the fingerprint must not fork.
        let fs_no_pool = SweepGrid::paper(Scale::Test).qos_policy("fair-share");
        assert_eq!(base.fingerprint(), fs_no_pool.fingerprint());
        // With a shared-capable backend swept, non-default policies refine
        // the fingerprint and distinct policies get distinct fingerprints.
        let pooled = SweepGrid::paper(Scale::Test).backend("pooled");
        let fs = SweepGrid::paper(Scale::Test).backend("pooled").qos_policy("fair-share");
        let prio = SweepGrid::paper(Scale::Test).backend("pooled").qos_policy("priority");
        let thr = SweepGrid::paper(Scale::Test).backend("pooled").qos_policy("throttle");
        assert_ne!(pooled.fingerprint(), fs.fingerprint());
        assert_ne!(fs.fingerprint(), prio.fingerprint());
        assert_ne!(prio.fingerprint(), thr.fingerprint());
        // Hybrid counts as shared-capable too.
        let hybrid = SweepGrid::paper(Scale::Test).backend("hybrid");
        let hybrid_fs = SweepGrid::paper(Scale::Test).backend("hybrid").qos_policy("fair-share");
        assert_ne!(hybrid.fingerprint(), hybrid_fs.fingerprint());
        // Alias spellings canonicalize in the builder, like the others.
        assert_eq!(
            SweepGrid::paper(Scale::Test).backend("pooled").qos_policy("fair_share"),
            fs
        );
        assert_eq!(
            SweepGrid::paper(Scale::Test).backend("pooled").qos_policy("prio").fingerprint(),
            prio.fingerprint()
        );
    }

    #[test]
    fn program_content_refines_the_fingerprint() {
        // No programs: identical to a grid minted before the loader
        // existed — the axis is invisible.
        let base = SweepGrid::paper(Scale::Test);
        let empty = SweepGrid::paper(Scale::Test).programs([]);
        assert_eq!(base, empty);
        assert_eq!(base.fingerprint(), empty.fingerprint());
        // A program forks the fingerprint; changed content forks it again.
        let v1 = SweepGrid::paper(Scale::Test).programs([("pchase".to_string(), 0x1111)]);
        let v2 = SweepGrid::paper(Scale::Test).programs([("pchase".to_string(), 0x2222)]);
        assert_ne!(base.fingerprint(), v1.fingerprint());
        assert_ne!(v1.fingerprint(), v2.fingerprint());
        // Registration order doesn't matter: the fold is name-sorted.
        let ab = SweepGrid::paper(Scale::Test)
            .programs([("a".to_string(), 1), ("b".to_string(), 2)]);
        let ba = SweepGrid::paper(Scale::Test)
            .programs([("b".to_string(), 2), ("a".to_string(), 1)]);
        assert_eq!(ab.fingerprint(), ba.fingerprint());
    }

    #[test]
    fn qos_policy_applies_to_every_request() {
        use crate::config::QosPolicyKind;
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .backends(["pooled"])
            .qos_policy("throttle");
        let reqs = g.requests().unwrap();
        assert!(reqs.iter().all(|r| r.config().far.qos_policy == QosPolicyKind::Throttle));
        // Default grids keep the unwrapped backend.
        let reqs = SweepGrid::paper(Scale::Test).requests().unwrap();
        assert!(reqs.iter().all(|r| r.config().far.qos_policy == QosPolicyKind::None));
    }

    #[test]
    fn unknown_qos_policy_fails_fast_naming_choices() {
        let g = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([100.0])
            .qos_policy("warp9");
        let e = g.requests().unwrap_err();
        assert!(matches!(e, SessionError::UnknownQosPolicy(_)));
        let msg = e.to_string();
        assert!(msg.contains("fair-share") && msg.contains("throttle"), "{msg}");
    }

    #[test]
    fn variant_sel_parses() {
        assert_eq!(VariantSel::parse("auto").unwrap(), VariantSel::Auto);
        assert_eq!(
            VariantSel::parse("gp16").unwrap(),
            VariantSel::Fixed(Variant::GroupPrefetch(16))
        );
        assert!(VariantSel::parse("bogus").is_err());
    }
}
