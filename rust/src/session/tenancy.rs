//! Multi-tenant simulation: N per-tenant simulators sharing **one**
//! far-memory data plane, with QoS policies and per-tenant metrics.
//!
//! The driver behind `amu-sim mtrun`. Each tenant is an independent
//! [`crate::sim::Simulator`] instance (its own pipeline, caches, guest
//! memory) whose `MemSys.link` is replaced by a
//! [`SharedFarHandle`](crate::mem::backend::SharedFarHandle) onto a single
//! [`SharedFar`] arbitration point, so every far request from every tenant
//! contends in the same pooled/hybrid backend under the cell's
//! [`QosPolicyKind`]. A deterministic round-based interleaver steps the
//! tenants [`ROUND_CYCLES`] at a time in fixed order, so co-scheduled
//! tenants perceive each other's congestion while each pipeline stays
//! single-threaded — `--jobs 1` and `--jobs N` produce byte-identical
//! output because parallelism is only across *cells* (QoS policies) and
//! solo baselines, never within one.
//!
//! A run proceeds in two phases:
//!
//! 1. **Solo baselines** — each unique benchmark runs alone (same config,
//!    `qos_policy = none`) to establish its uncontended `measured_cycles`.
//! 2. **Shared cells** — for each requested QoS policy, all tenants run
//!    co-scheduled against one shared backend; each tenant's slowdown is
//!    `measured_cycles / solo`, reported in permille, and the cell maximum
//!    is stamped into every row's `tenant_slowdown_max` column.
//!
//! All tenants keep the base config's seed unchanged: a tenant's request
//! stream is exactly what its solo run issues, so the slowdown isolates
//! contention + arbitration rather than seed drift.

use crate::config::{QosPolicyKind, SimConfig};
use crate::mem::backend::{QosClass, SharedFar, TenantShare};
use crate::power::{estimate, EnergyModel};
use crate::session::executor::parallel_map;
use crate::session::metrics::{self, Selection};
use crate::session::registry::{self, Workload as _};
use crate::session::request::{RunRequest, SessionError};
use crate::session::RunResult;
use crate::stats::schema::ScenarioCol;
use crate::workloads::{self, Scale};
use std::collections::HashMap;

/// Cycles each tenant advances per interleaver round. Small enough that
/// tenants observe each other's congestion at far-memory timescales (a
/// round is well under one mean RTT), large enough that stepping overhead
/// stays negligible.
pub const ROUND_CYCLES: u64 = 1024;

/// One parsed `bench[:count][@weight][/priority]` item of a `--tenants`
/// spec: `count` instances of `bench`, each with the given `fair-share`
/// weight and `priority` class.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    pub bench: String,
    pub count: usize,
    pub weight: u64,
    pub class: QosClass,
}

impl TenantSpec {
    /// Parse one item, e.g. `redis`, `bfs:3`, `redis:2@3/high`.
    pub fn parse(item: &str) -> Result<TenantSpec, SessionError> {
        let bad = |msg: String| SessionError::BadTenantSpec(msg);
        let (body, class) = match item.split_once('/') {
            Some((b, p)) => (
                b,
                QosClass::parse(p).ok_or_else(|| {
                    bad(format!("unknown priority '{p}' in '{item}' (valid: high, normal, low)"))
                })?,
            ),
            None => (item, QosClass::Normal),
        };
        let (body, weight) = match body.split_once('@') {
            Some((b, w)) => (
                b,
                w.parse::<u64>()
                    .ok()
                    .filter(|&w| w >= 1)
                    .ok_or_else(|| bad(format!("weight '{w}' in '{item}' must be >= 1")))?,
            ),
            None => (body, 1),
        };
        let (bench, count) = match body.split_once(':') {
            Some((b, n)) => (
                b,
                n.parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .ok_or_else(|| bad(format!("count '{n}' in '{item}' must be >= 1")))?,
            ),
            None => (body, 1),
        };
        if bench.is_empty() {
            return Err(bad(format!("empty benchmark name in '{item}'")));
        }
        registry::find_or_err(bench)?;
        Ok(TenantSpec { bench: bench.to_string(), count, weight, class })
    }

    /// Canonical spec form (round-trips through [`TenantSpec::parse`]).
    pub fn spec_string(&self) -> String {
        format!("{}:{}@{}/{}", self.bench, self.count, self.weight, self.class.tag())
    }
}

/// Parse a comma-separated `--tenants` spec, e.g. `redis:2@3/high,bfs:1`.
pub fn parse_tenants(s: &str) -> Result<Vec<TenantSpec>, SessionError> {
    let specs: Vec<TenantSpec> = s
        .split(',')
        .filter(|i| !i.is_empty())
        .map(TenantSpec::parse)
        .collect::<Result<_, _>>()?;
    if specs.is_empty() {
        return Err(SessionError::BadTenantSpec(format!("no tenants in '{s}'")));
    }
    Ok(specs)
}

/// Canonical comma-joined form of a tenant list (the `mtrun` CSV header
/// records this, so a file is self-describing).
pub fn spec_string(specs: &[TenantSpec]) -> String {
    specs.iter().map(TenantSpec::spec_string).collect::<Vec<_>>().join(",")
}

/// Parse a comma-separated QoS policy list (aliases canonicalized, order
/// preserved, duplicates dropped), e.g. `fair-share,throttle`.
pub fn parse_policies(s: &str) -> Result<Vec<QosPolicyKind>, SessionError> {
    let mut out = Vec::new();
    for item in s.split(',').filter(|i| !i.is_empty()) {
        let p = QosPolicyKind::parse(item)
            .ok_or_else(|| SessionError::UnknownQosPolicy(item.to_string()))?;
        if !out.contains(&p) {
            out.push(p);
        }
    }
    if out.is_empty() {
        return Err(SessionError::UnknownQosPolicy(s.to_string()));
    }
    Ok(out)
}

/// One instantiated tenant slot: a label unique within the run
/// (`bench#<index>`), the benchmark it runs, and its QoS share.
#[derive(Debug, Clone)]
pub struct Tenant {
    pub label: String,
    pub bench: String,
    pub share: TenantShare,
}

/// Expand specs into concrete tenant slots, labeled `bench#<index>` by
/// global tenant index (the index is also the tenant's [`SharedFar`] slot).
pub fn expand(specs: &[TenantSpec]) -> Vec<Tenant> {
    let mut out = Vec::new();
    for spec in specs {
        for _ in 0..spec.count {
            out.push(Tenant {
                label: format!("{}#{}", spec.bench, out.len()),
                bench: spec.bench.clone(),
                share: TenantShare { weight: spec.weight, class: spec.class },
            });
        }
    }
    out
}

/// One tenant's outcome within one QoS cell.
#[derive(Debug, Clone)]
pub struct MtRow {
    pub policy: QosPolicyKind,
    pub label: String,
    pub bench: String,
    pub weight: u64,
    pub class: QosClass,
    /// The benchmark's uncontended `measured_cycles` (phase-1 baseline).
    pub solo_cycles: u64,
    /// `measured_cycles * 1000 / solo_cycles`, rounded to nearest.
    pub slowdown_permille: u64,
    /// Full schema record for this tenant; `bench` carries the tenant
    /// label and `tenant_slowdown_max` the cell-wide maximum.
    pub result: RunResult,
}

/// One QoS policy cell: every tenant's row, in tenant order.
#[derive(Debug, Clone)]
pub struct MtOutcome {
    pub policy: QosPolicyKind,
    pub rows: Vec<MtRow>,
}

/// A validated multi-tenant run description: tenant specs, a base config
/// (its backend/latency/seed shared by every tenant), the QoS policies to
/// sweep, and execution knobs.
#[derive(Debug, Clone)]
pub struct MtRequest {
    pub tenants: Vec<TenantSpec>,
    pub config: SimConfig,
    pub policies: Vec<QosPolicyKind>,
    pub scale: Scale,
    pub jobs: usize,
    pub quiet: bool,
}

impl MtRequest {
    pub fn new(tenants: Vec<TenantSpec>, config: SimConfig) -> Self {
        Self {
            tenants,
            config,
            policies: vec![QosPolicyKind::FairShare],
            scale: Scale::Test,
            jobs: 1,
            quiet: false,
        }
    }

    /// Run both phases and return one outcome per policy, in policy order.
    pub fn run(&self) -> Result<Vec<MtOutcome>, SessionError> {
        if self.tenants.is_empty() {
            return Err(SessionError::EmptyGrid("tenants"));
        }
        if self.policies.is_empty() {
            return Err(SessionError::EmptyGrid("qos policies"));
        }
        let tenants = expand(&self.tenants);

        // Phase 1: solo baselines, one per unique benchmark, in parallel.
        let mut benches: Vec<String> = self.tenants.iter().map(|t| t.bench.clone()).collect();
        benches.sort();
        benches.dedup();
        let quiet = self.quiet;
        let solo_results = parallel_map(self.jobs, benches.len(), |i| {
            if !quiet {
                eprintln!("[mtrun] solo baseline: {} ...", benches[i]);
            }
            solo_cycles(&self.config, &benches[i], self.scale)
        });
        let mut solo: HashMap<String, u64> = HashMap::new();
        for (b, r) in benches.iter().zip(solo_results) {
            solo.insert(b.clone(), r?);
        }

        // Phase 2: one shared cell per QoS policy, cells in parallel,
        // tenants within a cell strictly interleaved single-threaded.
        let cells = parallel_map(self.jobs, self.policies.len(), |i| {
            if !quiet {
                eprintln!(
                    "[mtrun] qos={}: co-scheduling {} tenants ...",
                    self.policies[i].tag(),
                    tenants.len()
                );
            }
            run_cell(&self.config, &tenants, self.policies[i], self.scale)
        });

        let mut out = Vec::new();
        for (&policy, cell) in self.policies.iter().zip(cells) {
            let raw = cell?;
            let slowdowns: Vec<u64> = tenants
                .iter()
                .zip(&raw)
                .map(|(t, r)| {
                    let s = solo[&t.bench].max(1);
                    (r.measured_cycles * 1000 + s / 2) / s
                })
                .collect();
            let cell_max = slowdowns.iter().copied().max().unwrap_or(0);
            let rows = tenants
                .iter()
                .zip(raw)
                .zip(slowdowns)
                .map(|((t, mut r), sd)| {
                    r.scenario = r.scenario.with(ScenarioCol::TenantSlowdownMax, cell_max);
                    MtRow {
                        policy,
                        label: t.label.clone(),
                        bench: t.bench.clone(),
                        weight: t.share.weight,
                        class: t.share.class,
                        solo_cycles: solo[&t.bench],
                        slowdown_permille: sd,
                        result: r,
                    }
                })
                .collect();
            out.push(MtOutcome { policy, rows });
        }
        Ok(out)
    }
}

/// Phase-1 baseline: the benchmark alone on the same config with QoS off.
fn solo_cycles(base: &SimConfig, bench: &str, scale: Scale) -> Result<u64, SessionError> {
    let mut cfg = base.clone();
    cfg.far.qos_policy = QosPolicyKind::None;
    RunRequest::bench(bench).config(cfg).scale(scale).run().map(|r| r.measured_cycles)
}

/// Run one shared cell: every tenant against one [`SharedFar`] under
/// `policy`, stepped round-robin until all halt, then validated and
/// harvested. Rows come back in tenant order with the *final* pool-wide
/// scenario snapshot (uniform across the cell's rows by construction).
fn run_cell(
    base: &SimConfig,
    tenants: &[Tenant],
    policy: QosPolicyKind,
    scale: Scale,
) -> Result<Vec<RunResult>, SessionError> {
    let mut cfg = base.clone();
    cfg.far.qos_policy = policy;
    cfg.validate().map_err(SessionError::InvalidConfig)?;
    let shares: Vec<TenantShare> = tenants.iter().map(|t| t.share).collect();
    let shared = SharedFar::new(&cfg.far, cfg.core.freq_ghz, cfg.seed, shares);
    let variant = workloads::variant_for(&cfg);

    let mut specs = Vec::new();
    let mut sims = Vec::new();
    for (i, t) in tenants.iter().enumerate() {
        let w = registry::find_or_err(&t.bench)?;
        let spec = w.build(&cfg, variant, scale);
        // This path wires simulators by hand (shared backend swap below),
        // bypassing `WorkloadSpec::run` — so it gates on the verifier here.
        spec.verify_ok().map_err(SessionError::Verify)?;
        let mut sim = spec.instantiate(&cfg);
        // Swap the per-sim backend for this tenant's handle onto the one
        // shared data plane — the whole point of the exercise.
        sim.memsys.link = Box::new(SharedFar::handle(&shared, i));
        specs.push(spec);
        sims.push(sim);
    }

    // Deterministic round-based interleaver: fixed tenant order, fixed
    // budget, no dependence on wall-clock or thread scheduling.
    let mut done = vec![false; sims.len()];
    let mut remaining = sims.len();
    while remaining > 0 {
        for i in 0..sims.len() {
            if done[i] {
                continue;
            }
            let finished = sims[i]
                .run_for(ROUND_CYCLES)
                .map_err(|e| SessionError::Run(format!("{}: {e}", tenants[i].label)))?;
            if finished {
                done[i] = true;
                remaining -= 1;
            }
        }
    }

    let snapshot = shared.lock().expect("shared far-memory lock poisoned").scenario_snapshot();
    let mut rows = Vec::new();
    for ((sim, spec), t) in sims.iter_mut().zip(&specs).zip(tenants) {
        (spec.validate)(sim)
            .map_err(|e| SessionError::Run(format!("{}: validation: {e}", t.label)))?;
        let p = estimate(&cfg, &sim.stats, &EnergyModel::default());
        rows.push(RunResult {
            bench: t.label.clone(),
            config: cfg.name.clone(),
            backend: cfg.far.backend.tag().into(),
            variant: variant.tag(),
            latency_ns: cfg.far.added_latency_ns,
            measured_cycles: sim.stats.measured_cycles.max(1),
            total_cycles: sim.cycle,
            insts: sim.stats.insts_committed,
            ipc: sim.stats.ipc(),
            mlp: sim.stats.mlp(),
            peak_inflight: sim.stats.far_inflight.max,
            dynamic_uj: p.dynamic_uj,
            static_uj: p.static_uj,
            disambig_frac: sim.stats.region_fraction(crate::stats::Region::Disambig),
            scenario: snapshot,
        });
    }
    Ok(rows)
}

/// Serialize outcomes as the `mtrun` CSV: a self-describing comment line,
/// then per-tenant prefix columns followed by the full metric schema (the
/// same `Selection::All` columns the sweep cache stores; `bench` carries
/// the tenant label). Row order is (policy, tenant) — canonical, so the
/// file is byte-identical across `--jobs` counts.
pub fn mt_csv(specs: &[TenantSpec], scale: Scale, outcomes: &[MtOutcome]) -> String {
    let cols = Selection::All.columns();
    let mut s = format!(
        "# amu-sim mtrun tenants={} scale={} schema={:016x}\n",
        spec_string(specs),
        scale.tag(),
        metrics::schema_hash()
    );
    s.push_str("qos,tenant,weight,priority,solo_cycles,slowdown_permille,");
    s.push_str(&metrics::csv_header(&Selection::All));
    s.push('\n');
    for o in outcomes {
        for r in &o.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},",
                o.policy.tag(),
                r.label,
                r.weight,
                r.class.tag(),
                r.solo_cycles,
                r.slowdown_permille
            ));
            s.push_str(&metrics::csv_row_with(&cols, &r.result));
            s.push('\n');
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_specs_parse_the_full_grammar() {
        let t = TenantSpec::parse("redis").unwrap();
        assert_eq!(
            t,
            TenantSpec { bench: "redis".into(), count: 1, weight: 1, class: QosClass::Normal }
        );
        let t = TenantSpec::parse("redis:2@3/high").unwrap();
        assert_eq!(
            t,
            TenantSpec { bench: "redis".into(), count: 2, weight: 3, class: QosClass::High }
        );
        assert_eq!(t.spec_string(), "redis:2@3/high");
        let t2 = TenantSpec::parse(&t.spec_string()).unwrap();
        assert_eq!(t, t2, "spec_string must round-trip");
        // Partial forms.
        assert_eq!(TenantSpec::parse("bfs:3").unwrap().count, 3);
        assert_eq!(TenantSpec::parse("bfs@5").unwrap().weight, 5);
        assert_eq!(TenantSpec::parse("bfs/low").unwrap().class, QosClass::Low);
    }

    #[test]
    fn tenant_spec_errors_name_the_problem() {
        let e = TenantSpec::parse("warp9").unwrap_err();
        assert!(matches!(e, SessionError::UnknownBench(_)), "{e}");
        let e = TenantSpec::parse("redis:0").unwrap_err();
        assert!(e.to_string().contains(">= 1"), "{e}");
        let e = TenantSpec::parse("redis@0").unwrap_err();
        assert!(e.to_string().contains(">= 1"), "{e}");
        let e = TenantSpec::parse("redis/urgent").unwrap_err();
        assert!(e.to_string().contains("high, normal, low"), "{e}");
        let e = TenantSpec::parse("redis:x").unwrap_err();
        assert!(e.to_string().contains("bench[:count][@weight][/priority]"), "{e}");
        assert!(parse_tenants("").is_err());
    }

    #[test]
    fn tenant_lists_expand_with_global_labels() {
        let specs = parse_tenants("redis:2@3/high,bfs").unwrap();
        let tenants = expand(&specs);
        assert_eq!(tenants.len(), 3);
        assert_eq!(tenants[0].label, "redis#0");
        assert_eq!(tenants[1].label, "redis#1");
        assert_eq!(tenants[2].label, "bfs#2");
        assert_eq!(tenants[0].share, TenantShare { weight: 3, class: QosClass::High });
        assert_eq!(tenants[2].share, TenantShare { weight: 1, class: QosClass::Normal });
        assert_eq!(spec_string(&specs), "redis:2@3/high,bfs:1@1/normal");
    }

    #[test]
    fn policy_lists_canonicalize_and_dedup() {
        assert_eq!(
            parse_policies("fair_share,prio,fair-share,throttle").unwrap(),
            vec![QosPolicyKind::FairShare, QosPolicyKind::Priority, QosPolicyKind::Throttle]
        );
        let e = parse_policies("fair-share,warp9").unwrap_err();
        assert!(matches!(e, SessionError::UnknownQosPolicy(_)), "{e}");
        assert!(parse_policies("").is_err());
    }

    #[test]
    fn two_gups_tenants_share_one_pool_and_slow_each_other_down() {
        let mut req = MtRequest::new(
            parse_tenants("gups:2").unwrap(),
            SimConfig::amu().with_far_latency_ns(500.0),
        );
        req.config.far.backend = crate::config::FarBackendKind::Pooled;
        req.policies = vec![QosPolicyKind::FairShare];
        req.quiet = true;
        let out = req.run().unwrap();
        assert_eq!(out.len(), 1);
        let rows = &out[0].rows;
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].label, "gups#0");
        assert_eq!(rows[1].label, "gups#1");
        for r in rows {
            assert_eq!(r.result.bench, r.label);
            assert!(
                r.slowdown_permille > 1000,
                "{}: sharing one pool must cost something: {}",
                r.label,
                r.slowdown_permille
            );
            assert_eq!(
                r.result.scenario.get(ScenarioCol::TenantSlowdownMax),
                rows.iter().map(|x| x.slowdown_permille).max().unwrap(),
                "cell max must be stamped on every row"
            );
        }
        // Fair-share pacing of two contending floods must register steals.
        assert!(rows[0].result.scenario.get(ScenarioCol::PoolStealCycles) > 0);

        let csv = mt_csv(&req.tenants, req.scale, &out);
        let mut lines = csv.lines();
        assert!(lines.next().unwrap().starts_with("# amu-sim mtrun tenants=gups:2@1/normal"));
        let header = lines.next().unwrap();
        assert!(header.starts_with("qos,tenant,weight,priority,solo_cycles,slowdown_permille,"));
        assert!(header.ends_with("pool_steal_cycles"));
        let first = lines.next().unwrap();
        assert!(first.starts_with("fair-share,gups#0,1,normal,"), "{first}");
        assert_eq!(csv.lines().count(), 2 + 2);
    }
}
