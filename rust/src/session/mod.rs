//! First-class session API: typed run requests, the workload registry, and
//! the parallel sweep executor.
//!
//! This module is the front door for every simulation in the crate:
//!
//! * [`registry`] — the [`Workload`](registry::Workload) trait and the
//!   benchmark registry (typed lookup instead of a panic-on-unknown string
//!   `match`).
//! * [`programs`] — the external-program loader behind `--program
//!   <file.asm>`: text-format AMI assembly parsed by `isa::parse`,
//!   verified by the same gate as the builtins, registered as a
//!   first-class [`Workload`](registry::Workload).
//! * [`request`] — the [`RunRequest`] builder: bench/config/variant/latency
//!   combinations validated at construction, every failure a
//!   [`SessionError`] naming the valid choices.
//! * [`grid`] — [`SweepGrid`]: any benches × configs × latencies × variants
//!   × far-memory backends cross product, not just the paper's fixed
//!   matrix, with a stable fingerprint.
//! * [`executor`] — [`Session`]: fans runs out across scoped worker threads
//!   with deterministic row ordering and a per-run-keyed, resumable CSV
//!   cache.
//! * [`metrics`] — the versioned metric schema: ordered, typed column
//!   descriptors (core + per-backend scenario columns), the [`MetricSet`]
//!   record, and the `--columns` [`Selection`] every CSV is emitted
//!   through.
//! * [`cache`] — the fingerprint- and schema-hash-headed CSV format
//!   (bit-exact float round trips, strict rejection of corrupt or
//!   stale-schema files with a migration error).
//! * [`tenancy`] — the multi-tenant driver behind `amu-sim mtrun`: N
//!   tenant simulators sharing one far-memory pool through the
//!   shared-backend arbitration point, interleaved deterministically,
//!   with QoS policies and per-tenant slowdown metrics.
//!
//! # Running one benchmark
//!
//! ```no_run
//! use amu_sim::config::SimConfig;
//! use amu_sim::session::RunRequest;
//! use amu_sim::workloads::Variant;
//!
//! let result = RunRequest::bench("gups")
//!     .config(SimConfig::amu())
//!     .variant(Variant::Amu)
//!     .latency_ns(1000.0)
//!     .run()
//!     .expect("valid request");
//! println!("{} cycles, mlp {:.1}", result.measured_cycles, result.mlp);
//! ```
//!
//! # Running sweeps
//!
//! ```no_run
//! use amu_sim::session::{Session, SweepGrid};
//! use amu_sim::workloads::Scale;
//!
//! // The paper's 11 x 4 x 6 grid, parallel across all cores, cached.
//! let paper_rows = Session::new().sweep_paper(Scale::Test).unwrap();
//! assert_eq!(paper_rows.len(), 11 * 4 * 6);
//!
//! // Or any custom grid with an explicit worker count.
//! let grid = SweepGrid::new(Scale::Test)
//!     .benches(["gups", "bfs"])
//!     .configs(["baseline", "amu"])
//!     .latencies_ns([500.0, 2000.0]);
//! let rows = Session::new().jobs(4).sweep(&grid).unwrap();
//! assert_eq!(rows.len(), 8);
//! ```
//!
//! The CLI exposes the same executor as `amu-sim sweep --jobs N`.
//! `report::run_one` and `report::sweep_cached` remain as deprecated shims
//! over this API and will be removed once nothing links against them.

pub mod cache;
pub mod executor;
pub mod grid;
pub mod metrics;
pub mod programs;
pub mod registry;
pub mod request;
pub mod tenancy;

pub use executor::Session;
pub use grid::{SweepGrid, VariantSel, PAPER_CONFIGS};
pub use metrics::{MetricSet, Selection};
pub use programs::{LoadedProgram, ProgramError};
pub use registry::Workload;
pub use request::{RunRequest, RunRequestBuilder, SessionError};
pub use tenancy::{MtOutcome, MtRequest, MtRow, TenantSpec};

use crate::power::PowerBreakdown;
use crate::stats::schema::ScenarioStats;
use std::path::PathBuf;

/// Metrics from one completed, validated simulation run.
///
/// `RunResult` is the *typed view* over the schema-ordered
/// [`MetricSet`] record (see [`metrics`]): every field here backs a
/// [`metrics::CORE_COLUMNS`] entry, and the per-backend [`ScenarioStats`]
/// record backs the scenario columns. All CSV emission — the v5 sweep
/// cache, `--columns` reports — goes through the schema, so adding a
/// scenario metric is a schema-table edit, not a serialization change
/// here.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunResult {
    pub bench: String,
    pub config: String,
    /// Far-memory backend tag (`serial-link`, `pooled`, `distribution`,
    /// `hybrid`).
    pub backend: String,
    pub variant: String,
    pub latency_ns: f64,
    pub measured_cycles: u64,
    pub total_cycles: u64,
    pub insts: u64,
    pub ipc: f64,
    pub mlp: f64,
    pub peak_inflight: u64,
    pub dynamic_uj: f64,
    pub static_uj: f64,
    pub disambig_frac: f64,
    /// Per-backend scenario counters (near-tier hits/evictions, pool
    /// congestion/policy switches, ...), one value per
    /// [`crate::stats::schema::SCENARIO_COLUMNS`] entry. Zero for
    /// backends without the mechanism.
    pub scenario: ScenarioStats,
}

impl RunResult {
    pub fn power(&self) -> PowerBreakdown {
        PowerBreakdown { dynamic_uj: self.dynamic_uj, static_uj: self.static_uj }
    }

    /// Total run energy (static + dynamic), µJ.
    pub fn total_uj(&self) -> f64 {
        self.dynamic_uj + self.static_uj
    }

    /// This run's schema-ordered metric record (lossless snapshot).
    pub fn metrics(&self) -> MetricSet {
        MetricSet::of(self)
    }
}

/// Where reports, sweep caches, and figure CSVs land.
///
/// Defaults to `<crate root>/results`; a non-empty `AMU_RESULTS_DIR`
/// environment variable overrides it at *runtime* (CI artifact
/// collection and sandboxed runs redirect output without rebuilding —
/// the old compile-time-only `CARGO_MANIFEST_DIR` path could not).
pub fn results_dir() -> PathBuf {
    let d = match std::env::var_os("AMU_RESULTS_DIR") {
        Some(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results"),
    };
    std::fs::create_dir_all(&d).ok();
    d
}
