//! Typed, validated run requests.
//!
//! [`RunRequest`] replaces the stringly `report::run_one(&str, &str, ...)`
//! entry point: bench, config, variant, and latency are checked once at
//! construction and every failure is a [`SessionError`] naming the valid
//! choices — never a panic.

use crate::config::{FarBackendKind, PoolPolicy, QosPolicyKind, SimConfig};
use crate::power::{estimate, EnergyModel};
use crate::session::registry::{self, Workload};
use crate::session::RunResult;
use crate::workloads::{self, Scale, Variant};

/// Everything that can go wrong constructing or executing a run.
#[derive(Debug, Clone, PartialEq)]
pub enum SessionError {
    UnknownBench(String),
    UnknownConfig(String),
    UnknownBackend(String),
    UnknownPoolPolicy(String),
    UnknownQosPolicy(String),
    BadTenantSpec(String),
    UnknownVariant(String),
    UnsupportedVariant { bench: String, variant: String },
    InvalidLatency(f64),
    InvalidConfig(String),
    EmptyGrid(&'static str),
    /// The program failed static verification (`isa::verify`) — refused
    /// before simulation.
    Verify(String),
    Run(String),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::UnknownBench(name) => {
                // Built-ins plus loaded `.asm` programs, sorted + deduped;
                // one-edit-distance typos get a nearest-name hint.
                write!(
                    f,
                    "unknown benchmark '{name}' (valid: {})",
                    crate::session::registry::known_names().join(", ")
                )?;
                if let Some(hint) = crate::session::registry::nearest(name) {
                    write!(f, " — did you mean '{hint}'?")?;
                }
                Ok(())
            }
            SessionError::UnknownConfig(name) => write!(
                f,
                "unknown config '{name}' (valid: {})",
                SimConfig::preset_names().join(", ")
            ),
            SessionError::UnknownBackend(name) => write!(
                f,
                "unknown far-memory backend '{name}' (valid: {})",
                FarBackendKind::names().join(", ")
            ),
            SessionError::UnknownPoolPolicy(name) => write!(
                f,
                "unknown pool policy '{name}' (valid: {})",
                PoolPolicy::names().join(", ")
            ),
            SessionError::UnknownQosPolicy(name) => write!(
                f,
                "unknown qos policy '{name}' (valid: {})",
                QosPolicyKind::names().join(", ")
            ),
            SessionError::BadTenantSpec(msg) => write!(
                f,
                "bad tenant spec: {msg} \
                 (expected bench[:count][@weight][/priority], e.g. redis:2@3/high)"
            ),
            SessionError::UnknownVariant(msg) => write!(f, "{msg}"),
            SessionError::UnsupportedVariant { bench, variant } => {
                write!(f, "benchmark '{bench}' does not support variant '{variant}'")
            }
            SessionError::InvalidLatency(ns) => {
                write!(f, "invalid far-memory latency {ns} ns (must be finite and >= 0)")
            }
            SessionError::InvalidConfig(msg) => write!(f, "invalid config: {msg}"),
            SessionError::EmptyGrid(dim) => {
                write!(f, "sweep grid has an empty '{dim}' dimension")
            }
            SessionError::Verify(msg) => write!(f, "verification failed: {msg}"),
            SessionError::Run(msg) => write!(f, "run failed: {msg}"),
        }
    }
}

impl std::error::Error for SessionError {}

/// A fully validated single-run description: known benchmark, valid
/// configuration, supported variant, sane latency. Construct through
/// [`RunRequest::bench`].
#[derive(Clone)]
pub struct RunRequest {
    workload: &'static dyn Workload,
    config: SimConfig,
    variant: Variant,
    scale: Scale,
}

impl std::fmt::Debug for RunRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RunRequest")
            .field("bench", &self.workload.name())
            .field("config", &self.config.name)
            .field("backend", &self.backend_tag())
            .field("variant", &self.variant)
            .field("latency_ns", &self.config.far.added_latency_ns)
            .field("scale", &self.scale)
            .finish()
    }
}

impl RunRequest {
    /// Start building a request for benchmark `name` (validated at
    /// [`RunRequestBuilder::build`]).
    pub fn bench(name: impl Into<String>) -> RunRequestBuilder {
        RunRequestBuilder {
            bench: name.into(),
            config: None,
            config_name: None,
            variant: None,
            latency_ns: None,
            backend: None,
            pool_policy: None,
            qos_policy: None,
            near_capacity: None,
            no_jitter: false,
            scale: Scale::Test,
        }
    }

    pub fn bench_name(&self) -> &'static str {
        self.workload.name()
    }

    pub fn config_name(&self) -> &str {
        &self.config.name
    }

    pub fn config(&self) -> &SimConfig {
        &self.config
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    pub fn latency_ns(&self) -> f64 {
        self.config.far.added_latency_ns
    }

    /// Far-memory backend tag this run simulates under.
    pub fn backend_tag(&self) -> &'static str {
        self.config.far.backend.tag()
    }

    /// `pooled` channel-selection policy tag this run simulates under.
    pub fn pool_policy_tag(&self) -> &'static str {
        self.config.far.pool_policy.tag()
    }

    /// QoS admission policy tag this run simulates under (`none` unless the
    /// config wraps its backend in the shared arbitration point).
    pub fn qos_policy_tag(&self) -> &'static str {
        self.config.far.qos_policy.tag()
    }

    pub fn scale(&self) -> Scale {
        self.scale
    }

    /// The cache key identifying this run's row in a sweep CSV.
    pub fn key(&self) -> (String, String, String, String, u64) {
        (
            self.workload.name().to_string(),
            self.config.name.clone(),
            self.backend_tag().to_string(),
            self.variant.tag(),
            self.latency_ns().to_bits(),
        )
    }

    /// Build the workload, simulate to completion, validate the
    /// architectural result, and collect metrics.
    pub fn run(&self) -> Result<RunResult, SessionError> {
        let spec = self.workload.build(&self.config, self.variant, self.scale);
        spec.verify_ok().map_err(SessionError::Verify)?;
        let sim = spec.run(&self.config).map_err(SessionError::Run)?;
        let p = estimate(&self.config, &sim.stats, &EnergyModel::default());
        Ok(RunResult {
            bench: self.workload.name().into(),
            config: self.config.name.clone(),
            backend: self.backend_tag().into(),
            variant: self.variant.tag(),
            latency_ns: self.latency_ns(),
            measured_cycles: sim.stats.measured_cycles.max(1),
            total_cycles: sim.cycle,
            insts: sim.stats.insts_committed,
            ipc: sim.stats.ipc(),
            mlp: sim.stats.mlp(),
            peak_inflight: sim.stats.far_inflight.max,
            dynamic_uj: p.dynamic_uj,
            static_uj: p.static_uj,
            disambig_frac: sim.stats.region_fraction(crate::stats::Region::Disambig),
            // The backend's scenario record, straight into the result —
            // one assignment regardless of how many columns the scenario
            // schema grows.
            scenario: sim.stats.scenario,
        })
    }
}

/// Builder for [`RunRequest`]; `build()` performs all validation.
#[derive(Debug, Clone)]
pub struct RunRequestBuilder {
    bench: String,
    config: Option<SimConfig>,
    config_name: Option<String>,
    variant: Option<Variant>,
    latency_ns: Option<f64>,
    backend: Option<String>,
    pool_policy: Option<String>,
    qos_policy: Option<String>,
    near_capacity: Option<usize>,
    no_jitter: bool,
    scale: Scale,
}

impl RunRequestBuilder {
    /// Use a concrete configuration (possibly customized beyond a preset).
    pub fn config(mut self, cfg: SimConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Use a configuration preset by name (resolved and validated at
    /// `build()`).
    pub fn config_name(mut self, name: impl Into<String>) -> Self {
        self.config_name = Some(name.into());
        self
    }

    /// Force a specific variant. Without this, the natural variant for the
    /// configuration is chosen (AMU configs run coroutines, others sync).
    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = Some(v);
        self
    }

    /// Override the additional far-memory latency. Without this, the
    /// configuration's own `far.added_latency_ns` is kept.
    pub fn latency_ns(mut self, ns: f64) -> Self {
        self.latency_ns = Some(ns);
        self
    }

    /// Select the far-memory backend by tag (`serial-link`, `pooled`,
    /// `distribution`, `hybrid`). Without this, the configuration's own
    /// `far.backend` is kept (serial link by default). Validated at
    /// `build()`.
    pub fn backend(mut self, tag: impl Into<String>) -> Self {
        self.backend = Some(tag.into());
        self
    }

    /// Select the `pooled` backend's channel-selection policy by tag
    /// (`hash`, `least-loaded`, `round-robin`). Without this, the
    /// configuration's own `far.pool_policy` is kept (`hash` by default).
    /// Validated at `build()`. Harmless under non-pooled backends.
    pub fn pool_policy(mut self, tag: impl Into<String>) -> Self {
        self.pool_policy = Some(tag.into());
        self
    }

    /// Select the QoS admission policy by tag (`none`, `fair-share`,
    /// `priority`, `throttle`; aliases accepted). A non-`none` policy wraps
    /// the far backend in the shared arbitration point even for a solo run.
    /// Without this, the configuration's own `far.qos_policy` is kept
    /// (`none` by default). Validated at `build()`.
    pub fn qos_policy(mut self, tag: impl Into<String>) -> Self {
        self.qos_policy = Some(tag.into());
        self
    }

    /// Override the `hybrid` backend's near-tier capacity in 64 B lines
    /// (`0` = the legacy `near_frac` coin-flip). Without this, the
    /// configuration's own `far.near_capacity_lines` is kept. Harmless
    /// under non-hybrid backends.
    pub fn near_capacity(mut self, lines: usize) -> Self {
        self.near_capacity = Some(lines);
        self
    }

    /// Disable far-memory latency *variability* for A/B comparisons:
    /// zeroes the serial-link/pooled jitter fraction and the
    /// `distribution` backend's sigma/tail fraction (its samples collapse
    /// to the configured mean). The `hybrid` backend's near/far path
    /// choice is seeded-random rather than jitter and is not affected.
    pub fn no_jitter(mut self) -> Self {
        self.no_jitter = true;
        self
    }

    pub fn scale(mut self, s: Scale) -> Self {
        self.scale = s;
        self
    }

    /// Validate and produce the immutable request.
    pub fn build(self) -> Result<RunRequest, SessionError> {
        let workload = registry::find_or_err(&self.bench)?;
        let mut cfg = match (self.config, self.config_name) {
            (Some(cfg), _) => cfg,
            (None, Some(name)) => {
                SimConfig::preset(&name).ok_or(SessionError::UnknownConfig(name))?
            }
            (None, None) => SimConfig::baseline(),
        };
        if let Some(ns) = self.latency_ns {
            cfg = cfg.with_far_latency_ns(ns);
        }
        if let Some(tag) = &self.backend {
            cfg.far.backend = FarBackendKind::parse(tag)
                .ok_or_else(|| SessionError::UnknownBackend(tag.clone()))?;
        }
        if let Some(tag) = &self.pool_policy {
            cfg.far.pool_policy = PoolPolicy::parse(tag)
                .ok_or_else(|| SessionError::UnknownPoolPolicy(tag.clone()))?;
        }
        if let Some(tag) = &self.qos_policy {
            cfg.far.qos_policy = QosPolicyKind::parse(tag)
                .ok_or_else(|| SessionError::UnknownQosPolicy(tag.clone()))?;
        }
        if let Some(lines) = self.near_capacity {
            cfg.far.near_capacity_lines = lines;
        }
        if self.no_jitter {
            cfg.far.jitter_frac = 0.0;
            cfg.far.dist_sigma = 0.0;
            cfg.far.dist_tail_frac = 0.0;
        }
        let latency = cfg.far.added_latency_ns;
        if !latency.is_finite() || latency < 0.0 {
            return Err(SessionError::InvalidLatency(latency));
        }
        cfg.validate().map_err(SessionError::InvalidConfig)?;
        let variant = self.variant.unwrap_or_else(|| workloads::variant_for(&cfg));
        if !workload.supported_variants().contains(&variant.kind()) {
            return Err(SessionError::UnsupportedVariant {
                bench: self.bench,
                variant: variant.tag(),
            });
        }
        Ok(RunRequest { workload, config: cfg, variant, scale: self.scale })
    }

    /// Convenience: `build()?.run()`.
    pub fn run(self) -> Result<RunResult, SessionError> {
        self.build()?.run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_bench_hints_at_one_edit_typos() {
        let e = RunRequest::bench("gupz").build().unwrap_err();
        assert!(matches!(e, SessionError::UnknownBench(_)));
        assert!(e.to_string().contains("did you mean 'gups'?"), "{e}");
        // No hint when nothing is one edit away.
        let e = RunRequest::bench("zzzzzz").build().unwrap_err();
        assert!(!e.to_string().contains("did you mean"), "{e}");
    }

    #[test]
    fn builder_validates_bench_and_config() {
        let e = RunRequest::bench("nope").build().unwrap_err();
        assert!(matches!(e, SessionError::UnknownBench(_)));
        assert!(e.to_string().contains("gups"), "{e}");
        let e = RunRequest::bench("gups").config_name("warp9").build().unwrap_err();
        assert!(matches!(e, SessionError::UnknownConfig(_)));
        assert!(e.to_string().contains("baseline"), "{e}");
    }

    #[test]
    fn builder_rejects_bad_latency() {
        for ns in [-1.0, f64::NAN, f64::INFINITY] {
            let e = RunRequest::bench("gups").latency_ns(ns).build().unwrap_err();
            assert!(matches!(e, SessionError::InvalidLatency(_)), "{ns}");
        }
    }

    #[test]
    fn builder_picks_natural_variant() {
        let r = RunRequest::bench("gups").config(SimConfig::amu()).build().unwrap();
        assert_eq!(r.variant(), Variant::Amu);
        let r = RunRequest::bench("gups").config_name("baseline").build().unwrap();
        assert_eq!(r.variant(), Variant::Sync);
    }

    #[test]
    fn request_runs_and_reports_metrics() {
        let r = RunRequest::bench("gups")
            .config(SimConfig::amu())
            .variant(Variant::Amu)
            .latency_ns(1000.0)
            .scale(Scale::Test)
            .run()
            .unwrap();
        assert_eq!(r.bench, "gups");
        assert_eq!(r.config, "amu");
        assert!(r.measured_cycles > 0);
        assert!(r.mlp > 1.0, "AMU GUPS must overlap: mlp={}", r.mlp);
    }

    #[test]
    fn unsupported_variant_is_rejected_not_degraded() {
        // hj has no software-prefetch port; the raw build entry point used
        // to silently run sync and label the row gp16.
        let e = RunRequest::bench("hj")
            .config_name("cxl-ideal")
            .variant(Variant::GroupPrefetch(16))
            .build()
            .unwrap_err();
        assert!(matches!(e, SessionError::UnsupportedVariant { .. }), "{e}");
        assert!(e.to_string().contains("gp16"), "{e}");
        // gups implements it.
        assert!(RunRequest::bench("gups")
            .config_name("cxl-ideal")
            .variant(Variant::GroupPrefetch(16))
            .build()
            .is_ok());
    }

    #[test]
    fn builder_validates_backend() {
        let e = RunRequest::bench("gups").backend("warp9").build().unwrap_err();
        assert!(matches!(e, SessionError::UnknownBackend(_)), "{e}");
        assert!(e.to_string().contains("serial-link"), "{e}");
        for tag in ["serial-link", "pooled", "distribution", "hybrid"] {
            let r = RunRequest::bench("gups").backend(tag).build().unwrap();
            assert_eq!(r.backend_tag(), tag);
        }
        // Default: the config's own backend (serial link).
        let r = RunRequest::bench("gups").build().unwrap();
        assert_eq!(r.backend_tag(), "serial-link");
    }

    #[test]
    fn builder_validates_pool_policy() {
        let e = RunRequest::bench("gups").pool_policy("warp9").build().unwrap_err();
        assert!(matches!(e, SessionError::UnknownPoolPolicy(_)), "{e}");
        assert!(e.to_string().contains("least-loaded"), "{e}");
        for tag in ["hash", "least-loaded", "round-robin"] {
            let r = RunRequest::bench("gups").backend("pooled").pool_policy(tag).build().unwrap();
            assert_eq!(r.pool_policy_tag(), tag);
        }
        // Default: the config's own policy (hash).
        let r = RunRequest::bench("gups").backend("pooled").build().unwrap();
        assert_eq!(r.pool_policy_tag(), "hash");
        assert_eq!(r.config().far.pool_policy, PoolPolicy::Hash);
    }

    #[test]
    fn builder_validates_qos_policy_and_accepts_aliases() {
        let e = RunRequest::bench("gups").qos_policy("warp9").build().unwrap_err();
        assert!(matches!(e, SessionError::UnknownQosPolicy(_)), "{e}");
        assert!(e.to_string().contains("fair-share"), "{e}");
        for (alias, tag) in
            [("fair_share", "fair-share"), ("prio", "priority"), ("rate-limit", "throttle")]
        {
            let r = RunRequest::bench("gups").backend("pooled").qos_policy(alias).build().unwrap();
            assert_eq!(r.qos_policy_tag(), tag, "{alias}");
        }
        // Default: the config's own policy (none).
        let r = RunRequest::bench("gups").build().unwrap();
        assert_eq!(r.qos_policy_tag(), "none");
    }

    #[test]
    fn qos_wrapped_solo_run_still_validates() {
        use crate::stats::schema::ScenarioCol;
        // AMU gups floods the pool (MLP >> 1), so the single-tenant
        // fair-share pacing is guaranteed to bind on some bursts.
        let out = RunRequest::bench("gups")
            .config(SimConfig::amu())
            .backend("pooled")
            .qos_policy("fair-share")
            .latency_ns(500.0)
            .scale(Scale::Test)
            .run()
            .unwrap();
        assert!(out.measured_cycles > 0);
        // The single-tenant wrapper paces the stream at its 100% share;
        // admission delay surfaces through the schema-driven record.
        assert!(
            out.scenario.get(ScenarioCol::PoolStealCycles) > 0,
            "fair-share pacing must register steal cycles: {:?}",
            out.scenario
        );
    }

    #[test]
    fn backend_is_part_of_the_cache_key() {
        let a = RunRequest::bench("gups").backend("pooled").build().unwrap();
        let b = RunRequest::bench("gups").backend("hybrid").build().unwrap();
        assert_ne!(a.key(), b.key());
        assert_eq!(a.key().2, "pooled");
    }

    #[test]
    fn run_result_carries_backend_tag() {
        let r = RunRequest::bench("gups")
            .backend("hybrid")
            .latency_ns(500.0)
            .scale(Scale::Test)
            .run()
            .unwrap();
        assert_eq!(r.backend, "hybrid");
        assert!(r.measured_cycles > 0);
    }

    #[test]
    fn near_capacity_override_applies_and_harvests_scenario_stats() {
        use crate::stats::schema::ScenarioCol;
        let r = RunRequest::bench("gups")
            .backend("hybrid")
            .near_capacity(16)
            .latency_ns(500.0)
            .scale(Scale::Test)
            .build()
            .unwrap();
        assert_eq!(r.config().far.near_capacity_lines, 16);
        let out = r.run().unwrap();
        // The LRU capacity model counts hits/evictions, and the result
        // carries them (the whole point of the schema-driven record).
        let touched = out.scenario.get(ScenarioCol::NearHits)
            + out.scenario.get(ScenarioCol::NearEvictions);
        assert!(touched > 0, "hybrid LRU run must produce scenario stats: {:?}", out.scenario);
        // Default: the config's own capacity (0 = coin-flip model).
        let r = RunRequest::bench("gups").backend("hybrid").build().unwrap();
        assert_eq!(r.config().far.near_capacity_lines, 0);
    }

    #[test]
    fn serial_link_runs_report_zero_scenario_stats() {
        use crate::stats::schema::ScenarioStats;
        let out = RunRequest::bench("gups")
            .latency_ns(300.0)
            .scale(Scale::Test)
            .run()
            .unwrap();
        assert_eq!(out.scenario, ScenarioStats::default());
    }

    #[test]
    fn no_jitter_zeroes_the_jitter_fraction() {
        let r = RunRequest::bench("gups").no_jitter().build().unwrap();
        assert_eq!(r.config().far.jitter_frac, 0.0);
        // It silences the distribution backend's variability too.
        assert_eq!(r.config().far.dist_sigma, 0.0);
        assert_eq!(r.config().far.dist_tail_frac, 0.0);
    }

    #[test]
    fn invalid_config_is_reported_not_panicked() {
        let mut cfg = SimConfig::amu();
        cfg.amu.queue_length = 4096; // AMART metadata exceeds SPM
        let e = RunRequest::bench("gups").config(cfg).build().unwrap_err();
        assert!(matches!(e, SessionError::InvalidConfig(_)));
    }
}
