//! ALSU-side state: list vector registers and the uncommitted-ID-register
//! speculation contract (paper §4.2–4.3).
//!
//! A list vector register is a 512-bit physical vector register holding a
//! pointer plus up to 31 16-bit IDs. ID-management micro-ops pop/push IDs
//! at register speed; only when a register runs empty does the ALSU fetch a
//! batch from the ASMC. Speculative pops are journaled per ROB entry and
//! undone on squash — the timing equivalent of the paper's uncommitted ID
//! register, which guarantees IDs fetched from the ASMC survive
//! mispredictions. DMA-mode shrinks the registers to a single ID and makes
//! ID micro-ops non-speculative, modeling an external engine.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LvrKind {
    Free,
    Finished,
}

#[derive(Debug)]
pub struct Alsu {
    pub free_lvr: Vec<u16>,
    pub fin_lvr: Vec<u16>,
    /// Nominal LVR capacity (31, or 1 in DMA-mode). Squash-undo may
    /// transiently exceed this — that overflow *is* the uncommitted ID
    /// register.
    pub cap: usize,
    pub dma_mode: bool,
    /// Only one outstanding batch fetch until it completes (§4.3 case 3).
    pub batch_busy: bool,
}

impl Alsu {
    pub fn new(cap: usize, dma_mode: bool) -> Self {
        Self {
            free_lvr: Vec::with_capacity(cap * 2),
            fin_lvr: Vec::with_capacity(cap * 2),
            cap: cap.max(1),
            dma_mode,
            batch_busy: false,
        }
    }

    fn lvr(&mut self, kind: LvrKind) -> &mut Vec<u16> {
        match kind {
            LvrKind::Free => &mut self.free_lvr,
            LvrKind::Finished => &mut self.fin_lvr,
        }
    }

    /// Pop an ID for a micro-op; journal the result for squash recovery.
    pub fn pop(&mut self, kind: LvrKind) -> Option<u16> {
        self.lvr(kind).pop()
    }

    /// Undo a speculative pop (squash recovery).
    pub fn unpop(&mut self, kind: LvrKind, id: u16) {
        self.lvr(kind).push(id);
    }

    /// Refill from a delivered ASMC batch.
    pub fn refill(&mut self, kind: LvrKind, ids: &[u16]) {
        self.lvr(kind).extend_from_slice(ids);
    }

    /// Recycle a getfin-returned ID locally if there is register room;
    /// returns false if the caller should send it back to the ASMC.
    pub fn recycle_free(&mut self, id: u16) -> bool {
        if self.free_lvr.len() < self.cap {
            self.free_lvr.push(id);
            true
        } else {
            false
        }
    }

    pub fn ids_resident(&self) -> usize {
        self.free_lvr.len() + self.fin_lvr.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_refill_unpop_roundtrip() {
        let mut a = Alsu::new(31, false);
        assert_eq!(a.pop(LvrKind::Free), None);
        a.refill(LvrKind::Free, &[1, 2, 3]);
        let id = a.pop(LvrKind::Free).unwrap();
        assert_eq!(id, 3);
        a.unpop(LvrKind::Free, id);
        assert_eq!(a.free_lvr.len(), 3);
    }

    #[test]
    fn recycle_respects_capacity() {
        let mut a = Alsu::new(2, false);
        assert!(a.recycle_free(1));
        assert!(a.recycle_free(2));
        assert!(!a.recycle_free(3), "full register: send back to ASMC");
    }

    #[test]
    fn dma_mode_single_entry() {
        let a = Alsu::new(1, true);
        assert_eq!(a.cap, 1);
        assert!(a.dma_mode);
    }

    #[test]
    fn separate_registers() {
        let mut a = Alsu::new(31, false);
        a.refill(LvrKind::Free, &[7]);
        a.refill(LvrKind::Finished, &[9]);
        assert_eq!(a.pop(LvrKind::Finished), Some(9));
        assert_eq!(a.pop(LvrKind::Free), Some(7));
        assert_eq!(a.ids_resident(), 0);
    }
}
