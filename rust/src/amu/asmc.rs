//! ASMC — Asynchronous Scratchpad Memory Controller (paper §4.1, Fig 6).
//!
//! Owns the three SPM-resident metadata structures: the **free list**, the
//! **finished list**, and the **AMART** (Asynchronous Memory Access Request
//! Table, indexed by request ID). Converts committed AMI requests into far
//! memory transfers, splitting >64 B granularities into line-sized
//! sub-requests via a state machine with a bounded pending queue; caches
//! list heads in registers so ID batch transfers run at register speed.
//!
//! Functionally, an `aload` copies far memory -> SPM at completion and an
//! `astore` copies SPM -> far memory when the request is accepted (the data
//! leaves the SPM with the request, like a store buffer read).

use crate::config::AmuConfig;
use crate::isa::mem::GuestMem;
use crate::mem::MemSys;
use crate::stats::Stats;
use std::collections::VecDeque;

/// A committed AMI request from the ALSU.
#[derive(Debug, Clone, Copy)]
pub struct AmiReq {
    pub id: u16,
    pub spm: u64,
    pub mem: u64,
    pub is_store: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchKind {
    Free,
    Finished,
}

/// Handle for an in-flight ALSU<->ASMC batch ID transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchTicket(pub u64);

#[derive(Debug, Clone, Copy, Default)]
struct AmartEntry {
    spm: u64,
    mem: u64,
    gran: u64,
    is_store: bool,
    remaining_subs: u16,
    issued_at: u64,
    active: bool,
}

#[derive(Debug)]
struct PendingBatch {
    ticket: BatchTicket,
    kind: BatchKind,
    cap: usize,
    /// When the request reaches the ASMC (lists are popped here).
    arrive: u64,
    /// When the response reaches the ALSU.
    deliver: u64,
    ids: Option<Vec<u16>>,
}

#[derive(Debug, Clone, Copy)]
struct SubReq {
    id: u16,
    mem: u64,
    bytes: u32,
    is_store: bool,
    sub_idx: u16,
}

const PENDING_QUEUE_DEPTH: usize = 32;

pub struct Asmc {
    cfg: AmuConfig,
    pub granularity: u64,
    pub queue_length: usize,
    free_list: VecDeque<u16>,
    finished_list: VecDeque<u16>,
    amart: Vec<AmartEntry>,
    req_queue: VecDeque<AmiReq>,
    sub_queue: VecDeque<SubReq>,
    batches: Vec<PendingBatch>,
    next_ticket: u64,
    /// Bumped by `set_queue_length` and stamped into sub-request tokens
    /// (bits 24..32) so a completion issued before a reconfiguration can
    /// never be mistaken for one belonging to a recycled AMART id.
    generation: u8,
    /// IDs handed to the ALSU in free batches but not yet in-flight:
    /// conservation invariant bookkeeping only.
    pub ids_at_alsu: usize,
    // Stats.
    pub requests: u64,
    pub subrequests: u64,
    pub completions: u64,
    pub alloc_failures: u64,
    /// Reused scratch for draining `MemSys::asmc_completions` each tick
    /// (batched completion draining without a per-cycle allocation).
    drain_buf: Vec<crate::mem::Completion>,
}

impl Asmc {
    pub fn new(cfg: &AmuConfig) -> Self {
        let ql = cfg.queue_length;
        Self {
            cfg: cfg.clone(),
            granularity: 8,
            queue_length: ql,
            free_list: (1..=ql as u16).collect(),
            finished_list: VecDeque::new(),
            amart: vec![AmartEntry::default(); ql + 1],
            req_queue: VecDeque::new(),
            sub_queue: VecDeque::new(),
            batches: Vec::new(),
            next_ticket: 0,
            generation: 0,
            ids_at_alsu: 0,
            requests: 0,
            subrequests: 0,
            completions: 0,
            alloc_failures: 0,
            drain_buf: Vec::new(),
        }
    }

    /// Earliest future cycle at which the ASMC will act on its own: the
    /// next ID-batch command arrival (pops the free/finished lists) or
    /// response delivery (pollable by the ALSU). Queued requests and
    /// sub-requests don't appear here because a tick with a non-empty queue
    /// always makes progress — the fast-forward fixed-point check prevents
    /// skipping over them.
    pub fn next_event_cycle(&self) -> Option<u64> {
        self.batches
            .iter()
            .map(|b| if b.ids.is_none() { b.arrive } else { b.deliver })
            .min()
    }

    /// Mix everything an idle ASMC tick could structurally change into a
    /// state fingerprint (queue lengths, batch lifecycle, table identity).
    /// Counters are excluded: an ASMC fixed-point tick cannot advance them
    /// (every counter bump coincides with a queue/batch mutation).
    pub fn state_signature(&self, h: &mut crate::util::Mix64) {
        h.mix(self.free_list.len() as u64);
        h.mix(self.finished_list.len() as u64);
        h.mix(self.req_queue.len() as u64);
        h.mix(self.sub_queue.len() as u64);
        h.mix(self.next_ticket);
        h.mix(self.generation as u64);
        h.mix(self.ids_at_alsu as u64);
        h.mix(self.granularity);
        h.mix(self.queue_length as u64);
        h.mix(self.batches.len() as u64);
        for b in &self.batches {
            h.mix(b.ticket.0);
            h.mix(match &b.ids {
                Some(ids) => ids.len() as u64,
                None => u64::MAX,
            });
        }
    }

    /// Reconfigure via `cfgwr` (queue_length reinitializes the metadata).
    pub fn set_granularity(&mut self, g: u64) {
        self.granularity = g.clamp(1, 4096);
    }

    pub fn set_queue_length(&mut self, ql: u64) {
        let ql = (ql as usize).clamp(1, 4096);
        self.queue_length = ql;
        self.free_list = (1..=ql as u16).collect();
        self.finished_list.clear();
        self.amart = vec![AmartEntry::default(); ql + 1];
        self.ids_at_alsu = 0;
        // Reconfiguration discards queued-but-unissued work too: their ids
        // were just recycled into the fresh free list, so issuing them
        // later would alias the ids' new owners.
        self.req_queue.clear();
        self.sub_queue.clear();
        // Same for ID batches already popped from the *old* lists: deliver
        // them empty (the ALSU treats an empty free batch as allocation
        // failure and retries) instead of handing out ids that the fresh
        // free list will give to someone else. Batches that have not yet
        // arrived pop from the new lists and stay valid.
        for b in self.batches.iter_mut() {
            if let Some(ids) = b.ids.as_mut() {
                ids.clear();
            }
        }
        // Invalidate every in-flight sub-request token: ids are recycled
        // immediately, so only the generation distinguishes an old
        // completion from one belonging to the id's new owner.
        self.generation = self.generation.wrapping_add(1);
    }

    pub fn queue_has_space(&self) -> bool {
        self.req_queue.len() < PENDING_QUEUE_DEPTH
    }

    /// Accept a committed AMI request (caller checked `queue_has_space`).
    pub fn push_request(&mut self, req: AmiReq) {
        debug_assert!(self.queue_has_space());
        debug_assert!(req.id as usize <= self.queue_length && req.id != 0);
        self.req_queue.push_back(req);
    }

    /// ALSU requests a batch of IDs. `extra_latency` models DMA-mode uncore
    /// hops. Returns a ticket; poll with [`Asmc::poll_batch`].
    pub fn request_batch(
        &mut self,
        kind: BatchKind,
        cap: usize,
        now: u64,
        extra_latency: u64,
    ) -> BatchTicket {
        self.next_ticket += 1;
        let t = BatchTicket(self.next_ticket);
        let half = self.cfg.asmc_round_trip / 2 + extra_latency;
        self.batches.push(PendingBatch {
            ticket: t,
            kind,
            cap,
            arrive: now + half,
            deliver: now + half * 2,
            ids: None,
        });
        t
    }

    /// Check whether a batch response has arrived at the ALSU; returns the
    /// IDs once `now >= deliver`. Delivered free-list IDs are accounted as
    /// resident at the ALSU until they come back via a request or
    /// [`Asmc::return_ids`].
    pub fn poll_batch(&mut self, ticket: BatchTicket, now: u64) -> Option<Vec<u16>> {
        let idx = self.batches.iter().position(|b| b.ticket == ticket)?;
        if self.batches[idx].ids.is_some() && now >= self.batches[idx].deliver {
            let b = self.batches.swap_remove(idx);
            let ids = b.ids.unwrap();
            // Both free IDs (awaiting allocation) and finished IDs (awaiting
            // getfin, after which they become free again) live at the ALSU.
            self.ids_at_alsu += ids.len();
            return Some(ids);
        }
        None
    }

    /// Deliver any due batch regardless of ticket. Used when the micro-op
    /// that initiated a batch fetch was squashed: the uncommitted-ID
    /// register still captures the delivered IDs so they are not lost
    /// (paper §4.3 case 3).
    pub fn poll_any_batch(&mut self, now: u64) -> Option<(Vec<u16>, super::LvrKind)> {
        let idx = self
            .batches
            .iter()
            .position(|b| b.ids.is_some() && now >= b.deliver)?;
        let b = self.batches.swap_remove(idx);
        let ids = b.ids.unwrap();
        self.ids_at_alsu += ids.len();
        let kind = match b.kind {
            BatchKind::Free => super::LvrKind::Free,
            BatchKind::Finished => super::LvrKind::Finished,
        };
        Some((ids, kind))
    }

    /// Return IDs from the ALSU (squash recovery path / LVR writeback).
    pub fn return_ids(&mut self, ids: &[u16]) {
        for &id in ids {
            debug_assert!(id != 0 && id as usize <= self.queue_length);
            self.free_list.push_back(id);
            self.ids_at_alsu = self.ids_at_alsu.saturating_sub(1);
        }
    }

    /// One ASMC clock: process batch arrivals, accept requests, issue
    /// sub-requests, and retire completions.
    pub fn tick(
        &mut self,
        now: u64,
        mem_sys: &mut MemSys,
        guest: &mut GuestMem,
        stats: &mut Stats,
    ) {
        // 1. Batch requests whose command has arrived: pop the lists.
        for b in self.batches.iter_mut() {
            if b.ids.is_none() && now >= b.arrive {
                let list = match b.kind {
                    BatchKind::Free => &mut self.free_list,
                    BatchKind::Finished => &mut self.finished_list,
                };
                let n = b.cap.min(list.len());
                let ids: Vec<u16> = list.drain(..n).collect();
                if b.kind == BatchKind::Free && ids.is_empty() {
                    self.alloc_failures += 1;
                    stats.amart_full_events += 1;
                }
                stats.id_batch_fetches += 1;
                b.ids = Some(ids);
            }
        }

        // 2. Accept requests into the AMART and split into sub-requests.
        for _ in 0..self.cfg.asmc_ops_per_cycle {
            let Some(req) = self.req_queue.pop_front() else { break };
            self.requests += 1;
            self.ids_at_alsu = self.ids_at_alsu.saturating_sub(1);
            let gran = self.granularity;
            let n_subs = gran.div_ceil(64).max(1) as u16;
            self.amart[req.id as usize] = AmartEntry {
                spm: req.spm,
                mem: req.mem,
                gran,
                is_store: req.is_store,
                remaining_subs: n_subs,
                issued_at: now,
                active: true,
            };
            if req.is_store {
                // Data leaves the SPM with the request.
                guest.copy(req.mem, req.spm, gran as usize);
                stats.astores += 1;
            } else {
                stats.aloads += 1;
            }
            // SPM metadata write cost is covered by the ops/cycle pacing.
            stats.spm_accesses += 1;
            for k in 0..n_subs {
                let off = k as u64 * 64;
                let bytes = (gran - off).min(64) as u32;
                self.sub_queue.push_back(SubReq {
                    id: req.id,
                    mem: req.mem + off,
                    bytes,
                    is_store: req.is_store,
                    sub_idx: k,
                });
            }
        }

        // 3. Issue sub-requests onto the link.
        for _ in 0..self.cfg.asmc_ops_per_cycle {
            let Some(sub) = self.sub_queue.pop_front() else { break };
            self.subrequests += 1;
            stats.amu_subrequests += 1;
            let token = (self.generation as u32) << 24
                | (sub.id as u32) << 8
                | (sub.sub_idx as u32 & 0xff);
            mem_sys.far_direct(sub.is_store, sub.mem, sub.bytes as usize, token, now);
            if sub.is_store {
                stats.far_writes += 1;
            } else {
                stats.far_reads += 1;
            }
            stats.far_bytes += sub.bytes as u64;
        }

        // 4. Retire completed sub-requests (drained in one batch into a
        // reused buffer — no per-cycle allocation).
        self.drain_buf.clear();
        self.drain_buf.append(&mut mem_sys.asmc_completions);
        for i in 0..self.drain_buf.len() {
            let c = self.drain_buf[i];
            let id = ((c.token >> 8) & 0xFFFF) as usize;
            // A completion can outlive its AMART entry: `set_queue_length`
            // reinitializes the table (and may shrink it) while
            // sub-requests are still in flight — and the freed id can be
            // handed to a *new* request before the old completion lands.
            // The generation stamp makes staleness exact; the entry checks
            // are defense in depth. Dropping the stale completion is the
            // only safe move — decrementing `remaining_subs` would wrap in
            // release builds and corrupt an unrelated request.
            let stale = (c.token >> 24) as u8 != self.generation
                || match self.amart.get(id) {
                    Some(e) => !e.active || e.remaining_subs == 0,
                    None => true,
                };
            if stale {
                stats.stale_completions += 1;
                continue;
            }
            let e = &mut self.amart[id];
            e.remaining_subs -= 1;
            if e.remaining_subs == 0 {
                e.active = false;
                if !e.is_store {
                    // aload: data lands in SPM now.
                    let (spm, mem, gran) = (e.spm, e.mem, e.gran);
                    guest.copy(spm, mem, gran as usize);
                }
                self.finished_list.push_back(id as u16);
                self.completions += 1;
                stats.ami_completion_latency.add(now.saturating_sub(e.issued_at));
                stats.spm_accesses += 1;
            }
        }
    }

    // ---- introspection for tests / invariants ----

    pub fn free_len(&self) -> usize {
        self.free_list.len()
    }

    pub fn batches_len(&self) -> usize {
        self.batches.len()
    }

    pub fn finished_len(&self) -> usize {
        self.finished_list.len()
    }

    pub fn inflight_amart(&self) -> usize {
        self.amart.iter().filter(|e| e.active).count()
    }

    /// ID conservation: every ID `1..=queue_length` lives in exactly one
    /// place — the free list, the finished list, an active AMART entry, the
    /// ALSU (list vector registers / popped registers / the request queue,
    /// all covered by `ids_at_alsu`), or an undelivered batch in flight.
    pub fn id_conservation_holds(&self) -> bool {
        let undelivered: usize = self
            .batches
            .iter()
            .map(|b| b.ids.as_ref().map_or(0, |v| v.len()))
            .sum();
        self.free_list.len()
            + self.finished_list.len()
            + self.inflight_amart()
            + self.ids_at_alsu
            + undelivered
            == self.queue_length
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::isa::mem::{FAR_BASE, SPM_BASE};

    struct Rig {
        asmc: Asmc,
        mem: MemSys,
        guest: GuestMem,
        stats: Stats,
    }

    fn rig(latency_ns: f64) -> Rig {
        let mut cfg = SimConfig::amu().with_far_latency_ns(latency_ns);
        cfg.far.jitter_frac = 0.0;
        Rig {
            asmc: Asmc::new(&cfg.amu),
            mem: MemSys::new(&cfg),
            guest: GuestMem::new(),
            stats: Stats::default(),
        }
    }

    fn run(r: &mut Rig, from: u64, to: u64) {
        for c in from..to {
            r.mem.tick(c, 10, 4);
            r.asmc.tick(c, &mut r.mem, &mut r.guest, &mut r.stats);
        }
    }

    #[test]
    fn aload_completes_and_moves_data() {
        let mut r = rig(1000.0);
        r.guest.write_u64(FAR_BASE + 320, 0xFEED);
        r.asmc.push_request(AmiReq { id: 1, spm: SPM_BASE, mem: FAR_BASE + 320, is_store: false });
        run(&mut r, 0, 10_000);
        assert_eq!(r.asmc.finished_len(), 1);
        assert_eq!(r.guest.read_u64(SPM_BASE), 0xFEED);
        assert_eq!(r.stats.aloads, 1);
        assert!(r.stats.ami_completion_latency.mean() >= 3000.0);
    }

    #[test]
    fn astore_moves_data_at_accept_time() {
        let mut r = rig(1000.0);
        r.guest.write_u64(SPM_BASE + 64, 0xBEEF);
        r.asmc.push_request(AmiReq { id: 2, spm: SPM_BASE + 64, mem: FAR_BASE, is_store: true });
        run(&mut r, 0, 5); // just a few cycles: data already moved
        assert_eq!(r.guest.read_u64(FAR_BASE), 0xBEEF);
        // But completion (ack) takes the round trip.
        assert_eq!(r.asmc.finished_len(), 0);
        run(&mut r, 5, 10_000);
        assert_eq!(r.asmc.finished_len(), 1);
    }

    #[test]
    fn large_granularity_splits_into_subrequests() {
        let mut r = rig(1000.0);
        r.asmc.set_granularity(512);
        for i in 0..512u64 {
            r.guest.write(FAR_BASE + i, 1, i & 0xff);
        }
        r.asmc.push_request(AmiReq { id: 3, spm: SPM_BASE, mem: FAR_BASE, is_store: false });
        run(&mut r, 0, 20_000);
        assert_eq!(r.asmc.subrequests, 8, "512B / 64B = 8 sub-requests");
        assert_eq!(r.asmc.finished_len(), 1, "one completion for the whole request");
        for i in 0..512u64 {
            assert_eq!(r.guest.read(SPM_BASE + i, 1), i & 0xff);
        }
    }

    #[test]
    fn stale_completion_after_queue_resize_is_dropped_not_wrapped() {
        // A completion arriving for an AMART entry that `set_queue_length`
        // reinitialized mid-flight used to pass only a debug_assert and
        // then wrap `remaining_subs -= 1` in release builds.
        let mut r = rig(200.0); // 600-cycle RTT: completion lands ~cycle 600
        r.asmc.push_request(AmiReq { id: 1, spm: SPM_BASE, mem: FAR_BASE, is_store: false });
        run(&mut r, 0, 10); // accept + issue the sub-request
        assert_eq!(r.asmc.inflight_amart(), 1);
        // Reconfigure while the sub-request is in flight: the AMART (and
        // its active bits) are reinitialized and id 1 is free again.
        r.asmc.set_queue_length(256);
        assert_eq!(r.asmc.inflight_amart(), 0);
        // Worst case: the freed id is immediately recycled by a new
        // request *before* the old completion lands. The generation stamp
        // must keep the old completion from retiring the new request.
        r.asmc.push_request(AmiReq { id: 1, spm: SPM_BASE + 128, mem: FAR_BASE + 64, is_store: false });
        run(&mut r, 10, 10_000);
        assert_eq!(r.stats.stale_completions, 1, "old-generation completion must be dropped");
        assert_eq!(r.asmc.finished_len(), 1, "the recycled id's own request must finish");
        assert_eq!(r.asmc.inflight_amart(), 0);
        // The ASMC keeps working normally afterwards.
        r.asmc.push_request(AmiReq { id: 2, spm: SPM_BASE, mem: FAR_BASE + 192, is_store: false });
        run(&mut r, 10_000, 30_000);
        assert_eq!(r.asmc.finished_len(), 2);
    }

    #[test]
    fn queue_resize_discards_pending_subrequests() {
        // n_subs > ops_per_cycle leaves sub-requests queued but unissued;
        // a resize must drop them (their ids were just recycled), not
        // issue them later under the new generation against new owners.
        let mut r = rig(200.0);
        r.asmc.set_granularity(512); // 8 sub-requests, 2 issued per cycle
        r.asmc.push_request(AmiReq { id: 1, spm: SPM_BASE, mem: FAR_BASE, is_store: false });
        run(&mut r, 0, 2); // accept + issue only the first few subs
        let issued_before = r.asmc.subrequests;
        assert!(issued_before < 8, "test needs unissued subs ({issued_before})");
        r.asmc.set_queue_length(256);
        r.asmc.set_granularity(8);
        // The recycled id's new request must complete exactly once, and
        // no leftover old sub-requests may be issued.
        r.asmc.push_request(AmiReq { id: 1, spm: SPM_BASE + 64, mem: FAR_BASE + 64, is_store: false });
        run(&mut r, 2, 10_000);
        assert_eq!(r.asmc.subrequests, issued_before + 1, "pending old subs must be dropped");
        assert_eq!(r.stats.stale_completions, issued_before, "old completions all dropped");
        assert_eq!(r.asmc.finished_len(), 1);
        assert_eq!(r.asmc.inflight_amart(), 0);
    }

    #[test]
    fn queue_resize_empties_popped_id_batches() {
        // A free batch whose ids were popped from the OLD free list must
        // deliver empty after a resize — those ids now belong to the new
        // free list and would otherwise be handed out twice.
        let mut r = rig(1000.0);
        let t = r.asmc.request_batch(BatchKind::Free, 8, 0, 0);
        run(&mut r, 0, 20); // command arrived: 8 ids popped into the batch
        r.asmc.set_queue_length(256);
        run(&mut r, 20, 40);
        let ids = r.asmc.poll_batch(t, 40).expect("delivery still happens");
        assert!(ids.is_empty(), "stale batch must deliver empty, got {ids:?}");
        assert!(r.asmc.id_conservation_holds());
    }

    #[test]
    fn free_batch_fetch_roundtrip() {
        let mut r = rig(1000.0);
        let t = r.asmc.request_batch(BatchKind::Free, 31, 0, 0);
        assert!(r.asmc.poll_batch(t, 1).is_none(), "not ready immediately");
        run(&mut r, 0, 30);
        let ids = r.asmc.poll_batch(t, 30).expect("delivered after round trip");
        assert_eq!(ids.len(), 31);
        assert_eq!(r.asmc.free_len(), r.asmc.queue_length - 31);
        // Conservation: 31 at ALSU.
        assert_eq!(r.asmc.ids_at_alsu, 31);
    }

    #[test]
    fn finished_batch_empty_when_nothing_done() {
        let mut r = rig(1000.0);
        let t = r.asmc.request_batch(BatchKind::Finished, 31, 0, 0);
        run(&mut r, 0, 30);
        let ids = r.asmc.poll_batch(t, 30).expect("delivered");
        assert!(ids.is_empty(), "nothing finished yet");
    }

    #[test]
    fn free_exhaustion_reports_alloc_failure() {
        let mut cfg = SimConfig::amu();
        cfg.amu.queue_length = 4;
        let mut r = rig(1000.0);
        r.asmc.set_queue_length(4);
        let t1 = r.asmc.request_batch(BatchKind::Free, 31, 0, 0);
        run(&mut r, 0, 30);
        assert_eq!(r.asmc.poll_batch(t1, 30).unwrap().len(), 4);
        let t2 = r.asmc.request_batch(BatchKind::Free, 31, 30, 0);
        run(&mut r, 30, 60);
        assert!(r.asmc.poll_batch(t2, 60).unwrap().is_empty());
        assert_eq!(r.asmc.alloc_failures, 1);
        drop(cfg);
    }

    #[test]
    fn return_ids_restores_free_list() {
        let mut r = rig(1000.0);
        let t = r.asmc.request_batch(BatchKind::Free, 8, 0, 0);
        run(&mut r, 0, 30);
        let ids = r.asmc.poll_batch(t, 30).unwrap();
        let before = r.asmc.free_len();
        r.asmc.return_ids(&ids);
        assert_eq!(r.asmc.free_len(), before + 8);
        assert_eq!(r.asmc.ids_at_alsu, 0);
    }

    #[test]
    fn pending_queue_backpressure() {
        let mut r = rig(1000.0);
        let mut pushed = 0;
        for id in 1..=64u16 {
            if r.asmc.queue_has_space() {
                r.asmc.push_request(AmiReq {
                    id,
                    spm: SPM_BASE,
                    mem: FAR_BASE + id as u64 * 64,
                    is_store: false,
                });
                pushed += 1;
            }
        }
        assert_eq!(pushed, PENDING_QUEUE_DEPTH, "queue depth enforced");
    }

    #[test]
    fn many_outstanding_requests_supported() {
        // The headline claim: hundreds of in-flight requests with no MSHR
        // involvement.
        let mut r = rig(5000.0);
        for id in 1..=200u16 {
            // Pace pushes with queue space.
            let mut c = (id as u64) * 3;
            loop {
                run(&mut r, c, c + 1);
                if r.asmc.queue_has_space() {
                    break;
                }
                c += 1;
            }
            r.asmc.push_request(AmiReq {
                id,
                spm: SPM_BASE + (id as u64 % 64) * 64,
                mem: FAR_BASE + id as u64 * 4096,
                is_store: false,
            });
        }
        run(&mut r, 700, 2000);
        assert!(
            r.asmc.inflight_amart() > 130,
            "paper headline: >130 outstanding, got {}",
            r.asmc.inflight_amart()
        );
        assert_eq!(r.mem.l1d.misses + r.mem.l2.misses, 0, "no cache resources used");
        run(&mut r, 2000, 100_000);
        assert_eq!(r.asmc.finished_len(), 200);
    }
}
