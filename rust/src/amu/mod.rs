//! AMU — the paper's Asynchronous Memory Access Unit.
//!
//! Split exactly as in the paper (§3.2/§4): the **ALSU** lives in the
//! pipeline and executes AMI micro-ops against *list vector registers*
//! (batched ID transfer, §4.2) with squash-safe speculation (§4.3); the
//! **ASMC** sits beside the L2 controller and owns the SPM-resident
//! metadata — free list, finished list, and the AMART — converting AMI
//! requests into far-memory transfers, splitting large granularities into
//! line-sized sub-requests with a dedicated state machine.

pub mod alsu;
pub mod asmc;

pub use alsu::{Alsu, LvrKind};
pub use asmc::{AmiReq, Asmc, BatchKind, BatchTicket};
