//! Report generators: regenerate every table and figure in the paper's
//! evaluation (§6) from simulation. Each generator prints the same
//! rows/series the paper reports and writes CSV into `results/`.
//!
//! All simulation goes through the typed [`crate::session`] API: the
//! Fig 8/9/10/11 sweep (11 benchmarks x 4 configs x 6 latencies) is a
//! [`SweepGrid`](crate::session::SweepGrid) executed by a parallel
//! [`Session`], shared through the fingerprint-checked on-disk cache
//! (`results/sweep_<scale>.csv`), so the per-figure bench harnesses do not
//! re-simulate. Refined paper grids — a non-default `--backend` or
//! `--pool-policy` — land in fingerprint-suffixed cache files of their
//! own, so regenerating figures per-scenario never clobbers the default
//! sweep. The stringly [`run_one`] / [`sweep_cached`] entry points remain
//! only as deprecated shims.

use crate::config::SimConfig;
use crate::session::metrics::{self, Selection};
use crate::session::{RunRequest, Session, SweepGrid, VariantSel};
use crate::util::geomean;
use crate::workloads::{self, Scale, Variant};
use std::fmt::Write as _;

pub use crate::session::{results_dir, RunResult};

/// Schema-driven sweep CSV: `rows` under a `--columns` [`Selection`].
/// `Selection::Core` reproduces the historical (v3) row layout
/// byte-for-byte; `Selection::Backend`/`All` add the per-backend scenario
/// columns (`near_hits`, `near_evictions`, `pool_congestion`, ...). This
/// is the emission path behind `amu-sim sweep --columns` and
/// `amu-sim report sweep`.
pub fn sweep_csv(rows: &[RunResult], sel: &Selection) -> String {
    let cols = sel.columns();
    let mut s = String::with_capacity(80 * (rows.len() + 1));
    s.push_str(&metrics::csv_header(sel));
    s.push('\n');
    for r in rows {
        s.push_str(&metrics::csv_row_with(&cols, r));
        s.push('\n');
    }
    s
}

/// The paper's four evaluated configurations.
pub const SWEEP_CONFIGS: &[&str] = crate::session::PAPER_CONFIGS;

/// Run one benchmark under one configuration.
#[deprecated(note = "use session::RunRequest — typed, validated, no panics")]
pub fn run_one(
    bench: &str,
    config: &str,
    variant: Variant,
    latency_ns: f64,
    scale: Scale,
) -> Result<RunResult, String> {
    RunRequest::bench(bench)
        .config_name(config)
        .variant(variant)
        .latency_ns(latency_ns)
        .scale(scale)
        .run()
        .map_err(|e| e.to_string())
}

/// The shared Fig 8/9/10/11 sweep, cached in `results/`.
#[deprecated(note = "use session::Session::sweep_paper — parallel and non-panicking")]
pub fn sweep_cached(scale: Scale, quiet: bool) -> Vec<RunResult> {
    Session::new()
        .quiet(quiet)
        .sweep_paper(scale)
        .unwrap_or_else(|e| panic!("sweep failed: {e}"))
}

fn find<'a>(
    rows: &'a [RunResult],
    bench: &str,
    config: &str,
    lat: f64,
) -> Option<&'a RunResult> {
    rows.iter()
        .find(|r| r.bench == bench && r.config == config && r.latency_ns == lat)
}

/// Like [`find`], but also matching the variant tag (for grids that sweep
/// the variant axis).
fn find_v<'a>(
    rows: &'a [RunResult],
    bench: &str,
    config: &str,
    lat: f64,
    variant: &str,
) -> Option<&'a RunResult> {
    rows.iter().find(|r| {
        r.bench == bench && r.config == config && r.latency_ns == lat && r.variant == variant
    })
}

/// Run a generator grid through the session with the grid's own
/// fingerprint-keyed cache file: every distinct grid gets a distinct
/// `results/sweep_<scale>_<fp>.csv`, so fig3/table4/table5 resume across
/// invocations without clobbering each other or the paper sweep.
fn sweep_grid_cached(session: &Session, grid: &SweepGrid, what: &str) -> Vec<RunResult> {
    session
        .clone()
        .cache_path(Session::default_cache_path(grid))
        .sweep(grid)
        .unwrap_or_else(|e| panic!("{what} sweep failed: {e}"))
}

/// Baseline-at-100ns normalization denominator for one benchmark.
fn norm_base(rows: &[RunResult], bench: &str) -> f64 {
    find(rows, bench, "baseline", 100.0)
        .map(|r| r.measured_cycles as f64)
        .unwrap_or(1.0)
}

/// Title suffix naming the far-memory backend when the rows were produced
/// under a non-default one (the figures can be regenerated per-backend via
/// `amu-sim report <fig> --backend <tag>`). The generators key rows by
/// `(bench, config, latency)` and expect a single-backend row set; a mixed
/// set is flagged in the title rather than silently rendering whichever
/// backend sorts first.
fn backend_note(rows: &[RunResult]) -> String {
    let mut backends: Vec<&str> = rows.iter().map(|r| r.backend.as_str()).collect();
    backends.sort_unstable();
    backends.dedup();
    match backends.as_slice() {
        [] | ["serial-link"] => String::new(),
        [one] => format!(" [backend={one}]"),
        many => format!(" [WARNING: mixed backends {}; rows may be misattributed]", many.join("+")),
    }
}

// ---------------------------------------------------------------- figures

/// Fig 2: baseline slowdown vs far-memory latency (motivation).
pub fn fig2(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(s, "# Fig 2 — baseline slowdown vs far-memory latency{}", backend_note(rows))
        .unwrap();
    write!(s, "{:>8}", "lat(us)").unwrap();
    for b in workloads::ALL {
        write!(s, "{b:>9}").unwrap();
    }
    writeln!(s).unwrap();
    for &lat in SimConfig::paper_latencies_ns() {
        write!(s, "{:>8.1}", lat / 1000.0).unwrap();
        for b in workloads::ALL {
            let base = norm_base(rows, b);
            let v = find(rows, b, "baseline", lat)
                .map(|r| r.measured_cycles as f64 / base)
                .unwrap_or(f64::NAN);
            write!(s, "{v:>9.2}").unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

/// Fig 8: normalized execution time per benchmark / config / latency.
pub fn fig8(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Fig 8 — normalized execution time (lower is better; norm = baseline @0.1us){}",
        backend_note(rows)
    )
    .unwrap();
    for b in workloads::ALL {
        writeln!(s, "\n## {b}").unwrap();
        write!(s, "{:>10}", "lat(us)").unwrap();
        for c in SWEEP_CONFIGS {
            write!(s, "{c:>11}").unwrap();
        }
        writeln!(s).unwrap();
        let base = norm_base(rows, b);
        for &lat in SimConfig::paper_latencies_ns() {
            write!(s, "{:>10.1}", lat / 1000.0).unwrap();
            for c in SWEEP_CONFIGS {
                let v = find(rows, b, c, lat)
                    .map(|r| r.measured_cycles as f64 / base)
                    .unwrap_or(f64::NAN);
                write!(s, "{v:>11.3}").unwrap();
            }
            writeln!(s).unwrap();
        }
    }
    s
}

/// Fig 9 (MLP) / Fig 10 (IPC) share a formatter.
fn metric_table(rows: &[RunResult], title: &str, f: impl Fn(&RunResult) -> f64) -> String {
    let mut s = String::new();
    writeln!(s, "# {title}{}", backend_note(rows)).unwrap();
    for b in workloads::ALL {
        writeln!(s, "\n## {b}").unwrap();
        write!(s, "{:>10}", "lat(us)").unwrap();
        for c in SWEEP_CONFIGS {
            write!(s, "{c:>11}").unwrap();
        }
        writeln!(s).unwrap();
        for &lat in SimConfig::paper_latencies_ns() {
            write!(s, "{:>10.1}", lat / 1000.0).unwrap();
            for c in SWEEP_CONFIGS {
                let v = find(rows, b, c, lat).map(&f).unwrap_or(f64::NAN);
                write!(s, "{v:>11.2}").unwrap();
            }
            writeln!(s).unwrap();
        }
    }
    s
}

pub fn fig9(rows: &[RunResult]) -> String {
    metric_table(rows, "Fig 9 — MLP (average in-flight far-memory requests)", |r| r.mlp)
}

pub fn fig10(rows: &[RunResult]) -> String {
    metric_table(rows, "Fig 10 — IPC", |r| r.ipc)
}

/// Fig 11: energy normalized to baseline @0.1us, split static/dynamic.
/// (The paper's "power consumption" bars shrink when runtime shrinks —
/// i.e. they are run energy with a static component proportional to time.)
pub fn fig11(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Fig 11 — normalized energy (static+dynamic; norm = baseline @0.1us){}",
        backend_note(rows)
    )
    .unwrap();
    writeln!(s, "{:>8} {:>10} {:>12} {:>10} {:>10} {:>10}", "bench", "config", "lat(us)", "static", "dynamic", "total").unwrap();
    for b in workloads::ALL {
        let base = find(rows, b, "baseline", 100.0)
            .map(|r| r.total_uj())
            .unwrap_or(1.0);
        for c in SWEEP_CONFIGS {
            for &lat in [500.0, 1000.0].iter() {
                if let Some(r) = find(rows, b, c, lat) {
                    let st = r.static_uj / base;
                    let dy = r.dynamic_uj / base;
                    writeln!(
                        s,
                        "{:>8} {:>10} {:>12.1} {:>10.3} {:>10.3} {:>10.3}",
                        b,
                        c,
                        lat / 1000.0,
                        st,
                        dy,
                        st + dy
                    )
                    .unwrap();
                }
            }
        }
    }
    // Paper's headline geomeans: AMU/baseline power at 0.5us and 1us.
    for &lat in [500.0, 1000.0].iter() {
        let ratios: Vec<f64> = workloads::ALL
            .iter()
            .filter_map(|b| {
                let amu = find(rows, b, "amu", lat)?;
                let base = find(rows, b, "baseline", lat)?;
                Some(amu.total_uj() / base.total_uj())
            })
            .collect();
        if let Some(g) = geomean(&ratios) {
            writeln!(s, "\ngeomean AMU/baseline energy @{}us = {g:.2}", lat / 1000.0).unwrap();
        }
    }
    s
}

/// Fig 3: GUPS group-prefetch sensitivity across hardware scaling.
pub fn fig3(session: &Session, scale: Scale, latency_ns: f64) -> String {
    let groups = [2usize, 4, 8, 16, 32, 64, 128];
    let configs = ["cxl-ideal", "x2", "x4"];
    let mut variants = vec![VariantSel::Fixed(Variant::Sync)];
    variants.extend(groups.iter().map(|&g| VariantSel::Fixed(Variant::GroupPrefetch(g))));
    let grid = SweepGrid::new(scale)
        .benches(["gups"])
        .configs(configs)
        .latencies_ns([latency_ns])
        .variants(variants);
    let rows = sweep_grid_cached(session, &grid, "fig3");
    let mut s = String::new();
    writeln!(
        s,
        "# Fig 3 — GUPS with group prefetching vs group size (latency {}ns)",
        latency_ns
    )
    .unwrap();
    write!(s, "{:>10}", "group").unwrap();
    for c in configs {
        write!(s, "{c:>12}").unwrap();
    }
    writeln!(s, "{:>12}", "(cycles)").unwrap();
    // Baseline bars: plain GUPS per config.
    write!(s, "{:>10}", "none").unwrap();
    for c in configs {
        let r = find_v(&rows, "gups", c, latency_ns, "sync").expect("sync row");
        write!(s, "{:>12}", r.measured_cycles).unwrap();
    }
    writeln!(s).unwrap();
    for g in groups {
        write!(s, "{g:>10}").unwrap();
        let tag = Variant::GroupPrefetch(g).tag();
        for c in configs {
            let r = find_v(&rows, "gups", c, latency_ns, &tag).expect("gp row");
            write!(s, "{:>12}", r.measured_cycles).unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

/// Table 4: baseline vs best software prefetch vs AMU vs LLVM-AMU for
/// GUPS / HJ / STREAM. Benchmarks without a software-prefetch port (HJ)
/// report their sync run as `PF(best)` with `pf-cfg 0` — the previous
/// generator ran sync four times and labeled the rows `gp2..gp128`.
pub fn table4(session: &Session, scale: Scale) -> String {
    let benches = ["gups", "hj", "stream"];
    let pf_groups = [2usize, 8, 32, 128];
    let mut s = String::new();
    writeln!(s, "# Table 4 — normalized execution time (norm = cxl-ideal @0.1us per bench)").unwrap();
    writeln!(
        s,
        "{:>8} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "bench", "lat(us)", "CXL", "PF(best)", "pf-cfg", "AMU", "LLVM-AMU"
    )
    .unwrap();
    for b in benches {
        let pf_variant = |g: usize| {
            if b == "stream" {
                Variant::SwPrefetch { batch: g, depth: 0 }
            } else {
                Variant::GroupPrefetch(g)
            }
        };
        let has_pf_port = crate::session::registry::find(b)
            .map(|w| w.supported_variants().contains(&pf_variant(2).kind()))
            .unwrap_or(false);
        let mut cxl_variants = vec![VariantSel::Fixed(Variant::Sync)];
        if has_pf_port {
            cxl_variants.extend(pf_groups.iter().map(|&g| VariantSel::Fixed(pf_variant(g))));
        }
        let cxl_grid = SweepGrid::new(scale)
            .benches([b])
            .configs(["cxl-ideal"])
            .latencies_ns(SimConfig::paper_latencies_ns().iter().copied())
            .variants(cxl_variants);
        let amu_grid = SweepGrid::new(scale)
            .benches([b])
            .configs(["amu"])
            .latencies_ns(SimConfig::paper_latencies_ns().iter().copied())
            .variants([
                VariantSel::Fixed(Variant::Amu),
                VariantSel::Fixed(Variant::AmuLlvm),
            ]);
        let mut rows = sweep_grid_cached(session, &cxl_grid, "table4");
        rows.extend(sweep_grid_cached(session, &amu_grid, "table4"));
        let base = find_v(&rows, b, "cxl-ideal", 100.0, "sync")
            .expect("norm row")
            .measured_cycles as f64;
        for &lat in SimConfig::paper_latencies_ns() {
            let cxl = find_v(&rows, b, "cxl-ideal", lat, "sync").expect("cxl row");
            let mut best_pf = cxl.measured_cycles as f64;
            let mut best_cfg = 0usize;
            if has_pf_port {
                for &g in &pf_groups {
                    let tag = pf_variant(g).tag();
                    let r = find_v(&rows, b, "cxl-ideal", lat, &tag).expect("pf row");
                    if (r.measured_cycles as f64) < best_pf {
                        best_pf = r.measured_cycles as f64;
                        best_cfg = g;
                    }
                }
            }
            let amu = find_v(&rows, b, "amu", lat, "amu").expect("amu row");
            let llvm = find_v(&rows, b, "amu", lat, "llvm").expect("llvm row");
            writeln!(
                s,
                "{:>8} {:>8.1} {:>10.2} {:>10.2} {:>10} {:>10.2} {:>10.2}",
                b,
                lat / 1000.0,
                cxl.measured_cycles as f64 / base,
                best_pf / base,
                best_cfg,
                amu.measured_cycles as f64 / base,
                llvm.measured_cycles as f64 / base,
            )
            .unwrap();
        }
    }
    s
}

/// Table 5: % of execution time spent on software disambiguation (HJ, HT).
pub fn table5(session: &Session, scale: Scale) -> String {
    let grid = SweepGrid::new(scale)
        .benches(["hj", "ht"])
        .configs(["amu"])
        .latencies_ns(SimConfig::paper_latencies_ns().iter().copied())
        .variant(Variant::Amu);
    let rows = sweep_grid_cached(session, &grid, "table5");
    let mut s = String::new();
    writeln!(s, "# Table 5 — execution time share of software disambiguation").unwrap();
    write!(s, "{:>8}", "bench").unwrap();
    for &lat in SimConfig::paper_latencies_ns() {
        write!(s, "{:>9.1}", lat / 1000.0).unwrap();
    }
    writeln!(s, "   (us columns)").unwrap();
    for b in ["hj", "ht"] {
        write!(s, "{b:>8}").unwrap();
        for &lat in SimConfig::paper_latencies_ns() {
            let r = find_v(&rows, b, "amu", lat, "amu").expect("amu row");
            write!(s, "{:>8.2}%", r.disambig_frac * 100.0).unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

/// Table 6: hardware resource overhead vs NanHu-G.
pub fn table6() -> String {
    let t = crate::area::table6(&crate::area::NanhuBase::default());
    let mut s = String::new();
    writeln!(s, "# Table 6 — resource utilization vs NanHu-G").unwrap();
    writeln!(
        s,
        "LUT(logic) +{:.1}%  LUT(mem) +{:.1}%  FF +{:.1}%  BRAM +{:.0}%  URAM +{:.0}%",
        t.lut_logic_pct, t.lut_mem_pct, t.ff_pct, t.bram_pct, t.uram_pct
    )
    .unwrap();
    writeln!(s, "ASIC: {:.0} gates, area +{:.2}%", t.asic_gates, t.asic_area_pct).unwrap();
    writeln!(
        s,
        "AMU storage overhead: {:.1} KB (independent of required MLP)",
        crate::area::storage_overhead_bytes() as f64 / 1024.0
    )
    .unwrap();
    s
}

/// Headline numbers (abstract / §6.3).
pub fn headline(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(s, "# Headline reproduction{}", backend_note(rows)).unwrap();
    // Mean speedup of AMU over baseline at 1us across memory-bound suite.
    let speedups: Vec<f64> = workloads::ALL
        .iter()
        .filter_map(|b| {
            let amu = find(rows, b, "amu", 1000.0)?;
            let base = find(rows, b, "baseline", 1000.0)?;
            Some(base.measured_cycles as f64 / amu.measured_cycles as f64)
        })
        .collect();
    if let Some(g) = geomean(&speedups) {
        writeln!(
            s,
            "geomean AMU speedup @1us over baseline: {g:.2}x (paper: 2.42x)"
        )
        .unwrap();
    }
    if let (Some(amu), Some(base)) = (
        find(rows, "gups", "amu", 5000.0),
        find(rows, "gups", "baseline", 5000.0),
    ) {
        writeln!(
            s,
            "GUPS @5us: {:.2}x speedup (paper: 26.86x); peak in-flight {} (paper: >130)",
            base.measured_cycles as f64 / amu.measured_cycles as f64,
            amu.peak_inflight
        )
        .unwrap();
        writeln!(s, "GUPS @5us avg MLP: {:.1}", amu.mlp).unwrap();
    }
    s
}

/// `report mt` — the multi-tenant fairness table. One block per QoS
/// policy: per-tenant throughput (committed instructions per kilocycle of
/// shared-pool time), slowdown vs a solo run of the same benchmark on a
/// private backend, and the noisy-neighbor delta (how much of the run the
/// tenant lost to co-scheduling). The pool-wide arbitration counters
/// (`qos_throttle_events`, `pool_steal_cycles`) close each block.
pub fn mt_table(outcomes: &[crate::session::MtOutcome]) -> String {
    use crate::stats::schema::ScenarioCol;
    let mut s = String::new();
    writeln!(s, "# Multi-tenant fairness — slowdown vs solo run on a private backend").unwrap();
    for o in outcomes {
        writeln!(s, "\n## qos={}", o.policy.tag()).unwrap();
        writeln!(
            s,
            "{:>10} {:>7} {:>6} {:>8} {:>12} {:>12} {:>10} {:>10} {:>12}",
            "tenant", "weight", "class", "cycles", "solo_cycles", "slowdown", "neighbor", "ipc", "insts/kcyc"
        )
        .unwrap();
        for r in &o.rows {
            let slowdown = r.slowdown_permille as f64 / 1000.0;
            // Noisy-neighbor delta: the share of the co-scheduled run the
            // tenant spent beyond its solo time.
            let neighbor = (slowdown - 1.0).max(0.0) * 100.0;
            let kcyc = (r.result.measured_cycles as f64 / 1000.0).max(f64::MIN_POSITIVE);
            writeln!(
                s,
                "{:>10} {:>7} {:>6} {:>8} {:>12} {:>11.2}x {:>9.1}% {:>10.3} {:>12.1}",
                r.label,
                r.weight,
                r.class.tag(),
                r.result.measured_cycles,
                r.solo_cycles,
                slowdown,
                neighbor,
                r.result.ipc,
                r.result.insts as f64 / kcyc,
            )
            .unwrap();
        }
        if let Some(r) = o.rows.first() {
            writeln!(
                s,
                "pool: slowdown_max {:.2}x, throttle_events {}, steal_cycles {}",
                r.result.scenario.get(ScenarioCol::TenantSlowdownMax) as f64 / 1000.0,
                r.result.scenario.get(ScenarioCol::QosThrottleEvents),
                r.result.scenario.get(ScenarioCol::PoolStealCycles),
            )
            .unwrap();
        }
    }
    s
}

/// `amu-sim check` diagnostics table: one section per checked program
/// showing findings at or above `min` severity, then a one-line summary.
/// The row format is golden-pinned in `rust/tests/verify.rs`.
pub fn check_table(
    outcomes: &[(String, crate::isa::VerifyReport)],
    min: crate::isa::Severity,
) -> String {
    use crate::isa::Severity;
    let mut s = String::new();
    let (mut deny, mut warn, mut info) = (0usize, 0usize, 0usize);
    for (label, rep) in outcomes {
        deny += rep.deny_count();
        warn += rep.warn_count();
        info += rep.count(Severity::Info);
        let shown = rep.diags.iter().filter(|d| d.severity() >= min).count();
        if shown == 0 {
            let hidden = rep.diags.len();
            if hidden == 0 {
                writeln!(s, "{label}: {} insts, clean", rep.insts).unwrap();
            } else {
                writeln!(
                    s,
                    "{label}: {} insts, clean ({hidden} info note(s); --verbose to show)",
                    rep.insts
                )
                .unwrap();
            }
        } else {
            writeln!(s, "{label}: {} insts, {shown} finding(s)", rep.insts).unwrap();
            s.push_str(&rep.render_table(min));
        }
    }
    writeln!(
        s,
        "checked {} program(s): {deny} deny, {warn} warn, {info} info",
        outcomes.len()
    )
    .unwrap();
    s
}

/// `amu-sim check --format json`: the machine-readable diagnostics
/// envelope. Hand-rolled (the crate carries no JSON dependency) and fully
/// deterministic: same programs in, byte-identical text out. The
/// per-diagnostic field set (code/severity/index/label/message) and the
/// `schema_version` are a stable contract, golden-pinned in
/// `rust/tests/golden/verify_check.json` and grepped by the CI lint job.
pub fn check_json(outcomes: &[(String, crate::isa::VerifyReport)]) -> String {
    use crate::isa::Severity;
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema_version\": 1,\n");
    if outcomes.is_empty() {
        s.push_str("  \"programs\": [],\n");
    } else {
        s.push_str("  \"programs\": [\n");
        for (k, (label, rep)) in outcomes.iter().enumerate() {
            s.push_str(&rep.render_json(label));
            s.push_str(if k + 1 < outcomes.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ],\n");
    }
    let deny: usize = outcomes.iter().map(|(_, r)| r.deny_count()).sum();
    let warn: usize = outcomes.iter().map(|(_, r)| r.warn_count()).sum();
    let info: usize = outcomes.iter().map(|(_, r)| r.count(Severity::Info)).sum();
    s.push_str("  \"totals\": {\n");
    s.push_str(&format!("    \"programs\": {},\n", outcomes.len()));
    s.push_str(&format!("    \"deny\": {deny},\n"));
    s.push_str(&format!("    \"warn\": {warn},\n"));
    s.push_str(&format!("    \"info\": {info}\n"));
    s.push_str("  }\n");
    s.push_str("}\n");
    s
}

/// `amu-sim check --format sarif`: SARIF 2.1.0 for code-scanning UIs. One
/// run; every `AMIxxx` code is a rule, every finding a result whose
/// logical location is `<program label>@<instruction index>`.
pub fn check_sarif(outcomes: &[(String, crate::isa::VerifyReport)]) -> String {
    use crate::isa::verify::{json_escape, ALL_CODES};
    use crate::isa::Severity;
    let level = |sev: Severity| match sev {
        Severity::Deny => "error",
        Severity::Warn => "warning",
        Severity::Info => "note",
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"$schema\": \"https://json.schemastore.org/sarif-2.1.0.json\",\n");
    s.push_str("  \"version\": \"2.1.0\",\n");
    s.push_str("  \"runs\": [\n    {\n");
    s.push_str("      \"tool\": {\n        \"driver\": {\n");
    s.push_str("          \"name\": \"amu-sim check\",\n");
    s.push_str("          \"rules\": [\n");
    for (k, code) in ALL_CODES.iter().enumerate() {
        s.push_str("            {\n");
        s.push_str(&format!("              \"id\": \"{}\",\n", code.tag()));
        s.push_str(&format!(
            "              \"shortDescription\": {{ \"text\": \"{}\" }},\n",
            json_escape(code.meaning())
        ));
        s.push_str(&format!(
            "              \"defaultConfiguration\": {{ \"level\": \"{}\" }}\n",
            level(code.severity())
        ));
        s.push_str(if k + 1 < ALL_CODES.len() { "            },\n" } else { "            }\n" });
    }
    s.push_str("          ]\n        }\n      },\n");
    let nresults: usize = outcomes.iter().map(|(_, r)| r.diags.len()).sum();
    if nresults == 0 {
        s.push_str("      \"results\": []\n");
    } else {
        s.push_str("      \"results\": [\n");
        let mut k = 0usize;
        for (label, rep) in outcomes {
            for d in &rep.diags {
                k += 1;
                s.push_str("        {\n");
                s.push_str(&format!("          \"ruleId\": \"{}\",\n", d.code.tag()));
                s.push_str(&format!("          \"level\": \"{}\",\n", level(d.severity())));
                s.push_str(&format!(
                    "          \"message\": {{ \"text\": \"{}\" }},\n",
                    json_escape(&d.message)
                ));
                s.push_str("          \"locations\": [\n");
                s.push_str("            {\n              \"logicalLocations\": [\n");
                s.push_str(&format!(
                    "                {{ \"name\": \"{}\", \"fullyQualifiedName\": \"{}@{}\" }}\n",
                    json_escape(if d.label.is_empty() { "-" } else { &d.label }),
                    json_escape(label),
                    d.at
                ));
                s.push_str("              ]\n            }\n          ]\n");
                s.push_str(if k < nresults { "        },\n" } else { "        }\n" });
            }
        }
        s.push_str("      ]\n");
    }
    s.push_str("    }\n  ]\n}\n");
    s
}

pub fn write_report(name: &str, body: &str) {
    let path = results_dir().join(format!("{name}.txt"));
    std::fs::write(&path, body).ok();
    println!("{body}");
    eprintln!("[report] wrote {}", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(deprecated)]
    fn run_one_shim_still_produces_metrics() {
        let r = run_one("gups", "baseline", Variant::Sync, 200.0, Scale::Test).unwrap();
        assert!(r.measured_cycles > 0);
        assert!(r.ipc > 0.0);
        assert!(r.dynamic_uj > 0.0);
    }

    #[test]
    #[allow(deprecated)]
    fn run_one_shim_reports_errors_instead_of_panicking() {
        let e = run_one("nope", "baseline", Variant::Sync, 200.0, Scale::Test).unwrap_err();
        assert!(e.contains("unknown benchmark"), "{e}");
        let e = run_one("gups", "warp9", Variant::Sync, 200.0, Scale::Test).unwrap_err();
        assert!(e.contains("unknown config"), "{e}");
    }

    #[test]
    fn sweep_csv_selects_columns_consistently() {
        use crate::session::SweepGrid;
        let grid = SweepGrid::new(Scale::Test)
            .benches(["gups"])
            .configs(["baseline"])
            .latencies_ns([300.0])
            .backends(["hybrid"])
            .near_capacity(64);
        let rows = Session::new().quiet(true).without_cache().sweep(&grid).unwrap();
        let core = sweep_csv(&rows, &Selection::Core);
        let all = sweep_csv(&rows, &Selection::All);
        let backend = sweep_csv(&rows, &Selection::Backend);
        // Core is the v3 layout; all extends it; shared columns agree.
        for (c, a) in core.lines().zip(all.lines()) {
            assert!(a.starts_with(c), "core row must prefix all row:\n{c}\n{a}");
        }
        assert!(all.lines().next().unwrap().contains("near_hits"));
        assert!(backend.lines().next().unwrap().contains("pool_congestion"));
        // The hybrid LRU run actually populates the scenario columns.
        let data = backend.lines().nth(1).unwrap();
        let last: Vec<&str> = data.split(',').collect();
        let near_hits: u64 = last[5].parse().unwrap();
        let near_evictions: u64 = last[6].parse().unwrap();
        assert!(near_hits + near_evictions > 0, "{data}");
    }

    #[test]
    fn mt_table_renders_per_tenant_rows_and_pool_counters() {
        use crate::config::QosPolicyKind;
        use crate::mem::backend::QosClass;
        use crate::session::{MtOutcome, MtRow};
        use crate::stats::schema::{ScenarioCol, ScenarioStats};
        let result = RunResult {
            bench: "gups#0".into(),
            measured_cycles: 3000,
            insts: 1500,
            ipc: 0.5,
            scenario: ScenarioStats::default()
                .with(ScenarioCol::TenantSlowdownMax, 1500)
                .with(ScenarioCol::PoolStealCycles, 42),
            ..Default::default()
        };
        let o = MtOutcome {
            policy: QosPolicyKind::FairShare,
            rows: vec![MtRow {
                policy: QosPolicyKind::FairShare,
                label: "gups#0".into(),
                bench: "gups".into(),
                weight: 2,
                class: QosClass::Normal,
                solo_cycles: 2000,
                slowdown_permille: 1500,
                result,
            }],
        };
        let t = mt_table(&[o]);
        assert!(t.contains("qos=fair-share"), "{t}");
        assert!(t.contains("gups#0"), "{t}");
        assert!(t.contains("1.50x"), "{t}");
        assert!(t.contains("slowdown_max 1.50x"), "{t}");
        assert!(t.contains("steal_cycles 42"), "{t}");
    }

    #[test]
    fn table6_report_renders() {
        let t = table6();
        assert!(t.contains("LUT"));
        assert!(t.contains("71510") || t.contains("71,510") || t.contains("gates"));
    }
}
