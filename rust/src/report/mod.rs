//! Report generators: regenerate every table and figure in the paper's
//! evaluation (§6) from simulation. Each generator prints the same
//! rows/series the paper reports and writes CSV into `results/`.
//!
//! The Fig 8/9/10/11 sweep (11 benchmarks x 4 configs x 6 latencies) is
//! shared through an on-disk cache (`results/sweep_<scale>.csv`), so the
//! per-figure bench harnesses do not re-simulate.

use crate::config::SimConfig;
use crate::power::{estimate, EnergyModel, PowerBreakdown};
use crate::util::geomean;
use crate::workloads::{self, Scale, Variant};
use std::fmt::Write as _;
use std::path::PathBuf;

#[derive(Debug, Clone, PartialEq)]
pub struct RunResult {
    pub bench: String,
    pub config: String,
    pub variant: String,
    pub latency_ns: f64,
    pub measured_cycles: u64,
    pub total_cycles: u64,
    pub insts: u64,
    pub ipc: f64,
    pub mlp: f64,
    pub peak_inflight: u64,
    pub dynamic_uj: f64,
    pub static_uj: f64,
    pub disambig_frac: f64,
    pub host_ms: u64,
}

impl RunResult {
    pub fn power(&self) -> PowerBreakdown {
        PowerBreakdown { dynamic_uj: self.dynamic_uj, static_uj: self.static_uj }
    }
}

pub fn results_dir() -> PathBuf {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&d).ok();
    d
}

fn config_by_name(name: &str, latency_ns: f64) -> SimConfig {
    SimConfig::preset(name)
        .unwrap_or_else(|| panic!("unknown config '{name}'"))
        .with_far_latency_ns(latency_ns)
}

/// Run one benchmark under one configuration.
pub fn run_one(
    bench: &str,
    config: &str,
    variant: Variant,
    latency_ns: f64,
    scale: Scale,
) -> Result<RunResult, String> {
    let cfg = config_by_name(config, latency_ns);
    let spec = workloads::build(bench, &cfg, variant, scale);
    let t0 = std::time::Instant::now();
    let sim = spec.run(&cfg)?;
    let host_ms = t0.elapsed().as_millis() as u64;
    let p = estimate(&cfg, &sim.stats, &EnergyModel::default());
    Ok(RunResult {
        bench: bench.into(),
        config: config.into(),
        variant: variant.tag(),
        latency_ns,
        measured_cycles: sim.stats.measured_cycles.max(1),
        total_cycles: sim.cycle,
        insts: sim.stats.insts_committed,
        ipc: sim.stats.ipc(),
        mlp: sim.stats.mlp(),
        peak_inflight: sim.stats.far_inflight.max,
        dynamic_uj: p.dynamic_uj,
        static_uj: p.static_uj,
        disambig_frac: sim.stats.region_fraction(crate::stats::Region::Disambig),
        host_ms,
    })
}

pub const SWEEP_CONFIGS: &[&str] = &["baseline", "cxl-ideal", "amu", "amu-dma"];

fn scale_tag(scale: Scale) -> &'static str {
    match scale {
        Scale::Test => "test",
        Scale::Paper => "paper",
    }
}

const CSV_HEADER: &str = "bench,config,variant,latency_ns,measured_cycles,total_cycles,\
insts,ipc,mlp,peak_inflight,dynamic_uj,static_uj,disambig_frac,host_ms";

fn to_csv_row(r: &RunResult) -> String {
    format!(
        "{},{},{},{},{},{},{},{:.6},{:.4},{},{:.6},{:.6},{:.6},{}",
        r.bench,
        r.config,
        r.variant,
        r.latency_ns,
        r.measured_cycles,
        r.total_cycles,
        r.insts,
        r.ipc,
        r.mlp,
        r.peak_inflight,
        r.dynamic_uj,
        r.static_uj,
        r.disambig_frac,
        r.host_ms
    )
}

fn parse_csv(text: &str) -> Option<Vec<RunResult>> {
    let mut out = Vec::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 14 {
            return None;
        }
        out.push(RunResult {
            bench: f[0].into(),
            config: f[1].into(),
            variant: f[2].into(),
            latency_ns: f[3].parse().ok()?,
            measured_cycles: f[4].parse().ok()?,
            total_cycles: f[5].parse().ok()?,
            insts: f[6].parse().ok()?,
            ipc: f[7].parse().ok()?,
            mlp: f[8].parse().ok()?,
            peak_inflight: f[9].parse().ok()?,
            dynamic_uj: f[10].parse().ok()?,
            static_uj: f[11].parse().ok()?,
            disambig_frac: f[12].parse().ok()?,
            host_ms: f[13].parse().ok()?,
        });
    }
    Some(out)
}

/// The shared Fig 8/9/10/11 sweep, cached in `results/`.
pub fn sweep_cached(scale: Scale, quiet: bool) -> Vec<RunResult> {
    let path = results_dir().join(format!("sweep_{}.csv", scale_tag(scale)));
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Some(rows) = parse_csv(&text) {
            let expected =
                workloads::ALL.len() * SWEEP_CONFIGS.len() * SimConfig::paper_latencies_ns().len();
            if rows.len() == expected {
                if !quiet {
                    eprintln!("[sweep] using cached {}", path.display());
                }
                return rows;
            }
        }
    }
    let mut rows = Vec::new();
    for bench in workloads::ALL {
        for config in SWEEP_CONFIGS {
            for &lat in SimConfig::paper_latencies_ns() {
                let cfg = config_by_name(config, lat);
                let variant = workloads::variant_for(&cfg);
                if !quiet {
                    eprintln!("[sweep] {bench} {config} @{lat}ns ...");
                }
                let r = run_one(bench, config, variant, lat, scale)
                    .unwrap_or_else(|e| panic!("sweep failed: {e}"));
                rows.push(r);
            }
        }
    }
    let mut csv = String::from(CSV_HEADER);
    csv.push('\n');
    for r in &rows {
        csv.push_str(&to_csv_row(r));
        csv.push('\n');
    }
    std::fs::write(&path, csv).ok();
    rows
}

fn find<'a>(
    rows: &'a [RunResult],
    bench: &str,
    config: &str,
    lat: f64,
) -> Option<&'a RunResult> {
    rows.iter()
        .find(|r| r.bench == bench && r.config == config && r.latency_ns == lat)
}

/// Baseline-at-100ns normalization denominator for one benchmark.
fn norm_base(rows: &[RunResult], bench: &str) -> f64 {
    find(rows, bench, "baseline", 100.0)
        .map(|r| r.measured_cycles as f64)
        .unwrap_or(1.0)
}

// ---------------------------------------------------------------- figures

/// Fig 2: baseline slowdown vs far-memory latency (motivation).
pub fn fig2(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(s, "# Fig 2 — baseline slowdown vs far-memory latency").unwrap();
    write!(s, "{:>8}", "lat(us)").unwrap();
    for b in workloads::ALL {
        write!(s, "{b:>9}").unwrap();
    }
    writeln!(s).unwrap();
    for &lat in SimConfig::paper_latencies_ns() {
        write!(s, "{:>8.1}", lat / 1000.0).unwrap();
        for b in workloads::ALL {
            let base = norm_base(rows, b);
            let v = find(rows, b, "baseline", lat)
                .map(|r| r.measured_cycles as f64 / base)
                .unwrap_or(f64::NAN);
            write!(s, "{v:>9.2}").unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

/// Fig 8: normalized execution time per benchmark / config / latency.
pub fn fig8(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "# Fig 8 — normalized execution time (lower is better; norm = baseline @0.1us)"
    )
    .unwrap();
    for b in workloads::ALL {
        writeln!(s, "\n## {b}").unwrap();
        write!(s, "{:>10}", "lat(us)").unwrap();
        for c in SWEEP_CONFIGS {
            write!(s, "{c:>11}").unwrap();
        }
        writeln!(s).unwrap();
        let base = norm_base(rows, b);
        for &lat in SimConfig::paper_latencies_ns() {
            write!(s, "{:>10.1}", lat / 1000.0).unwrap();
            for c in SWEEP_CONFIGS {
                let v = find(rows, b, c, lat)
                    .map(|r| r.measured_cycles as f64 / base)
                    .unwrap_or(f64::NAN);
                write!(s, "{v:>11.3}").unwrap();
            }
            writeln!(s).unwrap();
        }
    }
    s
}

/// Fig 9 (MLP) / Fig 10 (IPC) share a formatter.
fn metric_table(rows: &[RunResult], title: &str, f: impl Fn(&RunResult) -> f64) -> String {
    let mut s = String::new();
    writeln!(s, "# {title}").unwrap();
    for b in workloads::ALL {
        writeln!(s, "\n## {b}").unwrap();
        write!(s, "{:>10}", "lat(us)").unwrap();
        for c in SWEEP_CONFIGS {
            write!(s, "{c:>11}").unwrap();
        }
        writeln!(s).unwrap();
        for &lat in SimConfig::paper_latencies_ns() {
            write!(s, "{:>10.1}", lat / 1000.0).unwrap();
            for c in SWEEP_CONFIGS {
                let v = find(rows, b, c, lat).map(&f).unwrap_or(f64::NAN);
                write!(s, "{v:>11.2}").unwrap();
            }
            writeln!(s).unwrap();
        }
    }
    s
}

pub fn fig9(rows: &[RunResult]) -> String {
    metric_table(rows, "Fig 9 — MLP (average in-flight far-memory requests)", |r| r.mlp)
}

pub fn fig10(rows: &[RunResult]) -> String {
    metric_table(rows, "Fig 10 — IPC", |r| r.ipc)
}

/// Fig 11: energy normalized to baseline @0.1us, split static/dynamic.
/// (The paper's "power consumption" bars shrink when runtime shrinks —
/// i.e. they are run energy with a static component proportional to time.)
pub fn fig11(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(s, "# Fig 11 — normalized energy (static+dynamic; norm = baseline @0.1us)").unwrap();
    writeln!(s, "{:>8} {:>10} {:>12} {:>10} {:>10} {:>10}", "bench", "config", "lat(us)", "static", "dynamic", "total").unwrap();
    for b in workloads::ALL {
        let base = find(rows, b, "baseline", 100.0)
            .map(|r| r.dynamic_uj + r.static_uj)
            .unwrap_or(1.0);
        for c in SWEEP_CONFIGS {
            for &lat in [500.0, 1000.0].iter() {
                if let Some(r) = find(rows, b, c, lat) {
                    let st = r.static_uj / base;
                    let dy = r.dynamic_uj / base;
                    writeln!(
                        s,
                        "{:>8} {:>10} {:>12.1} {:>10.3} {:>10.3} {:>10.3}",
                        b,
                        c,
                        lat / 1000.0,
                        st,
                        dy,
                        st + dy
                    )
                    .unwrap();
                }
            }
        }
    }
    // Paper's headline geomeans: AMU/baseline power at 0.5us and 1us.
    for &lat in [500.0, 1000.0].iter() {
        let ratios: Vec<f64> = workloads::ALL
            .iter()
            .filter_map(|b| {
                let amu = find(rows, b, "amu", lat)?;
                let base = find(rows, b, "baseline", lat)?;
                Some(
                    (amu.total_power()) / (base.total_power()),
                )
            })
            .collect();
        if let Some(g) = geomean(&ratios) {
            writeln!(s, "\ngeomean AMU/baseline energy @{}us = {g:.2}", lat / 1000.0).unwrap();
        }
    }
    s
}

impl RunResult {
    fn total_power(&self) -> f64 {
        self.dynamic_uj + self.static_uj
    }
}

/// Fig 3: GUPS group-prefetch sensitivity across hardware scaling.
pub fn fig3(scale: Scale, latency_ns: f64) -> String {
    let groups = [2usize, 4, 8, 16, 32, 64, 128];
    let configs = ["cxl-ideal", "x2", "x4"];
    let mut s = String::new();
    writeln!(
        s,
        "# Fig 3 — GUPS with group prefetching vs group size (latency {}ns)",
        latency_ns
    )
    .unwrap();
    write!(s, "{:>10}", "group").unwrap();
    for c in configs {
        write!(s, "{c:>12}").unwrap();
    }
    writeln!(s, "{:>12}", "(cycles)").unwrap();
    // Baseline bars: plain GUPS per config.
    write!(s, "{:>10}", "none").unwrap();
    for c in configs {
        let r = run_one("gups", c, Variant::Sync, latency_ns, scale).unwrap();
        write!(s, "{:>12}", r.measured_cycles).unwrap();
    }
    writeln!(s).unwrap();
    for g in groups {
        write!(s, "{g:>10}").unwrap();
        for c in configs {
            let r = run_one("gups", c, Variant::GroupPrefetch(g), latency_ns, scale).unwrap();
            write!(s, "{:>12}", r.measured_cycles).unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

/// Table 4: baseline vs best software prefetch vs AMU vs LLVM-AMU for
/// GUPS / HJ / STREAM.
pub fn table4(scale: Scale) -> String {
    let benches = ["gups", "hj", "stream"];
    let pf_groups = [2usize, 8, 32, 128];
    let mut s = String::new();
    writeln!(s, "# Table 4 — normalized execution time (norm = cxl-ideal @0.1us per bench)").unwrap();
    writeln!(
        s,
        "{:>8} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "bench", "lat(us)", "CXL", "PF(best)", "pf-cfg", "AMU", "LLVM-AMU"
    )
    .unwrap();
    for b in benches {
        let base = run_one(b, "cxl-ideal", Variant::Sync, 100.0, scale)
            .unwrap()
            .measured_cycles as f64;
        for &lat in SimConfig::paper_latencies_ns() {
            let cxl = run_one(b, "cxl-ideal", Variant::Sync, lat, scale).unwrap();
            let mut best_pf = f64::INFINITY;
            let mut best_cfg = 0usize;
            for &g in &pf_groups {
                let v = if b == "stream" {
                    Variant::SwPrefetch { batch: g, depth: 0 }
                } else {
                    Variant::GroupPrefetch(g)
                };
                let r = run_one(b, "cxl-ideal", v, lat, scale).unwrap();
                if (r.measured_cycles as f64) < best_pf {
                    best_pf = r.measured_cycles as f64;
                    best_cfg = g;
                }
            }
            let amu = run_one(b, "amu", Variant::Amu, lat, scale).unwrap();
            let llvm = run_one(b, "amu", Variant::AmuLlvm, lat, scale).unwrap();
            writeln!(
                s,
                "{:>8} {:>8.1} {:>10.2} {:>10.2} {:>10} {:>10.2} {:>10.2}",
                b,
                lat / 1000.0,
                cxl.measured_cycles as f64 / base,
                best_pf / base,
                best_cfg,
                amu.measured_cycles as f64 / base,
                llvm.measured_cycles as f64 / base,
            )
            .unwrap();
        }
    }
    s
}

/// Table 5: % of execution time spent on software disambiguation (HJ, HT).
pub fn table5(scale: Scale) -> String {
    let mut s = String::new();
    writeln!(s, "# Table 5 — execution time share of software disambiguation").unwrap();
    write!(s, "{:>8}", "bench").unwrap();
    for &lat in SimConfig::paper_latencies_ns() {
        write!(s, "{:>9.1}", lat / 1000.0).unwrap();
    }
    writeln!(s, "   (us columns)").unwrap();
    for b in ["hj", "ht"] {
        write!(s, "{b:>8}").unwrap();
        for &lat in SimConfig::paper_latencies_ns() {
            let r = run_one(b, "amu", Variant::Amu, lat, scale).unwrap();
            write!(s, "{:>8.2}%", r.disambig_frac * 100.0).unwrap();
        }
        writeln!(s).unwrap();
    }
    s
}

/// Table 6: hardware resource overhead vs NanHu-G.
pub fn table6() -> String {
    let t = crate::area::table6(&crate::area::NanhuBase::default());
    let mut s = String::new();
    writeln!(s, "# Table 6 — resource utilization vs NanHu-G").unwrap();
    writeln!(
        s,
        "LUT(logic) +{:.1}%  LUT(mem) +{:.1}%  FF +{:.1}%  BRAM +{:.0}%  URAM +{:.0}%",
        t.lut_logic_pct, t.lut_mem_pct, t.ff_pct, t.bram_pct, t.uram_pct
    )
    .unwrap();
    writeln!(s, "ASIC: {:.0} gates, area +{:.2}%", t.asic_gates, t.asic_area_pct).unwrap();
    writeln!(
        s,
        "AMU storage overhead: {:.1} KB (independent of required MLP)",
        crate::area::storage_overhead_bytes() as f64 / 1024.0
    )
    .unwrap();
    s
}

/// Headline numbers (abstract / §6.3).
pub fn headline(rows: &[RunResult]) -> String {
    let mut s = String::new();
    writeln!(s, "# Headline reproduction").unwrap();
    // Mean speedup of AMU over baseline at 1us across memory-bound suite.
    let speedups: Vec<f64> = workloads::ALL
        .iter()
        .filter_map(|b| {
            let amu = find(rows, b, "amu", 1000.0)?;
            let base = find(rows, b, "baseline", 1000.0)?;
            Some(base.measured_cycles as f64 / amu.measured_cycles as f64)
        })
        .collect();
    if let Some(g) = geomean(&speedups) {
        writeln!(
            s,
            "geomean AMU speedup @1us over baseline: {g:.2}x (paper: 2.42x)"
        )
        .unwrap();
    }
    if let (Some(amu), Some(base)) = (
        find(rows, "gups", "amu", 5000.0),
        find(rows, "gups", "baseline", 5000.0),
    ) {
        writeln!(
            s,
            "GUPS @5us: {:.2}x speedup (paper: 26.86x); peak in-flight {} (paper: >130)",
            base.measured_cycles as f64 / amu.measured_cycles as f64,
            amu.peak_inflight
        )
        .unwrap();
        writeln!(s, "GUPS @5us avg MLP: {:.1}", amu.mlp).unwrap();
    }
    s
}

pub fn write_report(name: &str, body: &str) {
    let path = results_dir().join(format!("{name}.txt"));
    std::fs::write(&path, body).ok();
    println!("{body}");
    eprintln!("[report] wrote {}", path.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_one_produces_metrics() {
        let r = run_one("gups", "baseline", Variant::Sync, 200.0, Scale::Test).unwrap();
        assert!(r.measured_cycles > 0);
        assert!(r.ipc > 0.0);
        assert!(r.dynamic_uj > 0.0);
    }

    #[test]
    fn csv_roundtrip() {
        let r = run_one("gups", "amu", Variant::Amu, 200.0, Scale::Test).unwrap();
        let csv = format!("{CSV_HEADER}\n{}\n", to_csv_row(&r));
        let parsed = parse_csv(&csv).expect("parse");
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].bench, "gups");
        assert_eq!(parsed[0].measured_cycles, r.measured_cycles);
        assert_eq!(parsed[0].peak_inflight, r.peak_inflight);
    }

    #[test]
    fn table6_report_renders() {
        let t = table6();
        assert!(t.contains("LUT"));
        assert!(t.contains("71510") || t.contains("71,510") || t.contains("gates"));
    }
}
