//! amu-sim — reproduction of *"Asynchronous Memory Access Unit: Exploiting
//! Massive Parallelism for Far Memory Access"* (ACM TACO 2024).
//!
//! A cycle-level out-of-order core + memory-hierarchy simulator with the
//! paper's AMI ISA extension and AMU function unit, the coroutine software
//! stack, the 11-benchmark evaluation suite, and report generators for
//! every figure and table in the paper's evaluation. See DESIGN.md for the
//! architecture and EXPERIMENTS.md for measured results.

pub mod amu;
pub mod area;
pub mod coro;
pub mod config;
pub mod isa;
pub mod mem;
pub mod power;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod testing;
pub mod util;
pub mod workloads;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
