//! amu-sim — reproduction of *"Asynchronous Memory Access Unit: Exploiting
//! Massive Parallelism for Far Memory Access"* (ACM TACO 2024).
//!
//! A cycle-level out-of-order core + memory-hierarchy simulator with the
//! paper's AMI ISA extension and AMU function unit, the coroutine software
//! stack, the 11-benchmark evaluation suite, and report generators for
//! every figure and table in the paper's evaluation. See DESIGN.md for the
//! architecture and EXPERIMENTS.md for measured results.
//!
//! # Running one benchmark
//!
//! The typed front door is [`session::RunRequest`]: it validates the
//! bench/config/variant/latency combination at construction and returns
//! `Err` (naming the valid choices) instead of panicking:
//!
//! ```no_run
//! use amu_sim::config::SimConfig;
//! use amu_sim::session::RunRequest;
//! use amu_sim::workloads::Variant;
//!
//! let r = RunRequest::bench("gups")
//!     .config(SimConfig::amu())
//!     .variant(Variant::Amu)
//!     .latency_ns(1000.0)
//!     .run()
//!     .unwrap();
//! println!("{} cycles @ mlp {:.1}", r.measured_cycles, r.mlp);
//! ```
//!
//! # Running sweeps
//!
//! [`session::Session`] executes a [`session::SweepGrid`] — any
//! benches × configs × latencies × variants × far-memory backends cross
//! product — across scoped worker threads with deterministic row ordering
//! and a resumable, fingerprint-checked CSV cache:
//!
//! ```no_run
//! use amu_sim::session::{Session, SweepGrid};
//! use amu_sim::workloads::Scale;
//!
//! let grid = SweepGrid::paper(Scale::Test);
//! let rows = Session::new().jobs(8).sweep(&grid).unwrap();
//! assert_eq!(rows.len(), 11 * 4 * 6);
//!
//! // The same grid under every far-memory data plane (see `mem::backend`):
//! let grid = SweepGrid::paper(Scale::Test)
//!     .backends(["serial-link", "pooled", "distribution", "hybrid"]);
//! assert_eq!(grid.len(), 11 * 4 * 6 * 4);
//! ```
//!
//! The same executor backs `amu-sim sweep --jobs N` on the command line.
//! The older stringly entry points `report::run_one` and
//! `report::sweep_cached` are deprecated shims over this API.

pub mod amu;
pub mod area;
pub mod coro;
pub mod config;
pub mod isa;
pub mod mem;
pub mod power;
pub mod report;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod stats;
pub mod testing;
pub mod util;
pub mod workloads;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
