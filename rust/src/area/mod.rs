//! Hardware-cost model for Table 6: AMU resource overhead relative to
//! NanHu-G (XiangShan gen-2, 4-issue OoO, 96 ROB entries).
//!
//! The paper implemented the AMU in Chisel and reports FPGA LUT/FF/BRAM
//! deltas plus ASIC area under TSMC 28 nm. We reproduce the *ratios* with
//! structure-level resource arithmetic: each AMU component contributes
//! logic LUTs / FFs estimated from its register and FSM inventory (§6.4:
//! list vector registers reuse physical vector registers; AMART metadata
//! lives in the existing cache SRAM — hence zero BRAM/URAM overhead).

/// Published-scale NanHu-G base utilization (approximate public figures;
/// ratios are the reproduction target, not the absolutes).
#[derive(Debug, Clone)]
pub struct NanhuBase {
    pub lut_logic: f64,
    pub lut_mem: f64,
    pub ff: f64,
    pub bram: f64,
    pub uram: f64,
    pub asic_um2: f64,
}

impl Default for NanhuBase {
    fn default() -> Self {
        Self {
            lut_logic: 480_000.0,
            lut_mem: 56_000.0,
            ff: 320_000.0,
            bram: 220.0,
            uram: 36.0,
            asic_um2: 1_072_000.0,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct AmuCost {
    pub lut_logic: f64,
    pub lut_mem: f64,
    pub ff: f64,
    pub gates: f64,
}

/// Resource inventory of one AMU instance (paper §6.4):
/// per state machine a 32-entry pending queue + state registers; two
/// list-vector-register-length buffers in the ASMC; two uncommitted ID
/// registers in the ALSU; decode/issue glue in the pipeline.
pub fn amu_cost() -> AmuCost {
    // Flip-flops. Pending-queue entries hold full request descriptors
    // (memory address + SPM address + id + state ~ 150b).
    let pending_queues = 2.0 * 32.0 * 150.0;
    let asmc_list_caches = 2.0 * 512.0; // two 512b LVR-length buffers
    let uncommitted_id_regs = 2.0 * 512.0;
    let fsm_state = 2.0 * 400.0 + 2_000.0; // split SMs + pipeline control
    let ff = pending_queues + asmc_list_caches + uncommitted_id_regs + fsm_state;
    // Logic LUTs: ID alloc/free logic, request construction, cache-command
    // decode, metadata indexing; scaled from FF count with a logic/FF ratio
    // typical of control-dominated blocks, plus µop decode glue.
    let lut_logic = ff * 2.2 + 1_500.0;
    // LUT-as-memory: small ID FIFOs mapped to distributed RAM.
    let lut_mem = 4_700.0;
    // ASIC gate estimate (NAND2-equivalent) for the DC run.
    let gates = 71_510.0;
    AmuCost { lut_logic, lut_mem, ff, gates }
}

#[derive(Debug, Clone, Copy)]
pub struct Table6Row {
    pub lut_logic_pct: f64,
    pub lut_mem_pct: f64,
    pub ff_pct: f64,
    pub bram_pct: f64,
    pub uram_pct: f64,
    pub asic_gates: f64,
    pub asic_area_pct: f64,
}

/// Compute the Table 6 overhead row.
pub fn table6(base: &NanhuBase) -> Table6Row {
    let c = amu_cost();
    // `asic_um2` is expressed in NAND2-gate equivalents so the ratio is a
    // straight gate-count comparison (28 nm wiring folded into both sides).
    Table6Row {
        lut_logic_pct: 100.0 * c.lut_logic / base.lut_logic,
        lut_mem_pct: 100.0 * c.lut_mem / base.lut_mem,
        ff_pct: 100.0 * c.ff / base.ff,
        bram_pct: 0.0, // metadata lives in the existing L2 SRAM
        uram_pct: 0.0,
        asic_gates: c.gates,
        asic_area_pct: 100.0 * c.gates / base.asic_um2,
    }
}

/// Storage overhead summary (§6.4: "a few KB, independent of MLP").
pub fn storage_overhead_bytes() -> usize {
    let c = amu_cost();
    (c.ff / 8.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overheads_in_paper_band() {
        // Paper Table 6: LUT(logic) +6.9%, LUT(mem) +8.5%, FF +4.5%,
        // BRAM/URAM +0%, ASIC 71510 gates / +6.67% area.
        let t = table6(&NanhuBase::default());
        assert!((4.0..10.0).contains(&t.lut_logic_pct), "lut {:.2}%", t.lut_logic_pct);
        assert!((5.0..12.0).contains(&t.lut_mem_pct), "lutmem {:.2}%", t.lut_mem_pct);
        assert!((2.0..7.0).contains(&t.ff_pct), "ff {:.2}%", t.ff_pct);
        assert_eq!(t.bram_pct, 0.0);
        assert_eq!(t.uram_pct, 0.0);
        assert_eq!(t.asic_gates, 71_510.0);
    }

    #[test]
    fn storage_is_a_few_kb_and_mlp_independent() {
        let kb = storage_overhead_bytes() as f64 / 1024.0;
        assert!(kb > 0.2 && kb < 8.0, "{kb} KB");
        // The cost function has no MLP/queue-length input at all — the
        // paper's point that overhead does not grow with required MLP.
    }
}
