//! Shared utilities: deterministic PRNG, CLI parsing, config file parsing,
//! plus small formatting helpers used by the report generators.

pub mod cli;
pub mod prng;
pub mod toml_lite;

/// Minimal FNV-1a 64-bit hasher (no external hash crates in the offline
/// image). Used for sweep-grid fingerprints and the metric-schema hash —
/// both stored in on-disk cache headers, so the function must stay stable.
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fast word-at-a-time mixer for in-memory state fingerprints (the
/// simulator's fast-forward fixed-point detection hashes a few thousand
/// words per attempt, so the byte-serial [`Fnv`] is too slow). One multiply
/// per word. Unlike [`Fnv`] this is never persisted to disk and carries no
/// stability guarantee across versions.
pub struct Mix64(u64);

impl Default for Mix64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Mix64 {
    pub fn new() -> Self {
        Mix64(0x243f_6a88_85a3_08d3)
    }

    #[inline]
    pub fn mix(&mut self, v: u64) {
        self.0 = (self.0 ^ v).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        self.0 ^= self.0 >> 29;
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Geometric mean of positive values; `None` if empty or any non-positive.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return None;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    Some((log_sum / xs.len() as f64).exp())
}

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Format a cycle count as a human-readable duration at `freq_ghz`.
pub fn cycles_to_us(cycles: u64, freq_ghz: f64) -> f64 {
    cycles as f64 / (freq_ghz * 1000.0)
}

/// Nanoseconds to core cycles at `freq_ghz` (rounded to nearest cycle).
pub fn ns_to_cycles(ns: f64, freq_ghz: f64) -> u64 {
    (ns * freq_ghz).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        let g = geomean(&[1.0, 4.0]).unwrap();
        assert!((g - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_none());
        assert!(geomean(&[1.0, 0.0]).is_none());
    }

    #[test]
    fn cycle_time_conversions() {
        assert_eq!(ns_to_cycles(1000.0, 3.0), 3000); // 1 us @3GHz
        assert!((cycles_to_us(3000, 3.0) - 1.0).abs() < 1e-12);
        assert_eq!(ns_to_cycles(100.0, 3.0), 300);
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(Fnv::new().finish(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv::new();
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h = Fnv::new();
        h.write(b"foobar");
        assert_eq!(h.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn mix64_order_and_value_sensitive() {
        let mut a = Mix64::new();
        a.mix(1);
        a.mix(2);
        let mut b = Mix64::new();
        b.mix(2);
        b.mix(1);
        assert_ne!(a.finish(), b.finish());
        let mut c = Mix64::new();
        c.mix(1);
        c.mix(2);
        assert_eq!(a.finish(), c.finish());
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
