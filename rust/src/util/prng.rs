//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate; the simulator needs
//! fully deterministic, seedable randomness anyway (every experiment must be
//! reproducible bit-for-bit from its config seed). We implement
//! `splitmix64` (seeding / stream splitting) and `xoshiro256**` (bulk
//! generation), the same generators the reference `rand_xoshiro` crate uses.

/// SplitMix64: used to expand a single `u64` seed into generator state and
/// to derive independent child seeds for sub-components.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit PRNG.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Guard against the all-zero state (astronomically unlikely, cheap).
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn split(&mut self) -> Xoshiro256 {
        Xoshiro256::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        // 128-bit multiply method; unbiased via rejection.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal (mean 0, variance 1) via Box–Muller. Deterministic:
    /// two uniform draws per sample, no cached spare.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE); // ln(0) guard
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u64> {
        let mut v: Vec<u64> = (0..n as u64).collect();
        self.shuffle(&mut v);
        v
    }

    /// Zipfian sample in `[0, n)` with exponent `theta` (YCSB-style),
    /// approximated via the rejection-inversion method of Hörmann.
    pub fn zipf(&mut self, n: u64, theta: f64) -> u64 {
        debug_assert!(n >= 1);
        if theta <= 0.0 {
            return self.below(n);
        }
        // Simple inverse-CDF on a truncated harmonic approximation: fast
        // enough for request generation and fully deterministic.
        let s = 1.0 - theta;
        let hmax = ((n as f64).powf(s) - 1.0) / s;
        loop {
            let u = self.next_f64() * hmax;
            let x = (u * s + 1.0).powf(1.0 / s) - 1.0;
            let k = x as u64;
            if k < n {
                return k;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public splitmix64.c.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_determinism_and_split_independence() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = a.split();
        let x: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(7);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_bounds() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..1000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(11);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(21);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.next_gaussian();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "gaussian variance {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(13);
        let p = r.permutation(100);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Xoshiro256::new(17);
        let n = 1000u64;
        let mut low = 0usize;
        for _ in 0..10_000 {
            let v = r.zipf(n, 0.99);
            assert!(v < n);
            if v < n / 10 {
                low += 1;
            }
        }
        // Zipf(0.99) concentrates mass on the low ranks.
        assert!(low > 5_000, "zipf skew too weak: {low}");
    }
}
