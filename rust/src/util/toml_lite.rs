//! A small TOML-subset parser for simulator config files (no `serde`/`toml`
//! in the offline image).
//!
//! Supported subset — more than enough for flat simulator configs:
//! `[section]` headers, `key = value` with integers (incl. `0x`, `k/m/g`
//! suffixes), floats, booleans, quoted strings, and `#` comments.
//! Values are exposed as `section.key` lookups with typed getters.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

#[derive(Debug, Default)]
pub struct Document {
    /// Flattened `section.key -> value`; top-level keys have no prefix.
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for ParseError {}

pub fn parse(src: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            section = name.to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| err(lineno, "expected key = value"))?;
        let key = k.trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(v.trim()).map_err(|m| err(lineno, &m))?;
        doc.entries.insert(full, value);
    }
    Ok(doc)
}

fn err(line: usize, msg: &str) -> ParseError {
    ParseError { line, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Integer (with suffix support) before float: "1e3" stays float.
    if let Ok(u) = super::cli::parse_u64(s.strip_prefix('-').unwrap_or(s)) {
        let has_float_marker = s.contains('.') || s.contains('e') || s.contains('E');
        if !has_float_marker {
            let v = u as i64;
            return Ok(Value::Int(if s.starts_with('-') { -v } else { v }));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unparseable value '{s}'"))
}

impl Document {
    pub fn get_i64(&self, key: &str) -> Option<i64> {
        match self.entries.get(key)? {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get_i64(key).and_then(|v| u64::try_from(v).ok())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        match self.entries.get(key)? {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.entries.get(key)? {
            Value::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.entries.get(key)? {
            Value::Str(v) => Some(v.as_str()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# top comment
seed = 42
[core]
rob = 512
width = 6
freq_ghz = 3.0
smt = false
name = "golden-cove-like"  # trailing comment
[mem]
l2_kb = 256
far_latency = 1_000
spm = 64k
"#,
        )
        .unwrap();
        assert_eq!(doc.get_u64("seed"), Some(42));
        assert_eq!(doc.get_u64("core.rob"), Some(512));
        assert_eq!(doc.get_f64("core.freq_ghz"), Some(3.0));
        assert_eq!(doc.get_bool("core.smt"), Some(false));
        assert_eq!(doc.get_str("core.name"), Some("golden-cove-like"));
        assert_eq!(doc.get_u64("mem.far_latency"), Some(1000));
        assert_eq!(doc.get_u64("mem.spm"), Some(64 * 1024));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("[]").is_err());
        assert!(parse("k = \"open").is_err());
    }

    #[test]
    fn int_float_coercion() {
        let doc = parse("x = 3\ny = 2.5\n").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
        assert_eq!(doc.get_f64("y"), Some(2.5));
        assert_eq!(doc.get_i64("y"), None);
    }

    #[test]
    fn negative_ints() {
        let doc = parse("x = -7\n").unwrap();
        assert_eq!(doc.get_i64("x"), Some(-7));
    }

    #[test]
    fn hash_inside_string_survives() {
        let doc = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }
}
