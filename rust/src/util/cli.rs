//! Minimal command-line argument parser (no `clap` in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and an auto-generated usage string.
//!
//! Options are *declared once* as [`Spec`] constants and composed into
//! per-subcommand tables (see `main.rs`): a spec carries its canonical
//! name, alias spellings, a value placeholder for help text, and an
//! optional syntactic validator that runs at parse time — so `--latency`
//! and `--latency-ns` land in the same slot, a typo'd option error names
//! every valid choice for the subcommand, and a malformed number fails
//! before any simulation starts.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}
impl std::error::Error for ArgError {}

/// Syntactic value check applied at parse time, before the value reaches
/// the subcommand. Semantic validation (known preset names, policy tags,
/// ...) stays with the consumer — the parser only rejects what can never
/// be well-formed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Validate {
    /// Any string.
    Str,
    /// One integer, `parse_u64` syntax (`64k`, `0x10`, `1_000`).
    U64,
    /// One float.
    F64,
    /// Comma-separated floats (e.g. `--latencies-ns 300,1000,5000`).
    F64List,
}

/// Declarative option spec: canonical name, alias spellings, value
/// placeholder for help text, syntactic validator, and help line. Declared
/// once per option as a `const` and shared across subcommand tables.
#[derive(Debug, Clone, Copy)]
pub struct Spec {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub takes_value: bool,
    pub value_name: &'static str,
    pub validate: Validate,
    pub help: &'static str,
}

/// A value-taking option: `--name <value_name>` (or `--name=<value>`).
pub const fn opt(name: &'static str, value_name: &'static str, help: &'static str) -> Spec {
    Spec { name, aliases: &[], takes_value: true, value_name, validate: Validate::Str, help }
}

/// A boolean flag: `--name`.
pub const fn flag(name: &'static str, help: &'static str) -> Spec {
    Spec { name, aliases: &[], takes_value: false, value_name: "", validate: Validate::Str, help }
}

impl Spec {
    /// Alias spellings that canonicalize to `self.name` at parse time.
    pub const fn aliases(mut self, aliases: &'static [&'static str]) -> Self {
        self.aliases = aliases;
        self
    }

    /// Attach a syntactic validator (value-taking options only).
    pub const fn validate(mut self, v: Validate) -> Self {
        self.validate = v;
        self
    }

    fn matches(&self, key: &str) -> bool {
        self.name == key || self.aliases.contains(&key)
    }

    fn check(&self, val: &str) -> Result<(), ArgError> {
        let bad = |what: &str| ArgError(format!("--{}: bad {what} '{val}'", self.name));
        match self.validate {
            Validate::Str => Ok(()),
            Validate::U64 => parse_u64(val)
                .map(drop)
                .map_err(|e| ArgError(format!("--{}: {e}", self.name))),
            Validate::F64 => val.parse::<f64>().map(drop).map_err(|_| bad("float")),
            Validate::F64List => {
                for item in val.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                    item.parse::<f64>().map_err(|_| bad("float list"))?;
                }
                Ok(())
            }
        }
    }
}

/// Render the option table for `cmd`, one aligned line per spec with the
/// value placeholder and any alias spellings.
pub fn usage(cmd: &str, specs: &[Spec]) -> String {
    let lhs: Vec<String> = specs
        .iter()
        .map(|sp| {
            if sp.takes_value {
                format!("--{} <{}>", sp.name, sp.value_name)
            } else {
                format!("--{}", sp.name)
            }
        })
        .collect();
    let width = lhs.iter().map(|l| l.len()).max().unwrap_or(0);
    let mut s = format!("usage: {cmd} [options]\n");
    for (sp, l) in specs.iter().zip(&lhs) {
        let alias = if sp.aliases.is_empty() {
            String::new()
        } else {
            let spelled: Vec<String> = sp.aliases.iter().map(|a| format!("--{a}")).collect();
            format!(" (alias: {})", spelled.join(", "))
        };
        s.push_str(&format!("  {l:<width$}  {}{alias}\n", sp.help));
    }
    s
}

/// Parse `argv` (without the program name) against `specs`. Alias
/// spellings are canonicalized — the `Args` maps are keyed by `Spec::name`
/// only — and an unknown option error names every valid choice.
pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, ArgError> {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (key, inline_val) = match body.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (body, None),
            };
            let spec = specs.iter().find(|s| s.matches(key)).ok_or_else(|| {
                let valid: Vec<String> =
                    specs.iter().map(|s| format!("--{}", s.name)).collect();
                ArgError(format!("unknown option --{key} (valid: {})", valid.join(", ")))
            })?;
            let key = spec.name.to_string();
            if spec.takes_value {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| ArgError(format!("--{key} needs a value")))?
                    }
                };
                spec.check(&val)?;
                out.options.entry(key).or_default().push(val);
            } else {
                if inline_val.is_some() {
                    return Err(ArgError(format!("--{key} takes no value")));
                }
                out.flags.push(key);
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

impl Args {
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => parse_u64(s).map_err(|e| ArgError(format!("--{name}: {e}"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| ArgError(format!("--{name}: bad float '{s}'"))),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
}

/// Parse integers with optional `k`/`m`/`g` (binary) and `_` separators,
/// e.g. `64k`, `1m`, `1_000_000`.
pub fn parse_u64(s: &str) -> Result<u64, String> {
    let s = s.trim().replace('_', "");
    if s.is_empty() {
        return Err("empty integer".into());
    }
    let (digits, mult) = match s.chars().last().unwrap().to_ascii_lowercase() {
        'k' => (&s[..s.len() - 1], 1024u64),
        'm' => (&s[..s.len() - 1], 1024 * 1024),
        'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (&s[..], 1),
    };
    let base = if let Some(hex) = digits.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).map_err(|_| format!("bad integer '{s}'"))?
    } else {
        digits.parse::<u64>().map_err(|_| format!("bad integer '{s}'"))?
    };
    base.checked_mul(mult).ok_or_else(|| format!("integer overflow '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[Spec] = &[
        opt("latency", "ns", "far memory latency").aliases(&["lat"]).validate(Validate::F64),
        opt("config", "name", "preset name"),
        opt("count", "n", "a count").validate(Validate::U64),
        opt("points", "list", "comma floats").validate(Validate::F64List),
        flag("verbose", "chatty output"),
    ];

    #[test]
    fn parses_positional_options_flags() {
        let a = parse(&argv(&["run", "--latency", "1000", "--verbose"]), SPECS).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("latency"), Some("1000"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&argv(&["--config=amu"]), SPECS).unwrap();
        assert_eq!(a.get("config"), Some("amu"));
    }

    #[test]
    fn alias_canonicalizes_to_primary_name() {
        let a = parse(&argv(&["--lat", "250", "--latency=500"]), SPECS).unwrap();
        // Both spellings land in the same slot, under the canonical name.
        assert_eq!(a.get_all("latency"), vec!["250", "500"]);
        assert_eq!(a.get("lat"), None);
    }

    #[test]
    fn unknown_option_error_names_valid_choices() {
        let e = parse(&argv(&["--bogus"]), SPECS).unwrap_err();
        assert!(e.0.contains("unknown option --bogus"), "{}", e.0);
        assert!(e.0.contains("--latency"), "{}", e.0);
        assert!(e.0.contains("--verbose"), "{}", e.0);
    }

    #[test]
    fn validators_reject_malformed_values_at_parse_time() {
        assert!(parse(&argv(&["--latency", "fast"]), SPECS).is_err());
        assert!(parse(&argv(&["--count", "banana"]), SPECS).is_err());
        assert!(parse(&argv(&["--points", "1,two,3"]), SPECS).is_err());
        assert!(parse(&argv(&["--count", "64k"]), SPECS).is_ok());
        assert!(parse(&argv(&["--points", "1,2.5,3e3"]), SPECS).is_ok());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&argv(&["--latency"]), SPECS).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&argv(&["--verbose=1"]), SPECS).is_err());
    }

    #[test]
    fn repeated_option_keeps_all_and_last_wins() {
        let a = parse(&argv(&["--latency", "1", "--latency", "2"]), SPECS).unwrap();
        assert_eq!(a.get_all("latency"), vec!["1", "2"]);
        assert_eq!(a.get("latency"), Some("2"));
    }

    #[test]
    fn usage_lists_value_names_and_aliases() {
        let u = usage("amu-sim test", SPECS);
        assert!(u.contains("--latency <ns>"), "{u}");
        assert!(u.contains("alias: --lat"), "{u}");
        assert!(u.contains("--verbose"), "{u}");
    }

    #[test]
    fn suffix_integers() {
        assert_eq!(parse_u64("64k").unwrap(), 64 * 1024);
        assert_eq!(parse_u64("1m").unwrap(), 1024 * 1024);
        assert_eq!(parse_u64("0x10").unwrap(), 16);
        assert_eq!(parse_u64("1_000").unwrap(), 1000);
        assert!(parse_u64("banana").is_err());
    }

    #[test]
    fn typed_getters_defaults() {
        let a = parse(&argv(&[]), SPECS).unwrap();
        assert_eq!(a.get_u64("count", 300).unwrap(), 300);
        assert_eq!(a.get_str("config", "baseline"), "baseline");
    }
}
