//! Minimal command-line argument parser (no `clap` in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}
impl std::error::Error for ArgError {}

/// Declarative option spec so `parse` can distinguish value-taking options
/// from boolean flags and emit usage text.
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

pub const fn opt(name: &'static str, help: &'static str) -> Spec {
    Spec { name, takes_value: true, help }
}

pub const fn flag(name: &'static str, help: &'static str) -> Spec {
    Spec { name, takes_value: false, help }
}

pub fn usage(cmd: &str, specs: &[Spec]) -> String {
    let mut s = format!("usage: {cmd} [options]\n");
    for sp in specs {
        let v = if sp.takes_value { " <value>" } else { "" };
        s.push_str(&format!("  --{}{:<12} {}\n", sp.name, v, sp.help));
    }
    s
}

/// Parse `argv` (without the program name) against `specs`.
pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, ArgError> {
    let mut out = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (key, inline_val) = match body.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == key)
                .ok_or_else(|| ArgError(format!("unknown option --{key}")))?;
            if spec.takes_value {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| ArgError(format!("--{key} needs a value")))?
                    }
                };
                out.options.entry(key).or_default().push(val);
            } else {
                if inline_val.is_some() {
                    return Err(ArgError(format!("--{key} takes no value")));
                }
                out.flags.push(key);
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

impl Args {
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.options
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => parse_u64(s).map_err(|e| ArgError(format!("--{name}: {e}"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| ArgError(format!("--{name}: bad float '{s}'"))),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }
}

/// Parse integers with optional `k`/`m`/`g` (binary) and `_` separators,
/// e.g. `64k`, `1m`, `1_000_000`.
pub fn parse_u64(s: &str) -> Result<u64, String> {
    let s = s.trim().replace('_', "");
    if s.is_empty() {
        return Err("empty integer".into());
    }
    let (digits, mult) = match s.chars().last().unwrap().to_ascii_lowercase() {
        'k' => (&s[..s.len() - 1], 1024u64),
        'm' => (&s[..s.len() - 1], 1024 * 1024),
        'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (&s[..], 1),
    };
    let base = if let Some(hex) = digits.strip_prefix("0x") {
        u64::from_str_radix(hex, 16).map_err(|_| format!("bad integer '{s}'"))?
    } else {
        digits.parse::<u64>().map_err(|_| format!("bad integer '{s}'"))?
    };
    base.checked_mul(mult).ok_or_else(|| format!("integer overflow '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[Spec] = &[
        opt("latency", "far memory latency"),
        opt("config", "preset name"),
        flag("verbose", "chatty output"),
    ];

    #[test]
    fn parses_positional_options_flags() {
        let a = parse(&argv(&["run", "--latency", "1000", "--verbose"]), SPECS).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("latency"), Some("1000"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&argv(&["--latency=5us_is_not_a_number"]), SPECS).unwrap();
        assert_eq!(a.get("latency"), Some("5us_is_not_a_number"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse(&argv(&["--bogus"]), SPECS).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse(&argv(&["--latency"]), SPECS).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse(&argv(&["--verbose=1"]), SPECS).is_err());
    }

    #[test]
    fn repeated_option_keeps_all_and_last_wins() {
        let a = parse(&argv(&["--latency", "1", "--latency", "2"]), SPECS).unwrap();
        assert_eq!(a.get_all("latency"), vec!["1", "2"]);
        assert_eq!(a.get("latency"), Some("2"));
    }

    #[test]
    fn suffix_integers() {
        assert_eq!(parse_u64("64k").unwrap(), 64 * 1024);
        assert_eq!(parse_u64("1m").unwrap(), 1024 * 1024);
        assert_eq!(parse_u64("0x10").unwrap(), 16);
        assert_eq!(parse_u64("1_000").unwrap(), 1000);
        assert!(parse_u64("banana").is_err());
    }

    #[test]
    fn typed_getters_defaults() {
        let a = parse(&argv(&[]), SPECS).unwrap();
        assert_eq!(a.get_u64("latency", 300).unwrap(), 300);
        assert_eq!(a.get_str("config", "baseline"), "baseline");
    }
}
