//! Simulation statistics: counters, time-weighted occupancy integrators,
//! histograms, and region-tagged cycle attribution.
//!
//! Everything the report generators need (IPC, MLP, power inputs,
//! disambiguation overhead) is collected here so the pipeline and memory
//! models stay free of formatting concerns.
//!
//! Per-backend scenario counters are *schema-driven*: [`schema`] is the
//! registry of scenario columns and [`schema::ScenarioStats`] the record
//! harvested from the selected far-memory backend at the end of a run.

pub mod schema;

pub use schema::{ScenarioCol, ScenarioStats};

/// Time-weighted average of a level signal (e.g. "requests in flight").
/// `update` must be called with non-decreasing cycles.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Integrator {
    last_cycle: u64,
    value: u64,
    area: u128,
    pub max: u64,
}

impl Integrator {
    #[inline]
    pub fn update(&mut self, cycle: u64, value: u64) {
        debug_assert!(cycle >= self.last_cycle, "time went backwards");
        self.area += (cycle - self.last_cycle) as u128 * self.value as u128;
        self.last_cycle = cycle;
        self.value = value;
        if value > self.max {
            self.max = value;
        }
    }

    #[inline]
    pub fn add(&mut self, cycle: u64, delta: i64) {
        let v = (self.value as i64 + delta).max(0) as u64;
        self.update(cycle, v);
    }

    pub fn current(&self) -> u64 {
        self.value
    }

    /// Average level over `[0, end_cycle]`.
    pub fn average(&self, end_cycle: u64) -> f64 {
        if end_cycle == 0 {
            return 0.0;
        }
        let area = self.area
            + (end_cycle.saturating_sub(self.last_cycle)) as u128 * self.value as u128;
        area as f64 / end_cycle as f64
    }
}

/// Power-of-two bucketed histogram for latencies / sizes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hist {
    pub buckets: [u64; 64],
    pub count: u64,
    pub sum: u128,
    pub min: u64,
    pub max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self { buckets: [0; 64], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl Hist {
    #[inline]
    pub fn add(&mut self, v: u64) {
        let b = 64 - v.leading_zeros() as usize; // 0 -> bucket 0
        self.buckets[b.min(63)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate percentile via bucket upper bounds.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return if i == 0 { 0 } else { 1u64 << i };
            }
        }
        self.max
    }
}

/// Guest-code regions for cycle attribution (Table 5 uses `Disambig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Main = 0,
    Scheduler = 1,
    Disambig = 2,
    Setup = 3,
}

pub const NUM_REGIONS: usize = 4;

impl Region {
    pub fn from_u8(v: u8) -> Region {
        match v {
            1 => Region::Scheduler,
            2 => Region::Disambig,
            3 => Region::Setup,
            _ => Region::Main,
        }
    }
}

/// All statistics for one simulation run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Stats {
    // Progress.
    pub cycles: u64,
    pub insts_committed: u64,
    pub uops_committed: u64,
    pub measured_cycles: u64, // cycles inside the region-of-interest
    pub measured_insts: u64,

    // Frontend / speculation.
    pub fetched_uops: u64,
    pub branches: u64,
    pub branch_mispredicts: u64,
    pub squashed_uops: u64,

    // Structure occupancy (time-weighted; for power + diagnostics).
    pub rob_occ: Integrator,
    pub iq_occ: Integrator,
    pub lq_occ: Integrator,
    pub sq_occ: Integrator,
    pub l1d_mshr_occ: Integrator,
    pub l2_mshr_occ: Integrator,

    // Far memory parallelism (Fig 9): in-flight far requests.
    pub far_inflight: Integrator,
    pub amu_inflight: Integrator,

    // Structure event counts (power model inputs).
    pub rob_writes: u64,
    pub iq_writes: u64,
    pub iq_wakeups: u64,
    pub regfile_reads: u64,
    pub regfile_writes: u64,
    pub lsq_searches: u64,

    // Memory system.
    pub l1d_accesses: u64,
    pub l1d_misses: u64,
    pub l2_accesses: u64,
    pub l2_misses: u64,
    pub spm_accesses: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,
    pub far_reads: u64,
    pub far_writes: u64,
    pub far_bytes: u64,
    /// Far-memory scenario counters (near-tier hits/evictions, pool
    /// congestion, policy switches, ...), harvested from the selected
    /// backend at the end of a run. One value per [`schema::SCENARIO_COLUMNS`]
    /// entry; backends without a mechanism report zero.
    pub scenario: ScenarioStats,
    pub link_stall_cycles: u64,
    pub prefetches_issued: u64,
    pub prefetches_useful: u64,
    pub mshr_reject_events: u64,

    // AMU.
    pub aloads: u64,
    pub astores: u64,
    pub getfins: u64,
    pub getfin_misses: u64, // getfin returned "nothing finished"
    pub id_batch_fetches: u64,
    pub amu_subrequests: u64,
    pub amu_speculative_rollbacks: u64,
    pub amart_full_events: u64,
    /// Completions for AMART entries that were reinitialized mid-flight
    /// (e.g. `set_queue_length` during outstanding sub-requests); dropped
    /// rather than corrupting a recycled entry.
    pub stale_completions: u64,

    // Latency distributions.
    pub far_read_latency: Hist,
    pub sync_load_latency: Hist,
    pub ami_completion_latency: Hist,

    // Region-tagged cycle attribution (ROB-head heuristic).
    pub region_cycles: [u64; NUM_REGIONS],
    pub region_uops: [u64; NUM_REGIONS],
}

impl Stats {
    pub fn ipc(&self) -> f64 {
        if self.measured_cycles == 0 {
            0.0
        } else {
            self.measured_insts as f64 / self.measured_cycles as f64
        }
    }

    /// Average MLP = mean number of in-flight far-memory requests
    /// (demand + AMU) over the measured window. Uses total cycles because
    /// integrators span the whole run; workloads keep setup off the far path.
    pub fn mlp(&self) -> f64 {
        self.far_inflight.average(self.cycles)
    }

    pub fn branch_mpki(&self) -> f64 {
        if self.insts_committed == 0 {
            0.0
        } else {
            self.branch_mispredicts as f64 * 1000.0 / self.insts_committed as f64
        }
    }

    pub fn region_fraction(&self, r: Region) -> f64 {
        let total: u64 = self.region_cycles.iter().sum();
        if total == 0 {
            0.0
        } else {
            self.region_cycles[r as usize] as f64 / total as f64
        }
    }

    /// Replicate the counter deltas of one idle (fixed-point) pipeline tick
    /// across `k` further skipped ticks, in closed form: for every plain
    /// counter, `self += k * (self - before)` where `before` is the snapshot
    /// taken just before that tick. Used by the simulator's event-driven
    /// fast-forward; `cycles` is excluded (the caller sets the clock
    /// directly), and integrators/histograms are excluded because a fixed
    /// point cannot change them (guarded by
    /// [`Stats::hists_and_levels_unchanged`]) — integrator area over the
    /// skipped span accrues exactly at the next real update since the level
    /// is constant.
    pub fn fold_idle(&mut self, k: u64, before: &Stats) {
        macro_rules! fold {
            ($($f:ident),* $(,)?) => {
                $( self.$f += k * (self.$f - before.$f); )*
            };
        }
        fold!(
            insts_committed,
            uops_committed,
            measured_cycles,
            measured_insts,
            fetched_uops,
            branches,
            branch_mispredicts,
            squashed_uops,
            rob_writes,
            iq_writes,
            iq_wakeups,
            regfile_reads,
            regfile_writes,
            lsq_searches,
            l1d_accesses,
            l1d_misses,
            l2_accesses,
            l2_misses,
            spm_accesses,
            dram_reads,
            dram_writes,
            far_reads,
            far_writes,
            far_bytes,
            link_stall_cycles,
            prefetches_issued,
            prefetches_useful,
            mshr_reject_events,
            aloads,
            astores,
            getfins,
            getfin_misses,
            id_batch_fetches,
            amu_subrequests,
            amu_speculative_rollbacks,
            amart_full_events,
            stale_completions,
        );
        for i in 0..NUM_REGIONS {
            self.region_cycles[i] += k * (self.region_cycles[i] - before.region_cycles[i]);
            self.region_uops[i] += k * (self.region_uops[i] - before.region_uops[i]);
        }
    }

    /// True when a tick left every histogram and every time-weighted level
    /// untouched — the part of `Stats` that [`Stats::fold_idle`] cannot
    /// replicate. A genuine fixed-point tick always satisfies this; the
    /// fast-forward path checks it as a defense before folding.
    pub fn hists_and_levels_unchanged(&self, before: &Stats) -> bool {
        self.far_read_latency.count == before.far_read_latency.count
            && self.sync_load_latency.count == before.sync_load_latency.count
            && self.ami_completion_latency.count == before.ami_completion_latency.count
            && self.rob_occ.current() == before.rob_occ.current()
            && self.iq_occ.current() == before.iq_occ.current()
            && self.lq_occ.current() == before.lq_occ.current()
            && self.sq_occ.current() == before.sq_occ.current()
            && self.l1d_mshr_occ.current() == before.l1d_mshr_occ.current()
            && self.l2_mshr_occ.current() == before.l2_mshr_occ.current()
            && self.far_inflight.current() == before.far_inflight.current()
            && self.amu_inflight.current() == before.amu_inflight.current()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrator_average() {
        let mut i = Integrator::default();
        i.update(0, 2); // value 2 during [0,10)
        i.update(10, 4); // value 4 during [10,20)
        assert!((i.average(20) - 3.0).abs() < 1e-12);
        assert_eq!(i.max, 4);
        assert_eq!(i.current(), 4);
    }

    #[test]
    fn integrator_add_saturates_at_zero() {
        let mut i = Integrator::default();
        i.add(0, 1);
        i.add(5, -3);
        assert_eq!(i.current(), 0);
    }

    #[test]
    fn integrator_tail_extension() {
        let mut i = Integrator::default();
        i.update(0, 10);
        // no update since cycle 0; average over 100 cycles is still 10
        assert!((i.average(100) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn hist_mean_and_percentile() {
        let mut h = Hist::default();
        for v in [1u64, 2, 4, 8, 1000] {
            h.add(v);
        }
        assert_eq!(h.count, 5);
        assert!((h.mean() - 203.0).abs() < 1.0);
        assert!(h.percentile(50.0) <= 8);
        assert!(h.percentile(100.0) >= 1000 || h.percentile(100.0) == h.max);
        assert_eq!(h.min, 1);
        assert_eq!(h.max, 1000);
    }

    #[test]
    fn ipc_and_mlp() {
        let mut s = Stats::default();
        s.measured_cycles = 100;
        s.measured_insts = 250;
        assert!((s.ipc() - 2.5).abs() < 1e-12);
        s.cycles = 100;
        s.far_inflight.update(0, 8);
        assert!((s.mlp() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn fold_idle_replicates_explicit_ticks() {
        // Simulate an "idle retry" tick that bumps a few counters by fixed
        // deltas, and check the closed-form fold equals ticking k more times.
        let tick = |s: &mut Stats| {
            s.lsq_searches += 3;
            s.l1d_accesses += 2;
            s.mshr_reject_events += 2;
            s.getfins += 1;
            s.measured_cycles += 1;
            s.region_cycles[Region::Main as usize] += 1;
        };
        let mut folded = Stats::default();
        folded.lsq_searches = 10; // pre-existing totals
        let mut explicit = folded.clone();

        let before = folded.clone();
        tick(&mut folded);
        assert!(folded.hists_and_levels_unchanged(&before));
        folded.fold_idle(7, &before);

        for _ in 0..8 {
            tick(&mut explicit);
        }
        assert_eq!(folded, explicit);
    }

    #[test]
    fn hists_and_levels_unchanged_detects_changes() {
        let base = Stats::default();
        let mut h = base.clone();
        h.far_read_latency.add(100);
        assert!(!h.hists_and_levels_unchanged(&base));
        let mut l = base.clone();
        l.rob_occ.update(5, 3);
        assert!(!l.hists_and_levels_unchanged(&base));
        assert!(base.clone().hists_and_levels_unchanged(&base));
    }

    #[test]
    fn region_fraction() {
        let mut s = Stats::default();
        s.region_cycles[Region::Main as usize] = 90;
        s.region_cycles[Region::Disambig as usize] = 10;
        assert!((s.region_fraction(Region::Disambig) - 0.1).abs() < 1e-12);
    }
}
