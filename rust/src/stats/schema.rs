//! The scenario-metric schema: per-backend diagnostic columns.
//!
//! Every far-memory backend can export scenario counters (near-tier hits,
//! pool congestion, ...) without a matching mechanism in the others. This
//! module is the single registry of those columns: [`ScenarioCol`] names
//! them, [`SCENARIO_COLUMNS`] carries their stable CSV name, unit, and
//! producing backend, and [`ScenarioStats`] stores one value per column in
//! schema order.
//!
//! **Adding a scenario metric is two adjacent edits in this file** — a
//! [`ScenarioCol`] variant and its [`SCENARIO_COLUMNS`] row — plus the
//! backend that produces it. The CSV schema, the v4 sweep cache, the
//! `--columns` report selector, and the schema hash all derive from this
//! table; nothing else needs to change (the cache schema hash changes
//! automatically, invalidating stale files with a migration error).

/// One per-backend scenario column, in stable schema order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioCol {
    /// `hybrid`: accesses served by the near tier.
    NearHits,
    /// `hybrid` (LRU capacity model): near-tier lines evicted.
    NearEvictions,
    /// `pooled`: requests delayed by a full channel queue.
    PoolCongestion,
    /// `pooled`/`adaptive`: channel-policy switches (hash -> least-loaded)
    /// triggered by observed congestion.
    PoolSwitches,
}

/// Descriptor of one scenario column: stable CSV name, unit, and the
/// backend that produces it (every other backend reports zero).
pub struct ScenarioDef {
    pub col: ScenarioCol,
    pub name: &'static str,
    pub unit: &'static str,
    pub producer: &'static str,
}

/// The scenario column table — the single source of truth for per-backend
/// metric columns. Order is the CSV column order.
pub const SCENARIO_COLUMNS: &[ScenarioDef] = &[
    ScenarioDef { col: ScenarioCol::NearHits, name: "near_hits", unit: "count", producer: "hybrid" },
    ScenarioDef {
        col: ScenarioCol::NearEvictions,
        name: "near_evictions",
        unit: "count",
        producer: "hybrid",
    },
    ScenarioDef {
        col: ScenarioCol::PoolCongestion,
        name: "pool_congestion",
        unit: "count",
        producer: "pooled",
    },
    ScenarioDef {
        col: ScenarioCol::PoolSwitches,
        name: "pool_switches",
        unit: "count",
        producer: "pooled",
    },
];

/// Number of scenario columns (sizes [`ScenarioStats`]).
pub const NUM_SCENARIO_COLS: usize = SCENARIO_COLUMNS.len();

impl ScenarioCol {
    /// This column's position in schema order.
    pub fn index(self) -> usize {
        SCENARIO_COLUMNS
            .iter()
            .position(|d| d.col == self)
            .expect("every ScenarioCol variant has a SCENARIO_COLUMNS row")
    }

    /// This column's schema descriptor.
    pub fn def(self) -> &'static ScenarioDef {
        &SCENARIO_COLUMNS[self.index()]
    }
}

/// Backend scenario counters, one value per [`SCENARIO_COLUMNS`] entry in
/// schema order. Harvested into [`crate::stats::Stats`] at the end of a
/// run and carried on every `RunResult`; backends without a given
/// mechanism report zero.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScenarioStats {
    vals: [u64; NUM_SCENARIO_COLS],
}

impl ScenarioStats {
    pub fn get(&self, c: ScenarioCol) -> u64 {
        self.vals[c.index()]
    }

    pub fn set(&mut self, c: ScenarioCol, v: u64) {
        self.vals[c.index()] = v;
    }

    /// Builder-style `set` for literal construction in backends and tests.
    pub fn with(mut self, c: ScenarioCol, v: u64) -> Self {
        self.set(c, v);
        self
    }

    /// Values in schema order (parallel to [`SCENARIO_COLUMNS`]).
    pub fn values(&self) -> &[u64; NUM_SCENARIO_COLS] {
        &self.vals
    }

    /// Set by schema position (CSV parsing).
    pub fn set_index(&mut self, i: usize, v: u64) {
        self.vals[i] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_has_a_table_row_and_stable_index() {
        for (i, d) in SCENARIO_COLUMNS.iter().enumerate() {
            assert_eq!(d.col.index(), i, "{}", d.name);
            assert_eq!(d.col.def().name, d.name);
        }
        // Names are unique (CSV columns must not collide).
        for a in SCENARIO_COLUMNS {
            assert_eq!(
                SCENARIO_COLUMNS.iter().filter(|b| b.name == a.name).count(),
                1,
                "duplicate scenario column '{}'",
                a.name
            );
        }
    }

    #[test]
    fn stats_get_set_round_trip() {
        let s = ScenarioStats::default()
            .with(ScenarioCol::NearHits, 7)
            .with(ScenarioCol::PoolCongestion, 42);
        assert_eq!(s.get(ScenarioCol::NearHits), 7);
        assert_eq!(s.get(ScenarioCol::NearEvictions), 0);
        assert_eq!(s.get(ScenarioCol::PoolCongestion), 42);
        assert_eq!(s.values()[ScenarioCol::NearHits.index()], 7);
        let mut t = ScenarioStats::default();
        t.set_index(ScenarioCol::PoolCongestion.index(), 42);
        t.set(ScenarioCol::NearHits, 7);
        assert_eq!(s, t);
    }
}
