//! The scenario-metric schema: per-backend diagnostic columns.
//!
//! Every far-memory backend can export scenario counters (near-tier hits,
//! pool congestion, ...) without a matching mechanism in the others. This
//! module is the single registry of those columns: [`ScenarioCol`] names
//! them, [`SCENARIO_COLUMNS`] carries their stable CSV name, unit, and
//! producing backend, and [`ScenarioStats`] stores one value per column in
//! schema order.
//!
//! **Adding a scenario metric is two adjacent edits in this file** — a
//! [`ScenarioCol`] variant and its [`SCENARIO_COLUMNS`] row — plus the
//! backend that produces it. The CSV schema, the v5 sweep cache, the
//! `--columns` report selector, and the schema hash all derive from this
//! table; nothing else needs to change (the cache schema hash changes
//! automatically, invalidating stale files with a migration error).

/// One per-backend scenario column, in stable schema order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioCol {
    /// `hybrid`: accesses served by the near tier.
    NearHits,
    /// `hybrid` (LRU capacity model): near-tier lines evicted.
    NearEvictions,
    /// `pooled`: requests delayed by a full channel queue.
    PoolCongestion,
    /// `pooled`/`adaptive`: channel-policy switches (hash -> least-loaded)
    /// triggered by observed congestion.
    PoolSwitches,
    /// Shared backend (`mtrun`): worst per-tenant slowdown vs the tenant's
    /// solo run, in permille (1000 = no slowdown). Stamped on every row of
    /// a multi-tenant cell; zero in single-tenant runs.
    TenantSlowdownMax,
    /// Shared backend: QoS `throttle` activations plus enforced delays.
    QosThrottleEvents,
    /// Shared backend: total cycles tenants spent stalled in QoS
    /// arbitration (bandwidth "stolen" by co-tenants).
    PoolStealCycles,
}

/// How a scenario column combines when rows are merged (multi-tenant cells
/// re-stamp one shared snapshot; accumulation folds per-shard snapshots).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Merge {
    /// Additive counter: merged value is the sum.
    Sum,
    /// High-water mark: merged value is the max.
    Max,
}

/// Descriptor of one scenario column: stable CSV name, unit, the backend
/// that produces it (every other backend reports zero), and its merge
/// semantics.
pub struct ScenarioDef {
    pub col: ScenarioCol,
    pub name: &'static str,
    pub unit: &'static str,
    pub producer: &'static str,
    pub merge: Merge,
}

/// The scenario column table — the single source of truth for per-backend
/// metric columns. Order is the CSV column order.
pub const SCENARIO_COLUMNS: &[ScenarioDef] = &[
    ScenarioDef {
        col: ScenarioCol::NearHits,
        name: "near_hits",
        unit: "count",
        producer: "hybrid",
        merge: Merge::Sum,
    },
    ScenarioDef {
        col: ScenarioCol::NearEvictions,
        name: "near_evictions",
        unit: "count",
        producer: "hybrid",
        merge: Merge::Sum,
    },
    ScenarioDef {
        col: ScenarioCol::PoolCongestion,
        name: "pool_congestion",
        unit: "count",
        producer: "pooled",
        merge: Merge::Sum,
    },
    ScenarioDef {
        col: ScenarioCol::PoolSwitches,
        name: "pool_switches",
        unit: "count",
        producer: "pooled",
        merge: Merge::Sum,
    },
    ScenarioDef {
        col: ScenarioCol::TenantSlowdownMax,
        name: "tenant_slowdown_max",
        unit: "permille",
        producer: "shared",
        merge: Merge::Max,
    },
    ScenarioDef {
        col: ScenarioCol::QosThrottleEvents,
        name: "qos_throttle_events",
        unit: "count",
        producer: "shared",
        merge: Merge::Sum,
    },
    ScenarioDef {
        col: ScenarioCol::PoolStealCycles,
        name: "pool_steal_cycles",
        unit: "cycles",
        producer: "shared",
        merge: Merge::Sum,
    },
];

/// Number of scenario columns (sizes [`ScenarioStats`]).
pub const NUM_SCENARIO_COLS: usize = SCENARIO_COLUMNS.len();

impl ScenarioCol {
    /// This column's position in schema order.
    pub fn index(self) -> usize {
        SCENARIO_COLUMNS
            .iter()
            .position(|d| d.col == self)
            .expect("every ScenarioCol variant has a SCENARIO_COLUMNS row")
    }

    /// This column's schema descriptor.
    pub fn def(self) -> &'static ScenarioDef {
        &SCENARIO_COLUMNS[self.index()]
    }
}

/// Backend scenario counters, one value per [`SCENARIO_COLUMNS`] entry in
/// schema order. Harvested into [`crate::stats::Stats`] at the end of a
/// run and carried on every `RunResult`; backends without a given
/// mechanism report zero.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScenarioStats {
    vals: [u64; NUM_SCENARIO_COLS],
}

impl ScenarioStats {
    pub fn get(&self, c: ScenarioCol) -> u64 {
        self.vals[c.index()]
    }

    pub fn set(&mut self, c: ScenarioCol, v: u64) {
        self.vals[c.index()] = v;
    }

    /// Builder-style `set` for literal construction in backends and tests.
    pub fn with(mut self, c: ScenarioCol, v: u64) -> Self {
        self.set(c, v);
        self
    }

    /// Values in schema order (parallel to [`SCENARIO_COLUMNS`]).
    pub fn values(&self) -> &[u64; NUM_SCENARIO_COLS] {
        &self.vals
    }

    /// Set by schema position (CSV parsing).
    pub fn set_index(&mut self, i: usize, v: u64) {
        self.vals[i] = v;
    }

    /// Fold another snapshot into this one, column by column, under each
    /// column's declared [`Merge`] semantics: additive counters sum,
    /// high-water marks take the max.
    pub fn accumulate(&mut self, other: &ScenarioStats) {
        for (i, d) in SCENARIO_COLUMNS.iter().enumerate() {
            self.vals[i] = match d.merge {
                Merge::Sum => self.vals[i].wrapping_add(other.vals[i]),
                Merge::Max => self.vals[i].max(other.vals[i]),
            };
        }
    }

    /// [`accumulate`](Self::accumulate) over any number of snapshots.
    pub fn merged<'a>(snapshots: impl IntoIterator<Item = &'a ScenarioStats>) -> ScenarioStats {
        let mut out = ScenarioStats::default();
        for s in snapshots {
            out.accumulate(s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_variant_has_a_table_row_and_stable_index() {
        for (i, d) in SCENARIO_COLUMNS.iter().enumerate() {
            assert_eq!(d.col.index(), i, "{}", d.name);
            assert_eq!(d.col.def().name, d.name);
        }
        // Names are unique (CSV columns must not collide).
        for a in SCENARIO_COLUMNS {
            assert_eq!(
                SCENARIO_COLUMNS.iter().filter(|b| b.name == a.name).count(),
                1,
                "duplicate scenario column '{}'",
                a.name
            );
        }
    }

    #[test]
    fn stats_get_set_round_trip() {
        let s = ScenarioStats::default()
            .with(ScenarioCol::NearHits, 7)
            .with(ScenarioCol::PoolCongestion, 42);
        assert_eq!(s.get(ScenarioCol::NearHits), 7);
        assert_eq!(s.get(ScenarioCol::NearEvictions), 0);
        assert_eq!(s.get(ScenarioCol::PoolCongestion), 42);
        assert_eq!(s.values()[ScenarioCol::NearHits.index()], 7);
        let mut t = ScenarioStats::default();
        t.set_index(ScenarioCol::PoolCongestion.index(), 42);
        t.set(ScenarioCol::NearHits, 7);
        assert_eq!(s, t);
    }

    #[test]
    fn accumulate_respects_declared_merge_semantics() {
        let a = ScenarioStats::default()
            .with(ScenarioCol::NearHits, 10)
            .with(ScenarioCol::TenantSlowdownMax, 1500)
            .with(ScenarioCol::PoolStealCycles, 100);
        let b = ScenarioStats::default()
            .with(ScenarioCol::NearHits, 5)
            .with(ScenarioCol::TenantSlowdownMax, 1200)
            .with(ScenarioCol::PoolStealCycles, 50);
        let mut m = a;
        m.accumulate(&b);
        // Sum columns add.
        assert_eq!(m.get(ScenarioCol::NearHits), 15);
        assert_eq!(m.get(ScenarioCol::PoolStealCycles), 150);
        // Max columns keep the high-water mark.
        assert_eq!(m.get(ScenarioCol::TenantSlowdownMax), 1500);
        // merged() over a slice matches pairwise accumulate.
        assert_eq!(ScenarioStats::merged([&a, &b]), m);
    }

    #[test]
    fn tenant_columns_are_registered_after_the_backend_columns() {
        // Cache/golden compatibility: the PR 5 columns keep their indices;
        // the shared-tenancy columns append.
        assert_eq!(ScenarioCol::NearHits.index(), 0);
        assert_eq!(ScenarioCol::PoolSwitches.index(), 3);
        assert_eq!(ScenarioCol::TenantSlowdownMax.index(), 4);
        assert_eq!(ScenarioCol::QosThrottleEvents.index(), 5);
        assert_eq!(ScenarioCol::PoolStealCycles.index(), 6);
        assert_eq!(ScenarioCol::TenantSlowdownMax.def().merge, Merge::Max);
    }
}
