//! Property-based testing support (the offline image has no `proptest`).
//!
//! `check` runs a property over many deterministic pseudo-random cases and,
//! on failure, performs greedy input shrinking via a caller-provided
//! shrinker. Generators are plain closures over [`Xoshiro256`]; the runner
//! reports the failing case and the seed needed to replay it.

use crate::util::prng::Xoshiro256;

pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_iters: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 256, seed: 0x5EED_CAFE, max_shrink_iters: 500 }
    }
}

/// Run `prop` on `cases` inputs drawn from `gen`. On failure, shrink with
/// `shrink` (which yields candidate smaller inputs) and panic with the
/// minimal failing case.
pub fn check_with<T, G, P, S>(cfg: &PropConfig, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Xoshiro256::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first smaller failing input.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut iters = 0;
            'outer: loop {
                if iters >= cfg.max_shrink_iters {
                    break;
                }
                for cand in shrink(&best) {
                    iters += 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if iters >= cfg.max_shrink_iters {
                        break 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {}",
                cfg.seed, best, best_msg
            );
        }
    }
}

/// Convenience wrapper without shrinking.
pub fn check<T, G, P>(cfg: &PropConfig, gen: G, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Xoshiro256) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check_with(cfg, gen, prop, |_| Vec::new());
}

/// Shrinker for vectors: halves, then remove-one-element candidates.
pub fn shrink_vec<T: Clone>(v: &Vec<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 16 {
        for i in 0..v.len() {
            let mut c = v.clone();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Shrinker for integers: 0, halves, decrements.
pub fn shrink_u64(v: &u64) -> Vec<u64> {
    let v = *v;
    let mut out = Vec::new();
    if v == 0 {
        return out;
    }
    out.push(0);
    out.push(v / 2);
    out.push(v - 1);
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            &PropConfig { cases: 64, ..Default::default() },
            |rng| rng.below(100),
            |&x| {
                if x < 100 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            check_with(
                &PropConfig { cases: 200, ..Default::default() },
                |rng| rng.below(1000),
                |&x| {
                    if x < 500 {
                        Ok(())
                    } else {
                        Err(format!("{x} >= 500"))
                    }
                },
                shrink_u64,
            );
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload is String"),
            Ok(()) => panic!("property should have failed"),
        };
        // Greedy shrink on x>=500 should land exactly on 500.
        assert!(msg.contains("input: 500"), "unexpected shrink result: {msg}");
    }

    #[test]
    fn vec_shrinker_produces_smaller() {
        let v: Vec<u32> = (0..10).collect();
        for c in shrink_vec(&v) {
            assert!(c.len() < v.len());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        // Two identical runs must see identical inputs: collect them.
        let collect = || {
            let mut seen = Vec::new();
            check(
                &PropConfig { cases: 16, seed: 99, ..Default::default() },
                |rng| rng.next_u64(),
                |&x| {
                    seen.push(x);
                    Ok(())
                },
            );
            seen
        };
        assert_eq!(collect(), collect());
    }
}
