//! Software-based memory disambiguation (paper §5.1, Listing 1).
//!
//! A small cacheable hash table in local DRAM tracks the addresses of
//! in-flight asynchronous requests. `start_access` claims an address
//! before the AMI request chain; a conflicting task is chained onto the
//! owning slot's waiter list and suspends. `end_access` hands the slot to
//! the first waiter (pushing its TCB onto the scheduler's ready ring) or
//! releases it.
//!
//! The paper uses a multi-table cuckoo variant; we use **lock striping**
//! (direct-mapped slot per address hash, chain-on-slot). Same-address
//! requests always meet in the same slot, which makes the scheme trivially
//! correct under any interleaving; hash collisions between *different*
//! addresses cost only a false serialization, and with a table much larger
//! than the in-flight window they are rare — the same low-conflict regime
//! the paper's §5.1 argues from. (DESIGN.md records this substitution.)
//!
//! All emitted code is tagged `Region::Disambig`, so Table 5's overhead
//! measurement falls out of the region cycle attribution.

use super::{CoroRt, OFF_CONT, OFF_NEXT_WAITER, OFF_SAVE, R_CUR_TCB, R_TMP, R_TMP2};
use crate::isa::mem::Layout;
use crate::isa::Asm;
use crate::stats::Region;

const H_MULT: i64 = 0x9E37_79B9_7F4A_7C15u64 as i64;

/// Slot: [claimed: u64][waiter_head: u64] — 16 B.
#[derive(Debug, Clone)]
pub struct DisambigRt {
    pub table_base: u64,
    pub entries: u64, // power of two
    next_label: std::cell::Cell<u32>,
}

impl DisambigRt {
    pub fn new(layout: &mut Layout, entries: u64) -> Self {
        let entries = entries.next_power_of_two().max(16);
        let table_base = layout.alloc_local(entries * 16, 64);
        Self { table_base, entries, next_label: std::cell::Cell::new(0) }
    }

    fn fresh(&self, stem: &str) -> String {
        let n = self.next_label.get();
        self.next_label.set(n + 1);
        format!("dis_{stem}_{n}")
    }

    /// `start_access(addr_reg)`: claims the slot for this address or
    /// suspends until the current owner releases it. Leaves the slot
    /// address in `slot_reg` for the matching `emit_end_access`. `live`
    /// must include every register needed afterwards (including `addr_reg`
    /// and `slot_reg`); constraints: regs ∉ {R_TMP, R_TMP2, R_CUR_TCB}.
    pub fn emit_start_access(
        &self,
        _rt: &CoroRt,
        a: &mut Asm,
        addr_reg: u8,
        slot_reg: u8,
        live: &[u8],
    ) {
        assert!(live.contains(&addr_reg) && live.contains(&slot_reg));
        assert!(live.len() <= super::MAX_SAVES);
        for r in [addr_reg, slot_reg] {
            assert!(![R_TMP, R_TMP2, R_CUR_TCB].contains(&r));
        }
        let l_claim = self.fresh("claim");
        let l_done = self.fresh("done");
        let l_resume = self.fresh("resume");
        a.region(Region::Disambig);
        // slot = base + ((addr * M) >> (64 - log2 E)) * 16
        let shift = 64 - self.entries.trailing_zeros() as i64;
        a.li(slot_reg, H_MULT);
        a.mul(slot_reg, slot_reg, addr_reg);
        a.srli(slot_reg, slot_reg, shift);
        a.slli(slot_reg, slot_reg, 4);
        a.li(R_TMP, self.table_base as i64);
        a.add(slot_reg, slot_reg, R_TMP);
        a.ld64(R_TMP, slot_reg, 0);
        a.beq(R_TMP, 0, &l_claim);
        // Conflict: chain self onto the slot's waiter list and suspend.
        a.ld64(R_TMP, slot_reg, 8); // old waiter head
        a.st64(R_TMP, R_CUR_TCB, OFF_NEXT_WAITER);
        a.st64(R_CUR_TCB, slot_reg, 8);
        for (i, &r) in live.iter().enumerate() {
            a.st64(r, R_CUR_TCB, OFF_SAVE + (i as i64) * 8);
        }
        a.li_label(R_TMP2, &l_resume);
        a.st64(R_TMP2, R_CUR_TCB, OFF_CONT);
        a.j("co_dispatch");
        a.label(&l_resume);
        for (i, &r) in live.iter().enumerate() {
            a.ld64(r, R_CUR_TCB, OFF_SAVE + (i as i64) * 8);
        }
        // Woken by end_access: slot ownership was transferred to us.
        a.j(&l_done);

        a.label(&l_claim);
        a.li(R_TMP, 1);
        a.st64(R_TMP, slot_reg, 0);
        a.label(&l_done);
        a.region(Region::Main);
    }

    /// `end_access(slot_reg)`: release the slot claimed by
    /// `emit_start_access`. Wakes one waiter via the scheduler's ready
    /// ring (ownership transfer) or clears the claim. Clobbers `slot_reg`.
    pub fn emit_end_access(&self, rt: &CoroRt, a: &mut Asm, slot_reg: u8) {
        let l_wake = self.fresh("wake");
        let l_done = self.fresh("edone");
        a.region(Region::Disambig);
        a.ld64(R_TMP, slot_reg, 8); // waiter head
        a.bne(R_TMP, 0, &l_wake);
        // No waiters: clear the claim.
        a.st64(0, slot_reg, 0);
        a.j(&l_done);
        a.label(&l_wake);
        // Pop head waiter (R_TMP = its TCB); slot stays claimed.
        a.ld64(R_TMP2, R_TMP, OFF_NEXT_WAITER);
        a.st64(R_TMP2, slot_reg, 8);
        // ready ring: slots[tail & mask] = tcb; tail++
        a.li(R_TMP2, rt.ready_base as i64);
        a.ld64(slot_reg, R_TMP2, 8); // tail
        a.andi(slot_reg, slot_reg, (rt.ready_cap - 1) as i64);
        a.slli(slot_reg, slot_reg, 3);
        a.add(slot_reg, slot_reg, R_TMP2);
        a.st64(R_TMP, slot_reg, 16);
        a.ld64(slot_reg, R_TMP2, 8);
        a.addi(slot_reg, slot_reg, 1);
        a.st64(slot_reg, R_TMP2, 8);
        a.label(&l_done);
        a.region(Region::Main);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::coro::CoroRt;
    use crate::isa::mem::SPM_BASE;
    use crate::isa::CfgReg;
    use crate::sim::Simulator;

    /// N tasks all read-modify-write a SINGLE shared far counter through
    /// aload/astore with disambiguation. Without it, lost updates would
    /// occur; with it, the final counter must equal N (each task +1).
    fn build_shared_counter(ntasks: usize, latency_ns: f64) -> (Simulator, u64) {
        let mut cfg = SimConfig::amu().with_far_latency_ns(latency_ns);
        cfg.far.jitter_frac = 0.0;
        let meta = cfg.amu.queue_length as u64 * 32;
        let spm_data = cfg.amu.spm_bytes as u64 - meta;
        let mut layout = Layout::new(spm_data as usize);
        let rt = CoroRt::new(&mut layout, ntasks, cfg.amu.queue_length);
        let dis = DisambigRt::new(&mut layout, 64);
        let counter = layout.alloc_far(8, 64);

        let mut a = Asm::new("shared-counter");
        a.li(1, 8);
        a.cfgwr(1, CfgReg::Granularity);
        rt.emit_prologue(&mut a);
        a.roi_begin();
        a.j("sched");
        a.label("task");
        rt.emit_load_param(&mut a, 10, 0); // far counter addr
        rt.emit_load_param(&mut a, 11, 1); // spm slot
        // Claim the address (suspends on conflict). r12 = slot ptr.
        dis.emit_start_access(&rt, &mut a, 10, 12, &[10, 11, 12]);
        a.aload(13, 11, 10);
        rt.emit_await(&mut a, 13, &[10, 11, 12], "t_r1");
        a.ld64(14, 11, 0);
        a.addi(14, 14, 1);
        a.st64(14, 11, 0);
        a.ld64(14, 11, 0);
        a.astore(15, 11, 10);
        rt.emit_await(&mut a, 15, &[10, 11, 12], "t_r2");
        dis.emit_end_access(&rt, &mut a, 12);
        rt.emit_task_finish(&mut a);
        a.label("sched");
        rt.emit_scheduler(&mut a, "done");
        a.label("done");
        a.roi_end();
        a.halt();
        let prog = a.finish();

        let mut sim = Simulator::new(cfg, prog.clone());
        rt.write_tcbs(&mut sim.guest, &prog, "task", |tid| {
            [counter, SPM_BASE + tid as u64 * 64, 0, 0]
        });
        (sim, counter)
    }

    #[test]
    fn shared_counter_no_lost_updates() {
        let n = 24;
        let (mut sim, counter) = build_shared_counter(n, 500.0);
        sim.run().expect("run");
        assert_eq!(
            sim.guest.read_u64(counter),
            n as u64,
            "disambiguation must serialize conflicting RMWs"
        );
        assert!(sim.amu_ids_conserved());
    }

    #[test]
    fn disambig_overhead_is_measured() {
        let (mut sim, _) = build_shared_counter(16, 500.0);
        sim.run().unwrap();
        let frac = sim.stats.region_fraction(crate::stats::Region::Disambig);
        assert!(frac > 0.0, "disambiguation cycles must be attributed");
    }

    /// Distinct addresses must not serialize.
    #[test]
    fn distinct_addresses_run_parallel() {
        let ntasks = 32;
        let mut cfg = SimConfig::amu().with_far_latency_ns(2000.0);
        cfg.far.jitter_frac = 0.0;
        let meta = cfg.amu.queue_length as u64 * 32;
        let mut layout = Layout::new((cfg.amu.spm_bytes as u64 - meta) as usize);
        let rt = CoroRt::new(&mut layout, ntasks, cfg.amu.queue_length);
        let dis = DisambigRt::new(&mut layout, 4096);
        let arr = layout.alloc_far(ntasks as u64 * 64, 64);

        let mut a = Asm::new("parallel");
        a.li(1, 8);
        a.cfgwr(1, CfgReg::Granularity);
        rt.emit_prologue(&mut a);
        a.roi_begin();
        a.j("sched");
        a.label("task");
        rt.emit_load_param(&mut a, 10, 0);
        rt.emit_load_param(&mut a, 11, 1);
        dis.emit_start_access(&rt, &mut a, 10, 12, &[10, 11, 12]);
        a.aload(13, 11, 10);
        rt.emit_await(&mut a, 13, &[10, 11, 12], "p_r1");
        dis.emit_end_access(&rt, &mut a, 12);
        rt.emit_task_finish(&mut a);
        a.label("sched");
        rt.emit_scheduler(&mut a, "done");
        a.label("done");
        a.roi_end();
        a.halt();
        let prog = a.finish();

        let mut sim = Simulator::new(cfg, prog.clone());
        rt.write_tcbs(&mut sim.guest, &prog, "task", |tid| {
            [arr + tid as u64 * 64, SPM_BASE + tid as u64 * 64, 0, 0]
        });
        sim.run().expect("run");
        // Serial would be ≥ 32 × 6000 cycles; parallel far less.
        assert!(
            sim.cycle < 60_000,
            "distinct addresses must overlap: {} cycles",
            sim.cycle
        );
        assert!(sim.stats.far_inflight.max >= 16);
    }
}
