//! Guest-side coroutine runtime (the paper's §5.2 framework, here emitted
//! as guest assembly by a builder instead of C++20 coroutines).
//!
//! Every AMU workload runs N lightweight tasks over a scheduler whose event
//! loop is exactly the paper's Figure 4 flow: a task issues `aload`,
//! registers itself in a waiters table keyed by request ID, and suspends;
//! the scheduler `getfin`s completed IDs and resumes the owning task.
//! Context save/restore costs are real instructions, so scheduler overhead
//! shows up in IPC and power exactly as the paper discusses.
//!
//! Memory layout (local DRAM):
//! * TCB array — one 128 B block per task:
//!   `[cont_pc][param0..3][save0..7][next_waiter][pad...]`
//! * waiters table — `queue_length+1` words: request id -> TCB address.
//! * ready ring — TCBs unblocked by the disambiguation layer (see
//!   `disambig`), drained by the scheduler before polling.
//!
//! Register conventions (tasks must not touch r56–r63 except via helpers):
//! r56 = TCB base, r57 = waiters base, r58 = current TCB, r59 = spawn
//! cursor, r60 = finished-task count, r61 = task count, r62/r63 = scratch.

pub mod disambig;

use crate::isa::mem::Layout;
use crate::isa::{Asm, GuestMem};
use crate::stats::Region;

pub const R_TCB_BASE: u8 = 56;
pub const R_WAITERS: u8 = 57;
pub const R_CUR_TCB: u8 = 58;
pub const R_SPAWN: u8 = 59;
pub const R_FINISHED: u8 = 60;
pub const R_NTASKS: u8 = 61;
pub const R_TMP: u8 = 62;
pub const R_TMP2: u8 = 63;

pub const TCB_SHIFT: u64 = 7; // 128 B per TCB
pub const TCB_BYTES: u64 = 1 << TCB_SHIFT;
pub const OFF_CONT: i64 = 0;
pub const OFF_PARAM: i64 = 8; // 4 params
pub const OFF_SAVE: i64 = 40; // 8 save slots
pub const OFF_NEXT_WAITER: i64 = 104;

pub const MAX_PARAMS: usize = 4;
pub const MAX_SAVES: usize = 8;

#[derive(Debug, Clone)]
pub struct CoroRt {
    pub ntasks: usize,
    pub tcb_base: u64,
    pub waiters_base: u64,
    pub ready_base: u64,
    pub ready_cap: u64, // power of two
}

impl CoroRt {
    pub fn new(layout: &mut Layout, ntasks: usize, queue_length: usize) -> Self {
        assert!(ntasks >= 1);
        // Each task holds at most one outstanding request, but up to three
        // LVR batches of IDs (~93) can be parked at the ALSU or in flight
        // between ALSU and ASMC at any instant; without this headroom an
        // allocation can transiently fail and strand a task.
        assert!(
            ntasks + 93 <= queue_length,
            "more tasks ({ntasks}) than AMART entries ({queue_length}) minus \
             batching headroom: ID allocation could fail"
        );
        let tcb_base = layout.alloc_local(ntasks as u64 * TCB_BYTES, 64);
        let waiters_base = layout.alloc_local((queue_length as u64 + 1) * 8, 64);
        let ready_cap = (ntasks as u64 + 1).next_power_of_two();
        // ready ring: [head][tail][slots...]
        let ready_base = layout.alloc_local(16 + ready_cap * 8, 64);
        Self { ntasks, tcb_base, waiters_base, ready_base, ready_cap }
    }

    pub fn tcb_addr(&self, tid: usize) -> u64 {
        self.tcb_base + (tid as u64) * TCB_BYTES
    }

    /// Host-side TCB initialization: continuation label is resolved after
    /// assembly via `Program::labels`, so write TCBs with the *entry label
    /// name* through [`CoroRt::write_tcbs`].
    pub fn write_tcbs(
        &self,
        mem: &mut GuestMem,
        prog: &crate::isa::Program,
        entry_label: &str,
        params: impl Fn(usize) -> [u64; MAX_PARAMS],
    ) {
        let entry = prog
            .labels
            .iter()
            .find(|(n, _)| n == entry_label)
            .unwrap_or_else(|| panic!("entry label '{entry_label}' not found"))
            .1 as u64;
        for tid in 0..self.ntasks {
            let tcb = self.tcb_addr(tid);
            mem.write_u64(tcb, entry);
            let p = params(tid);
            for (i, v) in p.iter().enumerate() {
                mem.write_u64(tcb + OFF_PARAM as u64 + (i as u64) * 8, *v);
            }
            mem.write_u64(tcb + OFF_NEXT_WAITER as u64, 0);
        }
        // Clear ready ring head/tail.
        mem.write_u64(self.ready_base, 0);
        mem.write_u64(self.ready_base + 8, 0);
    }

    /// Emit runtime register setup. Call before `emit_scheduler`.
    pub fn emit_prologue(&self, a: &mut Asm) {
        a.region(Region::Scheduler);
        a.li(R_TCB_BASE, self.tcb_base as i64);
        a.li(R_WAITERS, self.waiters_base as i64);
        a.li(R_SPAWN, 0);
        a.li(R_FINISHED, 0);
        a.li(R_NTASKS, self.ntasks as i64);
        a.region(Region::Main);
    }

    /// Emit the scheduler event loop. Control flow:
    /// ready-ring pop > spawn next task > getfin poll. Falls through to
    /// `done_label` when all tasks finished. Tasks are entered via `jalr`.
    pub fn emit_scheduler(&self, a: &mut Asm, done_label: &str) {
        a.region(Region::Scheduler);
        a.label("co_dispatch");
        // 1. Ready ring (disambiguation wakeups) has priority.
        a.li(R_TMP, self.ready_base as i64);
        a.ld64(R_TMP2, R_TMP, 0); // head
        a.ld64(R_TMP, R_TMP, 8); // tail
        a.bne(R_TMP2, R_TMP, "co_pop_ready");
        // 2. Spawn phase.
        a.blt(R_SPAWN, R_NTASKS, "co_spawn");
        // 3. All done?
        a.beq(R_FINISHED, R_NTASKS, "co_all_done");
        // 4. Poll for a completed request.
        a.getfin(R_TMP);
        a.beq(R_TMP, 0, "co_dispatch");
        // waiters[id] -> TCB
        a.slli(R_TMP, R_TMP, 3);
        a.add(R_TMP, R_TMP, R_WAITERS);
        a.ld64(R_CUR_TCB, R_TMP, 0);
        a.ld64(R_TMP2, R_CUR_TCB, OFF_CONT);
        a.jalr(0, R_TMP2); // resume task (returns via j co_dispatch)
        // (not reached)
        a.j("co_dispatch");

        a.label("co_pop_ready");
        // tcb = slots[head & (cap-1)]; head++
        a.li(R_TMP, self.ready_base as i64);
        a.ld64(R_TMP2, R_TMP, 0); // head
        a.andi(R_CUR_TCB, R_TMP2, (self.ready_cap - 1) as i64);
        a.slli(R_CUR_TCB, R_CUR_TCB, 3);
        a.add(R_CUR_TCB, R_CUR_TCB, R_TMP);
        a.ld64(R_CUR_TCB, R_CUR_TCB, 16);
        a.addi(R_TMP2, R_TMP2, 1);
        a.st64(R_TMP2, R_TMP, 0);
        a.ld64(R_TMP2, R_CUR_TCB, OFF_CONT);
        a.jalr(0, R_TMP2);
        a.j("co_dispatch");

        a.label("co_spawn");
        a.slli(R_CUR_TCB, R_SPAWN, TCB_SHIFT as i64);
        a.add(R_CUR_TCB, R_CUR_TCB, R_TCB_BASE);
        a.addi(R_SPAWN, R_SPAWN, 1);
        a.ld64(R_TMP2, R_CUR_TCB, OFF_CONT);
        a.jalr(0, R_TMP2);
        a.j("co_dispatch");

        a.label("co_all_done");
        a.j(done_label);
        a.region(Region::Main);
    }

    /// Emit a task-entry parameter load from the current TCB.
    pub fn emit_load_param(&self, a: &mut Asm, rd: u8, idx: usize) {
        assert!(idx < MAX_PARAMS);
        a.ld64(rd, R_CUR_TCB, OFF_PARAM + (idx as i64) * 8);
    }

    /// Suspend the current task until request `id_reg` completes:
    /// saves `live` registers (≤8), registers in the waiters table, and
    /// jumps to the scheduler. Control resumes at `resume` with the live
    /// set restored.
    pub fn emit_await(&self, a: &mut Asm, id_reg: u8, live: &[u8], resume: &str) {
        assert!(live.len() <= MAX_SAVES);
        assert!(id_reg != R_TMP2 && id_reg != R_CUR_TCB);
        a.region(Region::Scheduler);
        for (i, &r) in live.iter().enumerate() {
            a.st64(r, R_CUR_TCB, OFF_SAVE + (i as i64) * 8);
        }
        a.li_label(R_TMP2, resume);
        a.st64(R_TMP2, R_CUR_TCB, OFF_CONT);
        // waiters[id] = tcb
        a.slli(R_TMP2, id_reg, 3);
        a.add(R_TMP2, R_TMP2, R_WAITERS);
        a.st64(R_CUR_TCB, R_TMP2, 0);
        a.j("co_dispatch");
        a.label(resume);
        for (i, &r) in live.iter().enumerate() {
            a.ld64(r, R_CUR_TCB, OFF_SAVE + (i as i64) * 8);
        }
        a.region(Region::Main);
    }

    /// Emit task termination: bump the finished counter and return to the
    /// scheduler.
    pub fn emit_task_finish(&self, a: &mut Asm) {
        a.region(Region::Scheduler);
        a.addi(R_FINISHED, R_FINISHED, 1);
        a.j("co_dispatch");
        a.region(Region::Main);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::isa::mem::{FAR_BASE, SPM_BASE};
    use crate::sim::Simulator;

    /// N tasks each aload one far word into their SPM slot, add 1, and
    /// astore it back. The archetypal AMU workload shape.
    fn build_incr_workload(ntasks: usize, latency_ns: f64) -> Simulator {
        let mut cfg = SimConfig::amu().with_far_latency_ns(latency_ns);
        cfg.far.jitter_frac = 0.0;
        let meta = cfg.amu.queue_length as u64 * 32;
        let spm_data = cfg.amu.spm_bytes as u64 - meta;
        let mut layout = Layout::new(spm_data as usize);
        let rt = CoroRt::new(&mut layout, ntasks, cfg.amu.queue_length);
        let far = layout.alloc_far(ntasks as u64 * 8, 64);

        let mut a = Asm::new("coro-incr");
        a.li(1, 8);
        a.cfgwr(1, crate::isa::CfgReg::Granularity);
        rt.emit_prologue(&mut a);
        a.roi_begin();
        a.j("sched");
        a.label("task");
        // params: p0 = far addr, p1 = spm slot addr
        rt.emit_load_param(&mut a, 10, 0);
        rt.emit_load_param(&mut a, 11, 1);
        a.aload(12, 11, 10);
        rt.emit_await(&mut a, 12, &[10, 11], "task_r1");
        a.ld64(13, 11, 0);
        a.addi(13, 13, 1);
        a.st64(13, 11, 0);
        a.ld64(13, 11, 0); // ensure the SPM write is architecturally done
        a.astore(14, 11, 10);
        rt.emit_await(&mut a, 14, &[], "task_r2");
        rt.emit_task_finish(&mut a);
        a.label("sched");
        rt.emit_scheduler(&mut a, "done");
        a.label("done");
        a.roi_end();
        a.halt();
        let prog = a.finish();

        let mut sim = Simulator::new(cfg, prog.clone());
        for t in 0..ntasks {
            sim.guest.write_u64(far + t as u64 * 8, 1000 + t as u64);
        }
        let spm_slots = SPM_BASE;
        rt.write_tcbs(&mut sim.guest, &prog, "task", |tid| {
            [far + tid as u64 * 8, spm_slots + tid as u64 * 64, 0, 0]
        });
        sim
    }

    #[test]
    fn coro_increment_workload_correct() {
        let ntasks = 32;
        let mut sim = build_incr_workload(ntasks, 1000.0);
        sim.run().expect("run");
        for t in 0..ntasks as u64 {
            let v = sim.guest.read_u64(FAR_BASE + t * 8);
            assert_eq!(v, 1001 + t, "task {t} must increment its word");
        }
        assert!(sim.amu_ids_conserved());
    }

    #[test]
    fn coroutines_overlap_latency() {
        // 64 tasks at 2 us: serial would be ≥ 64 * 2 * 6000 = 768k cycles.
        // Interleaved coroutines must overlap nearly all of it.
        let mut sim = build_incr_workload(64, 2000.0);
        sim.run().expect("run");
        assert!(
            sim.cycle < 120_000,
            "coroutines failed to overlap: {} cycles",
            sim.cycle
        );
        assert!(
            sim.stats.far_inflight.max >= 32,
            "peak MLP too low: {}",
            sim.stats.far_inflight.max
        );
    }

    #[test]
    fn mlp_scales_with_task_count() {
        let mut small = build_incr_workload(8, 2000.0);
        small.run().unwrap();
        let mut big = build_incr_workload(128, 2000.0);
        big.run().unwrap();
        let mlp_small = small.stats.mlp();
        let mlp_big = big.stats.mlp();
        assert!(
            mlp_big > mlp_small * 2.0,
            "MLP should scale with coroutines: {mlp_small:.1} -> {mlp_big:.1}"
        );
    }

    #[test]
    fn scheduler_cycles_attributed() {
        let mut sim = build_incr_workload(32, 500.0);
        sim.run().unwrap();
        let sched = sim.stats.region_fraction(crate::stats::Region::Scheduler);
        assert!(sched > 0.01, "scheduler region must be visible: {sched}");
    }

    #[test]
    #[should_panic(expected = "more tasks")]
    fn too_many_tasks_rejected() {
        let mut layout = Layout::new(32 * 1024);
        let _ = CoroRt::new(&mut layout, 600, 512);
    }
}
