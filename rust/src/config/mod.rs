//! Simulator configuration: the paper's Table 2 machine, the four evaluated
//! configurations, hardware-scaled variants (Fig 3), and a TOML-lite
//! override mechanism so experiments are reproducible from files.

use crate::util::toml_lite::Document;

/// Out-of-order core parameters (paper Table 2: Golden-Cove-like).
#[derive(Debug, Clone)]
pub struct CoreConfig {
    pub freq_ghz: f64,
    pub fetch_width: usize,
    pub decode_width: usize,
    pub issue_width: usize,
    pub commit_width: usize,
    /// Frontend pipeline depth fetch->dispatch (mispredict redirect cost).
    pub frontend_depth: usize,
    pub rob_entries: usize,
    pub iq_entries: usize,
    pub lq_entries: usize,
    pub sq_entries: usize,
    pub phys_regs: usize,
    /// Post-commit store buffer entries (drain to L1D).
    pub store_buffer: usize,
    pub alu_units: usize,
    pub mul_units: usize,
    pub mem_ports: usize,
    pub mul_latency: u64,
    /// Branch predictor: gshare table bits and BTB entries.
    pub bp_table_bits: usize,
    pub btb_entries: usize,
}

impl Default for CoreConfig {
    fn default() -> Self {
        Self {
            freq_ghz: 3.0,
            fetch_width: 6,
            decode_width: 6,
            issue_width: 6,
            commit_width: 6,
            frontend_depth: 5,
            rob_entries: 512,
            iq_entries: 160,
            lq_entries: 128,
            sq_entries: 64,
            phys_regs: 512,
            store_buffer: 56,
            alu_units: 4,
            mul_units: 2,
            mem_ports: 2,
            mul_latency: 3,
            bp_table_bits: 14,
            btb_entries: 2048,
        }
    }
}

/// One cache level.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub ways: usize,
    pub line_bytes: usize,
    pub mshrs: usize,
    pub hit_latency: u64,
    /// Max demand accesses accepted per cycle.
    pub ports: usize,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }
}

/// Local DRAM (DDR4-2400-like, simplified bank model).
#[derive(Debug, Clone)]
pub struct DramConfig {
    pub banks: usize,
    /// Row-buffer hit / miss service times in ns.
    pub row_hit_ns: f64,
    pub row_miss_ns: f64,
    pub row_bytes: usize,
    /// Peak data bandwidth in GB/s (64B transfer serialization).
    pub bandwidth_gbps: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            banks: 32,
            row_hit_ns: 15.0,
            row_miss_ns: 45.0,
            row_bytes: 8192,
            bandwidth_gbps: 19.2,
        }
    }
}

/// Which data-plane model serves far-memory accesses (`mem::backend`).
///
/// The paper's evaluation uses a single CXL-like serial link, but its core
/// premise — far latencies are "significantly longer and *more variable*
/// than local DRAM" — spans a whole family of data planes: disaggregated
/// pools, RDMA/swap hybrids, packetized asynchronous DRAM. Each variant
/// here is one such scenario; `SerialLink` stays the default and preserves
/// the paper's Figure 7 model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FarBackendKind {
    /// CXL-like serial link with a remote memory controller (the default).
    #[default]
    SerialLink,
    /// Multi-channel disaggregated memory pool: per-channel service queues
    /// with congestion back-pressure.
    Pooled,
    /// Propagation latency sampled per request from a configurable
    /// lognormal/bimodal distribution whose *mean* is the configured
    /// latency (tail-latency scenarios).
    Distribution,
    /// Fast-path/slow-path split: a configurable fraction of accesses hit
    /// a near tier (RDMA/swap hybrid data planes).
    Hybrid,
}

impl FarBackendKind {
    pub const ALL: &'static [FarBackendKind] = &[
        FarBackendKind::SerialLink,
        FarBackendKind::Pooled,
        FarBackendKind::Distribution,
        FarBackendKind::Hybrid,
    ];

    /// Stable spelling used in sweep axes, CSV rows, and the CLI.
    pub fn tag(&self) -> &'static str {
        match self {
            FarBackendKind::SerialLink => "serial-link",
            FarBackendKind::Pooled => "pooled",
            FarBackendKind::Distribution => "distribution",
            FarBackendKind::Hybrid => "hybrid",
        }
    }

    pub fn parse(s: &str) -> Option<FarBackendKind> {
        match s {
            "serial-link" | "serial_link" | "serial" | "link" => {
                Some(FarBackendKind::SerialLink)
            }
            "pooled" | "pool" => Some(FarBackendKind::Pooled),
            "distribution" | "dist" => Some(FarBackendKind::Distribution),
            "hybrid" => Some(FarBackendKind::Hybrid),
            _ => None,
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["serial-link", "pooled", "distribution", "hybrid"]
    }
}

/// Channel-selection policy for [`FarBackendKind::Pooled`].
///
/// The pool's throughput under skewed address streams is dominated by how
/// requests are spread across channels: address hashing (the historical
/// default) keeps a line pinned to one channel but lets hot regions
/// saturate it while the rest idle. The alternatives trade affinity for
/// balance. Selected per run via `far.pool_policy` and sweepable as a
/// fingerprinted grid refinement (the default keeps historical sweep
/// fingerprints unchanged).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PoolPolicy {
    /// Multiplicative address hash (the default; deterministic affinity).
    #[default]
    Hash,
    /// Pick the channel with the smallest occupancy-weighted queue (sum of
    /// remaining busy cycles) at issue time; ties go to the lowest index.
    LeastLoaded,
    /// Strict rotation over channels regardless of address or load.
    RoundRobin,
    /// Feedback-driven: starts as `hash` (cheap, affinity-preserving) and
    /// switches to `least-loaded` once the observed congestion fraction
    /// over a sliding window of recent requests crosses
    /// `far.pool_adapt_threshold`. Deterministic — the decision depends
    /// only on the request stream, never on wall-clock time.
    Adaptive,
}

impl PoolPolicy {
    pub const ALL: &'static [PoolPolicy] = &[
        PoolPolicy::Hash,
        PoolPolicy::LeastLoaded,
        PoolPolicy::RoundRobin,
        PoolPolicy::Adaptive,
    ];

    /// Stable spelling used in config files, sweep fingerprints, and the CLI.
    pub fn tag(&self) -> &'static str {
        match self {
            PoolPolicy::Hash => "hash",
            PoolPolicy::LeastLoaded => "least-loaded",
            PoolPolicy::RoundRobin => "round-robin",
            PoolPolicy::Adaptive => "adaptive",
        }
    }

    pub fn parse(s: &str) -> Option<PoolPolicy> {
        match s {
            "hash" => Some(PoolPolicy::Hash),
            "least-loaded" | "least_loaded" | "ll" => Some(PoolPolicy::LeastLoaded),
            "round-robin" | "round_robin" | "rr" => Some(PoolPolicy::RoundRobin),
            "adaptive" | "adapt" => Some(PoolPolicy::Adaptive),
            _ => None,
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["hash", "least-loaded", "round-robin", "adaptive"]
    }
}

/// Per-tenant QoS arbitration policy for a *shared* far-memory backend.
///
/// Multi-tenant runs (`amu-sim mtrun`) point every tenant's simulator at
/// one shared `pooled`/`hybrid` data plane; this policy decides how the
/// shared arbitration point admits competing request streams. Selected
/// per run via `far.qos_policy` and sweepable as a fingerprinted grid
/// refinement exactly like `far.pool_policy` (the default keeps
/// historical sweep fingerprints unchanged). In single-tenant runs the
/// policy still applies — with one tenant `fair-share`/`priority` degrade
/// to pure pass-through pacing, while `throttle` can rate-limit a solo
/// stream that congests its own backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QosPolicyKind {
    /// No arbitration: requests reach the shared backend unmodified.
    #[default]
    None,
    /// Weighted fair sharing: each tenant's admissions are paced so its
    /// long-run bandwidth share converges to `weight / total_weight`.
    FairShare,
    /// Strict admission classes (high > normal > low): a request waits
    /// until every higher class's outstanding service window has drained.
    Priority,
    /// Adaptive per-tenant rate limiting generalizing the pooled
    /// `adaptive` policy: a tenant whose requests keep observing backend
    /// congestion (over a `far.pool_adapt_window` sliding window, trigger
    /// fraction `far.pool_adapt_threshold`) gets a minimum inter-request
    /// gap imposed. Deterministic — driven only by the request stream.
    Throttle,
}

impl QosPolicyKind {
    pub const ALL: &'static [QosPolicyKind] = &[
        QosPolicyKind::None,
        QosPolicyKind::FairShare,
        QosPolicyKind::Priority,
        QosPolicyKind::Throttle,
    ];

    /// Stable spelling used in config files, sweep fingerprints, and the CLI.
    pub fn tag(&self) -> &'static str {
        match self {
            QosPolicyKind::None => "none",
            QosPolicyKind::FairShare => "fair-share",
            QosPolicyKind::Priority => "priority",
            QosPolicyKind::Throttle => "throttle",
        }
    }

    pub fn parse(s: &str) -> Option<QosPolicyKind> {
        match s {
            "none" | "off" => Some(QosPolicyKind::None),
            "fair-share" | "fair_share" | "fair" | "fs" => Some(QosPolicyKind::FairShare),
            "priority" | "prio" | "strict" => Some(QosPolicyKind::Priority),
            "throttle" | "rate-limit" | "rate_limit" | "limit" => Some(QosPolicyKind::Throttle),
            _ => None,
        }
    }

    pub fn names() -> &'static [&'static str] {
        &["none", "fair-share", "priority", "throttle"]
    }
}

/// Latency distribution family for [`FarBackendKind::Distribution`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LatencyDist {
    /// Lognormal with shape `dist_sigma`, rescaled so the mean equals the
    /// configured added latency.
    #[default]
    Lognormal,
    /// Two modes: a `dist_tail_frac` fraction of requests take
    /// `dist_tail_mult` × the configured latency; the rest take a fast
    /// path chosen so the overall mean stays at the configured latency.
    Bimodal,
}

impl LatencyDist {
    pub fn tag(&self) -> &'static str {
        match self {
            LatencyDist::Lognormal => "lognormal",
            LatencyDist::Bimodal => "bimodal",
        }
    }

    pub fn parse(s: &str) -> Option<LatencyDist> {
        match s {
            "lognormal" => Some(LatencyDist::Lognormal),
            "bimodal" => Some(LatencyDist::Bimodal),
            _ => None,
        }
    }
}

/// Far memory: pluggable backend (serial link by default) + remote memory
/// controller. The paper models packet delay (size-dependent), link
/// bandwidth, and a configurable *additional* latency — coherence
/// internals are not modeled.
#[derive(Debug, Clone)]
pub struct FarMemConfig {
    /// Additional one-way-pair (request+response) latency added by the far
    /// tier, in nanoseconds. This is the swept x-axis of Figs 2/8/9/10.
    pub added_latency_ns: f64,
    /// Link bandwidth per direction, GB/s (CXL x8-ish).
    pub bandwidth_gbps: f64,
    /// Per-packet header bytes (flit/protocol overhead).
    pub header_bytes: usize,
    /// Uniform **zero-mean** jitter amplitude as a fraction of added
    /// latency (far memory latency is "long and highly variable"); the
    /// empirical mean round trip stays at the configured latency.
    /// 0.0 disables.
    pub jitter_frac: f64,
    /// Remote memory controller service config.
    pub remote_dram: DramConfig,
    /// Which far-memory data plane serves accesses (`serial-link` default).
    pub backend: FarBackendKind,
    /// `pooled`: number of independent service channels.
    pub pool_channels: usize,
    /// `pooled`: per-channel outstanding-request depth before congestion
    /// back-pressure delays new arrivals.
    pub pool_queue_depth: usize,
    /// `pooled`: channel-selection policy (`hash` default).
    pub pool_policy: PoolPolicy,
    /// `pooled`/`adaptive`: congestion fraction over the sliding window
    /// that triggers the hash -> least-loaded switch (in (0, 1]).
    pub pool_adapt_threshold: f64,
    /// `pooled`/`adaptive`: sliding window length in requests.
    pub pool_adapt_window: usize,
    /// Shared-backend QoS arbitration policy (`none` default). Only
    /// meaningful for `pooled`/`hybrid` backends (the ones `mtrun` can
    /// share between tenants); `throttle` reuses the adaptive knobs
    /// (`pool_adapt_threshold`/`pool_adapt_window`) per tenant.
    pub qos_policy: QosPolicyKind,
    /// `distribution`: latency distribution family.
    pub dist: LatencyDist,
    /// `distribution`/lognormal: shape parameter sigma (0 = deterministic).
    pub dist_sigma: f64,
    /// `distribution`/bimodal: fraction of requests on the slow mode.
    pub dist_tail_frac: f64,
    /// `distribution`/bimodal: slow-mode latency multiplier.
    pub dist_tail_mult: f64,
    /// `hybrid`: fraction of accesses served by the near tier. Only used
    /// when `near_capacity_lines == 0` (the legacy coin-flip model).
    pub near_frac: f64,
    /// `hybrid`: near-tier round-trip latency in ns.
    pub near_latency_ns: f64,
    /// `hybrid`: near-tier capacity in 64 B cache lines. `0` (the default)
    /// keeps the legacy static `near_frac` coin-flip; any positive value
    /// enables the LRU near-tier model, where the fast-path hit rate
    /// emerges from the access stream's actual reuse against this capacity
    /// (tracked in the `near_hits` / `near_evictions` stats).
    pub near_capacity_lines: usize,
}

impl Default for FarMemConfig {
    fn default() -> Self {
        Self {
            added_latency_ns: 1000.0,
            bandwidth_gbps: 16.0,
            header_bytes: 16,
            jitter_frac: 0.05,
            remote_dram: DramConfig::default(),
            backend: FarBackendKind::SerialLink,
            pool_channels: 4,
            pool_queue_depth: 16,
            pool_policy: PoolPolicy::Hash,
            pool_adapt_threshold: 0.5,
            pool_adapt_window: 64,
            qos_policy: QosPolicyKind::None,
            dist: LatencyDist::Lognormal,
            dist_sigma: 0.5,
            dist_tail_frac: 0.05,
            dist_tail_mult: 5.0,
            near_frac: 0.5,
            near_latency_ns: 100.0,
            near_capacity_lines: 0,
        }
    }
}

/// Prefetcher configuration (CXL-Ideal carries an L2 best-offset PF).
#[derive(Debug, Clone, Default)]
pub struct PrefetchConfig {
    pub l2_best_offset: bool,
    /// Prefetch degree per trigger.
    pub degree: usize,
    /// Fraction of L2 MSHRs prefetches may occupy (demand priority).
    pub mshr_quota: f64,
}

/// AMU / AMI configuration.
#[derive(Debug, Clone)]
pub struct AmuConfig {
    pub enabled: bool,
    /// SPM carved out of L2, bytes (paper: 64 KB fixed).
    pub spm_bytes: usize,
    /// AMART entries (queue_length config register default); bounds
    /// outstanding AMI requests.
    pub queue_length: usize,
    /// IDs a list vector register can hold (512-bit reg, 16-bit IDs, one
    /// slot for the pointer -> 31).
    pub lvr_capacity: usize,
    /// DMA-mode: models an external memory engine — LVR capacity 1, no
    /// speculative ID micro-ops, extra uncore round-trip per interaction.
    pub dma_mode: bool,
    /// Extra one-way cycles for DMA-mode engine interaction (NoC/IO bus).
    pub dma_uncore_cycles: u64,
    /// ASMC internal ops per cycle (metadata state machine throughput).
    pub asmc_ops_per_cycle: usize,
    /// SPM access latency in cycles (L2-class).
    pub spm_latency: u64,
    /// Cycles for an ALSU<->ASMC round trip (ID batch fetch, L1-L2 path).
    pub asmc_round_trip: u64,
}

impl Default for AmuConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            spm_bytes: 64 * 1024,
            // 512 x 32 B AMART entries = 16 KB of the 64 KB SPM. Must leave
            // batching headroom above the coroutine count: IDs parked in
            // list vector registers and in-flight batches (up to ~3 x 31)
            // are temporarily unavailable to allocation.
            queue_length: 512,
            lvr_capacity: 31,
            dma_mode: false,
            dma_uncore_cycles: 40,
            asmc_ops_per_cycle: 2,
            spm_latency: 10,
            asmc_round_trip: 24,
        }
    }
}

/// Complete simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub name: String,
    pub seed: u64,
    pub core: CoreConfig,
    pub l1d: CacheConfig,
    pub l2: CacheConfig,
    pub dram: DramConfig,
    pub far: FarMemConfig,
    pub prefetch: PrefetchConfig,
    pub amu: AmuConfig,
    /// Safety valve: abort runs exceeding this many cycles.
    pub max_cycles: u64,
    /// Event-driven fast-forward: when the pipeline is provably at a fixed
    /// point, jump the clock to the next scheduled event and fold the
    /// skipped cycles into the counters in closed form. Statistics are
    /// byte-identical either way; turning it off (`--no-fast-forward`)
    /// only trades host time for a tick-by-tick replay.
    pub fast_forward: bool,
}

fn l1d_table2() -> CacheConfig {
    CacheConfig {
        size_bytes: 32 * 1024,
        ways: 16,
        line_bytes: 64,
        mshrs: 48,
        hit_latency: 4,
        ports: 2,
    }
}

fn l2_table2() -> CacheConfig {
    CacheConfig {
        size_bytes: 256 * 1024,
        ways: 8,
        line_bytes: 64,
        mshrs: 48,
        hit_latency: 10,
        ports: 1,
    }
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig::baseline()
    }
}

impl SimConfig {
    /// Paper Table 2 `Baseline` (Golden-Cove-like, no prefetcher, no AMU).
    pub fn baseline() -> Self {
        Self {
            name: "baseline".into(),
            seed: 0xA11_5EED,
            core: CoreConfig::default(),
            l1d: l1d_table2(),
            l2: l2_table2(),
            dram: DramConfig::default(),
            far: FarMemConfig::default(),
            prefetch: PrefetchConfig::default(),
            amu: AmuConfig::default(),
            max_cycles: 2_000_000_000,
            fast_forward: true,
        }
    }

    /// `CXL Ideal (with BOP)`: 256 MSHRs at each level + L2 best-offset
    /// prefetcher — the paper's upper bound for conventional scaling.
    pub fn cxl_ideal() -> Self {
        let mut c = Self::baseline();
        c.name = "cxl-ideal".into();
        c.l1d.mshrs = 256;
        c.l2.mshrs = 256;
        c.prefetch = PrefetchConfig { l2_best_offset: true, degree: 2, mshr_quota: 0.75 };
        c
    }

    /// Proposed `AMU` configuration (64 KB SPM carved from L2).
    pub fn amu() -> Self {
        let mut c = Self::baseline();
        c.name = "amu".into();
        c.amu.enabled = true;
        // SPM occupies 64 KB of the 256 KB L2: effective cache shrinks.
        c.l2.size_bytes -= c.amu.spm_bytes;
        c
    }

    /// `AMU (DMA-mode)`: external-engine simulation — LVR batching off,
    /// no speculative ID micro-ops, extra uncore latency.
    pub fn amu_dma() -> Self {
        let mut c = Self::amu();
        c.name = "amu-dma".into();
        c.amu.dma_mode = true;
        c.amu.lvr_capacity = 1;
        c
    }

    /// Fig 3 hardware-scaled variants: multiply IQ/LSQ/ROB/MSHR/physregs.
    pub fn scaled(base: &SimConfig, factor: usize, name: &str) -> Self {
        let mut c = base.clone();
        c.name = name.into();
        c.core.rob_entries *= factor;
        c.core.iq_entries *= factor;
        c.core.lq_entries *= factor;
        c.core.sq_entries *= factor;
        c.core.phys_regs *= factor;
        c.l1d.mshrs *= factor;
        c.l2.mshrs *= factor;
        c
    }

    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "baseline" => Some(Self::baseline()),
            "cxl-ideal" | "cxl_ideal" | "cxl" => Some(Self::cxl_ideal()),
            "amu" => Some(Self::amu()),
            "amu-dma" | "amu_dma" | "dma" => Some(Self::amu_dma()),
            "x2" => Some(Self::scaled(&Self::cxl_ideal(), 2, "x2")),
            "x4" => Some(Self::scaled(&Self::cxl_ideal(), 4, "x4")),
            _ => None,
        }
    }

    pub fn preset_names() -> &'static [&'static str] {
        &["baseline", "cxl-ideal", "amu", "amu-dma", "x2", "x4"]
    }

    /// Set far-memory added latency from nanoseconds.
    pub fn with_far_latency_ns(mut self, ns: f64) -> Self {
        self.far.added_latency_ns = ns;
        self
    }

    /// Select the far-memory backend model.
    pub fn with_far_backend(mut self, backend: FarBackendKind) -> Self {
        self.far.backend = backend;
        self
    }

    pub fn far_latency_cycles(&self) -> u64 {
        crate::util::ns_to_cycles(self.far.added_latency_ns, self.core.freq_ghz)
    }

    /// Apply `section.key` overrides from a TOML-lite document. Unknown keys
    /// are rejected so config files can't silently rot.
    pub fn apply_overrides(&mut self, doc: &Document) -> Result<(), String> {
        for (key, _) in doc.entries.iter() {
            let handled = self.apply_one(doc, key)?;
            if !handled {
                return Err(format!("unknown config key '{key}'"));
            }
        }
        Ok(())
    }

    fn apply_one(&mut self, doc: &Document, key: &str) -> Result<bool, String> {
        macro_rules! set_u {
            ($field:expr) => {{
                $field = doc
                    .get_u64(key)
                    .ok_or_else(|| format!("'{key}' must be an integer"))?
                    as _;
                true
            }};
        }
        macro_rules! set_f {
            ($field:expr) => {{
                $field = doc
                    .get_f64(key)
                    .ok_or_else(|| format!("'{key}' must be a number"))?;
                true
            }};
        }
        macro_rules! set_b {
            ($field:expr) => {{
                $field = doc
                    .get_bool(key)
                    .ok_or_else(|| format!("'{key}' must be a bool"))?;
                true
            }};
        }
        Ok(match key {
            "seed" => set_u!(self.seed),
            "max_cycles" => set_u!(self.max_cycles),
            "fast_forward" => set_b!(self.fast_forward),
            "name" => {
                self.name = doc.get_str(key).ok_or("'name' must be a string")?.into();
                true
            }
            "core.freq_ghz" => set_f!(self.core.freq_ghz),
            "core.fetch_width" => set_u!(self.core.fetch_width),
            "core.issue_width" => set_u!(self.core.issue_width),
            "core.commit_width" => set_u!(self.core.commit_width),
            "core.rob_entries" => set_u!(self.core.rob_entries),
            "core.iq_entries" => set_u!(self.core.iq_entries),
            "core.lq_entries" => set_u!(self.core.lq_entries),
            "core.sq_entries" => set_u!(self.core.sq_entries),
            "core.phys_regs" => set_u!(self.core.phys_regs),
            "core.store_buffer" => set_u!(self.core.store_buffer),
            "core.mem_ports" => set_u!(self.core.mem_ports),
            "l1d.size_bytes" => set_u!(self.l1d.size_bytes),
            "l1d.ways" => set_u!(self.l1d.ways),
            "l1d.mshrs" => set_u!(self.l1d.mshrs),
            "l1d.hit_latency" => set_u!(self.l1d.hit_latency),
            "l2.size_bytes" => set_u!(self.l2.size_bytes),
            "l2.ways" => set_u!(self.l2.ways),
            "l2.mshrs" => set_u!(self.l2.mshrs),
            "l2.hit_latency" => set_u!(self.l2.hit_latency),
            "dram.bandwidth_gbps" => set_f!(self.dram.bandwidth_gbps),
            "far.added_latency_ns" => set_f!(self.far.added_latency_ns),
            "far.bandwidth_gbps" => set_f!(self.far.bandwidth_gbps),
            "far.jitter_frac" => set_f!(self.far.jitter_frac),
            "far.backend" => {
                let s = doc.get_str(key).ok_or("'far.backend' must be a string")?;
                self.far.backend = FarBackendKind::parse(s).ok_or_else(|| {
                    format!(
                        "unknown far.backend '{s}' (valid: {})",
                        FarBackendKind::names().join(", ")
                    )
                })?;
                true
            }
            "far.pool_channels" => set_u!(self.far.pool_channels),
            "far.pool_queue_depth" => set_u!(self.far.pool_queue_depth),
            "far.pool_policy" => {
                let s = doc.get_str(key).ok_or("'far.pool_policy' must be a string")?;
                self.far.pool_policy = PoolPolicy::parse(s).ok_or_else(|| {
                    format!(
                        "unknown far.pool_policy '{s}' (valid: {})",
                        PoolPolicy::names().join(", ")
                    )
                })?;
                true
            }
            "far.pool_adapt_threshold" => {
                let v = doc
                    .get_f64(key)
                    .ok_or_else(|| format!("'{key}' must be a number"))?;
                if !(0.0..=1.0).contains(&v) || !v.is_finite() {
                    return Err(format!(
                        "far.pool_adapt_threshold {v} out of range: must be in [0, 1]"
                    ));
                }
                self.far.pool_adapt_threshold = v;
                true
            }
            "far.pool_adapt_window" => {
                let v = doc
                    .get_u64(key)
                    .ok_or_else(|| format!("'{key}' must be an integer"))?;
                if v == 0 {
                    return Err(
                        "far.pool_adapt_window 0 out of range: must be >= 1 request".into()
                    );
                }
                self.far.pool_adapt_window = v as usize;
                true
            }
            "far.qos_policy" => {
                let s = doc.get_str(key).ok_or("'far.qos_policy' must be a string")?;
                self.far.qos_policy = QosPolicyKind::parse(s).ok_or_else(|| {
                    format!(
                        "unknown far.qos_policy '{s}' (valid: {})",
                        QosPolicyKind::names().join(", ")
                    )
                })?;
                true
            }
            "far.dist" => {
                let s = doc.get_str(key).ok_or("'far.dist' must be a string")?;
                self.far.dist = LatencyDist::parse(s)
                    .ok_or_else(|| format!("unknown far.dist '{s}' (valid: lognormal, bimodal)"))?;
                true
            }
            "far.dist_sigma" => set_f!(self.far.dist_sigma),
            "far.dist_tail_frac" => set_f!(self.far.dist_tail_frac),
            "far.dist_tail_mult" => set_f!(self.far.dist_tail_mult),
            "far.near_frac" => set_f!(self.far.near_frac),
            "far.near_latency_ns" => set_f!(self.far.near_latency_ns),
            "far.near_capacity_lines" => set_u!(self.far.near_capacity_lines),
            "prefetch.l2_best_offset" => set_b!(self.prefetch.l2_best_offset),
            "prefetch.degree" => set_u!(self.prefetch.degree),
            "amu.enabled" => set_b!(self.amu.enabled),
            "amu.spm_bytes" => set_u!(self.amu.spm_bytes),
            "amu.queue_length" => set_u!(self.amu.queue_length),
            "amu.lvr_capacity" => set_u!(self.amu.lvr_capacity),
            "amu.dma_mode" => set_b!(self.amu.dma_mode),
            "amu.spm_latency" => set_u!(self.amu.spm_latency),
            _ => false,
        })
    }

    /// Sanity checks that catch nonsensical configs before a run.
    pub fn validate(&self) -> Result<(), String> {
        if self.core.rob_entries == 0 || self.core.phys_regs < 64 {
            return Err("core too small (need >=64 phys regs, >0 ROB)".into());
        }
        if !self.l1d.size_bytes.is_power_of_two() || !self.l2.size_bytes.is_power_of_two() {
            // L2 minus SPM may be non-power-of-two; allow multiples of way*line.
            if self.l1d.size_bytes % (self.l1d.ways * self.l1d.line_bytes) != 0
                || self.l2.size_bytes % (self.l2.ways * self.l2.line_bytes) != 0
            {
                return Err("cache sizes must be multiples of ways*line".into());
            }
        }
        if self.amu.enabled {
            let meta = self.amu.queue_length * 32; // AMART entry ~32 B
            if meta >= self.amu.spm_bytes {
                return Err(format!(
                    "AMART metadata ({meta} B) must leave SPM data room ({} B)",
                    self.amu.spm_bytes
                ));
            }
        }
        if self.far.added_latency_ns < 0.0 || self.far.bandwidth_gbps <= 0.0 {
            return Err("far memory latency/bandwidth out of range".into());
        }
        if !(0.0..=0.5).contains(&self.far.jitter_frac) {
            // Above 0.5 the negative jitter tail would be clamped at the
            // request departure (one-way propagation is added/2), which
            // would re-bias the mean the zero-mean scheme guarantees.
            return Err("far.jitter_frac must be in [0, 0.5]".into());
        }
        if self.far.qos_policy == QosPolicyKind::Throttle {
            // Throttle reuses the adaptive knobs per tenant, regardless of
            // which shareable backend is underneath.
            if !(self.far.pool_adapt_threshold > 0.0 && self.far.pool_adapt_threshold <= 1.0) {
                return Err("throttle qos policy: pool_adapt_threshold must be in (0, 1]".into());
            }
            if self.far.pool_adapt_window == 0 {
                return Err("throttle qos policy: pool_adapt_window must be >= 1".into());
            }
        }
        match self.far.backend {
            FarBackendKind::Pooled => {
                if self.far.pool_channels == 0 || self.far.pool_queue_depth == 0 {
                    return Err("pooled backend needs >=1 channel and queue depth".into());
                }
                if self.far.pool_policy == PoolPolicy::Adaptive {
                    if !(self.far.pool_adapt_threshold > 0.0
                        && self.far.pool_adapt_threshold <= 1.0)
                    {
                        return Err(
                            "adaptive pool policy: pool_adapt_threshold must be in (0, 1]".into()
                        );
                    }
                    if self.far.pool_adapt_window == 0 {
                        return Err("adaptive pool policy: pool_adapt_window must be >= 1".into());
                    }
                }
            }
            FarBackendKind::Distribution => {
                if self.far.dist_sigma < 0.0 || !self.far.dist_sigma.is_finite() {
                    return Err("distribution backend: dist_sigma must be finite and >= 0".into());
                }
                if !(0.0..1.0).contains(&self.far.dist_tail_frac)
                    || self.far.dist_tail_mult < 1.0
                {
                    return Err(
                        "distribution backend: need 0 <= dist_tail_frac < 1, dist_tail_mult >= 1"
                            .into(),
                    );
                }
                if self.far.dist == LatencyDist::Bimodal
                    && self.far.dist_tail_frac * self.far.dist_tail_mult >= 1.0
                {
                    // The fast mode must keep a positive latency for the
                    // mean to stay at the configured value.
                    return Err(
                        "distribution backend: dist_tail_frac * dist_tail_mult must be < 1".into(),
                    );
                }
            }
            FarBackendKind::Hybrid => {
                if !(0.0..=1.0).contains(&self.far.near_frac) {
                    return Err("hybrid backend: near_frac must be in [0, 1]".into());
                }
                if self.far.near_latency_ns < 0.0 || !self.far.near_latency_ns.is_finite() {
                    return Err("hybrid backend: near_latency_ns out of range".into());
                }
            }
            FarBackendKind::SerialLink => {}
        }
        Ok(())
    }

    /// The paper's swept far-memory latencies in ns (0.1–5 µs).
    pub fn paper_latencies_ns() -> &'static [f64] {
        &[100.0, 200.0, 500.0, 1000.0, 2000.0, 5000.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_baseline_matches_paper() {
        let c = SimConfig::baseline();
        assert_eq!(c.core.rob_entries, 512);
        assert_eq!(c.core.phys_regs, 512);
        assert_eq!(c.core.lq_entries + c.core.sq_entries, 192); // 192-entry LSQ
        assert_eq!(c.l1d.size_bytes, 32 * 1024);
        assert_eq!(c.l1d.ways, 16);
        assert_eq!(c.l1d.mshrs, 48);
        assert_eq!(c.l1d.hit_latency, 4);
        assert_eq!(c.l2.size_bytes, 256 * 1024);
        assert_eq!(c.l2.ways, 8);
        assert_eq!(c.l2.hit_latency, 10);
        assert!((c.core.freq_ghz - 3.0).abs() < 1e-12);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn cxl_ideal_has_256_mshrs_and_bop() {
        let c = SimConfig::cxl_ideal();
        assert_eq!(c.l1d.mshrs, 256);
        assert_eq!(c.l2.mshrs, 256);
        assert!(c.prefetch.l2_best_offset);
    }

    #[test]
    fn amu_carves_spm_from_l2() {
        let c = SimConfig::amu();
        assert!(c.amu.enabled);
        assert_eq!(c.amu.spm_bytes, 64 * 1024);
        assert_eq!(c.l2.size_bytes, 192 * 1024);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn dma_mode_limits_lvr() {
        let c = SimConfig::amu_dma();
        assert!(c.amu.dma_mode);
        assert_eq!(c.amu.lvr_capacity, 1);
    }

    #[test]
    fn scaled_variants() {
        let x2 = SimConfig::preset("x2").unwrap();
        assert_eq!(x2.core.rob_entries, 1024);
        assert_eq!(x2.l1d.mshrs, 512);
        let x4 = SimConfig::preset("x4").unwrap();
        assert_eq!(x4.core.rob_entries, 2048);
    }

    #[test]
    fn far_latency_cycles() {
        let c = SimConfig::baseline().with_far_latency_ns(1000.0);
        assert_eq!(c.far_latency_cycles(), 3000); // 1 us @ 3 GHz
    }

    #[test]
    fn overrides_apply_and_reject_unknown() {
        let mut c = SimConfig::baseline();
        let doc = crate::util::toml_lite::parse("[core]\nrob_entries = 64\n").unwrap();
        c.apply_overrides(&doc).unwrap();
        assert_eq!(c.core.rob_entries, 64);
        let bad = crate::util::toml_lite::parse("[core]\nbogus = 1\n").unwrap();
        assert!(c.apply_overrides(&bad).is_err());
    }

    #[test]
    fn validate_rejects_oversized_amart() {
        let mut c = SimConfig::amu();
        c.amu.queue_length = 4096; // 4096*32 = 128 KB > 64 KB SPM
        assert!(c.validate().is_err());
    }

    #[test]
    fn all_presets_valid() {
        for name in SimConfig::preset_names() {
            let c = SimConfig::preset(name).unwrap();
            c.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn backend_tags_round_trip() {
        for &k in FarBackendKind::ALL {
            assert_eq!(FarBackendKind::parse(k.tag()), Some(k));
        }
        assert_eq!(FarBackendKind::parse("dist"), Some(FarBackendKind::Distribution));
        assert!(FarBackendKind::parse("warp9").is_none());
        assert_eq!(FarBackendKind::default(), FarBackendKind::SerialLink);
        assert_eq!(FarBackendKind::names().len(), FarBackendKind::ALL.len());
    }

    #[test]
    fn backend_overrides_apply() {
        let mut c = SimConfig::baseline();
        let doc = crate::util::toml_lite::parse(
            "[far]\nbackend = \"pooled\"\npool_channels = 8\n",
        )
        .unwrap();
        c.apply_overrides(&doc).unwrap();
        assert_eq!(c.far.backend, FarBackendKind::Pooled);
        assert_eq!(c.far.pool_channels, 8);
        let bad = crate::util::toml_lite::parse("[far]\nbackend = \"warp9\"\n").unwrap();
        let e = c.apply_overrides(&bad).unwrap_err();
        assert!(e.contains("serial-link"), "{e}");
    }

    #[test]
    fn pool_policy_tags_round_trip() {
        for &p in PoolPolicy::ALL {
            assert_eq!(PoolPolicy::parse(p.tag()), Some(p));
        }
        assert_eq!(PoolPolicy::parse("ll"), Some(PoolPolicy::LeastLoaded));
        assert_eq!(PoolPolicy::parse("rr"), Some(PoolPolicy::RoundRobin));
        assert_eq!(PoolPolicy::parse("adapt"), Some(PoolPolicy::Adaptive));
        assert!(PoolPolicy::parse("warp9").is_none());
        assert_eq!(PoolPolicy::default(), PoolPolicy::Hash);
        assert_eq!(PoolPolicy::names().len(), PoolPolicy::ALL.len());
    }

    #[test]
    fn pool_policy_and_near_capacity_overrides_apply() {
        let mut c = SimConfig::baseline();
        let doc = crate::util::toml_lite::parse(
            "[far]\npool_policy = \"least-loaded\"\nnear_capacity_lines = 4096\n",
        )
        .unwrap();
        c.apply_overrides(&doc).unwrap();
        assert_eq!(c.far.pool_policy, PoolPolicy::LeastLoaded);
        assert_eq!(c.far.near_capacity_lines, 4096);
        // Unknown policy spellings are rejected naming the valid choices.
        let bad = crate::util::toml_lite::parse("[far]\npool_policy = \"warp9\"\n").unwrap();
        let e = c.apply_overrides(&bad).unwrap_err();
        assert!(e.contains("least-loaded") && e.contains("round-robin"), "{e}");
        // Defaults keep the historical models (hash pool, coin-flip hybrid).
        let d = FarMemConfig::default();
        assert_eq!(d.pool_policy, PoolPolicy::Hash);
        assert_eq!(d.near_capacity_lines, 0);
    }

    #[test]
    fn adaptive_policy_overrides_and_validation() {
        let mut c = SimConfig::baseline().with_far_backend(FarBackendKind::Pooled);
        let doc = crate::util::toml_lite::parse(
            "[far]\npool_policy = \"adaptive\"\npool_adapt_threshold = 0.25\n\
             pool_adapt_window = 32\n",
        )
        .unwrap();
        c.apply_overrides(&doc).unwrap();
        assert_eq!(c.far.pool_policy, PoolPolicy::Adaptive);
        assert_eq!(c.far.pool_adapt_threshold, 0.25);
        assert_eq!(c.far.pool_adapt_window, 32);
        assert!(c.validate().is_ok());
        // Out-of-range adaptive parameters are rejected.
        c.far.pool_adapt_threshold = 0.0;
        assert!(c.validate().is_err());
        c.far.pool_adapt_threshold = 0.5;
        c.far.pool_adapt_window = 0;
        assert!(c.validate().is_err());
        // Defaults are sane, so `--pool-policy adaptive` works unconfigured.
        let d = FarMemConfig::default();
        assert!(d.pool_adapt_threshold > 0.0 && d.pool_adapt_threshold <= 1.0);
        assert!(d.pool_adapt_window >= 1);
    }

    #[test]
    fn qos_policy_tags_round_trip() {
        for &p in QosPolicyKind::ALL {
            assert_eq!(QosPolicyKind::parse(p.tag()), Some(p));
        }
        assert_eq!(QosPolicyKind::parse("fair"), Some(QosPolicyKind::FairShare));
        assert_eq!(QosPolicyKind::parse("fs"), Some(QosPolicyKind::FairShare));
        assert_eq!(QosPolicyKind::parse("prio"), Some(QosPolicyKind::Priority));
        assert_eq!(QosPolicyKind::parse("rate-limit"), Some(QosPolicyKind::Throttle));
        assert_eq!(QosPolicyKind::parse("off"), Some(QosPolicyKind::None));
        assert!(QosPolicyKind::parse("warp9").is_none());
        assert_eq!(QosPolicyKind::default(), QosPolicyKind::None);
        assert_eq!(QosPolicyKind::names().len(), QosPolicyKind::ALL.len());
    }

    #[test]
    fn qos_policy_overrides_apply_and_reject_unknown() {
        let mut c = SimConfig::baseline();
        let doc = crate::util::toml_lite::parse("[far]\nqos_policy = \"fair-share\"\n").unwrap();
        c.apply_overrides(&doc).unwrap();
        assert_eq!(c.far.qos_policy, QosPolicyKind::FairShare);
        let bad = crate::util::toml_lite::parse("[far]\nqos_policy = \"warp9\"\n").unwrap();
        let e = c.apply_overrides(&bad).unwrap_err();
        assert!(e.contains("fair-share") && e.contains("throttle"), "{e}");
        // Default keeps single-tenant runs arbitration-free.
        assert_eq!(FarMemConfig::default().qos_policy, QosPolicyKind::None);
    }

    #[test]
    fn adaptive_knobs_are_bounds_checked_at_parse_time() {
        // In-range values apply.
        let mut c = SimConfig::baseline();
        let ok = crate::util::toml_lite::parse(
            "[far]\npool_adapt_threshold = 0.75\npool_adapt_window = 16\n",
        )
        .unwrap();
        c.apply_overrides(&ok).unwrap();
        assert_eq!(c.far.pool_adapt_threshold, 0.75);
        assert_eq!(c.far.pool_adapt_window, 16);
        // Out-of-range threshold is rejected at parse time, naming [0, 1].
        let bad = crate::util::toml_lite::parse("[far]\npool_adapt_threshold = 1.5\n").unwrap();
        let e = c.apply_overrides(&bad).unwrap_err();
        assert!(e.contains("[0, 1]"), "{e}");
        let bad = crate::util::toml_lite::parse("[far]\npool_adapt_threshold = -0.1\n").unwrap();
        let e = c.apply_overrides(&bad).unwrap_err();
        assert!(e.contains("[0, 1]"), "{e}");
        // Zero-length window is rejected at parse time, naming the bound.
        let bad = crate::util::toml_lite::parse("[far]\npool_adapt_window = 0\n").unwrap();
        let e = c.apply_overrides(&bad).unwrap_err();
        assert!(e.contains(">= 1"), "{e}");
        // The rejected overrides did not clobber the applied values.
        assert_eq!(c.far.pool_adapt_threshold, 0.75);
        assert_eq!(c.far.pool_adapt_window, 16);
    }

    #[test]
    fn throttle_qos_reuses_and_validates_adaptive_knobs() {
        let mut c = SimConfig::baseline().with_far_backend(FarBackendKind::Pooled);
        c.far.qos_policy = QosPolicyKind::Throttle;
        assert!(c.validate().is_ok());
        c.far.pool_adapt_window = 0;
        assert!(c.validate().is_err());
        c.far.pool_adapt_window = 64;
        c.far.pool_adapt_threshold = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn backend_validation_catches_bad_params() {
        let mut c = SimConfig::baseline().with_far_backend(FarBackendKind::Pooled);
        c.far.pool_channels = 0;
        assert!(c.validate().is_err());

        let mut c = SimConfig::baseline().with_far_backend(FarBackendKind::Distribution);
        c.far.dist = LatencyDist::Bimodal;
        c.far.dist_tail_frac = 0.5;
        c.far.dist_tail_mult = 3.0; // 0.5 * 3 >= 1: fast mode would go negative
        assert!(c.validate().is_err());

        let mut c = SimConfig::baseline().with_far_backend(FarBackendKind::Hybrid);
        c.far.near_frac = 1.5;
        assert!(c.validate().is_err());

        let mut c = SimConfig::baseline();
        c.far.jitter_frac = 0.8; // would clamp the negative tail and re-bias the mean
        assert!(c.validate().is_err());

        for &k in FarBackendKind::ALL {
            assert!(SimConfig::baseline().with_far_backend(k).validate().is_ok(), "{k:?}");
        }
    }
}
